//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The container has no crates.io access, so the subset of anyhow this
//! repo uses — `Result`, `Error`, `anyhow!`, `bail!`, and the `Context`
//! extension trait over `Result`/`Option` — is implemented here.  Error
//! values carry a flattened message chain ("outer context: inner error")
//! rather than a source chain; that is all the callers ever format.

use std::fmt;

/// Flattened error: the full context chain rendered into one string.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket conversion coherent.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error { msg: e.to_string() }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(..)` / `.with_context(..)` on `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_into_message() {
        let err = io_fail().unwrap_err();
        assert!(err.to_string().starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let err = v.context("missing field").unwrap_err();
        assert_eq!(err.to_string(), "missing field");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        fn f() -> Result<()> {
            bail!("nope {x}", x = 1)
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");
    }
}
