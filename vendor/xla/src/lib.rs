//! Vendored stub of the `xla` (PJRT) bindings.
//!
//! The real crate links libxla_extension, which is not in this container.
//! This stub keeps the exact API surface `runtime/` compiles against:
//!
//! - [`Literal`] is FUNCTIONAL (host tensors round-trip through it, so
//!   `runtime::tensor` conversions are fully testable);
//! - [`PjRtClient::cpu`] returns an error, so `Engine::new()` fails
//!   cleanly and every artifact-backed path reports "PJRT unavailable"
//!   instead of crashing.  Native (pure-rust) paths never touch this.
//!
//! Swapping in the real bindings is a Cargo.toml change only.

use std::fmt;

#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what} unavailable: this build vendors an xla stub (no \
         libxla_extension in the container); artifact-backed paths need \
         the real PJRT toolchain"
    ))
}

/// Marker trait mirroring `xla::ArrayElement`.
pub trait ArrayElement {}
impl ArrayElement for f32 {}
impl ArrayElement for i32 {}

/// Element types a [`Literal`] can hold, mirroring `xla::NativeType`.
pub trait NativeType: Sized + Copy {
    fn store(data: Vec<Self>) -> Elems;
    fn load(elems: &Elems) -> Option<&[Self]>;
}

#[derive(Debug, Clone)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl Elems {
    fn len(&self) -> usize {
        match self {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
            Elems::Tuple(v) => v.len(),
        }
    }
}

impl NativeType for f32 {
    fn store(data: Vec<f32>) -> Elems {
        Elems::F32(data)
    }
    fn load(elems: &Elems) -> Option<&[f32]> {
        match elems {
            Elems::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn store(data: Vec<i32>) -> Elems {
        Elems::I32(data)
    }
    fn load(elems: &Elems) -> Option<&[i32]> {
        match elems {
            Elems::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Host-side array value.  Functional in the stub.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
    elems: Elems,
}

#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            elems: T::store(data.to_vec()),
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.elems.len() {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { dims: dims.to_vec(), elems: self.elems.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.elems {
            Elems::Tuple(_) => Err(Error("literal is a tuple".to_string())),
            _ => Ok(ArrayShape { dims: self.dims.clone() }),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::load(&self.elems)
            .map(|s| s.to_vec())
            .ok_or_else(|| Error("literal dtype mismatch".to_string()))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.elems {
            Elems::Tuple(v) => Ok(v.clone()),
            _ => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module.  The stub cannot parse HLO text.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(unavailable(&format!("HLO text parsing ({path})")))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("XLA compilation"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("device-to-host transfer"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PJRT execution"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[7]).is_err());
    }

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().unwrap().to_string();
        assert!(err.contains("unavailable"), "{err}");
    }
}
