"""The Sparsely-Gated Mixture-of-Experts layer (paper Section 2).

Forward: noisy top-k gating -> capacity dispatch -> batched expert FFN
(Pallas kernel) -> weighted combine (eq 1).  Dispatch uses the Mesh-TF
one-hot formulation so the whole layer lowers to dense HLO inside the AOT
artifact; the rust coordinator implements the *same* routing with real
scatter/gather for the distributed simulation (equality tested on both
sides).

Capacity note: the paper's TF implementation used dynamically-shaped
per-expert batches; XLA requires static shapes, so the AOT path gives each
expert ``capacity_factor * k * tokens / n`` slots and counts dropped
routes (reported in metrics; the rust distributed path drops nothing).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import gating
from .kernels.dispatch import combine as combine_kernel
from .kernels.dispatch import dispatch as dispatch_kernel
from .kernels.expert_ffn import expert_ffn
from .params import ParamSpec


class MoEOut(NamedTuple):
    y: jax.Array
    balance_loss: jax.Array
    cv_importance: jax.Array
    cv_load: jax.Array
    max_over_mean_load: jax.Array
    dropped_frac: jax.Array
    gates: jax.Array


def register_moe(spec: ParamSpec, name: str, d: int, h: int, n: int,
                 groups: int = 0):
    """Gating nets init to zero (Appendix A: equal initial load)."""
    if groups:
        b = n // groups
        spec.add(f"{name}.wg_pri", (d, groups), "zeros")
        spec.add(f"{name}.wn_pri", (d, groups), "zeros")
        spec.add(f"{name}.wg_sec", (d, groups, b), "zeros")
        spec.add(f"{name}.wn_sec", (d, groups, b), "zeros")
    else:
        spec.add(f"{name}.wg", (d, n), "zeros")
        spec.add(f"{name}.wn", (d, n), "zeros")
    spec.add(f"{name}.w_in", (n, d, h), "normal")
    spec.add(f"{name}.w_out", (n, h, d), "normal")


def _ffn_ref(x, w_in, w_out):
    from .kernels import ref
    return ref.expert_ffn_ref(x, w_in, w_out)


def gather_dispatch(gates, x, capacity):
    """Index-based dispatch (§Perf): build the (n, capacity, d) expert
    input tensor with ONE scatter of token indices plus ONE gather of
    rows — cost O(B*n + n*cap*d) — instead of the O(B*n*cap*d) one-hot
    contraction.  This is what the paper's TensorFlow implementation did
    (gather / unsorted_segment_sum); the einsum path is kept for ablation.

    Returns (expert_in, dropped_frac, aux) where aux carries the
    per-token slot bookkeeping for `gather_combine`.
    """
    b, n = gates.shape
    d = x.shape[-1]
    nonzero = (gates > 0).astype(jnp.int32)
    pos = jnp.cumsum(nonzero, axis=0) - 1                 # (B, n)
    keep = (nonzero == 1) & (pos < capacity)
    routes = jnp.sum(nonzero)
    dropped = 1.0 - jnp.sum(keep.astype(jnp.float32)) / jnp.maximum(routes, 1)
    # scatter: src[e, slot] = token row (B = "empty" sentinel -> zero row)
    slot = jnp.where(keep, pos, capacity)                 # (B, n)
    src = jnp.full((n, capacity + 1), b, jnp.int32)
    cols = jnp.broadcast_to(jnp.arange(n)[None, :], (b, n))
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], (b, n)).astype(jnp.int32)
    src = src.at[cols, slot].set(rows, mode="drop")
    src = src[:, :capacity]                               # (n, cap)
    xpad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    expert_in = xpad[src]                                 # (n, cap, d)
    return expert_in, dropped, (pos, keep)


def gather_combine(gates, expert_out, aux, k):
    """y[b] = sum_j gate_j * expert_out[e_j, slot_j]  over the k selected
    experts (eq 1), via one (B, k, d) gather — cost O(B*k*d).

    Gate gradients flow through the take_along_axis of the dense gates
    (the paper §2.1 gradient path); integer indices carry none.  `k` is
    the static per-token expert count (cfg.k_effective).  Ties in the
    gate row may put a zero-gate expert into the top-k — harmless, its
    weight is 0.
    """
    from .kernels.ref import topk_vals_idx
    pos, keep = aux
    n, capacity, d = expert_out.shape
    _, idx = topk_vals_idx(gates, k)                      # (B, k) int32
    topw = jnp.take_along_axis(gates, idx, axis=-1)       # differentiable
    p = jnp.take_along_axis(pos, idx, axis=-1)            # (B, k)
    kept = jnp.take_along_axis(keep, idx, axis=-1)
    flat_idx = jnp.where(kept, idx * capacity + p, n * capacity)
    eo_pad = jnp.concatenate(
        [expert_out.reshape(n * capacity, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    picked = eo_pad[flat_idx]                             # (B, k, d)
    return jnp.sum(topw[..., None] * picked, axis=1)


def positions(gates, capacity):
    """Batch-order slot assignment within each expert queue.

    Returns (pos_oh (B,n,cap) one-hot float, dropped_frac scalar).
    """
    nonzero = (gates > 0).astype(jnp.int32)
    pos = jnp.cumsum(nonzero, axis=0) - 1
    keep = nonzero * (pos < capacity).astype(jnp.int32)
    routes = jnp.sum(nonzero)
    dropped = 1.0 - jnp.sum(keep) / jnp.maximum(routes, 1)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=gates.dtype) \
        * keep[..., None].astype(gates.dtype)
    return pos_oh, dropped


def moe_layer(spec: ParamSpec, flat, name: str, x, rng, cfg, *,
              train: bool, use_kernels: bool = True) -> MoEOut:
    """x: (tokens, d) -- the layer is applied convolutionally (§3.1): the
    caller flattens (B, T, d) so all timesteps share one big batch."""
    n, k = cfg.n_experts, cfg.k
    toks = x.shape[0]
    if cfg.hierarchical:
        a, b = cfg.groups, cfg.group_size
        r1, r2 = jax.random.split(rng)
        g = gating.hierarchical_gating(
            x, spec.get(flat, f"{name}.wg_pri"),
            spec.get(flat, f"{name}.wn_pri"),
            spec.get(flat, f"{name}.wg_sec"),
            spec.get(flat, f"{name}.wn_sec"),
            jax.random.normal(r1, (toks, a)),
            jax.random.normal(r2, (toks, a, b)),
            k, w_importance=cfg.w_importance, w_load=cfg.w_load, train=train)
    else:
        noise = jax.random.normal(rng, (toks, n))
        g = gating.flat_gating(
            x, spec.get(flat, f"{name}.wg"), spec.get(flat, f"{name}.wn"),
            noise, k, w_importance=cfg.w_importance, w_load=cfg.w_load,
            train=train, use_kernel=use_kernels)

    capacity = cfg.capacity
    w_in = spec.get(flat, f"{name}.w_in")
    w_out = spec.get(flat, f"{name}.w_out")
    dispatch_mode = getattr(cfg, "dispatch", "gather")
    if dispatch_mode == "gather":
        expert_in, dropped, aux = gather_dispatch(g.gates, x, capacity)
        expert_out = (expert_ffn(expert_in, w_in, w_out) if use_kernels
                      else _ffn_ref(expert_in, w_in, w_out))
        y = gather_combine(g.gates, expert_out, aux, cfg.k_effective)
    else:
        pos_oh, dropped = positions(g.gates, capacity)
        if use_kernels:
            expert_in = dispatch_kernel(pos_oh, x)
            expert_out = expert_ffn(expert_in, w_in, w_out)
            y = combine_kernel(pos_oh * g.gates[..., None], expert_out)
        else:
            expert_in = jnp.einsum("bnc,bd->ncd", pos_oh, x)
            expert_out = _ffn_ref(expert_in, w_in, w_out)
            from .kernels import ref
            y = ref.combine_ref(expert_out, pos_oh * g.gates[..., None])

    mean_load = jnp.mean(g.load) + 1e-10
    return MoEOut(y, g.balance_loss, g.cv_importance, g.cv_load,
                  jnp.max(g.load) / mean_load, dropped, g.gates)
