"""L2: the paper's language model (Appendix C.1).

Five layers: embedding -> LSTM -> MoE -> LSTM -> softmax, with dropout on
every non-softmax layer output followed by a residual add (He et al. 2015).
The MoE is applied *convolutionally* (§3.1): all B*T positions form one
large batch for the MoE layer.  The middle layer is swappable to reproduce
the paper's computationally-matched baselines (MoE-1-Wide, MoE-1-Deep,
4xLSTM-512, LSTM-2048-512).

``build(cfg)`` returns the pure functions that ``aot.py`` lowers to HLO:
init / train_step / eval_step / decode_step, all over the flat parameter
vector (see params.py).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import lstm, moe, optim
from .configs import ModelConfig
from .params import ParamSpec

METRIC_NAMES = ["loss", "nll", "balance_loss", "cv_importance", "cv_load",
                "max_over_mean_load", "dropped_frac", "grad_norm", "lr"]


class Built(NamedTuple):
    spec: ParamSpec
    init: callable          # (seed i32) -> (params, m, v)
    train_step: callable    # (params, m, v, tokens, step) -> (p, m, v, metrics)
    eval_step: callable     # (params, tokens) -> [nll_sum, count]
    decode_step: callable   # (params, cs, hs, token) -> (logits, cs, hs)
    forward: callable       # debug/tests: (params, tokens_in, rng, train)
    n_lstm: int


def make_spec(cfg: ModelConfig) -> ParamSpec:
    spec = ParamSpec()
    d, h = cfg.d_model, cfg.lstm_hidden
    spec.add("embed", (cfg.vocab, d), "normal")
    lstm.register_lstm(spec, "lstm1", d, h, cfg.lstm_proj)
    if cfg.middle == "moe":
        moe.register_moe(spec, "moe", d, cfg.expert_hidden, cfg.n_experts,
                         cfg.groups)
    elif cfg.middle == "wide":
        spec.add("wide.w_in", (d, cfg.expert_hidden), "normal")
        spec.add("wide.w_out", (cfg.expert_hidden, d), "normal")
    elif cfg.middle == "deep":
        eh = cfg.expert_hidden
        dims = [d, eh, eh, eh, eh, d]
        for i in range(5):
            spec.add(f"deep.w{i}", (dims[i], dims[i + 1]), "normal")
    elif cfg.middle == "lstm":
        lstm.register_lstm(spec, "mid1", d, h, cfg.lstm_proj)
        lstm.register_lstm(spec, "mid2", d, h, cfg.lstm_proj)
    elif cfg.middle == "none":
        pass
    else:
        raise ValueError(cfg.middle)
    lstm.register_lstm(spec, "lstm2", d, h, cfg.lstm_proj)
    spec.add("softmax.w", (d, cfg.vocab), "normal")
    spec.add("softmax.b", (cfg.vocab,), "zeros")
    return spec


def middle_lstm_names(cfg: ModelConfig) -> list[str]:
    names = ["lstm1"]
    if cfg.middle == "lstm":
        names += ["mid1", "mid2"]
    names.append("lstm2")
    return names


def _dropout(x, rate, rng, train):
    if not train or rate == 0.0:
        return x
    keep = 1.0 - rate
    mask = jax.random.bernoulli(rng, keep, x.shape)
    return jnp.where(mask, x / keep, 0.0)


class MiddleOut(NamedTuple):
    y: jax.Array
    balance_loss: jax.Array
    metrics: tuple  # cv_imp, cv_load, max_over_mean, dropped


def _middle(spec, flat, cfg, x_flat, rng, train, use_kernels):
    """x_flat: (T*B, d) — the convolutional MoE batch."""
    zero = jnp.float32(0.0)
    if cfg.middle == "moe":
        out = moe.moe_layer(spec, flat, "moe", x_flat, rng, cfg, train=train,
                            use_kernels=use_kernels)
        return MiddleOut(jax.nn.sigmoid(out.y), out.balance_loss,
                         (out.cv_importance, out.cv_load,
                          out.max_over_mean_load, out.dropped_frac))
    if cfg.middle == "wide":
        hid = jnp.maximum(x_flat @ spec.get(flat, "wide.w_in"), 0.0)
        y = hid @ spec.get(flat, "wide.w_out")
        return MiddleOut(jax.nn.sigmoid(y), zero, (zero, zero, zero, zero))
    if cfg.middle == "deep":
        y = x_flat
        for i in range(5):
            y = y @ spec.get(flat, f"deep.w{i}")
            if i < 4:
                y = jnp.maximum(y, 0.0)
        return MiddleOut(jax.nn.sigmoid(y), zero, (zero, zero, zero, zero))
    return MiddleOut(x_flat, zero, (zero, zero, zero, zero))


def build(cfg: ModelConfig, use_kernels: bool = True) -> Built:
    spec = make_spec(cfg)
    d, h = cfg.d_model, cfg.lstm_hidden
    proj = cfg.lstm_proj
    n_lstm = 4 if cfg.middle == "lstm" else 2

    # ---------------------------------------------------------- forward --

    def forward(flat, tokens_in, rng, train):
        """tokens_in: (B, T) i32 -> logits (B, T, vocab) + middle stats."""
        b, t = tokens_in.shape
        r_emb, r_l1, r_mid, r_midd, r_l2 = jax.random.split(rng, 5)
        emb = spec.get(flat, "embed")
        x = emb[tokens_in]                       # (B, T, d)
        x = _dropout(x, cfg.dropout, r_emb, train)
        xs = jnp.transpose(x, (1, 0, 2))         # (T, B, d)

        y1 = lstm.lstm_scan(spec, flat, "lstm1", xs, h, proj)
        xs = xs + _dropout(y1, cfg.dropout, r_l1, train)

        if cfg.middle == "lstm":
            ym1 = lstm.lstm_scan(spec, flat, "mid1", xs, h, proj)
            xs = xs + _dropout(ym1, cfg.dropout, r_mid, train)
            ym2 = lstm.lstm_scan(spec, flat, "mid2", xs, h, proj)
            xs = xs + _dropout(ym2, cfg.dropout, r_midd, train)
            mid = MiddleOut(None, jnp.float32(0.0),
                            tuple(jnp.float32(0.0) for _ in range(4)))
        elif cfg.middle == "none":
            mid = MiddleOut(None, jnp.float32(0.0),
                            tuple(jnp.float32(0.0) for _ in range(4)))
        else:
            flat_x = xs.reshape(t * b, d)        # convolutional batch
            mid = _middle(spec, flat, cfg, flat_x, r_mid, train, use_kernels)
            y = _dropout(mid.y.reshape(t, b, d), cfg.dropout, r_midd, train)
            xs = xs + y

        y2 = lstm.lstm_scan(spec, flat, "lstm2", xs, h, proj)
        xs = xs + _dropout(y2, cfg.dropout, r_l2, train)

        logits = xs @ spec.get(flat, "softmax.w") + spec.get(flat, "softmax.b")
        return jnp.transpose(logits, (1, 0, 2)), mid

    def nll(logits, targets):
        logp = jax.nn.log_softmax(logits, axis=-1)
        picked = jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return -jnp.mean(picked)

    # ------------------------------------------------------------- init --

    def init(seed):
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), seed)
        flat = spec.init_flat(key)
        m_sz, v_sz = optim.opt_sizes(cfg, spec)
        return flat, jnp.zeros((m_sz,)), jnp.zeros((v_sz,))

    # ------------------------------------------------------- train_step --

    def train_step(flat, m, v, tokens, step):
        """tokens: (B, T+1) i32; step: i32 scalar."""
        rng = jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 1), step)

        def loss_fn(p):
            logits, mid = forward(p, tokens[:, :-1], rng, True)
            nll_v = nll(logits, tokens[:, 1:])
            return nll_v + mid.balance_loss, (nll_v, mid)

        (loss, (nll_v, mid)), grad = jax.value_and_grad(
            loss_fn, has_aux=True)(flat)
        new_flat, m, v = optim.update(cfg, spec, flat, m, v, grad, step)
        gnorm = jnp.sqrt(jnp.sum(grad * grad))
        lr = optim.lr_schedule(cfg.learning_rate, cfg.warmup_steps, step)
        metrics = jnp.stack([loss, nll_v, mid.balance_loss, *mid.metrics,
                             gnorm, lr])
        return new_flat, m, v, metrics

    # -------------------------------------------------------- eval_step --

    def eval_step(flat, tokens):
        rng = jax.random.PRNGKey(0)
        logits, _ = forward(flat, tokens[:, :-1], rng, False)
        logp = jax.nn.log_softmax(logits, axis=-1)
        picked = jnp.take_along_axis(logp, tokens[:, 1:, None], axis=-1)
        count = tokens[:, 1:].size
        return jnp.stack([-jnp.sum(picked), jnp.float32(count)])

    # ------------------------------------------------------ decode_step --

    def decode_step(flat, cs, hs, token):
        """Incremental decode.  cs: (L, B, d_h); hs: (L, B, d_out);
        token: (B,) i32 -> (logits (B, vocab), cs', hs')."""
        rng = jax.random.PRNGKey(0)
        names = middle_lstm_names(cfg)
        emb = spec.get(flat, "embed")
        x = emb[token]
        new_c, new_h = [], []
        li = 0
        c, hh = lstm.lstm_step(spec, flat, names[li], x, cs[li], hs[li], proj)
        new_c.append(c); new_h.append(hh)
        x = x + hh
        li += 1
        if cfg.middle == "lstm":
            for nm in ("mid1", "mid2"):
                c, hh = lstm.lstm_step(spec, flat, nm, x, cs[li], hs[li], proj)
                new_c.append(c); new_h.append(hh)
                x = x + hh
                li += 1
        elif cfg.middle != "none":
            midv = _middle(spec, flat, cfg, x, rng, False, use_kernels)
            x = x + midv.y
        c, hh = lstm.lstm_step(spec, flat, names[-1], x, cs[li], hs[li], proj)
        new_c.append(c); new_h.append(hh)
        x = x + hh
        logits = x @ spec.get(flat, "softmax.w") + spec.get(flat, "softmax.b")
        return logits, jnp.stack(new_c), jnp.stack(new_h)

    return Built(spec, init, train_step, eval_step, decode_step, forward,
                 n_lstm)
