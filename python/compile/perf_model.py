"""L1 perf model: VMEM footprint + MXU utilisation estimates for the
Pallas kernels (DESIGN.md §Perf).

interpret=True gives CPU-numpy timings only — not a TPU proxy — so the
kernel perf story is *structural*: for each config we report, per kernel,

  - the chosen BlockSpec tile shapes,
  - the per-grid-step VMEM footprint (must fit the ~16 MiB/core budget),
  - MXU tile utilisation: fraction of the 128x128 systolic array's
    capacity used by the inner matmuls (dims rounded up to 128 lanes /
    8 sublanes),
  - arithmetic intensity (FLOPs per HBM byte), which must exceed the
    TPU's compute/bandwidth ratio for the kernel to be compute-bound —
    the §3.2 criterion with VMEM in place of the network.

Run:  python -m compile.perf_model [config ...]
"""

from __future__ import annotations

import sys

from . import configs
from .kernels.expert_ffn import pick_block_c, vmem_bytes

MXU = 128          # systolic array dimension
SUBLANE = 8
VMEM_BUDGET = 16 * 2 ** 20


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def mxu_utilisation(m: int, k: int, n: int) -> float:
    """Fraction of MXU capacity used by an (m,k)@(k,n) matmul: real MACs
    over MACs of the padded (lane/sublane-rounded) computation."""
    real = m * k * n
    padded = _round_up(m, SUBLANE) * _round_up(k, MXU) * _round_up(n, MXU)
    return real / padded


def expert_kernel_report(cfg: configs.ModelConfig) -> dict:
    d, h, cap = cfg.d_model, cfg.expert_hidden, cfg.capacity
    block_c = pick_block_c(cap, d, h)
    vmem = vmem_bytes(block_c, d, h)
    # two matmuls: (block_c,d)@(d,h) and (block_c,h)@(h,d)
    util = (mxu_utilisation(block_c, d, h) + mxu_utilisation(block_c, h, d)) / 2
    flops = 2 * 2 * block_c * d * h                   # both matmuls, MAC=2
    hbm_bytes = 4 * (block_c * d * 2 + d * h * 2)     # tokens io + weights
    return {
        "kernel": "expert_ffn",
        "grid": (cfg.n_experts, max(1, -(-cap // block_c))),
        "block": (block_c, d, h),
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_BUDGET,
        "mxu_util": util,
        "arith_intensity": flops / hbm_bytes,
    }


def gating_kernel_report(cfg: configs.ModelConfig) -> dict:
    d = cfg.d_model
    n = cfg.n_experts if not cfg.hierarchical else cfg.groups
    b = min(cfg.batch * cfg.seq_len, 256)
    vmem = 4 * (b * d + 2 * d * n + 4 * b * n)
    return {
        "kernel": "noisy_topk_gating",
        "block": (b, d, n),
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= VMEM_BUDGET,
        "mxu_util": mxu_utilisation(b, d, n),
        "arith_intensity": (2 * 2 * b * d * n) / (4 * (b * d + 2 * d * n + b * n)),
    }


def report(names: list[str]) -> None:
    print(f"{'config':<18} {'kernel':<18} {'block':<16} {'VMEM':>9} "
          f"{'fits':>5} {'MXU util':>9} {'FLOP/B':>7}")
    for name in names:
        cfg = configs.get(name)
        if cfg.middle != "moe":
            continue
        for r in (expert_kernel_report(cfg), gating_kernel_report(cfg)):
            print(f"{name:<18} {r['kernel']:<18} "
                  f"{str(r['block']):<16} {r['vmem_bytes'] / 2**20:>8.2f}M "
                  f"{'yes' if r['vmem_ok'] else 'NO':>5} "
                  f"{r['mxu_util']:>9.3f} {r['arith_intensity']:>7.1f}")


if __name__ == "__main__":
    names = sys.argv[1:] or [n for n in configs.CONFIGS]
    report(names)
