"""L2 gating: noisy top-k (Section 2.1), load/importance losses (Section 4,
Appendix A), hierarchical gating (Appendix B) and the strictly-balanced
batchwise gating (Appendix F).

The flat-gating hot path calls the L1 Pallas kernel; the hierarchical
secondary gating and the smooth load estimator stay in jnp (tiny compute,
needs norm.cdf).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.gating_kernel import noisy_topk_gating


class GatingOut(NamedTuple):
    gates: jax.Array        # (B, n) dense, k nonzeros per row
    importance: jax.Array   # (n,)
    load: jax.Array         # (n,)
    balance_loss: jax.Array  # scalar: wi*CV^2(imp) + wl*CV^2(load)
    cv_importance: jax.Array
    cv_load: jax.Array


def _balance(gates, load, w_importance, w_load):
    importance = jnp.sum(gates, axis=0)
    cv_imp = ref.cv_squared(importance)
    cv_load = ref.cv_squared(load)
    loss = w_importance * cv_imp + w_load * cv_load
    return importance, cv_imp, cv_load, loss


def flat_gating(x, w_g, w_noise, noise, k, *, w_importance, w_load,
                train: bool, use_kernel: bool = True) -> GatingOut:
    """Noisy top-k gating over n experts.  x: (B, d)."""
    n = w_g.shape[-1]
    wn = w_noise if train else None
    fn = noisy_topk_gating if use_kernel else (
        lambda x, wg, wn_, nz, k: ref.noisy_topk_gating_ref(x, wg, wn_, nz, k))
    if use_kernel:
        gates, clean, noisy = fn(x, w_g, wn, noise, k=k)
    else:
        gates, clean, noisy = fn(x, w_g, wn, noise, k)
    if train and k < n:
        load = ref.load_ref(clean, noisy, x, w_noise, k)
    else:
        # at eval (no noise) the load estimator degenerates to the hard
        # assignment count
        load = jnp.sum((gates > 0).astype(jnp.float32), axis=0)
    importance, cv_imp, cv_load, loss = _balance(gates, load,
                                                 w_importance, w_load)
    if not train:
        loss = jnp.float32(0.0)
    return GatingOut(gates, importance, load, loss, cv_imp, cv_load)


def hierarchical_gating(x, w_g_pri, w_n_pri, w_g_sec, w_n_sec, noise_pri,
                        noise_sec, k, *, w_importance, w_load,
                        train: bool) -> GatingOut:
    """Two-level gating (Appendix B), flattened to effective gates over
    n = a*b experts so the downstream dispatch machinery is shared.

    w_g_pri: (d, a); w_g_sec: (d, a, b); noise_sec: (B, a, b).
    Effective gate for expert (i,j):  G_primary(x)_i * G_i(x)_j   (eq 12).
    Importance_H is the batch sum of the product gates (eq 13); Load_H is
    the normalised product of the per-level load estimates (eq 14).
    """
    b_sz, d = x.shape
    a = w_g_pri.shape[-1]
    b = w_g_sec.shape[-1]
    # ----- primary level (noisy top-k over groups) -----
    wnp = w_n_pri if train else None
    g_pri, clean_p, noisy_p = ref.noisy_topk_gating_ref(
        x, w_g_pri, wnp, noise_pri, k)
    # ----- secondary level: gate within every group, densely -----
    clean_s = jnp.einsum("bd,dag->bag", x, w_g_sec)
    if train:
        sigma_s = jax.nn.softplus(jnp.einsum("bd,dag->bag", x, w_n_sec))
        noisy_s = clean_s + noise_sec * sigma_s
    else:
        noisy_s = clean_s
    top_s = ref.topk_vals(noisy_s, k)[..., k - 1:k]
    masked = jnp.where(noisy_s >= top_s, noisy_s, -jnp.inf)
    g_sec = jax.nn.softmax(masked, axis=-1)              # (B, a, b)
    gates = (g_pri[:, :, None] * g_sec).reshape(b_sz, a * b)

    # ----- loads (eq 14) -----
    if train and k < a:
        load_pri = ref.load_ref(clean_p, noisy_p, x, w_n_pri, k)   # (a,)
    else:
        load_pri = jnp.sum((g_pri > 0).astype(jnp.float32), axis=0)
    if train and k < b:
        # per-group secondary load over the sub-batch X^(i) (dense form:
        # weight each token's P by the indicator that the group was chosen)
        sel = (g_pri > 0).astype(jnp.float32)            # (B, a)
        top_vals = ref.topk_vals(noisy_s, min(k + 1, b))
        kth_incl = top_vals[..., k - 1:k]
        kth_excl_in = top_vals[..., k:k + 1]
        is_in = noisy_s >= kth_incl
        threshold = jnp.where(is_in, kth_excl_in, kth_incl)
        sigma_s_l = jax.nn.softplus(jnp.einsum("bd,dag->bag", x, w_n_sec))
        p = ref.normal_cdf((clean_s - threshold) / (sigma_s_l + ref.EPS))
        load_sec = jnp.einsum("ba,bag->ag", sel, p)      # (a, b)
        cnt = jnp.maximum(jnp.sum(sel, axis=0), 1.0)     # |X^(i)|
    else:
        sel = (g_pri > 0).astype(jnp.float32)
        load_sec = jnp.einsum("ba,bag->ag", sel,
                              (g_sec > 0).astype(jnp.float32))
        cnt = jnp.maximum(jnp.sum(sel, axis=0), 1.0)
    load = (load_pri[:, None] * load_sec / cnt[:, None]).reshape(a * b)

    importance, cv_imp, cv_load, loss = _balance(gates, load,
                                                 w_importance, w_load)
    if not train:
        loss = jnp.float32(0.0)
    return GatingOut(gates, importance, load, loss, cv_imp, cv_load)


def batchwise_gating(x, w_g, m, *, train: bool, thresholds=None):
    """Appendix F strictly-balanced gating.

    Training: softmax gates masked by the batchwise top-m-per-expert mask
    (eq 16/18), renormalised.  Inference: threshold mask (eq 19).
    Returns (gates, aux_loss_inputs) where aux contains the scores for the
    threshold-learning loss (eq 20).
    """
    scores = jax.nn.softmax(x @ w_g, axis=-1)
    if train:
        mask = ref.batchwise_mask_ref(scores, m)
    else:
        assert thresholds is not None
        mask = ref.threshold_mask_ref(scores, thresholds)
    num = scores * mask
    gates = num / (jnp.sum(num, axis=-1, keepdims=True) + ref.EPS)
    return gates, scores
