"""AOT pipeline: lower every artifact the rust runtime needs to HLO text.

Interchange is HLO *text*, NOT ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Per config we emit:

  init_<cfg>.hlo.txt     (seed i32)                      -> (params, m, v)
  step_<cfg>.hlo.txt     (params, m, v, tokens, step)    -> (params, m, v, metrics)
  eval_<cfg>.hlo.txt     (params, tokens)                -> [nll_sum, count]
  decode_<cfg>.hlo.txt   (params, cs, hs, token)         -> (logits, cs, hs)
  gating_<cfg>.hlo.txt   (w_g, w_noise, x, noise)        -> (gates, idx, w, imp, load)
  expert_<cfg>.hlo.txt   (w_in, w_out, xs)               -> ys

plus ``manifest.json`` describing shapes/dtypes/param layout so rust never
parses Python.  ``make artifacts`` is incremental: a config is re-lowered
only when this package is newer than its artifacts.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import pathlib
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import configs, model, optim
from .gating import flat_gating

DECODE_BATCH = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sig(fn, *args):
    """Input/output signature via eval_shape (JSON-ready)."""
    out = jax.eval_shape(fn, *args)
    flat_out, _ = jax.tree.flatten(out)

    def enc(x):
        return {"shape": list(x.shape), "dtype": str(x.dtype)}
    return ([enc(a) for a in jax.tree.leaves(args)], [enc(o) for o in flat_out])


def lower_config(cfg: configs.ModelConfig, out_dir: pathlib.Path,
                 kinds: set[str]) -> dict:
    # §Perf (EXPERIMENTS.md): pallas interpret=True lowers to a per-grid
    # while loop that runs ~40x slower than the identical jnp math on
    # XLA-CPU (1588ms vs 37ms fwd on moe-256).  The monolithic artifacts
    # therefore embed the jnp path — pytest asserts it equals the kernel
    # path bit-for-bit-ish (test_kernel_path_matches_ref_path) — while the
    # test-* configs and the standalone gating/expert artifacts keep the
    # real Pallas kernels so the L1 path is exercised through PJRT by the
    # rust parity tests.  On real TPU hardware the kernels compile to
    # Mosaic and this switch would flip to always-kernels.
    use_kernels = cfg.name.startswith("test-")
    built = model.build(cfg, use_kernels=use_kernels)
    entry = {"config": cfg.to_json(), "metrics": model.METRIC_NAMES,
             "param_layout": built.spec.layout_json(),
             "param_size": built.spec.size,
             "opt_sizes": list(optim.opt_sizes(cfg, built.spec)),
             "decode_batch": DECODE_BATCH, "n_lstm": built.n_lstm,
             "artifacts": {}}

    d, n, k = cfg.d_model, cfg.n_experts, cfg.k
    tokens = jnp.zeros((cfg.batch, cfg.seq_len + 1), jnp.int32)
    step = jnp.int32(0)
    p_shape = jax.ShapeDtypeStruct((built.spec.size,), jnp.float32)
    m_sz, v_sz = optim.opt_sizes(cfg, built.spec)
    m_shape = jax.ShapeDtypeStruct((m_sz,), jnp.float32)
    v_shape = jax.ShapeDtypeStruct((v_sz,), jnp.float32)
    dh = cfg.lstm_hidden
    dout = cfg.lstm_proj or cfg.lstm_hidden
    cs = jax.ShapeDtypeStruct((built.n_lstm, DECODE_BATCH, dh), jnp.float32)
    hs = jax.ShapeDtypeStruct((built.n_lstm, DECODE_BATCH, dout), jnp.float32)
    tok1 = jax.ShapeDtypeStruct((DECODE_BATCH,), jnp.int32)

    def gating_fn(w_g, w_noise, x, noise):
        """Router-side gating for the distributed coordinator."""
        g = flat_gating(x, w_g, w_noise, noise, k, w_importance=0.0,
                        w_load=0.0, train=True)
        from .kernels.ref import topk_vals_idx
        topw, topi = topk_vals_idx(g.gates, k)
        return g.gates, topi, topw, g.importance, g.load

    def expert_fn(w_in, w_out, xs):
        """Single-expert FFN for shard workers (Pallas kernel, n=1)."""
        from .kernels.expert_ffn import expert_ffn
        y = expert_ffn(xs[None], w_in[None], w_out[None])
        return y[0]

    router_b = cfg.batch * cfg.seq_len
    gating_args = (jax.ShapeDtypeStruct((d, n), jnp.float32),
                   jax.ShapeDtypeStruct((d, n), jnp.float32),
                   jax.ShapeDtypeStruct((router_b, d), jnp.float32),
                   jax.ShapeDtypeStruct((router_b, n), jnp.float32))
    expert_args = (jax.ShapeDtypeStruct((d, cfg.expert_hidden), jnp.float32),
                   jax.ShapeDtypeStruct((cfg.expert_hidden, d), jnp.float32),
                   jax.ShapeDtypeStruct((cfg.capacity, d), jnp.float32))

    jobs = {
        "init": (built.init, (jnp.int32(0),)),
        "step": (built.train_step, (p_shape, m_shape, v_shape, tokens, step)),
        "eval": (built.eval_step, (p_shape, tokens)),
        "decode": (built.decode_step, (p_shape, cs, hs, tok1)),
    }
    if cfg.middle == "moe" and not cfg.hierarchical:
        jobs["gating"] = (gating_fn, gating_args)
        jobs["expert"] = (expert_fn, expert_args)
    elif cfg.middle == "moe":
        jobs["expert"] = (expert_fn, expert_args)

    for kind, (fn, args) in jobs.items():
        if kinds and kind not in kinds:
            continue
        path = out_dir / f"{kind}_{cfg.name}.hlo.txt"
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path.write_text(text)
        ins, outs = _sig(fn, *args)
        entry["artifacts"][kind] = {"file": path.name, "inputs": ins,
                                    "outputs": outs}
        print(f"  {path.name}: {len(text)//1024} KiB, "
              f"{len(ins)} in / {len(outs)} out", file=sys.stderr)
    return entry


DEFAULT_SET = [
    "test-tiny", "test-hier",
    "moe-4", "moe-32", "moe-256", "moe-256-h", "moe-1024-h",
    "moe-1-wide", "moe-1-deep", "lstm-4x", "lstm-big",
    "moe-lowbudget", "moe-midbudget", "moe-highbudget",
    "balance-wi0.0-wl0.0", "balance-wi0.2-wl0.0", "balance-wi0.0-wl0.2",
    "balance-wi0.1-wl0.1", "balance-wi0.01-wl0.01", "balance-wi1.0-wl1.0",
    "e2e-100m", "mt-moe", "mt-dense",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default=",".join(DEFAULT_SET),
                    help="comma-separated config names, or 'all'")
    ap.add_argument("--kinds", default="", help="subset of artifact kinds")
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    names = (list(configs.CONFIGS) if args.configs == "all"
             else args.configs.split(","))
    kinds = set(args.kinds.split(",")) if args.kinds else set()

    manifest_path = out / "manifest.json"
    manifest = {"configs": {}}
    if manifest_path.exists():
        manifest = json.loads(manifest_path.read_text())

    for name in names:
        cfg = configs.get(name)
        print(f"[aot] lowering {name}", file=sys.stderr)
        manifest["configs"][name] = lower_config(cfg, out, kinds)

    manifest_path.write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {manifest_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
