"""Model / experiment configurations mirroring the paper's model zoo.

Every config here is a scaled-down analogue of a model in Shazeer et al.
(ICLR 2017).  Scaling rule: d_model 512 -> 64..256, expert hidden 1024 ->
4x d_model, vocab 793k -> 8k synthetic-topic vocab.  The *relationships*
between configs (matched ops/timestep across the capacity ladder, the
dense-baseline ladder, hierarchical branching) are preserved because those
relationships are what the paper's tables measure.

``ops_per_timestep`` reproduces the paper's accounting: forward-pass
multiply-adds per token, excluding the embedding and softmax layers
(Section 5.1).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = 8192
    d_model: int = 128
    # --- LSTM stack -------------------------------------------------------
    lstm_hidden: int = 128          # hidden units per LSTM layer
    lstm_proj: int = 0              # output projection (Sak et al.); 0 = none
    n_lstm_extra: int = 0           # 4xLSTM-512 baseline: extra LSTM layers
    # --- middle layer -----------------------------------------------------
    # 'moe'   : sparsely-gated MoE (flat if groups==0 else hierarchical)
    # 'wide'  : MoE-1-Wide baseline (single expert, wider hidden)
    # 'deep'  : MoE-1-Deep baseline (single expert, 4 hidden layers)
    # 'lstm'  : 4xLSTM baseline (two extra LSTM layers in the middle)
    # 'none'  : no middle layer (LSTM-2048-512 style big recurrent model)
    middle: str = "moe"
    n_experts: int = 4
    k: int = 2
    groups: int = 0                 # hierarchical MoE: primary branching factor
    expert_hidden: int = 512
    capacity_factor: float = 2.0
    # 'gather': index-based dispatch/combine (scatter/gather, what the
    #           paper's TF implementation did -- cost O(B*k*d));
    # 'einsum': Mesh-TF one-hot contraction through the Pallas dispatch
    #           kernels (cost O(B*n*cap*d)) -- kept for ablation.
    dispatch: str = "gather"
    # --- regularisation & balancing ---------------------------------------
    dropout: float = 0.1
    w_importance: float = 0.1
    w_load: float = 0.1
    noisy_gating: bool = True
    # --- training ---------------------------------------------------------
    batch: int = 32
    seq_len: int = 16
    optimizer: str = "adam"         # 'adam' | 'factored' (Appendix D)
    learning_rate: float = 2e-3
    warmup_steps: int = 60
    # --- misc -------------------------------------------------------------
    seed: int = 0

    # ------------------------------------------------------------------ #

    @property
    def hierarchical(self) -> bool:
        return self.middle == "moe" and self.groups > 0

    @property
    def group_size(self) -> int:
        assert self.hierarchical and self.n_experts % self.groups == 0
        return self.n_experts // self.groups

    @property
    def k_effective(self) -> int:
        """Experts active per token (k1*k2 for hierarchical)."""
        if self.middle != "moe":
            return 0
        return self.k * self.k if self.hierarchical else self.k

    @property
    def capacity(self) -> int:
        """Per-expert token capacity for the AOT'd einsum dispatch."""
        tokens = self.batch * self.seq_len
        cap = int(self.capacity_factor * tokens * self.k_effective / max(self.n_experts, 1))
        return max(cap, 4)

    # --- ops accounting (paper Section 5.1: fwd multiply-adds / timestep,
    #     excluding embedding and softmax) ---------------------------------

    def lstm_ops(self, d_in: int, d_h: int, d_out: int) -> int:
        ops = 4 * (d_in * d_h + d_h * d_h)
        if self.lstm_proj:
            ops += d_h * d_out
        return ops

    @property
    def ops_per_timestep(self) -> int:
        d = self.d_model
        h = self.lstm_hidden
        proj = self.lstm_proj or h
        out = self.lstm_proj if self.lstm_proj else h
        ops = 2 * self.lstm_ops(d, h, out)  # two LSTM layers
        ops += self.n_lstm_extra * self.lstm_ops(d, h, out)
        if self.middle == "moe":
            gate = d * self.n_experts if not self.hierarchical else d * (
                self.groups + self.group_size)
            if self.noisy_gating:
                gate *= 2  # W_g and W_noise
            ops += gate
            ops += self.k_effective * 2 * d * self.expert_hidden
        elif self.middle == "wide":
            ops += 2 * d * self.expert_hidden
        elif self.middle == "deep":
            ops += 2 * d * self.expert_hidden + 3 * self.expert_hidden ** 2
        elif self.middle == "lstm":
            ops += 2 * self.lstm_ops(d, h, out)
        return ops

    @property
    def moe_params(self) -> int:
        """Parameters in the MoE layer (the paper's capacity axis)."""
        if self.middle != "moe":
            return 0
        return self.n_experts * 2 * self.d_model * self.expert_hidden

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["ops_per_timestep"] = self.ops_per_timestep
        d["moe_params"] = self.moe_params
        d["capacity"] = self.capacity
        d["k_effective"] = self.k_effective
        return d


def _ladder(name: str, **kw) -> ModelConfig:
    return ModelConfig(name=name, **kw)


# --------------------------------------------------------------------------
# The model zoo.  Keys are artifact-config names used by `aot.py` and the
# rust side (manifest.json).  Scaled analogues of Appendix C Table 7.
# --------------------------------------------------------------------------

D = 64          # scaled d_model for the ladder (paper: 512)
H = 4 * D       # scaled expert hidden       (paper: 1024)
VOCAB = 2048

_base = dict(vocab=VOCAB, d_model=D, lstm_hidden=D, expert_hidden=H,
             batch=32, seq_len=16)

CONFIGS: dict[str, ModelConfig] = {}


def _add(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# tiny config for unit tests / CI
_add(ModelConfig(name="test-tiny", vocab=64, d_model=16, lstm_hidden=16,
                 expert_hidden=32, n_experts=4, k=2, batch=4, seq_len=6,
                 warmup_steps=10))
_add(ModelConfig(name="test-hier", vocab=64, d_model=16, lstm_hidden=16,
                 expert_hidden=32, n_experts=16, groups=4, k=2, batch=4,
                 seq_len=6, warmup_steps=10))

# --- Table 7 ladder (scaled) ---
_add(_ladder("moe-4", middle="moe", n_experts=4, k=4, **_base))
_add(_ladder("moe-32", middle="moe", n_experts=32, k=4, **_base))
_add(_ladder("moe-256", middle="moe", n_experts=256, k=4, **_base))
_add(_ladder("moe-256-h", middle="moe", n_experts=256, groups=16, k=2, **_base))
_add(_ladder("moe-1024-h", middle="moe", n_experts=1024, groups=32, k=2,
             dropout=0.2, **_base))
_add(_ladder("moe-1-wide", middle="wide", expert_hidden=4 * H,
             **{k: v for k, v in _base.items() if k != "expert_hidden"}))
_add(_ladder("moe-1-deep", middle="deep", **_base))
_add(_ladder("lstm-4x", middle="lstm", **_base))
_add(_ladder("lstm-big", middle="none", lstm_hidden=4 * D, lstm_proj=D,
             **{k: v for k, v in _base.items() if k != "lstm_hidden"}))

# --- Table 1 budget ladder (scaled): vary computation at high capacity ---
_add(ModelConfig(name="moe-lowbudget", vocab=VOCAB, d_model=D, lstm_hidden=D,
                 expert_hidden=H, n_experts=256, groups=16, k=2,
                 batch=32, seq_len=16, dropout=0.2))
_add(ModelConfig(name="moe-midbudget", vocab=VOCAB, d_model=2 * D,
                 lstm_hidden=2 * D, expert_hidden=2 * H, n_experts=64,
                 groups=8, k=2, batch=32, seq_len=16, dropout=0.2))
_add(ModelConfig(name="moe-highbudget", vocab=VOCAB, d_model=2 * D,
                 lstm_hidden=4 * D, lstm_proj=2 * D, expert_hidden=4 * H,
                 n_experts=16, groups=4, k=2, batch=32, seq_len=16,
                 dropout=0.2))

# --- Table 6 ablation base (MoE-256 analogue, losses swept at runtime) ---
for wi, wl in [(0.0, 0.0), (0.2, 0.0), (0.0, 0.2), (0.1, 0.1),
               (0.01, 0.01), (1.0, 1.0)]:
    _add(ModelConfig(name=f"balance-wi{wi}-wl{wl}", vocab=VOCAB, d_model=D,
                     lstm_hidden=D, expert_hidden=H, n_experts=32, k=4,
                     w_importance=wi, w_load=wl, batch=32, seq_len=16,
                     warmup_steps=50, learning_rate=2e-3))

# --- end-to-end example: ~100M-param MoE LM (params dominated by experts:
#     192 experts x 2*256*1024 = 100.7M + 4.2M embed/softmax + LSTMs) ---
_add(ModelConfig(name="e2e-100m", vocab=8192, d_model=256, lstm_hidden=256,
                 expert_hidden=1024, n_experts=192, groups=0, k=4,
                 batch=16, seq_len=32, optimizer="factored", dropout=0.0,
                 warmup_steps=100, learning_rate=5e-4))

# --- MT configs (prefix-LM seq2seq; Tables 2-5 analogues).  Scaled so the
#     lexicon is learnable in a few hundred steps: small shared vocab,
#     short warmup, higher lr, no dropout (the task is deterministic). ---
_mt = dict(vocab=256, d_model=64, lstm_hidden=64, batch=64, seq_len=20,
           dropout=0.0, warmup_steps=60, learning_rate=3e-3)
_add(ModelConfig(name="mt-moe", expert_hidden=256, n_experts=64, groups=8,
                 k=2, w_importance=0.01, w_load=0.01, **_mt))
_add(ModelConfig(name="mt-dense", expert_hidden=256, middle="lstm", **_mt))


def get(name: str) -> ModelConfig:
    try:
        return CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown config '{name}'; known: {sorted(CONFIGS)}")


if __name__ == "__main__":
    print(json.dumps({k: v.to_json() for k, v in CONFIGS.items()}, indent=2))
