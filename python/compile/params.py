"""Flat parameter-vector layout.

All model parameters live in ONE flat f32 vector.  This keeps the
rust <-> HLO interface to a handful of buffers (params, opt moments,
tokens), makes buffer donation trivial on the step loop, and lets the
optimizer update be a single fused elementwise pass.

The layout (name -> offset/shape) is exported to ``artifacts/manifest.json``
so the rust side can slice expert weights out for the distributed
coordinator and write checkpoints with named tensors.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec:
    """Ordered registry of named parameter tensors inside one flat vector."""

    def __init__(self):
        self.entries: list[tuple[str, tuple[int, ...], str]] = []
        self.offsets: dict[str, tuple[int, tuple[int, ...]]] = {}
        self.size = 0

    def add(self, name: str, shape: tuple[int, ...], init: str = "normal"):
        """init: 'zeros' | 'normal' (fan-in scaled) | 'uniform' (glorot)."""
        assert name not in self.offsets, f"duplicate param {name}"
        n = math.prod(shape)
        self.entries.append((name, shape, init))
        self.offsets[name] = (self.size, shape)
        self.size += n
        return name

    def get(self, flat, name: str):
        off, shape = self.offsets[name]
        return jax.lax.dynamic_slice_in_dim(flat, off, math.prod(shape)
                                            ).reshape(shape)

    def init_flat(self, key):
        parts = []
        for name, shape, init in self.entries:
            key, sub = jax.random.split(key)
            n = math.prod(shape)
            if init == "zeros":
                parts.append(jnp.zeros((n,), jnp.float32))
            elif init == "normal":
                fan_in = shape[0] if len(shape) > 1 else shape[0]
                scale = 1.0 / math.sqrt(max(fan_in, 1))
                parts.append(jax.random.normal(sub, (n,)) * scale)
            elif init == "uniform":
                fan_in = shape[-2] if len(shape) > 1 else shape[0]
                fan_out = shape[-1]
                lim = math.sqrt(6.0 / (fan_in + fan_out))
                parts.append(jax.random.uniform(sub, (n,), minval=-lim,
                                                maxval=lim))
            else:
                raise ValueError(init)
        return jnp.concatenate(parts) if parts else jnp.zeros((0,))

    def layout_json(self) -> list[dict]:
        return [{"name": n, "shape": list(s), "offset": self.offsets[n][0],
                 "init": i} for n, s, i in self.entries]

    def matrix_shapes(self) -> list[tuple[str, tuple[int, ...]]]:
        return [(n, s) for n, s, _ in self.entries]
