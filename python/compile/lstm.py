"""LSTM substrate (Hochreiter & Schmidhuber 1997; Gers et al. 2000).

Standard LSTM with forget gate and optional output projection
(Sak et al. 2014) as used by the paper's LSTM-2048-512 baseline.  Written
against the flat ParamSpec so it lowers into the monolithic HLO artifact.
Weights are fetched from the flat vector ONCE per sequence (outside the
scan body) so the backward pass accumulates into a single slice-gradient
per matrix.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .params import ParamSpec


def register_lstm(spec: ParamSpec, name: str, d_in: int, d_h: int,
                  d_proj: int = 0):
    spec.add(f"{name}.wx", (d_in, 4 * d_h), "uniform")
    spec.add(f"{name}.wh", (d_proj or d_h, 4 * d_h), "uniform")
    spec.add(f"{name}.b", (4 * d_h,), "zeros")
    if d_proj:
        spec.add(f"{name}.wp", (d_h, d_proj), "uniform")


def fetch(spec: ParamSpec, flat, name: str, d_proj: int = 0):
    w = (spec.get(flat, f"{name}.wx"), spec.get(flat, f"{name}.wh"),
         spec.get(flat, f"{name}.b"))
    if d_proj:
        return w + (spec.get(flat, f"{name}.wp"),)
    return w + (None,)


def cell(weights, x, c, h):
    """One step.  x: (B, d_in); c: (B, d_h); h: (B, d_proj or d_h)."""
    wx, wh, b, wp = weights
    z = x @ wx + h @ wh + b
    i, f, g, o = jnp.split(z, 4, axis=-1)
    # forget-gate bias +1: standard trick to keep memory early in training
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    if wp is not None:
        h_new = h_new @ wp
    return c_new, h_new


def lstm_scan(spec: ParamSpec, flat, name: str, xs, d_h: int,
              d_proj: int = 0):
    """xs: (T, B, d_in) -> outputs (T, B, d_proj or d_h)."""
    b = xs.shape[1]
    weights = fetch(spec, flat, name, d_proj)
    c0 = jnp.zeros((b, d_h), xs.dtype)
    h0 = jnp.zeros((b, d_proj or d_h), xs.dtype)

    def step(carry, x):
        c, h = cell(weights, x, carry[0], carry[1])
        return (c, h), h

    (_, _), ys = jax.lax.scan(step, (c0, h0), xs)
    return ys


def lstm_step(spec: ParamSpec, flat, name: str, x, c, h, d_proj: int = 0):
    """Single-position step for incremental decoding."""
    return cell(fetch(spec, flat, name, d_proj), x, c, h)
