"""Optimizers over the flat parameter vector.

- ``adam``: standard Adam (Kingma & Ba 2015), the paper's optimizer.
- ``factored``: the paper's Appendix-D memory-reduced variant (the
  Adafactor precursor): beta1 = 0 (no first moment) and the second-moment
  matrix of every 2-D parameter replaced by the outer product of row/col
  means divided by the mean of the row vector.  Non-matrix parameters keep
  a full second moment.

Both are pure functions (flat, m, v, grad, step) -> (flat', m', v') lowered
into the monolithic train-step artifact, so rust round-trips opaque opt
buffers.  For ``factored``, v is a *packed* vector: per 2-D parameter the
row means then the col means; per other parameter the full moment.  The
packing layout is exported in the manifest.
"""

from __future__ import annotations

import math

import jax.numpy as jnp

from .params import ParamSpec

B1, B2, ADAM_EPS = 0.9, 0.999, 1e-8


def lr_schedule(base_lr, warmup, step):
    """Paper §C.1: linear warmup then proportional to 1/sqrt(step)."""
    s = jnp.maximum(step.astype(jnp.float32), 1.0)
    w = float(max(warmup, 1))
    return base_lr * jnp.minimum(s / w, math.sqrt(w) / jnp.sqrt(s))


# --------------------------------------------------------------------- Adam

def adam_sizes(spec: ParamSpec):
    return spec.size, spec.size


def adam_update(flat, m, v, grad, step, lr):
    m = B1 * m + (1 - B1) * grad
    v = B2 * v + (1 - B2) * grad * grad
    t = step.astype(jnp.float32) + 1.0
    mhat = m / (1 - B1 ** t)
    vhat = v / (1 - B2 ** t)
    new = flat - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return new, m, v


# ----------------------------------------------------------------- Factored

def factored_layout(spec: ParamSpec):
    """Packed second-moment layout: list of (name, kind, offset, size)."""
    out, off = [], 0
    for name, shape, _ in spec.entries:
        if len(shape) >= 2:
            # factor over (prod(leading), last) — 3-D expert weight tensors
            # (n, d, h) flatten to (n*d, h), Adafactor-style
            rows, cols = math.prod(shape[:-1]), shape[-1]
            size = rows + cols
            out.append((name, "factored", off, size, shape))
        else:
            size = math.prod(shape)
            out.append((name, "full", off, size, shape))
        off += size
    return out, off


def factored_sizes(spec: ParamSpec):
    _, total = factored_layout(spec)
    return 0, total  # no first moment (beta1 = 0)


def factored_update(spec: ParamSpec, flat, m, v, grad, step, lr):
    layout, _ = factored_layout(spec)
    t = step.astype(jnp.float32) + 1.0
    new_parts, v_parts = [], []
    for (name, kind, voff, vsize, shape) in layout:
        poff, _ = spec.offsets[name]
        psize = math.prod(shape)
        rows, cols = math.prod(shape[:-1]), shape[-1]
        g = jnp.reshape(grad[poff:poff + psize], (rows, cols))
        p = jnp.reshape(flat[poff:poff + psize], (rows, cols))
        if kind == "factored":
            r = v[voff:voff + rows]
            c = v[voff + rows:voff + rows + cols]
            g2 = g * g + 1e-30
            r = B2 * r + (1 - B2) * jnp.mean(g2, axis=1)
            c = B2 * c + (1 - B2) * jnp.mean(g2, axis=0)
            vhat = (jnp.outer(r, c) / (jnp.mean(r) + 1e-30)) / (1 - B2 ** t)
            v_parts.append(jnp.concatenate([r, c]))
        else:
            vv = v[voff:voff + vsize]
            vv = B2 * vv + (1 - B2) * (g * g).reshape(-1)
            vhat = (vv / (1 - B2 ** t)).reshape(rows, cols)
            v_parts.append(vv)
        upd = g / (jnp.sqrt(vhat) + ADAM_EPS)   # beta1 = 0: raw gradient
        new_parts.append((p - lr * upd).reshape(-1))
    return jnp.concatenate(new_parts), m, jnp.concatenate(v_parts)


def opt_sizes(cfg, spec: ParamSpec):
    return factored_sizes(spec) if cfg.optimizer == "factored" \
        else adam_sizes(spec)


def update(cfg, spec: ParamSpec, flat, m, v, grad, step):
    lr = lr_schedule(cfg.learning_rate, cfg.warmup_steps, step)
    if cfg.optimizer == "factored":
        return factored_update(spec, flat, m, v, grad, step, lr)
    return adam_update(flat, m, v, grad, step, lr)
