"""Pallas kernel: batched expert feed-forward network (the MoE hot spot).

Each expert is a bias-free ReLU MLP  y = max(x W_in, 0) W_out  (paper
Appendix C: [d*h] + [h*d] parameters per expert).  The batched form runs
over the dispatched token tensor (n_experts, capacity, d_model).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over
(expert, capacity-block); for each step the token block (block_c, d) and
both weight matrices of one expert are staged into VMEM by BlockSpec, and
the two matmuls target the MXU with float32 accumulation
(``preferred_element_type``).  The hidden activation h lives only in
registers/VMEM scratch — it is never written back to HBM, which is what
gives the expert its d_hidden arithmetic intensity (paper §3.2).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; correctness comes from pytest against ``ref.py`` and the
real-TPU perf story is the VMEM/MXU accounting in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w_in_ref, w_out_ref, o_ref):
    x = x_ref[0]                     # (block_c, d)
    w_in = w_in_ref[0]               # (d, h)
    w_out = w_out_ref[0]             # (h, d)
    h = jnp.dot(x, w_in, preferred_element_type=jnp.float32)
    h = jnp.maximum(h, 0.0)
    o_ref[0] = jnp.dot(h, w_out, preferred_element_type=jnp.float32)


def vmem_bytes(block_c: int, d: int, h: int, itemsize: int = 4) -> int:
    """Per-grid-step VMEM footprint estimate (tokens + weights + out + hid)."""
    return itemsize * (block_c * d * 2 + d * h * 2 + block_c * h)


def pick_block_c(capacity: int, d: int, h: int,
                 budget_bytes: int = 8 * 2 ** 20) -> int:
    """Largest capacity block (multiple of 8) fitting the VMEM budget."""
    block = min(capacity, 512)
    while block > 8 and vmem_bytes(block, d, h) > budget_bytes:
        block //= 2
    return max(8, min(block, capacity))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _expert_ffn(x, w_in, w_out, block_c, interpret):
    return _expert_ffn_fwd_only(x, w_in, w_out, block_c, interpret)


def _expert_ffn_vjp_fwd(x, w_in, w_out, block_c, interpret):
    y = _expert_ffn_fwd_only(x, w_in, w_out, block_c, interpret)
    # Residuals are inputs only: the hidden activation h is RECOMPUTED in
    # the backward pass — the paper's Appendix D memory optimization ("we
    # do not store the activations of the hidden layers of the experts,
    # but instead recompute them on the backwards pass").
    return y, (x, w_in, w_out)


def _expert_ffn_vjp_bwd(block_c, interpret, res, dy):
    x, w_in, w_out = res
    h = jnp.maximum(jnp.einsum("ecd,edh->ech", x, w_in), 0.0)  # recompute
    dh = jnp.einsum("ecd,ehd->ech", dy, w_out) * (h > 0)
    dw_out = jnp.einsum("ech,ecd->ehd", h, dy)
    dw_in = jnp.einsum("ecd,ech->edh", x, dh)
    dx = jnp.einsum("ech,edh->ecd", dh, w_in)
    return dx, dw_in, dw_out


_expert_ffn.defvjp(_expert_ffn_vjp_fwd, _expert_ffn_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def expert_ffn(x, w_in, w_out, *, block_c: int | None = None,
               interpret: bool = True):
    """x: (n, c, d); w_in: (n, d, h); w_out: (n, h, d) -> (n, c, d).

    Differentiable (custom VJP; hidden activations rematerialised in bwd).
    """
    if block_c is None:
        block_c = pick_block_c(x.shape[1], x.shape[2], w_in.shape[-1])
    return _expert_ffn(x, w_in, w_out, block_c, interpret)


def _expert_ffn_fwd_only(x, w_in, w_out, block_c, interpret):
    n, c, d = x.shape
    h = w_in.shape[-1]
    if c % block_c != 0:
        # pad capacity up to a block multiple; padded rows are zeros and
        # produce zeros (bias-free network), sliced off below.
        pad = block_c - c % block_c
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    cp = x.shape[1]
    grid = (n, cp // block_c)
    out = pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, d), lambda e, i: (e, i, 0)),
            pl.BlockSpec((1, d, h), lambda e, i: (e, 0, 0)),
            pl.BlockSpec((1, h, d), lambda e, i: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c, d), lambda e, i: (e, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cp, d), x.dtype),
        interpret=interpret,
    )(x, w_in, w_out)
    return out[:, :c, :]
