"""Pallas kernel: Noisy Top-K gating (paper eq 3-5).

Computes, for a block of tokens resident in VMEM:

    clean = x @ W_g
    noisy = clean + noise * softplus(x @ W_noise)
    gates = softmax(KeepTopK(noisy, k))

Top-k is an iterative k-step max-extraction rather than a sort: k <= 4 in
every paper configuration, and on TPU a k-pass max over a VMEM-resident
(block_b, n) tile beats a full sort by a wide margin.  The softmax over the
kept values uses the numerically-stable max-shift; masked lanes contribute
exp(-inf) = 0.

Outputs (gates, clean, noisy); the smooth load estimator (eq 8-10) consumes
clean/noisy downstream in L2 (it needs norm.cdf, which stays in jnp).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _gating_kernel(x_ref, wg_ref, wn_ref, noise_ref, g_ref, c_ref, n_ref, *,
                   k: int, noisy_gating: bool):
    x = x_ref[...]                                   # (block_b, d)
    clean = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    if noisy_gating:
        sigma = jax.nn.softplus(
            jnp.dot(x, wn_ref[...], preferred_element_type=jnp.float32))
        noisy = clean + noise_ref[...] * sigma
    else:
        noisy = clean
    # iterative top-k threshold: after k max-extractions `work`'s max is the
    # (k+1)-th largest, and `thresh` holds the k-th largest.
    work = noisy
    thresh = None
    for _ in range(k):
        thresh = jnp.max(work, axis=-1, keepdims=True)
        work = jnp.where(work >= thresh, NEG_INF, work)
    kept = jnp.where(noisy >= thresh, noisy, NEG_INF)
    kept = kept - jnp.max(kept, axis=-1, keepdims=True)
    e = jnp.where(kept > NEG_INF / 2, jnp.exp(kept), 0.0)
    g_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)
    c_ref[...] = clean
    n_ref[...] = noisy


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def _gating(x, w_g, w_noise, noise, k, noisy_gating, block_b, interpret):
    return _gating_fwd_only(x, w_g, w_noise, noise, k, noisy_gating,
                            block_b, interpret)


def _gating_vjp_fwd(x, w_g, w_noise, noise, k, noisy_gating, block_b,
                    interpret):
    out = _gating_fwd_only(x, w_g, w_noise, noise, k, noisy_gating,
                           block_b, interpret)
    return out, (x, w_g, w_noise, noise, out[0])


def _gating_vjp_bwd(k, noisy_gating, block_b, interpret, res, cotangents):
    """Gradient through the gating network (paper §2.1: for k > 1 the top-k
    gate values have nonzero derivatives; the top-k *selection* is treated
    as locally constant, exactly as tf.top_k does)."""
    x, w_g, w_noise, noise, gates = res
    dgates, dclean, dnoisy = cotangents
    # softmax vjp restricted to the kept set (gates == 0 off-support)
    s = jnp.sum(dgates * gates, axis=-1, keepdims=True)
    dnoisy_tot = dnoisy + gates * (dgates - s)
    dclean_tot = dclean + dnoisy_tot
    dx = dclean_tot @ w_g.T
    dwg = x.T @ dclean_tot
    if noisy_gating:
        pre = x @ w_noise
        sig = jax.nn.sigmoid(pre)              # d softplus
        dsigma = dnoisy_tot * noise
        dpre = dsigma * sig
        dx = dx + dpre @ w_noise.T
        dwn = x.T @ dpre
        dnz = dnoisy_tot * jax.nn.softplus(pre)
    else:
        dwn = jnp.zeros_like(w_noise)
        dnz = jnp.zeros_like(noise)
    return dx, dwg, dwn, dnz


_gating.defvjp(_gating_vjp_fwd, _gating_vjp_bwd)


@functools.partial(jax.jit,
                   static_argnames=("k", "block_b", "interpret"))
def noisy_topk_gating(x, w_g, w_noise, noise, *, k: int,
                      block_b: int | None = None, interpret: bool = True):
    """x: (B, d); w_g/w_noise: (d, n); noise: (B, n) -> (gates, clean, noisy).

    Pass ``w_noise=None`` for plain (non-noisy) top-k gating; ``noise`` is
    then ignored.  Differentiable (custom VJP).
    """
    b, d = x.shape
    n = w_g.shape[-1]
    noisy_gating = w_noise is not None
    if not noisy_gating:
        w_noise = jnp.zeros_like(w_g)
        noise = jnp.zeros((b, n), x.dtype)
    if block_b is None:
        block_b = min(b, 256)
    return _gating(x, w_g, w_noise, noise, k, noisy_gating, block_b,
                   interpret)


def _gating_fwd_only(x, w_g, w_noise, noise, k, noisy_gating, block_b,
                     interpret):
    b, d = x.shape
    n = w_g.shape[-1]
    if b % block_b != 0:
        pad = block_b - b % block_b
        x = jnp.pad(x, ((0, pad), (0, 0)))
        noise = jnp.pad(noise, ((0, pad), (0, 0)))
    bp = x.shape[0]
    kernel = functools.partial(_gating_kernel, k=k, noisy_gating=noisy_gating)
    shapes = jax.ShapeDtypeStruct((bp, n), jnp.float32)
    gates, clean, noisy = pl.pallas_call(
        kernel,
        grid=(bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, d), lambda i: (i, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((d, n), lambda i: (0, 0)),
            pl.BlockSpec((block_b, n), lambda i: (i, 0)),
        ],
        out_specs=[pl.BlockSpec((block_b, n), lambda i: (i, 0))] * 3,
        out_shape=[shapes, shapes, shapes],
        interpret=interpret,
    )(x, w_g, w_noise, noise)
    return gates[:b], clean[:b], noisy[:b]
