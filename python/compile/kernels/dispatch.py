"""Pallas kernels: dispatch / combine einsums for the MoE all-to-all.

The AOT'd (single-artifact) training path uses the Mesh-TensorFlow one-hot
formulation of the paper's dispatch: a (B, n, capacity) one-hot routing
tensor turns gather/scatter into two dense contractions that the MXU eats:

    dispatch:  expert_in[n,c,d] = sum_b pos_oh[b,n,c] * x[b,d]
    combine:   y[b,d]          = sum_{n,c} combine[b,n,c] * expert_out[n,c,d]

Per-expert, dispatch is (c,B) @ (B,d) and combine accumulates
(B,c) @ (c,d) over experts — both MXU-shaped.  The grid runs over experts;
for combine the expert axis is the *reduction*, accumulated into the output
block across sequential grid steps (TPU grids execute in order, so the
first step initialises and later steps add).

The position/priority computation (cumsum over the batch) stays in jnp in
L2 — it is O(B*n) elementwise and fuses with the gating ops.

The rust coordinator's distributed path does the same all-to-all with real
index-based scatter/gather (rust/src/coordinator/dispatcher.rs); equality
of the two paths is asserted in tests on both sides.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dispatch_kernel(oh_ref, x_ref, o_ref):
    oh = oh_ref[:, 0, :]                    # (B, c) for this expert
    x = x_ref[...]                          # (B, d)
    o_ref[0] = jnp.dot(oh.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _dispatch(pos_oh, x, interpret):
    return _dispatch_fwd_only(pos_oh, x, interpret)


def _dispatch_vjp_fwd(pos_oh, x, interpret):
    return _dispatch_fwd_only(pos_oh, x, interpret), (pos_oh, x)


def _dispatch_vjp_bwd(interpret, res, dy):
    pos_oh, x = res
    # linear contraction: d pos_oh and d x are the dual einsums
    dpos = jnp.einsum("ncd,bd->bnc", dy, x)
    dx = jnp.einsum("bnc,ncd->bd", pos_oh, dy)
    return dpos, dx


_dispatch.defvjp(_dispatch_vjp_fwd, _dispatch_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def dispatch(pos_oh, x, *, interpret: bool = True):
    """pos_oh: (B, n, c) one-hot routing; x: (B, d) -> (n, c, d)."""
    return _dispatch(pos_oh, x, interpret)


def _dispatch_fwd_only(pos_oh, x, interpret):
    b, n, c = pos_oh.shape
    d = x.shape[-1]
    return pl.pallas_call(
        _dispatch_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((b, 1, c), lambda e: (0, e, 0)),
            pl.BlockSpec((b, d), lambda e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, c, d), lambda e: (e, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c, d), x.dtype),
        interpret=interpret,
    )(pos_oh, x)


def _combine_kernel(cw_ref, eo_ref, o_ref):
    e = pl.program_id(0)
    cw = cw_ref[:, 0, :]                    # (B, c)
    eo = eo_ref[0]                          # (c, d)
    part = jnp.dot(cw, eo, preferred_element_type=jnp.float32)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = part

    @pl.when(e != 0)
    def _acc():
        o_ref[...] += part


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _combine(combine_w, expert_out, interpret):
    return _combine_fwd_only(combine_w, expert_out, interpret)


def _combine_vjp_fwd(combine_w, expert_out, interpret):
    return _combine_fwd_only(combine_w, expert_out, interpret), \
        (combine_w, expert_out)


def _combine_vjp_bwd(interpret, res, dy):
    combine_w, expert_out = res
    dcw = jnp.einsum("bd,ncd->bnc", dy, expert_out)
    deo = jnp.einsum("bnc,bd->ncd", combine_w, dy)
    return dcw, deo


_combine.defvjp(_combine_vjp_fwd, _combine_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def combine(combine_w, expert_out, *, interpret: bool = True):
    """combine_w: (B, n, c); expert_out: (n, c, d) -> (B, d).

    Differentiable: the cotangent w.r.t. combine_w carries the gate
    gradient (this is how the gating network learns, paper §2.1).
    """
    return _combine(combine_w, expert_out, interpret)


def _combine_fwd_only(combine_w, expert_out, interpret):
    b, n, c = combine_w.shape
    d = expert_out.shape[-1]
    return pl.pallas_call(
        _combine_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((b, 1, c), lambda e: (0, e, 0)),
            pl.BlockSpec((1, c, d), lambda e: (e, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b, d), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), expert_out.dtype),
        interpret=interpret,
    )(combine_w, expert_out)
