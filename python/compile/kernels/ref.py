"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here written in
the most obvious jnp form.  ``pytest python/tests`` asserts kernel == ref
over randomised shapes (hypothesis), which is the core L1 correctness
signal; the L2 model additionally has its own end-to-end gradient checks.

The math follows Shazeer et al. (ICLR 2017):

  H(x)_i = (x W_g)_i + StandardNormal() * Softplus((x W_noise)_i)      (eq 4)
  G(x)   = Softmax(KeepTopK(H(x), k))                                  (eq 3)
  P(x,i) = Phi((xW_g_i - kth_excluding(H(x),k,i)) / Softplus(xW_n_i))  (eq 9)
  Load(X)_i = sum_x P(x, i)                                            (eq 10)
  Importance(X) = sum_x G(x)                                           (eq 6)
  L = w * CV(.)^2                                                      (eq 7/11)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-10


def erf_poly(x):
    """erf via Abramowitz & Stegun 7.1.26 (|err| < 1.5e-7).

    jax's own erf lowers to the `erf` HLO opcode, which the xla_extension
    0.5.1 text parser behind the rust `xla` crate does not know; this
    polynomial lowers to plain mul/add/exp.  1.5e-7 absolute error is far
    below the load-estimator's Monte-Carlo validation tolerance.
    """
    sign = jnp.sign(x)
    x = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * x)
    y = 1.0 - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736) * t + 0.254829592) * t * jnp.exp(-x * x)
    return sign * y


def normal_cdf(x):
    """Standard normal CDF Φ(x) on top of erf_poly (matches the rust
    mirror gating::normal_cdf bit-for-bit in structure)."""
    return 0.5 * (1.0 + erf_poly(x / jnp.sqrt(jnp.float32(2.0))))


def softplus(x):
    return jax.nn.softplus(x)


NEG = -1e30


def topk_vals(x, k):
    """Top-k values along the last axis, descending — via iterative
    max-extraction rather than jax.lax.top_k.

    Rationale: jax >= 0.5 lowers lax.top_k to the `topk(..., largest)` HLO
    instruction, which the xla_extension 0.5.1 text parser (the version
    behind the rust `xla` crate) rejects.  k <= 5 in every paper config,
    so k max-passes are also the faster lowering.  NOTE on ties: all
    positions equal to the running max are masked together, so with tied
    inputs the k-th "value" can admit more than k winners downstream —
    KeepTopK keeps every tied entry (measure-zero under noisy gating).
    """
    vals = []
    work = x
    for _ in range(k):
        m = jnp.max(work, axis=-1, keepdims=True)
        vals.append(m)
        work = jnp.where(work >= m, NEG, work)
    return jnp.concatenate(vals, axis=-1)


def topk_vals_idx(x, k):
    """(values, indices) of the top-k along the last axis; ties resolve to
    the lowest index (one winner per pass, matching lax.top_k)."""
    n = x.shape[-1]
    iota = jnp.arange(n)
    vals, idxs = [], []
    work = x
    for _ in range(k):
        m = jnp.max(work, axis=-1, keepdims=True)
        # lowest index among the argmaxes
        ismax = work >= m
        idx = jnp.min(jnp.where(ismax, iota, n), axis=-1, keepdims=True)
        vals.append(jnp.take_along_axis(x, idx, axis=-1))
        idxs.append(idx)
        work = jnp.where(iota[None, :] == idx, NEG, work)
    return (jnp.concatenate(vals, axis=-1),
            jnp.concatenate(idxs, axis=-1).astype(jnp.int32))


def cv_squared(x):
    """Squared coefficient of variation of a vector (eq 7 / 11).

    Returns 0 for vectors with a single element (matching the
    tensor2tensor reference behaviour) to avoid NaN on n_experts == 1.
    """
    x = x.astype(jnp.float32)
    if x.shape[-1] <= 1:
        return jnp.float32(0.0)
    mean = jnp.mean(x)
    var = jnp.var(x)
    return var / (mean * mean + EPS)


def expert_ffn_ref(x, w_in, w_out):
    """Batched expert FFN: per-expert ReLU MLP, no biases (paper App. C).

    x:     (n_experts, capacity, d_model)
    w_in:  (n_experts, d_model, d_hidden)
    w_out: (n_experts, d_hidden, d_model)
    -> (n_experts, capacity, d_model)
    """
    h = jnp.maximum(jnp.einsum("ecd,edh->ech", x, w_in), 0.0)
    return jnp.einsum("ech,ehd->ecd", h, w_out)


def noisy_topk_gating_ref(x, w_g, w_noise, noise, k):
    """Noisy Top-K gating (eq 3-5).

    x: (B, d)   w_g, w_noise: (d, n)   noise: (B, n) ~ StandardNormal
    Returns (gates, clean_logits, noisy_logits):
      gates: (B, n) dense, rows sum to 1 with exactly k nonzeros.
    """
    clean = x @ w_g
    if w_noise is None:
        noisy = clean
    else:
        noisy = clean + noise * softplus(x @ w_noise)
    thresh = topk_vals(noisy, k)[:, k - 1:k]
    masked = jnp.where(noisy >= thresh, noisy, -jnp.inf)
    gates = jax.nn.softmax(masked, axis=-1)
    return gates, clean, noisy


def load_ref(clean, noisy, x, w_noise, k):
    """Smooth load estimator (eq 8-10), vector Load(X) of shape (n,).

    clean = x @ w_g, noisy = H(x) as produced by noisy_topk_gating_ref.
    """
    b, n = noisy.shape
    if k >= n:
        return jnp.full((n,), float(b), dtype=jnp.float32)
    # top (k+1) noisy values; for each position i:
    #   kth_excluding = (k+1)-th largest if i in top-k else k-th largest
    top_vals = topk_vals(noisy, k + 1)
    kth_incl = top_vals[:, k - 1:k]       # k-th largest (threshold if out)
    kth_excl_in = top_vals[:, k:k + 1]    # (k+1)-th largest (if i in top-k)
    is_in = noisy >= kth_incl
    threshold = jnp.where(is_in, kth_excl_in, kth_incl)
    sigma = softplus(x @ w_noise)
    p = normal_cdf((clean - threshold) / (sigma + EPS))
    return jnp.sum(p, axis=0)


def importance_ref(gates):
    return jnp.sum(gates, axis=0)


def dispatch_ref(x, gates, capacity):
    """Capacity-based dispatch (Mesh-TF one-hot formulation).

    x: (B, d), gates: (B, n) sparse-dense.
    Returns (expert_in, combine, dropped):
      expert_in: (n, capacity, d) token slots per expert (zero padded)
      combine:   (B, n, capacity) combine weights (gate value at the slot)
      dropped:   scalar fraction of (token, expert) routes dropped.
    """
    b, n = gates.shape
    nonzero = (gates > 0).astype(jnp.int32)
    # position of each token within its expert's queue, in batch order
    pos = jnp.cumsum(nonzero, axis=0) - 1                  # (B, n)
    keep = nonzero * (pos < capacity).astype(jnp.int32)
    routes = jnp.sum(nonzero)
    dropped = 1.0 - jnp.sum(keep) / jnp.maximum(routes, 1)
    pos_oh = jax.nn.one_hot(pos, capacity, dtype=x.dtype) * keep[..., None].astype(x.dtype)
    # dispatch tensor: (B, n, capacity)
    expert_in = jnp.einsum("bnc,bd->ncd", pos_oh, x)
    combine = pos_oh * gates[..., None]
    return expert_in, combine, dropped


def combine_ref(expert_out, combine):
    """expert_out: (n, capacity, d); combine: (B, n, capacity) -> (B, d)."""
    return jnp.einsum("bnc,ncd->bd", combine, expert_out)


def moe_ref(x, w_g, w_noise, noise, w_in, w_out, k, capacity):
    """Full flat MoE layer forward (reference path, eq 1)."""
    gates, clean, noisy = noisy_topk_gating_ref(x, w_g, w_noise, noise, k)
    expert_in, combine, dropped = dispatch_ref(x, gates, capacity)
    expert_out = expert_ffn_ref(expert_in, w_in, w_out)
    y = combine_ref(expert_out, combine)
    return y, gates, clean, noisy, dropped


def batchwise_mask_ref(scores, m):
    """Appendix F strictly-balanced mask M_batchwise (eq 18).

    scores: (B, n).  Keeps the top-m values per expert (column).
    """
    b, n = scores.shape
    top_vals = jax.lax.top_k(scores.T, m)[0]      # (n, m)
    thresh = top_vals[:, m - 1]                   # (n,)
    return (scores >= thresh[None, :]).astype(scores.dtype)


def threshold_mask_ref(scores, t):
    """Appendix F inference-time mask M_threshold (eq 19)."""
    return (scores > t[None, :]).astype(scores.dtype)


def batchwise_loss_ref(scores, t, m):
    """Appendix F threshold-learning loss (eq 20)."""
    mb = batchwise_mask_ref(scores, m)
    mt = threshold_mask_ref(scores, t)
    return jnp.sum((mt - mb) * (scores - t[None, :]))
