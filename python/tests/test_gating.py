"""L2 gating semantics: the load estimator (Appendix A), balance losses
(Section 4), hierarchical gating (Appendix B) and strictly-balanced gating
(Appendix F)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import gating
from compile.kernels import ref


def rng(seed):
    return np.random.RandomState(seed)


# ------------------------------------------------- load estimator (App A)

def test_load_estimator_matches_monte_carlo():
    """P(x,i) (eq 9) must equal the empirical probability that expert i is
    selected under a fresh noise draw on component i."""
    r = rng(0)
    b, d, n, k = 4, 6, 8, 2
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    wg = jnp.asarray(r.randn(d, n) * 0.7, jnp.float32)
    wn = jnp.asarray(r.randn(d, n) * 0.3, jnp.float32)
    noise = jnp.asarray(r.randn(b, n), jnp.float32)
    _, clean, noisy = ref.noisy_topk_gating_ref(x, wg, wn, noise, k)
    load = np.asarray(ref.load_ref(clean, noisy, x, wn, k))

    # Monte Carlo: for each (x, i), resample noise_i keeping others fixed
    trials = 4000
    sigma = np.asarray(jax.nn.softplus(x @ wn))
    clean_np, noisy_np = np.asarray(clean), np.asarray(noisy)
    mc = np.zeros(n)
    rs = rng(1)
    for t in range(trials):
        for i in range(n):
            h = noisy_np.copy()
            h[:, i] = clean_np[:, i] + rs.randn(b) * sigma[:, i]
            kth = np.sort(np.delete(h, i, axis=1), axis=1)[:, -k]
            mc[i] += np.sum(h[:, i] > kth)
    mc /= trials
    np.testing.assert_allclose(load, mc, rtol=0.12, atol=0.12)


def test_load_degenerate_k_equals_n():
    r = rng(2)
    x = jnp.asarray(r.randn(5, 4), jnp.float32)
    wn = jnp.asarray(r.randn(4, 3), jnp.float32)
    clean = jnp.asarray(r.randn(5, 3), jnp.float32)
    load = ref.load_ref(clean, clean, x, wn, 3)
    np.testing.assert_allclose(load, np.full(3, 5.0))


def test_cv_squared():
    assert float(ref.cv_squared(jnp.array([1.0, 1.0, 1.0]))) < 1e-6
    assert float(ref.cv_squared(jnp.array([5.0]))) == 0.0
    x = np.abs(rng(3).randn(16)) + 0.1
    want = np.var(x) / np.mean(x) ** 2
    np.testing.assert_allclose(float(ref.cv_squared(jnp.asarray(x))), want,
                               rtol=1e-4)


def test_balance_loss_zero_when_uniform():
    """Perfectly uniform gates => CV^2 terms vanish."""
    b, n, d = 8, 4, 4
    x = jnp.ones((b, d))
    out = gating.flat_gating(x, jnp.zeros((d, n)), jnp.zeros((d, n)),
                             jnp.zeros((b, n)), k=n, w_importance=1.0,
                             w_load=1.0, train=True, use_kernel=False)
    assert float(out.balance_loss) < 1e-6


def test_balance_loss_penalises_collapse():
    """Gates collapsed onto one expert => large CV^2."""
    r = rng(4)
    b, n, d = 16, 8, 4
    x = jnp.asarray(np.abs(r.randn(b, d)) + 1.0, jnp.float32)
    wg = jnp.zeros((d, n)).at[:, 0].set(10.0)  # favour expert 0 strongly
    out = gating.flat_gating(x, wg, jnp.zeros((d, n)),
                             jnp.zeros((b, n)), k=2, w_importance=1.0,
                             w_load=0.0, train=True, use_kernel=False)
    assert float(out.cv_importance) > 1.0


# ------------------------------------------------- hierarchical (App B)

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 99))
def test_hierarchical_gates_normalised(seed):
    r = rng(seed)
    b, d, a, g, k = 10, 6, 4, 3, 2
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    out = gating.hierarchical_gating(
        x, jnp.asarray(r.randn(d, a), jnp.float32) * 0.3,
        jnp.asarray(r.randn(d, a), jnp.float32) * 0.3,
        jnp.asarray(r.randn(d, a, g), jnp.float32) * 0.3,
        jnp.asarray(r.randn(d, a, g), jnp.float32) * 0.3,
        jnp.asarray(r.randn(b, a), jnp.float32),
        jnp.asarray(r.randn(b, a, g), jnp.float32),
        k, w_importance=0.1, w_load=0.1, train=True)
    gates = np.asarray(out.gates)
    # product gates: sum over the flattened a*g experts equals 1 (eq 12
    # with both levels softmax-normalised over their support)
    np.testing.assert_allclose(gates.sum(-1), np.ones(b), rtol=1e-5)
    # exactly k*k active experts per token
    assert ((gates > 1e-9).sum(-1) == k * k).all()
    assert out.load.shape == (a * g,)
    assert float(jnp.min(out.load)) >= 0.0


def test_hierarchical_importance_matches_eq13():
    r = rng(11)
    b, d, a, g, k = 6, 4, 3, 2, 1
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    args = (x, jnp.asarray(r.randn(d, a), jnp.float32),
            jnp.zeros((d, a), jnp.float32),
            jnp.asarray(r.randn(d, a, g), jnp.float32),
            jnp.zeros((d, a, g), jnp.float32),
            jnp.zeros((b, a), jnp.float32),
            jnp.zeros((b, a, g), jnp.float32))
    out = gating.hierarchical_gating(*args, k, w_importance=0.1, w_load=0.1,
                                     train=True)
    np.testing.assert_allclose(out.importance,
                               np.asarray(out.gates).sum(0), rtol=1e-5)


# ------------------------------------------- strictly balanced (App F)

def test_batchwise_mask_exact_m_per_expert():
    r = rng(5)
    scores = jnp.asarray(r.rand(24, 6), jnp.float32)
    m = 8
    mask = ref.batchwise_mask_ref(scores, m)
    np.testing.assert_array_equal(np.asarray(mask).sum(0), np.full(6, m))


def test_batchwise_gating_train_and_infer():
    r = rng(6)
    b, d, n, m = 32, 8, 4, 16
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    wg = jnp.asarray(r.randn(d, n), jnp.float32)
    gates, scores = gating.batchwise_gating(x, wg, m, train=True)
    assert ((np.asarray(gates) > 0).sum(0) == m).all()
    np.testing.assert_allclose(np.asarray(gates).sum(-1),
                               np.ones(b), rtol=1e-4)
    # inference with learned thresholds approximates the batchwise mask
    t = jnp.quantile(scores, 1 - m / b, axis=0)
    gi, _ = gating.batchwise_gating(x, wg, m, train=False, thresholds=t)
    agree = (np.asarray(gi) > 0) == (np.asarray(gates) > 0)
    assert agree.mean() > 0.9


def test_batchwise_threshold_loss_zero_at_optimum():
    """Eq 20 is zero when the threshold mask reproduces the batchwise mask
    exactly (thresholds sitting between the m-th and (m+1)-th scores)."""
    r = rng(7)
    scores = jnp.asarray(r.rand(16, 3), jnp.float32)
    m = 4
    srt = np.sort(np.asarray(scores), axis=0)[::-1]
    t = jnp.asarray((srt[m - 1] + srt[m]) / 2)
    loss = ref.batchwise_loss_ref(scores, t, m)
    np.testing.assert_allclose(float(loss), 0.0, atol=1e-6)
    # and positive when thresholds are wrong
    loss2 = ref.batchwise_loss_ref(scores, t + 0.2, m)
    assert float(loss2) > 0
