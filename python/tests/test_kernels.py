"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/k; every test asserts allclose against ref.  This
is the CORE kernel correctness signal — the AOT artifacts embed exactly
these kernels.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dispatch import combine, dispatch
from compile.kernels.expert_ffn import expert_ffn, pick_block_c, vmem_bytes
from compile.kernels.gating_kernel import noisy_topk_gating

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.RandomState(seed)


# --------------------------------------------------------------- expert FFN

@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 6), c=st.integers(1, 33), d=st.integers(1, 24),
       h=st.integers(1, 40), seed=st.integers(0, 2 ** 16))
def test_expert_ffn_matches_ref(n, c, d, h, seed):
    r = rng(seed)
    x = jnp.asarray(r.randn(n, c, d), jnp.float32)
    w_in = jnp.asarray(r.randn(n, d, h) * 0.3, jnp.float32)
    w_out = jnp.asarray(r.randn(n, h, d) * 0.3, jnp.float32)
    got = expert_ffn(x, w_in, w_out)
    want = ref.expert_ffn_ref(x, w_in, w_out)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_c", [8, 16, 64])
def test_expert_ffn_block_invariance(block_c):
    r = rng(0)
    x = jnp.asarray(r.randn(3, 48, 16), jnp.float32)
    w_in = jnp.asarray(r.randn(3, 16, 32) * 0.2, jnp.float32)
    w_out = jnp.asarray(r.randn(3, 32, 16) * 0.2, jnp.float32)
    got = expert_ffn(x, w_in, w_out, block_c=block_c)
    np.testing.assert_allclose(got, ref.expert_ffn_ref(x, w_in, w_out),
                               rtol=1e-4, atol=1e-4)


def test_expert_ffn_grad_matches_ref():
    r = rng(1)
    x = jnp.asarray(r.randn(2, 8, 6), jnp.float32)
    w_in = jnp.asarray(r.randn(2, 6, 10) * 0.3, jnp.float32)
    w_out = jnp.asarray(r.randn(2, 10, 6) * 0.3, jnp.float32)

    def f_kernel(*a):
        return jnp.sum(jnp.sin(expert_ffn(*a)))

    def f_ref(*a):
        return jnp.sum(jnp.sin(ref.expert_ffn_ref(*a)))

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w_in, w_out)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w_in, w_out)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_vmem_budget_picker():
    # picker must respect the budget and stay a power-of-two-ish block
    for cap, d, h in [(1024, 512, 1024), (4096, 256, 4096), (64, 64, 64)]:
        bc = pick_block_c(cap, d, h)
        assert 8 <= bc <= cap
        assert vmem_bytes(bc, d, h) <= 8 * 2 ** 20 or bc == 8


# ------------------------------------------------------------------- gating

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 40), d=st.integers(1, 16), n=st.integers(2, 32),
       k=st.integers(1, 4), seed=st.integers(0, 2 ** 16))
def test_gating_matches_ref(b, d, n, k, seed):
    k = min(k, n)
    r = rng(seed)
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    wg = jnp.asarray(r.randn(d, n) * 0.4, jnp.float32)
    wn = jnp.asarray(r.randn(d, n) * 0.4, jnp.float32)
    noise = jnp.asarray(r.randn(b, n), jnp.float32)
    g1, c1, n1 = noisy_topk_gating(x, wg, wn, noise, k=k)
    g2, c2, n2 = ref.noisy_topk_gating_ref(x, wg, wn, noise, k)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(c1, c2, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(n1, n2, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 16), n=st.integers(2, 16), k=st.integers(1, 4),
       seed=st.integers(0, 999))
def test_gating_invariants(b, n, k, seed):
    """Rows sum to 1 with exactly k nonzeros (paper eq 3-5)."""
    k = min(k, n)
    r = rng(seed)
    x = jnp.asarray(r.randn(b, 8), jnp.float32)
    wg = jnp.asarray(r.randn(8, n), jnp.float32)
    noise = jnp.asarray(r.randn(b, n), jnp.float32)
    g, _, _ = noisy_topk_gating(x, wg, None, noise, k=k)
    np.testing.assert_allclose(np.sum(g, -1), np.ones(b), rtol=1e-5)
    assert ((np.asarray(g) > 0).sum(-1) == k).all()
    assert (np.asarray(g) >= 0).all()


def test_gating_nonnoisy_path():
    r = rng(3)
    x = jnp.asarray(r.randn(6, 4), jnp.float32)
    wg = jnp.asarray(r.randn(4, 8), jnp.float32)
    g1, c1, n1 = noisy_topk_gating(x, wg, None, None, k=2)
    g2, c2, n2 = ref.noisy_topk_gating_ref(x, wg, None, None, 2)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(c1, n1)  # no noise: clean == noisy


def test_gating_grad_matches_ref():
    r = rng(4)
    x = jnp.asarray(r.randn(10, 6), jnp.float32)
    wg = jnp.asarray(r.randn(6, 8) * 0.5, jnp.float32)
    wn = jnp.asarray(r.randn(6, 8) * 0.5, jnp.float32)
    noise = jnp.asarray(r.randn(10, 8), jnp.float32)

    def loss_k(x, wg, wn):
        g, c, nz = noisy_topk_gating(x, wg, wn, noise, k=2)
        return jnp.sum(g * jnp.arange(8.0)) + jnp.sum(jnp.cos(nz))

    def loss_r(x, wg, wn):
        g, c, nz = ref.noisy_topk_gating_ref(x, wg, wn, noise, 2)
        return jnp.sum(g * jnp.arange(8.0)) + jnp.sum(jnp.cos(nz))

    g1 = jax.grad(loss_k, argnums=(0, 1, 2))(x, wg, wn)
    g2 = jax.grad(loss_r, argnums=(0, 1, 2))(x, wg, wn)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-4)


# --------------------------------------------------------- dispatch/combine

@settings(max_examples=15, deadline=None)
@given(b=st.integers(1, 32), n=st.integers(1, 8), cap=st.integers(1, 16),
       d=st.integers(1, 16), seed=st.integers(0, 2 ** 16))
def test_dispatch_combine_match_ref(b, n, cap, d, seed):
    r = rng(seed)
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    gates = jax.nn.softmax(jnp.asarray(r.randn(b, n), jnp.float32))
    ein_ref, cw, _ = ref.dispatch_ref(x, gates, cap)
    pos_oh = (cw > 0).astype(jnp.float32)
    np.testing.assert_allclose(dispatch(pos_oh, x), ein_ref,
                               rtol=1e-4, atol=1e-5)
    eo = jnp.asarray(r.randn(n, cap, d), jnp.float32)
    np.testing.assert_allclose(combine(cw, eo), ref.combine_ref(eo, cw),
                               rtol=1e-4, atol=1e-4)


def test_dispatch_combine_roundtrip_identity():
    """With capacity >= routes, combine(dispatch(x)) with gates summing to 1
    reconstructs sum_i g_i * x for identity experts."""
    r = rng(7)
    b, n, d, cap = 12, 4, 8, 12
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    gates, _, _ = ref.noisy_topk_gating_ref(
        x, jnp.asarray(r.randn(d, n), jnp.float32), None, None, 2)
    ein, cw, dropped = ref.dispatch_ref(x, gates, cap)
    assert float(dropped) == 0.0
    y = combine(cw, ein)  # identity experts: expert_out == expert_in
    np.testing.assert_allclose(y, x, rtol=1e-4, atol=1e-4)


def test_combine_grad_flows_to_gates():
    """The gate gradient (paper §2.1) must flow through combine weights."""
    r = rng(8)
    b, n, cap, d = 6, 3, 4, 5
    cw = jnp.asarray(np.abs(r.randn(b, n, cap)), jnp.float32)
    eo = jnp.asarray(r.randn(n, cap, d), jnp.float32)
    g = jax.grad(lambda c: jnp.sum(combine(c, eo) ** 2))(cw)
    assert np.abs(np.asarray(g)).sum() > 0
    g_ref = jax.grad(lambda c: jnp.sum(ref.combine_ref(eo, c) ** 2))(cw)
    np.testing.assert_allclose(g, g_ref, rtol=1e-4, atol=1e-4)
