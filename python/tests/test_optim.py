"""Optimizers: Adam and the Appendix-D factored second-moment variant."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile import configs, model, optim
from compile.params import ParamSpec


def small_spec():
    spec = ParamSpec()
    spec.add("a", (4, 6), "normal")
    spec.add("b", (6,), "zeros")
    spec.add("c", (3, 5), "uniform")
    return spec


def test_lr_schedule_shape():
    lr = [float(optim.lr_schedule(1.0, 100, jnp.int32(s)))
          for s in [1, 50, 100, 400, 10000]]
    assert lr[0] < lr[1] < lr[2]            # warmup rises
    assert lr[2] > lr[3] > lr[4]            # then decays
    np.testing.assert_allclose(lr[2], 1.0, rtol=1e-5)
    np.testing.assert_allclose(lr[3], 0.5, rtol=1e-5)  # sqrt(100/400)


def test_adam_matches_manual():
    r = np.random.RandomState(0)
    n = 20
    flat = jnp.asarray(r.randn(n), jnp.float32)
    g = jnp.asarray(r.randn(n), jnp.float32)
    m = jnp.zeros(n); v = jnp.zeros(n)
    new, m2, v2 = optim.adam_update(flat, m, v, g, jnp.int32(0), 0.1)
    mm = 0.1 * np.asarray(g)                 # (1-b1)*g
    vv = 0.001 * np.asarray(g) ** 2
    mhat = mm / (1 - 0.9)
    vhat = vv / (1 - 0.999)
    want = np.asarray(flat) - 0.1 * mhat / (np.sqrt(vhat) + optim.ADAM_EPS)
    np.testing.assert_allclose(new, want, rtol=1e-5, atol=1e-6)


def test_factored_layout_sizes():
    spec = small_spec()
    layout, total = optim.factored_layout(spec)
    # a: 4+6, b: 6 full, c: 3+5
    assert total == 10 + 6 + 8
    kinds = {name: kind for name, kind, *_ in layout}
    assert kinds == {"a": "factored", "b": "full", "c": "factored"}
    m_sz, v_sz = optim.factored_sizes(spec)
    assert m_sz == 0 and v_sz == total


def test_factored_vhat_is_rank_one_approx():
    """After one update from zero state, vhat for a matrix equals the
    rank-1 outer-product estimate of g^2 (Appendix D)."""
    spec = ParamSpec()
    spec.add("w", (3, 4), "normal")
    r = np.random.RandomState(1)
    flat = jnp.asarray(r.randn(12), jnp.float32)
    g = jnp.asarray(r.randn(12), jnp.float32)
    _, v_sz = optim.factored_sizes(spec)
    new, _, v2 = optim.factored_update(spec, flat, jnp.zeros(0),
                                       jnp.zeros(v_sz), g, jnp.int32(0), 0.1)
    g2 = np.asarray(g).reshape(3, 4) ** 2 + 1e-30
    rmean = g2.mean(1) * (1 - optim.B2)
    cmean = g2.mean(0) * (1 - optim.B2)
    np.testing.assert_allclose(v2[:3], rmean, rtol=1e-4)
    np.testing.assert_allclose(v2[3:], cmean, rtol=1e-4)
    vhat = np.outer(rmean, cmean) / rmean.mean() / (1 - optim.B2)
    want = np.asarray(flat).reshape(3, 4) - 0.1 * np.asarray(g).reshape(
        3, 4) / (np.sqrt(vhat) + optim.ADAM_EPS)
    np.testing.assert_allclose(new.reshape(3, 4), want, rtol=1e-3, atol=1e-5)


def test_factored_trains_tiny_model():
    cfg = dataclasses.replace(configs.get("test-tiny"), optimizer="factored",
                              name="t-fact")
    built = model.build(cfg)
    flat, m, v = built.init(jnp.int32(0))
    assert m.shape == (0,)
    toks = jax.random.randint(jax.random.PRNGKey(0),
                              (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)
    step = jax.jit(built.train_step)
    first = None
    for i in range(25):
        flat, m, v, met = step(flat, m, v, toks, jnp.int32(i))
        if first is None:
            first = float(met[1])
        assert np.isfinite(np.asarray(met)).all()
    assert float(met[1]) < first


def test_factored_memory_saving():
    """The point of Appendix D: second-moment storage is ~sqrt of Adam's
    for expert-dominated models."""
    cfg = configs.get("e2e-100m")
    spec = model.make_spec(cfg)
    _, v_fact = optim.factored_sizes(spec)
    _, v_adam = optim.adam_sizes(spec)
    assert v_fact < v_adam / 10
