"""L2 model: every middle-layer variant builds, trains (loss decreases),
and the kernel path agrees with the pure-ref path end to end."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model


def tiny(base="test-tiny", **kw):
    return dataclasses.replace(configs.get(base), **kw)


def data(cfg, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed),
                              (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab)


MIDDLES = ["moe", "wide", "deep", "lstm", "none"]


@pytest.mark.parametrize("middle", MIDDLES)
def test_variants_build_and_step(middle):
    cfg = tiny(name=f"v-{middle}", middle=middle)
    built = model.build(cfg)
    flat, m, v = built.init(jnp.int32(0))
    toks = data(cfg)
    f2, m2, v2, met = jax.jit(built.train_step)(flat, m, v, toks,
                                                jnp.int32(0))
    assert f2.shape == flat.shape
    assert np.isfinite(np.asarray(met)).all()
    ev = jax.jit(built.eval_step)(f2, toks)
    assert float(ev[1]) == cfg.batch * cfg.seq_len


@pytest.mark.parametrize("name", ["test-tiny", "test-hier"])
def test_loss_decreases(name):
    cfg = tiny(name)
    built = model.build(cfg)
    flat, m, v = built.init(jnp.int32(0))
    step = jax.jit(built.train_step)
    toks = data(cfg)
    first = None
    for i in range(30):
        flat, m, v, met = step(flat, m, v, toks, jnp.int32(i))
        if first is None:
            first = float(met[1])
    assert float(met[1]) < first - 0.1, (first, float(met[1]))


def test_kernel_path_matches_ref_path():
    cfg = tiny(dropout=0.0)
    bk = model.build(cfg, use_kernels=True)
    br = model.build(cfg, use_kernels=False)
    flat, m, v = bk.init(jnp.int32(0))
    toks = data(cfg)
    rng = jax.random.PRNGKey(0)
    lk, _ = jax.jit(lambda f: bk.forward(f, toks[:, :-1], rng, True))(flat)
    lr_, _ = jax.jit(lambda f: br.forward(f, toks[:, :-1], rng, True))(flat)
    np.testing.assert_allclose(lk, lr_, rtol=1e-3, atol=1e-3)
    # and the full training step (incl. gradients through kernels)
    fk, _, _, mk = jax.jit(bk.train_step)(flat, m, v, toks, jnp.int32(0))
    fr, _, _, mr = jax.jit(br.train_step)(flat, m, v, toks, jnp.int32(0))
    np.testing.assert_allclose(fk, fr, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(mk, mr, rtol=2e-3, atol=1e-4)


def test_eval_deterministic_and_noise_free():
    cfg = tiny()
    built = model.build(cfg)
    flat, _, _ = built.init(jnp.int32(0))
    toks = data(cfg)
    e1 = jax.jit(built.eval_step)(flat, toks)
    e2 = jax.jit(built.eval_step)(flat, toks)
    np.testing.assert_array_equal(np.asarray(e1), np.asarray(e2))


def test_decode_step_matches_forward():
    """Incremental decode over T steps must equal the scan forward (no
    dropout, eval gating).  capacity_factor is raised so the convolutional
    path drops no routes — otherwise late timesteps can overflow expert
    capacity in the batched path but never in the per-step path."""
    cfg = tiny(dropout=0.0, capacity_factor=8.0)
    built = model.build(cfg)
    flat, _, _ = built.init(jnp.int32(0))
    B, T = 8, 5
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0, cfg.vocab)
    logits_full, _ = built.forward(flat, toks, jax.random.PRNGKey(0), False)
    dh = cfg.lstm_hidden
    dout = cfg.lstm_proj or dh
    cs = jnp.zeros((built.n_lstm, B, dh))
    hs = jnp.zeros((built.n_lstm, B, dout))
    dec = jax.jit(built.decode_step)
    outs = []
    for t in range(T):
        lg, cs, hs = dec(flat, cs, hs, toks[:, t])
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    # decode capacity differs from train capacity; MoE selection identical
    np.testing.assert_allclose(got, logits_full, rtol=2e-3, atol=2e-3)


def test_param_layout_covers_flat_vector():
    cfg = tiny()
    built = model.build(cfg)
    layout = built.spec.layout_json()
    total = sum(int(np.prod(e["shape"])) for e in layout)
    assert total == built.spec.size
    offs = sorted((e["offset"], int(np.prod(e["shape"]))) for e in layout)
    pos = 0
    for off, sz in offs:
        assert off == pos
        pos += sz


def test_metrics_vector_order():
    assert model.METRIC_NAMES[0] == "loss"
    assert len(model.METRIC_NAMES) == 9
