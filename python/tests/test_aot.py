"""AOT pipeline: lowering produces parseable-by-XLA-0.5.1 HLO text and a
manifest whose shapes match the functions."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import aot, configs, model
from compile.kernels import ref


# ---------------------------------------------------- legacy-HLO hygiene --

def test_topk_vals_matches_lax_topk():
    r = np.random.RandomState(0)
    x = jnp.asarray(r.randn(16, 9), jnp.float32)
    for k in [1, 2, 4]:
        want = jax.lax.top_k(x, k)[0]
        got = ref.topk_vals(x, k)
        np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 12), n=st.integers(2, 12), k=st.integers(1, 5),
       seed=st.integers(0, 999))
def test_topk_vals_idx_matches_lax_topk(b, n, k, seed):
    k = min(k, n)
    r = np.random.RandomState(seed)
    x = jnp.asarray(r.randn(b, n), jnp.float32)
    wv, wi = jax.lax.top_k(x, k)
    gv, gi = ref.topk_vals_idx(x, k)
    np.testing.assert_allclose(gv, wv, rtol=1e-6)
    np.testing.assert_array_equal(gi, wi)


def test_erf_poly_accuracy():
    import math
    xs = np.linspace(-4, 4, 200, dtype=np.float32)
    got = np.asarray(ref.erf_poly(jnp.asarray(xs)))
    want = np.array([math.erf(float(x)) for x in xs])
    np.testing.assert_allclose(got, want, atol=5e-6)  # f32 rounding on top of the 1.5e-7 poly error


def test_normal_cdf_poly_accuracy():
    from jax.scipy.stats import norm
    xs = jnp.linspace(-4, 4, 101)
    np.testing.assert_allclose(ref.normal_cdf(xs), norm.cdf(xs), atol=5e-6)


# ----------------------------------------------------------- lowering  --

@pytest.fixture(scope="module")
def lowered(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = configs.get("test-tiny")
    entry = aot.lower_config(cfg, out, set())
    return out, cfg, entry


FORBIDDEN = [" topk(", " erf(", "topk.1"]


def test_hlo_text_avoids_post_051_opcodes(lowered):
    out, _, entry = lowered
    for kind, art in entry["artifacts"].items():
        text = (out / art["file"]).read_text()
        for op in FORBIDDEN:
            assert op not in text, f"{kind} artifact contains '{op}'"


def test_manifest_shapes_match_eval_shape(lowered):
    _, cfg, entry = lowered
    built = model.build(cfg)
    # init: 1 input, 3 outputs with param/opt sizes
    init = entry["artifacts"]["init"]
    assert init["outputs"][0]["shape"] == [entry["param_size"]]
    assert init["outputs"][1]["shape"] == [entry["opt_sizes"][0]]
    assert init["outputs"][2]["shape"] == [entry["opt_sizes"][1]]
    # step round-trips params
    step = entry["artifacts"]["step"]
    assert step["inputs"][0] == step["outputs"][0]
    assert step["inputs"][3]["dtype"] == "int32"
    assert step["outputs"][3]["shape"] == [len(model.METRIC_NAMES)]
    # param layout covers the vector
    total = sum(int(np.prod(p["shape"])) for p in entry["param_layout"])
    assert total == entry["param_size"] == built.spec.size


def test_manifest_json_serialisable(lowered):
    _, _, entry = lowered
    s = json.dumps({"configs": {"test-tiny": entry}})
    back = json.loads(s)
    assert back["configs"]["test-tiny"]["param_size"] == entry["param_size"]


def test_gating_artifact_semantics(lowered):
    """The gating artifact's top-k outputs must agree with the dense gates
    it also returns."""
    out, cfg, entry = lowered
    from compile.gating import flat_gating
    from compile.kernels.ref import topk_vals_idx

    d, n, k = cfg.d_model, cfg.n_experts, cfg.k
    r = np.random.RandomState(1)
    b = cfg.batch * cfg.seq_len
    wg = jnp.asarray(r.randn(d, n) * 0.4, jnp.float32)
    wn = jnp.asarray(r.randn(d, n) * 0.2, jnp.float32)
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    noise = jnp.asarray(r.randn(b, n), jnp.float32)
    g = flat_gating(x, wg, wn, noise, k, w_importance=0.0, w_load=0.0,
                    train=True)
    topw, topi = topk_vals_idx(g.gates, k)
    # weights sorted desc and sum to 1 (all k selected)
    np.testing.assert_allclose(np.asarray(topw).sum(-1), np.ones(b),
                               rtol=1e-5)
    dense = np.asarray(g.gates)
    for row in range(b):
        for j in range(k):
            np.testing.assert_allclose(
                dense[row, topi[row, j]], topw[row, j], rtol=1e-6)


def test_ops_accounting_matches_paper_structure():
    """MoE ladder configs are compute-matched: ops/timestep within 2x of
    each other while MoE params vary by ~100x (the Figure 2-left setup)."""
    ladder = ["moe-4", "moe-32", "moe-256", "moe-256-h", "moe-1024-h"]
    ops = [configs.get(n).ops_per_timestep for n in ladder]
    params = [configs.get(n).moe_params for n in ladder]
    assert max(ops) / min(ops) < 2.0, ops
    assert params[-1] / params[0] > 100, params
    # dense baselines also matched
    for n in ["moe-1-wide", "moe-1-deep", "lstm-4x"]:
        assert configs.get(n).ops_per_timestep < 2 * min(ops)
