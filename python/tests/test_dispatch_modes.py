"""Gather vs einsum dispatch equivalence (the §Perf L2 optimization must
be a pure refactor: identical forward, gradients and drop accounting)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, moe
from compile.params import ParamSpec


def cfgs():
    base = dataclasses.replace(configs.get("test-tiny"), dropout=0.0)
    return (dataclasses.replace(base, dispatch="gather"),
            dataclasses.replace(base, dispatch="einsum"))


def test_train_step_identical_across_dispatch_modes():
    cfg_g, cfg_e = cfgs()
    bg, be = model.build(cfg_g), model.build(cfg_e)
    flat, m, v = bg.init(jnp.int32(0))
    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (cfg_g.batch, cfg_g.seq_len + 1), 0, cfg_g.vocab)
    fg, _, _, mg = jax.jit(bg.train_step)(flat, m, v, toks, jnp.int32(0))
    fe, _, _, me = jax.jit(be.train_step)(flat, m, v, toks, jnp.int32(0))
    np.testing.assert_allclose(fg, fe, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(mg, me, rtol=2e-3, atol=1e-4)


def test_gather_dispatch_reconstructs_einsum_dispatch():
    from compile.kernels import ref
    r = np.random.RandomState(0)
    b, n, d, cap, k = 24, 6, 8, 10, 2
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    gates, _, _ = ref.noisy_topk_gating_ref(
        x, jnp.asarray(r.randn(d, n), jnp.float32), None, None, k)
    ein, cw, dropped_e = ref.dispatch_ref(x, gates, cap)
    got, dropped_g, _ = moe.gather_dispatch(gates, x, cap)
    np.testing.assert_allclose(got, ein, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(dropped_g), float(dropped_e), atol=1e-6)


def test_gather_combine_matches_einsum_combine():
    from compile.kernels import ref
    r = np.random.RandomState(1)
    b, n, d, cap, k = 16, 5, 6, 12, 2
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    gates, _, _ = ref.noisy_topk_gating_ref(
        x, jnp.asarray(r.randn(d, n), jnp.float32), None, None, k)
    _, cw, _ = ref.dispatch_ref(x, gates, cap)
    expert_in, _, aux = moe.gather_dispatch(gates, x, cap)
    eo = jnp.asarray(r.randn(n, cap, d), jnp.float32)
    want = ref.combine_ref(eo, cw)
    got = moe.gather_combine(gates, eo, aux, k)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_gather_combine_gradient_reaches_gates():
    from compile.kernels import ref
    r = np.random.RandomState(2)
    b, n, d, cap, k = 12, 4, 5, 8, 2
    x = jnp.asarray(r.randn(b, d), jnp.float32)
    wg = jnp.asarray(r.randn(d, n), jnp.float32)
    eo = jnp.asarray(r.randn(n, cap, d), jnp.float32)

    def loss(wg):
        gates, _, _ = ref.noisy_topk_gating_ref(x, wg, None, None, k)
        _, _, aux = moe.gather_dispatch(gates, x, cap)
        y = moe.gather_combine(gates, eo, aux, k)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(wg)
    assert float(jnp.abs(g).sum()) > 0, "gate gradient vanished"


def test_gather_dispatch_drops_overflow_in_batch_order():
    """With capacity 1, only the first token per expert is kept."""
    gates = jnp.asarray([[1.0, 0.0], [1.0, 0.0], [0.0, 1.0]], jnp.float32)
    x = jnp.arange(6, dtype=jnp.float32).reshape(3, 2)
    ein, dropped, aux = moe.gather_dispatch(gates, x, 1)
    np.testing.assert_allclose(ein[0, 0], x[0])   # expert 0 slot: token 0
    np.testing.assert_allclose(ein[1, 0], x[2])   # expert 1 slot: token 2
    assert abs(float(dropped) - 1.0 / 3.0) < 1e-6  # token 1's route dropped
