//! Bench/report: the 64 → 4096-expert cluster scaling study.
//!
//! Section 1 (always runs, writes `BENCH_cluster.json`): drive the REAL
//! engine — hierarchical O(√n) local-group routing, streaming dispatch,
//! GShard-style capacity buffers — at every rung of the expert ladder,
//! then price each step's *measured* dispatch plan on the multi-host
//! [`Topology`] model using the corrected §3.2 traffic accounting
//! (same-device routes are free; only inter-device bytes hit a link).
//! Swept at exact dispatch and capacity factors 1.0 / 2.0 so the curves
//! show what capping buys (bounded buffers, pay in dropped tokens) and
//! what it costs.  Set `BENCH_SMOKE=1` for a single-iteration CI run.
//!
//! Section 2 (print-only): the original analytic ladder out to the
//! paper's 131072-expert configuration (Table 8), which no real plan
//! can drive at this scale — retained as the TFLOPS/GPU shape check.

use moe::cluster::perf::{model_step, ClusterSpec};
use moe::harness::cluster_sim::{point_line, scaling_ladder, ClusterSim};
use moe::metrics::OpsModel;
use moe::runtime::ModelConfig;
use moe::util::bench::{black_box, BenchReport, Bencher};

fn measured_ladder(bench: &Bencher, report: &mut BenchReport) {
    let rows_per_replica = 8usize;
    println!(
        "== measured cluster scaling: real engine + corrected §3.2 \
         pricing (16 experts/device, 8 devices/host) =="
    );
    for cf in [None, Some(1.0f64), Some(2.0)] {
        for n in scaling_ladder() {
            let sim = ClusterSim::build(n, rows_per_replica, cf, 7).unwrap();
            let tokens = sim.tokens();
            let label = match cf {
                None => format!("cluster step n={n} exact"),
                Some(f) => format!("cluster step n={n} cf={f:.1}"),
            };
            // warm the persistent engine, then time the streamed step
            black_box(sim.step(0).unwrap());
            let mut fold = 0u64;
            let r = bench.run(&label, || {
                fold += 1;
                black_box(sim.step(fold).unwrap());
            });
            r.report_throughput("tok", tokens as f64);
            let p = sim.point().unwrap();
            println!("  {}", point_line(&p));
            report.push(
                &r,
                Some(("tok", tokens as f64)),
                &[
                    ("n_experts", p.n_experts as f64),
                    ("groups", p.groups as f64),
                    ("sim_devices", p.sim_devices as f64),
                    ("n_hosts", p.n_hosts as f64),
                    ("tokens", p.tokens as f64),
                    // 0.0 encodes exact (uncapped) dispatch
                    ("capacity_factor", p.capacity_factor),
                    ("capacity", p.capacity as f64),
                    ("offered_routes", p.offered_routes as f64),
                    ("kept_routes", p.kept_routes as f64),
                    ("dropped_routes", p.dropped_routes as f64),
                    ("rerouted_routes", p.rerouted_routes as f64),
                    ("drop_fraction", p.drop_fraction),
                    ("interconnect_bytes", p.interconnect_bytes as f64),
                    ("intra_host_bytes", p.intra_host_bytes as f64),
                    ("inter_host_bytes", p.inter_host_bytes as f64),
                    ("local_bytes", p.local_bytes as f64),
                    ("messages", p.messages as f64),
                    ("gating_time_s", p.timing.gating_time),
                    ("moe_compute_time_s", p.timing.moe_compute_time),
                    ("all_to_all_time_s", p.timing.all_to_all_time),
                    ("step_time_model_s", p.timing.total()),
                    ("model_tok_per_s", p.tokens_per_sec()),
                ],
            );
        }
    }
}

fn cfg(n_experts: usize, k: usize, devices: usize) -> ModelConfig {
    let d = 64;
    let eh = 256;
    ModelConfig {
        name: format!("moe-{n_experts}"),
        vocab: 2048,
        d_model: d,
        lstm_hidden: d,
        lstm_proj: 0,
        middle: "moe".into(),
        n_experts,
        k,
        groups: 0,
        expert_hidden: eh,
        capacity: 64,
        k_effective: k,
        batch: 16 * devices,
        seq_len: 16,
        w_importance: 0.1,
        w_load: 0.1,
        ops_per_timestep: (2 * 4 * (d * d + d * d) * 2 + k * 2 * d * eh) as u64,
        moe_params: (n_experts * 2 * d * eh) as u64,
        optimizer: "adam".into(),
    }
}

fn analytic_ladder() {
    println!(
        "\n== modelled TFLOPS/GPU vs expert count (k=4, analytic loads, \
         out to Table 8's 131072 experts) =="
    );
    println!(
        "{:>9} {:>8} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "experts", "devices", "tokens", "dense(ms)", "moe(ms)", "a2a(ms)",
        "TFLOPS"
    );
    for (n, devices) in [(4usize, 16usize), (32, 16), (256, 16), (1024, 32),
                         (4096, 32), (16384, 64), (65536, 64), (131072, 128)] {
        let c = cfg(n, 4, devices);
        let cluster = ClusterSpec::k40s(devices);
        let tokens = c.batch * c.seq_len;
        let routed = tokens * c.k_effective;
        let loads = vec![routed / n.max(1); n];
        let t = model_step(&c, &cluster, tokens / devices, &loads);
        let ops = OpsModel::from_config(&c);
        println!(
            "{:>9} {:>8} {:>10} {:>12.2} {:>12.2} {:>12.2} {:>10.2}",
            n,
            devices,
            tokens,
            t.dense_time * 1e3,
            t.moe_compute_time * 1e3,
            t.all_to_all_time * 1e3,
            ops.tflops_per_device(tokens as u64, t.total(), devices)
        );
    }

    println!("\n== load-imbalance cost (n=256, 16 devices): step time vs max/mean ==");
    let c = cfg(256, 4, 16);
    let cluster = ClusterSpec::k40s(16);
    let tokens = c.batch * c.seq_len;
    let routed = tokens * 4;
    for imbalance in [1.0f64, 2.0, 4.0, 8.0, 17.8] {
        let mean = routed as f64 / 256.0;
        let mut loads = vec![mean as usize; 256];
        loads[0] = (mean * imbalance) as usize;
        let t = model_step(&c, &cluster, tokens / 16, &loads);
        println!(
            "max/mean {:>5.1}: step {:.2} ms (moe {:.2} ms)",
            imbalance,
            t.total() * 1e3,
            t.moe_compute_time * 1e3
        );
    }
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("cluster");
    measured_ladder(&bench, &mut report);
    report.write("BENCH_cluster.json")?;
    println!("wrote BENCH_cluster.json");
    analytic_ladder();
    Ok(())
}
