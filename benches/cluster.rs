//! Bench/report: the cluster performance model across the paper's expert
//! ladder — regenerates the SHAPE of the TFLOPS/GPU columns (Tables 1, 7,
//! 8), including the efficiency drop at extreme expert counts (Table 8's
//! 131072-expert row) and the §3.1 shrinking-batch effect.

use moe::cluster::perf::{model_step, ClusterSpec};
use moe::metrics::OpsModel;
use moe::runtime::ModelConfig;

fn cfg(n_experts: usize, k: usize, devices: usize) -> ModelConfig {
    let d = 64;
    let eh = 256;
    ModelConfig {
        name: format!("moe-{n_experts}"),
        vocab: 2048,
        d_model: d,
        lstm_hidden: d,
        lstm_proj: 0,
        middle: "moe".into(),
        n_experts,
        k,
        groups: 0,
        expert_hidden: eh,
        capacity: 64,
        k_effective: k,
        batch: 16 * devices,
        seq_len: 16,
        w_importance: 0.1,
        w_load: 0.1,
        ops_per_timestep: (2 * 4 * (d * d + d * d) * 2 + k * 2 * d * eh) as u64,
        moe_params: (n_experts * 2 * d * eh) as u64,
        optimizer: "adam".into(),
    }
}

fn main() {
    println!("== modelled TFLOPS/GPU vs expert count (k=4, batch grows with devices) ==");
    println!(
        "{:>9} {:>8} {:>10} {:>12} {:>12} {:>12} {:>10}",
        "experts", "devices", "tokens", "dense(ms)", "moe(ms)", "a2a(ms)", "TFLOPS"
    );
    for (n, devices) in [(4usize, 16usize), (32, 16), (256, 16), (1024, 32),
                         (4096, 32), (16384, 64), (65536, 64), (131072, 128)] {
        let c = cfg(n, 4, devices);
        let cluster = ClusterSpec::k40s(devices);
        let tokens = c.batch * c.seq_len;
        let routed = tokens * c.k_effective;
        let loads = vec![routed / n.max(1); n];
        let t = model_step(&c, &cluster, tokens / devices, &loads);
        let ops = OpsModel::from_config(&c);
        println!(
            "{:>9} {:>8} {:>10} {:>12.2} {:>12.2} {:>12.2} {:>10.2}",
            n,
            devices,
            tokens,
            t.dense_time * 1e3,
            t.moe_compute_time * 1e3,
            t.all_to_all_time * 1e3,
            ops.tflops_per_device(tokens as u64, t.total(), devices)
        );
    }

    println!("\n== load-imbalance cost (n=256, 16 devices): step time vs max/mean ==");
    let c = cfg(256, 4, 16);
    let cluster = ClusterSpec::k40s(16);
    let tokens = c.batch * c.seq_len;
    let routed = tokens * 4;
    for imbalance in [1.0f64, 2.0, 4.0, 8.0, 17.8] {
        let mean = routed as f64 / 256.0;
        let mut loads = vec![mean as usize; 256];
        loads[0] = (mean * imbalance) as usize;
        let t = model_step(&c, &cluster, tokens / 16, &loads);
        println!(
            "max/mean {:>5.1}: step {:.2} ms (moe {:.2} ms)",
            imbalance,
            t.total() * 1e3,
            t.moe_compute_time * 1e3
        );
    }
}
