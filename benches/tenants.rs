//! Bench/report: the multi-tenant fairness sweep, writing
//! `BENCH_tenants.json`.
//!
//! Three rows — the victim-solo baseline, the heavy-hitter mix under
//! the weighted-fair drain, and the same mix under the global-FIFO
//! baseline.  The timed quantity is one full trace replay through the
//! tenant front-end; the extras carry every tenant's admission ledger
//! (offered/completed/shed/failed + fractions), p99 latency and the
//! conservation flag, so CI can re-assert per-tenant conservation and
//! the isolation claim (victim survives WFQ, drowns under FIFO) from
//! the artifact alone.  Set `BENCH_SMOKE=1` for a single-iteration CI
//! run.

use moe::harness::workload::{
    fairness_solo_traffic, fairness_tenants, fairness_traffic,
    tenant_fairness_run, TenantHarness,
};
use moe::serve::DrainPolicy;
use moe::util::bench::{black_box, BenchReport, Bencher};

const SEED: u64 = 17;
const N_VICTIM: usize = 16;

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("tenants");
    println!("== tenant fairness sweep: heavy hitter vs SLO victim ==");

    // the structured outcome (warm replays) supplies every ledger
    // number; the timing loop below re-replays the same traces
    let out = tenant_fairness_run(SEED, 1, N_VICTIM)?;
    println!("{}", out.isolation_line());

    let h = TenantHarness::new(SEED, 1);
    let hh = fairness_traffic(&h, out.capacity_tok_per_sec, N_VICTIM);
    let solo = fairness_solo_traffic(&hh);
    let runs = [
        ("tenants solo", DrainPolicy::WeightedFair, &solo, &out.solo),
        ("tenants wfq", DrainPolicy::WeightedFair, &hh, &out.wfq),
        ("tenants fifo", DrainPolicy::GlobalFifo, &hh, &out.fifo),
    ];
    for (label, drain, traffic, rep) in runs {
        let lp = h.single_loop(
            fairness_tenants(out.victim_deadline_ns),
            h.config(drain),
        )?;
        let trace = h.trace(traffic);
        lp.run_trace(&trace)?; // warm
        let r = bench.run(label, || {
            black_box(lp.run_trace(&trace).unwrap());
        });
        r.report_throughput("req", trace.len() as f64);
        for line in rep.summary_lines() {
            println!("  {line}");
        }
        let mut extras: Vec<(String, f64)> = vec![
            ("capacity_tok_per_sec".into(), out.capacity_tok_per_sec),
            ("victim_deadline_ns".into(), out.victim_deadline_ns as f64),
        ];
        for row in out.rows().into_iter().filter(|row| {
            label.ends_with(row.run)
        }) {
            let t = &row.tenant;
            extras.push((format!("{t}_offered"), row.offered as f64));
            extras.push((format!("{t}_completed"), row.completed as f64));
            extras.push((format!("{t}_shed"), row.shed as f64));
            extras.push((format!("{t}_failed"), row.failed as f64));
            extras.push((
                format!("{t}_completed_fraction"),
                row.completed_fraction,
            ));
            extras.push((format!("{t}_shed_fraction"), row.shed_fraction));
            extras.push((format!("{t}_p99_ns"), row.p99_total_ns as f64));
            extras.push((
                format!("{t}_conserved"),
                if row.conserved { 1.0 } else { 0.0 },
            ));
        }
        let borrowed: Vec<(&str, f64)> =
            extras.iter().map(|(k, v)| (k.as_str(), *v)).collect();
        report.push(&r, Some(("req", trace.len() as f64)), &borrowed);
    }
    report.write("BENCH_tenants.json")?;
    println!("wrote BENCH_tenants.json");
    Ok(())
}
