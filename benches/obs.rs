//! Bench: observability overhead and export well-formedness.
//!
//! The tracing contract is "zero-cost when disabled, cheap and
//! bit-neutral when enabled".  This bench measures both sides on the
//! streamed step executor with a *paired, interleaved* design — each
//! repeat times one untraced and one traced step back to back, and the
//! overhead fraction is computed from the medians — so slow drift on
//! the CI host cancels instead of biasing the comparison.  It also
//! replays a traced serve burst and validates the exports the way CI
//! gates them: the Chrome trace parses as JSON, the registry snapshot
//! parses and round-trips, and the serve ledger conserves
//! (`offered == completed + shed + failed`).  Emits `BENCH_obs.json`
//! with `trace_overhead_frac` budgeted at < 5% by the CI validator.

use moe::harness::workload::{poisson_trace, trace_requests, SyntheticMoe, TraceSpec};
use moe::obs::{chrome_trace_json, ObsConfig, Registry};
use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::serve::{ServeConfig, ServeLoop};
use moe::util::bench::{black_box, BenchReport, Bencher};
use moe::util::json;

const DEVICES: usize = 4;
const N_EXPERTS: usize = 16;

fn sched(obs: ObsConfig) -> Scheduler {
    Scheduler::new(
        ShardLayout::new(DEVICES, N_EXPERTS),
        ExpertBackend::Native,
    )
    .with_obs(obs)
}

fn median(mut v: Vec<u64>) -> u64 {
    v.sort_unstable();
    v[v.len() / 2]
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::var_os("BENCH_SMOKE").is_some();
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("obs");

    // a step big enough that per-span clock reads are measurable noise,
    // not the workload: 512 tokens routed k=2 over 16 experts
    let work = SyntheticMoe::build(77, 64, 128, N_EXPERTS, 2, DEVICES, 128)?;
    let plain = sched(ObsConfig::default());
    let traced = sched(ObsConfig::enabled());
    work.run_streamed(&plain, None)?; // warm engines + arenas
    work.run_streamed(&traced, None)?;
    traced.take_spans();

    println!(
        "== obs: tracing overhead on the streamed step ({} tokens, {} \
         experts, {} shards) ==",
        work.tokens(),
        N_EXPERTS,
        DEVICES
    );

    // paired interleaved measurement: medians over `repeats` A/B pairs
    let repeats = if smoke { 24 } else { 50 };
    let mut off_ns = Vec::with_capacity(repeats);
    let mut on_ns = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t0 = std::time::Instant::now();
        black_box(work.run_streamed(&plain, None)?);
        off_ns.push(t0.elapsed().as_nanos() as u64);
        let t1 = std::time::Instant::now();
        black_box(work.run_streamed(&traced, None)?);
        on_ns.push(t1.elapsed().as_nanos() as u64);
    }
    let spans = traced.take_spans();
    let (med_off, med_on) = (median(off_ns), median(on_ns));
    let overhead = med_on as f64 / med_off.max(1) as f64 - 1.0;
    let spans_per_step = spans.len() as f64 / repeats as f64;
    println!(
        "  step median: untraced {:.3}ms, traced {:.3}ms -> overhead \
         {:+.2}%  ({spans_per_step:.0} spans/step, {} dropped)",
        med_off as f64 / 1e6,
        med_on as f64 / 1e6,
        overhead * 100.0,
        traced.trace_dropped(),
    );
    anyhow::ensure!(
        traced.trace_dropped() == 0,
        "default ring capacity dropped spans on a bench-sized step"
    );
    // the Chrome export parses and carries every span
    let doc = chrome_trace_json(&spans, DEVICES);
    let parsed = json::parse(&doc)
        .map_err(|e| anyhow::anyhow!("chrome trace unparseable: {e:?}"))?;
    let n_events = parsed
        .field("traceEvents")?
        .as_arr()
        .map_or(0, |a| a.len());

    // named timing rows for the PR-over-PR trajectory
    let r_off = bench.run("streamed step, tracing off", || {
        black_box(work.run_streamed(&plain, None).unwrap());
    });
    r_off.report_throughput("tok", work.tokens() as f64);
    report.push(&r_off, Some(("tok", work.tokens() as f64)), &[]);
    let r_on = bench.run("streamed step, tracing on", || {
        black_box(work.run_streamed(&traced, None).unwrap());
    });
    r_on.report_throughput("tok", work.tokens() as f64);
    report.push(
        &r_on,
        Some(("tok", work.tokens() as f64)),
        &[
            ("trace_overhead_frac", overhead),
            ("paired_repeats", repeats as f64),
            ("median_off_ns", med_off as f64),
            ("median_on_ns", med_on as f64),
            ("spans_per_step", spans_per_step),
            ("trace_events", n_events as f64),
            ("ring_dropped", traced.trace_dropped() as f64),
        ],
    );
    traced.take_spans();

    // a traced serve burst: ledger conservation + snapshot parseability
    let serve_work = SyntheticMoe::build(31, 32, 64, N_EXPERTS, 2, 1, 8)?;
    let serve = ServeLoop::new(
        sched(ObsConfig::enabled()),
        serve_work.router,
        serve_work.weights,
        ServeConfig {
            queue_depth: 32,
            max_batch_tokens: 64,
            latency_budget_ns: 200_000,
            ..Default::default()
        },
    )?;
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 19,
            rate_per_sec: 30_000.0,
            n_requests: if smoke { 32 } else { 128 },
            min_rows: 1,
            max_rows: 8,
            bursty: false,
        }),
        32,
        21,
    );
    let r_serve = bench.run("traced serve replay", || {
        black_box(serve.run_trace(&trace).unwrap());
    });
    let stats = serve.run_trace(&trace)?.stats;
    let serve_spans = serve.take_spans();
    r_serve.report_throughput("req", trace.len() as f64);
    println!("  {}", stats.summary_line());
    anyhow::ensure!(
        stats.offered == stats.completed + stats.shed + stats.failed,
        "serve ledger broke: {} != {} + {} + {}",
        stats.offered,
        stats.completed,
        stats.shed,
        stats.failed
    );
    anyhow::ensure!(!serve_spans.is_empty(), "traced serve had no spans");
    let mut reg = Registry::new();
    stats.publish(&mut reg);
    let snap = reg.snapshot();
    json::parse(&snap.to_json())
        .map_err(|e| anyhow::anyhow!("snapshot JSON unparseable: {e:?}"))?;
    anyhow::ensure!(
        snap.to_prometheus().contains("# TYPE"),
        "prometheus export missing TYPE lines"
    );
    report.push(
        &r_serve,
        Some(("req", trace.len() as f64)),
        &[
            ("offered", stats.offered as f64),
            ("completed", stats.completed as f64),
            ("shed", stats.shed as f64),
            ("failed", stats.failed as f64),
            ("slo_violations", stats.slo_violations as f64),
            ("ledger_conserved", 1.0),
            ("snapshot_parses", 1.0),
            ("serve_spans", serve_spans.len() as f64),
        ],
    );

    report.write("BENCH_obs.json")?;
    println!("wrote BENCH_obs.json");
    Ok(())
}
