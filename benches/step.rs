//! Bench: end-to-end MoE step latency.
//!
//! Section 1 (always runs): the Native-backend step on the persistent
//! [`ExecutionEngine`] vs the retained serial reference, with the
//! per-phase gather/compute/combine breakdown from `StepStats` — the
//! §3.1 shrinking-batch economics measured, not modelled.
//!
//! Section 2 (needs `make artifacts`): the full rust->PJRT->rust round
//! trip of the AOT'd train step (the Table 1/7 "Training Time" axis).

use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::harness::workload::{phase_line, SyntheticMoe};
use moe::runtime::{Engine, Manifest};
use moe::train::Trainer;
use moe::util::bench::{black_box, Bencher};

fn native_engine_section(bench: &Bencher) {
    let (d, h, n, k, tokens) = (64, 256, 64, 4, 4096);
    let work = SyntheticMoe::build(7, d, h, n, k, 1, tokens).unwrap();
    let refs = work.refs();

    println!(
        "== native MoE step, persistent engine vs serial reference \
         (n={n}, k={k}, d={d}, {tokens} tokens) =="
    );
    for devices in [1, 2, 4, 8] {
        let sched =
            Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native);
        sched.execute(&work.plan, &refs, &work.weights).unwrap(); // warm up
        let r = bench.run(&format!("engine step, {devices} device(s)"), || {
            black_box(sched.execute(&work.plan, &refs, &work.weights).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        let r = bench.run(&format!("serial step, {devices} device(s)"), || {
            black_box(
                sched.execute_serial(&work.plan, &refs, &work.weights).unwrap(),
            );
        });
        r.report_throughput("tok", tokens as f64);
        let (_, stats) = sched.execute(&work.plan, &refs, &work.weights).unwrap();
        println!("  phases: {}", phase_line(&stats));
    }
}

fn artifact_section(bench: &Bencher) -> anyhow::Result<()> {
    let engine = match Engine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping artifact section: {e}");
            return Ok(());
        }
    };
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping artifact section: {e}");
            return Ok(());
        }
    };
    println!("== train-step latency (AOT artifact, CPU PJRT) ==");
    for cfg in ["moe-4", "moe-32", "moe-256", "moe-256-h", "lstm-4x",
                "moe-1-wide"] {
        if manifest.config(cfg).is_err() {
            eprintln!("skipping {cfg}: not in manifest");
            continue;
        }
        let trainer = Trainer::new(&engine, &manifest, cfg)?;
        let c = trainer.entry.config.clone();
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: c.vocab,
            ..Default::default()
        });
        let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
        let mut state = trainer.init(0)?;
        let tokens = batcher.next_batch();
        let tokens_per_step = (c.batch * c.seq_len) as f64;
        let r = bench.run(&format!("step {cfg}"), || {
            trainer.step(&mut state, &tokens).unwrap();
        });
        r.report_throughput("tok", tokens_per_step);
        let m = trainer.step(&mut state, &tokens)?;
        println!(
            "  phases: stage-in {:.3}ms  execute {:.3}ms  stage-out {:.3}ms",
            m.phases.h2d_ns as f64 / 1e6,
            m.phases.exec_ns as f64 / 1e6,
            m.phases.d2h_ns as f64 / 1e6,
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::quick();
    native_engine_section(&bench);
    artifact_section(&bench)
}
