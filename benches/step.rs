//! Bench: end-to-end training-step latency per config (the Table 1/7
//! "Training Time" axis).  Measures the full rust->PJRT->rust round trip
//! of the AOT'd train step, which is what a paper-scale deployment pays
//! per step on this substrate.

use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::runtime::{Engine, Manifest};
use moe::train::Trainer;
use moe::util::bench::Bencher;

fn main() -> anyhow::Result<()> {
    let engine = Engine::new()?;
    let manifest = Manifest::load("artifacts")?;
    let bench = Bencher::quick();
    println!("== train-step latency (AOT artifact, CPU PJRT) ==");
    for cfg in ["moe-4", "moe-32", "moe-256", "moe-256-h", "lstm-4x",
                "moe-1-wide"] {
        if manifest.config(cfg).is_err() {
            eprintln!("skipping {cfg}: not in manifest");
            continue;
        }
        let trainer = Trainer::new(&engine, &manifest, cfg)?;
        let c = trainer.entry.config.clone();
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: c.vocab,
            ..Default::default()
        });
        let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
        let mut state = trainer.init(0)?;
        let tokens = batcher.next_batch();
        let tokens_per_step = (c.batch * c.seq_len) as f64;
        let r = bench.run(&format!("step {cfg}"), || {
            trainer.step(&mut state, &tokens).unwrap();
        });
        r.report_throughput("tok", tokens_per_step);
    }
    Ok(())
}
