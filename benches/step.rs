//! Bench: end-to-end MoE step latency.
//!
//! Section 1 (always runs): the *full* Native-backend step — routing,
//! dispatch and expert execution — three ways at n=64, k=4:
//!
//! - **streamed**: the routing→dispatch pipeline on the persistent
//!   [`ExecutionEngine`] with adaptive wave capacity (row-blocked
//!   parallel gating, incremental plan, waves dispatched as routes
//!   land);
//! - **engine + serial route**: the PR-1 shape — route and plan built
//!   serially on the coordinator, then the engine executes;
//! - **serial reference**: the retained single-threaded oracle.
//!
//! Results (ns/op, tok/s, per-phase breakdown including `overlap_ns`
//! and `combine_overlap_ratio` — the combine work the dependency-driven
//! executor hid under expert compute) are also written to
//! `BENCH_step.json` so the perf trajectory is tracked across PRs.
//! Set `BENCH_SMOKE=1` for a single-iteration CI smoke run.
//!
//! Section 2 (needs `make artifacts`): the full rust->PJRT->rust round
//! trip of the AOT'd train step (the Table 1/7 "Training Time" axis).

use moe::coordinator::scheduler::{
    AdaptiveWave, ExpertBackend, Scheduler, ShardLayout, StepStats,
    WavePolicy,
};
use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::harness::workload::{phase_line, SyntheticMoe};
use moe::runtime::{Engine, Manifest};
use moe::train::Trainer;
use moe::util::bench::{black_box, BenchReport, Bencher};

fn phase_extras(stats: &StepStats) -> Vec<(&'static str, f64)> {
    vec![
        ("route_ns", stats.phases.route as f64),
        ("gather_ns", stats.phases.gather as f64),
        ("compute_ns", stats.phases.compute as f64),
        ("combine_ns", stats.phases.combine as f64),
        ("overlap_ns", stats.phases.overlap_ns as f64),
        ("combine_overlap_ratio", stats.combine_overlap_ratio()),
        ("waves", stats.waves as f64),
        (
            "max_shard_idle_ns",
            stats.shard_idle_ns.iter().copied().max().unwrap_or(0) as f64,
        ),
    ]
}

fn native_engine_section(bench: &Bencher, report: &mut BenchReport) {
    let (d, h, n, k, tokens) = (64, 256, 64, 4, 4096);
    let work = SyntheticMoe::build(7, d, h, n, k, 1, tokens).unwrap();
    let tput = Some(("tok", tokens as f64));

    println!(
        "== native MoE full step: streamed pipeline vs engine + serial \
         route vs serial reference (n={n}, k={k}, d={d}, {tokens} tokens) =="
    );
    for devices in [1, 2, 4, 8] {
        // streamed pipeline with adaptive wave capacity
        let streamed = Scheduler::with_policy(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
            WavePolicy::Adaptive(AdaptiveWave::new()),
        );
        // the PR-1 shape: unchunked engine, route serial on coordinator
        let unpipelined = Scheduler::new(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
        );
        work.run_streamed(&streamed, None).unwrap(); // warm + adapt
        work.run_unpipelined(&unpipelined, None).unwrap(); // warm

        let r = bench.run(&format!("streamed step, {devices} device(s)"), || {
            black_box(work.run_streamed(&streamed, None).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        let s = work.run_streamed(&streamed, None).unwrap();
        report.push(&r, tput, &phase_extras(&s.stats));

        let r = bench.run(
            &format!("engine step + serial route, {devices} device(s)"),
            || {
                black_box(work.run_unpipelined(&unpipelined, None).unwrap());
            },
        );
        r.report_throughput("tok", tokens as f64);
        let (_, u_stats) = work.run_unpipelined(&unpipelined, None).unwrap();
        report.push(&r, tput, &phase_extras(&u_stats));

        // full step too (route + plan + execute_serial), so all three
        // rows measure the same work
        let r = bench.run(&format!("serial step, {devices} device(s)"), || {
            black_box(work.run_serial_reference(&unpipelined, None).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        let (_, s_stats) = work.run_serial_reference(&unpipelined, None).unwrap();
        report.push(&r, tput, &phase_extras(&s_stats));

        println!("  streamed phases:    {}", phase_line(&s.stats));
        println!("  unpipelined phases: {}", phase_line(&u_stats));
    }
}

fn artifact_section(bench: &Bencher) -> anyhow::Result<()> {
    let engine = match Engine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping artifact section: {e}");
            return Ok(());
        }
    };
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping artifact section: {e}");
            return Ok(());
        }
    };
    println!("== train-step latency (AOT artifact, CPU PJRT) ==");
    for cfg in ["moe-4", "moe-32", "moe-256", "moe-256-h", "lstm-4x",
                "moe-1-wide"] {
        if manifest.config(cfg).is_err() {
            eprintln!("skipping {cfg}: not in manifest");
            continue;
        }
        let trainer = Trainer::new(&engine, &manifest, cfg)?;
        let c = trainer.entry.config.clone();
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: c.vocab,
            ..Default::default()
        });
        let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
        let mut state = trainer.init(0)?;
        let tokens = batcher.next_batch();
        let tokens_per_step = (c.batch * c.seq_len) as f64;
        let r = bench.run(&format!("step {cfg}"), || {
            trainer.step(&mut state, &tokens).unwrap();
        });
        r.report_throughput("tok", tokens_per_step);
        let m = trainer.step(&mut state, &tokens)?;
        println!(
            "  phases: stage-in {:.3}ms  execute {:.3}ms  stage-out {:.3}ms",
            m.phases.h2d_ns as f64 / 1e6,
            m.phases.exec_ns as f64 / 1e6,
            m.phases.d2h_ns as f64 / 1e6,
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("step");
    native_engine_section(&bench, &mut report);
    report.write("BENCH_step.json")?;
    println!("wrote BENCH_step.json");
    artifact_section(&bench)
}
