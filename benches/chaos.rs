//! Bench/report: the chaos sweep — deterministic fault injection on
//! the real engine + serve loop, writing `BENCH_chaos.json`.
//!
//! Each row is one (fault_rate, recovery_policy) point (plus two
//! shard-death schedules at the max rate, including all-shards-dead):
//! the timed quantity is the streamed step under injected faults, and
//! the extras carry the recovery counters and the serving-boundary
//! conservation buckets so CI can re-assert liveness and
//! `offered == completed + shed + failed` from the artifact alone.
//! Set `BENCH_SMOKE=1` for a single-iteration CI run.

use moe::coordinator::{FaultPlan, RecoveryPolicy};
use moe::harness::chaos::{point_line, run_point, ChaosSim};
use moe::util::bench::{black_box, BenchReport, Bencher};

fn policy_code(p: RecoveryPolicy) -> f64 {
    match p {
        RecoveryPolicy::Redispatch => 1.0,
        RecoveryPolicy::DegradeOnly => 0.0,
    }
}

fn bench_point(
    bench: &Bencher,
    report: &mut BenchReport,
    label: &str,
    plan: FaultPlan,
) -> anyhow::Result<()> {
    let (devices, n_experts, rows) = (4usize, 8usize, 8usize);
    let sim = ChaosSim::build(devices, n_experts, rows, plan, 7)?;
    let tokens = devices * rows;
    // warm the persistent engine, then time the streamed step; fault
    // draws follow the engine's step counter, so every iteration sees
    // the schedule of a fresh step
    black_box(sim.step(0)?);
    let mut fold = 0u64;
    let r = bench.run(label, || {
        fold += 1;
        black_box(sim.step(fold).unwrap());
    });
    r.report_throughput("tok", tokens as f64);
    let p = run_point(&sim, 2, 24)?;
    println!("  {}", point_line(&p));
    report.push(
        &r,
        Some(("tok", tokens as f64)),
        &[
            ("fault_rate", p.fault_rate),
            ("policy", policy_code(p.policy)),
            ("shard_deaths", p.shard_deaths as f64),
            ("live_fraction", p.live_fraction),
            ("failed_chunks", p.failed_chunks as f64),
            ("redispatched_routes", p.redispatched_routes as f64),
            ("degraded_tokens", p.degraded_tokens as f64),
            ("renorm_mass_lost", p.renorm_mass_lost),
            ("max_step_ns", p.max_step_ns as f64),
            ("all_finite", if p.all_finite { 1.0 } else { 0.0 }),
            ("offered", p.offered as f64),
            ("completed", p.completed as f64),
            ("shed", p.shed as f64),
            ("failed", p.failed as f64),
            ("retried", p.retried as f64),
            ("conserved", if p.conserved() { 1.0 } else { 0.0 }),
        ],
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("chaos");
    println!("== chaos sweep: seeded fault injection on the real engine ==");
    for rate in [0.0f64, 0.05, 0.2, 0.5] {
        for policy in [RecoveryPolicy::Redispatch, RecoveryPolicy::DegradeOnly]
        {
            let plan = FaultPlan {
                seed: 0xc4a0_5000,
                chunk_fail_rate: rate,
                straggler_rate: rate * 0.5,
                straggler_delay_ns: 30_000,
                deadline_ns: 60_000,
                combine_drop_rate: rate * 0.25,
                shard_deaths: Vec::new(),
                policy,
            };
            let label = format!(
                "chaos step rate={rate:.2} {}",
                match policy {
                    RecoveryPolicy::Redispatch => "redispatch",
                    RecoveryPolicy::DegradeOnly => "degrade",
                }
            );
            bench_point(&bench, &mut report, &label, plan)?;
        }
    }
    // shard deaths at the max swept rate: one mid-run death, then the
    // all-dead extreme — liveness means both rows exist at all
    for (name, deaths) in [
        ("one-death", vec![(1u64, 1usize)]),
        ("all-dead", (0..4).map(|sh| (0u64, sh)).collect::<Vec<_>>()),
    ] {
        let plan = FaultPlan {
            seed: 0xdead,
            chunk_fail_rate: 0.5,
            straggler_rate: 0.0,
            straggler_delay_ns: 0,
            deadline_ns: u64::MAX,
            combine_drop_rate: 0.125,
            shard_deaths: deaths,
            policy: RecoveryPolicy::Redispatch,
        };
        let label = format!("chaos step rate=0.50 {name}");
        bench_point(&bench, &mut report, &label, plan)?;
    }
    report.write("BENCH_chaos.json")?;
    println!("wrote BENCH_chaos.json");
    Ok(())
}
