//! Bench: router + dispatcher + combine throughput (the L3 hot path).
//! Backs the §3.1 shrinking-batch analysis and the Table 7/8 efficiency
//! columns: reports tokens/s through the all-to-all at several expert
//! counts and device counts.

use moe::coordinator::router::Router;
use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::coordinator::Dispatcher;
use moe::harness::workload::{phase_line, SyntheticMoe};
use moe::runtime::TensorF;
use moe::util::bench::{black_box, Bencher};
use moe::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let d = 64;
    let tokens = 4096;
    println!("== dispatch/combine throughput (d_model={d}, {tokens} tokens) ==");
    for n in [8, 64, 512] {
        let k = 4.min(n);
        let mut rng = Rng::new(1);
        let router = Router::flat_native(
            d, n, k,
            (0..d * n).map(|_| rng.normal_f32() * 0.4).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.4).collect()),
        );
        let x = TensorF::new(
            vec![tokens, d],
            (0..tokens * d).map(|_| rng.normal_f32()).collect(),
        );
        let mut nrng = rng.fold_in(7);
        let dec = router.route(&x, Some(&mut nrng)).unwrap();

        let r = b.run(&format!("route n={n} k={k}"), || {
            let mut nrng = Rng::new(2);
            black_box(router.route(&x, Some(&mut nrng)).unwrap());
        });
        r.report_throughput("tok", tokens as f64);

        let decisions = vec![dec];
        let r = b.run(&format!("plan n={n}"), || {
            black_box(Dispatcher::plan(&decisions, n));
        });
        r.report_throughput("tok", tokens as f64);

        let plan = Dispatcher::plan(&decisions, n);
        let r = b.run(&format!("gather+combine n={n}"), || {
            let outs: Vec<TensorF> = (0..n)
                .map(|e| Dispatcher::gather(&plan, e, &[&x]))
                .collect();
            black_box(Dispatcher::combine(&plan, &outs, d));
        });
        r.report_throughput("tok", tokens as f64);
    }

    println!("\n== full native MoE step vs devices (n=64, k=4) ==");
    let n = 64;
    let work = SyntheticMoe::build(3, d, 4 * d, n, 4, 1, tokens).unwrap();
    let refs = work.refs();
    for devices in [1, 2, 4, 8] {
        let sched =
            Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native);
        sched.execute(&work.plan, &refs, &work.weights).unwrap(); // warm up
        let r = b.run(&format!("moe step (engine), {devices} device(s)"), || {
            black_box(sched.execute(&work.plan, &refs, &work.weights).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        let r = b.run(&format!("moe step (serial), {devices} device(s)"), || {
            black_box(
                sched.execute_serial(&work.plan, &refs, &work.weights).unwrap(),
            );
        });
        r.report_throughput("tok", tokens as f64);
        let (_, stats) = sched.execute(&work.plan, &refs, &work.weights).unwrap();
        println!("  phases: {}", phase_line(&stats));
    }
}
