//! Bench: router + dispatcher + combine throughput (the L3 hot path).
//! Backs the §3.1 shrinking-batch analysis and the Table 7/8 efficiency
//! columns: reports tokens/s through the all-to-all at several expert
//! counts and device counts, for both the serially-composed step and
//! the streamed routing→dispatch pipeline.
//!
//! Results are also written to `BENCH_dispatch.json` (ns/op, tok/s) so
//! the perf trajectory is tracked across PRs.  Set `BENCH_SMOKE=1` for
//! a single-iteration CI smoke run.

use moe::coordinator::router::Router;
use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::coordinator::Dispatcher;
use moe::harness::workload::{phase_line, SyntheticMoe};
use moe::runtime::TensorF;
use moe::util::bench::{black_box, BenchReport, Bencher};
use moe::util::rng::Rng;

fn main() {
    let b = Bencher::from_env();
    let mut report = BenchReport::new("dispatch");
    let d = 64;
    let tokens = 4096;
    let tput = Some(("tok", tokens as f64));
    println!("== dispatch/combine throughput (d_model={d}, {tokens} tokens) ==");
    for n in [8, 64, 512] {
        let k = 4.min(n);
        let mut rng = Rng::new(1);
        let router = Router::flat_native(
            d, n, k,
            (0..d * n).map(|_| rng.normal_f32() * 0.4).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.4).collect()),
        );
        let x = TensorF::new(
            vec![tokens, d],
            (0..tokens * d).map(|_| rng.normal_f32()).collect(),
        );
        let mut nrng = rng.fold_in(7);
        let dec = router.route(&x, Some(&mut nrng)).unwrap();

        let r = b.run(&format!("route n={n} k={k}"), || {
            let mut nrng = Rng::new(2);
            black_box(router.route(&x, Some(&mut nrng)).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);

        let decisions = vec![dec];
        let r = b.run(&format!("plan n={n}"), || {
            black_box(Dispatcher::plan(&decisions, n));
        });
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);

        let plan = Dispatcher::plan(&decisions, n);
        let r = b.run(&format!("gather+combine n={n}"), || {
            let outs: Vec<TensorF> = (0..n)
                .map(|e| Dispatcher::gather(&plan, e, &[&x]))
                .collect();
            black_box(Dispatcher::combine(&plan, &outs, d));
        });
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);
    }

    println!("\n== full native MoE step vs devices (n=64, k=4) ==");
    let n = 64;
    let work = SyntheticMoe::build(3, d, 4 * d, n, 4, 1, tokens).unwrap();
    for devices in [1, 2, 4, 8] {
        let sched =
            Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native);
        work.run_streamed(&sched, None).unwrap(); // warm up
        let r = b.run(
            &format!("moe step (streamed), {devices} device(s)"),
            || {
                black_box(work.run_streamed(&sched, None).unwrap());
            },
        );
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);
        let r = b.run(
            &format!("moe step (engine, serial route), {devices} device(s)"),
            || {
                black_box(work.run_unpipelined(&sched, None).unwrap());
            },
        );
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);
        // full step too (route + plan + execute_serial), comparable with
        // the two rows above
        let r = b.run(&format!("moe step (serial), {devices} device(s)"), || {
            black_box(work.run_serial_reference(&sched, None).unwrap());
        });
        r.report_throughput("tok", tokens as f64);
        report.push(&r, tput, &[]);
        let s = work.run_streamed(&sched, None).unwrap();
        println!("  streamed phases: {}", phase_line(&s.stats));
    }
    report.write("BENCH_dispatch.json").unwrap();
    println!("wrote BENCH_dispatch.json");
}
