//! Bench: the kernel layer itself — every kernel the host can run,
//! swept over the hot-path GEMM shapes, f32 and int8.
//!
//! Rows are named `"<op> <m>x<k>x<n> [<kernel>]"`; the scalar oracle is
//! always measured first so every row carries `speedup_vs_scalar`
//! (scalar rows themselves report 1.0 by construction).  Int8 rows also
//! carry `int8_max_rel_err` — the measured normwise error of the
//! quantized fused FFN against the same kernel's f32 fused FFN on the
//! same inputs — asserted under the serve budget both here and by the
//! CI validator.
//!
//! Results go to `BENCH_kernels.json`.  Set `BENCH_SMOKE=1` for a
//! single-iteration CI smoke run.

use moe::coordinator::scheduler::ExpertWeights;
use moe::kernels::quant::{QuantizedExpertWeights, SERVE_REL_ERR_BUDGET};
use moe::kernels::{ffn_forward, Kernel, MatmulKernel};
use moe::util::bench::{black_box, BenchReport, Bencher};
use moe::util::prop;
use moe::util::rng::Rng;

/// Quantized fused FFN on an explicit kernel (the serve path routes
/// through the selected kernel; the sweep needs to pin each one).
fn ffn_q8(
    kern: &dyn MatmulKernel,
    q: &QuantizedExpertWeights,
    x: &[f32],
    rows: usize,
    hid: &mut [f32],
    out: &mut [f32],
) {
    let (d, h) = (q.d_model, q.hidden);
    kern.matmul_q8(x, &q.q_in, &q.s_in, hid, rows, d, h);
    for v in hid.iter_mut() {
        *v = v.max(0.0);
    }
    kern.matmul_q8(hid, &q.q_out, &q.s_out, out, rows, h, d);
}

fn gemm_section(bench: &Bencher, report: &mut BenchReport) {
    // (op, m, k, n): gating logits (tokens × d_model → n_experts), the
    // expert in/out projections, and the two backward transposes
    let cases: &[(&str, usize, usize, usize)] = &[
        ("matmul", 512, 64, 64),
        ("matmul", 128, 64, 256),
        ("matmul", 128, 256, 64),
        ("matmul_tn", 128, 64, 256),
        ("matmul_nt", 128, 64, 256),
    ];
    let mut rng = Rng::new(7);
    println!("== kernel GEMM sweep (f32) ==");
    for &(op, m, k, n) in cases {
        // matmul_nt reads (m,n,k): a (m,k)·bᵀ with b (n,k); flops match
        let (alen, blen, olen) = match op {
            "matmul" => (m * k, k * n, m * n),
            "matmul_tn" => (m * k, m * n, k * n),
            _ => (m * k, n * k, m * n),
        };
        let a = prop::vec_f32(&mut rng, alen, 1.0);
        let b = prop::vec_f32(&mut rng, blen, 1.0);
        let flops = 2.0 * (m * k * n) as f64;
        let mut scalar_mean = 0.0f64;
        for kern in Kernel::available() {
            let mut out = vec![0f32; olen];
            let name = format!("{op} {m}x{k}x{n} [{}]", kern.name());
            let r = bench.run(&name, || match op {
                "matmul" => kern.matmul(&a, &b, &mut out, m, k, n),
                "matmul_tn" => {
                    // += contract: reset so every iteration is the same work
                    out.fill(0.0);
                    kern.matmul_tn(&a, &b, &mut out, m, k, n);
                }
                _ => kern.matmul_nt(&a, &b, &mut out, m, n, k),
            });
            black_box(&out);
            r.report_throughput("flop", flops);
            if kern.name() == "scalar" {
                scalar_mean = r.mean_secs();
            }
            let speedup = scalar_mean / r.mean_secs();
            report.push(
                &r,
                None,
                &[
                    ("gflops", flops / r.mean_secs() / 1e9),
                    ("speedup_vs_scalar", speedup),
                ],
            );
        }
    }
}

fn ffn_section(bench: &Bencher, report: &mut BenchReport) {
    let (rows, d, h) = (256, 64, 256);
    let mut rng = Rng::new(11);
    let w = ExpertWeights {
        w_in: prop::vec_f32(&mut rng, d * h, 0.3),
        w_out: prop::vec_f32(&mut rng, h * d, 0.3),
        d_model: d,
        hidden: h,
    };
    let q = QuantizedExpertWeights::from_f32(&w);
    let x = prop::vec_f32(&mut rng, rows * d, 1.0);
    let flops = 2.0 * (rows * d * h) as f64 * 2.0;
    println!("== fused expert FFN: f32 vs int8, per kernel ==");
    for kern in Kernel::available() {
        let mut scratch = Vec::new();
        let mut out = vec![0f32; rows * d];
        let f32_name = format!("ffn_f32 {rows}x{d}x{h} [{}]", kern.name());
        let rf = bench.run(&f32_name, || {
            ffn_forward(kern, &x, rows, d, h, &w.w_in, &w.w_out, &mut scratch, &mut out);
        });
        black_box(&out);
        rf.report_throughput("flop", flops);
        let y32 = out.clone();
        report.push(&rf, Some(("row", rows as f64)), &[(
            "gflops",
            flops / rf.mean_secs() / 1e9,
        )]);

        let mut hid = vec![0f32; rows * h];
        let mut out8 = vec![0f32; rows * d];
        let q8_name = format!("ffn_int8 {rows}x{d}x{h} [{}]", kern.name());
        let r8 = bench.run(&q8_name, || {
            ffn_q8(kern, &q, &x, rows, &mut hid, &mut out8);
        });
        black_box(&out8);
        r8.report_throughput("flop", flops);
        // measured int8 error vs the same kernel's f32 output, normwise
        let norm: f64 =
            y32.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let err: f64 = y32
            .iter()
            .zip(out8.iter())
            .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        let rel = if norm > 0.0 { err / norm } else { 0.0 };
        assert!(
            rel <= SERVE_REL_ERR_BUDGET,
            "{q8_name}: int8 rel err {rel:.3e} over serve budget"
        );
        report.push(
            &r8,
            Some(("row", rows as f64)),
            &[
                ("gflops", flops / r8.mean_secs() / 1e9),
                ("speedup_vs_f32", rf.mean_secs() / r8.mean_secs()),
                ("int8_max_rel_err", rel),
            ],
        );
    }
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("kernels");
    println!(
        "selected kernel: {} (MOE_KERNEL overrides; sweep measures all \
         available)",
        Kernel::selected_name()
    );
    gemm_section(&bench, &mut report);
    ffn_section(&bench, &mut report);
    report.write("BENCH_kernels.json")?;
    println!("wrote BENCH_kernels.json");
    Ok(())
}
