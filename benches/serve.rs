//! Bench: serving SLOs under open-loop Poisson load.
//!
//! Replays seeded traces through the continuous micro-batching runtime
//! (`moe::serve::ServeLoop`) at three offered loads relative to a
//! burst-calibrated engine capacity (the shared
//! `harness::workload::ServeHarness`), and emits the SLO metrics —
//! total-latency p50/p95/p99, queue-wait p50, achieved tokens/sec,
//! batch occupancy, shed count — into `BENCH_serve.json` so the
//! serving trajectory is tracked across PRs alongside
//! `BENCH_step.json`.  Set `BENCH_SMOKE=1` for the one-iteration CI
//! smoke run, which gates on the report being well-formed (finite
//! p50 <= p99, tokens/sec > 0).

use moe::harness::workload::{serve_phase_line, ServeHarness};
use moe::serve::ServeStats;
use moe::util::bench::{black_box, BenchReport, Bencher};

fn serve_extras(stats: &ServeStats) -> Vec<(&'static str, f64)> {
    let total = stats.total.percentiles(&[0.50, 0.95, 0.99]);
    vec![
        ("serve_p50_ns", total[0] as f64),
        ("serve_p95_ns", total[1] as f64),
        ("serve_p99_ns", total[2] as f64),
        ("queue_p50_ns", stats.queue_wait.percentile(0.50) as f64),
        ("serve_tok_per_sec", stats.tokens_per_sec()),
        ("batch_occupancy", stats.batch_occupancy()),
        ("completed", stats.completed as f64),
        ("shed", stats.shed as f64),
        ("peak_queue_depth", stats.peak_queue_depth as f64),
    ]
}

fn main() -> anyhow::Result<()> {
    let bench = Bencher::from_env_quick();
    let mut report = BenchReport::new("serve");
    let n_requests = 192;

    let harness = ServeHarness::build(23, 4)?;
    let capacity = harness.calibrate(23)?;
    println!(
        "== serve: open-loop Poisson load on {} experts (k={}, d={}), \
         {} device(s), capacity ~{capacity:.0} tok/s ==",
        harness.n_experts, harness.k, harness.d_model, harness.devices,
    );
    for (label, mult, bursty) in [
        ("0.3x", 0.3, false),
        ("1.0x", 1.0, false),
        ("3.0x", 3.0, false),
        ("1.0x bursty", 1.0, true),
    ] {
        let rate = harness.rate_for(capacity, mult);
        let trace = harness.trace(
            0x5e12 ^ (mult * 1e3) as u64,
            rate,
            n_requests,
            bursty,
            2,
        );
        let r = bench.run(&format!("serve replay, offered {label}"), || {
            black_box(harness.serve.run_trace(&trace).unwrap());
        });
        let stats = harness.serve.run_trace(&trace)?.stats;
        r.report_throughput("req", n_requests as f64);
        println!("  {}", stats.summary_line());
        println!("  {}", serve_phase_line(&stats));
        report.push(
            &r,
            Some(("req", n_requests as f64)),
            &serve_extras(&stats),
        );
    }
    report.write("BENCH_serve.json")?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
