//! Observability correctness (tier-1): tracing is **bit-neutral** and
//! the span/metrics exports are well-formed.
//!
//! The load-bearing property is the differential one: recording spans
//! must not perturb the computation it observes.  Tracing only reads
//! clocks and writes per-worker rings — it draws no randomness,
//! reorders no accumulation and changes no scheduling decision — so a
//! traced run must produce outputs **bit-identical** to an untraced run
//! from the same seeds, on both the streamed engine step and the serve
//! loop.  On top of that: the drained span stream is non-empty and
//! schema-valid (known kinds, sane durations, correct lanes), the
//! Chrome trace export parses as JSON, and the registry snapshot
//! round-trips through `moe::util::json` with the console lines
//! rendering byte-identically from it.

use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
use moe::harness::workload::{
    phase_line, poisson_trace, render_phase_line, trace_requests,
    SyntheticMoe, TraceSpec,
};
use moe::obs::{
    chrome_trace_json, ObsConfig, Registry, Span, SpanKind, NO_ID,
};
use moe::serve::{ServeConfig, ServeLoop, ServeStats};
use moe::util::json;
use moe::util::rng::Rng;

const DEVICES: usize = 2;
const N_EXPERTS: usize = 8;

fn sched(obs: ObsConfig) -> Scheduler {
    Scheduler::new(
        ShardLayout::new(DEVICES, N_EXPERTS),
        ExpertBackend::Native,
    )
    .with_obs(obs)
}

/// Every span the engine may emit, checked against the schema: a known
/// kind, a 1-based step id, the coordinator lane exactly for
/// coordinator-side kinds, and durations far below the step wall.
fn assert_schema(spans: &[Span], max_step: u64) {
    assert!(!spans.is_empty(), "traced run recorded no spans");
    for s in spans {
        assert!(
            s.step >= 1 && s.step <= max_step,
            "span step {} outside 1..={max_step}",
            s.step
        );
        assert!(!s.kind.name().is_empty());
        assert!(
            s.dur_ns < 60_000_000_000,
            "{} span claims {}ns — clock bug",
            s.kind.name(),
            s.dur_ns
        );
        match s.kind {
            SpanKind::Step | SpanKind::Dispatch | SpanKind::Retry
                if s.shard == NO_ID => {}
            SpanKind::Retry | SpanKind::Compute => {
                // worker-side compute/retry lands on a real shard lane
                assert!(
                    (s.shard as usize) < DEVICES || s.shard == NO_ID,
                    "shard {} out of range",
                    s.shard
                );
                assert!(s.rows >= 1, "{} span with 0 rows", s.kind.name());
            }
            SpanKind::Route | SpanKind::Gather | SpanKind::Combine => {
                assert!(
                    (s.shard as usize) < DEVICES,
                    "{} span off-lane: shard {}",
                    s.kind.name(),
                    s.shard
                );
            }
            _ => {}
        }
    }
    let step_count =
        spans.iter().filter(|s| s.kind == SpanKind::Step).count() as u64;
    assert_eq!(
        step_count, max_step,
        "exactly one Step span per traced step"
    );
}

#[test]
fn traced_streamed_step_is_bit_identical_to_untraced() {
    let work = SyntheticMoe::build(91, 8, 16, N_EXPERTS, 2, DEVICES, 24)
        .unwrap();
    let plain = sched(ObsConfig::default());
    let traced = sched(ObsConfig::enabled());
    assert!(!plain.tracing_enabled());
    assert!(traced.tracing_enabled());

    let steps = 3u64;
    for step in 0..steps {
        let mut r1 = Rng::new(400 + step);
        let mut r2 = Rng::new(400 + step);
        let a = work.run_streamed(&plain, Some(&mut r1)).unwrap();
        let b = work.run_streamed(&traced, Some(&mut r2)).unwrap();
        for (x, y) in a.outs.iter().zip(b.outs.iter()) {
            assert_eq!(x.shape, y.shape);
            assert_eq!(
                x.data, y.data,
                "step {step}: tracing perturbed the streamed outputs"
            );
        }
        assert_eq!(a.stats.expert_loads, b.stats.expert_loads);
        assert_eq!(a.stats.waves, b.stats.waves);
        assert_eq!(a.stats.network_bytes, b.stats.network_bytes);
    }

    assert!(plain.take_spans().is_empty(), "untraced engine has no spans");
    let spans = traced.take_spans();
    assert_schema(&spans, steps);
    for kind in [SpanKind::Route, SpanKind::Compute, SpanKind::Combine] {
        assert!(
            spans.iter().any(|s| s.kind == kind),
            "streamed step recorded no {} span",
            kind.name()
        );
    }
    // drained means drained: a second take starts empty
    assert!(traced.take_spans().is_empty());
}

#[test]
fn traced_unpipelined_step_is_bit_identical_to_untraced() {
    let work = SyntheticMoe::build(17, 8, 16, N_EXPERTS, 2, DEVICES, 16)
        .unwrap();
    let plain = sched(ObsConfig::default());
    let traced = sched(ObsConfig::enabled());
    let mut r1 = Rng::new(7);
    let mut r2 = Rng::new(7);
    let (a, _) = work.run_unpipelined(&plain, Some(&mut r1)).unwrap();
    let (b, _) = work.run_unpipelined(&traced, Some(&mut r2)).unwrap();
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(
            x.data, y.data,
            "tracing perturbed the pre-routed engine step"
        );
    }
    let spans = traced.take_spans();
    assert_schema(&spans, 1);
    assert!(
        spans.iter().any(|s| s.kind == SpanKind::Compute),
        "engine step recorded no compute span"
    );
}

#[test]
fn traced_serve_run_is_bit_identical_to_untraced() {
    // same frozen model behind two serve loops (SyntheticMoe is
    // seed-deterministic), same trace; the queue is deep enough that
    // nothing sheds, so both runs complete every request and each
    // completed output must match bit for bit
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 51,
            rate_per_sec: 5_000.0,
            n_requests: 20,
            min_rows: 1,
            max_rows: 5,
            bursty: false,
        }),
        8,
        53,
    );
    let run = |obs: ObsConfig| {
        let work =
            SyntheticMoe::build(29, 8, 16, N_EXPERTS, 2, 1, 8).unwrap();
        let serve = ServeLoop::new(
            sched(obs),
            work.router,
            work.weights,
            ServeConfig {
                queue_depth: 64,
                max_batch_tokens: 12,
                latency_budget_ns: 100_000,
                capture_outputs: true,
                ..Default::default()
            },
        )
        .unwrap();
        let report = serve.run_trace(&trace).unwrap();
        let spans = serve.take_spans();
        (report, spans)
    };
    let (plain, no_spans) = run(ObsConfig::default());
    let (traced, spans) = run(ObsConfig::enabled());
    assert!(no_spans.is_empty(), "untraced serve loop has no spans");
    assert!(!spans.is_empty(), "traced serve loop recorded no spans");
    assert_eq!(plain.stats.offered, trace.len() as u64);
    assert_eq!(traced.stats.offered, plain.stats.offered);
    assert_eq!(traced.stats.completed, plain.stats.completed);
    assert_eq!(traced.stats.shed, 0, "queue_depth covers the whole trace");
    assert_eq!(
        traced.stats.completed + traced.stats.shed + traced.stats.failed,
        traced.stats.offered,
        "admission ledger must conserve"
    );
    for (i, (a, b)) in
        plain.outputs.iter().zip(traced.outputs.iter()).enumerate()
    {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(
            a.data, b.data,
            "request {i}: tracing perturbed the served output"
        );
    }
    // serve spans cover one engine step per dispatched batch
    let batch_steps =
        spans.iter().filter(|s| s.kind == SpanKind::Step).count() as u64;
    assert_eq!(batch_steps, traced.stats.batches);
}

#[test]
fn chrome_trace_export_is_parseable_and_complete() {
    let work = SyntheticMoe::build(5, 8, 16, N_EXPERTS, 2, DEVICES, 12)
        .unwrap();
    let traced = sched(ObsConfig::enabled());
    work.run_streamed(&traced, None).unwrap();
    let spans = traced.take_spans();
    let doc = chrome_trace_json(&spans, DEVICES);
    let v = json::parse(&doc).expect("chrome trace must be valid JSON");
    let events = v
        .get("traceEvents")
        .expect("traceEvents key")
        .as_arr()
        .expect("traceEvents is an array");
    // thread metadata for every shard lane + the coordinator lane, then
    // one X event per span
    let ph = |e: &json::Value| -> Option<String> {
        e.get("ph").and_then(|p| p.as_str()).map(|s| s.to_string())
    };
    let meta = events
        .iter()
        .filter(|e| ph(e).as_deref() == Some("M"))
        .count();
    let complete: Vec<_> = events
        .iter()
        .filter(|e| ph(e).as_deref() == Some("X"))
        .collect();
    assert!(meta >= DEVICES + 1, "a tid label per lane plus the process");
    assert_eq!(complete.len(), spans.len(), "every span exports once");
    for e in complete {
        for key in ["name", "pid", "tid", "ts", "dur", "args"] {
            assert!(e.get(key).is_some(), "X event missing {key}");
        }
        let tid = e.get("tid").unwrap().as_usize().unwrap();
        assert!(tid <= DEVICES, "tid {tid} beyond the coordinator lane");
    }
}

#[test]
fn registry_snapshot_roundtrips_and_renders_the_console_lines() {
    let work = SyntheticMoe::build(3, 8, 16, N_EXPERTS, 2, DEVICES, 12)
        .unwrap();
    let s = work.run_streamed(&sched(ObsConfig::default()), None).unwrap();
    let mut reg = Registry::new();
    s.stats.publish(&mut reg);
    let snap = reg.snapshot();

    // console line == renderer over the snapshot, byte for byte
    assert_eq!(phase_line(&s.stats), render_phase_line(&snap));

    // JSON export parses and carries the published counters
    let v = json::parse(&snap.to_json()).expect("snapshot JSON parses");
    let counters = v.get("counters").expect("counters object");
    assert_eq!(
        counters.get("step_waves").and_then(|x| x.as_usize()),
        Some(snap.counter("step_waves") as usize)
    );

    // Prometheus text has a TYPE line and a sample per base family
    let prom = snap.to_prometheus();
    assert!(prom.contains("# TYPE step_phase_ns counter"));
    assert!(prom.contains("step_phase_ns{phase=\"compute\"}"));
    assert!(prom.contains("step_waves"));

    // serve stats publish + render the same way (empty stats: the
    // degenerate snapshot still renders without panicking)
    let stats = ServeStats::default();
    let mut sreg = Registry::new();
    stats.publish(&mut sreg);
    assert_eq!(
        stats.summary_line(),
        ServeStats::render_summary(&sreg.snapshot())
    );
}

#[test]
fn peak_queue_depth_publish_is_idempotent_not_summing() {
    // regression: peak_queue_depth used to go in via counter_add, so
    // publishing the same stats twice (or merging replays into one
    // registry) reported the *sum* of high-water marks — a queue that
    // never got deeper than 6 showed peak 12.  High-water marks must
    // max-combine.
    let mut stats = ServeStats::default();
    stats.offered = 4;
    stats.completed = 4;
    stats.peak_queue_depth = 6;
    let mut reg = Registry::new();
    stats.publish(&mut reg);
    stats.publish(&mut reg);
    let snap = reg.snapshot();
    // flows legitimately accumulate across publishes...
    assert_eq!(snap.counter("serve_offered"), 8);
    // ...but the high-water mark must not
    assert_eq!(
        snap.gauge("serve_peak_queue_depth"),
        6.0,
        "double publish summed the peak instead of max-combining"
    );
    // and merging a replay with a lower peak keeps the maximum
    let mut shallower = ServeStats::default();
    shallower.peak_queue_depth = 2;
    shallower.publish(&mut reg);
    assert_eq!(reg.snapshot().gauge("serve_peak_queue_depth"), 6.0);
}
