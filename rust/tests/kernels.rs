//! Kernel-layer differential proofs: every kernel the host can run is
//! checked against an f64 naive oracle with an error budget derived
//! from accumulation analysis, and the int8 serve path is budgeted
//! normwise against the f32 path over the same weights.
//!
//! Two tiers of claim (see `rust/src/kernels/mod.rs`):
//!
//! - **bit-exact**: the scalar kernel vs the naive triple loop, the
//!   sparse-aware scalar entry vs its dense twin, and row-block
//!   invariance *within* any one kernel (the engine's streaming paths
//!   depend on it);
//! - **error-budgeted**: any kernel vs the f64 oracle (SIMD kernels
//!   reassociate the k-reduction), and int8 vs f32 end to end.
//!
//! Budget: one output element reduces `k` products; worst-case f32
//! accumulation error is `O(k) · eps · Σ|aₗ·bₗ|`, so the per-element
//! tolerance is `2(k+8) · eps · Σ|aₗ·bₗ| + 1e-9` — loose enough for any
//! reduction order (sequential, lane-tiled, pairwise), tight enough
//! that a single wrong/missing term (order `|aₗ·bₗ|` itself) fails.

use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout,
};
use moe::coordinator::Router;
use moe::harness::workload::{poisson_trace, trace_requests, TraceSpec};
use moe::kernels::quant::{
    Precision, QuantizedExpertWeights, SERVE_REL_ERR_BUDGET,
};
use moe::kernels::{Kernel, MatmulKernel};
use moe::runtime::TensorF;
use moe::serve::{ServeConfig, ServeLoop};
use moe::util::prop;
use moe::util::rng::Rng;

/// Accumulation-analysis tolerance (module docs): per-element bound for
/// a k-term f32 reduction, valid for any reduction order.
fn assert_within(got: &[f32], want: &[f64], abs_sum: &[f64], k: usize, ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    let eps = f32::EPSILON as f64;
    for (idx, ((g, w), s)) in
        got.iter().zip(want.iter()).zip(abs_sum.iter()).enumerate()
    {
        let tol = 2.0 * (k as f64 + 8.0) * eps * s + 1e-9;
        let err = (*g as f64 - w).abs();
        assert!(
            err <= tol,
            "{ctx}[{idx}]: got {g}, want {w:.9e}, err {err:.3e} > tol {tol:.3e}"
        );
    }
}

/// f64 oracle for `a (m,k) · b (k,n)`; also returns `Σ|aₗ·bₗ|` per
/// element for the tolerance.
fn oracle_mm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut want = vec![0f64; m * n];
    let mut abs = vec![0f64; m * n];
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l] as f64;
            for j in 0..n {
                let p = av * b[l * n + j] as f64;
                want[i * n + j] += p;
                abs[i * n + j] += p.abs();
            }
        }
    }
    (want, abs)
}

/// f64 oracle for `init (k,n) + aᵀ (k,m) · b (m,n)` (the accumulating
/// `matmul_tn` contract).
fn oracle_tn(
    a: &[f32],
    b: &[f32],
    init: &[f32],
    m: usize,
    k: usize,
    n: usize,
) -> (Vec<f64>, Vec<f64>) {
    let mut want: Vec<f64> = init.iter().map(|v| *v as f64).collect();
    let mut abs: Vec<f64> = init.iter().map(|v| (*v as f64).abs()).collect();
    for i in 0..m {
        for l in 0..k {
            let av = a[i * k + l] as f64;
            for j in 0..n {
                let p = av * b[i * n + j] as f64;
                want[l * n + j] += p;
                abs[l * n + j] += p.abs();
            }
        }
    }
    (want, abs)
}

/// f64 oracle for `a (m,k) · bᵀ (n,k)`.
fn oracle_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> (Vec<f64>, Vec<f64>) {
    let mut want = vec![0f64; m * n];
    let mut abs = vec![0f64; m * n];
    for i in 0..m {
        for j in 0..n {
            for l in 0..k {
                let p = a[i * k + l] as f64 * b[j * k + l] as f64;
                want[i * n + j] += p;
                abs[i * n + j] += p.abs();
            }
        }
    }
    (want, abs)
}

/// Shapes that hit the structural edges: empty batches (`m = 0`),
/// degenerate reductions (`k = 0`, `k = 1`), widths off every unroll
/// multiple (9, 17, 31, 33 vs the 4/8/16/32-wide tiles), and spans
/// crossing the KB = 64/128/256 k-block boundaries.
const EDGE_SHAPES: &[(usize, usize, usize)] = &[
    (0, 5, 7),
    (2, 0, 4),
    (1, 1, 1),
    (3, 1, 9),
    (2, 1, 33),
    (3, 7, 31),
    (2, 65, 17),
    (4, 130, 33),
    (2, 257, 9),
    (1, 300, 40),
];

#[test]
fn matmul_matches_f64_oracle_on_all_kernels() {
    let run = |m: usize, k: usize, n: usize, rng: &mut Rng| {
        let a = prop::vec_f32(rng, m * k, 1.0);
        let b = prop::vec_f32(rng, k * n, 1.0);
        let (want, abs) = oracle_mm(&a, &b, m, k, n);
        for kern in Kernel::available() {
            let mut got = vec![f32::NAN; m * n];
            kern.matmul(&a, &b, &mut got, m, k, n);
            let ctx = format!("{} matmul {m}x{k}x{n}", kern.name());
            assert_within(&got, &want, &abs, k, &ctx);
        }
    };
    let mut rng = prop::case_rng(1);
    for &(m, k, n) in EDGE_SHAPES {
        run(m, k, n, &mut rng);
    }
    prop::forall("matmul vs f64", |rng| {
        let m = prop::dim(rng, 0, 6);
        let k = prop::dim(rng, 1, 90);
        let n = prop::dim(rng, 1, 70);
        run(m, k, n, rng);
    });
}

#[test]
fn matmul_tn_accumulates_and_matches_f64_oracle_on_all_kernels() {
    let run = |m: usize, k: usize, n: usize, rng: &mut Rng| {
        let a = prop::vec_f32(rng, m * k, 1.0);
        let b = prop::vec_f32(rng, m * n, 1.0);
        // seeded output: the += (dW accumulation) contract is part of
        // the oracle, not zeroed away
        let init = prop::vec_f32(rng, k * n, 1.0);
        let (want, abs) = oracle_tn(&a, &b, &init, m, k, n);
        for kern in Kernel::available() {
            let mut got = init.clone();
            kern.matmul_tn(&a, &b, &mut got, m, k, n);
            let ctx = format!("{} matmul_tn {m}x{k}x{n}", kern.name());
            // m terms fold into each element on top of the seed
            assert_within(&got, &want, &abs, m + 1, &ctx);
        }
    };
    let mut rng = prop::case_rng(2);
    for &(k, m, n) in EDGE_SHAPES {
        // reuse the edge list with m as the reduced dim (tn reduces m)
        run(m, k, n, &mut rng);
    }
    prop::forall("matmul_tn vs f64", |rng| {
        let m = prop::dim(rng, 0, 40);
        let k = prop::dim(rng, 1, 12);
        let n = prop::dim(rng, 1, 70);
        run(m, k, n, rng);
    });
}

#[test]
fn matmul_nt_matches_f64_oracle_on_all_kernels() {
    let run = |m: usize, n: usize, k: usize, rng: &mut Rng| {
        let a = prop::vec_f32(rng, m * k, 1.0);
        let b = prop::vec_f32(rng, n * k, 1.0);
        let (want, abs) = oracle_nt(&a, &b, m, n, k);
        for kern in Kernel::available() {
            let mut got = vec![f32::NAN; m * n];
            kern.matmul_nt(&a, &b, &mut got, m, n, k);
            let ctx = format!("{} matmul_nt {m}x{n}x{k}", kern.name());
            assert_within(&got, &want, &abs, k, &ctx);
        }
    };
    let mut rng = prop::case_rng(3);
    for &(m, k, n) in EDGE_SHAPES {
        run(m, n, k, &mut rng);
    }
    prop::forall("matmul_nt vs f64", |rng| {
        let m = prop::dim(rng, 0, 6);
        let n = prop::dim(rng, 1, 12);
        let k = prop::dim(rng, 1, 300);
        run(m, n, k, rng);
    });
}

#[test]
fn scalar_sparse_entry_is_bit_identical_to_dense_twin() {
    // the retained `av == 0.0` skip branch lives only in the
    // sparse-aware entry; for finite inputs (dense or post-ReLU sparse)
    // it must be an exact no-op vs the branch-free twin
    let scalar = Kernel::scalar();
    prop::forall("sparse == dense bitwise", |rng| {
        let m = prop::dim(rng, 1, 6);
        let k = prop::dim(rng, 1, 80);
        let n = prop::dim(rng, 1, 40);
        let dense = prop::vec_f32(rng, m * k, 1.0);
        // post-ReLU-shaped input: roughly half the entries exactly 0.0
        let sparse: Vec<f32> = dense.iter().map(|v| v.max(0.0)).collect();
        let b = prop::vec_f32(rng, k * n, 1.0);
        for a in [&dense, &sparse] {
            let mut d = vec![0f32; m * n];
            let mut s = vec![0f32; m * n];
            scalar.matmul(a, &b, &mut d, m, k, n);
            scalar.matmul_sparse(a, &b, &mut s, m, k, n);
            for (x, y) in d.iter().zip(s.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "sparse entry drifted");
            }
        }
    });
}

#[test]
fn row_blocks_are_bit_identical_to_full_batch_on_all_kernels() {
    // the engine streams expert chunks and gating row blocks; every
    // kernel must keep contiguous row blocks bit-identical to the
    // full-batch call (module-doc invariant)
    prop::forall("row-block invariance", |rng| {
        let m = prop::dim(rng, 2, 9);
        let k = prop::dim(rng, 1, 70);
        let n = prop::dim(rng, 1, 40);
        let a = prop::vec_f32(rng, m * k, 1.0);
        let b = prop::vec_f32(rng, k * n, 1.0);
        let m1 = m / 2;
        for kern in Kernel::available() {
            let mut full = vec![0f32; m * n];
            kern.matmul(&a, &b, &mut full, m, k, n);
            let mut blocked = vec![0f32; m * n];
            kern.matmul(&a[..m1 * k], &b, &mut blocked[..m1 * n], m1, k, n);
            kern.matmul(&a[m1 * k..], &b, &mut blocked[m1 * n..], m - m1, k, n);
            for (x, y) in full.iter().zip(blocked.iter()) {
                assert_eq!(
                    x.to_bits(),
                    y.to_bits(),
                    "{}: row block drifted from full batch",
                    kern.name()
                );
            }
        }
    });
}

#[test]
fn matmul_q8_matches_f64_oracle_on_dequantized_weights_on_all_kernels() {
    // the int8 GEMM applies per-column scales once after the full
    // k-reduction: in exact arithmetic (Σ a·q)·s == Σ a·(q·s), so the
    // f64 oracle over the *dequantized* matrix is the reference
    prop::forall("matmul_q8 vs f64", |rng| {
        let m = prop::dim(rng, 0, 5);
        let k = prop::dim(rng, 1, 80);
        let n = prop::dim(rng, 1, 40);
        let a = prop::vec_f32(rng, m * k, 1.0);
        let q: Vec<i8> =
            (0..k * n).map(|_| (prop::dim(rng, 0, 254) as i32 - 127) as i8).collect();
        let scales: Vec<f32> =
            prop::vec_f32(rng, n, 0.02).iter().map(|s| s.abs() + 1e-3).collect();
        let dq: Vec<f32> = q
            .chunks(n)
            .flat_map(|row| {
                row.iter().zip(scales.iter()).map(|(&qv, &sv)| qv as f32 * sv)
            })
            .collect();
        let (want, abs) = oracle_mm(&a, &dq, m, k, n);
        for kern in Kernel::available() {
            let mut got = vec![f32::NAN; m * n];
            kern.matmul_q8(&a, &q, &scales, &mut got, m, k, n);
            let ctx = format!("{} matmul_q8 {m}x{k}x{n}", kern.name());
            assert_within(&got, &want, &abs, k, &ctx);
        }
    });
}

// ---------------------------------------------------------------------
// end-to-end: kernel selection surfaced in telemetry, int8 serving
// budgeted against f32 serving, f32 checkpoints untouched by int8 load
// ---------------------------------------------------------------------

struct Frozen {
    d: usize,
    n: usize,
    w_g: Vec<f32>,
    w_noise: Vec<f32>,
    weights: Vec<ExpertWeights>,
}

impl Frozen {
    fn build(seed: u64, d: usize, h: usize, n: usize) -> Self {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, d * h, 0.3),
                w_out: prop::vec_f32(&mut rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect();
        Frozen {
            d,
            n,
            w_g: prop::vec_f32(&mut rng, d * n, 0.5),
            w_noise: prop::vec_f32(&mut rng, d * n, 0.3),
            weights,
        }
    }

    fn router(&self, k: usize) -> Router {
        Router::flat_native(
            self.d,
            self.n,
            k,
            self.w_g.clone(),
            Some(self.w_noise.clone()),
        )
    }
}

fn assert_weights_bit_equal(a: &[ExpertWeights], b: &[ExpertWeights], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: expert count");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let bits =
            |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&x.w_in), bits(&y.w_in), "{ctx}: expert {i} w_in");
        assert_eq!(bits(&x.w_out), bits(&y.w_out), "{ctx}: expert {i} w_out");
    }
}

#[test]
fn step_stats_record_the_selected_kernel() {
    let (d, h, n, k) = (6, 8, 4, 2);
    let frozen = Frozen::build(17, d, h, n);
    let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
    let mut rng = Rng::new(5);
    let x = TensorF::new(vec![7, d], prop::vec_f32(&mut rng, 7 * d, 1.0));
    let (outs, stats) = sched
        .execute_forward(&frozen.router(k), &[&x], &frozen.weights)
        .unwrap();
    assert_eq!(outs[0].shape, vec![7, d]);
    assert_eq!(stats.kernel, Kernel::selected_name());
    assert!(
        Kernel::available().iter().any(|kk| kk.name() == stats.kernel),
        "stats.kernel {:?} not runnable on this host",
        stats.kernel
    );
}

#[test]
fn int8_serving_tracks_f32_serving_within_budget() {
    let (d, h, n, k) = (8, 12, 5, 2);
    let frozen = Frozen::build(43, d, h, n);
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 71,
            rate_per_sec: 40_000.0,
            n_requests: 23,
            min_rows: 1,
            max_rows: 6,
            bursty: true,
        }),
        d,
        91,
    );
    let mk = |precision: Precision| {
        ServeLoop::new(
            Scheduler::new(ShardLayout::new(3, n), ExpertBackend::Native),
            frozen.router(k),
            frozen.weights.clone(),
            ServeConfig {
                queue_depth: 64,
                max_batch_tokens: 16,
                latency_budget_ns: 200_000,
                capture_outputs: true,
                precision,
                ..Default::default()
            },
        )
        .unwrap()
    };
    let f32_loop = mk(Precision::F32);
    let int8_loop = mk(Precision::Int8);
    assert!(f32_loop.quantized_weights().is_none());
    let q = int8_loop.quantized_weights().expect("int8 config quantizes at load");
    assert_eq!(q.len(), n);
    // quantize-at-load must leave the f32 weights untouched
    assert_weights_bit_equal(int8_loop.weights(), &frozen.weights, "int8 load");

    let rf = f32_loop.run_trace(&trace).unwrap();
    let r8 = int8_loop.run_trace(&trace).unwrap();
    assert_eq!(rf.stats.shed, 0);
    assert_eq!(r8.stats.shed, 0);
    let mut worst = 0f64;
    for (i, (a, b)) in rf.outputs.iter().zip(r8.outputs.iter()).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.shape, b.shape, "request {i} shape");
        let norm: f64 =
            a.data.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
        let err: f64 = a
            .data
            .iter()
            .zip(b.data.iter())
            .map(|(x, y)| (*x as f64 - *y as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(
            err <= SERVE_REL_ERR_BUDGET * norm + 1e-6,
            "request {i}: int8 serve error {err:.3e} over budget (norm {norm:.3e})"
        );
        if norm > 1e-9 {
            worst = worst.max(err / norm);
        }
    }
    assert!(
        worst > 0.0,
        "int8 and f32 serve outputs are bitwise identical — the \
         quantized path did not run"
    );
}

#[test]
fn int8_quantization_is_deterministic_across_loads() {
    let frozen = Frozen::build(29, 6, 9, 3);
    let q1 = QuantizedExpertWeights::quantize_all(&frozen.weights);
    let q2 = QuantizedExpertWeights::quantize_all(&frozen.weights.clone());
    for (a, b) in q1.iter().zip(q2.iter()) {
        assert_eq!(a.q_in, b.q_in);
        assert_eq!(a.q_out, b.q_out);
        let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a.s_in), bits(&b.s_in));
        assert_eq!(bits(&a.s_out), bits(&b.s_out));
    }
}

#[test]
fn f32_checkpoints_load_bit_unchanged_under_int8_serving() {
    use moe::runtime::ModelConfig;
    use moe::train::{checkpoint, Trainer};

    // train a few streamed f32 steps, freeze, then load the same
    // checkpoint under both precisions: the f32 weights must be
    // bit-identical (quantization is load-time and additive only)
    let (d, h, n, k) = (6, 8, 4, 2);
    let model = ModelConfig::native_moe("kernels-ckpt", d, n, k, h, 1, 8);
    let trainer = Trainer::native(model.clone());
    let mut state = trainer.init_streamed(13);
    let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
    let mut rng = Rng::new(31);
    let xs = vec![TensorF::new(vec![9, d], prop::vec_f32(&mut rng, 9 * d, 1.0))];
    let targets =
        vec![TensorF::new(vec![9, d], prop::vec_f32(&mut rng, 9 * d, 1.0))];
    for _ in 0..2 {
        trainer
            .step_streamed(&sched, &mut state, &xs, &targets, 0.05, None)
            .unwrap();
    }
    let dir = std::env::temp_dir().join("moe_kernels_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("kernels.ckpt");
    checkpoint::save_streamed(&path, &model.name, &state).unwrap();

    let load = |precision: Precision| {
        ServeLoop::from_checkpoint(
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
            &path,
            &model.name,
            &model,
            ServeConfig { precision, ..Default::default() },
        )
        .unwrap()
    };
    let serve_f32 = load(Precision::F32);
    let serve_int8 = load(Precision::Int8);
    assert_weights_bit_equal(
        serve_f32.weights(),
        serve_int8.weights(),
        "checkpoint under int8",
    );
    // and the quantized side really derives from those f32 weights
    let q = serve_int8.quantized_weights().unwrap();
    let expect = QuantizedExpertWeights::quantize_all(serve_int8.weights());
    for (a, b) in q.iter().zip(expect.iter()) {
        assert_eq!(a.q_in, b.q_in, "quantized codes drifted from f32 source");
        assert_eq!(a.q_out, b.q_out);
    }
}
