//! End-to-end integration over the runtime + trainer + coordinator.
//! Requires `make artifacts`.

mod common;

use moe::data::synthetic::{CorpusSpec, TopicCorpus};
use moe::data::Batcher;
use moe::runtime::{Engine, Manifest};
use moe::train::{checkpoint, Trainer};

fn setup() -> Option<(Engine, Manifest)> {
    common::setup_artifacts("integration")
}

#[test]
fn training_reduces_loss_flat_moe() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 4,
        branch: 3,
        mean_len: 8,
        seed: 0,
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0).unwrap();
    let metrics = trainer.run(&mut state, &mut batcher, 80, 0).unwrap();
    let first10: f64 =
        metrics[..10].iter().map(|m| m.nll).sum::<f64>() / 10.0;
    let last10: f64 =
        metrics[70..].iter().map(|m| m.nll).sum::<f64>() / 10.0;
    assert!(
        last10 < first10 - 0.15,
        "nll should fall: first10={first10:.3} last10={last10:.3}"
    );
    // all metrics finite throughout
    for m in &metrics {
        assert!(m.loss.is_finite() && m.grad_norm.is_finite());
    }
}

#[test]
fn training_reduces_loss_hierarchical_moe() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-hier").unwrap();
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0).unwrap();
    let metrics = trainer.run(&mut state, &mut batcher, 60, 0).unwrap();
    assert!(metrics.last().unwrap().nll < metrics[0].nll);
}

#[test]
fn eval_perplexity_beats_uniform_after_training() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 2,
        branch: 2,
        mean_len: 8,
        seed: 1,
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0).unwrap();
    let untrained = {
        let mut t = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);
        trainer.evaluate(&state, &mut t, 10).unwrap().perplexity()
    };
    trainer.run(&mut state, &mut batcher, 120, 0).unwrap();
    let mut test = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);
    let ppl = trainer.evaluate(&state, &mut test, 10).unwrap().perplexity();
    // the test-tiny model is deliberately miniature (d=16), so demand a
    // clear-but-modest margin over both uniform and the untrained net
    assert!(
        ppl < c.vocab as f64 * 0.85,
        "trained ppl {ppl:.1} should beat uniform {}",
        c.vocab
    );
    assert!(
        ppl < untrained * 0.85,
        "trained ppl {ppl:.1} should beat untrained {untrained:.1}"
    );
}

#[test]
fn checkpoint_roundtrip_through_trainer() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0).unwrap();
    trainer.run(&mut state, &mut batcher, 5, 0).unwrap();
    let path = std::env::temp_dir().join("moe_integ.ckpt");
    checkpoint::save(&path, "test-tiny", &state).unwrap();
    let restored = checkpoint::load(&path, "test-tiny").unwrap();
    assert_eq!(restored.step, state.step);
    // evals agree exactly
    let mut b1 = Batcher::new(&corpus, c.batch, c.seq_len, 9);
    let mut b2 = Batcher::new(&corpus, c.batch, c.seq_len, 9);
    let e1 = trainer.evaluate(&state, &mut b1, 2).unwrap();
    let e2 = trainer.evaluate(&restored, &mut b2, 2).unwrap();
    assert_eq!(e1.nll_sum, e2.nll_sum);
}

#[test]
fn balance_losses_keep_experts_utilised() {
    // after training with w_importance = w_load = 0.1, no expert should be
    // starved (the §4 failure mode)
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        ..Default::default()
    });
    let mut batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    let mut state = trainer.init(0).unwrap();
    let metrics = trainer.run(&mut state, &mut batcher, 60, 0).unwrap();
    let tail: Vec<_> = metrics[40..].iter().collect();
    let cv_imp =
        tail.iter().map(|m| m.cv_importance).sum::<f64>() / tail.len() as f64;
    let mm = tail.iter().map(|m| m.max_over_mean_load).sum::<f64>()
        / tail.len() as f64;
    assert!(cv_imp < 0.5, "CV^2(importance) stayed high: {cv_imp:.3}");
    assert!(mm < 2.5, "max/mean load stayed high: {mm:.2}");
}

#[test]
fn decode_artifact_produces_finite_logits() {
    use moe::translate::BeamDecoder;
    let Some((engine, manifest)) = setup() else { return };
    let trainer = Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let state = trainer.init(0).unwrap();
    let decoder = BeamDecoder::new(
        engine.load(&manifest, "test-tiny", "decode").unwrap(),
        &trainer.entry,
    );
    let hyps = decoder
        .decode(&state.params, &[0, 5, 9], 4, 8, 1)
        .unwrap();
    assert!(!hyps.is_empty());
    for h in &hyps {
        assert!(h.log_prob.is_finite());
        assert!(h.tokens.len() <= 8);
    }
    // beam returns distinct hypotheses sorted by score
    for w in hyps.windows(2) {
        assert!(w[0].score() >= w[1].score());
    }
}

#[test]
fn manifest_covers_every_expected_artifact_kind() {
    let Some((_, manifest)) = setup() else { return };
    let entry = manifest.config("test-tiny").unwrap();
    for kind in ["init", "step", "eval", "decode", "gating", "expert"] {
        assert!(
            entry.artifacts.contains_key(kind),
            "missing artifact kind {kind}"
        );
    }
    // hierarchical configs: no flat gating artifact, but expert is there
    let h = manifest.config("test-hier").unwrap();
    assert!(!h.artifacts.contains_key("gating"));
    assert!(h.artifacts.contains_key("expert"));
}

#[test]
fn shape_mismatch_fails_loudly() {
    let Some((engine, manifest)) = setup() else { return };
    let exe = engine.load(&manifest, "test-tiny", "eval").unwrap();
    let bad = moe::runtime::Host::F32(moe::runtime::TensorF::zeros(vec![3]));
    let err = exe.run(&[bad.clone(), bad]).unwrap_err().to_string();
    assert!(err.contains("mismatch"), "unexpected error: {err}");
}
