//! Shared setup for the artifact-dependent test suites.

use moe::runtime::{Engine, Manifest};

/// Artifact-dependent tests skip (with a note) instead of panicking
/// when the PJRT engine or `artifacts/manifest.json` is absent, so
/// `cargo test -q` passes on a bare checkout.  Run `make artifacts`
/// with the real xla toolchain to activate them.
pub fn setup_artifacts(suite: &str) -> Option<(Engine, Manifest)> {
    let engine = match Engine::new() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP {suite} test (PJRT engine unavailable): {e}");
            return None;
        }
    };
    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            eprintln!("SKIP {suite} test (run `make artifacts`): {e}");
            return None;
        }
    };
    Some((engine, manifest))
}
