//! Cross-language parity: the rust mirrors must agree with the AOT'd
//! JAX/Pallas artifacts on identical inputs.  This is the proof that the
//! distributed L3 path (rust gating + dispatch + expert artifacts)
//! computes the same MoE as the monolithic L2 graph.
//!
//! Requires `make artifacts` (uses the test-tiny config).

mod common;

use moe::coordinator::router::{Router, RouterBackend};
use moe::coordinator::scheduler::ExpertWeights;
use moe::runtime::{Engine, Host, Manifest, TensorF};
use moe::util::rng::Rng;

fn setup() -> Option<(Engine, Manifest)> {
    common::setup_artifacts("parity")
}

fn perturbed_gates(d: usize, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let wg = (0..d * n).map(|_| rng.normal_f32() * 0.5).collect();
    let wn = (0..d * n).map(|_| rng.normal_f32() * 0.3).collect();
    (wg, wn)
}

#[test]
fn gating_artifact_matches_rust_mirror_deterministic() {
    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.config("test-tiny").unwrap().clone();
    let c = entry.config.clone();
    let (wg, wn) = perturbed_gates(c.d_model, c.n_experts, 3);

    let art = Router {
        backend: RouterBackend::Artifact(
            engine.load(&manifest, "test-tiny", "gating").unwrap(),
        ),
        n_experts: c.n_experts,
        k: c.k,
        groups: 0,
        d_model: c.d_model,
        w_g: wg.clone(),
        w_noise: Some(wn.clone()),
        w_g_sec: None,
        w_n_sec: None,
    };
    let native = Router::flat_native(c.d_model, c.n_experts, c.k, wg,
                                     Some(wn));
    let mut rng = Rng::new(11);
    let b = c.batch * c.seq_len;
    let x = TensorF::new(
        vec![b, c.d_model],
        (0..b * c.d_model).map(|_| rng.normal_f32()).collect(),
    );
    // deterministic comparison: no gate noise on either side
    let da = art.route(&x, None).unwrap();
    let dn = native.route(&x, None).unwrap();
    assert_eq!(da.per_token.len(), dn.per_token.len());
    for (ta, tn) in da.per_token.iter().zip(dn.per_token.iter()) {
        let mut ea = ta.experts.clone();
        let mut en = tn.experts.clone();
        ea.sort();
        en.sort();
        assert_eq!(ea, en, "expert selection differs");
        let mut wa: Vec<(usize, f32)> =
            ta.experts.iter().cloned().zip(ta.weights.iter().cloned()).collect();
        let mut wn_: Vec<(usize, f32)> =
            tn.experts.iter().cloned().zip(tn.weights.iter().cloned()).collect();
        wa.sort_by_key(|p| p.0);
        wn_.sort_by_key(|p| p.0);
        for ((_, a), (_, b)) in wa.iter().zip(wn_.iter()) {
            assert!((a - b).abs() < 1e-4, "gate weight {a} vs {b}");
        }
    }
    // importance agrees
    for (a, b) in da.importance.iter().zip(dn.importance.iter()) {
        assert!((a - b).abs() < 1e-3, "importance {a} vs {b}");
    }
}

#[test]
fn expert_artifact_matches_rust_ffn() {
    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.config("test-tiny").unwrap().clone();
    let c = entry.config.clone();
    let exe = engine.load(&manifest, "test-tiny", "expert").unwrap();
    let mut rng = Rng::new(5);
    let (d, h, cap) = (c.d_model, c.expert_hidden, c.capacity);
    let w = ExpertWeights {
        w_in: (0..d * h).map(|_| rng.normal_f32() * 0.3).collect(),
        w_out: (0..h * d).map(|_| rng.normal_f32() * 0.3).collect(),
        d_model: d,
        hidden: h,
    };
    let x = TensorF::new(
        vec![cap, d],
        (0..cap * d).map(|_| rng.normal_f32()).collect(),
    );
    let outs = exe
        .run(&[
            Host::F32(TensorF::new(vec![d, h], w.w_in.clone())),
            Host::F32(TensorF::new(vec![h, d], w.w_out.clone())),
            Host::F32(x.clone()),
        ])
        .unwrap();
    let y_art = outs[0].as_f32().unwrap();
    let y_rust = w.forward(&x);
    assert_eq!(y_art.shape, y_rust.shape);
    for (a, b) in y_art.data.iter().zip(y_rust.data.iter()) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn distributed_moe_matches_monolithic_semantics() {
    // route + dispatch + expert artifact + combine == sum_i g_i E_i(x)
    // computed naively with the rust FFN, on the same deterministic gates.
    use moe::coordinator::scheduler::{ExpertBackend, Scheduler, ShardLayout};
    use moe::coordinator::Dispatcher;

    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.config("test-tiny").unwrap().clone();
    let c = entry.config.clone();
    let mut rng = Rng::new(21);
    let (wg, wn) = perturbed_gates(c.d_model, c.n_experts, 8);
    let router = Router::flat_native(c.d_model, c.n_experts, c.k, wg,
                                     Some(wn));
    let weights: Vec<ExpertWeights> = (0..c.n_experts)
        .map(|_| ExpertWeights {
            w_in: (0..c.d_model * c.expert_hidden)
                .map(|_| rng.normal_f32() * 0.3)
                .collect(),
            w_out: (0..c.expert_hidden * c.d_model)
                .map(|_| rng.normal_f32() * 0.3)
                .collect(),
            d_model: c.d_model,
            hidden: c.expert_hidden,
        })
        .collect();
    let rows = 10;
    let x = TensorF::new(
        vec![rows, c.d_model],
        (0..rows * c.d_model).map(|_| rng.normal_f32()).collect(),
    );
    let dec = router.route(&x, None).unwrap();
    let plan = Dispatcher::plan(std::slice::from_ref(&dec), c.n_experts);
    let sched = Scheduler::new(
        ShardLayout::new(2, c.n_experts),
        ExpertBackend::Artifact {
            exe: engine.load(&manifest, "test-tiny", "expert").unwrap(),
            capacity: c.capacity,
        },
    );
    let (outs, _) = sched.execute(&plan, &[&x], &weights).unwrap();
    for (row, tok) in dec.per_token.iter().enumerate() {
        let xt = TensorF::new(vec![1, c.d_model], x.row(row).to_vec());
        let mut want = vec![0f32; c.d_model];
        for (e, g) in tok.experts.iter().zip(tok.weights.iter()) {
            for (w, v) in want.iter_mut().zip(weights[*e].forward(&xt).data.iter()) {
                *w += g * v;
            }
        }
        for (a, b) in outs[0].row(row).iter().zip(want.iter()) {
            assert!((a - b).abs() < 2e-3, "row {row}: {a} vs {b}");
        }
    }
}

#[test]
fn waves_handle_over_capacity_batches() {
    // a batch bigger than the artifact capacity must be processed in
    // multiple waves with identical numerics
    use moe::coordinator::scheduler::ExpertBackend;
    let Some((engine, manifest)) = setup() else { return };
    let entry = manifest.config("test-tiny").unwrap().clone();
    let c = entry.config.clone();
    let exe = engine.load(&manifest, "test-tiny", "expert").unwrap();
    let mut rng = Rng::new(2);
    let (d, h) = (c.d_model, c.expert_hidden);
    let w = ExpertWeights {
        w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
        w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
        d_model: d,
        hidden: h,
    };
    let len = c.capacity * 2 + 3;
    let x = TensorF::new(
        vec![len, d],
        (0..len * d).map(|_| rng.normal_f32()).collect(),
    );
    // wave execution through the scheduler internals: emulate via a
    // single-expert plan
    use moe::coordinator::router::RoutingDecision;
    use moe::coordinator::scheduler::{Scheduler, ShardLayout};
    use moe::coordinator::Dispatcher;
    use moe::gating::noisy_topk::GateVec;
    let dec = RoutingDecision {
        per_token: (0..len)
            .map(|_| GateVec { experts: vec![0], weights: vec![1.0] })
            .collect(),
        importance: vec![len as f32],
        load: vec![len as f32],
        noise: None,
    };
    let plan = Dispatcher::plan(std::slice::from_ref(&dec), 1);
    let sched = Scheduler::new(
        ShardLayout::new(1, 1),
        ExpertBackend::Artifact { exe, capacity: c.capacity },
    );
    let (outs, stats) = sched
        .execute(&plan, &[&x], std::slice::from_ref(&w))
        .unwrap();
    assert_eq!(stats.waves, 3, "expected 3 waves for 2*cap+3 tokens");
    let want = w.forward(&x);
    for (a, b) in outs[0].data.iter().zip(want.data.iter()) {
        assert!((a - b).abs() < 1e-3);
    }
}

#[test]
fn eval_artifact_is_deterministic() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer =
        moe::train::Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let state = trainer.init(7).unwrap();
    let c = trainer.entry.config.clone();
    let corpus = moe::data::synthetic::TopicCorpus::new(
        moe::data::synthetic::CorpusSpec { vocab: c.vocab, ..Default::default() },
    );
    let mut b1 = moe::data::Batcher::new(&corpus, c.batch, c.seq_len, 3);
    let mut b2 = moe::data::Batcher::new(&corpus, c.batch, c.seq_len, 3);
    let e1 = trainer.evaluate(&state, &mut b1, 3).unwrap();
    let e2 = trainer.evaluate(&state, &mut b2, 3).unwrap();
    assert_eq!(e1.nll_sum, e2.nll_sum);
    assert_eq!(e1.tokens, e2.tokens);
}

#[test]
fn init_is_seed_dependent_but_reproducible() {
    let Some((engine, manifest)) = setup() else { return };
    let trainer =
        moe::train::Trainer::new(&engine, &manifest, "test-tiny").unwrap();
    let a = trainer.init(0).unwrap();
    let b = trainer.init(0).unwrap();
    let c = trainer.init(1).unwrap();
    assert_eq!(a.params.data, b.params.data);
    assert_ne!(a.params.data, c.params.data);
    // gating nets start at zero (Appendix A initial-balance requirement)
    let entry = manifest.config("test-tiny").unwrap();
    let wg = entry.slice(&a.params.data, "moe.wg").unwrap();
    assert!(wg.iter().all(|&v| v == 0.0));
    let wn = entry.slice(&a.params.data, "moe.wn").unwrap();
    assert!(wn.iter().all(|&v| v == 0.0));
}
