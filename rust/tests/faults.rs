//! Fault-tolerance proofs: the deterministic fault layer against its
//! serial failure-masked oracle.
//!
//! The contract under test (see `coordinator::faults` and the module
//! docs' "Fault tolerance and the degraded combine" section):
//!
//! - a zero-fault [`FaultPlan`] is *bit-neutral*: the streamed engine
//!   with the plan threaded through is bit-identical to the engine
//!   without one;
//! - degraded streamed outputs are *bit-equal* to the serial oracle
//!   that replays the same chunking under the same fault draws
//!   ([`degrade_plan`] + [`combine_degraded`]), across both recovery
//!   policies, combine drops and shard deaths;
//! - same seed ⇒ same faults ⇒ same degraded outputs, bit for bit;
//! - every shard-death schedule — including all shards dead — leaves
//!   the engine live: steps return (no hang), outputs stay finite,
//!   and permanently dead shards are masked out of routing on
//!   subsequent steps;
//! - a worker panic without a fault session surfaces as a step error
//!   and leaves the engine reusable.

use moe::coordinator::router::Router;
use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout, WavePolicy,
};
use moe::coordinator::{
    combine_degraded, degrade_plan, FaultPlan, RecoveryPolicy,
};
use moe::gating::noisy_topk::GateVec;
use moe::runtime::TensorF;
use moe::util::prop;
use moe::util::rng::Rng;

const TOL: f32 = 1e-5;

fn mk_weights(
    n: usize,
    d: usize,
    h: usize,
    rng: &mut Rng,
) -> Vec<ExpertWeights> {
    (0..n)
        .map(|_| ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.3),
            w_out: prop::vec_f32(rng, h * d, 0.3),
            d_model: d,
            hidden: h,
        })
        .collect()
}

fn mk_router(d: usize, n: usize, k: usize, rng: &mut Rng) -> Router {
    Router::flat_native(
        d,
        n,
        k,
        prop::vec_f32(rng, d * n, 0.5),
        Some(prop::vec_f32(rng, d * n, 0.3)),
    )
}

fn mk_xs(replicas: usize, d: usize, rng: &mut Rng) -> Vec<TensorF> {
    (0..replicas)
        .map(|_| {
            let rows = prop::dim(rng, 1, 8);
            TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
        })
        .collect()
}

fn assert_outs_bit_eq(a: &[TensorF], b: &[TensorF], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}");
    for (r, (ta, tb)) in a.iter().zip(b).enumerate() {
        assert_eq!(ta.shape, tb.shape, "{ctx}: replica {r}");
        let ba: Vec<u32> = ta.data.iter().map(|v| v.to_bits()).collect();
        let bb: Vec<u32> = tb.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(ba, bb, "{ctx}: replica {r} outputs not bit-equal");
    }
}

fn sched_with(
    devices: usize,
    n: usize,
    wave_cap: usize,
    dispatch_cap: Option<usize>,
    plan: Option<FaultPlan>,
) -> Scheduler {
    Scheduler::with_policy(
        ShardLayout::new(devices, n),
        ExpertBackend::Native,
        WavePolicy::Fixed(Some(wave_cap)),
    )
    .with_dispatch_capacity(dispatch_cap)
    .with_fault_plan(plan)
}

/// A zero-fault plan threads the whole fault machinery through the
/// streamed step — per-chunk outcome draws, gate-vector retention, the
/// combine's lost-mass bookkeeping — and must change *nothing*:
/// decisions, plan and outputs bit-identical to an engine with no plan.
#[test]
fn zero_fault_plan_is_bit_neutral() {
    prop::forall("zero-fault plan is bit-neutral", |rng| {
        let d = prop::dim(rng, 2, 6);
        let h = prop::dim(rng, 2, 8);
        let n = prop::dim(rng, 2, 8);
        let k = prop::dim(rng, 1, n.min(3));
        let replicas = prop::dim(rng, 1, 3);
        let devices = prop::dim(rng, 1, n);
        let wave_cap = prop::dim(rng, 1, 6);
        let weights = mk_weights(n, d, h, rng);
        let router = mk_router(d, n, k, rng);
        let xs = mk_xs(replicas, d, rng);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let seed_rng = rng.fold_in(41);

        let plain = sched_with(devices, n, wave_cap, None, None);
        let mut ra = seed_rng.clone();
        let a = plain
            .execute_streamed(&router, &refs, &weights, Some(&mut ra))
            .unwrap();

        let faulted =
            sched_with(devices, n, wave_cap, None, Some(FaultPlan::none(9)));
        let mut rb = seed_rng.clone();
        let b = faulted
            .execute_streamed(&router, &refs, &weights, Some(&mut rb))
            .unwrap();

        assert_outs_bit_eq(&a.outs, &b.outs, "zero-fault");
        assert_eq!(b.stats.failed_chunks, 0);
        assert_eq!(b.stats.redispatched_routes, 0);
        assert_eq!(b.stats.degraded_tokens, 0);
        assert_eq!(b.stats.renorm_mass_lost, 0.0);
        assert_eq!(faulted.live_fraction(), 1.0);
    });
}

/// The core equivalence: streamed outputs under injected faults are
/// bit-equal to the serial oracle that replays the engine's chunking
/// over the finished plan, applies the identical draws, re-homes
/// redirectable routes and renormalizes the combine — across recovery
/// policies, chunk failures, timed-out stragglers, combine drops and
/// day-0 shard deaths, with and without GShard dispatch capacity.
#[test]
fn degraded_streamed_outputs_match_failure_masked_oracle() {
    prop::forall("degraded == failure-masked oracle", |rng| {
        let d = prop::dim(rng, 2, 6);
        let h = prop::dim(rng, 2, 8);
        let n = prop::dim(rng, 2, 8);
        let k = prop::dim(rng, 1, n.min(3));
        let replicas = prop::dim(rng, 1, 3);
        let devices = prop::dim(rng, 1, n);
        let wave_cap = prop::dim(rng, 1, 5);
        let dispatch_cap = if prop::dim(rng, 0, 1) == 1 {
            Some(prop::dim(rng, 1, 6))
        } else {
            None
        };
        let weights = mk_weights(n, d, h, rng);
        let router = mk_router(d, n, k, rng);
        let xs = mk_xs(replicas, d, rng);
        let refs: Vec<&TensorF> = xs.iter().collect();

        let policy = if prop::dim(rng, 0, 1) == 1 {
            RecoveryPolicy::Redispatch
        } else {
            RecoveryPolicy::DegradeOnly
        };
        let mut shard_deaths = Vec::new();
        if prop::dim(rng, 0, 2) == 0 {
            shard_deaths.push((0u64, prop::dim(rng, 0, devices - 1)));
        }
        let fp = FaultPlan {
            seed: prop::dim(rng, 0, 1 << 20) as u64,
            chunk_fail_rate: [0.0, 0.15, 0.4][prop::dim(rng, 0, 2)],
            straggler_rate: 0.25,
            straggler_delay_ns: 5_000,
            // sometimes under the injected delay, so stragglers time out
            deadline_ns: if prop::dim(rng, 0, 1) == 1 { 2_000 } else { 1 << 20 },
            combine_drop_rate: [0.0, 0.2][prop::dim(rng, 0, 1)],
            shard_deaths,
            policy,
        };
        let seed_rng = rng.fold_in(43);

        let sched =
            sched_with(devices, n, wave_cap, dispatch_cap, Some(fp.clone()));
        let mut r = seed_rng.clone();
        let s = sched
            .execute_streamed(&router, &refs, &weights, Some(&mut r))
            .unwrap();

        // the serial oracle over the same finished plan and fault step
        let layout = ShardLayout::new(devices, n);
        let sel: Vec<Vec<GateVec>> =
            s.decisions.iter().map(|dec| dec.per_token.clone()).collect();
        let dp = degrade_plan(&s.plan, &layout, &sel, wave_cap, 0, &fp);
        let expert_outputs: Vec<TensorF> = dp
            .plan
            .per_expert
            .iter()
            .enumerate()
            .map(|(e, batch)| {
                let rows = batch.tokens.len();
                let mut data = Vec::with_capacity(rows * d);
                for addr in &batch.tokens {
                    data.extend_from_slice(
                        &xs[addr.replica].data
                            [addr.row * d..(addr.row + 1) * d],
                    );
                }
                weights[e].forward(&TensorF::new(vec![rows, d], data))
            })
            .collect();
        let want = combine_degraded(&dp, &expert_outputs, d);

        assert_outs_bit_eq(&s.outs, &want, "degraded oracle");
        assert_eq!(s.stats.failed_chunks, dp.failed_chunks);
        assert_eq!(s.stats.redispatched_routes, dp.redispatched_routes);
        let oracle_degraded = dp
            .lost_mass
            .iter()
            .flat_map(|lm| lm.iter())
            .filter(|&&m| m > 0.0)
            .count();
        assert_eq!(s.stats.degraded_tokens, oracle_degraded);
        let oracle_lost: f64 = dp
            .lost_mass
            .iter()
            .flat_map(|lm| lm.iter())
            .map(|&m| m as f64)
            .sum();
        assert!(
            (s.stats.renorm_mass_lost - oracle_lost).abs()
                <= 1e-4 * oracle_lost.max(1.0),
            "lost mass {} vs oracle {}",
            s.stats.renorm_mass_lost,
            oracle_lost
        );
    });
}

/// Same seed, same faults: two fresh engines under the same plan
/// produce bit-identical degraded outputs and identical recovery
/// counters — chaos runs are exactly reproducible.
#[test]
fn same_seed_fault_runs_are_deterministic() {
    prop::forall("same seed same faults", |rng| {
        let (d, h) = (5, 7);
        let n = prop::dim(rng, 3, 8);
        let k = prop::dim(rng, 2, n.min(3));
        let devices = prop::dim(rng, 1, n);
        let weights = mk_weights(n, d, h, rng);
        let router = mk_router(d, n, k, rng);
        let xs = mk_xs(2, d, rng);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let fp = FaultPlan {
            seed: prop::dim(rng, 0, 1 << 20) as u64,
            chunk_fail_rate: 0.35,
            combine_drop_rate: 0.15,
            ..Default::default()
        };
        let seed_rng = rng.fold_in(47);

        let run = || {
            let sched =
                sched_with(devices, n, 3, Some(4), Some(fp.clone()));
            let mut r = seed_rng.clone();
            let first = sched
                .execute_streamed(&router, &refs, &weights, Some(&mut r))
                .unwrap();
            // second step advances the fault counter: different draws,
            // still deterministic across engines
            let mut r2 = seed_rng.clone();
            let second = sched
                .execute_streamed(&router, &refs, &weights, Some(&mut r2))
                .unwrap();
            (first, second)
        };
        let (a1, a2) = run();
        let (b1, b2) = run();
        assert_outs_bit_eq(&a1.outs, &b1.outs, "step 0");
        assert_outs_bit_eq(&a2.outs, &b2.outs, "step 1");
        assert_eq!(a1.stats.failed_chunks, b1.stats.failed_chunks);
        assert_eq!(
            a1.stats.redispatched_routes,
            b1.stats.redispatched_routes
        );
        assert_eq!(a1.stats.degraded_tokens, b1.stats.degraded_tokens);
        assert_eq!(a2.stats.failed_chunks, b2.stats.failed_chunks);
    });
}

/// Liveness under every death schedule: for every subset of shards
/// (including all of them) dying at step 0, three consecutive steps
/// return without hanging, outputs stay finite, dead shards are masked
/// out of routing on the steps after the death, and the all-dead
/// extreme degrades every row to zero.
#[test]
fn every_shard_death_schedule_terminates() {
    let (d, h, n, k, devices) = (4usize, 6usize, 4usize, 2usize, 2usize);
    let mut rng = Rng::new(61);
    let weights = mk_weights(n, d, h, &mut rng);
    let router = mk_router(d, n, k, &mut rng);
    let xs = mk_xs(2, d, &mut rng);
    let refs: Vec<&TensorF> = xs.iter().collect();
    let layout = ShardLayout::new(devices, n);

    for bits in 0..(1u32 << devices) {
        let deaths: Vec<(u64, usize)> = (0..devices)
            .filter(|sh| bits & (1 << sh) != 0)
            .map(|sh| (0u64, sh))
            .collect();
        let all_dead = deaths.len() == devices;
        let fp = FaultPlan {
            seed: 71,
            shard_deaths: deaths.clone(),
            ..Default::default()
        };
        let sched = sched_with(devices, n, 3, None, Some(fp));
        for step in 0..3u64 {
            let mut r = Rng::new(5).fold_in(step);
            let s = sched
                .execute_streamed(&router, &refs, &weights, Some(&mut r))
                .unwrap_or_else(|e| {
                    panic!("deaths {deaths:?} step {step}: {e}")
                });
            for o in &s.outs {
                assert!(
                    o.data.iter().all(|v| v.is_finite()),
                    "deaths {deaths:?} step {step}: non-finite output"
                );
            }
            if all_dead {
                // no live redirect target and no survivable chunk:
                // every row renormalizes to zero delivered mass
                for o in &s.outs {
                    assert!(
                        o.data.iter().all(|&v| v == 0.0),
                        "all-dead step {step} must zero every row"
                    );
                }
            } else if !deaths.is_empty() && step >= 1 {
                // permanently dead shards are masked out of the router
                // on the steps after the death step
                let loads = s.plan.expert_loads();
                for e in 0..n {
                    let dead =
                        deaths.iter().any(|&(_, sh)| sh == layout.owner(e));
                    if dead {
                        assert_eq!(
                            loads[e], 0,
                            "deaths {deaths:?} step {step}: dead expert \
                             {e} still routed"
                        );
                    }
                }
            }
        }
        if !deaths.is_empty() {
            let want = (devices - deaths.len()) as f64 / devices as f64;
            assert!((sched.live_fraction() - want).abs() < 1e-12);
        }
    }
}

/// Satellite: a worker panic without a fault session is surfaced as a
/// step error (not a hang, not a poisoned engine) and the same engine
/// serves the next step normally.
#[test]
fn worker_panic_surfaces_as_error_and_engine_survives() {
    let (d, h, n) = (4usize, 6usize, 4usize);
    let mut rng = Rng::new(77);
    let good = mk_weights(n, d, h, &mut rng);
    let mut bad = good.clone();
    // undersized weight: the worker's matmul indexes out of bounds and
    // panics inside catch_unwind
    bad[2].w_in = vec![0.0; 3];
    // k = n so expert 2 is guaranteed a chunk
    let router = mk_router(d, n, n, &mut rng);
    let xs = mk_xs(2, d, &mut rng);
    let refs: Vec<&TensorF> = xs.iter().collect();
    let sched = sched_with(2, n, 3, None, None);

    let err = sched.execute_streamed(&router, &refs, &bad, None);
    assert!(err.is_err(), "panicked worker must fail the step");

    // the engine (and its worker threads) survive for the next step
    let s = sched
        .execute_streamed(&router, &refs, &good, None)
        .expect("engine must be reusable after a worker panic");
    let (want, _) = sched.execute_serial(&s.plan, &refs, &good).unwrap();
    for (g, w) in s.outs.iter().zip(&want) {
        assert_eq!(g.shape, w.shape);
        for (a, b) in g.data.iter().zip(&w.data) {
            assert!((a - b).abs() <= TOL, "{a} vs {b}");
        }
    }
}

/// Satellite: under a fault session the same panic degrades instead of
/// failing — the step completes, the panicked chunk's rows renormalize
/// over their surviving experts, and outputs stay finite.
#[test]
fn worker_panic_under_fault_session_degrades_instead_of_failing() {
    let (d, h, n) = (4usize, 6usize, 4usize);
    let mut rng = Rng::new(83);
    let good = mk_weights(n, d, h, &mut rng);
    let mut bad = good.clone();
    bad[2].w_in = vec![0.0; 3];
    let router = mk_router(d, n, n, &mut rng);
    let xs = mk_xs(2, d, &mut rng);
    let refs: Vec<&TensorF> = xs.iter().collect();
    let sched = sched_with(2, n, 3, None, Some(FaultPlan::none(7)));

    let s = sched
        .execute_streamed(&router, &refs, &bad, None)
        .expect("fault session must absorb the panic as degradation");
    assert!(s.stats.failed_chunks > 0, "panic must be charged as a fault");
    assert!(s.stats.degraded_tokens > 0);
    for o in &s.outs {
        assert!(o.data.iter().all(|v| v.is_finite()));
    }
}
