//! Differential proof that the persistent [`ExecutionEngine`] computes
//! exactly the same MoE step as the retained serial reference path.
//!
//! None of these tests need artifacts: the Native backend exercises the
//! whole engine — persistent workers, arena reuse, wave chunking and the
//! gather/compute/combine pipeline — against pure-rust oracles, across
//! `util::prop::forall` randomized cases (replica counts, shard counts,
//! k, degenerate layouts, over-capacity waves).

use moe::coordinator::engine::ExecutionEngine;
use moe::coordinator::router::{Router, RouterBackend};
use moe::coordinator::scheduler::{
    AdaptiveWave, ExpertBackend, ExpertWeights, PhaseNanos, Scheduler,
    ShardLayout, StepStats, WavePolicy,
};
use moe::coordinator::{DispatchPlan, Dispatcher};
use moe::runtime::TensorF;
use moe::util::prop;
use moe::util::rng::Rng;

const TOL: f32 = 1e-5;

fn mk_weights(n: usize, d: usize, h: usize, rng: &mut Rng) -> Vec<ExpertWeights> {
    (0..n)
        .map(|_| ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.3),
            w_out: prop::vec_f32(rng, h * d, 0.3),
            d_model: d,
            hidden: h,
        })
        .collect()
}

/// Random replicas + routing decisions + plan for one case.
fn mk_case(
    rng: &mut Rng,
    d: usize,
    n: usize,
    k: usize,
    replicas: usize,
) -> (Vec<TensorF>, DispatchPlan) {
    let router = Router::flat_native(
        d, n, k,
        prop::vec_f32(rng, d * n, 0.5),
        Some(prop::vec_f32(rng, d * n, 0.3)),
    );
    let xs: Vec<TensorF> = (0..replicas)
        .map(|_| {
            let rows = prop::dim(rng, 1, 12);
            TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
        })
        .collect();
    let mut nrng = rng.fold_in(17);
    let decisions: Vec<_> = xs
        .iter()
        .map(|x| router.route(x, Some(&mut nrng)).unwrap())
        .collect();
    let plan = Dispatcher::plan(&decisions, n);
    (xs, plan)
}

#[test]
fn engine_matches_serial_reference_on_random_workloads() {
    prop::forall("engine == serial", |rng| {
        let d = prop::dim(rng, 2, 10);
        let h = prop::dim(rng, 2, 14);
        let n = prop::dim(rng, 1, 20);
        let k = prop::dim(rng, 1, n.min(4));
        let replicas = prop::dim(rng, 1, 4);
        // deliberately includes devices > experts
        let devices = prop::dim(rng, 1, n + 3);
        let weights = mk_weights(n, d, h, rng);
        let (xs, plan) = mk_case(rng, d, n, k, replicas);
        let refs: Vec<&TensorF> = xs.iter().collect();

        let layout = ShardLayout::new(devices, n);
        let sched = Scheduler::new(layout.clone(), ExpertBackend::Native);
        let (want, ref_stats) =
            sched.execute_serial(&plan, &refs, &weights).unwrap();
        let (got, stats) = sched.execute(&plan, &refs, &weights).unwrap();

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.shape, w.shape);
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "engine {a} vs serial {b}");
            }
        }
        assert_eq!(stats.expert_loads, ref_stats.expert_loads);
        assert_eq!(stats.network_bytes, ref_stats.network_bytes);
        assert_eq!(stats.busiest_shard_tokens, ref_stats.busiest_shard_tokens);
    });
}

#[test]
fn over_capacity_waves_match_unchunked_execution() {
    // a wave capacity smaller than the heaviest expert batch forces
    // multi-wave pipelined execution; the math must not change
    prop::forall("waves exact", |rng| {
        let (d, h) = (6, 8);
        let n = prop::dim(rng, 1, 8);
        let k = prop::dim(rng, 1, n.min(3));
        let devices = prop::dim(rng, 1, 6);
        let weights = mk_weights(n, d, h, rng);
        let (xs, plan) = mk_case(rng, d, n, k, 2);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let layout = ShardLayout::new(devices, n);

        let mut unchunked = ExecutionEngine::start(layout.clone());
        let (want, base_stats) =
            unchunked.execute_native(&plan, &refs, &weights).unwrap();

        let max_load =
            plan.expert_loads().into_iter().max().unwrap_or(0).max(1);
        let cap = prop::dim(rng, 1, max_load);
        let mut chunked =
            ExecutionEngine::with_wave_capacity(layout, Some(cap));
        let (got, stats) =
            chunked.execute_native(&plan, &refs, &weights).unwrap();

        for (g, w) in got.iter().zip(want.iter()) {
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "cap={cap}: {a} vs {b}");
            }
        }
        let want_waves = plan
            .expert_loads()
            .iter()
            .map(|&l| if l == 0 { 0 } else { 1 + (l - 1) / cap })
            .max()
            .unwrap_or(0);
        assert_eq!(stats.waves, want_waves, "cap={cap}");
        if plan.total_routes() > 0 {
            assert!(base_stats.waves == 1);
            assert!(stats.waves >= 1);
        }
    });
}

#[test]
fn combine_is_linear_in_gate_weights() {
    // y[token] = Σ_e g_e · E_e(x): scaling every gate by α must scale
    // the combined output by α, and combine must be additive over
    // expert outputs (eq 1 linearity)
    prop::forall("combine linearity", |rng| {
        let (d, n, k) = (5, 6, 2);
        let (xs, plan) = mk_case(rng, d, n, k, 2);
        let _ = &xs;
        let outs_a: Vec<TensorF> = (0..n)
            .map(|e| {
                let rows = plan.per_expert[e].tokens.len();
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let outs_b: Vec<TensorF> = (0..n)
            .map(|e| {
                let rows = plan.per_expert[e].tokens.len();
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();

        // α-scaled gates
        let alpha = 0.5f32 + rng.uniform() as f32;
        let mut scaled = plan.clone();
        for batch in scaled.per_expert.iter_mut() {
            for g in batch.gates.iter_mut() {
                *g *= alpha;
            }
        }
        let base = Dispatcher::combine(&plan, &outs_a, d);
        let scaled_out = Dispatcher::combine(&scaled, &outs_a, d);
        for (b, s) in base.iter().zip(scaled_out.iter()) {
            for (x, y) in b.data.iter().zip(s.data.iter()) {
                assert!((alpha * x - y).abs() <= TOL * alpha.max(1.0),
                        "{} vs {}", alpha * x, y);
            }
        }

        // additivity over expert outputs
        let sum_outs: Vec<TensorF> = outs_a
            .iter()
            .zip(outs_b.iter())
            .map(|(a, b)| {
                TensorF::new(
                    a.shape.clone(),
                    a.data.iter().zip(b.data.iter()).map(|(x, y)| x + y).collect(),
                )
            })
            .collect();
        let ya = Dispatcher::combine(&plan, &outs_a, d);
        let yb = Dispatcher::combine(&plan, &outs_b, d);
        let ysum = Dispatcher::combine(&plan, &sum_outs, d);
        for ((a, b), s) in ya.iter().zip(yb.iter()).zip(ysum.iter()) {
            for ((x, y), z) in
                a.data.iter().zip(b.data.iter()).zip(s.data.iter()) {
                assert!((x + y - z).abs() <= 1e-4, "{} vs {z}", x + y);
            }
        }
    });
}

#[test]
fn shard_layout_properties() {
    // every expert has exactly one owner, owner(e) < n_devices, and
    // experts_of partitions 0..n_experts — including devices > experts
    prop::forall("shard layout", |rng| {
        let devices = prop::dim(rng, 1, 12);
        let experts = prop::dim(rng, 1, 48);
        let layout = ShardLayout::new(devices, experts);
        let mut owners = vec![usize::MAX; experts];
        for e in 0..experts {
            let o = layout.owner(e);
            assert!(o < devices, "owner({e}) = {o} >= {devices}");
            owners[e] = o;
        }
        let mut covered = vec![0usize; experts];
        for dev in 0..devices {
            for e in layout.experts_of(dev) {
                assert!(e < experts);
                assert_eq!(owners[e], dev);
                covered[e] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "not a partition: {covered:?}");
    });
}

#[test]
fn shard_layout_degenerate_more_devices_than_experts() {
    let layout = ShardLayout::new(8, 3);
    let mut total = 0;
    for dev in 0..8 {
        total += layout.experts_of(dev).len();
    }
    assert_eq!(total, 3, "all experts assigned despite idle devices");
    for e in 0..3 {
        assert!(layout.owner(e) < 8);
    }
}

#[test]
fn native_step_smoke_stats_invariants() {
    // one tiny Native-backend step through the public Scheduler path;
    // asserts the StepStats contract end to end
    let (d, h, n, k, devices) = (16, 32, 8, 2, 3);
    let mut rng = Rng::new(33);
    let weights = mk_weights(n, d, h, &mut rng);
    let router = Router::flat_native(
        d, n, k,
        prop::vec_f32(&mut rng, d * n, 0.5),
        Some(prop::vec_f32(&mut rng, d * n, 0.3)),
    );
    let rows = 256;
    let x = TensorF::new(vec![rows, d], prop::vec_f32(&mut rng, rows * d, 1.0));
    let mut nrng = rng.fold_in(2);
    let dec = router.route(&x, Some(&mut nrng)).unwrap();
    let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
    let layout = ShardLayout::new(devices, n);
    let sched = Scheduler::new(layout.clone(), ExpertBackend::Native);
    let (outs, stats) = sched.execute(&plan, &[&x], &weights).unwrap();

    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, vec![rows, d]);
    assert!(stats.waves >= 1, "waves = {}", stats.waves);
    assert_eq!(stats.network_bytes, plan.network_bytes(d, &layout));
    assert_eq!(
        stats.expert_loads.iter().sum::<usize>(),
        plan.total_routes(),
        "loads must sum to total routes"
    );
    assert_eq!(stats.expert_loads, plan.expert_loads());
    assert_eq!(stats.shard_compute_ns.len(), devices);
    assert_eq!(stats.shard_idle_ns.len(), devices);
    assert!(
        stats.phases.total() > 0,
        "per-phase timings must be populated: {:?}",
        stats.phases
    );
    assert!(
        stats.busiest_shard_tokens
            <= stats.expert_loads.iter().sum::<usize>()
    );
    // every shard's idle is bounded by the compute-phase wall
    for (busy, idle) in
        stats.shard_compute_ns.iter().zip(stats.shard_idle_ns.iter()) {
        assert!(busy + idle >= stats.phases.compute || *idle == 0);
    }
}

/// Serial oracle for the streamed pipeline: route every replica in
/// order with `rng`, build the batch plan, execute on the retained
/// single-threaded reference.
fn serial_oracle(
    router: &Router,
    xs: &[TensorF],
    weights: &[ExpertWeights],
    layout: &ShardLayout,
    mut rng: Option<&mut Rng>,
) -> (Vec<TensorF>, Vec<moe::coordinator::router::RoutingDecision>, DispatchPlan) {
    let refs: Vec<&TensorF> = xs.iter().collect();
    let decisions: Vec<_> = xs
        .iter()
        .map(|x| router.route(x, rng.as_deref_mut()).unwrap())
        .collect();
    let plan = Dispatcher::plan(&decisions, router.n_experts);
    let sched = Scheduler::new(layout.clone(), ExpertBackend::Native);
    let (want, _) = sched.execute_serial(&plan, &refs, weights).unwrap();
    (want, decisions, plan)
}

/// Assert a streamed step equals the serial oracle: outputs within TOL,
/// gate decisions bit-identical, balance sums within reassociation
/// tolerance.
fn assert_streamed_matches(
    s: &moe::coordinator::engine::StreamedStep,
    want: &[TensorF],
    decisions: &[moe::coordinator::router::RoutingDecision],
    plan: &DispatchPlan,
    layout: &ShardLayout,
) {
    assert_eq!(s.outs.len(), want.len());
    for (g, w) in s.outs.iter().zip(want.iter()) {
        assert_eq!(g.shape, w.shape);
        for (a, b) in g.data.iter().zip(w.data.iter()) {
            assert!((a - b).abs() <= TOL, "streamed {a} vs serial {b}");
        }
    }
    assert_eq!(s.decisions.len(), decisions.len());
    for (sd, wd) in s.decisions.iter().zip(decisions.iter()) {
        assert_eq!(sd.per_token.len(), wd.per_token.len());
        for (a, b) in sd.per_token.iter().zip(wd.per_token.iter()) {
            assert_eq!(a.experts, b.experts, "gate selection differs");
            assert_eq!(a.weights, b.weights, "gate weights differ");
        }
        for (a, b) in sd.importance.iter().zip(wd.importance.iter()) {
            assert!((a - b).abs() < 1e-4, "importance {a} vs {b}");
        }
        for (a, b) in sd.load.iter().zip(wd.load.iter()) {
            assert!((a - b).abs() < 1e-3, "load {a} vs {b}");
        }
    }
    assert_eq!(s.stats.expert_loads, plan.expert_loads());
    assert_eq!(
        s.stats.network_bytes,
        plan.network_bytes(want[0].shape[1], layout)
    );
    // the streamed step's finished plan is the oracle plan, exactly
    assert_eq!(s.plan.n_experts, plan.n_experts);
    assert_eq!(s.plan.replica_rows, plan.replica_rows);
    for (a, b) in s.plan.per_expert.iter().zip(plan.per_expert.iter()) {
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.gates, b.gates);
    }
}

#[test]
fn streamed_pipeline_matches_serial_reference() {
    // the tentpole differential: the full streamed step (row-blocked
    // parallel gating -> incremental plan -> waves dispatched as routes
    // land) equals serial route -> plan -> execute, across randomized
    // b/n/k/shard/replica shapes and wave policies
    prop::forall("streamed == serial", |rng| {
        let d = prop::dim(rng, 2, 10);
        let h = prop::dim(rng, 2, 14);
        let n = prop::dim(rng, 1, 20);
        let k = prop::dim(rng, 1, n.min(4));
        let replicas = prop::dim(rng, 1, 4);
        // deliberately includes devices > experts
        let devices = prop::dim(rng, 1, n + 3);
        let weights = mk_weights(n, d, h, rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(rng, d * n, 0.5),
            Some(prop::vec_f32(rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                let rows = prop::dim(rng, 1, 12);
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let layout = ShardLayout::new(devices, n);

        let seed = rng.fold_in(23);
        let mut r1 = seed.clone();
        let (want, decisions, plan) =
            serial_oracle(&router, &xs, &weights, &layout, Some(&mut r1));

        // random wave policy: unchunked, forced multi-wave, or adaptive
        let policy = match rng.below(3) {
            0 => WavePolicy::Fixed(None),
            1 => {
                let max_load =
                    plan.expert_loads().into_iter().max().unwrap_or(0).max(1);
                WavePolicy::Fixed(Some(prop::dim(rng, 1, max_load)))
            }
            _ => WavePolicy::Adaptive(AdaptiveWave::with_bounds(
                prop::dim(rng, 1, 16),
                1,
                64,
            )),
        };
        let mut engine = ExecutionEngine::with_policy(layout, policy);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let mut r2 = seed.clone();
        let s = engine
            .execute_streaming(&router, &refs, &weights, Some(&mut r2))
            .unwrap();
        assert_streamed_matches(&s, &want, &decisions, &plan, &engine.layout);
    });
}

#[test]
fn streamed_pipeline_matches_serial_on_hierarchical_gating() {
    prop::forall("streamed hier == serial", |rng| {
        let d = prop::dim(rng, 2, 8);
        let h = prop::dim(rng, 2, 10);
        let (a, gs) = (prop::dim(rng, 2, 4), prop::dim(rng, 2, 5));
        let n = a * gs;
        let k = prop::dim(rng, 1, 2);
        let devices = prop::dim(rng, 1, 6);
        let replicas = prop::dim(rng, 1, 3);
        let weights = mk_weights(n, d, h, rng);
        let router = Router {
            backend: RouterBackend::Native,
            n_experts: n,
            k,
            groups: a,
            d_model: d,
            w_g: prop::vec_f32(rng, d * a, 0.5),
            w_noise: Some(prop::vec_f32(rng, d * a, 0.3)),
            w_g_sec: Some(prop::vec_f32(rng, d * a * gs, 0.5)),
            w_n_sec: Some(prop::vec_f32(rng, d * a * gs, 0.3)),
        };
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                let rows = prop::dim(rng, 1, 10);
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let layout = ShardLayout::new(devices, n);

        let seed = rng.fold_in(29);
        let mut r1 = seed.clone();
        let (want, decisions, plan) =
            serial_oracle(&router, &xs, &weights, &layout, Some(&mut r1));

        let cap = prop::dim(rng, 1, 8);
        let mut engine = ExecutionEngine::with_wave_capacity(
            layout,
            Some(cap),
        );
        let refs: Vec<&TensorF> = xs.iter().collect();
        let mut r2 = seed.clone();
        let s = engine
            .execute_streaming(&router, &refs, &weights, Some(&mut r2))
            .unwrap();
        assert_streamed_matches(&s, &want, &decisions, &plan, &engine.layout);
    });
}

#[test]
fn streamed_degenerate_all_tokens_one_expert() {
    // every token routed to expert 0 with a tiny wave capacity: the
    // worst-case layout for the pipeline (nothing to overlap until the
    // flush) still must be exact, and must chunk into ceil(load/cap)
    // waves
    let (d, h, n) = (5, 7, 6);
    let mut rng = Rng::new(21);
    let weights = mk_weights(n, d, h, &mut rng);
    // column 0 strongly positive, the rest strongly negative; with
    // all-positive activations expert 0 always wins top-1
    let mut w_g = vec![0f32; d * n];
    for l in 0..d {
        for e in 0..n {
            w_g[l * n + e] = if e == 0 { 10.0 } else { -10.0 };
        }
    }
    let router = Router::flat_native(d, n, 1, w_g, None);
    let xs: Vec<TensorF> = (0..2)
        .map(|_| {
            TensorF::new(
                vec![9, d],
                (0..9 * d).map(|_| rng.normal_f32().abs() + 0.1).collect(),
            )
        })
        .collect();
    let layout = ShardLayout::new(3, n);
    let (want, decisions, plan) =
        serial_oracle(&router, &xs, &weights, &layout, None);
    assert_eq!(plan.expert_loads(), vec![18, 0, 0, 0, 0, 0]);

    let mut engine =
        ExecutionEngine::with_wave_capacity(layout, Some(4));
    let refs: Vec<&TensorF> = xs.iter().collect();
    let s = engine
        .execute_streaming(&router, &refs, &weights, None)
        .unwrap();
    assert_streamed_matches(&s, &want, &decisions, &plan, &engine.layout);
    assert_eq!(s.stats.waves, 5, "ceil(18/4) waves");
}

#[test]
fn overlapped_combine_matches_serial_on_multiwave_multireplica() {
    // the tentpole differential: the dependency-driven executor (per-
    // replica completion records, combine emitted as worker jobs while
    // later replicas still route/compute) must be exact across
    // randomized replica/shard/k shapes with forced multi-wave caps,
    // on both the streamed pipeline and the pre-routed engine path
    prop::forall("overlapped combine == serial", |rng| {
        let d = prop::dim(rng, 2, 8);
        let h = prop::dim(rng, 2, 10);
        let n = prop::dim(rng, 2, 10);
        let k = prop::dim(rng, 1, n.min(3));
        let replicas = prop::dim(rng, 2, 5);
        let devices = prop::dim(rng, 1, n + 2);
        let weights = mk_weights(n, d, h, rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(rng, d * n, 0.5),
            Some(prop::vec_f32(rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                let rows = prop::dim(rng, 2, 16);
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let layout = ShardLayout::new(devices, n);

        let seed = rng.fold_in(41);
        let mut r1 = seed.clone();
        let (want, decisions, plan) =
            serial_oracle(&router, &xs, &weights, &layout, Some(&mut r1));

        // tiny cap => many waves per expert => many chunks per replica
        let cap = prop::dim(rng, 1, 4);
        let mut engine =
            ExecutionEngine::with_wave_capacity(layout.clone(), Some(cap));
        let refs: Vec<&TensorF> = xs.iter().collect();
        let mut r2 = seed.clone();
        let s = engine
            .execute_streaming(&router, &refs, &weights, Some(&mut r2))
            .unwrap();
        assert_streamed_matches(&s, &want, &decisions, &plan, &engine.layout);
        assert!(
            s.stats.combines_overlapped <= replicas,
            "at most one combine per replica"
        );
        let ratio = s.stats.combine_overlap_ratio();
        assert!((0.0..=1.0).contains(&ratio), "ratio {ratio}");

        // the pre-routed engine path runs the same completion-tracked
        // combine machinery
        let (got, stats) = engine.execute_native(&plan, &refs, &weights).unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.shape, w.shape);
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "native {a} vs serial {b}");
            }
        }
        assert!(stats.combines_overlapped <= replicas);
    });
}

#[test]
fn a_replica_combine_completes_before_the_last_expert_wave() {
    // acceptance: on a multi-replica workload at least one replica's
    // combine must finish while later replicas' expert waves are still
    // in flight.  The assertion is timing-dependent, so escalate the
    // workload until it happens (deterministic math either way — the
    // exactness is covered by the differential tests above).
    use moe::harness::workload::SyntheticMoe;

    for (attempt, rows) in [256usize, 512, 1024, 2048, 4096]
        .iter()
        .enumerate()
    {
        let w =
            SyntheticMoe::build(90 + attempt as u64, 32, 64, 8, 2, 4, *rows)
                .unwrap();
        let sched = Scheduler::with_policy(
            ShardLayout::new(4, 8),
            ExpertBackend::Native,
            WavePolicy::Fixed(Some(64)),
        );
        let s = w.run_streamed(&sched, None).unwrap();
        let ratio = s.stats.combine_overlap_ratio();
        assert!((0.0..=1.0).contains(&ratio), "ratio {ratio}");
        if s.stats.combines_overlapped > 0 {
            return; // structural overlap witnessed
        }
    }
    panic!(
        "no replica combine completed before the final expert wave in \
         any attempt"
    );
}

#[test]
fn adaptive_wave_bounds_under_pathological_stats() {
    // satellite: AdaptiveWave::with_bounds must keep the capacity in
    // [min, max] under degenerate telemetry
    let in_bounds = |a: &AdaptiveWave, min: usize, max: usize| {
        let c = a.capacity();
        assert!((min..=max).contains(&c), "cap {c} outside [{min}, {max}]");
    };

    // zero busy time everywhere (e.g. an empty step): no shard computed,
    // so idle reads 0 and the controller only ever grows toward max
    let zero_busy = StepStats {
        shard_compute_ns: vec![0, 0, 0],
        shard_idle_ns: vec![0, 0, 0],
        ..StepStats::default()
    };
    let mut a = AdaptiveWave::with_bounds(32, 8, 128);
    for _ in 0..20 {
        a.observe(&zero_busy);
        in_bounds(&a, 8, 128);
    }
    assert_eq!(a.capacity(), 128, "zero-busy steps saturate at max");

    // every shard structurally idle (busy 0, idle = whole wall): the
    // busy>0 filter leaves nothing, so the capacity must not collapse
    let all_idle = StepStats {
        phases: PhaseNanos { compute: 1_000, ..PhaseNanos::default() },
        shard_compute_ns: vec![0, 0],
        shard_idle_ns: vec![1_000, 1_000],
        ..StepStats::default()
    };
    let mut b = AdaptiveWave::with_bounds(64, 16, 64);
    for _ in 0..10 {
        b.observe(&all_idle);
        in_bounds(&b, 16, 64);
    }
    assert_eq!(b.capacity(), 64, "structural idle must not shrink the cap");

    // single-step oscillation between saturated idle and none: the
    // multiplicative controller ping-pongs but never leaves the bounds
    let hot = StepStats {
        phases: PhaseNanos { compute: 1_000, ..PhaseNanos::default() },
        shard_compute_ns: vec![100, 1_000],
        shard_idle_ns: vec![900, 0],
        ..StepStats::default()
    };
    let calm = StepStats {
        phases: PhaseNanos { compute: 1_000, ..PhaseNanos::default() },
        shard_compute_ns: vec![1_000, 1_000],
        shard_idle_ns: vec![0, 0],
        ..StepStats::default()
    };
    let mut c = AdaptiveWave::with_bounds(16, 16, 32);
    for i in 0..50 {
        c.observe(if i % 2 == 0 { &hot } else { &calm });
        in_bounds(&c, 16, 32);
    }

    // degenerate bounds: min/max clamp their own inputs
    let d = AdaptiveWave::with_bounds(0, 0, 0);
    assert_eq!(d.capacity(), 1, "min is floored at 1");
    let e = AdaptiveWave::with_bounds(500, 64, 16);
    assert_eq!(e.capacity(), 64, "max is lifted to min, start clamped");
}

#[test]
fn adaptive_wave_controller_reacts_to_idle() {
    // both shards busy: shard 0 waits `idle` ns on shard 1
    let mk = |compute: u64, idle: u64| StepStats {
        phases: PhaseNanos { compute, ..PhaseNanos::default() },
        shard_compute_ns: vec![compute - idle, compute],
        shard_idle_ns: vec![idle, 0],
        ..StepStats::default()
    };
    let mut a = AdaptiveWave::with_bounds(64, 16, 256);
    a.observe(&mk(1000, 500)); // 50% idle -> halve
    assert_eq!(a.capacity(), 32);
    a.observe(&mk(1000, 500));
    assert_eq!(a.capacity(), 16);
    a.observe(&mk(1000, 500)); // clamped at min
    assert_eq!(a.capacity(), 16);
    a.observe(&mk(1000, 0)); // idle-free -> grow back
    assert_eq!(a.capacity(), 32);
    a.observe(&mk(1000, 100)); // 10% idle -> hold
    assert_eq!(a.capacity(), 32);
    for _ in 0..10 {
        a.observe(&mk(1000, 0));
    }
    assert_eq!(a.capacity(), 256, "clamped at max");

    // a structurally idle shard (no experts / no tokens this step) is
    // idle at every wave size and must not drag the capacity down
    let structural = StepStats {
        phases: PhaseNanos { compute: 1000, ..PhaseNanos::default() },
        shard_compute_ns: vec![1000, 0],
        shard_idle_ns: vec![0, 1000],
        ..StepStats::default()
    };
    let mut b = AdaptiveWave::with_bounds(64, 16, 256);
    b.observe(&structural);
    assert_eq!(b.capacity(), 128, "structural idle must not shrink cap");
}

#[test]
fn adaptive_engine_stays_exact_across_steps() {
    // the adaptive controller must only ever change *performance*: many
    // consecutive streamed steps, each checked against the serial
    // oracle while the capacity moves
    let (d, h, n) = (6, 8, 6);
    let mut rng = Rng::new(31);
    let weights = mk_weights(n, d, h, &mut rng);
    let layout = ShardLayout::new(2, n);
    let router = Router::flat_native(
        d, n, 2,
        prop::vec_f32(&mut rng, d * n, 0.5),
        Some(prop::vec_f32(&mut rng, d * n, 0.3)),
    );
    let mut engine = ExecutionEngine::with_policy(
        layout.clone(),
        WavePolicy::Adaptive(AdaptiveWave::with_bounds(4, 1, 64)),
    );
    for step in 0..6 {
        let rows = 3 + step;
        let x = TensorF::new(
            vec![rows, d],
            prop::vec_f32(&mut rng, rows * d, 1.0),
        );
        let xs = vec![x];
        let seed = rng.fold_in(50 + step as u64);
        let mut r1 = seed.clone();
        let (want, decisions, plan) =
            serial_oracle(&router, &xs, &weights, &layout, Some(&mut r1));
        let refs: Vec<&TensorF> = xs.iter().collect();
        let mut r2 = seed.clone();
        let s = engine
            .execute_streaming(&router, &refs, &weights, Some(&mut r2))
            .unwrap();
        let cap = engine.wave_capacity().expect("adaptive cap is concrete");
        assert!((1..=64).contains(&cap), "cap {cap} within bounds");
        assert_streamed_matches(&s, &want, &decisions, &plan, &engine.layout);
    }
}

#[test]
fn engine_is_reusable_across_many_steps_and_shapes() {
    // one engine, many plans of different shapes: arenas must never leak
    // state between steps
    let (d, h, n) = (4, 6, 5);
    let mut rng = Rng::new(9);
    let weights = mk_weights(n, d, h, &mut rng);
    let layout = ShardLayout::new(2, n);
    let mut engine = ExecutionEngine::with_wave_capacity(layout.clone(), Some(3));
    let sched = Scheduler::new(layout, ExpertBackend::Native);
    for step in 0..8 {
        let (xs, plan) = mk_case(&mut rng, d, n, 1 + step % 3, 1 + step % 2);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let (want, _) = sched.execute_serial(&plan, &refs, &weights).unwrap();
        let (got, _) = engine.execute_native(&plan, &refs, &weights).unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "step {step}: {a} vs {b}");
            }
        }
    }
}
