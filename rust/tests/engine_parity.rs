//! Differential proof that the persistent [`ExecutionEngine`] computes
//! exactly the same MoE step as the retained serial reference path.
//!
//! None of these tests need artifacts: the Native backend exercises the
//! whole engine — persistent workers, arena reuse, wave chunking and the
//! gather/compute/combine pipeline — against pure-rust oracles, across
//! `util::prop::forall` randomized cases (replica counts, shard counts,
//! k, degenerate layouts, over-capacity waves).

use moe::coordinator::engine::ExecutionEngine;
use moe::coordinator::router::Router;
use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout,
};
use moe::coordinator::{DispatchPlan, Dispatcher};
use moe::runtime::TensorF;
use moe::util::prop;
use moe::util::rng::Rng;

const TOL: f32 = 1e-5;

fn mk_weights(n: usize, d: usize, h: usize, rng: &mut Rng) -> Vec<ExpertWeights> {
    (0..n)
        .map(|_| ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.3),
            w_out: prop::vec_f32(rng, h * d, 0.3),
            d_model: d,
            hidden: h,
        })
        .collect()
}

/// Random replicas + routing decisions + plan for one case.
fn mk_case(
    rng: &mut Rng,
    d: usize,
    n: usize,
    k: usize,
    replicas: usize,
) -> (Vec<TensorF>, DispatchPlan) {
    let router = Router::flat_native(
        d, n, k,
        prop::vec_f32(rng, d * n, 0.5),
        Some(prop::vec_f32(rng, d * n, 0.3)),
    );
    let xs: Vec<TensorF> = (0..replicas)
        .map(|_| {
            let rows = prop::dim(rng, 1, 12);
            TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
        })
        .collect();
    let mut nrng = rng.fold_in(17);
    let decisions: Vec<_> = xs
        .iter()
        .map(|x| router.route(x, Some(&mut nrng)).unwrap())
        .collect();
    let plan = Dispatcher::plan(&decisions, n);
    (xs, plan)
}

#[test]
fn engine_matches_serial_reference_on_random_workloads() {
    prop::forall("engine == serial", |rng| {
        let d = prop::dim(rng, 2, 10);
        let h = prop::dim(rng, 2, 14);
        let n = prop::dim(rng, 1, 20);
        let k = prop::dim(rng, 1, n.min(4));
        let replicas = prop::dim(rng, 1, 4);
        // deliberately includes devices > experts
        let devices = prop::dim(rng, 1, n + 3);
        let weights = mk_weights(n, d, h, rng);
        let (xs, plan) = mk_case(rng, d, n, k, replicas);
        let refs: Vec<&TensorF> = xs.iter().collect();

        let layout = ShardLayout::new(devices, n);
        let sched = Scheduler::new(layout.clone(), ExpertBackend::Native);
        let (want, ref_stats) =
            sched.execute_serial(&plan, &refs, &weights).unwrap();
        let (got, stats) = sched.execute(&plan, &refs, &weights).unwrap();

        assert_eq!(got.len(), want.len());
        for (g, w) in got.iter().zip(want.iter()) {
            assert_eq!(g.shape, w.shape);
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "engine {a} vs serial {b}");
            }
        }
        assert_eq!(stats.expert_loads, ref_stats.expert_loads);
        assert_eq!(stats.network_bytes, ref_stats.network_bytes);
        assert_eq!(stats.busiest_shard_tokens, ref_stats.busiest_shard_tokens);
    });
}

#[test]
fn over_capacity_waves_match_unchunked_execution() {
    // a wave capacity smaller than the heaviest expert batch forces
    // multi-wave pipelined execution; the math must not change
    prop::forall("waves exact", |rng| {
        let (d, h) = (6, 8);
        let n = prop::dim(rng, 1, 8);
        let k = prop::dim(rng, 1, n.min(3));
        let devices = prop::dim(rng, 1, 6);
        let weights = mk_weights(n, d, h, rng);
        let (xs, plan) = mk_case(rng, d, n, k, 2);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let layout = ShardLayout::new(devices, n);

        let mut unchunked = ExecutionEngine::start(layout.clone());
        let (want, base_stats) =
            unchunked.execute_native(&plan, &refs, &weights).unwrap();

        let max_load =
            plan.expert_loads().into_iter().max().unwrap_or(0).max(1);
        let cap = prop::dim(rng, 1, max_load);
        let mut chunked =
            ExecutionEngine::with_wave_capacity(layout, Some(cap));
        let (got, stats) =
            chunked.execute_native(&plan, &refs, &weights).unwrap();

        for (g, w) in got.iter().zip(want.iter()) {
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "cap={cap}: {a} vs {b}");
            }
        }
        let want_waves = plan
            .expert_loads()
            .iter()
            .map(|&l| if l == 0 { 0 } else { 1 + (l - 1) / cap })
            .max()
            .unwrap_or(0);
        assert_eq!(stats.waves, want_waves, "cap={cap}");
        if plan.total_routes() > 0 {
            assert!(base_stats.waves == 1);
            assert!(stats.waves >= 1);
        }
    });
}

#[test]
fn combine_is_linear_in_gate_weights() {
    // y[token] = Σ_e g_e · E_e(x): scaling every gate by α must scale
    // the combined output by α, and combine must be additive over
    // expert outputs (eq 1 linearity)
    prop::forall("combine linearity", |rng| {
        let (d, n, k) = (5, 6, 2);
        let (xs, plan) = mk_case(rng, d, n, k, 2);
        let _ = &xs;
        let outs_a: Vec<TensorF> = (0..n)
            .map(|e| {
                let rows = plan.per_expert[e].tokens.len();
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let outs_b: Vec<TensorF> = (0..n)
            .map(|e| {
                let rows = plan.per_expert[e].tokens.len();
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();

        // α-scaled gates
        let alpha = 0.5f32 + rng.uniform() as f32;
        let mut scaled = plan.clone();
        for batch in scaled.per_expert.iter_mut() {
            for g in batch.gates.iter_mut() {
                *g *= alpha;
            }
        }
        let base = Dispatcher::combine(&plan, &outs_a, d);
        let scaled_out = Dispatcher::combine(&scaled, &outs_a, d);
        for (b, s) in base.iter().zip(scaled_out.iter()) {
            for (x, y) in b.data.iter().zip(s.data.iter()) {
                assert!((alpha * x - y).abs() <= TOL * alpha.max(1.0),
                        "{} vs {}", alpha * x, y);
            }
        }

        // additivity over expert outputs
        let sum_outs: Vec<TensorF> = outs_a
            .iter()
            .zip(outs_b.iter())
            .map(|(a, b)| {
                TensorF::new(
                    a.shape.clone(),
                    a.data.iter().zip(b.data.iter()).map(|(x, y)| x + y).collect(),
                )
            })
            .collect();
        let ya = Dispatcher::combine(&plan, &outs_a, d);
        let yb = Dispatcher::combine(&plan, &outs_b, d);
        let ysum = Dispatcher::combine(&plan, &sum_outs, d);
        for ((a, b), s) in ya.iter().zip(yb.iter()).zip(ysum.iter()) {
            for ((x, y), z) in
                a.data.iter().zip(b.data.iter()).zip(s.data.iter()) {
                assert!((x + y - z).abs() <= 1e-4, "{} vs {z}", x + y);
            }
        }
    });
}

#[test]
fn shard_layout_properties() {
    // every expert has exactly one owner, owner(e) < n_devices, and
    // experts_of partitions 0..n_experts — including devices > experts
    prop::forall("shard layout", |rng| {
        let devices = prop::dim(rng, 1, 12);
        let experts = prop::dim(rng, 1, 48);
        let layout = ShardLayout::new(devices, experts);
        let mut owners = vec![usize::MAX; experts];
        for e in 0..experts {
            let o = layout.owner(e);
            assert!(o < devices, "owner({e}) = {o} >= {devices}");
            owners[e] = o;
        }
        let mut covered = vec![0usize; experts];
        for dev in 0..devices {
            for e in layout.experts_of(dev) {
                assert!(e < experts);
                assert_eq!(owners[e], dev);
                covered[e] += 1;
            }
        }
        assert!(covered.iter().all(|&c| c == 1), "not a partition: {covered:?}");
    });
}

#[test]
fn shard_layout_degenerate_more_devices_than_experts() {
    let layout = ShardLayout::new(8, 3);
    let mut total = 0;
    for dev in 0..8 {
        total += layout.experts_of(dev).len();
    }
    assert_eq!(total, 3, "all experts assigned despite idle devices");
    for e in 0..3 {
        assert!(layout.owner(e) < 8);
    }
}

#[test]
fn native_step_smoke_stats_invariants() {
    // one tiny Native-backend step through the public Scheduler path;
    // asserts the StepStats contract end to end
    let (d, h, n, k, devices) = (16, 32, 8, 2, 3);
    let mut rng = Rng::new(33);
    let weights = mk_weights(n, d, h, &mut rng);
    let router = Router::flat_native(
        d, n, k,
        prop::vec_f32(&mut rng, d * n, 0.5),
        Some(prop::vec_f32(&mut rng, d * n, 0.3)),
    );
    let rows = 256;
    let x = TensorF::new(vec![rows, d], prop::vec_f32(&mut rng, rows * d, 1.0));
    let mut nrng = rng.fold_in(2);
    let dec = router.route(&x, Some(&mut nrng)).unwrap();
    let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
    let sched = Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native);
    let (outs, stats) = sched.execute(&plan, &[&x], &weights).unwrap();

    assert_eq!(outs.len(), 1);
    assert_eq!(outs[0].shape, vec![rows, d]);
    assert!(stats.waves >= 1, "waves = {}", stats.waves);
    assert_eq!(stats.network_bytes, plan.network_bytes(d));
    assert_eq!(
        stats.expert_loads.iter().sum::<usize>(),
        plan.total_routes(),
        "loads must sum to total routes"
    );
    assert_eq!(stats.expert_loads, plan.expert_loads());
    assert_eq!(stats.shard_compute_ns.len(), devices);
    assert_eq!(stats.shard_idle_ns.len(), devices);
    assert!(
        stats.phases.total() > 0,
        "per-phase timings must be populated: {:?}",
        stats.phases
    );
    assert!(
        stats.busiest_shard_tokens
            <= stats.expert_loads.iter().sum::<usize>()
    );
    // every shard's idle is bounded by the compute-phase wall
    for (busy, idle) in
        stats.shard_compute_ns.iter().zip(stats.shard_idle_ns.iter()) {
        assert!(busy + idle >= stats.phases.compute || *idle == 0);
    }
}

#[test]
fn engine_is_reusable_across_many_steps_and_shapes() {
    // one engine, many plans of different shapes: arenas must never leak
    // state between steps
    let (d, h, n) = (4, 6, 5);
    let mut rng = Rng::new(9);
    let weights = mk_weights(n, d, h, &mut rng);
    let layout = ShardLayout::new(2, n);
    let mut engine = ExecutionEngine::with_wave_capacity(layout.clone(), Some(3));
    let sched = Scheduler::new(layout, ExpertBackend::Native);
    for step in 0..8 {
        let (xs, plan) = mk_case(&mut rng, d, n, 1 + step % 3, 1 + step % 2);
        let refs: Vec<&TensorF> = xs.iter().collect();
        let (want, _) = sched.execute_serial(&plan, &refs, &weights).unwrap();
        let (got, _) = engine.execute_native(&plan, &refs, &weights).unwrap();
        for (g, w) in got.iter().zip(want.iter()) {
            for (a, b) in g.data.iter().zip(w.data.iter()) {
                assert!((a - b).abs() <= TOL, "step {step}: {a} vs {b}");
            }
        }
    }
}
