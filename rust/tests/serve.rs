//! Differential proof of serve-path correctness, plus observable
//! backpressure.
//!
//! The serving runtime coalesces ragged requests into shared engine
//! batches, so the load-bearing property is **isolation**: what a
//! request gets back must not depend on who it shared a batch with.
//! The oracle is the retained single-threaded reference path — every
//! request routed alone (deterministic, no gate noise) through
//! `Dispatcher::plan` + `Scheduler::execute_serial` — and the serve
//! outputs must match it **bit for bit**.
//!
//! Backpressure: at offered load above engine throughput the queue
//! must stay depth-bounded and every drop must be counted in
//! `ServeStats::shed` (asserted for both admission policies).

use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout,
};
use moe::coordinator::{Dispatcher, Router};
use moe::harness::workload::{poisson_trace, trace_requests, TraceSpec};
use moe::runtime::TensorF;
use moe::serve::{AdmissionPolicy, ServeConfig, ServeLoop, TimedRequest};
use moe::util::prop;
use moe::util::rng::Rng;

struct Frozen {
    d: usize,
    n: usize,
    w_g: Vec<f32>,
    w_noise: Vec<f32>,
    weights: Vec<ExpertWeights>,
}

impl Frozen {
    fn build(seed: u64, d: usize, h: usize, n: usize) -> Self {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(&mut rng, d * h, 0.3),
                w_out: prop::vec_f32(&mut rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect();
        Frozen {
            d,
            n,
            w_g: prop::vec_f32(&mut rng, d * n, 0.5),
            w_noise: prop::vec_f32(&mut rng, d * n, 0.3),
            weights,
        }
    }

    /// Routers are not Clone (they may hold artifact handles); rebuild
    /// an identical Native router from the frozen gating weights.
    fn router(&self, k: usize) -> Router {
        Router::flat_native(
            self.d,
            self.n,
            k,
            self.w_g.clone(),
            Some(self.w_noise.clone()),
        )
    }
}

#[test]
fn serve_outputs_are_bit_identical_to_the_serial_oracle_per_request() {
    let (d, h, n, k) = (8, 12, 6, 2);
    let frozen = Frozen::build(41, d, h, n);
    // a trace dense enough that batches genuinely coalesce requests
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 77,
            rate_per_sec: 50_000.0,
            n_requests: 37,
            min_rows: 1,
            max_rows: 7,
            bursty: true,
        }),
        d,
        99,
    );
    let serve = ServeLoop::new(
        Scheduler::new(ShardLayout::new(3, n), ExpertBackend::Native),
        frozen.router(k),
        frozen.weights.clone(),
        ServeConfig {
            queue_depth: 64, // ample: nothing may shed in this test
            max_batch_tokens: 16,
            latency_budget_ns: 200_000,
            capture_outputs: true,
            ..Default::default()
        },
    )
    .unwrap();
    let report = serve.run_trace(&trace).unwrap();
    assert_eq!(report.stats.shed, 0, "sheds would break the differential");
    assert_eq!(report.stats.completed as usize, trace.len());
    assert!(
        (report.stats.batches as usize) < trace.len(),
        "micro-batching never coalesced; the differential is vacuous"
    );

    let oracle_router = frozen.router(k);
    let oracle =
        Scheduler::new(ShardLayout::new(3, n), ExpertBackend::Native);
    for (i, req) in trace.iter().enumerate() {
        let dec = oracle_router.route(&req.x, None).unwrap();
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
        let (outs, _) = oracle
            .execute_serial(&plan, &[&req.x], &frozen.weights)
            .unwrap();
        let got = report.outputs[i].as_ref().expect("request was served");
        assert_eq!(got.shape, outs[0].shape, "request {i} shape");
        assert_eq!(
            got.data, outs[0].data,
            "request {i}: serve output != serial oracle (bitwise)"
        );
    }
}

#[test]
fn overload_sheds_are_counted_and_memory_stays_bounded() {
    let (d, h, n, k) = (6, 8, 4, 2);
    let frozen = Frozen::build(5, d, h, n);
    // 40 requests all due at t=0: offered load is far above anything the
    // engine can drain before admission, whatever the hardware
    let mut rng = Rng::new(13);
    let burst: Vec<TimedRequest> = (0..40)
        .map(|_| TimedRequest {
            arrival_ns: 0,
            x: TensorF::new(vec![2, d], prop::vec_f32(&mut rng, 2 * d, 1.0)),
        })
        .collect();

    for policy in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
        let serve = ServeLoop::new(
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
            frozen.router(k),
            frozen.weights.clone(),
            ServeConfig {
                queue_depth: 8,
                policy,
                max_batch_tokens: 8,
                latency_budget_ns: 1_000,
                capture_outputs: true,
                ..Default::default()
            },
        )
        .unwrap();
        let report = serve.run_trace(&burst).unwrap();
        assert_eq!(
            report.stats.shed, 32,
            "{policy:?}: 40 offered into a depth-8 queue must shed 32"
        );
        assert_eq!(report.stats.completed, 8, "{policy:?}");
        assert!(
            report.stats.peak_queue_depth <= 8,
            "{policy:?}: queue depth exceeded its bound"
        );
        let served: Vec<usize> = report
            .outputs
            .iter()
            .enumerate()
            .filter_map(|(i, o)| o.as_ref().map(|_| i))
            .collect();
        match policy {
            // reject keeps the first-admitted 8
            AdmissionPolicy::Reject => {
                assert_eq!(served, (0..8).collect::<Vec<_>>())
            }
            // shed-oldest keeps the freshest 8
            AdmissionPolicy::ShedOldest => {
                assert_eq!(served, (32..40).collect::<Vec<_>>())
            }
        }
    }
}

#[test]
fn latency_budget_flushes_partial_batches() {
    let (d, h, n, k) = (6, 8, 4, 1);
    let frozen = Frozen::build(21, d, h, n);
    // arrivals 10ms apart with a 1ms budget and a huge batch cap: every
    // request must ship in its own deadline-flushed batch
    let trace: Vec<TimedRequest> = (0..5)
        .map(|i| TimedRequest {
            arrival_ns: i * 10_000_000,
            x: TensorF::new(vec![3, d], vec![0.1; 3 * d]),
        })
        .collect();
    let serve = ServeLoop::new(
        Scheduler::new(ShardLayout::new(1, n), ExpertBackend::Native),
        frozen.router(k),
        frozen.weights.clone(),
        ServeConfig {
            queue_depth: 16,
            max_batch_tokens: 4096,
            latency_budget_ns: 1_000_000,
            ..Default::default()
        },
    )
    .unwrap();
    let report = serve.run_trace(&trace).unwrap();
    assert_eq!(report.stats.completed, 5);
    assert_eq!(report.stats.shed, 0);
    // each batch waits out the 1ms budget before flushing (unless the
    // engine step itself ran past the next arrival), so queue-wait is
    // bounded by the budget and at least one batch waited the full slack
    assert!(report.stats.queue_wait.max_ns() >= 1_000_000);
    assert!(report.stats.batches >= 2, "arrivals 10ms apart cannot all coalesce");
}

#[test]
fn admission_accounting_is_conserved_across_random_traces() {
    // the serve loop drains its queue before returning, so over any
    // trace: offered == completed + shed, outputs agree with the
    // completion count, and nothing is double-counted — under both
    // admission policies, across randomized traces and configs
    let (d, h, n, k) = (5, 7, 4, 2);
    let frozen = Frozen::build(61, d, h, n);
    for case in 0..10u64 {
        let rng = &mut prop::case_rng(5000 + case);
        let n_requests = prop::dim(rng, 5, 40);
        let trace = trace_requests(
            &poisson_trace(&TraceSpec {
                seed: 100 + case,
                rate_per_sec: 1_000.0 * (1 + rng.below(200)) as f64,
                n_requests,
                min_rows: 1,
                max_rows: prop::dim(rng, 1, 6),
                bursty: rng.below(2) == 1,
            }),
            d,
            999 + case,
        );
        let queue_depth = prop::dim(rng, 1, 8);
        let max_batch_tokens = prop::dim(rng, 2, 12);
        let latency_budget_ns = 50_000 * (1 + rng.below(40)) as u64;
        for policy in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
            let serve = ServeLoop::new(
                Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
                frozen.router(k),
                frozen.weights.clone(),
                ServeConfig {
                    queue_depth,
                    policy,
                    max_batch_tokens,
                    latency_budget_ns,
                    capture_outputs: true,
                    ..Default::default()
                },
            )
            .unwrap();
            let report = serve.run_trace(&trace).unwrap();
            assert_eq!(
                report.stats.offered,
                trace.len() as u64,
                "case {case} {policy:?}: every trace entry is offered once"
            );
            assert_eq!(
                report.stats.completed + report.stats.shed
                    + report.stats.failed,
                report.stats.offered,
                "case {case} {policy:?}: requests leaked or double-counted \
                 (completed {} + shed {} + failed {} != offered {})",
                report.stats.completed,
                report.stats.shed,
                report.stats.failed,
                report.stats.offered
            );
            assert_eq!(
                report.stats.failed, 0,
                "case {case} {policy:?}: no faults injected, nothing fails"
            );
            assert_eq!(
                report.stats.slo_violations, 0,
                "case {case} {policy:?}: no deadline configured, no SLO \
                 violations"
            );
            let served = report.outputs.iter().filter(|o| o.is_some()).count();
            assert_eq!(
                served as u64, report.stats.completed,
                "case {case} {policy:?}: outputs disagree with completions"
            );
            // every completed request's tokens are accounted
            let served_tokens: usize = report
                .outputs
                .iter()
                .flatten()
                .map(|t| t.shape[0])
                .sum();
            assert_eq!(
                served_tokens as u64, report.stats.tokens_served,
                "case {case} {policy:?}: token accounting drifted"
            );
        }
    }
}

#[test]
fn deadline_violations_are_counted_among_completions() {
    // with a latency SLO configured, every delivered request that beat
    // its deadline counts once in completed only, and every delivered
    // request past it also counts once in slo_violations — while the
    // admission ledger keeps conserving.  A 1ns deadline makes every
    // completion a violation; the first arrivals still complete because
    // the feasibility check has no throughput estimate yet.
    let (d, h, n, k) = (5, 7, 4, 2);
    let frozen = Frozen::build(67, d, h, n);
    let trace = trace_requests(
        &poisson_trace(&TraceSpec {
            seed: 71,
            rate_per_sec: 20_000.0,
            n_requests: 24,
            min_rows: 1,
            max_rows: 4,
            bursty: false,
        }),
        d,
        73,
    );
    let run = |deadline_ns: Option<u64>| {
        let serve = ServeLoop::new(
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native),
            frozen.router(k),
            frozen.weights.clone(),
            ServeConfig {
                queue_depth: 32,
                max_batch_tokens: 8,
                latency_budget_ns: 100_000,
                deadline_ns,
                ..Default::default()
            },
        )
        .unwrap();
        serve.run_trace(&trace).unwrap().stats
    };
    // generous SLO: everything completes, nothing violates
    let lax = run(Some(u64::MAX / 2));
    assert_eq!(lax.offered, trace.len() as u64);
    assert_eq!(lax.completed + lax.shed + lax.failed, lax.offered);
    assert_eq!(lax.slo_violations, 0, "an unreachable deadline never trips");
    // impossible SLO: whatever completes (measured latency > 1ns always)
    // is a violation, and the up-front feasibility shed handles the rest
    let tight = run(Some(1));
    assert_eq!(tight.offered, trace.len() as u64);
    assert_eq!(tight.completed + tight.shed + tight.failed, tight.offered);
    assert!(tight.completed > 0, "first arrivals beat the estimator");
    assert_eq!(
        tight.slo_violations, tight.completed,
        "every completion past a 1ns deadline is a violation"
    );
    assert!(
        tight.slo_violations <= tight.completed,
        "violations are a subset of completions"
    );
}

#[test]
fn queue_conservation_under_random_offer_pop_interleavings() {
    // the queue-level invariant behind the loop-level one: at every
    // instant, admitted == popped + shed + still-queued, under random
    // interleavings of offers and pops for both policies
    use moe::serve::{RequestQueue, ServeRequest};
    for policy in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest] {
        prop::forall("queue conservation", |rng| {
            let depth = prop::dim(rng, 1, 6);
            let mut q = RequestQueue::new(depth, policy);
            let mut offered = 0u64;
            let mut popped = 0u64;
            for step in 0..prop::dim(rng, 1, 60) {
                if rng.below(3) < 2 {
                    if q.will_reject_next() {
                        q.reject_next();
                    } else {
                        q.offer(ServeRequest {
                            id: step,
                            arrival_ns: step as u64,
                            x: TensorF::zeros(vec![1, 2]),
                        });
                    }
                    offered += 1;
                } else if q.pop().is_some() {
                    popped += 1;
                }
                assert!(q.len() <= depth, "{policy:?}: depth bound broken");
                assert_eq!(
                    offered,
                    popped + q.shed() + q.len() as u64,
                    "{policy:?}: conservation broken at step {step}"
                );
            }
        });
    }
}
