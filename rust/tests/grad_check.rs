//! Finite-difference proof of the native backward pass.
//!
//! Every analytic gradient of the streamed trainer —
//! `train::streamed_backward`: task MSE through the eq-1 combine, the
//! expert FFNs, the noisy top-k softmax into `w_g`/`w_noise` (and the
//! hierarchical secondaries), the eq-6/7 importance loss, and the eq-8
//! smooth load loss through the normal-CDF estimator *including its
//! threshold term* — is checked against central finite differences of
//! an independent **f64 frozen-branch oracle**.
//!
//! "Frozen branch" is the load-bearing idea: top-k selection, the
//! eq-10 threshold indices/membership, and the relu masks are all
//! piecewise-constant, so the analytic gradient is the gradient of the
//! *active branch*.  The oracle freezes those structures at the base
//! point (taken from the production forward's retained decisions) and
//! evaluates the loss in f64, which makes the finite differences exact
//! for that branch — even at deliberate duplicate-top-k ties, where a
//! naive FD would step across the selection boundary.  The f64
//! evaluation is what makes the 1e-4 relative tolerance honest: an f32
//! loss would bury the quotient in rounding noise.
//!
//! Checked over randomized shapes (k, experts, hierarchical vs flat,
//! noise on/off, duplicate ties), via `util::prop::grad_check`.  The
//! same file carries the seed-determinism guard for the
//! pre-drawn-noise contract and the end-to-end acceptance run: with
//! the balance losses on, per-step balance CVs fall below the
//! frozen-gating baseline while the task loss stays no worse.

use moe::coordinator::router::RouterBackend;
use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout,
};
use moe::coordinator::{Router, StreamedStep};
use moe::gating::erf;
use moe::gating::noisy_topk::noisy_topk_block;
use moe::runtime::{ModelConfig, TensorF};
use moe::train::{streamed_backward, StreamedStepOptions, Trainer};
use moe::util::prop;
use moe::util::rng::Rng;

// ---------------------------------------------------------------------
// f64 mirrors of the forward math (same branch structure as the f32
// production code, so base-point values agree to f32 precision)

fn softplus64(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

fn phi64(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn cv2_64(v: &[f64]) -> f64 {
    if v.len() <= 1 {
        return 0.0;
    }
    let n = v.len() as f64;
    let mean = v.iter().sum::<f64>() / n;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    var / (mean * mean + 1e-10)
}

/// softmax over the given values (f64, max-shifted like the forward).
fn softmax64(vals: &[f64]) -> Vec<f64> {
    let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = vals.iter().map(|v| (v - max).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

/// `x_row · w[:, j]` for row-major w (d, n), all f64.
fn dot_col64(x: &[f64], w: &[f32], d: usize, n: usize, j: usize) -> f64 {
    (0..d).map(|l| x[l] * w[l * n + j] as f64).sum()
}

// ---------------------------------------------------------------------
// the model under test and its frozen branch structure

#[derive(Clone)]
struct Params {
    w_g: Vec<f32>,
    w_noise: Option<Vec<f32>>,
    w_g_sec: Option<Vec<f32>>,
    w_n_sec: Option<Vec<f32>>,
    experts: Vec<ExpertWeights>,
}

struct Model {
    d: usize,
    n: usize,
    k: usize,
    /// 0 = flat
    groups: usize,
    gs: usize,
    w_importance: f64,
    w_load: f64,
}

/// Everything piecewise-constant, captured at the base point.
struct Frozen {
    /// [replica][token] selected (composed) experts, forward slot order
    sel: Vec<Vec<Vec<usize>>>,
    /// hierarchical: [replica][token] primary groups per slot
    pri: Vec<Vec<Vec<usize>>>,
    /// hierarchical: [replica][token][primary slot] secondary picks
    sec: Vec<Vec<Vec<Vec<usize>>>>,
    /// flat smooth load: [replica][token] (k-th, k+1-th) competitor
    /// indices under the forward's rank rule
    thr: Vec<Vec<(usize, usize)>>,
    /// flat smooth load: [replica][token][expert] in-top-k by value
    member: Vec<Vec<Vec<bool>>>,
    /// [replica][token][slot][hidden unit] relu mask of the selected
    /// expert's preactivation (f32 sign, matching the backward)
    relu: Vec<Vec<Vec<Vec<bool>>>>,
    load_on: bool,
}

struct Inputs {
    xs: Vec<Vec<f64>>,
    targets: Vec<Vec<f64>>,
    rows: Vec<usize>,
    eps_pri: Vec<Option<Vec<f64>>>,
    eps_sec: Vec<Option<Vec<f64>>>,
    n_el: usize,
}

/// Rank order of the forward (`select_topk` / `topk_softmax_via_sort`):
/// descending value, ties to the lower index.
fn rank_order_f32(h: &[f32]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..h.len()).collect();
    idx.sort_by(|&a, &b| {
        h[b].partial_cmp(&h[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx
}

/// Capture the frozen branch structure at the base point from the
/// production forward's retained decisions (+ f32 recomputes that are
/// bit-identical to the backward's own).
fn freeze(
    m: &Model,
    p: &Params,
    xs: &[TensorF],
    s: &StreamedStep,
    load_on: bool,
) -> Frozen {
    let (d, n) = (m.d, m.n);
    let k2 = m.k.min(m.gs.max(1));
    let mut fr = Frozen {
        sel: Vec::new(),
        pri: Vec::new(),
        sec: Vec::new(),
        thr: Vec::new(),
        member: Vec::new(),
        relu: Vec::new(),
        load_on,
    };
    for (r, dec) in s.decisions.iter().enumerate() {
        let x = &xs[r];
        let b = x.shape[0];
        let mut sel_r = Vec::with_capacity(b);
        let mut pri_r = Vec::new();
        let mut sec_r = Vec::new();
        let mut relu_r = Vec::with_capacity(b);
        for (t, tok) in dec.per_token.iter().enumerate() {
            sel_r.push(tok.experts.clone());
            if m.groups > 0 {
                let pri: Vec<usize> = (0..tok.experts.len() / k2)
                    .map(|si| tok.experts[si * k2] / m.gs)
                    .collect();
                let sec: Vec<Vec<usize>> = (0..pri.len())
                    .map(|si| {
                        (0..k2)
                            .map(|sj| tok.experts[si * k2 + sj] % m.gs)
                            .collect()
                    })
                    .collect();
                pri_r.push(pri);
                sec_r.push(sec);
            }
            // relu masks: f32 preactivations in the same l-increasing
            // reduction order as the production matmul (bit-identical)
            let xrow = &x.data[t * d..(t + 1) * d];
            let mut relu_t = Vec::with_capacity(tok.experts.len());
            for &e in &tok.experts {
                let w = &p.experts[e];
                let h = w.hidden;
                let mut mask = vec![false; h];
                for (j, mk) in mask.iter_mut().enumerate() {
                    let mut acc = 0f32;
                    for (l, &xv) in xrow.iter().enumerate() {
                        acc += xv * w.w_in[l * h + j];
                    }
                    *mk = acc > 0.0;
                }
                relu_t.push(mask);
            }
            relu_r.push(relu_t);
        }
        fr.sel.push(sel_r);
        fr.pri.push(pri_r);
        fr.sec.push(sec_r);
        fr.relu.push(relu_r);

        // flat load thresholds from the f32 noisy logits, recomputed
        // exactly as the backward recomputes them
        if load_on {
            let eps = dec
                .noise
                .as_ref()
                .map(|ns| ns.primary.as_slice())
                .expect("load-on freeze needs retained noise");
            let g = noisy_topk_block(
                &x.data,
                b,
                d,
                &p.w_g,
                p.w_noise.as_deref(),
                n,
                m.k,
                Some(eps),
            );
            let mut thr_r = Vec::with_capacity(b);
            let mut mem_r = Vec::with_capacity(b);
            for t in 0..b {
                let noisy = &g.noisy[t * n..(t + 1) * n];
                let order = rank_order_f32(noisy);
                let (jk, jk1) = (order[m.k - 1], order[m.k]);
                let kth = noisy[jk];
                thr_r.push((jk, jk1));
                mem_r.push((0..n).map(|i| noisy[i] >= kth).collect());
            }
            fr.thr.push(thr_r);
            fr.member.push(mem_r);
        } else {
            fr.thr.push(Vec::new());
            fr.member.push(Vec::new());
        }
    }
    fr
}

/// The frozen-branch loss in f64: MSE + w_imp·CV²(Importance)
/// (+ w_load·CV²(Load) through the smooth estimator when `load_on`).
fn frozen_loss(m: &Model, inp: &Inputs, fr: &Frozen, p: &Params) -> f64 {
    let (d, n) = (m.d, m.n);
    let n_pri = if m.groups > 0 { m.groups } else { n };
    let mut mse = 0.0f64;
    let mut imp = vec![0.0f64; n];
    let mut load = vec![0.0f64; n];
    for (r, x) in inp.xs.iter().enumerate() {
        let b = inp.rows[r];
        let eps = inp.eps_pri[r].as_deref();
        for t in 0..b {
            let xrow = &x[t * d..(t + 1) * d];
            // primary (or flat) logits of this row
            let mut clean = vec![0.0f64; n_pri];
            let mut raw = vec![0.0f64; n_pri];
            let mut noisy = vec![0.0f64; n_pri];
            for j in 0..n_pri {
                clean[j] = dot_col64(xrow, &p.w_g, d, n_pri, j);
                noisy[j] = clean[j];
                if let (Some(wn), Some(eps)) = (p.w_noise.as_deref(), eps) {
                    raw[j] = dot_col64(xrow, wn, d, n_pri, j);
                    noisy[j] +=
                        eps[t * n_pri + j] * softplus64(raw[j]);
                }
            }
            // gates over the frozen selection
            let gates: Vec<f64> = if m.groups == 0 {
                let sel = &fr.sel[r][t];
                let vals: Vec<f64> = sel.iter().map(|&e| noisy[e]).collect();
                softmax64(&vals)
            } else {
                let pri = &fr.pri[r][t];
                let pvals: Vec<f64> = pri.iter().map(|&g| noisy[g]).collect();
                let pg = softmax64(&pvals);
                let eps_sec = inp.eps_sec[r].as_deref();
                let mut composed = Vec::new();
                for (si, (&gi, &pw)) in
                    pri.iter().zip(pg.iter()).enumerate()
                {
                    // this slot's secondary logits over the full group
                    let mut h = vec![0.0f64; m.gs];
                    for (j, hv) in h.iter_mut().enumerate() {
                        *hv = (0..d)
                            .map(|l| {
                                xrow[l]
                                    * p.w_g_sec.as_ref().unwrap()
                                        [l * m.groups * m.gs + gi * m.gs + j]
                                        as f64
                            })
                            .sum();
                        if let (Some(wn), Some(eps)) =
                            (p.w_n_sec.as_deref(), eps_sec)
                        {
                            let rawj: f64 = (0..d)
                                .map(|l| {
                                    xrow[l]
                                        * wn[l * m.groups * m.gs
                                            + gi * m.gs
                                            + j]
                                            as f64
                                })
                                .sum();
                            *hv += eps[t * m.k * m.gs + si * m.gs + j]
                                * softplus64(rawj);
                        }
                    }
                    let sec_sel = &fr.sec[r][t][si];
                    let svals: Vec<f64> =
                        sec_sel.iter().map(|&j| h[j]).collect();
                    let sg = softmax64(&svals);
                    for sw in sg {
                        composed.push(pw * sw);
                    }
                }
                composed
            };
            // frozen-mask expert mixture -> MSE
            let sel = &fr.sel[r][t];
            let mut y = vec![0.0f64; d];
            for (slot, (&e, &g)) in sel.iter().zip(gates.iter()).enumerate() {
                let w = &p.experts[e];
                let h = w.hidden;
                let mask = &fr.relu[r][t][slot];
                let mut hid = vec![0.0f64; h];
                for (j, hv) in hid.iter_mut().enumerate() {
                    if !mask[j] {
                        continue;
                    }
                    *hv = (0..d)
                        .map(|l| xrow[l] * w.w_in[l * h + j] as f64)
                        .sum();
                }
                for (o, yv) in y.iter_mut().enumerate() {
                    let mut acc = 0.0f64;
                    for (j, hv) in hid.iter().enumerate() {
                        acc += hv * w.w_out[j * d + o] as f64;
                    }
                    *yv += g * acc;
                }
                imp[e] += g;
            }
            for (o, yv) in y.iter().enumerate() {
                let e = yv - inp.targets[r][t * d + o];
                mse += e * e;
            }
            // smooth load over the frozen threshold structure
            if fr.load_on {
                let (jk, jk1) = fr.thr[r][t];
                let member = &fr.member[r][t];
                for i in 0..n {
                    let thr = if member[i] { noisy[jk1] } else { noisy[jk] };
                    let sigma = softplus64(raw[i]) + 1e-10;
                    load[i] += phi64((clean[i] - thr) / sigma);
                }
            }
        }
    }
    let mut total = mse / inp.n_el.max(1) as f64
        + m.w_importance * cv2_64(&imp);
    if fr.load_on {
        total += m.w_load * cv2_64(&load);
    }
    total
}

// ---------------------------------------------------------------------
// harness plumbing

/// Run the production forward + backward, build the oracle, and check
/// every analytic gradient against central differences of the frozen
/// f64 loss at 1e-4 relative tolerance.
fn check_case(
    tag: &str,
    m: &Model,
    p: &Params,
    xs: Vec<TensorF>,
    targets: Vec<TensorF>,
    devices: usize,
    rng: Option<&mut Rng>,
) {
    let router = if m.groups > 0 {
        Router {
            backend: RouterBackend::Native,
            n_experts: m.n,
            k: m.k,
            groups: m.groups,
            d_model: m.d,
            w_g: p.w_g.clone(),
            w_noise: p.w_noise.clone(),
            w_g_sec: p.w_g_sec.clone(),
            w_n_sec: p.w_n_sec.clone(),
        }
    } else {
        Router::flat_native(
            m.d,
            m.n,
            m.k,
            p.w_g.clone(),
            p.w_noise.clone(),
        )
    };
    let with_noise = rng.is_some();
    let sched =
        Scheduler::new(ShardLayout::new(devices, m.n), ExpertBackend::Native);
    let refs: Vec<&TensorF> = xs.iter().collect();
    let s = sched
        .execute_streamed(&router, &refs, &p.experts, rng)
        .unwrap();
    if with_noise {
        assert!(
            s.decisions.iter().all(|dec| dec.noise.is_some()),
            "{tag}: training path must retain the pre-drawn noise"
        );
    }
    let (loss, grads) = streamed_backward(
        &router,
        &p.experts,
        &refs,
        &targets,
        &s,
        m.w_importance as f32,
        m.w_load as f32,
        true,
    )
    .unwrap();
    let gate = grads.gate.as_ref().expect("gating gradients requested");

    let load_on = with_noise
        && m.groups == 0
        && p.w_noise.is_some()
        && m.k < m.n
        && m.w_load != 0.0;
    let fr = freeze(m, p, &xs, &s, load_on);
    let inp = Inputs {
        xs: xs.iter().map(|x| x.data.iter().map(|v| *v as f64).collect()).collect(),
        targets: targets
            .iter()
            .map(|x| x.data.iter().map(|v| *v as f64).collect())
            .collect(),
        rows: xs.iter().map(|x| x.shape[0]).collect(),
        eps_pri: s
            .decisions
            .iter()
            .map(|dec| {
                dec.noise.as_ref().and_then(|ns| {
                    (!ns.primary.is_empty()).then(|| {
                        ns.primary.iter().map(|v| *v as f64).collect()
                    })
                })
            })
            .collect(),
        eps_sec: s
            .decisions
            .iter()
            .map(|dec| {
                dec.noise.as_ref().and_then(|ns| {
                    (!ns.secondary.is_empty()).then(|| {
                        ns.secondary.iter().map(|v| *v as f64).collect()
                    })
                })
            })
            .collect(),
        n_el: xs.iter().map(|x| x.data.len()).sum(),
    };

    // the oracle must reproduce the production loss at the base point
    // (validates the mirror before any FD is trusted)
    let base = frozen_loss(m, &inp, &fr, p);
    let expect = loss.task
        + m.w_importance * loss.cv_importance * loss.cv_importance
        + if load_on {
            m.w_load * loss.cv_load * loss.cv_load
        } else {
            0.0
        };
    assert!(
        (base - expect).abs() <= 1e-3 * expect.abs().max(1.0),
        "{tag}: oracle loss {base} vs production {expect}"
    );

    // h = 5e-4 keeps the truncation term of the normal-CDF load path
    // (third derivatives grow like 1/σ³) well under tol; the achieved-
    // step division in `central_diff` keeps f32 quantization out of it
    let (h, tol) = (5e-4f32, 1e-4f64);
    prop::grad_check(
        &format!("{tag}/w_g"),
        &p.w_g,
        &gate.w_g,
        |w| {
            let mut p2 = p.clone();
            p2.w_g = w.to_vec();
            frozen_loss(m, &inp, &fr, &p2)
        },
        h,
        tol,
    );
    if with_noise && p.w_noise.is_some() {
        let an = gate
            .w_noise
            .as_ref()
            .expect("noise net trained on the noisy path");
        prop::grad_check(
            &format!("{tag}/w_noise"),
            p.w_noise.as_ref().unwrap(),
            an,
            |w| {
                let mut p2 = p.clone();
                p2.w_noise = Some(w.to_vec());
                frozen_loss(m, &inp, &fr, &p2)
            },
            h,
            tol,
        );
    } else {
        assert!(
            gate.w_noise.is_none(),
            "{tag}: deterministic routing must not grad the noise net"
        );
    }
    if let Some(wsec) = &p.w_g_sec {
        let an = gate.w_g_sec.as_ref().expect("secondary gate grads");
        prop::grad_check(
            &format!("{tag}/w_g_sec"),
            wsec,
            an,
            |w| {
                let mut p2 = p.clone();
                p2.w_g_sec = Some(w.to_vec());
                frozen_loss(m, &inp, &fr, &p2)
            },
            h,
            tol,
        );
    }
    if with_noise {
        if let (Some(wnsec), Some(an)) = (&p.w_n_sec, gate.w_n_sec.as_ref()) {
            prop::grad_check(
                &format!("{tag}/w_n_sec"),
                wnsec,
                an,
                |w| {
                    let mut p2 = p.clone();
                    p2.w_n_sec = Some(w.to_vec());
                    frozen_loss(m, &inp, &fr, &p2)
                },
                h,
                tol,
            );
        }
    }
    for (e, (g_in, g_out)) in grads.experts.iter().enumerate() {
        prop::grad_check(
            &format!("{tag}/expert{e}/w_in"),
            &p.experts[e].w_in,
            g_in,
            |w| {
                let mut p2 = p.clone();
                p2.experts[e].w_in = w.to_vec();
                frozen_loss(m, &inp, &fr, &p2)
            },
            h,
            tol,
        );
        prop::grad_check(
            &format!("{tag}/expert{e}/w_out"),
            &p.experts[e].w_out,
            g_out,
            |w| {
                let mut p2 = p.clone();
                p2.experts[e].w_out = w.to_vec();
                frozen_loss(m, &inp, &fr, &p2)
            },
            h,
            tol,
        );
    }
}

fn mk_experts(rng: &mut Rng, n: usize, d: usize, h: usize) -> Vec<ExpertWeights> {
    (0..n)
        .map(|_| ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.4),
            w_out: prop::vec_f32(rng, h * d, 0.4),
            d_model: d,
            hidden: h,
        })
        .collect()
}

fn mk_batch(rng: &mut Rng, replicas: usize, rows: usize, d: usize, s: f32)
    -> Vec<TensorF> {
    (0..replicas)
        .map(|_| {
            TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, s))
        })
        .collect()
}

// ---------------------------------------------------------------------
// the checks

#[test]
fn flat_gating_gradients_match_central_differences_with_noise() {
    // the full stack: task + importance + smooth load, noise net live —
    // randomized (b, d, n, k, replicas, devices) shapes
    for case in 0..6u64 {
        let rng = &mut prop::case_rng(1000 + case);
        let d = prop::dim(rng, 3, 5);
        let n = prop::dim(rng, 3, 6);
        let k = prop::dim(rng, 1, (n - 1).min(3));
        let hdim = prop::dim(rng, 3, 6);
        let replicas = prop::dim(rng, 1, 2);
        let rows = prop::dim(rng, 3, 7);
        let devices = prop::dim(rng, 1, 3);
        let m = Model {
            d,
            n,
            k,
            groups: 0,
            gs: 0,
            w_importance: 0.1,
            w_load: 0.1,
        };
        let p = Params {
            w_g: prop::vec_f32(rng, d * n, 0.5),
            // modest noise-net scale keeps σ = softplus(x·W_noise) away
            // from the sharp small-σ regime of the load estimator
            w_noise: Some(prop::vec_f32(rng, d * n, 0.25)),
            w_g_sec: None,
            w_n_sec: None,
            experts: mk_experts(rng, n, d, hdim),
        };
        let xs = mk_batch(rng, replicas, rows, d, 1.0);
        let targets = mk_batch(rng, replicas, rows, d, 0.5);
        let mut nrng = rng.fold_in(77);
        check_case(
            &format!("flat-noise#{case}"),
            &m,
            &p,
            xs,
            targets,
            devices,
            Some(&mut nrng),
        );
    }
}

#[test]
fn flat_gating_gradients_match_without_noise() {
    // deterministic routing: gating still trains through the clean
    // logits (task + importance); the noise net and the load loss are
    // inert and must stay gradient-free
    for case in 0..4u64 {
        let rng = &mut prop::case_rng(2000 + case);
        let d = prop::dim(rng, 3, 5);
        let n = prop::dim(rng, 3, 6);
        let k = prop::dim(rng, 1, n.min(3));
        let hdim = prop::dim(rng, 3, 6);
        let m = Model {
            d,
            n,
            k,
            groups: 0,
            gs: 0,
            w_importance: 0.15,
            w_load: 0.1,
        };
        let p = Params {
            w_g: prop::vec_f32(rng, d * n, 0.5),
            w_noise: Some(prop::vec_f32(rng, d * n, 0.4)),
            w_g_sec: None,
            w_n_sec: None,
            experts: mk_experts(rng, n, d, hdim),
        };
        let xs = mk_batch(rng, 1, prop::dim(rng, 4, 8), d, 1.0);
        let targets: Vec<TensorF> = xs
            .iter()
            .map(|x| {
                TensorF::new(
                    x.shape.clone(),
                    prop::vec_f32(rng, x.data.len(), 0.5),
                )
            })
            .collect();
        check_case(&format!("flat-eval#{case}"), &m, &p, xs, targets, 2, None);
    }
}

#[test]
fn hierarchical_gradients_match_central_differences() {
    // Appendix-B two-level gating: task + importance through both
    // softmaxes into the primary and secondary nets, with live noise
    for case in 0..4u64 {
        let rng = &mut prop::case_rng(3000 + case);
        let d = prop::dim(rng, 3, 4);
        let a = prop::dim(rng, 2, 3);
        let gs = prop::dim(rng, 2, 3);
        let k = prop::dim(rng, 1, a.min(2));
        let n = a * gs;
        let hdim = prop::dim(rng, 3, 5);
        let m = Model {
            d,
            n,
            k,
            groups: a,
            gs,
            w_importance: 0.1,
            w_load: 0.1,
        };
        let p = Params {
            w_g: prop::vec_f32(rng, d * a, 0.5),
            w_noise: Some(prop::vec_f32(rng, d * a, 0.3)),
            w_g_sec: Some(prop::vec_f32(rng, d * a * gs, 0.5)),
            w_n_sec: Some(prop::vec_f32(rng, d * a * gs, 0.3)),
            experts: mk_experts(rng, n, d, hdim),
        };
        let rows = prop::dim(rng, 3, 6);
        let xs = mk_batch(rng, 1, rows, d, 1.0);
        let targets = mk_batch(rng, 1, rows, d, 0.5);
        let mut nrng = rng.fold_in(13);
        check_case(
            &format!("hier#{case}"),
            &m,
            &p,
            xs,
            targets,
            2,
            Some(&mut nrng),
        );
    }
}

#[test]
fn duplicate_topk_ties_are_frozen_and_still_differentiable() {
    // w_g with duplicated columns + deterministic routing ⇒ exact
    // duplicate logits on every row; selection must tie-break to the
    // lower index, and the frozen-branch gradients must still pass the
    // FD check (a naive FD would step across the selection boundary)
    for case in 0..2u64 {
        let rng = &mut prop::case_rng(4000 + case);
        let (d, n, k, hdim) = (4, 5, 2, 5);
        let mut w_g = prop::vec_f32(rng, d * n, 0.5);
        // expert columns 1 and 2 identical -> tied logits on every row
        for l in 0..d {
            w_g[l * n + 2] = w_g[l * n + 1];
        }
        let m = Model {
            d,
            n,
            k,
            groups: 0,
            gs: 0,
            w_importance: 0.2,
            w_load: 0.1,
        };
        let p = Params {
            w_g,
            w_noise: Some(prop::vec_f32(rng, d * n, 0.4)),
            w_g_sec: None,
            w_n_sec: None,
            experts: mk_experts(rng, n, d, hdim),
        };
        let xs = mk_batch(rng, 1, 6, d, 1.0);
        let targets = mk_batch(rng, 1, 6, d, 0.5);

        // tie-break sanity on the actual decisions
        let router = Router::flat_native(
            d, n, k, p.w_g.clone(), p.w_noise.clone(),
        );
        let dec = router.route(&xs[0], None).unwrap();
        for tok in &dec.per_token {
            if tok.experts.contains(&2) {
                assert!(
                    tok.experts.contains(&1),
                    "tied duplicate column must enter at the lower index \
                     first: {:?}",
                    tok.experts
                );
            }
        }
        check_case(&format!("ties#{case}"), &m, &p, xs, targets, 2, None);
    }
}

// ---------------------------------------------------------------------
// satellite: the pre-drawn-noise / determinism contract

#[test]
fn same_seed_training_runs_are_bit_identical() {
    // two full Trainer runs from the same seeds: the engine's parallel
    // row-blocked routing must consume the pre-drawn eq-4 noise stream
    // identically under any thread interleaving, and the backward +
    // Adam must be deterministic — weights and moments agree bit for
    // bit after N steps
    let (d, h, n, k) = (6, 10, 5, 2);
    let run = || {
        let trainer = Trainer::native(ModelConfig::native_moe(
            "det", d, n, k, h, 2, 8,
        ));
        let mut state = trainer.init_streamed(21);
        let sched =
            Scheduler::new(ShardLayout::new(3, n), ExpertBackend::Native);
        let mut data_rng = Rng::new(7);
        let xs = mk_batch(&mut data_rng, 2, 12, d, 1.0);
        let targets = mk_batch(&mut data_rng, 2, 12, d, 0.5);
        let mut noise_rng = Rng::new(42);
        let mut losses = Vec::new();
        for _ in 0..5 {
            let m = trainer
                .step_streamed(
                    &sched,
                    &mut state,
                    &xs,
                    &targets,
                    0.01,
                    Some(&mut noise_rng),
                )
                .unwrap();
            losses.push(m.loss.to_bits());
        }
        (state, losses)
    };
    let (sa, la) = run();
    let (sb, lb) = run();
    assert_eq!(la, lb, "per-step losses diverged between identical runs");
    assert_eq!(sa.router.w_g, sb.router.w_g, "w_g drifted");
    assert_eq!(sa.router.w_noise, sb.router.w_noise, "w_noise drifted");
    for (wa, wb) in sa.weights.iter().zip(sb.weights.iter()) {
        assert_eq!(wa.w_in, wb.w_in, "expert w_in drifted");
        assert_eq!(wa.w_out, wb.w_out, "expert w_out drifted");
    }
    assert_eq!(sa.opt, sb.opt, "Adam moments drifted");
}

// ---------------------------------------------------------------------
// satellite: the end-to-end acceptance run

#[test]
fn balance_losses_reduce_cv_and_task_loss_is_no_worse() {
    // identical init / data / noise streams, one run with the gating
    // frozen (the pre-PR behaviour) and one with the full backward +
    // balance losses: the learned run's balance CVs must fall below
    // the frozen baseline without giving up task loss
    let (d, h, n, k) = (8, 16, 8, 2);
    let steps = 60;
    let trainer = Trainer::native(ModelConfig::native_moe(
        "bal-e2e", d, n, k, h, 2, 32,
    ));
    let run = |train_gating: bool| {
        let mut state = trainer.init_streamed(3);
        let sched =
            Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let mut data_rng = Rng::new(11);
        let xs = mk_batch(&mut data_rng, 2, 32, d, 1.0);
        let targets = mk_batch(&mut data_rng, 2, 32, d, 0.5);
        let mut noise_rng = Rng::new(99);
        let opts = StreamedStepOptions {
            lr: 0.01,
            train_gating,
            w_importance: 0.1,
            w_load: 0.1,
        };
        let mut cvs = Vec::with_capacity(steps);
        let mut tasks = Vec::with_capacity(steps);
        for i in 0..steps {
            let m = trainer
                .step_streamed_with(
                    &sched,
                    &mut state,
                    &xs,
                    &targets,
                    Some(&mut noise_rng),
                    &opts,
                )
                .unwrap();
            assert!(m.loss.is_finite(), "step {i} diverged");
            cvs.push(m.cv_importance);
            tasks.push(m.loss);
        }
        (cvs, tasks)
    };
    let (cv_frozen, task_frozen) = run(false);
    let (cv_learned, task_learned) = run(true);
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    let late = |v: &[f64]| mean(&v[v.len() - 10..]);

    // the balance losses must actually balance: late-window CV below
    // both the frozen baseline and the learned run's own start
    assert!(
        late(&cv_learned) < late(&cv_frozen),
        "balance CV did not fall below the frozen-gating baseline: \
         learned {:.4} vs frozen {:.4}",
        late(&cv_learned),
        late(&cv_frozen)
    );
    assert!(
        late(&cv_learned) < mean(&cv_learned[..10]),
        "balance CV did not fall over training: {:.4} -> {:.4}",
        mean(&cv_learned[..10]),
        late(&cv_learned)
    );
    // ...without costing the task: late-window task loss no worse than
    // the frozen baseline's
    assert!(
        late(&task_learned) <= late(&task_frozen) * 1.02,
        "task loss regressed with gating learning on: learned {:.5} vs \
         frozen {:.5}",
        late(&task_learned),
        late(&task_frozen)
    );
    // and both descended overall
    assert!(late(&task_learned) < mean(&task_learned[..5]));
}
