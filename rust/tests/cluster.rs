//! Capacity-factor dispatch parity: the GShard-style capped streaming
//! path against the serial oracle, end to end.
//!
//! The contract under test (see [`PlanBuilder::with_capacity`]):
//! capped dispatch is a pure function of the routing decisions — same
//! seed, same drop set, every time — and with capacity at or above
//! every expert's natural load it is *bit-identical* to exact dispatch,
//! so turning the GShard buffers on costs nothing until they actually
//! bind.  The engine's streamed pipeline must reproduce the serial
//! `plan_with_capacity` + `execute_serial` composition exactly (plans
//! and drop accounting bit-equal, outputs within float-reassociation
//! tolerance), and the cluster-simulation harness must inherit all of
//! it at hierarchical-routing scale.
//!
//! [`PlanBuilder::with_capacity`]:
//!     moe::coordinator::dispatcher::PlanBuilder::with_capacity

use moe::coordinator::router::{Router, RoutingDecision};
use moe::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout, WavePolicy,
};
use moe::coordinator::{DispatchPlan, Dispatcher};
use moe::gating::noisy_topk::GateVec;
use moe::harness::cluster_sim::ClusterSim;
use moe::runtime::TensorF;
use moe::util::prop;
use moe::util::rng::Rng;

const TOL: f32 = 1e-5;

fn mk_weights(n: usize, d: usize, h: usize, rng: &mut Rng) -> Vec<ExpertWeights> {
    (0..n)
        .map(|_| ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.3),
            w_out: prop::vec_f32(rng, h * d, 0.3),
            d_model: d,
            hidden: h,
        })
        .collect()
}

fn assert_decisions_eq(a: &[RoutingDecision], b: &[RoutingDecision]) {
    assert_eq!(a.len(), b.len());
    for (da, db) in a.iter().zip(b) {
        assert_eq!(da.per_token.len(), db.per_token.len());
        for (ta, tb) in da.per_token.iter().zip(&db.per_token) {
            assert_eq!(ta.experts, tb.experts);
            let wa: Vec<u32> = ta.weights.iter().map(|w| w.to_bits()).collect();
            let wb: Vec<u32> = tb.weights.iter().map(|w| w.to_bits()).collect();
            assert_eq!(wa, wb, "gate weights must be bit-identical");
        }
    }
}

fn assert_plans_eq(a: &DispatchPlan, b: &DispatchPlan, ctx: &str) {
    assert_eq!(a.n_experts, b.n_experts, "{ctx}");
    assert_eq!(a.replica_rows, b.replica_rows, "{ctx}");
    assert_eq!(a.rerouted_routes, b.rerouted_routes, "{ctx}");
    assert_eq!(a.dropped_routes, b.dropped_routes, "{ctx}");
    for (e, (ba, bb)) in a.per_expert.iter().zip(&b.per_expert).enumerate() {
        assert_eq!(ba.tokens, bb.tokens, "{ctx}: expert {e} token order");
        let ga: Vec<u32> = ba.gates.iter().map(|g| g.to_bits()).collect();
        let gb: Vec<u32> = bb.gates.iter().map(|g| g.to_bits()).collect();
        assert_eq!(ga, gb, "{ctx}: expert {e} gates");
    }
}

/// Streamed engine with a dispatch capacity == serially routing the
/// same seed, capping with the oracle `plan_with_capacity`, and running
/// `execute_serial` — decisions and plans bit-equal (including the
/// drop/reroute accounting), outputs within reassociation tolerance.
#[test]
fn streamed_capacity_matches_capped_serial_oracle() {
    prop::forall("streamed cap == serial cap", |rng| {
        let d = prop::dim(rng, 2, 8);
        let h = prop::dim(rng, 2, 10);
        let n = prop::dim(rng, 2, 12);
        let k = prop::dim(rng, 1, n.min(4));
        let replicas = prop::dim(rng, 1, 4);
        let devices = prop::dim(rng, 1, n + 2);
        let cap = prop::dim(rng, 1, 9);
        let weights = mk_weights(n, d, h, rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(rng, d * n, 0.5),
            Some(prop::vec_f32(rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                let rows = prop::dim(rng, 1, 10);
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let refs: Vec<&TensorF> = xs.iter().collect();
        let seed_rng = rng.fold_in(23);

        let sched = Scheduler::with_policy(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
            WavePolicy::Fixed(None),
        )
        .with_dispatch_capacity(Some(cap));
        let mut rng_a = seed_rng.clone();
        let s = sched
            .execute_streamed(&router, &refs, &weights, Some(&mut rng_a))
            .unwrap();

        // the serial oracle: same noise seed, capped plan, serial step
        let mut rng_b = seed_rng.clone();
        let decisions: Vec<RoutingDecision> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut rng_b)).unwrap())
            .collect();
        let plan = Dispatcher::plan_with_capacity(&decisions, n, Some(cap));
        let (want, ref_stats) =
            sched.execute_serial(&plan, &refs, &weights).unwrap();

        // capacity must not touch the routing decisions themselves —
        // the balance losses still see the router's true output
        assert_decisions_eq(&s.decisions, &decisions);
        assert_plans_eq(&s.plan, &plan, &format!("cap={cap}"));
        for load in s.plan.expert_loads() {
            assert!(load <= cap, "load {load} escaped capacity {cap}");
        }
        assert_eq!(s.stats.dropped_routes, ref_stats.dropped_routes);
        assert_eq!(s.stats.rerouted_routes, ref_stats.rerouted_routes);
        assert_eq!(s.stats.network_bytes, ref_stats.network_bytes);
        assert_eq!(s.outs.len(), want.len());
        for (g, w) in s.outs.iter().zip(&want) {
            assert_eq!(g.shape, w.shape);
            for (a, b) in g.data.iter().zip(&w.data) {
                assert!((a - b).abs() <= TOL, "cap={cap}: {a} vs {b}");
            }
        }
    });
}

/// With capacity at or above the heaviest expert's natural load, the
/// capped streamed step *is* the exact streamed step: bit-identical
/// plan, zero drops, zero reroutes, and `execute_serial` over both
/// plans produces bit-identical outputs.
#[test]
fn capacity_above_peak_load_is_bit_neutral() {
    prop::forall("cap >= peak is exact", |rng| {
        let d = prop::dim(rng, 2, 8);
        let h = prop::dim(rng, 2, 10);
        let n = prop::dim(rng, 2, 10);
        let k = prop::dim(rng, 1, n.min(3));
        let replicas = prop::dim(rng, 1, 3);
        let devices = prop::dim(rng, 1, n + 1);
        let weights = mk_weights(n, d, h, rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(rng, d * n, 0.5),
            Some(prop::vec_f32(rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                let rows = prop::dim(rng, 1, 10);
                TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0))
            })
            .collect();
        let refs: Vec<&TensorF> = xs.iter().collect();
        let seed_rng = rng.fold_in(29);

        let mut rng_exact = seed_rng.clone();
        let exact_sched = Scheduler::with_policy(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
            WavePolicy::Fixed(None),
        );
        let exact = exact_sched
            .execute_streamed(&router, &refs, &weights, Some(&mut rng_exact))
            .unwrap();

        let peak = exact.plan.expert_loads().into_iter().max().unwrap_or(0);
        let cap = peak.max(1) + prop::dim(rng, 1, 3) - 1; // peak, peak+1, peak+2
        let capped_sched = Scheduler::with_policy(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
            WavePolicy::Fixed(None),
        )
        .with_dispatch_capacity(Some(cap));
        let mut rng_cap = seed_rng.clone();
        let capped = capped_sched
            .execute_streamed(&router, &refs, &weights, Some(&mut rng_cap))
            .unwrap();

        assert_plans_eq(&capped.plan, &exact.plan, &format!("cap={cap}"));
        assert_eq!(capped.plan.dropped_routes, 0);
        assert_eq!(capped.plan.rerouted_routes, 0);

        // the serial oracle over two bit-identical plans is bit-identical
        let (a, _) =
            exact_sched.execute_serial(&exact.plan, &refs, &weights).unwrap();
        let (b, _) = exact_sched
            .execute_serial(&capped.plan, &refs, &weights)
            .unwrap();
        for (ta, tb) in a.iter().zip(&b) {
            let ba: Vec<u32> = ta.data.iter().map(|v| v.to_bits()).collect();
            let bb: Vec<u32> = tb.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ba, bb);
        }
    });
}

/// Same seed, same drop set — twice through the capped streamed path
/// with a deliberately binding capacity yields the same plan bit for
/// bit, including which routes were dropped and which were rerouted.
#[test]
fn same_seed_capacity_drops_are_identical() {
    prop::forall("same seed same drops", |rng| {
        let (d, h) = (6, 8);
        let n = prop::dim(rng, 3, 10);
        let k = prop::dim(rng, 2, n.min(4));
        let replicas = prop::dim(rng, 2, 4);
        let weights = mk_weights(n, d, h, rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(rng, d * n, 0.5),
            Some(prop::vec_f32(rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                TensorF::new(vec![8, d], prop::vec_f32(rng, 8 * d, 1.0))
            })
            .collect();
        let refs: Vec<&TensorF> = xs.iter().collect();
        // well under the balanced load so the buffers genuinely bind
        let cap = Dispatcher::capacity_for(0.5, 8 * replicas, k, n);
        let seed_rng = rng.fold_in(31);

        let run = || {
            let sched = Scheduler::with_policy(
                ShardLayout::new(2, n),
                ExpertBackend::Native,
                WavePolicy::Fixed(None),
            )
            .with_dispatch_capacity(Some(cap));
            let mut r = seed_rng.clone();
            sched.execute_streamed(&router, &refs, &weights, Some(&mut r))
                .unwrap()
        };
        let first = run();
        let second = run();
        assert_plans_eq(&first.plan, &second.plan, "same seed");
        // and the run is genuinely lossy in this regime or the test
        // proves nothing about drop determinism
        if first.plan.dropped_routes == 0 {
            assert!(
                first.plan.expert_loads().iter().all(|&l| l <= cap),
                "no drops must mean no buffer ever overflowed"
            );
        }
    });
}

/// A perfectly balanced router at capacity factor 1.0 fills every
/// buffer exactly and drops nothing: `plan_with_capacity` is
/// bit-identical to the exact `plan` (the GShard cf=1 fixed point).
#[test]
fn balanced_load_at_factor_one_drops_nothing() {
    let (n, k, replicas, rows) = (8usize, 2usize, 3usize, 16usize);
    // round-robin decisions: token t of any replica routes to experts
    // (2t, 2t+1) mod n — every expert sees exactly rows*replicas*k/n
    let decisions: Vec<RoutingDecision> = (0..replicas)
        .map(|_| RoutingDecision {
            per_token: (0..rows)
                .map(|t| GateVec {
                    experts: (0..k).map(|j| (k * t + j) % n).collect(),
                    weights: vec![1.0 / k as f32; k],
                })
                .collect(),
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        })
        .collect();
    let cap = Dispatcher::capacity_for(1.0, rows * replicas, k, n);
    assert_eq!(cap, rows * replicas * k / n);
    let exact = Dispatcher::plan(&decisions, n);
    let capped = Dispatcher::plan_with_capacity(&decisions, n, Some(cap));
    assert_plans_eq(&capped, &exact, "balanced cf=1.0");
    assert_eq!(capped.dropped_routes, 0);
    assert_eq!(capped.rerouted_routes, 0);
    assert!(capped.expert_loads().iter().all(|&l| l == cap));
}

/// The cluster harness inherits all of the above at hierarchical
/// (k² routes/token) scale: same seed → bit-identical plans, capacity
/// respected, drop accounting conserved.
#[test]
fn cluster_sim_steps_are_deterministic_and_capacity_bounded() {
    let sim = ClusterSim::build(64, 6, Some(1.25), 13).unwrap();
    let cap = sim.capacity.unwrap();
    let a = sim.step(4).unwrap();
    let b = sim.step(4).unwrap();
    assert_plans_eq(&a.plan, &b.plan, "same fold");
    assert_decisions_eq(&a.decisions, &b.decisions);
    for load in a.plan.expert_loads() {
        assert!(load <= cap);
    }
    assert_eq!(
        a.plan.offered_routes(),
        sim.tokens() * 4,
        "hierarchical gate offers k²=4 routes per token"
    );
    assert_eq!(
        a.plan.total_routes() + a.plan.dropped_routes,
        a.plan.offered_routes()
    );
    // a different noise fold is a different step (the gate actually
    // consumed the eq-4 noise); routing may coincide on tiny models,
    // but the gates' float pattern must not be an accident of reuse
    let c = sim.step(5).unwrap();
    assert_eq!(c.plan.replica_rows, a.plan.replica_rows);
}
