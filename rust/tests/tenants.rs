//! Multi-tenant admission front-end correctness (tier-1).
//!
//! Three load-bearing properties of `moe::serve::tenant`:
//!
//! 1. **Conservation** — under adversarial traffic (heavy hitter,
//!    long tail) and every admission × drain policy combination, each
//!    tenant's ledger conserves (`offered == completed + shed +
//!    failed`) and the per-tenant ledgers sum *exactly* to the global
//!    one.  No request is lost or double-counted at any boundary:
//!    capability filtering, lane shedding, cross-tenant displacement,
//!    batching, degraded completion.
//! 2. **Isolation** — the fairness experiment: with a tenant flooding
//!    at 10× capacity, the weighted-fair (DRR) drain keeps a
//!    well-behaved victim's completed fraction and p99 latency near
//!    its solo baseline, while the global-FIFO drain — same trace,
//!    same engine — demonstrably sheds the victim.  This is the
//!    paper's serving economics at the front door: capacity is only
//!    affordable per query if one tenant can't buy the whole queue.
//! 3. **Routing bit-identity** — a mixed trace routed across two
//!    backends (exact f32 "base" + int8 "canary" over a different
//!    checkpoint) produces, for every completed request, outputs
//!    bit-identical to running that request alone on its assigned
//!    backend: coalescing, tenancy and capability routing add zero
//!    numeric perturbation.

use moe::harness::workload::{
    heavy_hitter_specs, long_tail_specs, tenant_fairness_run, FairnessOutcome,
    TenantHarness, TraceSpec, HITTER, VICTIM,
};
use moe::kernels::quant::Precision;
use moe::serve::{
    AdmissionPolicy, DrainPolicy, ServeBackend, TenantServeConfig,
    TenantServeReport, TenantSpec,
};

/// Per-tenant ledgers conserve and sum exactly to the global ledger.
fn assert_conserved(rep: &TenantServeReport, trace_len: u64, ctx: &str) {
    let (mut offered, mut completed, mut shed, mut failed) = (0, 0, 0, 0);
    for (name, s) in rep.tenants.iter().zip(&rep.per_tenant) {
        assert_eq!(
            s.offered,
            s.completed + s.shed + s.failed,
            "{ctx}: tenant {name} ledger does not conserve"
        );
        offered += s.offered;
        completed += s.completed;
        shed += s.shed;
        failed += s.failed;
    }
    assert_eq!(offered, rep.global.offered, "{ctx}: offered sums");
    assert_eq!(completed, rep.global.completed, "{ctx}: completed sums");
    assert_eq!(shed, rep.global.shed, "{ctx}: shed sums");
    assert_eq!(failed, rep.global.failed, "{ctx}: failed sums");
    assert_eq!(
        rep.global.offered, trace_len,
        "{ctx}: every trace entry must be offered exactly once"
    );
    assert_eq!(
        rep.global.offered,
        rep.global.completed + rep.global.shed + rep.global.failed,
        "{ctx}: global ledger does not conserve"
    );
}

#[test]
fn ledgers_conserve_under_heavy_hitter_across_all_policies() {
    let h = TenantHarness::new(33, 1);
    // burst-scale rates so lane bounds actually bind: most of the
    // flood sheds, a bounded prefix completes — both ledger branches
    // exercised
    let specs = heavy_hitter_specs(33, 2e8, 1e7, 12, h.min_rows, h.max_rows);
    let trace = h.trace(&specs);
    let tenants = || {
        vec![
            TenantSpec::new("hitter", 8),
            TenantSpec {
                deadline_ns: Some(2_000_000),
                ..TenantSpec::new("victim", 4)
            },
        ]
    };
    for drain in [DrainPolicy::GlobalFifo, DrainPolicy::WeightedFair] {
        for admission in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest]
        {
            let cfg = TenantServeConfig {
                admission,
                drain,
                ..h.config(drain)
            };
            let lp = h.ab_loop(tenants(), cfg).unwrap();
            let rep = lp.run_trace(&trace).unwrap();
            let ctx = format!("heavy-hitter {drain:?}/{admission:?}");
            assert_conserved(&rep, trace.len() as u64, &ctx);
            assert!(
                rep.global.shed > 0,
                "{ctx}: burst trace should overflow the lanes"
            );
            assert!(
                rep.per_tenant[HITTER].completed > 0,
                "{ctx}: some of the flood must still serve"
            );
        }
    }
}

#[test]
fn ledgers_conserve_under_long_tail_with_capability_pins() {
    let h = TenantHarness::new(47, 1);
    let specs = long_tail_specs(47, 5e7, 48, 3, h.min_rows, h.max_rows);
    let trace = h.trace(&specs);
    // head + three tails; two tails pin capabilities so routing has to
    // respect hard filters while conserving
    let tenants = || {
        vec![
            TenantSpec {
                weight: 4,
                ..TenantSpec::new("head", 16)
            },
            TenantSpec {
                required_precision: Some(Precision::F32),
                ..TenantSpec::new("tail-exact", 4)
            },
            TenantSpec {
                required_variant: Some("canary".to_string()),
                ..TenantSpec::new("tail-canary", 4)
            },
            TenantSpec {
                deadline_ns: Some(1_000_000),
                ..TenantSpec::new("tail-slo", 4)
            },
        ]
    };
    for drain in [DrainPolicy::GlobalFifo, DrainPolicy::WeightedFair] {
        for admission in [AdmissionPolicy::Reject, AdmissionPolicy::ShedOldest]
        {
            let cfg = TenantServeConfig {
                admission,
                drain,
                ..h.config(drain)
            };
            let lp = h.ab_loop(tenants(), cfg).unwrap();
            let rep = lp.run_trace(&trace).unwrap();
            let ctx = format!("long-tail {drain:?}/{admission:?}");
            assert_conserved(&rep, trace.len() as u64, &ctx);
        }
    }
}

#[test]
fn oversized_requests_are_hard_filtered_before_any_load_scoring() {
    // capability-first ordering: a request larger than every backend's
    // batch ceiling is shed at the edge even though all queues are
    // empty — it never reaches slack scoring or a lane
    let mut h = TenantHarness::new(5, 1);
    h.max_batch_tokens = 16;
    h.min_rows = 20;
    h.max_rows = 24;
    let trace = h.trace(&[TraceSpec {
        seed: 5,
        rate_per_sec: 1_000.0,
        n_requests: 6,
        min_rows: h.min_rows,
        max_rows: h.max_rows,
        bursty: false,
    }]);
    let lp = h
        .single_loop(
            vec![TenantSpec::new("big", 8)],
            h.config(DrainPolicy::WeightedFair),
        )
        .unwrap();
    let rep = lp.run_trace(&trace).unwrap();
    assert_conserved(&rep, trace.len() as u64, "oversized");
    assert_eq!(rep.global.completed, 0);
    assert_eq!(rep.global.shed, trace.len() as u64);
    assert_eq!(rep.per_tenant[0].shed, trace.len() as u64);
}

#[test]
fn weighted_fair_isolates_the_victim_where_global_fifo_does_not() {
    let out = tenant_fairness_run(17, 1, 16).unwrap();
    for row in out.rows() {
        assert!(
            row.conserved,
            "{}/{}: ledger does not conserve",
            row.run, row.tenant
        );
        assert!(
            (0.0..=1.0).contains(&row.shed_fraction),
            "{}/{}: shed fraction {}",
            row.run,
            row.tenant,
            row.shed_fraction
        );
    }
    let solo = FairnessOutcome::victim_fraction(&out.solo);
    let wfq = FairnessOutcome::victim_fraction(&out.wfq);
    let fifo = FairnessOutcome::victim_fraction(&out.fifo);
    // the victim alone (0.25x capacity) completes essentially all its
    // requests — the yardstick isolation is measured against
    assert!(solo >= 0.9, "solo victim only completed {solo:.2}");
    // stated isolation bound: weighted-fair keeps the victim within
    // 25% of its solo completed fraction despite a 10x-capacity flood
    assert!(
        wfq >= 0.75 * solo,
        "weighted-fair victim completed {wfq:.2} vs solo {solo:.2}"
    );
    // the contrast baseline must demonstrably violate isolation: under
    // the shared FIFO the flood takes the victim's admission away
    assert!(
        fifo <= 0.5 * wfq,
        "global FIFO victim completed {fifo:.2} vs weighted-fair {wfq:.2} \
         — the baseline is supposed to starve the victim"
    );
    // stated latency bound: weighted-fair victim p99 stays within 50x
    // of the solo baseline (the FIFO run barely completes anything, so
    // its p99 is not a meaningful statistic)
    let solo_p99 = FairnessOutcome::victim_p99_ns(&out.solo).max(1);
    let wfq_p99 = FairnessOutcome::victim_p99_ns(&out.wfq);
    assert!(wfq_p99 > 0, "weighted-fair victim completed nothing");
    assert!(
        wfq_p99 <= 50 * solo_p99,
        "weighted-fair victim p99 {wfq_p99}ns vs solo {solo_p99}ns"
    );
    // the hitter itself is not starved by fairness — it keeps the
    // capacity the victim does not use
    assert!(out.wfq.per_tenant[HITTER].completed > 0);
    assert!(out.wfq.per_tenant[VICTIM].offered == out.solo.per_tenant[VICTIM].offered);
}

#[test]
fn backend_routing_is_bit_identical_to_solo_execution() {
    let h = TenantHarness::new(71, 1);
    let mk_specs = |t: u64| TraceSpec {
        seed: 71 ^ (t << 4),
        rate_per_sec: 2_000.0,
        n_requests: 10,
        min_rows: 2,
        max_rows: 6,
        bursty: false,
    };
    let trace = h.trace(&[mk_specs(1), mk_specs(2), mk_specs(3)]);
    let tenants = vec![
        TenantSpec {
            required_precision: Some(Precision::F32),
            required_variant: Some("base".to_string()),
            ..TenantSpec::new("exact", 64)
        },
        TenantSpec {
            required_variant: Some("canary".to_string()),
            ..TenantSpec::new("turbo", 64)
        },
        TenantSpec::new("free", 64),
    ];
    let cfg = TenantServeConfig {
        capture_outputs: true,
        ..h.config(DrainPolicy::WeightedFair)
    };
    let lp = h.ab_loop(tenants, cfg).unwrap();
    let rep = lp.run_trace(&trace).unwrap();
    assert_conserved(&rep, trace.len() as u64, "routing");
    assert_eq!(
        rep.global.shed, 0,
        "lanes are deep enough that nothing sheds"
    );
    assert_eq!(rep.global.failed, 0);

    // rebuild the fleet exactly as ab_loop froze it and serve every
    // request alone on the backend the front-end assigned it to
    let solo: Vec<_> = vec![
        h.backend("exact", "base", Precision::F32, h.seed).unwrap(),
        h.backend("turbo", "canary", Precision::Int8, h.seed ^ 0xab)
            .unwrap(),
    ];
    let mut served_per_backend = [0usize; 2];
    for (i, req) in trace.iter().enumerate() {
        let b = rep.assigned_backend[i]
            .expect("nothing shed, so every request was assigned");
        served_per_backend[b] += 1;
        // capability pins were honoured as hard filters
        match req.tenant {
            0 => assert_eq!(b, 0, "request {i}: 'exact' pinned to f32/base"),
            1 => assert_eq!(b, 1, "request {i}: 'turbo' pinned to canary"),
            _ => {}
        }
        let routed = rep.outputs[i].as_ref().expect("completed output");
        let (alone, _) = solo[b].execute_forward(&req.x).unwrap();
        assert_eq!(routed.shape, alone.shape, "request {i} shape");
        assert_eq!(
            routed.data, alone.data,
            "request {i} on backend {b}: coalesced serving must be \
             bit-identical to solo execution"
        );
    }
    assert!(
        served_per_backend.iter().all(|&n| n > 0),
        "both backends must have served: {served_per_backend:?}"
    );
}
