//! Structured tracing: per-worker lock-free span rings + Chrome export.
//!
//! Every instrumented site records a [`Span`] — a fixed-size `Copy`
//! record carrying the full (step, shard, expert, chunk, replica)
//! identity plus wall-clock start/duration — into a single-producer
//! ring owned by that worker thread ([`SpanRing`]).  The coordinator
//! drains all rings after each step, at quiescence (the engine's drain
//! guards guarantee every worker has replied before the step returns),
//! so the hot path never takes a lock and never allocates: a push is
//! two atomic loads, one slot write and one atomic store.  A full ring
//! drops the span and counts it ([`SpanRing::dropped`]) rather than
//! blocking — tracing must never perturb the execution it observes.
//!
//! **Bit-neutrality contract**: recording only *reads* the clock and
//! *writes* rings.  It draws no randomness, reorders no accumulation,
//! and changes no scheduling decision, so traced runs produce outputs
//! bit-identical to untraced runs (proven differentially in
//! `rust/tests/obs.rs`).
//!
//! [`chrome_trace_json`] renders drained spans as Chrome trace-event
//! JSON (`"X"` complete events, microsecond timestamps, one `tid` per
//! shard plus a coordinator lane) — `repro trace` writes `trace.json`,
//! loadable directly in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Sentinel for an identity field a span does not carry (a route span
/// has no expert yet; a combine span has no single expert).
pub const NO_ID: u32 = u32::MAX;

/// What an instrumented interval did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// a row block gated on a route worker
    Route,
    /// token rows staged into one expert chunk (all-to-all "send")
    Gather,
    /// one expert task's FFN forward on its owning shard
    Compute,
    /// one replica's gate-weighted combine (all-to-all "receive")
    Combine,
    /// a failed route re-dispatched to another selected expert
    Retry,
    /// coordinator-side chunk dispatch onto a shard's queue
    Dispatch,
    /// one full engine step (coordinator lane)
    Step,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Route => "route",
            SpanKind::Gather => "gather",
            SpanKind::Compute => "compute",
            SpanKind::Combine => "combine",
            SpanKind::Retry => "retry",
            SpanKind::Dispatch => "dispatch",
            SpanKind::Step => "step",
        }
    }
}

/// One traced interval.  `Copy` and exactly 48 bytes so ring slots are
/// plain stores; identity fields use [`NO_ID`] when not applicable.
/// `shard == NO_ID` means the coordinator lane.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// engine step counter (1-based; monotonic per engine)
    pub step: u64,
    pub shard: u32,
    pub expert: u32,
    /// chunk identity: the chunk's row offset (`chunk_lo` for expert
    /// chunks, block `lo` for route blocks)
    pub chunk: u32,
    pub replica: u32,
    pub rows: u32,
    /// nanoseconds since the owning [`TraceShared`] epoch
    pub start_ns: u64,
    pub dur_ns: u64,
}

impl Span {
    pub const fn empty() -> Self {
        Span {
            kind: SpanKind::Step,
            step: 0,
            shard: NO_ID,
            expert: NO_ID,
            chunk: NO_ID,
            replica: NO_ID,
            rows: 0,
            start_ns: 0,
            dur_ns: 0,
        }
    }
}

/// Lock-free single-producer / single-consumer span ring.
///
/// The producer is the one worker thread that owns the ring; the
/// consumer is the coordinator, which drains only at step-end
/// quiescence.  `head` is advanced by the producer with a `Release`
/// store after the slot write; the consumer `Acquire`-loads it, so
/// every drained slot's contents are visible.  A push into a full ring
/// increments `dropped` and returns — never blocks, never overwrites
/// undrained spans.
pub struct SpanRing {
    slots: Box<[UnsafeCell<Span>]>,
    /// next write index (producer-owned)
    head: AtomicUsize,
    /// next read index (consumer-owned)
    tail: AtomicUsize,
    dropped: AtomicU64,
}

// Sound: `head`/`tail` ordering establishes happens-before between the
// single producer's slot writes and the single consumer's reads; a slot
// is never accessed by both sides at once (full rings drop).
unsafe impl Send for SpanRing {}
unsafe impl Sync for SpanRing {}

impl SpanRing {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2);
        SpanRing {
            slots: (0..cap)
                .map(|_| UnsafeCell::new(Span::empty()))
                .collect::<Vec<_>>()
                .into_boxed_slice(),
            head: AtomicUsize::new(0),
            tail: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Producer side: record one span; drops (counted) when full.
    pub fn push(&self, span: Span) {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head.wrapping_sub(tail) >= self.slots.len() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        // sole producer: `head` slot is ours until the store below
        unsafe {
            *self.slots[head % self.slots.len()].get() = span;
        }
        self.head.store(head.wrapping_add(1), Ordering::Release);
    }

    /// Consumer side: move every recorded span into `out` (in push
    /// order) and free the slots.
    pub fn drain_into(&self, out: &mut Vec<Span>) {
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        while tail != head {
            out.push(unsafe { *self.slots[tail % self.slots.len()].get() });
            tail = tail.wrapping_add(1);
        }
        self.tail.store(tail, Ordering::Release);
    }

    /// Spans lost to a full ring since construction.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

/// The trace state one engine shares with its workers: a common clock
/// epoch (all span timestamps are offsets from it, so lanes line up in
/// the viewer), the engine step counter, and one ring per worker plus a
/// coordinator ring (index `n_shards`).
pub struct TraceShared {
    epoch: Instant,
    step: AtomicU64,
    rings: Vec<SpanRing>,
}

impl TraceShared {
    pub fn new(n_shards: usize, ring_capacity: usize) -> Arc<Self> {
        Arc::new(TraceShared {
            epoch: Instant::now(),
            step: AtomicU64::new(0),
            rings: (0..n_shards + 1)
                .map(|_| SpanRing::new(ring_capacity))
                .collect(),
        })
    }

    pub fn n_shards(&self) -> usize {
        self.rings.len() - 1
    }

    /// Nanoseconds since this trace's epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Advance to a new step; returns its 1-based id.
    pub fn begin_step(&self) -> u64 {
        self.step.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Id of the step currently in flight (0 before the first).
    pub fn step_id(&self) -> u64 {
        self.step.load(Ordering::Relaxed)
    }

    pub fn ring(&self, shard: usize) -> &SpanRing {
        &self.rings[shard]
    }

    pub fn coord_ring(&self) -> &SpanRing {
        self.rings.last().unwrap()
    }

    /// Drain every ring (workers first, coordinator last) into `out`.
    pub fn drain_into(&self, out: &mut Vec<Span>) {
        for ring in &self.rings {
            ring.drain_into(out);
        }
    }

    /// Total spans dropped across all rings.
    pub fn dropped(&self) -> u64 {
        self.rings.iter().map(|r| r.dropped()).sum()
    }
}

/// Chrome trace-event timestamps are microseconds (fractional ok).
fn fmt_us(ns: u64) -> String {
    format!("{:.3}", ns as f64 / 1000.0)
}

/// Append one process's worth of Chrome trace events (metadata + one
/// `"X"` complete event per span) as pre-rendered JSON objects.
/// `n_shards` maps `shard == NO_ID` spans onto the coordinator lane
/// (`tid == n_shards`).
pub fn push_chrome_events(
    events: &mut Vec<String>,
    spans: &[Span],
    pid: usize,
    process: &str,
    n_shards: usize,
) {
    events.push(format!(
        "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \
         \"tid\": 0, \"args\": {{\"name\": \"{process}\"}}}}"
    ));
    for tid in 0..=n_shards {
        let tname = if tid == n_shards {
            "coordinator".to_string()
        } else {
            format!("shard-{tid}")
        };
        events.push(format!(
            "{{\"name\": \"thread_name\", \"ph\": \"M\", \"pid\": {pid}, \
             \"tid\": {tid}, \"args\": {{\"name\": \"{tname}\"}}}}"
        ));
    }
    for s in spans {
        let tid =
            if s.shard == NO_ID { n_shards } else { s.shard as usize };
        let mut args = format!("\"step\": {}", s.step);
        if s.expert != NO_ID {
            args.push_str(&format!(", \"expert\": {}", s.expert));
        }
        if s.chunk != NO_ID {
            args.push_str(&format!(", \"chunk\": {}", s.chunk));
        }
        if s.replica != NO_ID {
            args.push_str(&format!(", \"replica\": {}", s.replica));
        }
        if s.rows > 0 {
            args.push_str(&format!(", \"rows\": {}", s.rows));
        }
        events.push(format!(
            "{{\"name\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \
             \"pid\": {pid}, \"tid\": {tid}, \"args\": {{{args}}}}}",
            s.kind.name(),
            fmt_us(s.start_ns),
            fmt_us(s.dur_ns),
        ));
    }
}

/// Render one span stream as a complete Chrome trace-event document.
/// The output is the dialect `crate::util::json` parses (round-trip
/// asserted in tests) and loads directly in Perfetto.
pub fn chrome_trace_json(spans: &[Span], n_shards: usize) -> String {
    let mut events = Vec::new();
    push_chrome_events(&mut events, spans, 0, "moe", n_shards);
    format!("{{\"traceEvents\": [{}]}}\n", events.join(", "))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(kind: SpanKind, start: u64) -> Span {
        Span { kind, step: 1, start_ns: start, dur_ns: 10, ..Span::empty() }
    }

    #[test]
    fn ring_preserves_push_order_and_drains_clean() {
        let ring = SpanRing::new(8);
        for i in 0..5 {
            ring.push(span(SpanKind::Compute, i));
        }
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 5);
        for (i, s) in out.iter().enumerate() {
            assert_eq!(s.start_ns, i as u64);
        }
        assert_eq!(ring.dropped(), 0);
        out.clear();
        ring.drain_into(&mut out);
        assert!(out.is_empty(), "second drain must find nothing");
        // the ring is reusable after a drain
        ring.push(span(SpanKind::Route, 99));
        ring.drain_into(&mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].start_ns, 99);
    }

    #[test]
    fn full_ring_drops_and_counts_instead_of_blocking() {
        let ring = SpanRing::new(4);
        for i in 0..10 {
            ring.push(span(SpanKind::Gather, i));
        }
        assert_eq!(ring.dropped(), 6);
        let mut out = Vec::new();
        ring.drain_into(&mut out);
        // the *first* 4 survive: a full ring never overwrites undrained
        assert_eq!(out.len(), 4);
        assert_eq!(out[0].start_ns, 0);
        assert_eq!(out[3].start_ns, 3);
    }

    #[test]
    fn trace_shared_steps_and_drains_all_rings() {
        let tr = TraceShared::new(3, 16);
        assert_eq!(tr.n_shards(), 3);
        assert_eq!(tr.step_id(), 0);
        assert_eq!(tr.begin_step(), 1);
        assert_eq!(tr.begin_step(), 2);
        assert_eq!(tr.step_id(), 2);
        tr.ring(0).push(span(SpanKind::Compute, 1));
        tr.ring(2).push(span(SpanKind::Combine, 2));
        tr.coord_ring().push(span(SpanKind::Step, 0));
        let mut out = Vec::new();
        tr.drain_into(&mut out);
        assert_eq!(out.len(), 3);
        assert_eq!(tr.dropped(), 0);
    }

    #[test]
    fn chrome_export_is_parseable_and_schema_valid() {
        let spans = vec![
            Span {
                kind: SpanKind::Compute,
                step: 1,
                shard: 0,
                expert: 3,
                chunk: 128,
                replica: NO_ID,
                rows: 64,
                start_ns: 1_500,
                dur_ns: 2_000,
            },
            Span {
                kind: SpanKind::Step,
                step: 1,
                shard: NO_ID,
                expert: NO_ID,
                chunk: NO_ID,
                replica: NO_ID,
                rows: 0,
                start_ns: 0,
                dur_ns: 10_000,
            },
        ];
        let doc = chrome_trace_json(&spans, 2);
        let v = crate::util::json::parse(&doc).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process_name + 3 thread_name + 2 spans
        assert_eq!(events.len(), 6);
        let xs: Vec<_> = events
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(xs.len(), 2);
        let compute = &xs[0];
        assert_eq!(compute.get("name").unwrap().as_str(), Some("compute"));
        assert_eq!(compute.get("tid").unwrap().as_usize(), Some(0));
        assert_eq!(compute.get("ts").unwrap().as_f64(), Some(1.5));
        assert_eq!(compute.get("dur").unwrap().as_f64(), Some(2.0));
        let args = compute.get("args").unwrap();
        assert_eq!(args.get("expert").unwrap().as_usize(), Some(3));
        assert_eq!(args.get("chunk").unwrap().as_usize(), Some(128));
        assert!(args.get("replica").is_none(), "NO_ID fields omitted");
        // the coordinator span lands on the coordinator lane
        assert_eq!(xs[1].get("tid").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn rings_move_spans_across_threads() {
        let tr = TraceShared::new(2, 1024);
        let t2 = Arc::clone(&tr);
        let h = std::thread::spawn(move || {
            for i in 0..100 {
                t2.ring(1).push(span(SpanKind::Compute, i));
            }
        });
        h.join().unwrap();
        let mut out = Vec::new();
        tr.drain_into(&mut out);
        assert_eq!(out.len(), 100);
        assert_eq!(out[99].start_ns, 99);
    }
}
