//! Observability: structured tracing + the unified metrics registry.
//!
//! Two halves, one contract:
//!
//! - [`trace`] — per-worker lock-free span rings ([`SpanRing`]) the
//!   engine's shard workers record route / gather / compute / combine /
//!   retry intervals into, drained by the coordinator at step-end
//!   quiescence and exportable as Chrome trace-event JSON
//!   ([`chrome_trace_json`], `repro trace` → `trace.json`, loadable in
//!   Perfetto).  Zero-cost when disabled (the engine holds
//!   `Option<Arc<TraceShared>>` — one branch per job when `None`) and
//!   **bit-neutral** when enabled: recording reads clocks and writes
//!   rings, nothing else, so traced outputs are bit-identical to
//!   untraced ones (`rust/tests/obs.rs`).
//! - [`registry`] — typed counters / gauges / histograms every stats
//!   producer publishes into (`StepStats::publish`,
//!   `ServeStats::publish`, `FaultTally::publish`, chaos and cluster
//!   points), with one snapshot format rendered as JSON or
//!   Prometheus-style text.  The console reporters (`phase_line`,
//!   `serve_phase_line`, `summary_line`) are renderers over
//!   [`Snapshot`]s, so console, JSON and exposition always agree.
//!
//! [`ObsConfig`] gates both: constructed explicitly
//! (`Scheduler::with_obs`) or from the environment
//! ([`ObsConfig::from_env`], `MOE_TRACE=1`).  The enabled-vs-disabled
//! overhead is measured in `benches/obs.rs` → `BENCH_obs.json` and
//! budgeted at < 5% in CI.

pub mod registry;
pub mod trace;

pub use registry::{key, HistSummary, Registry, Snapshot};
pub use trace::{
    chrome_trace_json, push_chrome_events, Span, SpanKind, SpanRing,
    TraceShared, NO_ID,
};

/// Observability switches, fixed at engine start (the workers are
/// spawned with or without ring handles).
#[derive(Clone, Debug)]
pub struct ObsConfig {
    /// record spans (default off — tracing is opt-in per engine)
    pub tracing: bool,
    /// per-worker ring capacity in spans; a full ring drops (counted)
    pub ring_capacity: usize,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig { tracing: false, ring_capacity: 8192 }
    }
}

impl ObsConfig {
    /// Tracing on with default ring sizing.
    pub fn enabled() -> Self {
        ObsConfig { tracing: true, ..Default::default() }
    }

    /// `MOE_TRACE` set (and not `0`/empty) turns tracing on — the
    /// ambient default every `Scheduler` starts from, so any demo or
    /// bench can be traced without code changes.
    pub fn from_env() -> Self {
        let tracing = std::env::var("MOE_TRACE")
            .map(|v| !v.is_empty() && v != "0")
            .unwrap_or(false);
        ObsConfig { tracing, ..Default::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_off_and_enabled_is_on() {
        assert!(!ObsConfig::default().tracing);
        assert!(ObsConfig::enabled().tracing);
        assert!(ObsConfig::default().ring_capacity >= 2);
    }
}
