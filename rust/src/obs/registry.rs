//! Unified metrics registry: typed counters / gauges / histograms with
//! one snapshot format.
//!
//! Every telemetry producer in the repo — `StepStats`, `ServeStats`,
//! `FaultTally`, chaos points, per-link cluster traffic — publishes
//! into a [`Registry`] through a `publish(&self, &mut Registry)`
//! method, and every human-facing report (`phase_line`,
//! `serve_phase_line`, `ServeStats::summary_line`) renders from the
//! resulting [`Snapshot`] rather than reaching into ad-hoc struct
//! fields.  That makes the registry the single source of truth: the
//! same numbers feed the console lines, the JSON snapshot
//! ([`Snapshot::to_json`], parseable by `crate::util::json`) and the
//! Prometheus-style text exposition ([`Snapshot::to_prometheus`]).
//!
//! Metric identity is a canonical key built by [`key`]:
//! `name{label="value",...}` with caller-ordered labels — the same
//! string in both export formats, so a metric seen in the console can
//! be grepped verbatim in the exposition.

use std::collections::BTreeMap;

use crate::util::bench::Histogram;

/// Canonical metric key: `name` alone, or `name{k="v",k2="v2"}`.
/// Labels render in the order given — callers keep them sorted so
/// equal metrics always share one key.
pub fn key(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let inner = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{v}\""))
        .collect::<Vec<_>>()
        .join(",");
    format!("{name}{{{inner}}}")
}

/// Insert one more label into an existing canonical key (used by the
/// Prometheus renderer to add `quantile` to histogram keys).
fn with_label(k: &str, label: &str, value: &str) -> String {
    match k.strip_suffix('}') {
        Some(head) => format!("{head},{label}=\"{value}\"}}"),
        None => format!("{k}{{{label}=\"{value}\"}}"),
    }
}

/// Base metric name of a canonical key (everything before `{`).
fn base(k: &str) -> &str {
    k.split('{').next().unwrap_or(k)
}

/// Typed metric store.  Counters are monotonic `u64` sums, gauges are
/// last-write-wins `f64` (with an additive variant for mass-style
/// values), histograms are exact nanosecond sample sets
/// ([`crate::util::bench::Histogram`] — same nearest-rank percentile
/// convention as the bench harness and `ServeStats`).
#[derive(Clone, Debug, Default)]
pub struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Histogram>,
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn counter_add(&mut self, key: &str, v: u64) {
        *self.counters.entry(key.to_string()).or_insert(0) += v;
    }

    pub fn gauge_set(&mut self, key: &str, v: f64) {
        self.gauges.insert(key.to_string(), v);
    }

    pub fn gauge_add(&mut self, key: &str, v: f64) {
        *self.gauges.entry(key.to_string()).or_insert(0.0) += v;
    }

    /// Max-combining gauge for high-water marks (`peak_queue_depth` and
    /// friends): re-publishing the same peak is idempotent, and merging
    /// replays keeps the maximum rather than summing.
    pub fn gauge_max(&mut self, key: &str, v: f64) {
        let e = self.gauges.entry(key.to_string()).or_insert(v);
        if v > *e {
            *e = v;
        }
    }

    /// Record one nanosecond sample into the named histogram.
    pub fn observe_ns(&mut self, key: &str, ns: u64) {
        self.hists.entry(key.to_string()).or_default().push(ns);
    }

    /// Merge a whole pre-accumulated histogram (e.g. a `ServeStats`
    /// latency histogram) into the named one, sample for sample.
    pub fn merge_hist(&mut self, key: &str, h: &Histogram) {
        self.hists.entry(key.to_string()).or_default().merge(h);
    }

    pub fn counter(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    pub fn gauge(&self, key: &str) -> f64 {
        self.gauges.get(key).copied().unwrap_or(0.0)
    }

    /// Freeze the current values into an immutable, sorted snapshot.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            counters: self
                .counters
                .iter()
                .map(|(k, &v)| (k.clone(), v))
                .collect(),
            gauges: self
                .gauges
                .iter()
                .map(|(k, &v)| (k.clone(), v))
                .collect(),
            hists: self
                .hists
                .iter()
                .map(|(k, h)| {
                    let q = h.percentiles(&[0.5, 0.95, 0.99]);
                    (
                        k.clone(),
                        HistSummary {
                            count: h.count() as u64,
                            mean_ns: h.mean_ns(),
                            p50_ns: q[0],
                            p95_ns: q[1],
                            p99_ns: q[2],
                            max_ns: h.max_ns(),
                        },
                    )
                })
                .collect(),
        }
    }
}

/// Percentile summary of one histogram at snapshot time (nearest-rank,
/// matching [`Histogram::percentile`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_ns: u64,
    pub p50_ns: u64,
    pub p95_ns: u64,
    pub p99_ns: u64,
    pub max_ns: u64,
}

/// An immutable, key-sorted view of a [`Registry`] — what renderers
/// format and exporters serialize.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, f64)>,
    pub hists: Vec<(String, HistSummary)>,
}

impl Snapshot {
    pub fn counter(&self, key: &str) -> u64 {
        match self.counters.binary_search_by(|(k, _)| k.as_str().cmp(key)) {
            Ok(i) => self.counters[i].1,
            Err(_) => 0,
        }
    }

    pub fn gauge(&self, key: &str) -> f64 {
        match self.gauges.binary_search_by(|(k, _)| k.as_str().cmp(key)) {
            Ok(i) => self.gauges[i].1,
            Err(_) => 0.0,
        }
    }

    pub fn hist(&self, key: &str) -> Option<&HistSummary> {
        self.hists
            .binary_search_by(|(k, _)| k.as_str().cmp(key))
            .ok()
            .map(|i| &self.hists[i].1)
    }

    /// JSON document — exactly the dialect `crate::util::json` parses
    /// (round-trip asserted in tests):
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
    pub fn to_json(&self) -> String {
        use crate::util::bench::{json_num, json_str};
        let counters = self
            .counters
            .iter()
            .map(|(k, v)| format!("{}: {v}", json_str(k)))
            .collect::<Vec<_>>()
            .join(", ");
        let gauges = self
            .gauges
            .iter()
            .map(|(k, v)| format!("{}: {}", json_str(k), json_num(*v)))
            .collect::<Vec<_>>()
            .join(", ");
        let hists = self
            .hists
            .iter()
            .map(|(k, h)| {
                format!(
                    "{}: {{\"count\": {}, \"mean_ns\": {}, \"p50_ns\": {}, \
                     \"p95_ns\": {}, \"p99_ns\": {}, \"max_ns\": {}}}",
                    json_str(k),
                    h.count,
                    h.mean_ns,
                    h.p50_ns,
                    h.p95_ns,
                    h.p99_ns,
                    h.max_ns
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{{\"counters\": {{{counters}}}, \"gauges\": {{{gauges}}}, \
             \"histograms\": {{{hists}}}}}\n"
        )
    }

    /// Prometheus-style text exposition: `# TYPE` comments per base
    /// name, histograms as summary quantiles plus `_count` / `_max_ns`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut last = String::new();
        for (k, v) in &self.counters {
            if base(k) != last {
                last = base(k).to_string();
                out.push_str(&format!("# TYPE {last} counter\n"));
            }
            out.push_str(&format!("{k} {v}\n"));
        }
        last.clear();
        for (k, v) in &self.gauges {
            if base(k) != last {
                last = base(k).to_string();
                out.push_str(&format!("# TYPE {last} gauge\n"));
            }
            out.push_str(&format!("{k} {v}\n"));
        }
        for (k, h) in &self.hists {
            out.push_str(&format!("# TYPE {} summary\n", base(k)));
            for (q, v) in [
                ("0.5", h.p50_ns),
                ("0.95", h.p95_ns),
                ("0.99", h.p99_ns),
            ] {
                out.push_str(&format!(
                    "{} {v}\n",
                    with_label(k, "quantile", q)
                ));
            }
            out.push_str(&format!("{}_count {}\n", base(k), h.count));
            out.push_str(&format!("{}_max_ns {}\n", base(k), h.max_ns));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_canonical() {
        assert_eq!(key("moe_waves", &[]), "moe_waves");
        assert_eq!(
            key("link_bytes", &[("link", "inter_host"), ("tier", "2")]),
            "link_bytes{link=\"inter_host\",tier=\"2\"}"
        );
        assert_eq!(
            with_label("x{a=\"b\"}", "quantile", "0.5"),
            "x{a=\"b\",quantile=\"0.5\"}"
        );
        assert_eq!(with_label("x", "quantile", "0.5"), "x{quantile=\"0.5\"}");
    }

    #[test]
    fn counters_gauges_hists_round_trip_through_snapshot() {
        let mut r = Registry::new();
        r.counter_add("served", 3);
        r.counter_add("served", 4);
        r.counter_add(&key("link_bytes", &[("link", "local")]), 100);
        r.gauge_set("live_fraction", 0.75);
        r.gauge_add("mass", 0.5);
        r.gauge_add("mass", 0.25);
        r.gauge_max("peak", 8.0);
        r.gauge_max("peak", 3.0);
        r.gauge_max("peak", 8.0);
        for ns in [10u64, 20, 30, 40, 50] {
            r.observe_ns("lat_ns", ns);
        }
        let s = r.snapshot();
        assert_eq!(s.counter("served"), 7);
        assert_eq!(s.counter("link_bytes{link=\"local\"}"), 100);
        assert_eq!(s.counter("missing"), 0);
        assert_eq!(s.gauge("live_fraction"), 0.75);
        assert_eq!(s.gauge("mass"), 0.75);
        assert_eq!(s.gauge("peak"), 8.0);
        let h = s.hist("lat_ns").unwrap();
        assert_eq!(h.count, 5);
        assert_eq!(h.p50_ns, 30);
        assert_eq!(h.max_ns, 50);
        assert!(h.p50_ns <= h.p95_ns && h.p95_ns <= h.p99_ns);
        assert!(s.hist("missing").is_none());
    }

    #[test]
    fn merge_hist_is_sample_exact() {
        let mut h = Histogram::new();
        for ns in [5u64, 15, 25] {
            h.push(ns);
        }
        let mut r = Registry::new();
        r.observe_ns("lat_ns", 35);
        r.merge_hist("lat_ns", &h);
        let s = r.snapshot();
        assert_eq!(s.hist("lat_ns").unwrap().count, 4);
        assert_eq!(s.hist("lat_ns").unwrap().max_ns, 35);
    }

    #[test]
    fn json_snapshot_parses_and_preserves_values() {
        let mut r = Registry::new();
        r.counter_add("serve_completed", 12);
        r.gauge_set("live_fraction", 0.5);
        r.observe_ns("serve_total_ns", 1000);
        let doc = r.snapshot().to_json();
        let v = crate::util::json::parse(&doc).unwrap();
        assert_eq!(
            v.get("counters")
                .unwrap()
                .get("serve_completed")
                .unwrap()
                .as_usize(),
            Some(12)
        );
        assert_eq!(
            v.get("gauges").unwrap().get("live_fraction").unwrap().as_f64(),
            Some(0.5)
        );
        let h = v.get("histograms").unwrap().get("serve_total_ns").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize(), Some(1));
        assert_eq!(h.get("p50_ns").unwrap().as_usize(), Some(1000));
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let mut r = Registry::new();
        r.counter_add(&key("link_bytes", &[("link", "local")]), 9);
        r.counter_add(&key("link_bytes", &[("link", "xhost")]), 4);
        r.gauge_set("live_fraction", 1.0);
        r.observe_ns("lat_ns", 7);
        let text = r.snapshot().to_prometheus();
        // one TYPE line per base name, not per labeled series
        assert_eq!(text.matches("# TYPE link_bytes counter").count(), 1);
        assert!(text.contains("link_bytes{link=\"local\"} 9\n"));
        assert!(text.contains("link_bytes{link=\"xhost\"} 4\n"));
        assert!(text.contains("# TYPE live_fraction gauge\n"));
        assert!(text.contains("live_fraction 1\n"));
        assert!(text.contains("# TYPE lat_ns summary\n"));
        assert!(text.contains("lat_ns{quantile=\"0.5\"} 7\n"));
        assert!(text.contains("lat_ns_count 1\n"));
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE ")
                    || line.split(' ').count() == 2,
                "malformed exposition line: {line}"
            );
        }
    }
}
