//! Noisy Top-K gating (paper eq 3–5) and the balance statistics
//! (eq 6–11), over plain slices.  Semantics mirror
//! `python/compile/kernels/ref.py` exactly; cross-language agreement is
//! asserted in `rust/tests/parity.rs` through the gating artifact.

use crate::gating::{normal_cdf, softplus};
use crate::util::rng::Rng;

/// One token's gate vector: the `k` selected experts with weights
/// summing to 1.
#[derive(Clone, Debug, PartialEq)]
pub struct GateVec {
    pub experts: Vec<usize>,
    pub weights: Vec<f32>,
}

/// Full gating output for a batch.
#[derive(Clone, Debug)]
pub struct Gating {
    pub n_experts: usize,
    pub per_token: Vec<GateVec>,
    /// clean logits x·W_g, row-major (B, n)
    pub clean: Vec<f32>,
    /// noisy logits H(x), row-major (B, n)
    pub noisy: Vec<f32>,
    /// softplus *input* x·W_noise, row-major (B, n), kept so the load
    /// estimator reuses it instead of recomputing the matmul; `None`
    /// when gating ran without noise weights.
    pub sigma_raw: Option<Vec<f32>>,
}

/// x: (b, d) row-major; w_g, w_noise: (d, n) row-major.  `noise_rng` draws
/// the StandardNormal() term of eq 4; pass `None` for deterministic
/// (eval-time) gating.
pub fn noisy_topk(
    x: &[f32],
    b: usize,
    d: usize,
    w_g: &[f32],
    w_noise: Option<&[f32]>,
    n: usize,
    k: usize,
    noise_rng: Option<&mut Rng>,
) -> Gating {
    // draw the eq-4 normals up front, in the row-major order the
    // pre-streaming code used, so decisions are unchanged and row-blocked
    // callers can hand each block its slice of the same sequence
    let normals: Option<Vec<f32>> = match (w_noise, noise_rng) {
        (Some(_), Some(rng)) => {
            Some((0..b * n).map(|_| rng.normal_f32()).collect())
        }
        _ => None,
    };
    noisy_topk_block(x, b, d, w_g, w_noise, n, k, normals.as_deref())
}

/// Core of [`noisy_topk`] over a row block, with pre-drawn eq-4 normals
/// (`normals[r*n + i]` perturbs logit `i` of block row `r`).  The
/// streaming pipeline routes disjoint row blocks of one batch on
/// different workers; feeding each block its slice of one serially-drawn
/// normal sequence makes the result bit-identical to gating the whole
/// batch at once.
pub fn noisy_topk_block(
    x: &[f32],
    rows: usize,
    d: usize,
    w_g: &[f32],
    w_noise: Option<&[f32]>,
    n: usize,
    k: usize,
    normals: Option<&[f32]>,
) -> Gating {
    noisy_topk_block_masked(x, rows, d, w_g, w_noise, n, k, normals, None)
}

/// [`noisy_topk_block`] with an optional expert mask: masked experts'
/// noisy logits are forced to `-inf` *after* the eq-4 noise add, so
/// they can never be selected and (with at least one live expert in
/// the row) receive exactly-zero softmax weight.  The fault layer uses
/// this to route around permanently dead shards; with `masked: None`
/// the path is byte-for-byte the unmasked one.
#[allow(clippy::too_many_arguments)]
pub fn noisy_topk_block_masked(
    x: &[f32],
    rows: usize,
    d: usize,
    w_g: &[f32],
    w_noise: Option<&[f32]>,
    n: usize,
    k: usize,
    normals: Option<&[f32]>,
    masked: Option<&[bool]>,
) -> Gating {
    assert_eq!(x.len(), rows * d);
    assert_eq!(w_g.len(), d * n);
    assert!(k >= 1 && k <= n, "k={k} n={n}");
    let mut clean = vec![0f32; rows * n];
    matmul(x, w_g, &mut clean, rows, d, n);
    let mut noisy = clean.clone();
    let sigma_raw = w_noise.map(|wn| {
        assert_eq!(wn.len(), d * n);
        let mut raw = vec![0f32; rows * n];
        matmul(x, wn, &mut raw, rows, d, n);
        raw
    });
    if let (Some(raw), Some(eps)) = (&sigma_raw, normals) {
        assert_eq!(eps.len(), rows * n);
        for i in 0..rows * n {
            noisy[i] += eps[i] * softplus(raw[i]);
        }
    }
    if let Some(mask) = masked {
        assert_eq!(mask.len(), n);
        debug_assert!(
            mask.iter().any(|&m| !m),
            "an all-masked row has no valid softmax"
        );
        for r in 0..rows {
            for (i, &dead) in mask.iter().enumerate() {
                if dead {
                    noisy[r * n + i] = f32::NEG_INFINITY;
                }
            }
        }
    }
    let per_token = (0..rows)
        .map(|r| topk_softmax(&noisy[r * n..(r + 1) * n], k))
        .collect();
    Gating { n_experts: n, per_token, clean, noisy, sigma_raw }
}

/// The rank order the original full sort used: descending value, ties
/// broken by lower index (matching `jax.lax.top_k`).  A strict total
/// order for non-NaN inputs.
fn rank(h: &[f32], a: usize, b: usize) -> std::cmp::Ordering {
    h[b].partial_cmp(&h[a])
        .unwrap_or(std::cmp::Ordering::Equal)
        .then(a.cmp(&b))
}

/// Indices of the `k` highest-ranked entries of `h`, in rank order,
/// without sorting the other n-k: an insertion scan for small k, a
/// select-nth partition plus a k-element sort otherwise — O(n + k log k)
/// instead of the old O(n log n) full sort.  Bit-identical to
/// `sort_by(rank); truncate(k)` because `rank` is a strict total order
/// (asserted against [`topk_softmax_via_sort`] by a property test).
/// `pub(crate)` so the gating backward resolves the eq-10 threshold
/// *indices* under exactly the forward's rank rule.
pub(crate) fn select_topk(h: &[f32], k: usize) -> Vec<usize> {
    use std::cmp::Ordering;
    let n = h.len();
    if k == 0 {
        return Vec::new();
    }
    if k >= n {
        let mut idx: Vec<usize> = (0..n).collect();
        idx.sort_unstable_by(|&a, &b| rank(h, a, b));
        return idx;
    }
    if k <= 8 {
        // `best` holds the current top indices in rank order
        let mut best: Vec<usize> = Vec::with_capacity(k + 1);
        for i in 0..n {
            if best.len() == k && rank(h, i, best[k - 1]) != Ordering::Less {
                continue;
            }
            let mut p = best.len();
            while p > 0 && rank(h, i, best[p - 1]) == Ordering::Less {
                p -= 1;
            }
            best.insert(p, i);
            best.truncate(k);
        }
        return best;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.select_nth_unstable_by(k - 1, |&a, &b| rank(h, a, b));
    idx.truncate(k);
    idx.sort_unstable_by(|&a, &b| rank(h, a, b));
    idx
}

fn softmax_over(h: &[f32], idx: Vec<usize>) -> GateVec {
    let max = idx.iter().map(|&i| h[i]).fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = idx.iter().map(|&i| (h[i] - max).exp()).collect();
    let z: f32 = exps.iter().sum();
    GateVec {
        experts: idx,
        weights: exps.into_iter().map(|e| e / z).collect(),
    }
}

/// softmax(KeepTopK(h, k)) for one row; ties broken by lower index,
/// matching `jax.lax.top_k`.  Selection is O(n) partial selection, not a
/// full sort — see [`select_topk`].
pub fn topk_softmax(h: &[f32], k: usize) -> GateVec {
    softmax_over(h, select_topk(h, k))
}

/// The pre-streaming implementation — top-k via a full O(n log n) sort —
/// retained verbatim as the oracle for the partial-selection property
/// test (`topk_partial_selection_matches_sort`).
pub fn topk_softmax_via_sort(h: &[f32], k: usize) -> GateVec {
    let n = h.len();
    let mut idx: Vec<usize> = (0..n).collect();
    // stable selection of the k largest
    idx.sort_by(|&a, &b| rank(h, a, b));
    idx.truncate(k);
    softmax_over(h, idx)
}

/// Importance(X) (eq 6): batchwise sum of gate values per expert.
pub fn importance(g: &Gating) -> Vec<f32> {
    let mut imp = vec![0f32; g.n_experts];
    for tok in &g.per_token {
        for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
            imp[*e] += w;
        }
    }
    imp
}

/// Smooth load estimator Load(X) (eq 8–10), reusing the softplus input
/// x·W_noise that [`noisy_topk`] already computed ([`Gating::sigma_raw`])
/// — the estimator no longer re-runs that matmul, halving gating FLOPs
/// when the load loss is on.  Gatings produced without noise weights
/// (deterministic eval) get the hard assignment count instead.  Row
/// contributions accumulate in row order, so summing disjoint row
/// blocks' results reproduces the whole-batch value up to f32
/// reassociation.
pub fn load_estimate(g: &Gating, k: usize) -> Vec<f32> {
    let n = g.n_experts;
    let b = g.per_token.len();
    let Some(sigma_raw) = &g.sigma_raw else {
        // deterministic gating: Load = hard counts
        let mut load = vec![0f32; n];
        for tok in &g.per_token {
            for &e in &tok.experts {
                load[e] += 1.0;
            }
        }
        return load;
    };
    if k >= n {
        return vec![b as f32; n];
    }
    let mut load = vec![0f32; n];
    let mut row: Vec<f32> = Vec::with_capacity(n);
    for r in 0..b {
        let noisy = &g.noisy[r * n..(r + 1) * n];
        let clean = &g.clean[r * n..(r + 1) * n];
        // k-th and (k+1)-th largest of the noisy row by partial
        // selection: after select-nth under the descending order, slot k
        // holds the (k+1)-th largest and the slots before it the k
        // larger values (so their min is the k-th largest) — the same
        // order statistics the old full sort produced
        row.clear();
        row.extend_from_slice(noisy);
        row.select_nth_unstable_by(k, |a, b| b.partial_cmp(a).unwrap());
        let kth1 = row[k];
        let kth = row[..k].iter().copied().fold(f32::INFINITY, f32::min);
        for i in 0..n {
            let threshold = if noisy[i] >= kth { kth1 } else { kth };
            let sigma = softplus(sigma_raw[r * n + i]) + 1e-10;
            load[i] += normal_cdf((clean[i] - threshold) / sigma);
        }
    }
    load
}

/// CV(v)² (eq 7 / 11); 0 for len <= 1 (matches ref.py).
pub fn cv_squared(v: &[f32]) -> f32 {
    if v.len() <= 1 {
        return 0.0;
    }
    let n = v.len() as f32;
    let mean = v.iter().sum::<f32>() / n;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n;
    var / (mean * mean + 1e-10)
}

/// Compose two-level hierarchical gates (Appendix B eq 12) into effective
/// flat gates over a*b experts: gate(i,j) = primary_i * secondary_{i,j}.
pub fn compose_hierarchical(
    primary: &GateVec,
    secondary_per_group: &[GateVec],
    group_size: usize,
) -> GateVec {
    let mut experts = Vec::new();
    let mut weights = Vec::new();
    for (gi, gw) in primary.experts.iter().zip(primary.weights.iter()) {
        let sec = &secondary_per_group[*gi];
        for (ej, ew) in sec.experts.iter().zip(sec.weights.iter()) {
            experts.push(gi * group_size + ej);
            weights.push(gw * ew);
        }
    }
    GateVec { experts, weights }
}

/// Row-major `(m,k) × (k,n) → (m,n)` on the process-wide selected
/// kernel ([`crate::kernels::Kernel::select`]).
///
/// The original cache-blocked scalar loop lives on verbatim as
/// [`crate::kernels::scalar::ScalarKernel`] — the bit-exact oracle
/// (bit-identical to the naive triple loop, which `MOE_KERNEL=scalar`
/// reproduces).  SIMD kernels contract multiply-adds, so their results
/// are error-budgeted against that oracle (`rust/tests/kernels.rs`)
/// rather than bit-equal.  Engine-vs-serial differentials are
/// unaffected: both sides call the same selected kernel.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    crate::kernels::matmul(a, b, out, m, k, n);
}

/// `out (k, n) += aᵀ · b` for row-major `a (m, k)`, `b (m, n)` on the
/// selected kernel.  The backward-pass workhorse (`dW = xᵀ · dY`),
/// shared by the trainer and the gating backward.  Accumulating —
/// callers zero (or deliberately seed) `out`.
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    crate::kernels::matmul_tn(a, b, out, m, k, n);
}

/// `out (m, n) = a · bᵀ` for row-major `a (m, k)`, `b (n, k)` on the
/// selected kernel.  Now k-blocked even on the scalar path (long
/// `d_model` rows no longer thrash L1 on the backward) — which changes
/// the reduction order vs the old single-pass dot product, so
/// `matmul_nt` results are oracle-budgeted, not bit-stable across this
/// change (per-element order is still fixed and row-independent).
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    crate::kernels::matmul_nt(a, b, out, m, n, k);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn topk_softmax_basics() {
        let g = topk_softmax(&[1.0, 3.0, 2.0, -1.0], 2);
        assert_eq!(g.experts, vec![1, 2]);
        assert!((g.weights.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(g.weights[0] > g.weights[1]);
    }

    #[test]
    fn topk_ties_prefer_lower_index() {
        let g = topk_softmax(&[2.0, 2.0, 2.0], 2);
        assert_eq!(g.experts, vec![0, 1]);
    }

    #[test]
    fn duplicate_logits_select_deterministically_and_match_sort_oracle() {
        // duplicates spanning the k boundary: selection must be the rank
        // rule (higher value, then lower index) and bit-identical to the
        // retained full-sort oracle for every k
        let h = [1.0f32, 2.0, 2.0, 2.0, 0.5, 2.0];
        for k in 1..=h.len() {
            let fast = topk_softmax(&h, k);
            let slow = topk_softmax_via_sort(&h, k);
            assert_eq!(fast.experts, slow.experts, "k={k}");
            assert_eq!(fast.weights, slow.weights, "k={k} (bitwise)");
        }
        // the four tied 2.0s win in index order before the rest
        assert_eq!(topk_softmax(&h, 2).experts, vec![1, 2]);
        assert_eq!(topk_softmax(&h, 4).experts, vec![1, 2, 3, 5]);
        // rerunning the same row is bit-stable
        let a = topk_softmax(&h, 3);
        let b = topk_softmax(&h, 3);
        assert_eq!(a.experts, b.experts);
        assert_eq!(a.weights, b.weights);
    }

    #[test]
    fn all_equal_rows_select_lowest_indices_in_every_branch() {
        // n/k chosen to exercise all three selection branches: k >= n,
        // the k <= 8 insertion scan, and the select-nth partition
        for n in [1usize, 3, 9, 17] {
            let h = vec![3.25f32; n];
            for k in [1, 2, (n + 1) / 2, 9, n] {
                let k = k.clamp(1, n);
                let fast = topk_softmax(&h, k);
                assert_eq!(
                    fast.experts,
                    (0..k).collect::<Vec<_>>(),
                    "n={n} k={k}: all-equal row must pick the lowest indices"
                );
                let slow = topk_softmax_via_sort(&h, k);
                assert_eq!(fast.experts, slow.experts, "n={n} k={k}");
                assert_eq!(fast.weights, slow.weights, "n={n} k={k} (bitwise)");
                // equal logits get exactly equal gate weights
                for w in &fast.weights {
                    assert_eq!(*w, fast.weights[0], "n={n} k={k}");
                }
            }
        }
    }

    #[test]
    fn topk_partial_selection_matches_sort() {
        // the O(n) selection must be bit-identical to the retained full
        // sort, across randomized rows including exact ties and all
        // three selection branches (k >= n, insertion, select-nth)
        prop::forall("topk == sort", |rng| {
            let n = prop::dim(rng, 1, 40);
            let k = prop::dim(rng, 1, n);
            // quantized values force frequent exact ties
            let ties: Vec<f32> =
                (0..n).map(|_| rng.below(6) as f32 * 0.5 - 1.0).collect();
            let smooth = prop::vec_f32(rng, n, 1.0);
            for h in [&ties, &smooth] {
                let fast = topk_softmax(h, k);
                let slow = topk_softmax_via_sort(h, k);
                assert_eq!(fast.experts, slow.experts, "k={k} h={h:?}");
                assert_eq!(fast.weights, slow.weights, "k={k} h={h:?}");
            }
        });
    }

    #[test]
    fn gates_sum_to_one_property() {
        prop::forall("gates normalized", |rng| {
            let (b, d) = (prop::dim(rng, 1, 12), prop::dim(rng, 1, 8));
            let n = prop::dim(rng, 2, 16);
            let k = prop::dim(rng, 1, n.min(4));
            let x = prop::vec_f32(rng, b * d, 1.0);
            let wg = prop::vec_f32(rng, d * n, 0.5);
            let wn = prop::vec_f32(rng, d * n, 0.5);
            let mut nrng = rng.fold_in(1);
            let g = noisy_topk(&x, b, d, &wg, Some(&wn), n, k, Some(&mut nrng));
            for tok in &g.per_token {
                assert_eq!(tok.experts.len(), k);
                let s: f32 = tok.weights.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "sum={s}");
                // selected experts are distinct
                let mut e = tok.experts.clone();
                e.sort();
                e.dedup();
                assert_eq!(e.len(), k);
            }
        });
    }

    #[test]
    fn masked_experts_are_never_selected_and_none_mask_is_identity() {
        prop::forall("masked gating", |rng| {
            let (b, d) = (prop::dim(rng, 1, 10), prop::dim(rng, 1, 6));
            let n = prop::dim(rng, 3, 12);
            let k = prop::dim(rng, 1, (n - 1).min(3));
            let x = prop::vec_f32(rng, b * d, 1.0);
            let wg = prop::vec_f32(rng, d * n, 0.5);
            let wn = prop::vec_f32(rng, d * n, 0.5);
            let normals = prop::vec_f32(rng, b * n, 1.0);
            // mask up to n-k experts so k live ones always remain
            let mut mask = vec![false; n];
            for _ in 0..prop::dim(rng, 1, n - k) {
                mask[rng.below(n)] = true;
            }
            while mask.iter().filter(|&&m| !m).count() < k {
                mask[rng.below(n)] = false;
            }
            let g = noisy_topk_block_masked(
                &x, b, d, &wg, Some(&wn), n, k, Some(&normals), Some(&mask),
            );
            for tok in &g.per_token {
                for (&e, &w) in tok.experts.iter().zip(tok.weights.iter()) {
                    assert!(!mask[e], "masked expert {e} selected");
                    assert!(w.is_finite() && w >= 0.0);
                }
                let s: f32 = tok.weights.iter().sum();
                assert!((s - 1.0).abs() < 1e-5, "sum={s}");
            }
            // masked: None is byte-identical to the unmasked entry point
            let a = noisy_topk_block(
                &x, b, d, &wg, Some(&wn), n, k, Some(&normals),
            );
            let bm = noisy_topk_block_masked(
                &x, b, d, &wg, Some(&wn), n, k, Some(&normals), None,
            );
            for (ta, tb) in a.per_token.iter().zip(&bm.per_token) {
                assert_eq!(ta.experts, tb.experts);
                let wa: Vec<u32> =
                    ta.weights.iter().map(|w| w.to_bits()).collect();
                let wb: Vec<u32> =
                    tb.weights.iter().map(|w| w.to_bits()).collect();
                assert_eq!(wa, wb);
            }
        });
    }

    #[test]
    fn importance_counts_weights() {
        let g = Gating {
            n_experts: 3,
            per_token: vec![
                GateVec { experts: vec![0, 2], weights: vec![0.7, 0.3] },
                GateVec { experts: vec![0, 1], weights: vec![0.5, 0.5] },
            ],
            clean: vec![],
            noisy: vec![],
            sigma_raw: None,
        };
        assert_eq!(importance(&g), vec![1.2, 0.5, 0.3]);
    }

    #[test]
    fn load_estimate_sums_to_kb_roughly() {
        // sum_i Load_i ≈ k * B  (each token selects exactly k experts and
        // P is a smooth estimate of selection)
        prop::forall("load mass", |rng| {
            let (b, d, n, k) = (8, 4, prop::dim(rng, 4, 10), 2);
            let x = prop::vec_f32(rng, b * d, 1.0);
            let wg = prop::vec_f32(rng, d * n, 0.6);
            let wn = prop::vec_f32(rng, d * n, 0.3);
            let mut nrng = rng.fold_in(9);
            let g = noisy_topk(&x, b, d, &wg, Some(&wn), n, k, Some(&mut nrng));
            let load = load_estimate(&g, k);
            let total: f32 = load.iter().sum();
            let want = (k * b) as f32;
            assert!(
                (total - want).abs() < want * 0.5,
                "total={total} want≈{want}"
            );
        });
    }

    #[test]
    fn scalar_kernel_matmul_matches_naive_reference_bitwise() {
        // the bit-exactness claim belongs to the scalar oracle kernel;
        // the dispatched kernel (possibly SIMD) is covered by the
        // error-budgeted oracle tests in rust/tests/kernels.rs
        use crate::kernels::MatmulKernel;
        let scalar = crate::kernels::Kernel::scalar();
        prop::forall("blocked matmul", |rng| {
            let m = prop::dim(rng, 1, 9);
            // spans the KB=64 / JB=256 block edges
            let k = prop::dim(rng, 1, 70);
            let n = prop::dim(rng, 1, 300);
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, k * n, 1.0);
            let mut fast = vec![0f32; m * n];
            scalar.matmul(&a, &b, &mut fast, m, k, n);
            let mut naive = vec![0f32; m * n];
            for i in 0..m {
                for l in 0..k {
                    for j in 0..n {
                        naive[i * n + j] += a[i * k + l] * b[l * n + j];
                    }
                }
            }
            for (f, v) in fast.iter().zip(naive.iter()) {
                assert_eq!(f, v, "scalar matmul must be bit-exact");
            }
        });
    }

    #[test]
    fn dispatched_matmul_matches_naive_within_budget() {
        // whatever kernel Kernel::select() resolved to must still agree
        // with the naive reference to SIMD-reassociation tolerance
        prop::forall("dispatched matmul", |rng| {
            let m = prop::dim(rng, 1, 5);
            let k = prop::dim(rng, 1, 70);
            let n = prop::dim(rng, 1, 70);
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, k * n, 1.0);
            let mut fast = vec![0f32; m * n];
            matmul(&a, &b, &mut fast, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..k)
                        .map(|l| a[i * k + l] as f64 * b[l * n + j] as f64)
                        .sum();
                    let got = fast[i * n + j] as f64;
                    assert!(
                        (got - want).abs() <= 1e-4 * want.abs().max(1.0),
                        "[{i},{j}]: {got} vs {want}"
                    );
                }
            }
        });
    }

    #[test]
    fn transpose_matmuls_match_naive() {
        prop::forall("tn/nt matmuls", |rng| {
            let (m, k, n) = (
                prop::dim(rng, 1, 6),
                prop::dim(rng, 1, 5),
                prop::dim(rng, 1, 4),
            );
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, m * n, 1.0);
            let mut got = vec![0f32; k * n];
            matmul_tn(&a, &b, &mut got, m, k, n);
            for p in 0..k {
                for q in 0..n {
                    let want: f32 =
                        (0..m).map(|i| a[i * k + p] * b[i * n + q]).sum();
                    assert!((got[p * n + q] - want).abs() < 1e-4);
                }
            }
            let c = prop::vec_f32(rng, n * k, 1.0);
            let mut got = vec![0f32; m * n];
            matmul_nt(&a, &c, &mut got, m, n, k);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 =
                        (0..k).map(|l| a[i * k + l] * c[j * k + l]).sum();
                    assert!((got[i * n + j] - want).abs() < 1e-4);
                }
            }
        });
    }

    #[test]
    fn cv_squared_matches_definition() {
        assert_eq!(cv_squared(&[1.0]), 0.0);
        assert!(cv_squared(&[2.0, 2.0, 2.0]) < 1e-9);
        let v = [1.0f32, 3.0];
        // mean 2, var 1 -> cv^2 = 0.25
        assert!((cv_squared(&v) - 0.25).abs() < 1e-6);
    }

    #[test]
    fn hierarchical_composition_weights_multiply() {
        let primary = GateVec { experts: vec![1, 0], weights: vec![0.6, 0.4] };
        let secs = vec![
            GateVec { experts: vec![0], weights: vec![1.0] },
            GateVec { experts: vec![2], weights: vec![1.0] },
        ];
        let flat = compose_hierarchical(&primary, &secs, 4);
        assert_eq!(flat.experts, vec![1 * 4 + 2, 0]);
        assert_eq!(flat.weights, vec![0.6, 0.4]);
    }
}
