//! Strictly-balanced gating (paper Appendix F).
//!
//! Training time: `batchwise_mask` keeps, per expert, the top
//! m = k·|X|/n scores across the batch so every expert receives exactly m
//! examples (eq 18).  Inference time: per-expert learned thresholds
//! (eq 19), trained here with the paper's threshold loss (eq 20) via its
//! (sub)gradient — the loss is piecewise linear in T.

use crate::gating::noisy_topk::GateVec;

/// scores: (b, n) row-major softmax gate scores; keeps top-m per expert.
/// Returns a boolean mask (b, n).
pub fn batchwise_mask(scores: &[f32], b: usize, n: usize, m: usize) -> Vec<bool> {
    assert!(m <= b, "m={m} must be <= batch {b}");
    let mut mask = vec![false; b * n];
    let mut col: Vec<(f32, usize)> = Vec::with_capacity(b);
    for e in 0..n {
        col.clear();
        col.extend((0..b).map(|r| (scores[r * n + e], r)));
        // sort descending by score, stable on row index
        col.sort_by(|a, b| {
            b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal)
                .then(a.1.cmp(&b.1))
        });
        for &(_, r) in col.iter().take(m) {
            mask[r * n + e] = true;
        }
    }
    mask
}

/// Inference-time mask M_threshold (eq 19).
pub fn threshold_inference(scores: &[f32], b: usize, n: usize, t: &[f32]) -> Vec<bool> {
    assert_eq!(t.len(), n);
    (0..b * n).map(|i| scores[i] > t[i % n]).collect()
}

/// Renormalised gates under a mask (eq 16).
pub fn masked_gates(scores: &[f32], mask: &[bool], b: usize, n: usize) -> Vec<GateVec> {
    (0..b)
        .map(|r| {
            let mut experts = Vec::new();
            let mut weights = Vec::new();
            let mut z = 0f32;
            for e in 0..n {
                if mask[r * n + e] {
                    experts.push(e);
                    weights.push(scores[r * n + e]);
                    z += scores[r * n + e];
                }
            }
            for w in &mut weights {
                *w /= z.max(1e-10);
            }
            GateVec { experts, weights }
        })
        .collect()
}

/// Appendix-F threshold learner.  Maintains per-expert thresholds T and
/// minimises L_batchwise (eq 20) by gradient descent on its subgradient:
/// dL/dT_i = Σ_j (M_batchwise − M_threshold)_{j,i}  (the (X_{j,i} − T_i)
/// factor's sign pattern makes disagreement always push T the right way).
pub struct BalancedGater {
    pub n: usize,
    pub m: usize,
    pub thresholds: Vec<f32>,
    pub lr: f32,
}

impl BalancedGater {
    pub fn new(n: usize, m: usize, lr: f32) -> Self {
        BalancedGater { n, m, thresholds: vec![0.5; n], lr }
    }

    /// Training-time gating: batchwise mask + threshold update.
    /// Returns (gates, loss eq 20).
    pub fn train_batch(&mut self, scores: &[f32], b: usize) -> (Vec<GateVec>, f32) {
        let n = self.n;
        let mb = batchwise_mask(scores, b, n, self.m);
        let mt = threshold_inference(scores, b, n, &self.thresholds);
        let mut loss = 0f32;
        let mut grad = vec![0f32; n];
        for r in 0..b {
            for e in 0..n {
                let i = r * n + e;
                let diff = (mt[i] as i32 - mb[i] as i32) as f32;
                loss += diff * (scores[i] - self.thresholds[e]);
                grad[e] -= diff; // d/dT of the (x - T) factor, masks frozen
            }
        }
        for e in 0..n {
            self.thresholds[e] -= self.lr * grad[e];
        }
        (masked_gates(scores, &mb, b, n), loss)
    }

    /// Inference-time gating with the learned thresholds.
    pub fn infer_batch(&self, scores: &[f32], b: usize) -> Vec<GateVec> {
        let mt = threshold_inference(scores, b, self.n, &self.thresholds);
        masked_gates(scores, &mt, b, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{prop, rng::Rng};

    fn softmax_rows(raw: &mut [f32], b: usize, n: usize) {
        for r in 0..b {
            let row = &mut raw[r * n..(r + 1) * n];
            let mx = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut z = 0f32;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                z += *v;
            }
            for v in row.iter_mut() {
                *v /= z;
            }
        }
    }

    #[test]
    fn batchwise_mask_exactly_m_per_expert() {
        prop::forall("mask column sums", |rng| {
            let (b, n) = (prop::dim(rng, 4, 24), prop::dim(rng, 2, 8));
            let m = prop::dim(rng, 1, b);
            let mut s = prop::vec_f32(rng, b * n, 1.0);
            softmax_rows(&mut s, b, n);
            let mask = batchwise_mask(&s, b, n, m);
            for e in 0..n {
                let cnt = (0..b).filter(|r| mask[r * n + e]).count();
                assert_eq!(cnt, m);
            }
        });
    }

    #[test]
    fn masked_gates_renormalise() {
        let scores = vec![0.5, 0.3, 0.2, 0.1, 0.6, 0.3];
        let mask = vec![true, false, true, true, true, false];
        let gates = masked_gates(&scores, &mask, 2, 3);
        for g in &gates {
            assert!((g.weights.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
        assert_eq!(gates[0].experts, vec![0, 2]);
    }

    #[test]
    fn threshold_learner_converges_to_batchwise_mask() {
        // Stationary score distribution: after training, the threshold
        // mask should agree with the batchwise mask on ~all entries.
        let (b, n, m) = (32, 4, 8);
        let mut gater = BalancedGater::new(n, m, 0.002);
        let mut rng = Rng::new(5);
        let mut last_agree = 0.0;
        for it in 0..400 {
            let mut s = prop::vec_f32(&mut rng, b * n, 1.0);
            softmax_rows(&mut s, b, n);
            gater.train_batch(&s, b);
            if it >= 399 {
                let mb = batchwise_mask(&s, b, n, m);
                let mt = threshold_inference(&s, b, n, &gater.thresholds);
                let agree = mb
                    .iter()
                    .zip(mt.iter())
                    .filter(|(a, b)| a == b)
                    .count() as f32
                    / (b * n) as f32;
                last_agree = agree;
            }
        }
        assert!(last_agree > 0.85, "agreement {last_agree}");
    }

    #[test]
    fn inference_uses_thresholds() {
        let mut g = BalancedGater::new(2, 1, 0.1);
        g.thresholds = vec![0.4, 0.6];
        let scores = vec![0.5, 0.5, 0.3, 0.7];
        let gates = g.infer_batch(&scores, 2);
        assert_eq!(gates[0].experts, vec![0]); // 0.5 > 0.4, 0.5 < 0.6... no
        assert_eq!(gates[1].experts, vec![1]);
    }
}
