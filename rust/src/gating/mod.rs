//! Pure-rust mirror of the gating math (paper §2.1, Appendices A & F).
//!
//! The L3 coordinator needs gating decisions *outside* the XLA graph to
//! build its all-to-all dispatch plan, and the tests need an independent
//! oracle for the L1 kernel semantics.  This module implements:
//!
//! - noisy top-k gating (eq 3–5),
//! - the smooth load estimator P(x,i) / Load(X) (eq 8–10),
//! - importance / CV² balance statistics (eq 6–7, 11),
//! - strictly-balanced batchwise gating (Appendix F, eq 16–20),
//! - two-level hierarchical gate composition (Appendix B, eq 12),
//! - the exact analytic backward of all of the above ([`backward`]):
//!   task-loss gradients through the top-k softmax and the eq-4 noise
//!   path, and the eq-6/7 importance and eq-8 smooth-load balance-loss
//!   gradients into `w_g` / `w_noise`.
//!
//! # Matmul contract (kernel layer)
//!
//! The matmuls here ([`noisy_topk::matmul`] and friends) dispatch
//! through [`crate::kernels`].  The old contract — "bit-identical to
//! the naive triple loop" — now belongs to the **scalar oracle kernel**
//! only (`MOE_KERNEL=scalar` restores it process-wide); the dispatched
//! kernel may be SIMD (AVX2/NEON) and is **error-budgeted** against
//! that oracle instead (`rust/tests/kernels.rs`).  All same-process
//! bit-equality proofs (engine vs serial, row-blocked vs whole-batch
//! gating) are unaffected: every path shares the one selected kernel,
//! and every kernel keeps row independence and a fixed per-element
//! reduction order.

pub mod backward;
pub mod balanced;
pub mod noisy_topk;

pub use balanced::{batchwise_mask, threshold_inference, BalancedGater};
pub use noisy_topk::{
    cv_squared, importance, load_estimate, noisy_topk, GateVec, Gating,
};

/// Numerically-stable softplus, matching `jax.nn.softplus`.
pub fn softplus(x: f32) -> f32 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        (1.0 + x.exp()).ln()
    }
}

/// Logistic sigmoid — the derivative of [`softplus`] (in every branch:
/// d/dx x = 1 ≈ σ(x>30), d/dx eˣ = eˣ ≈ σ(x<-30) to f32 precision).
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Standard normal density φ — the derivative of [`normal_cdf`].
pub fn normal_pdf(x: f32) -> f32 {
    const INV_SQRT_2PI: f64 = 0.398_942_280_401_432_7;
    ((-0.5 * (x as f64) * (x as f64)).exp() * INV_SQRT_2PI) as f32
}

/// Standard normal CDF Φ via erf (Abramowitz–Stegun 7.1.26 is not enough
/// precision for the load test; use the erf series from W. J. Cody).
pub fn normal_cdf(x: f32) -> f32 {
    0.5 * (1.0 + erf(x as f64 / std::f64::consts::SQRT_2)) as f32
}

/// erf with ~1e-7 absolute error (sufficient: paper's load estimator is
/// compared against Monte-Carlo at ~1e-2).
pub fn erf(x: f64) -> f64 {
    // Abramowitz & Stegun 7.1.26 with double-precision constants
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softplus_matches_limits() {
        assert!((softplus(0.0) - 0.6931472).abs() < 1e-6);
        assert!((softplus(40.0) - 40.0).abs() < 1e-6);
        assert!(softplus(-40.0) > 0.0);
        assert!(softplus(-40.0) < 1e-15);
    }

    #[test]
    fn sigmoid_is_softplus_derivative() {
        for x in [-35.0f32, -3.0, -0.1, 0.0, 0.7, 4.0, 35.0] {
            let h = 1e-3f32;
            let fd = (softplus(x + h) - softplus(x - h)) / (2.0 * h);
            assert!(
                (sigmoid(x) - fd).abs() < 1e-3,
                "x={x}: sigmoid {} vs fd {fd}",
                sigmoid(x)
            );
            assert!((sigmoid(x) + sigmoid(-x) - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn normal_pdf_matches_cdf_slope() {
        for x in [-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3f32;
            let fd = (normal_cdf(x + h) - normal_cdf(x - h)) / (2.0 * h);
            assert!(
                (normal_pdf(x) - fd).abs() < 2e-3,
                "x={x}: pdf {} vs fd {fd}",
                normal_pdf(x)
            );
        }
        assert!((normal_pdf(0.0) - 0.3989423).abs() < 1e-6);
    }

    #[test]
    fn normal_cdf_symmetry() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-6);
        for x in [-2.0f32, -0.5, 0.3, 1.7] {
            let s = normal_cdf(x) + normal_cdf(-x);
            assert!((s - 1.0).abs() < 1e-5, "x={x} sum={s}");
        }
        assert!((normal_cdf(1.96) - 0.975).abs() < 2e-4);
    }
}
