//! Exact analytic backward of the gating math — the paper's *trainable*
//! gating network (§4, Appendices A & B) in native rust.
//!
//! Forward (eq 3–5): `H(x) = x·W_g + ε ⊙ softplus(x·W_noise)`, gates =
//! `softmax(KeepTopK(H, k))`.  Three gradient sources flow back into
//! `W_g` / `W_noise`:
//!
//! - the **task loss** through the top-k softmax: for a token with
//!   selected set S and gates g, `∂L/∂H_i = g_i (a_i − Σ_j g_j a_j)`
//!   for i ∈ S (zero outside S — KeepTopK pins the others at −∞),
//!   where `a_i = ∂L/∂g_i`;
//! - the **importance loss** (eq 6–7): `Importance_e = Σ_t g_{t,e}`,
//!   so `w_imp · ∂CV²/∂Imp_e` simply adds to every selected gate's
//!   `a_i` ([`cv_squared_grad`], chained by the caller);
//! - the **load loss** (eq 8–10) through the smooth estimator:
//!   `P_{t,i} = Φ((x·W_g)_i − T_{t,i}) / σ_{t,i})` with
//!   `σ = softplus(x·W_noise) + 1e-10` and `T` the k-th (or k+1-th for
//!   in-top-k logits) largest *noisy* logit of the row.  The gradient
//!   goes through all three occurrences: the clean logit, σ, **and the
//!   threshold** — T is itself a noisy logit `H_j` of a specific
//!   competitor j (resolved under the forward's exact rank rule), so
//!   `−∂L/∂T` flows into that competitor's clean logit and noise net.
//!
//! The noise path uses the **pre-drawn eq-4 normals retained from the
//! forward** ([`RoutingDecision::noise`]
//! (crate::coordinator::router::RoutingDecision)); the backward
//! recomputes the cheap matmuls but never redraws ε, which is what
//! makes two same-seed steps bit-identical.  Every formula here is
//! proven against central finite differences in
//! `rust/tests/grad_check.rs`.

use crate::gating::noisy_topk::{
    matmul_tn, noisy_topk_block, select_topk, GateVec,
};
use crate::gating::{normal_pdf, sigmoid, softplus};

/// Gradients of the gating parameters, shaped like the router weights:
/// `w_g` is (d, n) for flat routers and (d, a) for hierarchical
/// primaries; secondary grads are (d, a, gs) flattened.
#[derive(Clone, Debug)]
pub struct GateGrads {
    pub w_g: Vec<f32>,
    pub w_noise: Option<Vec<f32>>,
    pub w_g_sec: Option<Vec<f32>>,
    pub w_n_sec: Option<Vec<f32>>,
}

impl GateGrads {
    /// Accumulate another replica's gradients (shapes must match).
    pub fn add(&mut self, other: &GateGrads) {
        fn acc(a: &mut [f32], b: &[f32]) {
            debug_assert_eq!(a.len(), b.len());
            for (x, y) in a.iter_mut().zip(b.iter()) {
                *x += y;
            }
        }
        acc(&mut self.w_g, &other.w_g);
        if let (Some(a), Some(b)) = (self.w_noise.as_mut(), other.w_noise.as_ref()) {
            acc(a, b);
        }
        if let (Some(a), Some(b)) = (self.w_g_sec.as_mut(), other.w_g_sec.as_ref()) {
            acc(a, b);
        }
        if let (Some(a), Some(b)) = (self.w_n_sec.as_mut(), other.w_n_sec.as_ref()) {
            acc(a, b);
        }
    }

    /// Σ g² over every tensor, for the step's grad-norm telemetry.
    pub fn sq_norm(&self) -> f64 {
        let part = |v: &Option<Vec<f32>>| -> f64 {
            v.as_deref()
                .map(|s| s.iter().map(|g| (*g as f64) * (*g as f64)).sum())
                .unwrap_or(0.0)
        };
        self.w_g.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>()
            + part(&self.w_noise)
            + part(&self.w_g_sec)
            + part(&self.w_n_sec)
    }
}

/// d/dv CV²(v) (eq 7 / 11, the exact gradient of
/// [`cv_squared`](crate::gating::noisy_topk::cv_squared)):
/// `∂/∂v_j [var/(mean²+ε)] = (2(v_j−mean)/n·(mean²+ε) − var·2·mean/n)
/// / (mean²+ε)²`.  Zero for len ≤ 1, matching the forward.
pub fn cv_squared_grad(v: &[f32]) -> Vec<f32> {
    let n = v.len();
    if n <= 1 {
        return vec![0.0; n];
    }
    let nf = n as f32;
    let mean = v.iter().sum::<f32>() / nf;
    let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / nf;
    let denom = mean * mean + 1e-10;
    v.iter()
        .map(|&x| {
            (2.0 * (x - mean) / nf * denom - var * 2.0 * mean / nf)
                / (denom * denom)
        })
        .collect()
}

/// Per-token softmax backward over the selected slots: given gates `g`
/// and `a = ∂L/∂g`, returns `∂L/∂H_i = g_i (a_i − Σ_j g_j a_j)` per
/// slot (softmax is shift-invariant, so the rows sum to ~0).
fn softmax_backward(gates: &[f32], d_gates: &[f32]) -> Vec<f32> {
    debug_assert_eq!(gates.len(), d_gates.len());
    let dot: f32 = gates.iter().zip(d_gates.iter()).map(|(g, a)| g * a).sum();
    gates
        .iter()
        .zip(d_gates.iter())
        .map(|(g, a)| g * (a - dot))
        .collect()
}

/// Backward of one replica's **flat** noisy top-k gating.
///
/// - `x`: (b, d) activations the replica was routed with;
/// - `w_g` (d, n), `w_noise` (d, n): the forward's gating parameters;
/// - `eps`: the retained pre-drawn eq-4 normals, (b, n) row-major —
///   `None` reproduces the deterministic (eval-routing) forward, in
///   which case `w_noise` gets no gradient and `d_load` must be zeros
///   (hard-count load is piecewise constant);
/// - `per_token`: the forward's gate vectors (selection + weights);
/// - `d_gates[t][slot]`: ∂L/∂gate for each selected slot — the task
///   term plus the importance-loss term;
/// - `d_load[e]`: ∂L/∂Load_e coefficient (`w_load · ∂CV²/∂Load_e`),
///   applied through the eq-10 smooth estimator for every (token,
///   expert) pair.
#[allow(clippy::too_many_arguments)]
pub fn flat_gate_backward(
    x: &[f32],
    b: usize,
    d: usize,
    w_g: &[f32],
    w_noise: Option<&[f32]>,
    n: usize,
    k: usize,
    eps: Option<&[f32]>,
    per_token: &[GateVec],
    d_gates: &[Vec<f32>],
    d_load: &[f32],
) -> GateGrads {
    assert_eq!(per_token.len(), b);
    assert_eq!(d_gates.len(), b);
    assert_eq!(d_load.len(), n);
    // mirror the forward exactly: the noise net only participates when
    // the step drew noise (route_rows passes w_noise only when training)
    let wn = if eps.is_some() { w_noise } else { None };
    let g = noisy_topk_block(x, b, d, w_g, wn, n, k, eps);
    let noise_active = g.sigma_raw.is_some() && eps.is_some();

    let mut d_clean = vec![0f32; b * n];
    let mut d_raw = vec![0f32; b * n];
    // ∂L/∂noisy_j folds into clean_j (coefficient 1) and, when the
    // noise path ran, into raw_j via ε_j · σ'(raw_j)
    let add_noisy = |d_clean: &mut [f32],
                         d_raw: &mut [f32],
                         t: usize,
                         j: usize,
                         v: f32| {
        d_clean[t * n + j] += v;
        if noise_active {
            let raw = g.sigma_raw.as_ref().unwrap()[t * n + j];
            d_raw[t * n + j] += v * eps.unwrap()[t * n + j] * sigmoid(raw);
        }
    };

    for (t, tok) in per_token.iter().enumerate() {
        debug_assert_eq!(
            tok.experts, g.per_token[t].experts,
            "backward re-routed differently from the forward"
        );
        let dh = softmax_backward(&tok.weights, &d_gates[t]);
        for (&e, dv) in tok.experts.iter().zip(dh.iter()) {
            add_noisy(&mut d_clean, &mut d_raw, t, e, *dv);
        }
    }

    // eq-8/10 load loss: only defined for the smooth estimator (noise
    // path on, k < n); the forward's k >= n load is constant
    let smooth = noise_active && k < n && d_load.iter().any(|c| *c != 0.0);
    if smooth {
        let raw_all = g.sigma_raw.as_ref().unwrap();
        for t in 0..b {
            let noisy = &g.noisy[t * n..(t + 1) * n];
            let clean = &g.clean[t * n..(t + 1) * n];
            // threshold indices under the forward's rank rule: order[k-1]
            // is the k-th largest noisy logit, order[k] the (k+1)-th
            let order = select_topk(noisy, k + 1);
            let (jk, jk1) = (order[k - 1], order[k]);
            let kth = noisy[jk];
            for i in 0..n {
                let c = d_load[i];
                if c == 0.0 {
                    continue;
                }
                // membership by value, exactly as load_estimate tests it
                let thr_idx = if noisy[i] >= kth { jk1 } else { jk };
                let sigma = softplus(raw_all[t * n + i]) + 1e-10;
                let z = (clean[i] - noisy[thr_idx]) / sigma;
                let base = c * normal_pdf(z) / sigma;
                // ∂P/∂clean_i = φ(z)/σ
                d_clean[t * n + i] += base;
                // ∂P/∂T = −φ(z)/σ, T = noisy_{thr_idx}
                add_noisy(&mut d_clean, &mut d_raw, t, thr_idx, -base);
                // ∂P/∂σ = −φ(z)·z/σ, σ = softplus(raw_i) + 1e-10
                d_raw[t * n + i] +=
                    -(base * z) * sigmoid(raw_all[t * n + i]);
            }
        }
    }

    let mut d_w_g = vec![0f32; d * n];
    matmul_tn(x, &d_clean, &mut d_w_g, b, d, n);
    let d_w_noise = noise_active.then(|| {
        let mut dwn = vec![0f32; d * n];
        matmul_tn(x, &d_raw, &mut dwn, b, d, n);
        dwn
    });
    GateGrads {
        w_g: d_w_g,
        w_noise: d_w_noise,
        w_g_sec: None,
        w_n_sec: None,
    }
}

/// Backward of one replica's **two-level hierarchical** gating
/// (Appendix B): composed gate (eq 12) `gate_{gi,ej} = p_{gi} ·
/// s_{gi,ej}` unchains into both softmaxes, then into the primary
/// (`w_g`/`w_noise`, (d, a)) and secondary (`w_g_sec`/`w_n_sec`,
/// (d, a, gs)) nets.  `d_gates[t]` aligns with the composed flat
/// [`GateVec`] (primary-slot-major, as `compose_hierarchical` emits).
/// Hierarchical load is hard counts (piecewise constant), so there is
/// no load-loss path here; importance flows through `d_gates` like any
/// task gradient.  `eps_pri` is (b, a); `eps_sec` is (b, k, gs)
/// consumed in primary-selection order — both retained from the
/// forward.
#[allow(clippy::too_many_arguments)]
pub fn hierarchical_gate_backward(
    x: &[f32],
    b: usize,
    d: usize,
    w_g: &[f32],
    w_noise: Option<&[f32]>,
    w_g_sec: &[f32],
    w_n_sec: Option<&[f32]>,
    a: usize,
    gs: usize,
    k: usize,
    eps_pri: Option<&[f32]>,
    eps_sec: Option<&[f32]>,
    per_token: &[GateVec],
    d_gates: &[Vec<f32>],
) -> GateGrads {
    assert_eq!(per_token.len(), b);
    assert_eq!(d_gates.len(), b);
    assert_eq!(w_g_sec.len(), d * a * gs);
    let wn_pri = if eps_pri.is_some() { w_noise } else { None };
    let primary = noisy_topk_block(x, b, d, w_g, wn_pri, a, k, eps_pri);
    let pri_noise_active = primary.sigma_raw.is_some() && eps_pri.is_some();
    let sec_noise_active = w_n_sec.is_some() && eps_sec.is_some();
    let k2 = k.min(gs);

    let mut d_clean_p = vec![0f32; b * a];
    let mut d_raw_p = vec![0f32; b * a];
    let mut d_wsec = vec![0f32; d * a * gs];
    let mut d_wnsec = vec![0f32; d * a * gs];

    for (t, ptok) in primary.per_token.iter().enumerate() {
        let xrow = &x[t * d..(t + 1) * d];
        let mut d_primary = vec![0f32; ptok.experts.len()];
        for (si, (&gi, &p)) in
            ptok.experts.iter().zip(ptok.weights.iter()).enumerate()
        {
            // recompute this (token, slot)'s secondary logits exactly as
            // the forward did, keeping the softplus inputs for the grads
            let mut h = vec![0f32; gs];
            for (l, &xv) in xrow.iter().enumerate() {
                let base = l * a * gs + gi * gs;
                for (j, hv) in h.iter_mut().enumerate() {
                    *hv += xv * w_g_sec[base + j];
                }
            }
            let mut rawsec = vec![0f32; gs];
            if sec_noise_active {
                let wn = w_n_sec.unwrap();
                let eps = eps_sec.unwrap();
                for (l, &xv) in xrow.iter().enumerate() {
                    let base = l * a * gs + gi * gs;
                    for (j, rv) in rawsec.iter_mut().enumerate() {
                        *rv += xv * wn[base + j];
                    }
                }
                for (j, hv) in h.iter_mut().enumerate() {
                    *hv += eps[t * k * gs + si * gs + j] * softplus(rawsec[j]);
                }
            }
            let sec = crate::gating::noisy_topk::topk_softmax(&h, k2);
            // unchain the composed gates of this slot: slots si*k2 + sj
            let mut d_sec = vec![0f32; sec.experts.len()];
            for (sj, (&ej, &sw)) in
                sec.experts.iter().zip(sec.weights.iter()).enumerate()
            {
                // the recomputed routing must reproduce the forward's
                // composed order, or the slot alignment is garbage
                debug_assert_eq!(
                    per_token[t].experts[si * k2 + sj],
                    gi * gs + ej,
                    "hierarchical backward re-routed differently from \
                     the forward"
                );
                let dg = d_gates[t][si * k2 + sj];
                d_primary[si] += sw * dg;
                d_sec[sj] = p * dg;
            }
            // secondary softmax backward, then into the secondary nets
            let dh_sec = softmax_backward(&sec.weights, &d_sec);
            for (&ej, &dv) in sec.experts.iter().zip(dh_sec.iter()) {
                for (l, &xv) in xrow.iter().enumerate() {
                    d_wsec[l * a * gs + gi * gs + ej] += xv * dv;
                }
                if sec_noise_active {
                    let eps = eps_sec.unwrap();
                    let dr = dv
                        * eps[t * k * gs + si * gs + ej]
                        * sigmoid(rawsec[ej]);
                    for (l, &xv) in xrow.iter().enumerate() {
                        d_wnsec[l * a * gs + gi * gs + ej] += xv * dr;
                    }
                }
            }
        }
        // primary softmax backward, then into the primary nets
        let dh_pri = softmax_backward(&ptok.weights, &d_primary);
        for (&gi, &dv) in ptok.experts.iter().zip(dh_pri.iter()) {
            d_clean_p[t * a + gi] += dv;
            if pri_noise_active {
                let raw = primary.sigma_raw.as_ref().unwrap()[t * a + gi];
                d_raw_p[t * a + gi] +=
                    dv * eps_pri.unwrap()[t * a + gi] * sigmoid(raw);
            }
        }
    }

    let mut d_w_g = vec![0f32; d * a];
    matmul_tn(x, &d_clean_p, &mut d_w_g, b, d, a);
    let d_w_noise = pri_noise_active.then(|| {
        let mut dwn = vec![0f32; d * a];
        matmul_tn(x, &d_raw_p, &mut dwn, b, d, a);
        dwn
    });
    GateGrads {
        w_g: d_w_g,
        w_noise: d_w_noise,
        w_g_sec: Some(d_wsec),
        w_n_sec: sec_noise_active.then_some(d_wnsec),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::noisy_topk::{cv_squared, topk_softmax};
    use crate::util::prop;

    #[test]
    fn cv_squared_grad_matches_central_differences() {
        prop::forall("cv² grad", |rng| {
            let n = prop::dim(rng, 1, 12);
            // keep the mean away from 0 so the quotient stays tame
            let mut v: Vec<f32> =
                prop::vec_f32(rng, n, 0.5).iter().map(|x| x + 2.0).collect();
            let grad = cv_squared_grad(&v);
            for i in 0..n {
                let w0 = v[i];
                let h = 1e-3f32;
                v[i] = w0 + h;
                let lp = cv_squared(&v) as f64;
                v[i] = w0 - h;
                let lm = cv_squared(&v) as f64;
                v[i] = w0;
                let fd = (lp - lm) / (2.0 * h as f64);
                let an = grad[i] as f64;
                assert!(
                    (fd - an).abs() <= 1e-3 * 1f64.max(fd.abs()).max(an.abs()),
                    "i={i}: analytic {an} vs fd {fd}"
                );
            }
        });
    }

    #[test]
    fn softmax_backward_matches_central_differences() {
        prop::forall("topk softmax grad", |rng| {
            let n = prop::dim(rng, 2, 10);
            let k = prop::dim(rng, 1, n);
            let mut h = prop::vec_f32(rng, n, 1.0);
            let a = prop::vec_f32(rng, k, 1.0);
            if k < n {
                // skip selections thinner than the FD step: ±1e-3 on a
                // near-tied boundary logit would flip the branch
                let mut sorted = h.clone();
                sorted.sort_by(|x, y| y.partial_cmp(x).unwrap());
                if sorted[k - 1] - sorted[k] < 1e-2 {
                    return;
                }
            }
            let g0 = topk_softmax(&h, k);
            // L = Σ a_i g_i ; ∂L/∂h at the selected logits
            let dh = softmax_backward(&g0.weights, &a);
            for (slot, &e) in g0.experts.iter().enumerate() {
                let w0 = h[e];
                let step = 1e-3f32;
                h[e] = w0 + step;
                let gp = topk_softmax(&h, k);
                h[e] = w0 - step;
                let gm = topk_softmax(&h, k);
                h[e] = w0;
                // frozen-branch FD: ±1e-3 can flip the selection only at
                // exact ties, which vec_f32 never produces
                assert_eq!(gp.experts, g0.experts);
                let lp: f64 = gp
                    .weights
                    .iter()
                    .zip(a.iter())
                    .map(|(g, a)| (*g as f64) * (*a as f64))
                    .sum();
                let lm: f64 = gm
                    .weights
                    .iter()
                    .zip(a.iter())
                    .map(|(g, a)| (*g as f64) * (*a as f64))
                    .sum();
                let fd = (lp - lm) / (2.0 * step as f64);
                let an = dh[slot] as f64;
                assert!(
                    (fd - an).abs() <= 2e-3 * 1f64.max(fd.abs()).max(an.abs()),
                    "slot {slot} (logit {e}): analytic {an} vs fd {fd}"
                );
            }
        });
    }

    #[test]
    fn gate_grads_accumulate_and_norm() {
        let mut a = GateGrads {
            w_g: vec![1.0, 2.0],
            w_noise: Some(vec![0.5, -0.5]),
            w_g_sec: None,
            w_n_sec: None,
        };
        let b = GateGrads {
            w_g: vec![0.25, -1.0],
            w_noise: Some(vec![1.0, 1.0]),
            w_g_sec: None,
            w_n_sec: None,
        };
        a.add(&b);
        assert_eq!(a.w_g, vec![1.25, 1.0]);
        assert_eq!(a.w_noise.as_deref().unwrap(), &[1.5, 0.5]);
        let want = 1.25f64 * 1.25 + 1.0 + 1.5 * 1.5 + 0.25;
        assert!((a.sq_norm() - want).abs() < 1e-9);
    }
}
