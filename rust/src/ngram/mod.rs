//! Kneser–Ney smoothed n-gram language model (Kneser & Ney 1995).
//!
//! The paper's Tables 7 and 8 include an unpruned KN 5-gram baseline; this
//! module implements interpolated modified-free KN (single discount D per
//! order, the textbook formulation) trained from a token stream.  Counts
//! are exact (hash maps), which is fine at our synthetic-corpus scale.

use std::collections::HashMap;

/// Interpolated Kneser–Ney model of order `n`.
pub struct KneserNey {
    pub order: usize,
    pub vocab: usize,
    pub discount: f64,
    /// counts[o][(ctx, w)] for o-gram (o = context length + 1)
    counts: Vec<HashMap<(Vec<i32>, i32), u64>>,
    /// context totals per order
    ctx_totals: Vec<HashMap<Vec<i32>, u64>>,
    /// distinct continuations per context (for the backoff weight)
    ctx_types: Vec<HashMap<Vec<i32>, u64>>,
    /// continuation counts for the unigram base distribution:
    /// number of distinct bigram contexts each word follows
    continuation: HashMap<i32, u64>,
    bigram_types: u64,
}

impl KneserNey {
    pub fn new(order: usize, vocab: usize) -> Self {
        assert!(order >= 2);
        KneserNey {
            order,
            vocab,
            discount: 0.75,
            counts: vec![HashMap::new(); order],
            ctx_totals: vec![HashMap::new(); order],
            ctx_types: vec![HashMap::new(); order],
            continuation: HashMap::new(),
            bigram_types: 0,
        }
    }

    /// Accumulate counts from a token stream.
    pub fn train(&mut self, tokens: &[i32]) {
        for i in 0..tokens.len() {
            let w = tokens[i];
            for o in 1..=self.order {
                if i + 1 < o {
                    continue;
                }
                let ctx: Vec<i32> = tokens[i + 1 - o..i].to_vec();
                let e = self.counts[o - 1]
                    .entry((ctx.clone(), w))
                    .or_insert(0);
                let first_time = *e == 0;
                *e += 1;
                *self.ctx_totals[o - 1].entry(ctx.clone()).or_insert(0) += 1;
                if first_time {
                    *self.ctx_types[o - 1].entry(ctx).or_insert(0) += 1;
                    if o == 2 {
                        *self.continuation.entry(w).or_insert(0) += 1;
                        self.bigram_types += 1;
                    }
                }
            }
        }
    }

    /// Base (continuation) unigram probability with add-one smoothing so
    /// unseen words keep nonzero mass.
    fn p_continuation(&self, w: i32) -> f64 {
        let c = self.continuation.get(&w).copied().unwrap_or(0);
        (c as f64 + 1.0) / (self.bigram_types as f64 + self.vocab as f64)
    }

    /// Interpolated KN probability P(w | ctx) using up to order-1 context.
    pub fn prob(&self, ctx: &[i32], w: i32) -> f64 {
        let max_ctx = (self.order - 1).min(ctx.len());
        let ctx = &ctx[ctx.len() - max_ctx..];
        self.prob_rec(ctx, w)
    }

    fn prob_rec(&self, ctx: &[i32], w: i32) -> f64 {
        if ctx.is_empty() {
            return self.p_continuation(w);
        }
        let o = ctx.len() + 1;
        let key = ctx.to_vec();
        let total = self.ctx_totals[o - 1].get(&key).copied().unwrap_or(0);
        if total == 0 {
            // unseen context: back off entirely
            return self.prob_rec(&ctx[1..], w);
        }
        let c = self.counts[o - 1]
            .get(&(key.clone(), w))
            .copied()
            .unwrap_or(0);
        let types = self.ctx_types[o - 1].get(&key).copied().unwrap_or(0);
        let d = self.discount;
        let main = ((c as f64 - d).max(0.0)) / total as f64;
        let lambda = d * types as f64 / total as f64;
        main + lambda * self.prob_rec(&ctx[1..], w)
    }

    /// Perplexity over a token stream.
    pub fn perplexity(&self, tokens: &[i32]) -> f64 {
        let mut nll = 0f64;
        let mut n = 0u64;
        for i in 1..tokens.len() {
            let lo = i.saturating_sub(self.order - 1);
            let p = self.prob(&tokens[lo..i], tokens[i]);
            nll -= p.max(1e-12).ln();
            n += 1;
        }
        (nll / n.max(1) as f64).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{CorpusSpec, TopicCorpus};

    #[test]
    fn probabilities_normalise() {
        // sum over vocab of P(w|ctx) == 1 for a seen context
        let mut m = KneserNey::new(3, 8);
        let toks = vec![2, 3, 4, 2, 3, 5, 2, 3, 4, 6, 2, 3, 5, 7];
        m.train(&toks);
        for ctx in [vec![], vec![3], vec![2, 3], vec![7, 7]] {
            let s: f64 = (0..8).map(|w| m.prob(&ctx, w)).sum();
            assert!((s - 1.0).abs() < 1e-6, "ctx {ctx:?} sums to {s}");
        }
    }

    #[test]
    fn seen_ngrams_likelier_than_unseen() {
        let mut m = KneserNey::new(3, 16);
        let toks: Vec<i32> = (0..200).map(|i| 2 + (i % 4)).collect();
        m.train(&toks);
        // after "2 3" the corpus always has 4
        assert!(m.prob(&[2, 3], 4) > m.prob(&[2, 3], 9) * 10.0);
    }

    #[test]
    fn perplexity_improves_with_order_on_structured_data() {
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: 128,
            n_topics: 2,
            branch: 3,
            mean_len: 10,
            seed: 3,
        });
        let mut train = vec![0i32; 30_000];
        corpus.stream(0).fill(&mut train);
        let mut test = vec![0i32; 3_000];
        corpus.stream(999).fill(&mut test);
        let mut uni = KneserNey::new(2, 128);
        uni.train(&train);
        let mut five = KneserNey::new(5, 128);
        five.train(&train);
        let (p2, p5) = (uni.perplexity(&test), five.perplexity(&test));
        // the topic is latent, so longer context helps but can't fully
        // disambiguate; require a clear (>=10%) win, not a blowout
        assert!(
            p5 < p2 * 0.9,
            "5-gram {p5:.2} should beat 2-gram {p2:.2} clearly"
        );
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        let mut m = KneserNey::new(5, 64);
        let mut toks = vec![0i32; 5_000];
        TopicCorpus::new(CorpusSpec { vocab: 64, ..Default::default() })
            .stream(0)
            .fill(&mut toks);
        m.train(&toks);
        let ppl = m.perplexity(&toks[..1000]);
        assert!(ppl > 1.0 && ppl < 64.0, "ppl {ppl}");
    }
}
