//! Word-level vocabulary: token-id <-> surface-string mapping.
//!
//! The synthetic corpora work in token ids; surface forms only matter for
//! human-readable output (Table 9 expert-specialisation contexts, the
//! translation demo).  Words get deterministic pronounceable names so the
//! same id always renders the same across runs.

use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct Vocab {
    pub size: usize,
    names: Vec<String>,
    index: HashMap<String, i32>,
}

const ONSETS: [&str; 16] = [
    "b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z",
    "ch", "sh",
];
const NUCLEI: [&str; 8] = ["a", "e", "i", "o", "u", "ai", "ou", "ei"];

fn spell(id: usize) -> String {
    // base-(16*8) syllables; always at least two syllables so words look
    // like words
    let mut n = id;
    let mut s = String::new();
    for _ in 0..2 {
        s.push_str(ONSETS[n % 16]);
        n /= 16;
        s.push_str(NUCLEI[n % 8]);
        n /= 8;
    }
    while n > 0 {
        s.push_str(ONSETS[n % 16]);
        n /= 16;
        s.push_str(NUCLEI[n % 8]);
        n /= 8;
    }
    s
}

impl Vocab {
    pub fn synthetic(size: usize) -> Self {
        let mut names = Vec::with_capacity(size);
        let mut index = HashMap::new();
        for id in 0..size {
            let name = match id {
                0 => "<s>".to_string(),
                1 => "</s>".to_string(),
                _ => spell(id - 2),
            };
            index.insert(name.clone(), id as i32);
            names.push(name);
        }
        Vocab { size, names, index }
    }

    pub fn word(&self, id: i32) -> &str {
        self.names
            .get(id as usize)
            .map(|s| s.as_str())
            .unwrap_or("<unk>")
    }

    pub fn id(&self, word: &str) -> Option<i32> {
        self.index.get(word).copied()
    }

    pub fn detokenize(&self, ids: &[i32]) -> String {
        ids.iter()
            .map(|&i| self.word(i))
            .collect::<Vec<_>>()
            .join(" ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_roundtrip() {
        let v = Vocab::synthetic(512);
        for id in 0..512 {
            let w = v.word(id);
            assert_eq!(v.id(w), Some(id), "word {w}");
        }
    }

    #[test]
    fn names_unique() {
        let v = Vocab::synthetic(2048);
        let mut set = std::collections::HashSet::new();
        for id in 0..2048 {
            assert!(set.insert(v.word(id).to_string()), "dup {}", v.word(id));
        }
    }

    #[test]
    fn specials() {
        let v = Vocab::synthetic(8);
        assert_eq!(v.word(0), "<s>");
        assert_eq!(v.word(1), "</s>");
        assert_eq!(v.word(99), "<unk>");
    }

    #[test]
    fn detokenize_joins() {
        let v = Vocab::synthetic(8);
        assert_eq!(v.detokenize(&[0, 1]), "<s> </s>");
    }
}
