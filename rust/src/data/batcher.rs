//! Batcher: turns a token stream into (batch, seq_len + 1) i32 tensors for
//! the train-step artifact.
//!
//! Layout note (§3.1 "Taking Advantage of Convolutionality"): the MoE
//! inside the artifact flattens all batch*seq_len positions into one big
//! expert batch, so the batcher's only job is to keep `batch` independent
//! continuation streams — each row continues where it left off, giving the
//! LSTMs coherent context while the MoE sees B*T tokens at once.
//!
//! Two row sources share that contract:
//! - [`Batcher::new`] — the infinite [`TopicCorpus`] streams (training);
//! - [`Batcher::from_tokens`] — a *finite* token slice (eval replays,
//!   fixture corpora): rows start at staggered offsets and wrap around
//!   at the corpus tail, so a corpus shorter than `batch * seq_len`
//!   still batches forever without panicking and never emits a token
//!   that was not in the slice.

use crate::data::synthetic::{TokenStream, TopicCorpus, BOS};
use crate::runtime::TensorI;

/// One row's token supply: an infinite corpus stream, or a finite slice
/// tiled with wrap-around at the tail.
enum RowStream<'a> {
    Corpus(TokenStream<'a>),
    Finite { tokens: &'a [i32], pos: usize },
}

impl RowStream<'_> {
    fn next_token(&mut self) -> i32 {
        match self {
            RowStream::Corpus(s) => s.next_token(),
            RowStream::Finite { tokens, pos } => {
                if tokens.is_empty() {
                    // degenerate empty corpus: emit BOS rather than panic
                    return BOS;
                }
                let t = tokens[*pos];
                *pos = (*pos + 1) % tokens.len();
                t
            }
        }
    }
}

pub struct Batcher<'a> {
    rows: Vec<RowStream<'a>>,
    batch: usize,
    seq_len: usize,
    /// last token of the previous chunk per row (next chunk's first input)
    carry: Vec<i32>,
    pub tokens_served: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(corpus: &'a TopicCorpus, batch: usize, seq_len: usize,
               stream_base: u64) -> Self {
        let rows: Vec<RowStream<'a>> = (0..batch)
            .map(|i| RowStream::Corpus(corpus.stream(stream_base + i as u64)))
            .collect();
        Self::from_rows(rows, batch, seq_len)
    }

    /// Batch a finite token slice: row `r` starts at offset
    /// `r * len / batch` and wraps at the corpus tail, so every token
    /// of the slice is covered and a corpus shorter than
    /// `batch * seq_len` simply tiles (module docs).
    pub fn from_tokens(tokens: &'a [i32], batch: usize, seq_len: usize) -> Self {
        let len = tokens.len();
        let rows: Vec<RowStream<'a>> = (0..batch)
            .map(|r| RowStream::Finite {
                tokens,
                pos: if len == 0 { 0 } else { r * len / batch.max(1) },
            })
            .collect();
        Self::from_rows(rows, batch, seq_len)
    }

    fn from_rows(mut rows: Vec<RowStream<'a>>, batch: usize, seq_len: usize)
        -> Self {
        let carry = rows.iter_mut().map(|s| s.next_token()).collect();
        Batcher { rows, batch, seq_len, carry, tokens_served: 0 }
    }

    /// Next (batch, seq_len+1) chunk.  Column 0 of row r is the carry from
    /// the previous chunk so targets tile the stream exactly once.
    pub fn next_batch(&mut self) -> TensorI {
        let cols = self.seq_len + 1;
        let mut data = vec![0i32; self.batch * cols];
        for r in 0..self.batch {
            data[r * cols] = self.carry[r];
            for c in 1..cols {
                data[r * cols + c] = self.rows[r].next_token();
            }
            self.carry[r] = data[r * cols + cols - 1];
        }
        self.tokens_served += (self.batch * self.seq_len) as u64;
        TensorI::new(vec![self.batch, cols], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::CorpusSpec;

    #[test]
    fn batches_have_right_shape_and_continuity() {
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: 128,
            n_topics: 2,
            branch: 3,
            mean_len: 6,
            seed: 1,
        });
        let mut b = Batcher::new(&corpus, 4, 10, 0);
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_eq!(b1.shape, vec![4, 11]);
        // continuity: first input of chunk 2 == last token of chunk 1
        for r in 0..4 {
            assert_eq!(b2.at2(r, 0), b1.at2(r, 10));
        }
        assert_eq!(b.tokens_served, 80);
    }

    #[test]
    fn rows_are_distinct_streams() {
        let corpus = TopicCorpus::new(CorpusSpec::default());
        let mut b = Batcher::new(&corpus, 3, 16, 0);
        let t = b.next_batch();
        assert_ne!(t.row(0), t.row(1));
        assert_ne!(t.row(1), t.row(2));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let spec = CorpusSpec { vocab: 64, ..Default::default() };
        let corpus = TopicCorpus::new(spec);
        let mut b = Batcher::new(&corpus, 2, 32, 5);
        for _ in 0..10 {
            let t = b.next_batch();
            assert!(t.data.iter().all(|&w| w >= 0 && (w as usize) < 64));
        }
    }

    #[test]
    fn corpus_shorter_than_one_batch_wraps_without_panicking() {
        // 7 tokens vs a 4 * 5 = 20-token batch: every row must wrap the
        // tail (multiple times) and only ever emit tokens from the slice
        let vocab = 10;
        let tokens: Vec<i32> = vec![2, 3, 4, 5, 6, 7, 8];
        let mut b = Batcher::from_tokens(&tokens, 4, 5);
        for _ in 0..6 {
            let t = b.next_batch();
            assert_eq!(t.shape, vec![4, 6]);
            for &w in &t.data {
                assert!(
                    tokens.contains(&w),
                    "token {w} not from the finite corpus"
                );
                assert!(w >= 0 && (w as usize) < vocab);
            }
        }
        assert_eq!(b.tokens_served, 6 * 4 * 5);
    }

    #[test]
    fn wraparound_at_tail_preserves_order_and_continuity() {
        // single row: the emitted stream must be the slice repeated
        // (carry included), i.e. wrap-around never skips or invents ids
        let tokens: Vec<i32> = vec![5, 6, 7];
        let mut b = Batcher::from_tokens(&tokens, 1, 4);
        let t1 = b.next_batch();
        let t2 = b.next_batch();
        let mut emitted: Vec<i32> = t1.data.clone();
        // column 0 of chunk 2 repeats the carry; drop it when splicing
        emitted.extend_from_slice(&t2.data[1..]);
        for (i, &w) in emitted.iter().enumerate() {
            assert_eq!(
                w,
                tokens[i % tokens.len()],
                "position {i} broke the wrap-around order"
            );
        }
    }

    #[test]
    fn staggered_offsets_cover_the_corpus() {
        // rows start at r * len / batch, so with batch = 2 over 8 tokens
        // row 1 starts mid-corpus and wraps past the tail
        let tokens: Vec<i32> = (10..18).collect();
        let mut b = Batcher::from_tokens(&tokens, 2, 8);
        let t = b.next_batch();
        assert_eq!(t.at2(0, 0), 10);
        assert_eq!(t.at2(1, 0), 14);
        // row 1 wraps: ...16 17 10 11...
        assert_eq!(t.row(1)[..6], [14, 15, 16, 17, 10, 11]);
    }

    #[test]
    fn empty_and_degenerate_corpora_do_not_panic() {
        let empty: Vec<i32> = Vec::new();
        let mut b = Batcher::from_tokens(&empty, 2, 3);
        let t = b.next_batch();
        assert_eq!(t.shape, vec![2, 4]);
        assert!(t.data.iter().all(|&w| w == BOS), "empty corpus emits BOS");

        // zero rows and zero seq_len are valid no-ops
        let tokens = vec![3, 4];
        let mut none = Batcher::from_tokens(&tokens, 0, 4);
        assert_eq!(none.next_batch().shape, vec![0, 5]);
        let mut flat = Batcher::from_tokens(&tokens, 2, 0);
        let t = flat.next_batch();
        assert_eq!(t.shape, vec![2, 1]);
        assert_eq!(flat.tokens_served, 0);
    }
}
