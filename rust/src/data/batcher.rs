//! Batcher: turns a token stream into (batch, seq_len + 1) i32 tensors for
//! the train-step artifact.
//!
//! Layout note (§3.1 "Taking Advantage of Convolutionality"): the MoE
//! inside the artifact flattens all batch*seq_len positions into one big
//! expert batch, so the batcher's only job is to keep `batch` independent
//! continuation streams — each row continues where it left off, giving the
//! LSTMs coherent context while the MoE sees B*T tokens at once.

use crate::data::synthetic::{TokenStream, TopicCorpus};
use crate::runtime::TensorI;

pub struct Batcher<'a> {
    streams: Vec<TokenStream<'a>>,
    batch: usize,
    seq_len: usize,
    /// last token of the previous chunk per row (next chunk's first input)
    carry: Vec<i32>,
    pub tokens_served: u64,
}

impl<'a> Batcher<'a> {
    pub fn new(corpus: &'a TopicCorpus, batch: usize, seq_len: usize,
               stream_base: u64) -> Self {
        let mut streams: Vec<TokenStream<'a>> = (0..batch)
            .map(|i| corpus.stream(stream_base + i as u64))
            .collect();
        let carry = streams.iter_mut().map(|s| s.next_token()).collect();
        Batcher { streams, batch, seq_len, carry, tokens_served: 0 }
    }

    /// Next (batch, seq_len+1) chunk.  Column 0 of row r is the carry from
    /// the previous chunk so targets tile the stream exactly once.
    pub fn next_batch(&mut self) -> TensorI {
        let cols = self.seq_len + 1;
        let mut data = vec![0i32; self.batch * cols];
        for r in 0..self.batch {
            data[r * cols] = self.carry[r];
            for c in 1..cols {
                data[r * cols + c] = self.streams[r].next_token();
            }
            self.carry[r] = data[r * cols + cols - 1];
        }
        self.tokens_served += (self.batch * self.seq_len) as u64;
        TensorI::new(vec![self.batch, cols], data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::CorpusSpec;

    #[test]
    fn batches_have_right_shape_and_continuity() {
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: 128,
            n_topics: 2,
            branch: 3,
            mean_len: 6,
            seed: 1,
        });
        let mut b = Batcher::new(&corpus, 4, 10, 0);
        let b1 = b.next_batch();
        let b2 = b.next_batch();
        assert_eq!(b1.shape, vec![4, 11]);
        // continuity: first input of chunk 2 == last token of chunk 1
        for r in 0..4 {
            assert_eq!(b2.at2(r, 0), b1.at2(r, 10));
        }
        assert_eq!(b.tokens_served, 80);
    }

    #[test]
    fn rows_are_distinct_streams() {
        let corpus = TopicCorpus::new(CorpusSpec::default());
        let mut b = Batcher::new(&corpus, 3, 16, 0);
        let t = b.next_batch();
        assert_ne!(t.row(0), t.row(1));
        assert_ne!(t.row(1), t.row(2));
    }

    #[test]
    fn tokens_in_vocab_range() {
        let spec = CorpusSpec { vocab: 64, ..Default::default() };
        let corpus = TopicCorpus::new(spec);
        let mut b = Batcher::new(&corpus, 2, 32, 5);
        for _ in 0..10 {
            let t = b.next_batch();
            assert!(t.data.iter().all(|&w| w >= 0 && (w as usize) < 64));
        }
    }
}
