//! Topic-mixture Markov corpus — the stand-in for the 1B-word benchmark
//! and the 100B-word Google News corpus (DESIGN.md §Substitutions).
//!
//! Generative process: `n_topics` latent topics; each topic owns a sparse
//! bigram table over a shared vocabulary (plus a shared function-word
//! core).  A sentence picks one topic and random-walks that topic's
//! bigrams.  Why this preserves the paper's capacity story:
//!
//! - a model can only reach low perplexity by memorising *per-topic*
//!   bigram statistics, so test perplexity improves monotonically with
//!   how many topics the model can store — capacity buys quality exactly
//!   as on the real corpora (Fig 2-left / Fig 3);
//! - the topic posterior is inferable from context, giving the gating
//!   network a real routing signal (expert specialisation, Table 9);
//! - sentences are i.i.d. and shuffled, matching the benchmark protocol.
//!
//! The stream is generated on the fly (never materialised), so "train
//! once over N tokens" scales to any N like the 100B-word run.

use crate::util::rng::Rng;

pub const BOS: i32 = 0;
pub const EOS: i32 = 1;
/// first content token id (0/1 reserved)
pub const FIRST_WORD: i32 = 2;

#[derive(Clone, Debug)]
pub struct CorpusSpec {
    pub vocab: usize,
    pub n_topics: usize,
    /// distinct successor words per (topic, word)
    pub branch: usize,
    /// mean sentence length (geometric)
    pub mean_len: usize,
    pub seed: u64,
}

impl Default for CorpusSpec {
    fn default() -> Self {
        CorpusSpec { vocab: 2048, n_topics: 32, branch: 4, mean_len: 12, seed: 0 }
    }
}

/// Deterministic topic-conditional bigram language.  Successor tables are
/// *derived* (hashed) rather than stored, so a 131072-expert scale corpus
/// costs no memory.
pub struct TopicCorpus {
    pub spec: CorpusSpec,
    base: Rng,
}

impl TopicCorpus {
    pub fn new(spec: CorpusSpec) -> Self {
        let base = Rng::new(spec.seed ^ CORPUS_SALT);
        TopicCorpus { spec, base }
    }

    /// The `j`-th successor of `word` under `topic` (uniform over branch).
    fn successor(&self, topic: usize, word: i32, j: usize) -> i32 {
        let mut r = self.base.fold_in(
            (topic as u64) << 40 ^ (word as u64) << 8 ^ j as u64,
        );
        let content = self.spec.vocab - FIRST_WORD as usize;
        FIRST_WORD + r.below(content) as i32
    }

    /// Generate one sentence: BOS w1 ... wn EOS.
    pub fn sentence(&self, rng: &mut Rng) -> (usize, Vec<i32>) {
        let topic = rng.below(self.spec.n_topics);
        let mut out = vec![BOS];
        // topic-specific start word
        let mut w = self.successor(topic, BOS, rng.below(self.spec.branch));
        loop {
            out.push(w);
            // geometric stop
            if out.len() >= 2 && rng.uniform() < 1.0 / self.spec.mean_len as f64 {
                break;
            }
            if out.len() > 4 * self.spec.mean_len {
                break;
            }
            w = self.successor(topic, w, rng.below(self.spec.branch));
        }
        out.push(EOS);
        (topic, out)
    }

    /// Infinite token stream (sentences concatenated), split train/test by
    /// the rng stream id.
    pub fn stream(&self, stream_id: u64) -> TokenStream<'_> {
        TokenStream {
            corpus: self,
            rng: self.base.fold_in(0x57_4e_a8 ^ stream_id),
            buf: Vec::new(),
            pos: 0,
        }
    }

    /// The true entropy floor is ln(branch) per content token (uniform
    /// choice among `branch` successors) — used by tests to sanity-check
    /// that trained perplexities approach a real floor.
    pub fn bigram_entropy(&self) -> f64 {
        (self.spec.branch as f64).ln()
    }
}

const CORPUS_SALT: u64 = 0xC0FF_EE00_D15C_0000;

pub struct TokenStream<'a> {
    corpus: &'a TopicCorpus,
    rng: Rng,
    buf: Vec<i32>,
    pos: usize,
}

impl<'a> TokenStream<'a> {
    pub fn next_token(&mut self) -> i32 {
        if self.pos >= self.buf.len() {
            let (_, s) = self.corpus.sentence(&mut self.rng);
            self.buf = s;
            self.pos = 0;
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        t
    }

    pub fn fill(&mut self, out: &mut [i32]) {
        for o in out.iter_mut() {
            *o = self.next_token();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> TopicCorpus {
        TopicCorpus::new(CorpusSpec {
            vocab: 256,
            n_topics: 4,
            branch: 3,
            mean_len: 8,
            seed: 7,
        })
    }

    #[test]
    fn sentences_are_framed() {
        let c = corpus();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let (_, s) = c.sentence(&mut rng);
            assert_eq!(s[0], BOS);
            assert_eq!(*s.last().unwrap(), EOS);
            assert!(s.len() >= 3);
            for &w in &s[1..s.len() - 1] {
                assert!(w >= FIRST_WORD && (w as usize) < c.spec.vocab);
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let c1 = corpus();
        let c2 = corpus();
        let mut a = c1.stream(0);
        let mut b = c2.stream(0);
        for _ in 0..200 {
            assert_eq!(a.next_token(), b.next_token());
        }
    }

    #[test]
    fn streams_differ() {
        let c = corpus();
        let mut a = c.stream(0);
        let mut b = c.stream(1);
        let va: Vec<i32> = (0..100).map(|_| a.next_token()).collect();
        let vb: Vec<i32> = (0..100).map(|_| b.next_token()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn bigram_structure_is_learnable() {
        // successors of a word within a topic are few (== branch)
        let c = corpus();
        let mut rng = Rng::new(3);
        use std::collections::{HashMap, HashSet};
        let mut succ: HashMap<(usize, i32), HashSet<i32>> = HashMap::new();
        for _ in 0..500 {
            let (topic, s) = c.sentence(&mut rng);
            for w in s.windows(2) {
                if w[0] >= FIRST_WORD && w[1] >= FIRST_WORD {
                    succ.entry((topic, w[0])).or_default().insert(w[1]);
                }
            }
        }
        let max_succ = succ.values().map(|s| s.len()).max().unwrap();
        assert!(
            max_succ <= c.spec.branch,
            "bigram fan-out {max_succ} > branch {}",
            c.spec.branch
        );
    }
}
