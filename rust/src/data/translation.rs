//! Synthetic translation task — the WMT'14 / multilingual stand-in
//! (DESIGN.md §Substitutions).
//!
//! A "language pair" is a deterministic lexicon permutation plus local
//! reorderings: the source sentence comes from the topic corpus; the
//! target is produced by (a) mapping each word through the pair's
//! bijective lexicon, (b) swapping adjacent words inside windows of 3
//! with a pair-specific deterministic pattern.  The task is exactly
//! learnable, so BLEU differences between models measure *capacity and
//! routing*, which is what Tables 2–5 compare.  Multiple pairs share one
//! vocabulary (as wordpieces do) which makes the multilingual experiment
//! (Table 5) a direct analogue: one model must store all lexicons.
//!
//! Sequence format (prefix-LM): `<s> src … <sep> tgt … </s>` — the MoE
//! seq2seq is the same LSTM stack, conditioned on the source prefix.

use crate::data::synthetic::{TopicCorpus, BOS, EOS, FIRST_WORD};
use crate::runtime::TensorI;
use crate::util::rng::Rng;

/// separator between source and target segments
pub const SEP: i32 = EOS; // reuse </s> as the pivot, GNMT-style

#[derive(Clone, Debug)]
pub struct TranslationTask {
    pub pair_id: u64,
    pub vocab: usize,
    lexicon: Vec<i32>,
}

impl TranslationTask {
    /// Build the deterministic bijective lexicon for a language pair.
    pub fn new(pair_id: u64, vocab: usize) -> Self {
        let content = vocab - FIRST_WORD as usize;
        let mut perm: Vec<i32> =
            (0..content as i32).map(|i| i + FIRST_WORD).collect();
        let mut rng = Rng::new(pair_salt(pair_id));
        rng.shuffle(&mut perm);
        let mut lexicon = vec![0i32; vocab];
        lexicon[BOS as usize] = BOS;
        lexicon[EOS as usize] = EOS;
        for (i, &t) in perm.iter().enumerate() {
            lexicon[FIRST_WORD as usize + i] = t;
        }
        TranslationTask { pair_id, vocab, lexicon }
    }

    /// Translate a source segment into the target language.
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let mut out: Vec<i32> =
            src.iter().map(|&w| self.lexicon[w as usize]).collect();
        // deterministic local reordering: swap positions (3i, 3i+1) when
        // the pair id's bit pattern says so
        for i in (0..out.len().saturating_sub(1)).step_by(3) {
            if (self.pair_id >> (i % 8)) & 1 == 1 {
                out.swap(i, i + 1);
            }
        }
        out
    }

    /// One training/eval example: (source, reference-target).
    pub fn example(&self, corpus: &TopicCorpus, rng: &mut Rng)
        -> (Vec<i32>, Vec<i32>) {
        let (_, sent) = corpus.sentence(rng);
        let src: Vec<i32> =
            sent[1..sent.len() - 1].to_vec(); // strip BOS/EOS
        let tgt = self.translate(&src);
        (src, tgt)
    }

    /// Pack an example into a fixed (seq_len + 1) prefix-LM row:
    /// `<s> src <sep> tgt </s> <pad…>` (pad = EOS; loss over padding is a
    /// constant the comparison shares).  Truncates symmetrically if long.
    pub fn pack_row(&self, src: &[i32], tgt: &[i32], cols: usize) -> Vec<i32> {
        let seg = (cols - 3) / 2;
        let s = &src[..src.len().min(seg)];
        let t = &tgt[..tgt.len().min(seg)];
        let mut row = Vec::with_capacity(cols);
        row.push(BOS);
        row.extend_from_slice(s);
        row.push(SEP);
        row.extend_from_slice(t);
        row.push(EOS);
        row.resize(cols, EOS);
        row
    }

    /// Batch of packed examples, shape (batch, seq_len + 1).
    pub fn batch(&self, corpus: &TopicCorpus, rng: &mut Rng, batch: usize,
                 seq_len: usize) -> TensorI {
        let cols = seq_len + 1;
        let mut data = Vec::with_capacity(batch * cols);
        for _ in 0..batch {
            let (src, tgt) = self.example(corpus, rng);
            data.extend(self.pack_row(&src, &tgt, cols));
        }
        TensorI::new(vec![batch, cols], data)
    }
}

fn pair_salt(pair_id: u64) -> u64 {
    // "translate" in ascii, xor-folded with the pair id
    0x7261_6e73_6c61_7465 ^ pair_id.wrapping_mul(0x1000_0000_1b3)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::CorpusSpec;

    fn task() -> TranslationTask {
        TranslationTask::new(3, 256)
    }

    #[test]
    fn lexicon_is_bijective_on_content() {
        let t = task();
        let mut seen = std::collections::HashSet::new();
        for w in FIRST_WORD..256 {
            let m = t.lexicon[w as usize];
            assert!(m >= FIRST_WORD);
            assert!(seen.insert(m));
        }
    }

    #[test]
    fn translation_deterministic() {
        let t = task();
        let src = vec![5, 9, 12, 40, 7];
        assert_eq!(t.translate(&src), t.translate(&src));
    }

    #[test]
    fn different_pairs_differ() {
        let a = TranslationTask::new(1, 256);
        let b = TranslationTask::new(2, 256);
        let src: Vec<i32> = (2..40).collect();
        assert_ne!(a.translate(&src), b.translate(&src));
    }

    #[test]
    fn pack_row_shape_and_frame() {
        let t = task();
        let row = t.pack_row(&[5, 6, 7], &[9, 10, 11], 25);
        assert_eq!(row.len(), 25);
        assert_eq!(row[0], BOS);
        assert_eq!(row[4], SEP);
        assert_eq!(row[8], EOS);
    }

    #[test]
    fn batch_shape() {
        let corpus = TopicCorpus::new(CorpusSpec {
            vocab: 256,
            ..Default::default()
        });
        let t = task();
        let mut rng = Rng::new(0);
        let b = t.batch(&corpus, &mut rng, 8, 24);
        assert_eq!(b.shape, vec![8, 25]);
    }
}
