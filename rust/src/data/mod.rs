//! Data substrate: synthetic corpora standing in for the paper's datasets
//! (see DESIGN.md §Substitutions), a word-level vocabulary, and the
//! batcher implementing the §3.1 "convolutionality" batching.

pub mod batcher;
pub mod synthetic;
pub mod translation;
pub mod vocab;

pub use batcher::Batcher;
pub use synthetic::TopicCorpus;
pub use translation::TranslationTask;
pub use vocab::Vocab;
