//! Router: computes gating decisions for a replica's token batch.
//!
//! Two interchangeable backends:
//! - [`RouterBackend::Artifact`]: the AOT'd gating artifact (the L1 Pallas
//!   kernel running under PJRT) — the production path;
//! - [`RouterBackend::Native`]: the pure-rust mirror (gating::noisy_topk)
//!   — used for tests, for hierarchical routing, and when no artifact was
//!   lowered for the config.
//!
//! Both produce identical decisions on identical noise (asserted in
//! rust/tests/parity.rs), which is what lets the distributed simulation
//! claim numerical equivalence with the monolithic artifact.
//!
//! # Row-blocked routing (the streaming gate stage)
//!
//! The Native math is exposed in two grains: [`Router::route`] gates a
//! whole batch, and [`Router::route_rows`] gates one row block of it.
//! Because every eq-4 noise draw is pre-drawn serially by
//! [`Router::draw_noise`], disjoint row blocks can be routed on
//! different worker threads and still produce gate vectors bit-identical
//! to the serial whole-batch call — this is what lets the
//! [`ExecutionEngine`](crate::coordinator::engine::ExecutionEngine)
//! overlap gating with expert compute instead of serializing
//! route → dispatch → execute on the coordinator.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::gating::noisy_topk::{
    compose_hierarchical, importance, load_estimate, noisy_topk_block,
    noisy_topk_block_masked, GateVec,
};
use crate::runtime::{Executable, Host, TensorF};
use crate::util::rng::Rng;

pub enum RouterBackend {
    Artifact(Arc<Executable>),
    Native,
}

pub struct Router {
    pub backend: RouterBackend,
    pub n_experts: usize,
    pub k: usize,
    /// hierarchical: number of primary groups (0 = flat)
    pub groups: usize,
    pub d_model: usize,
    /// gating parameters, row-major (d, n) — sliced from the flat param
    /// vector by the caller (manifest layout)
    pub w_g: Vec<f32>,
    pub w_noise: Option<Vec<f32>>,
    /// hierarchical secondary gates: (d, groups, group_size) flattened
    pub w_g_sec: Option<Vec<f32>>,
    pub w_n_sec: Option<Vec<f32>>,
}

#[derive(Clone, Debug)]
pub struct RoutingDecision {
    pub per_token: Vec<GateVec>,
    pub importance: Vec<f32>,
    pub load: Vec<f32>,
    /// the pre-drawn eq-4 normals this replica's routing consumed,
    /// retained on the training paths so the backward pass
    /// ([`crate::gating::backward`]) can differentiate through the
    /// noise term without redrawing it; `None` on deterministic (eval /
    /// serving) routes and on the artifact router
    pub noise: Option<RouteNoise>,
}

/// Every eq-4 normal one routing of a `b`-row batch will consume, drawn
/// up front in the exact order the serial path draws them.  Pre-drawing
/// is what lets disjoint row blocks route concurrently (each consumes
/// its own slice) while staying bit-identical to [`Router::route`] —
/// and, retained on [`RoutingDecision::noise`], it is the tape the
/// gating backward replays.
#[derive(Clone, Debug)]
pub struct RouteNoise {
    /// primary-gate normals, row-major (b, n) for flat routers and
    /// (b, groups) for hierarchical; empty without noise weights
    pub primary: Vec<f32>,
    /// hierarchical secondary normals, (b, k, group_size) consumed in
    /// primary-selection order; empty for flat routers or without
    /// secondary noise weights
    pub secondary: Vec<f32>,
}

/// One routed row block: per-row gate vectors plus partial balance sums
/// over just these rows — the unit of work the streaming pipeline moves
/// from the gate stage to the dispatch stage.
pub struct RouteBlock {
    pub per_token: Vec<GateVec>,
    /// eq-6 importance summed over just these rows
    pub importance: Vec<f32>,
    /// eq-8–10 smooth load (hard counts at eval) over just these rows
    pub load: Vec<f32>,
}

impl Router {
    pub fn flat_native(
        d_model: usize,
        n_experts: usize,
        k: usize,
        w_g: Vec<f32>,
        w_noise: Option<Vec<f32>>,
    ) -> Self {
        Router {
            backend: RouterBackend::Native,
            n_experts,
            k,
            groups: 0,
            d_model,
            w_g,
            w_noise,
            w_g_sec: None,
            w_n_sec: None,
        }
    }

    /// Route a batch x (b, d).  `rng` draws the eq-4 noise; None = eval.
    pub fn route(&self, x: &TensorF, mut rng: Option<&mut Rng>)
        -> Result<RoutingDecision> {
        let b = x.shape[0];
        if x.shape.len() != 2 || x.shape[1] != self.d_model {
            bail!("router: bad input shape {:?}", x.shape);
        }
        // hierarchical routing is Native math regardless of backend
        if self.groups > 0 || matches!(self.backend, RouterBackend::Native) {
            let noise = self.draw_noise(b, rng.as_deref_mut());
            let blk = self.route_rows(x, 0, b, noise.as_ref())?;
            return Ok(RoutingDecision {
                per_token: blk.per_token,
                importance: blk.importance,
                load: blk.load,
                noise,
            });
        }
        match &self.backend {
            RouterBackend::Native => unreachable!("handled above"),
            RouterBackend::Artifact(exe) => {
                let n = self.n_experts;
                // the artifact's batch dimension is static: pad the token
                // batch up (zero rows) and slice the decisions back down.
                let art_b = exe.sig.inputs[2].shape[0];
                if b > art_b {
                    bail!(
                        "router artifact batch {art_b} < tokens {b}; split \
                         the replica batch"
                    );
                }
                let mut xp = x.data.clone();
                xp.resize(art_b * self.d_model, 0.0);
                let noise: Vec<f32> = match rng {
                    Some(r) => {
                        (0..art_b * n).map(|_| r.normal_f32()).collect()
                    }
                    None => vec![0.0; art_b * n],
                };
                let wn = self
                    .w_noise
                    .clone()
                    .unwrap_or_else(|| vec![0.0; self.d_model * n]);
                let outs = exe.run(&[
                    Host::F32(TensorF::new(vec![self.d_model, n], self.w_g.clone())),
                    Host::F32(TensorF::new(vec![self.d_model, n], wn)),
                    Host::F32(TensorF::new(vec![art_b, self.d_model], xp)),
                    Host::F32(TensorF::new(vec![art_b, n], noise)),
                ])?;
                // outputs: gates (B,n), topi (B,k), topw (B,k), imp, load —
                // imp/load include the padding rows, so recompute from the
                // sliced decisions (load as hard counts; the smooth eq-10
                // estimate is only needed for training, which happens in
                // the monolithic step artifact).
                let topi = outs[1].as_i32()?;
                let topw = outs[2].as_f32()?;
                let mut importance = vec![0f32; n];
                let mut load = vec![0f32; n];
                let per_token: Vec<GateVec> = (0..b)
                    .map(|r| {
                        let experts: Vec<usize> =
                            topi.row(r).iter().map(|&i| i as usize).collect();
                        let weights = topw.row(r).to_vec();
                        for (e, w) in experts.iter().zip(weights.iter()) {
                            importance[*e] += w;
                            load[*e] += 1.0;
                        }
                        GateVec { experts, weights }
                    })
                    .collect();
                // the artifact consumed its noise device-side; nothing
                // to retain for a native backward
                Ok(RoutingDecision { per_token, importance, load, noise: None })
            }
        }
    }

    /// Draw every eq-4 normal one routing of a `b`-row batch will
    /// consume, in the exact order the serial path draws them.  `None`
    /// (eval) means deterministic routing.
    pub fn draw_noise(&self, b: usize, rng: Option<&mut Rng>)
        -> Option<RouteNoise> {
        let rng = rng?;
        let n_pri = if self.groups > 0 { self.groups } else { self.n_experts };
        let primary: Vec<f32> = if self.w_noise.is_some() {
            (0..b * n_pri).map(|_| rng.normal_f32()).collect()
        } else {
            Vec::new()
        };
        let secondary: Vec<f32> = if self.groups > 0 && self.w_n_sec.is_some() {
            let gs = self.n_experts / self.groups;
            (0..b * self.k * gs).map(|_| rng.normal_f32()).collect()
        } else {
            Vec::new()
        };
        Some(RouteNoise { primary, secondary })
    }

    /// Route rows `[lo, hi)` of `x` with the Native math (flat or
    /// hierarchical).  `noise` must come from
    /// [`draw_noise`](Self::draw_noise) over the same batch; `None` =
    /// eval.  Appending blocks in row order reproduces
    /// [`route`](Self::route) exactly: gate vectors are bit-identical,
    /// importance/load sums equal up to f32 reassociation across blocks.
    pub fn route_rows(&self, x: &TensorF, lo: usize, hi: usize,
                      noise: Option<&RouteNoise>) -> Result<RouteBlock> {
        self.route_rows_masked(x, lo, hi, noise, None)
    }

    /// [`route_rows`](Self::route_rows) with an optional dead-expert
    /// mask (the fault layer's [`FaultPlan::router_mask`] output):
    /// masked experts' noisy logits are `-inf`, so they are never
    /// selected and carry exactly-zero gate weight.  `dead: None` is
    /// byte-identical to the unmasked path.  The hierarchical path
    /// ignores the mask (degrade-only there): its group-structured
    /// gate has no per-expert logit row to mask, and dead shards still
    /// degrade safely at dispatch time.
    ///
    /// [`FaultPlan::router_mask`]:
    ///     crate::coordinator::faults::FaultPlan::router_mask
    pub fn route_rows_masked(
        &self,
        x: &TensorF,
        lo: usize,
        hi: usize,
        noise: Option<&RouteNoise>,
        dead: Option<&[bool]>,
    ) -> Result<RouteBlock> {
        let (b, d) = (x.shape[0], self.d_model);
        if x.shape.len() != 2 || x.shape[1] != d {
            bail!("router: bad input shape {:?}", x.shape);
        }
        if lo > hi || hi > b {
            bail!("route_rows: bad row range {lo}..{hi} of {b}");
        }
        if self.groups > 0 {
            return self.route_rows_hierarchical(x, lo, hi, noise);
        }
        let n = self.n_experts;
        let train = noise.is_some();
        let wn = if train { self.w_noise.as_deref() } else { None };
        let normals = noise.and_then(|ns| {
            (!ns.primary.is_empty()).then(|| &ns.primary[lo * n..hi * n])
        });
        let g = noisy_topk_block_masked(
            &x.data[lo * d..hi * d],
            hi - lo,
            d,
            &self.w_g,
            wn,
            n,
            self.k,
            normals,
            dead,
        );
        let imp = importance(&g);
        let load = load_estimate(&g, self.k);
        Ok(RouteBlock { per_token: g.per_token, importance: imp, load })
    }

    /// Two-level routing (Appendix B) for one row block: primary picks k
    /// groups, secondary picks k experts inside each chosen group; gates
    /// multiply (eq 12).
    fn route_rows_hierarchical(&self, x: &TensorF, lo: usize, hi: usize,
                               noise: Option<&RouteNoise>)
        -> Result<RouteBlock> {
        let (d, a) = (self.d_model, self.groups);
        let gs = self.n_experts / a;
        let Some(wsec) = self.w_g_sec.as_ref() else {
            bail!("hierarchical router needs secondary gates");
        };
        let train = noise.is_some();
        let wn_pri = if train { self.w_noise.as_deref() } else { None };
        let pri_normals = noise.and_then(|ns| {
            (!ns.primary.is_empty()).then(|| &ns.primary[lo * a..hi * a])
        });
        let primary = noisy_topk_block(
            &x.data[lo * d..hi * d],
            hi - lo,
            d,
            &self.w_g,
            wn_pri,
            a,
            self.k,
            pri_normals,
        );
        // secondary gating per group: w_g_sec is (d, a, gs) row-major;
        // extract the (d, gs) slice for group gi
        let mut per_token = Vec::with_capacity(hi - lo);
        let mut imp = vec![0f32; self.n_experts];
        let mut load = vec![0f32; self.n_experts];
        for (r_off, ptok) in primary.per_token.iter().enumerate() {
            let r = lo + r_off;
            let xrow = &x.data[r * d..(r + 1) * d];
            let mut secondary = vec![GateVec { experts: vec![], weights: vec![] }; a];
            // this row's pre-drawn secondary normals, consumed in
            // primary-selection order exactly as the serial path drew them
            let sec_normals = noise.and_then(|ns| {
                (!ns.secondary.is_empty()).then(|| {
                    &ns.secondary[r * self.k * gs..(r + 1) * self.k * gs]
                })
            });
            for (si, &gi) in ptok.experts.iter().enumerate() {
                let mut h = vec![0f32; gs];
                for l in 0..d {
                    let base = l * a * gs + gi * gs;
                    let xv = xrow[l];
                    for j in 0..gs {
                        h[j] += xv * wsec[base + j];
                    }
                }
                if let (Some(wn), Some(eps)) =
                    (self.w_n_sec.as_ref(), sec_normals) {
                    for j in 0..gs {
                        let mut raw = 0f32;
                        for l in 0..d {
                            raw += xrow[l] * wn[l * a * gs + gi * gs + j];
                        }
                        h[j] += eps[si * gs + j] * crate::gating::softplus(raw);
                    }
                }
                secondary[gi] =
                    crate::gating::noisy_topk::topk_softmax(&h, self.k.min(gs));
            }
            let flat = compose_hierarchical(ptok, &secondary, gs);
            for (e, w) in flat.experts.iter().zip(flat.weights.iter()) {
                imp[*e] += w;
                load[*e] += 1.0;
            }
            per_token.push(flat);
        }
        Ok(RouteBlock { per_token, importance: imp, load })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn native_flat_routing_shapes() {
        prop::forall("flat routing", |rng| {
            let (b, d, n) = (prop::dim(rng, 1, 16), 8, prop::dim(rng, 4, 32));
            let k = prop::dim(rng, 1, 4.min(n));
            let router = Router::flat_native(
                d, n, k,
                prop::vec_f32(rng, d * n, 0.5),
                Some(prop::vec_f32(rng, d * n, 0.5)),
            );
            let x = TensorF::new(vec![b, d], prop::vec_f32(rng, b * d, 1.0));
            let mut nrng = rng.fold_in(3);
            let dec = router.route(&x, Some(&mut nrng)).unwrap();
            assert_eq!(dec.per_token.len(), b);
            assert_eq!(dec.importance.len(), n);
            assert_eq!(dec.load.len(), n);
            for t in &dec.per_token {
                assert_eq!(t.experts.len(), k);
            }
            // importance mass == b (each row's gates sum to 1)
            let s: f32 = dec.importance.iter().sum();
            assert!((s - b as f32).abs() < 1e-3, "importance mass {s}");
        });
    }

    #[test]
    fn row_blocked_routing_matches_whole_batch() {
        // routing a batch as random row blocks with pre-drawn noise must
        // give bit-identical gate vectors and (up to f32 reassociation)
        // the same importance/load as the serial whole-batch route
        prop::forall("route_rows == route", |rng| {
            let (b, d) = (prop::dim(rng, 1, 14), 6);
            let hierarchical = rng.below(2) == 1;
            let router = if hierarchical {
                let (a, gs) = (prop::dim(rng, 2, 4), prop::dim(rng, 2, 4));
                Router {
                    backend: RouterBackend::Native,
                    n_experts: a * gs,
                    k: prop::dim(rng, 1, 2),
                    groups: a,
                    d_model: d,
                    w_g: prop::vec_f32(rng, d * a, 0.5),
                    w_noise: Some(prop::vec_f32(rng, d * a, 0.3)),
                    w_g_sec: Some(prop::vec_f32(rng, d * a * gs, 0.5)),
                    w_n_sec: Some(prop::vec_f32(rng, d * a * gs, 0.3)),
                }
            } else {
                let n = prop::dim(rng, 2, 12);
                Router::flat_native(
                    d,
                    n,
                    prop::dim(rng, 1, n.min(3)),
                    prop::vec_f32(rng, d * n, 0.5),
                    Some(prop::vec_f32(rng, d * n, 0.3)),
                )
            };
            let x = TensorF::new(vec![b, d], prop::vec_f32(rng, b * d, 1.0));
            let train = rng.below(2) == 1;
            let seed_rng = rng.fold_in(5);

            let mut rng_a = seed_rng.clone();
            let whole = router
                .route(&x, if train { Some(&mut rng_a) } else { None })
                .unwrap();

            let mut rng_b = seed_rng.clone();
            let noise = router.draw_noise(
                b,
                if train { Some(&mut rng_b) } else { None },
            );
            let n = router.n_experts;
            let mut per_token = Vec::new();
            let mut imp = vec![0f32; n];
            let mut load = vec![0f32; n];
            let mut lo = 0;
            while lo < b {
                let hi = (lo + 1 + rng.below(4)).min(b);
                let blk =
                    router.route_rows(&x, lo, hi, noise.as_ref()).unwrap();
                for (acc, v) in imp.iter_mut().zip(blk.importance.iter()) {
                    *acc += v;
                }
                for (acc, v) in load.iter_mut().zip(blk.load.iter()) {
                    *acc += v;
                }
                per_token.extend(blk.per_token);
                lo = hi;
            }

            assert_eq!(per_token.len(), whole.per_token.len());
            for (a, b) in per_token.iter().zip(whole.per_token.iter()) {
                assert_eq!(a.experts, b.experts, "gate selection differs");
                assert_eq!(a.weights, b.weights, "gate weights differ");
            }
            for (a, b) in imp.iter().zip(whole.importance.iter()) {
                assert!((a - b).abs() < 1e-4, "importance {a} vs {b}");
            }
            for (a, b) in load.iter().zip(whole.load.iter()) {
                assert!((a - b).abs() < 1e-3, "load {a} vs {b}");
            }
        });
    }

    #[test]
    fn eval_routing_is_deterministic() {
        let d = 4;
        let router = Router::flat_native(
            d, 8, 2,
            (0..d * 8).map(|i| (i as f32 * 0.37).sin()).collect(),
            Some(vec![0.5; d * 8]),
        );
        let x = TensorF::new(vec![3, d], (0..12).map(|i| i as f32 * 0.1).collect());
        let a = router.route(&x, None).unwrap();
        let b = router.route(&x, None).unwrap();
        for (ta, tb) in a.per_token.iter().zip(b.per_token.iter()) {
            assert_eq!(ta.experts, tb.experts);
        }
    }

    #[test]
    fn hierarchical_routing_selects_k_squared() {
        let (d, a, gs, k) = (6, 4, 4, 2);
        let n = a * gs;
        let mut rng = crate::util::rng::Rng::new(9);
        let router = Router {
            backend: RouterBackend::Native,
            n_experts: n,
            k,
            groups: a,
            d_model: d,
            w_g: prop::vec_f32(&mut rng, d * a, 0.5),
            w_noise: Some(prop::vec_f32(&mut rng, d * a, 0.3)),
            w_g_sec: Some(prop::vec_f32(&mut rng, d * a * gs, 0.5)),
            w_n_sec: Some(prop::vec_f32(&mut rng, d * a * gs, 0.3)),
        };
        let x = TensorF::new(vec![5, d], prop::vec_f32(&mut rng, 5 * d, 1.0));
        let mut nrng = rng.fold_in(1);
        let dec = router.route(&x, Some(&mut nrng)).unwrap();
        for t in &dec.per_token {
            assert_eq!(t.experts.len(), k * k);
            let s: f32 = t.weights.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "weights sum {s}");
            // all selected experts distinct and in range
            let mut e = t.experts.clone();
            e.sort();
            e.dedup();
            assert_eq!(e.len(), k * k);
            assert!(*e.last().unwrap() < n);
        }
    }
}
