//! Dispatcher: the all-to-all at the heart of the paper's §3.1 scheme.
//!
//! Takes routing decisions from every data-parallel replica and builds,
//! for each expert, the combined batch of token vectors routed to it —
//! the "kbd/n" batch that restores expert efficiency.  After expert
//! execution it scatters the outputs back and applies the gate-weighted
//! combine (eq 1).
//!
//! Unlike the AOT'd einsum path (static `capacity`, overflow dropped),
//! this dispatcher is exact: every route is kept and shards process
//! oversized batches in multiple waves.  The two paths' agreement (up to
//! drops) is covered in rust/tests/.

use crate::coordinator::router::RoutingDecision;
use crate::runtime::TensorF;

/// (replica, token-row) source address of a dispatched token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenAddr {
    pub replica: usize,
    pub row: usize,
}

/// Batch bound for one expert: where each token came from and its gate.
#[derive(Clone, Debug, Default)]
pub struct ExpertBatch {
    pub tokens: Vec<TokenAddr>,
    pub gates: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub n_experts: usize,
    pub per_expert: Vec<ExpertBatch>,
    /// tokens per replica (for combine allocation)
    pub replica_rows: Vec<usize>,
}

impl DispatchPlan {
    /// Total (token, expert) routes.
    pub fn total_routes(&self) -> usize {
        self.per_expert.iter().map(|e| e.tokens.len()).sum()
    }

    pub fn expert_loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(|e| e.tokens.len()).collect()
    }

    /// Bytes moved over the interconnect for this plan (activations in +
    /// out, f32), the §3.2 quantity.
    pub fn network_bytes(&self, d_model: usize) -> u64 {
        (self.total_routes() * d_model * 4 * 2) as u64
    }
}

pub struct Dispatcher;

impl Dispatcher {
    /// Build the all-to-all plan from per-replica routing decisions.
    /// Tokens keep replica-major, row-major order per expert, which makes
    /// the plan deterministic (and testable) regardless of thread timing.
    pub fn plan(decisions: &[RoutingDecision], n_experts: usize) -> DispatchPlan {
        let mut per_expert = vec![ExpertBatch::default(); n_experts];
        for (replica, dec) in decisions.iter().enumerate() {
            for (row, tok) in dec.per_token.iter().enumerate() {
                for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
                    per_expert[*e].tokens.push(TokenAddr { replica, row });
                    per_expert[*e].gates.push(*w);
                }
            }
        }
        DispatchPlan {
            n_experts,
            per_expert,
            replica_rows: decisions.iter().map(|d| d.per_token.len()).collect(),
        }
    }

    /// Gather the input rows for one expert from the replica activations.
    /// `xs[replica]` is (rows, d).  Returns (len, d) row-major.
    pub fn gather(plan: &DispatchPlan, expert: usize, xs: &[&TensorF]) -> TensorF {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let mut data = Vec::new();
        let rows = Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            &mut data,
        );
        TensorF::new(vec![rows, d], data)
    }

    /// Gather one expert's full batch into a caller-owned buffer
    /// (cleared first); returns the number of rows written.
    pub fn gather_into(
        plan: &DispatchPlan,
        expert: usize,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            buf,
        )
    }

    /// Gather a contiguous row range (one wave) of an expert's batch
    /// into a caller-owned buffer.  The engine's wave pipeline uses this
    /// to stage wave w+1 while wave w computes.
    pub fn gather_range_into(
        plan: &DispatchPlan,
        expert: usize,
        rows: std::ops::Range<usize>,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let n_rows = rows.len();
        buf.clear();
        buf.reserve(n_rows * d);
        for addr in &plan.per_expert[expert].tokens[rows] {
            buf.extend_from_slice(xs[addr.replica].row(addr.row));
        }
        n_rows
    }

    /// Scatter-combine expert outputs back to per-replica (rows, d)
    /// tensors: y[token] = Σ_e gate_e · expert_e(x_token)   (eq 1).
    pub fn combine(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
    ) -> Vec<TensorF> {
        let mut out: Vec<TensorF> = plan
            .replica_rows
            .iter()
            .map(|&rows| TensorF::zeros(vec![rows, d_model]))
            .collect();
        Self::combine_into(plan, expert_outputs, d_model, &mut out);
        out
    }

    /// Combine into caller-owned (and caller-zeroed) per-replica output
    /// tensors.  Accumulation order is expert-major, so any caller that
    /// presents complete expert outputs gets bit-identical results
    /// regardless of how the experts were scheduled.
    pub fn combine_into(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
        out: &mut [TensorF],
    ) {
        for (e, batch) in plan.per_expert.iter().enumerate() {
            let eo = &expert_outputs[e];
            debug_assert_eq!(eo.shape, vec![batch.tokens.len(), d_model]);
            for (slot, (addr, gate)) in
                batch.tokens.iter().zip(batch.gates.iter()).enumerate() {
                let src = &eo.data[slot * d_model..(slot + 1) * d_model];
                let dst = &mut out[addr.replica].data
                    [addr.row * d_model..(addr.row + 1) * d_model];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d += gate * s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::noisy_topk::GateVec;
    use crate::util::prop;

    fn decision(rows: usize, n: usize, k: usize, rng: &mut crate::util::rng::Rng)
        -> RoutingDecision {
        let per_token = (0..rows)
            .map(|_| {
                let mut experts: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut experts);
                experts.truncate(k);
                let mut weights = vec![0f32; k];
                let mut z = 0f32;
                for w in weights.iter_mut() {
                    *w = rng.uniform() as f32 + 0.1;
                    z += *w;
                }
                weights.iter_mut().for_each(|w| *w /= z);
                GateVec { experts, weights }
            })
            .collect();
        RoutingDecision { per_token, importance: vec![0.0; n], load: vec![0.0; n] }
    }

    #[test]
    fn plan_preserves_every_route() {
        prop::forall("routes preserved", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 2));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            let want: usize =
                decisions.iter().map(|d| d.per_token.len() * k).sum();
            assert_eq!(plan.total_routes(), want);
            // every address valid
            for eb in &plan.per_expert {
                for a in &eb.tokens {
                    assert!(a.replica < replicas);
                    assert!(a.row < decisions[a.replica].per_token.len());
                }
            }
        });
    }

    #[test]
    fn identity_experts_reconstruct_input() {
        // with identity experts and gates summing to 1, combine(gather(x))
        // must equal x exactly
        prop::forall("identity roundtrip", |rng| {
            let (d, n, k) = (4, 6, 2);
            let rows = prop::dim(rng, 1, 8);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            let outs: Vec<TensorF> = (0..n)
                .map(|e| Dispatcher::gather(&plan, e, &[&x]))
                .collect();
            let combined = Dispatcher::combine(&plan, &outs, d);
            for (a, b) in combined[0].data.iter().zip(x.data.iter()) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn gather_range_concatenates_to_full_gather() {
        prop::forall("gather ranges", |rng| {
            let (d, n, k) = (3, 5, 2);
            let rows = prop::dim(rng, 1, 12);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            for e in 0..n {
                let full = Dispatcher::gather(&plan, e, &[&x]);
                let len = plan.per_expert[e].tokens.len();
                let cut = if len == 0 { 0 } else { prop::dim(rng, 0, len) };
                let mut buf = Vec::new();
                let r1 = Dispatcher::gather_range_into(&plan, e, 0..cut, &[&x], &mut buf);
                let mut tail = Vec::new();
                let r2 = Dispatcher::gather_range_into(&plan, e, cut..len, &[&x], &mut tail);
                buf.extend_from_slice(&tail);
                assert_eq!(r1 + r2, len);
                assert_eq!(buf, full.data);
            }
        });
    }

    #[test]
    fn network_bytes_accounting() {
        let mut rng = crate::util::rng::Rng::new(0);
        let dec = decision(10, 4, 2, &mut rng);
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), 4);
        // 10 tokens * k=2 routes * d=8 * 4 bytes * 2 directions
        assert_eq!(plan.network_bytes(8), 10 * 2 * 8 * 4 * 2);
    }

    #[test]
    fn plan_is_deterministic_and_ordered() {
        let mut rng = crate::util::rng::Rng::new(1);
        let decs: Vec<_> = (0..3).map(|_| decision(4, 5, 2, &mut rng)).collect();
        let p1 = Dispatcher::plan(&decs, 5);
        let p2 = Dispatcher::plan(&decs, 5);
        for (a, b) in p1.per_expert.iter().zip(p2.per_expert.iter()) {
            assert_eq!(a.tokens, b.tokens);
        }
        // replica-major order within each expert queue
        for eb in &p1.per_expert {
            for w in eb.tokens.windows(2) {
                assert!(
                    (w[0].replica, w[0].row) <= (w[1].replica, w[1].row)
                );
            }
        }
    }
}
