//! Dispatcher: the all-to-all at the heart of the paper's §3.1 scheme.
//!
//! Takes routing decisions from every data-parallel replica and builds,
//! for each expert, the combined batch of token vectors routed to it —
//! the "kbd/n" batch that restores expert efficiency.  After expert
//! execution it scatters the outputs back and applies the gate-weighted
//! combine (eq 1).
//!
//! Unlike the AOT'd einsum path (static `capacity`, overflow dropped),
//! this dispatcher is exact: every route is kept and shards process
//! oversized batches in multiple waves.  The two paths' agreement (up to
//! drops) is covered in rust/tests/.

use crate::coordinator::router::RoutingDecision;
use crate::coordinator::scheduler::ShardLayout;
use crate::runtime::TensorF;

/// (replica, token-row) source address of a dispatched token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenAddr {
    pub replica: usize,
    pub row: usize,
}

/// Batch bound for one expert: where each token came from and its gate.
#[derive(Clone, Debug, Default)]
pub struct ExpertBatch {
    pub tokens: Vec<TokenAddr>,
    pub gates: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub n_experts: usize,
    pub per_expert: Vec<ExpertBatch>,
    /// tokens per replica (for combine allocation)
    pub replica_rows: Vec<usize>,
    /// routes redirected to another of their token's selected experts
    /// because the first choice's capacity buffer was full (GShard-style
    /// residual dispatch); always 0 on the exact (uncapped) paths
    pub rerouted_routes: usize,
    /// routes dropped outright — every expert the token selected was
    /// full; always 0 on the exact paths
    pub dropped_routes: usize,
}

/// The device a replica's activations live on: replica `r` is combined
/// on device `r % n_devices` (the engine's convention in
/// `ExecutionEngine::emit_combine`), so that is where its tokens depart
/// from and return to.
pub fn home_device(replica: usize, layout: &ShardLayout) -> usize {
    replica % layout.n_devices.max(1)
}

impl DispatchPlan {
    /// Total (token, expert) routes the plan kept.
    pub fn total_routes(&self) -> usize {
        self.per_expert.iter().map(|e| e.tokens.len()).sum()
    }

    /// Routes the router offered this step: kept + dropped.
    pub fn offered_routes(&self) -> usize {
        self.total_routes() + self.dropped_routes
    }

    /// Fraction of offered routes the capacity buffers dropped.
    pub fn drop_fraction(&self) -> f64 {
        if self.dropped_routes == 0 {
            return 0.0;
        }
        self.dropped_routes as f64 / self.offered_routes() as f64
    }

    pub fn expert_loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(|e| e.tokens.len()).collect()
    }

    /// Bytes moved over the interconnect for this plan (activations in +
    /// out, f32), the §3.2 quantity.  Only routes whose expert lives on
    /// a *different* device than the token's replica cross the
    /// interconnect; a token dispatched to an expert on its own shard
    /// never leaves the device and costs nothing here.
    pub fn network_bytes(&self, d_model: usize, layout: &ShardLayout) -> u64 {
        let mut remote_routes = 0u64;
        for (e, batch) in self.per_expert.iter().enumerate() {
            let owner = layout.owner(e);
            for addr in &batch.tokens {
                if home_device(addr.replica, layout) != owner {
                    remote_routes += 1;
                }
            }
        }
        remote_routes * (d_model * 4 * 2) as u64
    }

    /// Per-link breakdown of the same traffic: directional bytes and
    /// message counts between every (source, destination) device pair,
    /// with shard-local bytes tallied separately.  One "message" is one
    /// contiguous (replica, expert) run per direction — exactly the
    /// chunks [`Dispatcher::replica_runs`] partitions an expert batch
    /// into, i.e. the units the async all-to-all actually sends — so a
    /// topology model can price per-message latency as well as
    /// bandwidth, and intra-host vs inter-host hops separately.
    pub fn network_bytes_by_link(
        &self,
        d_model: usize,
        layout: &ShardLayout,
    ) -> LinkTraffic {
        let mut traffic = LinkTraffic::new(layout.n_devices);
        let row_bytes = (d_model * 4) as u64;
        for (e, batch) in self.per_expert.iter().enumerate() {
            let owner = layout.owner(e);
            for (replica, rows) in
                Dispatcher::replica_runs(self, e, 0..batch.tokens.len())
            {
                let bytes = rows.len() as u64 * row_bytes;
                let home = home_device(replica, layout);
                if home == owner {
                    // stays on-device: in + out, but never on a link
                    traffic.local_bytes += bytes * 2;
                } else {
                    traffic.add(home, owner, bytes, 1); // dispatch leg
                    traffic.add(owner, home, bytes, 1); // combine leg
                }
            }
        }
        traffic
    }
}

/// Directional per-device-pair traffic of one plan's all-to-all, as
/// measured from the dispatch plan by
/// [`DispatchPlan::network_bytes_by_link`].  The diagonal is always
/// empty: same-device traffic is recorded in `local_bytes` and is not
/// interconnect traffic.
#[derive(Clone, Debug)]
pub struct LinkTraffic {
    pub n_devices: usize,
    /// bytes moved src→dst, row-major `src * n_devices + dst`
    bytes: Vec<u64>,
    /// messages src→dst (one per contiguous replica-run per direction)
    messages: Vec<u64>,
    /// bytes that never left their device (expert on the token's shard)
    pub local_bytes: u64,
}

impl LinkTraffic {
    pub fn new(n_devices: usize) -> Self {
        let n = n_devices.max(1);
        LinkTraffic {
            n_devices: n,
            bytes: vec![0; n * n],
            messages: vec![0; n * n],
            local_bytes: 0,
        }
    }

    fn add(&mut self, src: usize, dst: usize, bytes: u64, msgs: u64) {
        debug_assert_ne!(src, dst, "local traffic is not link traffic");
        self.bytes[src * self.n_devices + dst] += bytes;
        self.messages[src * self.n_devices + dst] += msgs;
    }

    pub fn bytes_between(&self, src: usize, dst: usize) -> u64 {
        self.bytes[src * self.n_devices + dst]
    }

    pub fn messages_between(&self, src: usize, dst: usize) -> u64 {
        self.messages[src * self.n_devices + dst]
    }

    /// Total bytes crossing any link — equals
    /// [`DispatchPlan::network_bytes`] for the same plan and layout.
    pub fn interconnect_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    pub fn total_messages(&self) -> u64 {
        self.messages.iter().sum()
    }

    /// Non-empty links as `(src, dst, bytes, messages)`.
    pub fn links(&self) -> impl Iterator<Item = (usize, usize, u64, u64)> + '_ {
        let n = self.n_devices;
        self.bytes.iter().enumerate().filter(|(_, &b)| b > 0).map(
            move |(i, &b)| (i / n, i % n, b, self.messages[i]),
        )
    }
}

/// How residual dispatch picks among a full token's *other* selected
/// experts with room when its first choice's capacity buffer is full.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ResidualPolicy {
    /// First eligible expert in gate (descending-weight) order — the
    /// original deterministic rule.
    #[default]
    GateOrder,
    /// Seeded uniform pick among the eligible experts: a keyed hash of
    /// `(seed, replica, row, slot)` indexes the candidate list, so the
    /// choice is reproducible (same seed, same plan, bit for bit) and
    /// independent of thread timing, while spreading overflow load
    /// instead of always piling onto the next-heaviest gate.
    Random { seed: u64 },
}

/// splitmix64 finalizer — the residual pick's keyed hash.
fn mix64(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Incrementally built [`DispatchPlan`]: gate vectors are appended in
/// (replica, row) order — replica by replica, any number of row blocks
/// per replica — and per-expert rows become immutable the moment they
/// are appended.  That immutable-prefix property is what lets the
/// streaming pipeline gather and dispatch an expert's wave to its shard
/// *before* routing of the remaining tokens has finished: rows
/// `[0, len)` of an expert's batch never change once pushed, only grow.
///
/// `finish()` yields exactly the plan [`Dispatcher::plan`] builds from
/// the same decisions (asserted by tests): same token order, gates and
/// `replica_rows`.
pub struct PlanBuilder {
    plan: DispatchPlan,
    /// rows appended so far for the replica currently being routed
    cur_rows: usize,
    /// per-expert capacity buffer (GShard-style); `None` = exact
    /// dispatch, every route kept
    capacity: Option<usize>,
    /// residual-target selection rule when the first choice is full
    residual: ResidualPolicy,
}

impl PlanBuilder {
    pub fn new(n_experts: usize) -> Self {
        Self::with_capacity(n_experts, None)
    }

    /// Set the residual-target selection rule (default
    /// [`ResidualPolicy::GateOrder`]).  Only relevant with a capacity.
    pub fn with_residual_policy(mut self, residual: ResidualPolicy) -> Self {
        self.residual = residual;
        self
    }

    /// A builder whose per-expert batches are bounded by `capacity`
    /// rows.  When a token's chosen expert is full, the route falls
    /// through to the token's next selected expert with room (residual
    /// second-choice dispatch, gate weight carried along); if every
    /// selected expert is full the route is dropped.  The rule depends
    /// only on loads-so-far and tokens are processed in (replica, row,
    /// gate-slot) order, so capped dispatch is exactly as deterministic
    /// — and keeps the immutable-prefix property — as the exact path,
    /// and with `capacity` at or above every expert's natural load the
    /// resulting plan is bit-identical to the uncapped one.
    pub fn with_capacity(n_experts: usize, capacity: Option<usize>) -> Self {
        PlanBuilder {
            plan: DispatchPlan {
                n_experts,
                per_expert: vec![ExpertBatch::default(); n_experts],
                replica_rows: Vec::new(),
                rerouted_routes: 0,
                dropped_routes: 0,
            },
            cur_rows: 0,
            capacity,
            residual: ResidualPolicy::GateOrder,
        }
    }

    /// Append the next routed rows of the current replica; row indices
    /// are assigned consecutively from the rows already pushed.
    pub fn push_rows(&mut self, gates: &[crate::gating::noisy_topk::GateVec]) {
        let cap = self.capacity.unwrap_or(usize::MAX);
        let replica = self.plan.replica_rows.len();
        for tok in gates {
            let row = self.cur_rows;
            for (slot, (&first, &w)) in
                tok.experts.iter().zip(tok.weights.iter()).enumerate()
            {
                let chosen = if self.plan.per_expert[first].tokens.len() < cap
                {
                    Some(first)
                } else {
                    // residual dispatch: among the token's other selected
                    // experts with room (a duplicate of `first` can never
                    // qualify — its buffer is the full one), pick per the
                    // residual policy
                    match self.residual {
                        ResidualPolicy::GateOrder => tok
                            .experts
                            .iter()
                            .enumerate()
                            .filter(|&(j, _)| j != slot)
                            .map(|(_, &e)| e)
                            .find(|&e| {
                                self.plan.per_expert[e].tokens.len() < cap
                            }),
                        ResidualPolicy::Random { seed } => {
                            let cands: Vec<usize> = tok
                                .experts
                                .iter()
                                .enumerate()
                                .filter(|&(j, _)| j != slot)
                                .map(|(_, &e)| e)
                                .filter(|&e| {
                                    self.plan.per_expert[e].tokens.len() < cap
                                })
                                .collect();
                            if cands.is_empty() {
                                None
                            } else {
                                // keyed hash of the route coordinates —
                                // deterministic, timing-independent
                                let h = mix64(
                                    mix64(seed ^ replica as u64)
                                        ^ ((row as u64) << 20 | slot as u64),
                                );
                                Some(cands[(h % cands.len() as u64) as usize])
                            }
                        }
                    }
                };
                match chosen {
                    Some(e) => {
                        if e != first {
                            self.plan.rerouted_routes += 1;
                        }
                        self.plan.per_expert[e]
                            .tokens
                            .push(TokenAddr { replica, row });
                        self.plan.per_expert[e].gates.push(w);
                    }
                    None => self.plan.dropped_routes += 1,
                }
            }
            self.cur_rows += 1;
        }
    }

    /// Close out the current replica (recording its row count) and start
    /// appending the next one.
    pub fn finish_replica(&mut self) {
        self.plan.replica_rows.push(self.cur_rows);
        self.cur_rows = 0;
    }

    /// Rows appended so far for `expert` (the immutable prefix of its
    /// final batch).
    pub fn expert_len(&self, expert: usize) -> usize {
        self.plan.per_expert[expert].tokens.len()
    }

    /// The plan under construction.  `per_expert` rows `[0, expert_len)`
    /// are final; `replica_rows` only covers finished replicas.  Safe
    /// for [`Dispatcher::gather_range_into`] over already-appended rows.
    pub fn plan(&self) -> &DispatchPlan {
        &self.plan
    }

    /// Finalize.  Every replica must have been closed with
    /// [`finish_replica`](Self::finish_replica).
    pub fn finish(self) -> DispatchPlan {
        debug_assert_eq!(self.cur_rows, 0, "unfinished replica");
        self.plan
    }
}

pub struct Dispatcher;

impl Dispatcher {
    /// Serially route every replica in order and build the batch plan —
    /// the pre-streaming composition, shared by the scheduler's artifact
    /// fallback, the workload harness and the benches so the
    /// route→plan reference semantics live in exactly one place.
    pub fn route_and_plan(
        router: &crate::coordinator::router::Router,
        xs: &[&crate::runtime::TensorF],
        mut rng: Option<&mut crate::util::rng::Rng>,
    ) -> anyhow::Result<(Vec<RoutingDecision>, DispatchPlan)> {
        let decisions = xs
            .iter()
            .map(|x| router.route(x, rng.as_deref_mut()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let plan = Self::plan(&decisions, router.n_experts);
        Ok((decisions, plan))
    }

    /// Build the all-to-all plan from per-replica routing decisions.
    /// Tokens keep replica-major, row-major order per expert, which makes
    /// the plan deterministic (and testable) regardless of thread timing.
    pub fn plan(decisions: &[RoutingDecision], n_experts: usize) -> DispatchPlan {
        let mut per_expert = vec![ExpertBatch::default(); n_experts];
        for (replica, dec) in decisions.iter().enumerate() {
            for (row, tok) in dec.per_token.iter().enumerate() {
                for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
                    per_expert[*e].tokens.push(TokenAddr { replica, row });
                    per_expert[*e].gates.push(*w);
                }
            }
        }
        DispatchPlan {
            n_experts,
            per_expert,
            replica_rows: decisions.iter().map(|d| d.per_token.len()).collect(),
            rerouted_routes: 0,
            dropped_routes: 0,
        }
    }

    /// Like [`plan`](Self::plan) but with a GShard-style per-expert
    /// capacity buffer — the serial oracle for capacity-factor dispatch.
    /// `capacity: None` is exact and bit-identical to `plan`.
    pub fn plan_with_capacity(
        decisions: &[RoutingDecision],
        n_experts: usize,
        capacity: Option<usize>,
    ) -> DispatchPlan {
        Self::plan_with_capacity_policy(
            decisions,
            n_experts,
            capacity,
            ResidualPolicy::GateOrder,
        )
    }

    /// [`plan_with_capacity`](Self::plan_with_capacity) with an explicit
    /// [`ResidualPolicy`] — the serial oracle for the seeded-random
    /// residual dispatch variant.
    pub fn plan_with_capacity_policy(
        decisions: &[RoutingDecision],
        n_experts: usize,
        capacity: Option<usize>,
        residual: ResidualPolicy,
    ) -> DispatchPlan {
        let mut builder = PlanBuilder::with_capacity(n_experts, capacity)
            .with_residual_policy(residual);
        for dec in decisions {
            builder.push_rows(&dec.per_token);
            builder.finish_replica();
        }
        builder.finish()
    }

    /// GShard's per-expert buffer size for a capacity factor:
    /// `max(ceil(cf · tokens · k / n_experts), 1)` — at `cf = 1.0` a
    /// perfectly balanced router fills every buffer exactly and drops
    /// nothing.
    pub fn capacity_for(
        factor: f64,
        tokens: usize,
        k: usize,
        n_experts: usize,
    ) -> usize {
        let per_expert =
            (tokens * k) as f64 * factor / n_experts.max(1) as f64;
        (per_expert.ceil() as usize).max(1)
    }

    /// Gather the input rows for one expert from the replica activations.
    /// `xs[replica]` is (rows, d).  Returns (len, d) row-major.
    pub fn gather(plan: &DispatchPlan, expert: usize, xs: &[&TensorF]) -> TensorF {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let mut data = Vec::new();
        let rows = Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            &mut data,
        );
        TensorF::new(vec![rows, d], data)
    }

    /// Gather one expert's full batch into a caller-owned buffer
    /// (cleared first); returns the number of rows written.
    pub fn gather_into(
        plan: &DispatchPlan,
        expert: usize,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            buf,
        )
    }

    /// Split rows `[rows.start, rows.end)` of one expert's batch into
    /// maximal per-replica runs — the combine partition of one drained
    /// expert chunk.  Tokens are replica-major within every expert
    /// batch ([`Dispatcher::plan`] order, preserved by [`PlanBuilder`]),
    /// so each replica's rows form exactly one contiguous run; the
    /// dependency-driven executor uses these runs as the "messages" of
    /// the async all-to-all, delivering each to its replica's combine
    /// queue the moment the chunk drains.
    pub fn replica_runs(
        plan: &DispatchPlan,
        expert: usize,
        rows: std::ops::Range<usize>,
    ) -> Vec<(usize, std::ops::Range<usize>)> {
        let toks = &plan.per_expert[expert].tokens[rows.clone()];
        let mut runs = Vec::new();
        let mut i = 0;
        while i < toks.len() {
            let replica = toks[i].replica;
            let start = i;
            while i < toks.len() && toks[i].replica == replica {
                i += 1;
            }
            runs.push((replica, rows.start + start..rows.start + i));
        }
        runs
    }

    /// Gather a contiguous row range (one wave) of an expert's batch
    /// into a caller-owned buffer.  The engine's wave pipeline uses this
    /// to stage wave w+1 while wave w computes.
    pub fn gather_range_into(
        plan: &DispatchPlan,
        expert: usize,
        rows: std::ops::Range<usize>,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let n_rows = rows.len();
        buf.clear();
        buf.reserve(n_rows * d);
        for addr in &plan.per_expert[expert].tokens[rows] {
            buf.extend_from_slice(xs[addr.replica].row(addr.row));
        }
        n_rows
    }

    /// Scatter-combine expert outputs back to per-replica (rows, d)
    /// tensors: y[token] = Σ_e gate_e · expert_e(x_token)   (eq 1).
    pub fn combine(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
    ) -> Vec<TensorF> {
        let mut out: Vec<TensorF> = plan
            .replica_rows
            .iter()
            .map(|&rows| TensorF::zeros(vec![rows, d_model]))
            .collect();
        Self::combine_into(plan, expert_outputs, d_model, &mut out);
        out
    }

    /// Combine into caller-owned (and caller-zeroed) per-replica output
    /// tensors.  Accumulation order is expert-major, so any caller that
    /// presents complete expert outputs gets bit-identical results
    /// regardless of how the experts were scheduled.
    pub fn combine_into(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
        out: &mut [TensorF],
    ) {
        for (e, batch) in plan.per_expert.iter().enumerate() {
            let eo = &expert_outputs[e];
            debug_assert_eq!(eo.shape, vec![batch.tokens.len(), d_model]);
            for (slot, (addr, gate)) in
                batch.tokens.iter().zip(batch.gates.iter()).enumerate() {
                let src = &eo.data[slot * d_model..(slot + 1) * d_model];
                let dst = &mut out[addr.replica].data
                    [addr.row * d_model..(addr.row + 1) * d_model];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d += gate * s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::noisy_topk::GateVec;
    use crate::util::prop;

    fn decision(rows: usize, n: usize, k: usize, rng: &mut crate::util::rng::Rng)
        -> RoutingDecision {
        let per_token = (0..rows)
            .map(|_| {
                let mut experts: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut experts);
                experts.truncate(k);
                let mut weights = vec![0f32; k];
                let mut z = 0f32;
                for w in weights.iter_mut() {
                    *w = rng.uniform() as f32 + 0.1;
                    z += *w;
                }
                weights.iter_mut().for_each(|w| *w /= z);
                GateVec { experts, weights }
            })
            .collect();
        RoutingDecision {
            per_token,
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }
    }

    #[test]
    fn plan_preserves_every_route() {
        prop::forall("routes preserved", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 2));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            let want: usize =
                decisions.iter().map(|d| d.per_token.len() * k).sum();
            assert_eq!(plan.total_routes(), want);
            // every address valid
            for eb in &plan.per_expert {
                for a in &eb.tokens {
                    assert!(a.replica < replicas);
                    assert!(a.row < decisions[a.replica].per_token.len());
                }
            }
        });
    }

    #[test]
    fn identity_experts_reconstruct_input() {
        // with identity experts and gates summing to 1, combine(gather(x))
        // must equal x exactly
        prop::forall("identity roundtrip", |rng| {
            let (d, n, k) = (4, 6, 2);
            let rows = prop::dim(rng, 1, 8);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            let outs: Vec<TensorF> = (0..n)
                .map(|e| Dispatcher::gather(&plan, e, &[&x]))
                .collect();
            let combined = Dispatcher::combine(&plan, &outs, d);
            for (a, b) in combined[0].data.iter().zip(x.data.iter()) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn gather_range_concatenates_to_full_gather() {
        prop::forall("gather ranges", |rng| {
            let (d, n, k) = (3, 5, 2);
            let rows = prop::dim(rng, 1, 12);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            for e in 0..n {
                let full = Dispatcher::gather(&plan, e, &[&x]);
                let len = plan.per_expert[e].tokens.len();
                let cut = if len == 0 { 0 } else { prop::dim(rng, 0, len) };
                let mut buf = Vec::new();
                let r1 = Dispatcher::gather_range_into(&plan, e, 0..cut, &[&x], &mut buf);
                let mut tail = Vec::new();
                let r2 = Dispatcher::gather_range_into(&plan, e, cut..len, &[&x], &mut tail);
                buf.extend_from_slice(&tail);
                assert_eq!(r1 + r2, len);
                assert_eq!(buf, full.data);
            }
        });
    }

    #[test]
    fn incremental_builder_matches_batch_plan() {
        // a PlanBuilder fed the same decisions in randomized row blocks
        // must produce exactly Dispatcher::plan: token order, gates and
        // replica_rows (satellite contract for the streaming pipeline)
        prop::forall("builder == plan", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let want = Dispatcher::plan(&decisions, n);

            let mut builder = PlanBuilder::new(n);
            for dec in &decisions {
                let rows = dec.per_token.len();
                let mut lo = 0;
                while lo < rows {
                    let hi = (lo + 1 + rng.below(4)).min(rows);
                    builder.push_rows(&dec.per_token[lo..hi]);
                    lo = hi;
                }
                builder.finish_replica();
                // prefix immutability mid-build: rows appended so far
                // already equal the final plan's prefix
                for e in 0..n {
                    let len = builder.expert_len(e);
                    assert_eq!(
                        builder.plan().per_expert[e].tokens[..len],
                        want.per_expert[e].tokens[..len]
                    );
                }
            }
            let got = builder.finish();
            assert_eq!(got.n_experts, want.n_experts);
            assert_eq!(got.replica_rows, want.replica_rows);
            for (g, w) in got.per_expert.iter().zip(want.per_expert.iter()) {
                assert_eq!(g.tokens, w.tokens);
                assert_eq!(g.gates, w.gates);
            }
        });
    }

    /// Like `decision` but each token may route the *same* expert more
    /// than once (duplicate top-k indices — possible for callers that
    /// feed unnormalized gate vectors), which the builder and the
    /// combine partition must both tolerate.
    fn decision_with_duplicates(
        rows: usize,
        n: usize,
        k: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> RoutingDecision {
        let per_token = (0..rows)
            .map(|_| {
                let experts: Vec<usize> =
                    (0..k).map(|_| rng.below(n)).collect();
                let weights = vec![1.0 / k as f32; k];
                GateVec { experts, weights }
            })
            .collect();
        RoutingDecision {
            per_token,
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }
    }

    #[test]
    fn replica_runs_partition_expert_batches() {
        // per-replica combine partition: the runs of any row range must
        // concatenate back to the range, be replica-major, and name each
        // replica at most once (tokens are replica-major per expert)
        prop::forall("replica runs", |rng| {
            let (n, k) = (prop::dim(rng, 2, 8), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 5);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 8), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            for e in 0..n {
                let len = plan.per_expert[e].tokens.len();
                let lo = if len == 0 { 0 } else { prop::dim(rng, 0, len) };
                let hi = if lo == len { len } else { prop::dim(rng, lo, len) };
                let runs = Dispatcher::replica_runs(&plan, e, lo..hi);
                let mut cursor = lo;
                let mut last_replica = None;
                for (r, range) in &runs {
                    assert_eq!(range.start, cursor, "runs must be contiguous");
                    assert!(range.end > range.start, "empty run");
                    cursor = range.end;
                    if let Some(prev) = last_replica {
                        assert!(*r > prev, "replica-major run order");
                    }
                    last_replica = Some(*r);
                    for addr in &plan.per_expert[e].tokens[range.clone()] {
                        assert_eq!(addr.replica, *r);
                    }
                }
                assert_eq!(cursor, hi, "runs must cover the range");
            }
        });
    }

    #[test]
    fn dispatched_prefixes_stay_immutable_with_duplicate_topk() {
        // satellite contract: once a wave [0, len) has been dispatched,
        // those rows never change — even when tokens route the same
        // expert twice via duplicate top-k indices
        prop::forall("prefix immutable (duplicates)", |rng| {
            let (n, k) = (prop::dim(rng, 2, 6), prop::dim(rng, 2, 4));
            let replicas = prop::dim(rng, 1, 3);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision_with_duplicates(prop::dim(rng, 1, 8), n, k, rng))
                .collect();
            let want = Dispatcher::plan(&decisions, n);

            let mut builder = PlanBuilder::new(n);
            // snapshots[e] = (len, tokens, gates) at simulated dispatch
            type Snapshot = (usize, Vec<TokenAddr>, Vec<f32>);
            let mut snapshots: Vec<Option<Snapshot>> = vec![None; n];
            for dec in &decisions {
                let rows = dec.per_token.len();
                let mut lo = 0;
                while lo < rows {
                    let hi = (lo + 1 + rng.below(3)).min(rows);
                    builder.push_rows(&dec.per_token[lo..hi]);
                    lo = hi;
                    // simulate dispatching a wave of a random expert:
                    // snapshot its current prefix
                    let e = rng.below(n);
                    let len = builder.expert_len(e);
                    let b = &builder.plan().per_expert[e];
                    snapshots[e] = Some((
                        len,
                        b.tokens[..len].to_vec(),
                        b.gates[..len].to_vec(),
                    ));
                    // every earlier snapshot still bit-equal to the
                    // prefix it was taken from
                    for (se, snap) in snapshots.iter().enumerate() {
                        let Some((slen, stoks, sgates)) = snap else {
                            continue;
                        };
                        let cur = &builder.plan().per_expert[se];
                        assert_eq!(&cur.tokens[..*slen], &stoks[..]);
                        assert_eq!(&cur.gates[..*slen], &sgates[..]);
                    }
                }
                builder.finish_replica();
            }
            let got = builder.finish();
            assert_eq!(got.replica_rows, want.replica_rows);
            for (g, w) in got.per_expert.iter().zip(want.per_expert.iter()) {
                assert_eq!(g.tokens, w.tokens);
                assert_eq!(g.gates, w.gates);
            }
        });
    }

    #[test]
    fn builder_prefixes_on_all_tokens_one_expert() {
        // degenerate layout: every route lands on expert 0; the prefix
        // is the whole (growing) batch and must match the batch plan at
        // every block boundary
        let n = 5;
        let rows = 13;
        let gv = GateVec { experts: vec![0, 0], weights: vec![0.5, 0.5] };
        let decisions = vec![RoutingDecision {
            per_token: vec![gv; rows],
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }];
        let want = Dispatcher::plan(&decisions, n);
        assert_eq!(want.per_expert[0].tokens.len(), 2 * rows);

        let mut builder = PlanBuilder::new(n);
        let mut lo = 0;
        while lo < rows {
            let hi = (lo + 4).min(rows);
            builder.push_rows(&decisions[0].per_token[lo..hi]);
            let len = builder.expert_len(0);
            assert_eq!(len, 2 * hi, "two routes per appended row");
            assert_eq!(
                builder.plan().per_expert[0].tokens[..len],
                want.per_expert[0].tokens[..len]
            );
            for e in 1..n {
                assert_eq!(builder.expert_len(e), 0);
            }
            lo = hi;
        }
        builder.finish_replica();
        let got = builder.finish();
        assert_eq!(got.per_expert[0].tokens, want.per_expert[0].tokens);
        assert_eq!(got.per_expert[0].gates, want.per_expert[0].gates);
    }

    #[test]
    fn network_bytes_accounting() {
        let mut rng = crate::util::rng::Rng::new(0);
        let dec = decision(10, 4, 2, &mut rng);
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), 4);
        // one device owns every expert: nothing crosses the interconnect
        let one = ShardLayout::new(1, 4);
        assert_eq!(plan.network_bytes(8, &one), 0);
        // one expert per device, the single replica homes on device 0:
        // only expert 0's routes stay local (§3.2 counts inter-device
        // traffic only)
        let four = ShardLayout::new(4, 4);
        let remote: usize = (1..4).map(|e| plan.per_expert[e].tokens.len()).sum();
        assert_eq!(plan.network_bytes(8, &four), (remote * 8 * 4 * 2) as u64);
        assert!(remote < plan.total_routes(), "some routes must be local");
    }

    #[test]
    fn local_expert_routes_cost_zero_interconnect() {
        // all tokens on their home shard's expert => zero interconnect
        // bytes, all bytes local (the over-counting bug this fixes)
        let n = 4;
        let layout = ShardLayout::new(2, n);
        // replica 0 homes on device 0, which owns experts 0 and 1
        let gv = GateVec { experts: vec![0, 1], weights: vec![0.5, 0.5] };
        let dec = RoutingDecision {
            per_token: vec![gv; 6],
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        };
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
        assert_eq!(plan.network_bytes(8, &layout), 0);
        let traffic = plan.network_bytes_by_link(8, &layout);
        assert_eq!(traffic.interconnect_bytes(), 0);
        assert_eq!(traffic.total_messages(), 0);
        // in + out for every route, all of it on-device
        assert_eq!(traffic.local_bytes, (12 * 8 * 4 * 2) as u64);
    }

    #[test]
    fn per_link_breakdown_is_conservative() {
        // link totals + local bytes == the old (over-counted) figure,
        // and interconnect totals match network_bytes, on any layout
        prop::forall("link conservation", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 5);
            let devices = prop::dim(rng, 1, 4);
            let d_model = prop::dim(rng, 1, 8);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 9), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            let layout = ShardLayout::new(devices, n);
            let traffic = plan.network_bytes_by_link(d_model, &layout);
            assert_eq!(
                traffic.interconnect_bytes(),
                plan.network_bytes(d_model, &layout)
            );
            assert_eq!(
                traffic.interconnect_bytes() + traffic.local_bytes,
                (plan.total_routes() * d_model * 4 * 2) as u64
            );
            // diagonal stays empty and links() agrees with the matrix
            for dev in 0..devices {
                assert_eq!(traffic.bytes_between(dev, dev), 0);
            }
            let from_links: u64 =
                traffic.links().map(|(_, _, b, _)| b).sum();
            assert_eq!(from_links, traffic.interconnect_bytes());
        });
    }

    #[test]
    fn capacity_respects_buffers_and_conserves_routes() {
        // capped dispatch: no expert ever exceeds the buffer (even via
        // residual second choices), kept + dropped == offered, and the
        // same decisions always produce the bit-identical plan
        prop::forall("capacity buffers", |rng| {
            let (n, k) = (prop::dim(rng, 2, 8), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let offered: usize =
                decisions.iter().map(|d| d.per_token.len() * k).sum();
            let cap = prop::dim(rng, 1, 6);
            let plan =
                Dispatcher::plan_with_capacity(&decisions, n, Some(cap));
            for load in plan.expert_loads() {
                assert!(load <= cap, "load {load} exceeds capacity {cap}");
            }
            assert_eq!(plan.total_routes() + plan.dropped_routes, offered);
            assert_eq!(plan.offered_routes(), offered);
            assert!(plan.drop_fraction() >= 0.0 && plan.drop_fraction() <= 1.0);
            let again =
                Dispatcher::plan_with_capacity(&decisions, n, Some(cap));
            assert_eq!(plan.dropped_routes, again.dropped_routes);
            assert_eq!(plan.rerouted_routes, again.rerouted_routes);
            for (a, b) in plan.per_expert.iter().zip(again.per_expert.iter()) {
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.gates, b.gates);
            }
        });
    }

    #[test]
    fn capacity_at_or_above_peak_load_is_bit_identical_to_exact() {
        prop::forall("ample capacity is exact", |rng| {
            let (n, k) = (prop::dim(rng, 2, 8), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let exact = Dispatcher::plan(&decisions, n);
            let peak = exact.expert_loads().into_iter().max().unwrap_or(0);
            let capped =
                Dispatcher::plan_with_capacity(&decisions, n, Some(peak.max(1)));
            assert_eq!(capped.dropped_routes, 0);
            assert_eq!(capped.rerouted_routes, 0);
            assert_eq!(capped.replica_rows, exact.replica_rows);
            for (a, b) in capped.per_expert.iter().zip(exact.per_expert.iter())
            {
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.gates, b.gates);
            }
        });
    }

    #[test]
    fn capacity_for_matches_gshard_formula() {
        // cf=1.0: perfectly divisible load fills buffers exactly
        assert_eq!(Dispatcher::capacity_for(1.0, 64, 2, 8), 16);
        // fractional capacities round up
        assert_eq!(Dispatcher::capacity_for(1.25, 64, 2, 8), 20);
        assert_eq!(Dispatcher::capacity_for(1.0, 10, 2, 8), 3);
        // floor at one row so an expert can always be addressed
        assert_eq!(Dispatcher::capacity_for(0.01, 4, 1, 64), 1);
    }

    #[test]
    fn random_residual_policy_is_seeded_and_conserves_routes() {
        // the seeded-random residual target selection keeps every
        // capacity invariant (buffers bounded, kept + dropped ==
        // offered) and is a pure function of (decisions, seed)
        prop::forall("random residual", |rng| {
            let (n, k) = (prop::dim(rng, 3, 8), prop::dim(rng, 2, 4));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let offered: usize =
                decisions.iter().map(|d| d.per_token.len() * k).sum();
            let cap = prop::dim(rng, 1, 4);
            let seed = rng.next_u64();
            let policy = ResidualPolicy::Random { seed };
            let plan = Dispatcher::plan_with_capacity_policy(
                &decisions, n, Some(cap), policy,
            );
            for load in plan.expert_loads() {
                assert!(load <= cap, "load {load} exceeds capacity {cap}");
            }
            assert_eq!(plan.total_routes() + plan.dropped_routes, offered);
            let again = Dispatcher::plan_with_capacity_policy(
                &decisions, n, Some(cap), policy,
            );
            assert_eq!(plan.dropped_routes, again.dropped_routes);
            assert_eq!(plan.rerouted_routes, again.rerouted_routes);
            for (a, b) in plan.per_expert.iter().zip(again.per_expert.iter())
            {
                assert_eq!(a.tokens, b.tokens);
                assert_eq!(a.gates, b.gates);
            }
        });
    }

    #[test]
    fn random_residual_policy_can_differ_from_gate_order() {
        // a witness that the random policy actually changes placement.
        // cap=9: nine k=1 tokens fill expert 0, then eight k=3 tokens
        // overflow slot 0 with residual candidates {1, 2} both open —
        // GateOrder always sends that route to expert 1, so some seed
        // of Random must place it differently
        let n = 3;
        let filler = GateVec { experts: vec![0], weights: vec![1.0] };
        let over = GateVec {
            experts: vec![0, 1, 2],
            weights: vec![0.5, 0.3, 0.2],
        };
        let mut per_token = vec![filler; 9];
        per_token.extend(vec![over; 8]);
        let offered: usize =
            per_token.iter().map(|t| t.experts.len()).sum();
        let decisions = vec![RoutingDecision {
            per_token,
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }];
        let gate_order =
            Dispatcher::plan_with_capacity(&decisions, n, Some(9));
        assert!(gate_order.rerouted_routes > 0, "witness must reroute");
        let mut saw_different = false;
        for seed in 0..32u64 {
            let p = Dispatcher::plan_with_capacity_policy(
                &decisions,
                n,
                Some(9),
                ResidualPolicy::Random { seed },
            );
            assert_eq!(p.total_routes() + p.dropped_routes, offered);
            for load in p.expert_loads() {
                assert!(load <= 9);
            }
            if p.per_expert[1].tokens != gate_order.per_expert[1].tokens
                || p.per_expert[2].tokens != gate_order.per_expert[2].tokens
            {
                saw_different = true;
                break;
            }
        }
        assert!(
            saw_different,
            "32 seeds of Random placed residual routes exactly like \
             GateOrder — the policy is not actually randomizing"
        );
    }

    #[test]
    fn plan_is_deterministic_and_ordered() {
        let mut rng = crate::util::rng::Rng::new(1);
        let decs: Vec<_> = (0..3).map(|_| decision(4, 5, 2, &mut rng)).collect();
        let p1 = Dispatcher::plan(&decs, 5);
        let p2 = Dispatcher::plan(&decs, 5);
        for (a, b) in p1.per_expert.iter().zip(p2.per_expert.iter()) {
            assert_eq!(a.tokens, b.tokens);
        }
        // replica-major order within each expert queue
        for eb in &p1.per_expert {
            for w in eb.tokens.windows(2) {
                assert!(
                    (w[0].replica, w[0].row) <= (w[1].replica, w[1].row)
                );
            }
        }
    }
}
