//! Dispatcher: the all-to-all at the heart of the paper's §3.1 scheme.
//!
//! Takes routing decisions from every data-parallel replica and builds,
//! for each expert, the combined batch of token vectors routed to it —
//! the "kbd/n" batch that restores expert efficiency.  After expert
//! execution it scatters the outputs back and applies the gate-weighted
//! combine (eq 1).
//!
//! Unlike the AOT'd einsum path (static `capacity`, overflow dropped),
//! this dispatcher is exact: every route is kept and shards process
//! oversized batches in multiple waves.  The two paths' agreement (up to
//! drops) is covered in rust/tests/.

use crate::coordinator::router::RoutingDecision;
use crate::runtime::TensorF;

/// (replica, token-row) source address of a dispatched token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenAddr {
    pub replica: usize,
    pub row: usize,
}

/// Batch bound for one expert: where each token came from and its gate.
#[derive(Clone, Debug, Default)]
pub struct ExpertBatch {
    pub tokens: Vec<TokenAddr>,
    pub gates: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub n_experts: usize,
    pub per_expert: Vec<ExpertBatch>,
    /// tokens per replica (for combine allocation)
    pub replica_rows: Vec<usize>,
}

impl DispatchPlan {
    /// Total (token, expert) routes.
    pub fn total_routes(&self) -> usize {
        self.per_expert.iter().map(|e| e.tokens.len()).sum()
    }

    pub fn expert_loads(&self) -> Vec<usize> {
        self.per_expert.iter().map(|e| e.tokens.len()).collect()
    }

    /// Bytes moved over the interconnect for this plan (activations in +
    /// out, f32), the §3.2 quantity.
    pub fn network_bytes(&self, d_model: usize) -> u64 {
        (self.total_routes() * d_model * 4 * 2) as u64
    }
}

/// Incrementally built [`DispatchPlan`]: gate vectors are appended in
/// (replica, row) order — replica by replica, any number of row blocks
/// per replica — and per-expert rows become immutable the moment they
/// are appended.  That immutable-prefix property is what lets the
/// streaming pipeline gather and dispatch an expert's wave to its shard
/// *before* routing of the remaining tokens has finished: rows
/// `[0, len)` of an expert's batch never change once pushed, only grow.
///
/// `finish()` yields exactly the plan [`Dispatcher::plan`] builds from
/// the same decisions (asserted by tests): same token order, gates and
/// `replica_rows`.
pub struct PlanBuilder {
    plan: DispatchPlan,
    /// rows appended so far for the replica currently being routed
    cur_rows: usize,
}

impl PlanBuilder {
    pub fn new(n_experts: usize) -> Self {
        PlanBuilder {
            plan: DispatchPlan {
                n_experts,
                per_expert: vec![ExpertBatch::default(); n_experts],
                replica_rows: Vec::new(),
            },
            cur_rows: 0,
        }
    }

    /// Append the next routed rows of the current replica; row indices
    /// are assigned consecutively from the rows already pushed.
    pub fn push_rows(&mut self, gates: &[crate::gating::noisy_topk::GateVec]) {
        let replica = self.plan.replica_rows.len();
        for tok in gates {
            let row = self.cur_rows;
            for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
                self.plan.per_expert[*e].tokens.push(TokenAddr { replica, row });
                self.plan.per_expert[*e].gates.push(*w);
            }
            self.cur_rows += 1;
        }
    }

    /// Close out the current replica (recording its row count) and start
    /// appending the next one.
    pub fn finish_replica(&mut self) {
        self.plan.replica_rows.push(self.cur_rows);
        self.cur_rows = 0;
    }

    /// Rows appended so far for `expert` (the immutable prefix of its
    /// final batch).
    pub fn expert_len(&self, expert: usize) -> usize {
        self.plan.per_expert[expert].tokens.len()
    }

    /// The plan under construction.  `per_expert` rows `[0, expert_len)`
    /// are final; `replica_rows` only covers finished replicas.  Safe
    /// for [`Dispatcher::gather_range_into`] over already-appended rows.
    pub fn plan(&self) -> &DispatchPlan {
        &self.plan
    }

    /// Finalize.  Every replica must have been closed with
    /// [`finish_replica`](Self::finish_replica).
    pub fn finish(self) -> DispatchPlan {
        debug_assert_eq!(self.cur_rows, 0, "unfinished replica");
        self.plan
    }
}

pub struct Dispatcher;

impl Dispatcher {
    /// Serially route every replica in order and build the batch plan —
    /// the pre-streaming composition, shared by the scheduler's artifact
    /// fallback, the workload harness and the benches so the
    /// route→plan reference semantics live in exactly one place.
    pub fn route_and_plan(
        router: &crate::coordinator::router::Router,
        xs: &[&crate::runtime::TensorF],
        mut rng: Option<&mut crate::util::rng::Rng>,
    ) -> anyhow::Result<(Vec<RoutingDecision>, DispatchPlan)> {
        let decisions = xs
            .iter()
            .map(|x| router.route(x, rng.as_deref_mut()))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let plan = Self::plan(&decisions, router.n_experts);
        Ok((decisions, plan))
    }

    /// Build the all-to-all plan from per-replica routing decisions.
    /// Tokens keep replica-major, row-major order per expert, which makes
    /// the plan deterministic (and testable) regardless of thread timing.
    pub fn plan(decisions: &[RoutingDecision], n_experts: usize) -> DispatchPlan {
        let mut per_expert = vec![ExpertBatch::default(); n_experts];
        for (replica, dec) in decisions.iter().enumerate() {
            for (row, tok) in dec.per_token.iter().enumerate() {
                for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
                    per_expert[*e].tokens.push(TokenAddr { replica, row });
                    per_expert[*e].gates.push(*w);
                }
            }
        }
        DispatchPlan {
            n_experts,
            per_expert,
            replica_rows: decisions.iter().map(|d| d.per_token.len()).collect(),
        }
    }

    /// Gather the input rows for one expert from the replica activations.
    /// `xs[replica]` is (rows, d).  Returns (len, d) row-major.
    pub fn gather(plan: &DispatchPlan, expert: usize, xs: &[&TensorF]) -> TensorF {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let mut data = Vec::new();
        let rows = Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            &mut data,
        );
        TensorF::new(vec![rows, d], data)
    }

    /// Gather one expert's full batch into a caller-owned buffer
    /// (cleared first); returns the number of rows written.
    pub fn gather_into(
        plan: &DispatchPlan,
        expert: usize,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        Self::gather_range_into(
            plan,
            expert,
            0..plan.per_expert[expert].tokens.len(),
            xs,
            buf,
        )
    }

    /// Split rows `[rows.start, rows.end)` of one expert's batch into
    /// maximal per-replica runs — the combine partition of one drained
    /// expert chunk.  Tokens are replica-major within every expert
    /// batch ([`Dispatcher::plan`] order, preserved by [`PlanBuilder`]),
    /// so each replica's rows form exactly one contiguous run; the
    /// dependency-driven executor uses these runs as the "messages" of
    /// the async all-to-all, delivering each to its replica's combine
    /// queue the moment the chunk drains.
    pub fn replica_runs(
        plan: &DispatchPlan,
        expert: usize,
        rows: std::ops::Range<usize>,
    ) -> Vec<(usize, std::ops::Range<usize>)> {
        let toks = &plan.per_expert[expert].tokens[rows.clone()];
        let mut runs = Vec::new();
        let mut i = 0;
        while i < toks.len() {
            let replica = toks[i].replica;
            let start = i;
            while i < toks.len() && toks[i].replica == replica {
                i += 1;
            }
            runs.push((replica, rows.start + start..rows.start + i));
        }
        runs
    }

    /// Gather a contiguous row range (one wave) of an expert's batch
    /// into a caller-owned buffer.  The engine's wave pipeline uses this
    /// to stage wave w+1 while wave w computes.
    pub fn gather_range_into(
        plan: &DispatchPlan,
        expert: usize,
        rows: std::ops::Range<usize>,
        xs: &[&TensorF],
        buf: &mut Vec<f32>,
    ) -> usize {
        let d = xs.first().map(|t| t.shape[1]).unwrap_or(0);
        let n_rows = rows.len();
        buf.clear();
        buf.reserve(n_rows * d);
        for addr in &plan.per_expert[expert].tokens[rows] {
            buf.extend_from_slice(xs[addr.replica].row(addr.row));
        }
        n_rows
    }

    /// Scatter-combine expert outputs back to per-replica (rows, d)
    /// tensors: y[token] = Σ_e gate_e · expert_e(x_token)   (eq 1).
    pub fn combine(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
    ) -> Vec<TensorF> {
        let mut out: Vec<TensorF> = plan
            .replica_rows
            .iter()
            .map(|&rows| TensorF::zeros(vec![rows, d_model]))
            .collect();
        Self::combine_into(plan, expert_outputs, d_model, &mut out);
        out
    }

    /// Combine into caller-owned (and caller-zeroed) per-replica output
    /// tensors.  Accumulation order is expert-major, so any caller that
    /// presents complete expert outputs gets bit-identical results
    /// regardless of how the experts were scheduled.
    pub fn combine_into(
        plan: &DispatchPlan,
        expert_outputs: &[TensorF],
        d_model: usize,
        out: &mut [TensorF],
    ) {
        for (e, batch) in plan.per_expert.iter().enumerate() {
            let eo = &expert_outputs[e];
            debug_assert_eq!(eo.shape, vec![batch.tokens.len(), d_model]);
            for (slot, (addr, gate)) in
                batch.tokens.iter().zip(batch.gates.iter()).enumerate() {
                let src = &eo.data[slot * d_model..(slot + 1) * d_model];
                let dst = &mut out[addr.replica].data
                    [addr.row * d_model..(addr.row + 1) * d_model];
                for (d, s) in dst.iter_mut().zip(src.iter()) {
                    *d += gate * s;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::noisy_topk::GateVec;
    use crate::util::prop;

    fn decision(rows: usize, n: usize, k: usize, rng: &mut crate::util::rng::Rng)
        -> RoutingDecision {
        let per_token = (0..rows)
            .map(|_| {
                let mut experts: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut experts);
                experts.truncate(k);
                let mut weights = vec![0f32; k];
                let mut z = 0f32;
                for w in weights.iter_mut() {
                    *w = rng.uniform() as f32 + 0.1;
                    z += *w;
                }
                weights.iter_mut().for_each(|w| *w /= z);
                GateVec { experts, weights }
            })
            .collect();
        RoutingDecision {
            per_token,
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }
    }

    #[test]
    fn plan_preserves_every_route() {
        prop::forall("routes preserved", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 2));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            let want: usize =
                decisions.iter().map(|d| d.per_token.len() * k).sum();
            assert_eq!(plan.total_routes(), want);
            // every address valid
            for eb in &plan.per_expert {
                for a in &eb.tokens {
                    assert!(a.replica < replicas);
                    assert!(a.row < decisions[a.replica].per_token.len());
                }
            }
        });
    }

    #[test]
    fn identity_experts_reconstruct_input() {
        // with identity experts and gates summing to 1, combine(gather(x))
        // must equal x exactly
        prop::forall("identity roundtrip", |rng| {
            let (d, n, k) = (4, 6, 2);
            let rows = prop::dim(rng, 1, 8);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            let outs: Vec<TensorF> = (0..n)
                .map(|e| Dispatcher::gather(&plan, e, &[&x]))
                .collect();
            let combined = Dispatcher::combine(&plan, &outs, d);
            for (a, b) in combined[0].data.iter().zip(x.data.iter()) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn gather_range_concatenates_to_full_gather() {
        prop::forall("gather ranges", |rng| {
            let (d, n, k) = (3, 5, 2);
            let rows = prop::dim(rng, 1, 12);
            let dec = decision(rows, n, k, rng);
            let x = TensorF::new(vec![rows, d], prop::vec_f32(rng, rows * d, 1.0));
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            for e in 0..n {
                let full = Dispatcher::gather(&plan, e, &[&x]);
                let len = plan.per_expert[e].tokens.len();
                let cut = if len == 0 { 0 } else { prop::dim(rng, 0, len) };
                let mut buf = Vec::new();
                let r1 = Dispatcher::gather_range_into(&plan, e, 0..cut, &[&x], &mut buf);
                let mut tail = Vec::new();
                let r2 = Dispatcher::gather_range_into(&plan, e, cut..len, &[&x], &mut tail);
                buf.extend_from_slice(&tail);
                assert_eq!(r1 + r2, len);
                assert_eq!(buf, full.data);
            }
        });
    }

    #[test]
    fn incremental_builder_matches_batch_plan() {
        // a PlanBuilder fed the same decisions in randomized row blocks
        // must produce exactly Dispatcher::plan: token order, gates and
        // replica_rows (satellite contract for the streaming pipeline)
        prop::forall("builder == plan", |rng| {
            let (n, k) = (prop::dim(rng, 2, 12), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 4);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 10), n, k, rng))
                .collect();
            let want = Dispatcher::plan(&decisions, n);

            let mut builder = PlanBuilder::new(n);
            for dec in &decisions {
                let rows = dec.per_token.len();
                let mut lo = 0;
                while lo < rows {
                    let hi = (lo + 1 + rng.below(4)).min(rows);
                    builder.push_rows(&dec.per_token[lo..hi]);
                    lo = hi;
                }
                builder.finish_replica();
                // prefix immutability mid-build: rows appended so far
                // already equal the final plan's prefix
                for e in 0..n {
                    let len = builder.expert_len(e);
                    assert_eq!(
                        builder.plan().per_expert[e].tokens[..len],
                        want.per_expert[e].tokens[..len]
                    );
                }
            }
            let got = builder.finish();
            assert_eq!(got.n_experts, want.n_experts);
            assert_eq!(got.replica_rows, want.replica_rows);
            for (g, w) in got.per_expert.iter().zip(want.per_expert.iter()) {
                assert_eq!(g.tokens, w.tokens);
                assert_eq!(g.gates, w.gates);
            }
        });
    }

    /// Like `decision` but each token may route the *same* expert more
    /// than once (duplicate top-k indices — possible for callers that
    /// feed unnormalized gate vectors), which the builder and the
    /// combine partition must both tolerate.
    fn decision_with_duplicates(
        rows: usize,
        n: usize,
        k: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> RoutingDecision {
        let per_token = (0..rows)
            .map(|_| {
                let experts: Vec<usize> =
                    (0..k).map(|_| rng.below(n)).collect();
                let weights = vec![1.0 / k as f32; k];
                GateVec { experts, weights }
            })
            .collect();
        RoutingDecision {
            per_token,
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }
    }

    #[test]
    fn replica_runs_partition_expert_batches() {
        // per-replica combine partition: the runs of any row range must
        // concatenate back to the range, be replica-major, and name each
        // replica at most once (tokens are replica-major per expert)
        prop::forall("replica runs", |rng| {
            let (n, k) = (prop::dim(rng, 2, 8), prop::dim(rng, 1, 3));
            let replicas = prop::dim(rng, 1, 5);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision(prop::dim(rng, 1, 8), n, k, rng))
                .collect();
            let plan = Dispatcher::plan(&decisions, n);
            for e in 0..n {
                let len = plan.per_expert[e].tokens.len();
                let lo = if len == 0 { 0 } else { prop::dim(rng, 0, len) };
                let hi = if lo == len { len } else { prop::dim(rng, lo, len) };
                let runs = Dispatcher::replica_runs(&plan, e, lo..hi);
                let mut cursor = lo;
                let mut last_replica = None;
                for (r, range) in &runs {
                    assert_eq!(range.start, cursor, "runs must be contiguous");
                    assert!(range.end > range.start, "empty run");
                    cursor = range.end;
                    if let Some(prev) = last_replica {
                        assert!(*r > prev, "replica-major run order");
                    }
                    last_replica = Some(*r);
                    for addr in &plan.per_expert[e].tokens[range.clone()] {
                        assert_eq!(addr.replica, *r);
                    }
                }
                assert_eq!(cursor, hi, "runs must cover the range");
            }
        });
    }

    #[test]
    fn dispatched_prefixes_stay_immutable_with_duplicate_topk() {
        // satellite contract: once a wave [0, len) has been dispatched,
        // those rows never change — even when tokens route the same
        // expert twice via duplicate top-k indices
        prop::forall("prefix immutable (duplicates)", |rng| {
            let (n, k) = (prop::dim(rng, 2, 6), prop::dim(rng, 2, 4));
            let replicas = prop::dim(rng, 1, 3);
            let decisions: Vec<_> = (0..replicas)
                .map(|_| decision_with_duplicates(prop::dim(rng, 1, 8), n, k, rng))
                .collect();
            let want = Dispatcher::plan(&decisions, n);

            let mut builder = PlanBuilder::new(n);
            // snapshots[e] = (len, tokens, gates) at simulated dispatch
            type Snapshot = (usize, Vec<TokenAddr>, Vec<f32>);
            let mut snapshots: Vec<Option<Snapshot>> = vec![None; n];
            for dec in &decisions {
                let rows = dec.per_token.len();
                let mut lo = 0;
                while lo < rows {
                    let hi = (lo + 1 + rng.below(3)).min(rows);
                    builder.push_rows(&dec.per_token[lo..hi]);
                    lo = hi;
                    // simulate dispatching a wave of a random expert:
                    // snapshot its current prefix
                    let e = rng.below(n);
                    let len = builder.expert_len(e);
                    let b = &builder.plan().per_expert[e];
                    snapshots[e] = Some((
                        len,
                        b.tokens[..len].to_vec(),
                        b.gates[..len].to_vec(),
                    ));
                    // every earlier snapshot still bit-equal to the
                    // prefix it was taken from
                    for (se, snap) in snapshots.iter().enumerate() {
                        let Some((slen, stoks, sgates)) = snap else {
                            continue;
                        };
                        let cur = &builder.plan().per_expert[se];
                        assert_eq!(&cur.tokens[..*slen], &stoks[..]);
                        assert_eq!(&cur.gates[..*slen], &sgates[..]);
                    }
                }
                builder.finish_replica();
            }
            let got = builder.finish();
            assert_eq!(got.replica_rows, want.replica_rows);
            for (g, w) in got.per_expert.iter().zip(want.per_expert.iter()) {
                assert_eq!(g.tokens, w.tokens);
                assert_eq!(g.gates, w.gates);
            }
        });
    }

    #[test]
    fn builder_prefixes_on_all_tokens_one_expert() {
        // degenerate layout: every route lands on expert 0; the prefix
        // is the whole (growing) batch and must match the batch plan at
        // every block boundary
        let n = 5;
        let rows = 13;
        let gv = GateVec { experts: vec![0, 0], weights: vec![0.5, 0.5] };
        let decisions = vec![RoutingDecision {
            per_token: vec![gv; rows],
            importance: vec![0.0; n],
            load: vec![0.0; n],
            noise: None,
        }];
        let want = Dispatcher::plan(&decisions, n);
        assert_eq!(want.per_expert[0].tokens.len(), 2 * rows);

        let mut builder = PlanBuilder::new(n);
        let mut lo = 0;
        while lo < rows {
            let hi = (lo + 4).min(rows);
            builder.push_rows(&decisions[0].per_token[lo..hi]);
            let len = builder.expert_len(0);
            assert_eq!(len, 2 * hi, "two routes per appended row");
            assert_eq!(
                builder.plan().per_expert[0].tokens[..len],
                want.per_expert[0].tokens[..len]
            );
            for e in 1..n {
                assert_eq!(builder.expert_len(e), 0);
            }
            lo = hi;
        }
        builder.finish_replica();
        let got = builder.finish();
        assert_eq!(got.per_expert[0].tokens, want.per_expert[0].tokens);
        assert_eq!(got.per_expert[0].gates, want.per_expert[0].gates);
    }

    #[test]
    fn network_bytes_accounting() {
        let mut rng = crate::util::rng::Rng::new(0);
        let dec = decision(10, 4, 2, &mut rng);
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), 4);
        // 10 tokens * k=2 routes * d=8 * 4 bytes * 2 directions
        assert_eq!(plan.network_bytes(8), 10 * 2 * 8 * 4 * 2);
    }

    #[test]
    fn plan_is_deterministic_and_ordered() {
        let mut rng = crate::util::rng::Rng::new(1);
        let decs: Vec<_> = (0..3).map(|_| decision(4, 5, 2, &mut rng)).collect();
        let p1 = Dispatcher::plan(&decs, 5);
        let p2 = Dispatcher::plan(&decs, 5);
        for (a, b) in p1.per_expert.iter().zip(p2.per_expert.iter()) {
            assert_eq!(a.tokens, b.tokens);
        }
        // replica-major order within each expert queue
        for eb in &p1.per_expert {
            for w in eb.tokens.windows(2) {
                assert!(
                    (w[0].replica, w[0].row) <= (w[1].replica, w[1].row)
                );
            }
        }
    }
}
