//! Deterministic fault injection for the MoE execution engine.
//!
//! The paper's capacity argument (§1, §3) assumes clusters where shard
//! failures, stragglers and lost all-to-all messages are routine.  MoE
//! is naturally fault-tolerant: a token's output is a gate-weighted sum
//! over k experts (eq 1), so a lost expert contribution can be absorbed
//! by renormalizing the gates over the surviving routes — the same
//! degradation GShard's capacity-factor token dropping already exploits.
//!
//! A [`FaultPlan`] is a *schedule*, not a random process: every fault
//! outcome is a pure keyed hash of `(seed, kind, step, expert,
//! chunk_lo[, replica])`, evaluated at the moment the engine would
//! dispatch (or deliver) that chunk — the same pre-drawn-determinism
//! trick [`Router::draw_noise`](crate::coordinator::Router) uses for the
//! eq-4 noise.  Same seed ⇒ bit-identical chaos run, regardless of
//! thread timing.  Three fault kinds:
//!
//! - **permanent shard death** (`shard_deaths`): every chunk owned by a
//!   dead shard fails from its death step on; from the *next* step the
//!   shard's experts are masked out of the router
//!   ([`FaultPlan::router_mask`]) so no new routes are offered to it;
//! - **straggler delay** (`straggler_rate` / `straggler_delay_ns`): the
//!   chunk completes but `straggler_delay_ns` late; if the injected
//!   delay exceeds `deadline_ns` the chunk is treated as timed out and
//!   fails (the deadline is enforced on the injected delay, which keeps
//!   the outcome deterministic — real compute time is only measured);
//! - **dropped combine message** (`combine_drop_rate`): the chunk
//!   computes but one of its per-replica all-to-all combine messages is
//!   lost in flight.
//!
//! Recovery is two-tier ([`RecoveryPolicy`]): a failed chunk's routes
//! are first re-dispatched one by one to the token's *other* selected
//! experts on live shards (reusing the PR-6 residual-dispatch idea at
//! execution time), and whatever cannot be re-homed becomes *lost gate
//! mass* — the replica's combine then renormalizes eq-1 over the
//! surviving contributions ([`renormalize_row`]).  The serial oracle
//! for all of this is [`degrade_plan`] + [`combine_degraded`], which
//! `rust/tests/faults.rs` proves bit-equal to the streamed engine under
//! the same plan.

use crate::coordinator::dispatcher::{
    DispatchPlan, Dispatcher, ExpertBatch, TokenAddr,
};
use crate::coordinator::scheduler::ShardLayout;
use crate::gating::noisy_topk::GateVec;
use crate::runtime::TensorF;

/// What to do with the routes of a failed chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RecoveryPolicy {
    /// Re-dispatch each route to the token's next selected expert on a
    /// live shard (single bounded retry), degrade whatever remains.
    Redispatch,
    /// Skip re-dispatch: every failed route immediately becomes lost
    /// mass and the combine renormalizes over survivors.
    DegradeOnly,
}

/// Deterministic injected outcome for one dispatched expert chunk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkOutcome {
    Healthy,
    /// Completes, but the worker is held for this many injected ns.
    Delayed(u64),
    /// Never delivers (shard dead, injected failure, or the injected
    /// straggler delay blew the per-chunk deadline).
    Failed,
}

/// A seeded, deterministic schedule of injected faults.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// per-chunk probability of outright failure
    pub chunk_fail_rate: f64,
    /// per-chunk probability of a straggler delay
    pub straggler_rate: f64,
    /// injected delay for straggler chunks
    pub straggler_delay_ns: u64,
    /// per-chunk compute deadline; a straggler whose injected delay
    /// exceeds it counts as failed (timed out)
    pub deadline_ns: u64,
    /// per-delivery probability the chunk's combine message is dropped
    pub combine_drop_rate: f64,
    /// `(death_step, shard)`: the shard fails every chunk from
    /// `death_step` on, and is masked out of the router afterwards
    pub shard_deaths: Vec<(u64, usize)>,
    pub policy: RecoveryPolicy,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            chunk_fail_rate: 0.0,
            straggler_rate: 0.0,
            straggler_delay_ns: 0,
            deadline_ns: u64::MAX,
            combine_drop_rate: 0.0,
            shard_deaths: Vec::new(),
            policy: RecoveryPolicy::Redispatch,
        }
    }
}

/// splitmix64 finalizer: the one-way mixer behind every fault draw.
fn mix(z: u64) -> u64 {
    let mut z = z.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// A plan that injects nothing (useful as the zero-fault control).
    pub fn none(seed: u64) -> Self {
        FaultPlan { seed, ..Default::default() }
    }

    /// Chained keyed draw in [0, 1): a pure function of the plan seed
    /// and the fault coordinates, independent of thread timing.
    fn draw(&self, kind: u64, keys: &[u64]) -> f64 {
        let mut h = mix(self.seed ^ kind.wrapping_mul(0x2545f4914f6cdd1d));
        for &k in keys {
            h = mix(h ^ k);
        }
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Does the schedule inject anything at all?
    pub fn any_faults(&self) -> bool {
        self.chunk_fail_rate > 0.0
            || self.straggler_rate > 0.0
            || self.combine_drop_rate > 0.0
            || !self.shard_deaths.is_empty()
    }

    /// Is `shard` dead during `step`?  A shard fails chunks *during*
    /// its death step (the step discovers the failure mid-flight).
    pub fn shard_dead(&self, shard: usize, step: u64) -> bool {
        self.shard_deaths.iter().any(|&(s, sh)| sh == shard && s <= step)
    }

    /// Shards live at `step` as a fraction of the layout (health signal
    /// for admission control).
    pub fn live_fraction(&self, layout: &ShardLayout, step: u64) -> f64 {
        let n = layout.n_devices.max(1);
        let live =
            (0..n).filter(|&sh| !self.shard_dead(sh, step)).count();
        live as f64 / n as f64
    }

    /// Experts to mask out of the router at `step`: those owned by a
    /// shard that died on an *earlier* step ("permanently dead shards
    /// are masked out for subsequent steps" — the death step itself
    /// still routes to them and degrades).  `None` when nothing is
    /// masked, and also when *every* expert would be masked: with no
    /// live expert the softmax over masked logits is undefined, so the
    /// all-dead case routes normally and degrades at dispatch instead
    /// (every chunk fails, every row renormalizes to zero mass).
    pub fn router_mask(
        &self,
        step: u64,
        layout: &ShardLayout,
    ) -> Option<Vec<bool>> {
        let mask: Vec<bool> = (0..layout.n_experts)
            .map(|e| {
                self.shard_deaths
                    .iter()
                    .any(|&(s, sh)| sh == layout.owner(e) && s < step)
            })
            .collect();
        if mask.iter().any(|&m| m) && !mask.iter().all(|&m| m) {
            Some(mask)
        } else {
            None
        }
    }

    /// Injected outcome for the chunk `[chunk_lo, ..)` of `expert`
    /// dispatched at `step` to `owner_shard`.
    pub fn chunk_outcome(
        &self,
        step: u64,
        owner_shard: usize,
        expert: usize,
        chunk_lo: usize,
    ) -> ChunkOutcome {
        if self.shard_dead(owner_shard, step) {
            return ChunkOutcome::Failed;
        }
        let keys = [step, expert as u64, chunk_lo as u64];
        if self.chunk_fail_rate > 0.0
            && self.draw(1, &keys) < self.chunk_fail_rate
        {
            return ChunkOutcome::Failed;
        }
        if self.straggler_rate > 0.0
            && self.draw(2, &keys) < self.straggler_rate
        {
            return if self.straggler_delay_ns > self.deadline_ns {
                ChunkOutcome::Failed
            } else {
                ChunkOutcome::Delayed(self.straggler_delay_ns)
            };
        }
        ChunkOutcome::Healthy
    }

    /// Is the combine message of chunk `(expert, chunk_lo)` to
    /// `replica` dropped in flight?
    pub fn combine_dropped(
        &self,
        step: u64,
        expert: usize,
        chunk_lo: usize,
        replica: usize,
    ) -> bool {
        self.combine_drop_rate > 0.0
            && self.draw(
                3,
                &[step, expert as u64, chunk_lo as u64, replica as u64],
            ) < self.combine_drop_rate
    }

    /// Re-dispatch target for one failed route: the first of the
    /// token's *other* selected experts that lives on a shard still
    /// alive at `step`.  `None` under [`RecoveryPolicy::DegradeOnly`]
    /// or when no live alternative exists — the route's gate mass is
    /// then lost and the combine renormalizes.
    pub fn redirect_target(
        &self,
        step: u64,
        layout: &ShardLayout,
        experts: &[usize],
        failed: usize,
    ) -> Option<usize> {
        if self.policy == RecoveryPolicy::DegradeOnly {
            return None;
        }
        experts
            .iter()
            .copied()
            .find(|&e| e != failed && !self.shard_dead(layout.owner(e), step))
    }
}

/// A live fault schedule threaded through the engine: the plan plus
/// the engine's step counter (each `execute_streaming` call is one
/// fault step).
#[derive(Clone, Debug)]
pub struct FaultSession {
    pub plan: FaultPlan,
    pub step: u64,
}

impl FaultSession {
    pub fn new(plan: FaultPlan) -> Self {
        FaultSession { plan, step: 0 }
    }
}

/// Per-step fault/recovery accounting, surfaced on
/// [`StepStats`](crate::coordinator::StepStats).
#[derive(Clone, Debug, Default)]
pub struct FaultTally {
    pub failed_chunks: usize,
    pub redispatched_routes: usize,
    pub degraded_tokens: usize,
    pub renorm_mass_lost: f64,
}

impl FaultTally {
    /// Publish into the unified registry under the shared `fault_*`
    /// keys — the same series `StepStats::publish` and
    /// `ServeStats::publish` feed, so per-step, per-run and serve-side
    /// fault accounting all aggregate into one place.
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        reg.counter_add("fault_failed_chunks", self.failed_chunks as u64);
        reg.counter_add(
            "fault_redispatched_routes",
            self.redispatched_routes as u64,
        );
        reg.counter_add("fault_degraded_tokens", self.degraded_tokens as u64);
        reg.gauge_add("fault_renorm_mass_lost", self.renorm_mass_lost);
    }
}

/// Renormalize one combined output row over its delivered gate mass:
/// the degraded eq-1.  `mass` is the sum of the gates that actually
/// contributed; zero delivered mass zeroes the row (every route lost).
pub fn renormalize_row(row: &mut [f32], mass: f32) {
    if mass > 0.0 {
        let inv = 1.0 / mass;
        for v in row.iter_mut() {
            *v *= inv;
        }
    } else {
        row.fill(0.0);
    }
}

/// The failure-masked plan [`degrade_plan`] builds: what survives of a
/// [`DispatchPlan`] under a [`FaultPlan`], plus the gate mass each
/// token lost.
#[derive(Clone, Debug)]
pub struct DegradedPlan {
    pub plan: DispatchPlan,
    /// per replica, per row: gate mass whose routes were lost
    pub lost_mass: Vec<Vec<f32>>,
    pub failed_chunks: usize,
    pub redispatched_routes: usize,
}

/// Serial oracle for fault recovery: replay the engine's chunking of
/// `plan` (streamed chunks never span replicas, and start at each
/// replica run's start with stride `cap`), apply the fault schedule to
/// every chunk and combine delivery, re-home redirectable routes, and
/// return the surviving plan plus the lost mass per token.
/// `sel[replica][row]` are the routing decisions the redirects consult.
pub fn degrade_plan(
    plan: &DispatchPlan,
    layout: &ShardLayout,
    sel: &[Vec<GateVec>],
    cap: usize,
    step: u64,
    fp: &FaultPlan,
) -> DegradedPlan {
    let cap = cap.max(1);
    let n = plan.n_experts;
    let mut kept = vec![ExpertBatch::default(); n];
    // redirects land after an expert's kept originals, sorted by
    // (src_expert, src_pos) — the engine's `retry_order` key
    let mut redirects: Vec<Vec<(usize, usize, TokenAddr, f32)>> =
        vec![Vec::new(); n];
    let mut lost_mass: Vec<Vec<f32>> =
        plan.replica_rows.iter().map(|&r| vec![0.0; r]).collect();
    let mut failed_chunks = 0usize;
    let mut redispatched = 0usize;

    for (e, batch) in plan.per_expert.iter().enumerate() {
        let owner = layout.owner(e);
        for (replica, run) in
            Dispatcher::replica_runs(plan, e, 0..batch.tokens.len())
        {
            let mut lo = run.start;
            while lo < run.end {
                let hi = (lo + cap).min(run.end);
                let mut chunk_lost = false;
                match fp.chunk_outcome(step, owner, e, lo) {
                    ChunkOutcome::Failed => {
                        failed_chunks += 1;
                        for pos in lo..hi {
                            let addr = batch.tokens[pos];
                            let gate = batch.gates[pos];
                            let experts =
                                &sel[addr.replica][addr.row].experts;
                            match fp.redirect_target(step, layout, experts, e)
                            {
                                Some(t) => {
                                    redirects[t].push((e, pos, addr, gate));
                                    redispatched += 1;
                                }
                                None => {
                                    lost_mass[addr.replica][addr.row] += gate;
                                }
                            }
                        }
                        chunk_lost = true;
                    }
                    ChunkOutcome::Healthy | ChunkOutcome::Delayed(_) => {
                        if fp.combine_dropped(step, e, lo, replica) {
                            failed_chunks += 1;
                            for pos in lo..hi {
                                let addr = batch.tokens[pos];
                                lost_mass[addr.replica][addr.row] +=
                                    batch.gates[pos];
                            }
                            chunk_lost = true;
                        }
                    }
                }
                if !chunk_lost {
                    for pos in lo..hi {
                        kept[e].tokens.push(batch.tokens[pos]);
                        kept[e].gates.push(batch.gates[pos]);
                    }
                }
                lo = hi;
            }
        }
    }
    for (e, mut rs) in redirects.into_iter().enumerate() {
        rs.sort_by_key(|&(src_e, src_pos, _, _)| (src_e, src_pos));
        for (_, _, addr, gate) in rs {
            kept[e].tokens.push(addr);
            kept[e].gates.push(gate);
        }
    }
    DegradedPlan {
        plan: DispatchPlan {
            n_experts: n,
            per_expert: kept,
            replica_rows: plan.replica_rows.clone(),
            rerouted_routes: plan.rerouted_routes,
            dropped_routes: plan.dropped_routes,
        },
        lost_mass,
        failed_chunks,
        redispatched_routes: redispatched,
    }
}

/// The degraded eq-1 combine the oracle uses: accumulate surviving
/// contributions *and* delivered gate mass expert-major (the same
/// per-destination-row float sequence the engine's sorted combine
/// segments produce), then renormalize every row that lost mass.
pub fn combine_degraded(
    dp: &DegradedPlan,
    expert_outputs: &[TensorF],
    d_model: usize,
) -> Vec<TensorF> {
    let mut out: Vec<TensorF> = dp
        .plan
        .replica_rows
        .iter()
        .map(|&rows| TensorF::zeros(vec![rows, d_model]))
        .collect();
    let mut mass: Vec<Vec<f32>> =
        dp.plan.replica_rows.iter().map(|&r| vec![0.0; r]).collect();
    for (e, batch) in dp.plan.per_expert.iter().enumerate() {
        let eo = &expert_outputs[e];
        debug_assert_eq!(eo.shape, vec![batch.tokens.len(), d_model]);
        for (slot, (addr, gate)) in
            batch.tokens.iter().zip(batch.gates.iter()).enumerate()
        {
            let src = &eo.data[slot * d_model..(slot + 1) * d_model];
            let dst = &mut out[addr.replica].data
                [addr.row * d_model..(addr.row + 1) * d_model];
            for (o, s) in dst.iter_mut().zip(src.iter()) {
                *o += gate * s;
            }
            mass[addr.replica][addr.row] += gate;
        }
    }
    for (r, lm) in dp.lost_mass.iter().enumerate() {
        let d = d_model;
        for (row, &lost) in lm.iter().enumerate() {
            if lost > 0.0 {
                renormalize_row(
                    &mut out[r].data[row * d..(row + 1) * d],
                    mass[r][row],
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_deterministic_and_keyed() {
        let fp = FaultPlan {
            seed: 42,
            chunk_fail_rate: 0.5,
            ..Default::default()
        };
        let a = fp.chunk_outcome(3, 0, 5, 128);
        let b = fp.chunk_outcome(3, 0, 5, 128);
        assert_eq!(a, b, "same key, same outcome");
        // different keys decorrelate: over many chunks roughly half
        // fail at rate 0.5 (a pure schedule, not a biased one)
        let fails = (0..1000)
            .filter(|&c| {
                fp.chunk_outcome(0, 0, 0, c) == ChunkOutcome::Failed
            })
            .count();
        assert!((300..700).contains(&fails), "{fails}/1000 at rate 0.5");
        // a different seed is a different schedule
        let fp2 = FaultPlan { seed: 43, ..fp.clone() };
        let diff = (0..200)
            .filter(|&c| fp.chunk_outcome(0, 0, 0, c)
                != fp2.chunk_outcome(0, 0, 0, c))
            .count();
        assert!(diff > 0, "seeds must change the schedule");
    }

    #[test]
    fn zero_rates_inject_nothing() {
        let fp = FaultPlan::none(7);
        assert!(!fp.any_faults());
        for c in 0..100 {
            assert_eq!(fp.chunk_outcome(0, 0, 0, c), ChunkOutcome::Healthy);
            assert!(!fp.combine_dropped(0, 0, c, 0));
        }
    }

    #[test]
    fn shard_death_semantics() {
        let layout = ShardLayout::new(2, 8);
        let fp = FaultPlan {
            shard_deaths: vec![(2, 1)],
            ..Default::default()
        };
        assert!(!fp.shard_dead(1, 1));
        assert!(fp.shard_dead(1, 2), "dead during its death step");
        assert!(fp.shard_dead(1, 5), "death is permanent");
        // masked only on steps after the death step
        assert!(fp.router_mask(2, &layout).is_none());
        let m = fp.router_mask(3, &layout).unwrap();
        for (e, &dead) in m.iter().enumerate() {
            assert_eq!(dead, layout.owner(e) == 1);
        }
        assert!((fp.live_fraction(&layout, 1) - 1.0).abs() < 1e-12);
        assert!((fp.live_fraction(&layout, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn all_dead_mask_is_none() {
        // with every expert masked the softmax would be undefined, so
        // the all-dead case routes normally and degrades at dispatch
        let layout = ShardLayout::new(2, 4);
        let fp = FaultPlan {
            shard_deaths: vec![(0, 0), (0, 1)],
            ..Default::default()
        };
        assert!(fp.router_mask(5, &layout).is_none());
        assert_eq!(fp.live_fraction(&layout, 5), 0.0);
    }

    #[test]
    fn straggler_deadline_turns_delay_into_failure() {
        let base = FaultPlan {
            straggler_rate: 1.0,
            straggler_delay_ns: 500,
            ..Default::default()
        };
        assert_eq!(base.chunk_outcome(0, 0, 0, 0), ChunkOutcome::Delayed(500));
        let tight = FaultPlan { deadline_ns: 100, ..base };
        assert_eq!(tight.chunk_outcome(0, 0, 0, 0), ChunkOutcome::Failed);
    }

    #[test]
    fn redirect_respects_policy_and_dead_shards() {
        let layout = ShardLayout::new(4, 4); // expert e on shard e
        let fp = FaultPlan {
            shard_deaths: vec![(0, 1)],
            ..Default::default()
        };
        // expert 0 failed; token also selected 1 (dead) and 2 (live)
        assert_eq!(fp.redirect_target(0, &layout, &[0, 1, 2], 0), Some(2));
        assert_eq!(fp.redirect_target(0, &layout, &[0, 1], 0), None);
        let degrade =
            FaultPlan { policy: RecoveryPolicy::DegradeOnly, ..fp };
        assert_eq!(degrade.redirect_target(0, &layout, &[0, 1, 2], 0), None);
    }

    #[test]
    fn renormalize_row_divides_or_zeroes() {
        let mut row = [1.0f32, 2.0, 4.0];
        renormalize_row(&mut row, 0.5);
        assert_eq!(row, [2.0, 4.0, 8.0]);
        renormalize_row(&mut row, 0.0);
        assert_eq!(row, [0.0, 0.0, 0.0]);
    }
}
