//! Persistent parallel execution engine for the MoE step.
//!
//! The seed scheduler spawned fresh OS threads and reallocated every
//! gather/compute/combine buffer on *every step*, so step latency
//! measured harness overhead instead of the paper's §3.1–3.2 economics.
//! This engine keeps one long-lived worker thread per simulated device
//! shard, fed over channels, with reusable arenas:
//!
//! - **gather arenas** — token rows are staged into pooled buffers
//!   ([`Dispatcher::gather_range_into`]), recycled step after step;
//! - **compute arenas** — each worker owns a persistent hidden-layer
//!   scratch buffer, and expert outputs land in pooled buffers;
//! - **combine arenas** — per-replica outputs adopt pooled allocations
//!   via [`TensorF::from_buffer`].
//!
//! Over-capacity batches run in *waves*; the engine stages wave `w+1`
//! while wave `w` computes (Native: on the coordinator thread against
//! the worker pool; Artifact: a persistent worker prefetches the next
//! padded chunk while the PJRT call for the current one runs).
//!
//! # Dependency-driven combine (async all-to-all)
//!
//! The step does **not** end in a global combine barrier.  Each replica
//! carries an explicit completion record ([`ReplicaTracker`]): how many
//! dispatched expert chunks still owe it rows.  When a chunk drains,
//! its output is split along [`Dispatcher::replica_runs`] into
//! per-replica [`CombineSegment`] messages — the "recv" side of the
//! async all-to-all, with destination rows and gates copied out of the
//! plan's immutable prefix so the message borrows nothing — and the
//! moment a replica's last owed chunk arrives, its gate-weighted
//! combine (eq 1) is emitted as a [`Job::Combine`] onto the worker
//! pool.  Replica 0's combine therefore runs while later replicas are
//! still routing and computing; only the post-compute combine *tail*
//! lands on the critical path ([`PhaseNanos::combine`]), and the hidden
//! worker-side combine time is reported as [`PhaseNanos::overlap_ns`].
//! Segment lists are sorted expert-major before emission, so every
//! token accumulates its k contributions in exactly the serial
//! reference order (bit-stable regardless of chunk completion timing).
//!
//! # Streaming pipeline
//!
//! [`ExecutionEngine::execute_streaming`] goes further: instead of
//! receiving a finished [`DispatchPlan`], it runs the *whole* step —
//! gating, dispatch and expert execution — as a pipeline over the same
//! worker pool.  Row blocks of each replica are gated in parallel on the
//! workers ([`Router::route_rows`], fed pre-drawn eq-4 noise so results
//! are bit-identical to serial routing); as routed blocks stream back in
//! row order they are appended to an incremental
//! [`PlanBuilder`], whose per-expert batches have an immutable prefix —
//! so each expert's wave is gathered and dispatched to its shard the
//! moment enough of its rows are final.  Replica r+1 therefore routes
//! while replica r's experts compute, and the first expert wave starts
//! before the last token is gated: step latency approaches
//! max(route, execute) instead of route + dispatch + execute.
//!
//! The Native wave size is governed by a
//! [`WavePolicy`] — either a fixed capacity or
//! [`AdaptiveWave`](crate::coordinator::scheduler::AdaptiveWave), which
//! derives the next step's capacity from the previous step's measured
//! busiest-shard idle.
//!
//! # Safety
//!
//! Jobs smuggle borrows of the caller's `plan`, `xs`, `weights`,
//! `router` and pre-drawn noise to the persistent workers as raw
//! pointers (a persistent thread cannot hold a non-`'static`
//! reference).  The invariants that make this sound:
//!
//! 1. workers dereference job pointers only between receiving the job
//!    and sending its reply (worker bodies are wrapped in
//!    `catch_unwind`, so a reply is *always* sent, even on panic);
//! 2. `execute_*` never returns — including on the error path, via
//!    [`DrainGuard`] — until every job it sent has been replied to;
//! 3. route jobs only ever run `Router::route_rows`, which is pure
//!    Native math over the router's weight slices — the (non-`Send`)
//!    artifact handle is never touched off-thread, and
//!    `execute_streaming` rejects artifact-backed flat routers up front.
//!
//! Together these guarantee no worker touches the borrowed step inputs
//! after `execute_*` returns.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::dispatcher::{
    DispatchPlan, Dispatcher, PlanBuilder, ResidualPolicy,
};
use crate::coordinator::faults::{
    renormalize_row, ChunkOutcome, FaultPlan, FaultSession, FaultTally,
};
use crate::coordinator::router::{
    RouteBlock, RouteNoise, Router, RouterBackend, RoutingDecision,
};
use crate::coordinator::scheduler::{
    build_stats, waves_for_loads, ExpertWeights, PhaseNanos, ShardLayout,
    StepStats, WavePolicy,
};
use crate::gating::noisy_topk::GateVec;
use crate::kernels::quant::QuantizedExpertWeights;
use crate::obs::{ObsConfig, Span, SpanKind, TraceShared, NO_ID};
use crate::runtime::{Executable, Host, TensorF};
use crate::util::rng::Rng;

/// Streaming wave size used when the policy says "unchunked"
/// (`WavePolicy::Fixed(None)`): the streaming path must chunk to
/// overlap dispatch with routing at all, so it falls back to this.
/// Chunking is bit-exact (expert rows are independent), so the value
/// only affects pipelining granularity, never results.
pub(crate) const STREAM_DEFAULT_CAP: usize = 128;

/// Provenance of a single re-dispatched route: when a streamed chunk is
/// failed by the active [`FaultPlan`], each of its routes is retried as
/// a one-row task on the token's next surviving selected expert.  The
/// `retry_order` key reproduces the serial oracle's accumulation order
/// (redirects sort after originals, by source `(expert, position)`).
struct RetryTask {
    replica: usize,
    /// replica-local destination row
    row: usize,
    gate: f32,
    /// `((src_expert + 1) << 32) | src_pos` — strictly positive, so
    /// original segments (order 0) always sort first
    retry_order: u64,
}

/// One expert-chunk of work bound for a shard worker.
struct ExpertTask {
    expert: usize,
    rows: usize,
    /// row offset of this chunk inside the expert's full output
    out_offset: usize,
    /// gathered (rows, d) input, from the buffer pool
    input: Vec<f32>,
    /// output buffer, from the buffer pool; worker fills (rows, d)
    output: Vec<f32>,
    /// `Some` when this task is a fault-recovery re-dispatch of a
    /// single route rather than a planned chunk
    retry: Option<RetryTask>,
}

/// Expert weights for one step, either width: the f32 training weights
/// or the int8 serve-time quantization
/// ([`crate::kernels::quant::QuantizedExpertWeights`]).  Both variants
/// run through identical engine machinery — same jobs, same combine,
/// same fault recovery — the only difference is which `forward_into`
/// the shard worker calls.
#[derive(Clone, Copy)]
pub enum StepWeights<'a> {
    F32(&'a [ExpertWeights]),
    Int8(&'a [QuantizedExpertWeights]),
}

impl StepWeights<'_> {
    /// Erase the lifetime for smuggling through a [`ComputeJob`] — see
    /// module safety notes (only dereferenced while the coordinating
    /// `execute_*` call is blocked on the job's reply).
    fn raw(self) -> WeightsPtr {
        match self {
            StepWeights::F32(w) => WeightsPtr::F32(w),
            StepWeights::Int8(w) => WeightsPtr::Int8(w),
        }
    }
}

/// Raw-pointer twin of [`StepWeights`] carried by in-flight jobs.
#[derive(Clone, Copy)]
enum WeightsPtr {
    F32(*const [ExpertWeights]),
    Int8(*const [QuantizedExpertWeights]),
}

struct ComputeJob {
    device: usize,
    /// borrowed [`StepWeights`] — see module safety notes
    weights: WeightsPtr,
    tasks: Vec<ExpertTask>,
    /// injected straggler delay (fault plan); the worker sleeps this
    /// long inside its timed compute window
    delay_ns: u64,
    reply: Sender<ComputeReply>,
}

// SAFETY: the raw pointer is only dereferenced while the coordinating
// `execute_*` call is blocked waiting for this job's reply.
unsafe impl Send for ComputeJob {}

struct ComputeReply {
    device: usize,
    ok: bool,
    tasks: Vec<ExpertTask>,
    compute_ns: u64,
}

struct GatherJob {
    /// borrowed `&DispatchPlan` — see module safety notes
    plan: *const DispatchPlan,
    /// borrowed replica activations
    xs: Vec<*const TensorF>,
    expert: usize,
    lo: usize,
    hi: usize,
    buf: Vec<f32>,
    reply: Sender<GatherReply>,
}

// SAFETY: as for ComputeJob.
unsafe impl Send for GatherJob {}

struct GatherReply {
    ok: bool,
    buf: Vec<f32>,
}

/// One row block of a replica batch bound for the gate stage.
struct RouteJob {
    /// borrowed `&Router` — see module safety notes; workers only call
    /// the pure-math `route_rows`, never a (non-`Send`) artifact handle
    router: *const Router,
    /// borrowed replica activations (rows, d)
    x: *const TensorF,
    /// borrowed pre-drawn eq-4 noise; `None` = deterministic eval
    noise: Option<*const RouteNoise>,
    /// borrowed dead-expert mask (fault plan); masked experts gate to
    /// −inf before top-k, so dead shards receive no routes
    mask: Option<*const Vec<bool>>,
    /// block index, for in-order reassembly on the coordinator
    block: usize,
    lo: usize,
    hi: usize,
    reply: Sender<RouteReply>,
}

// SAFETY: as for ComputeJob.
unsafe impl Send for RouteJob {}

struct RouteReply {
    block: usize,
    /// the routed block, or the underlying error message (worker panic
    /// or `route_rows` error) so the coordinator can surface the cause
    result: std::result::Result<RouteBlock, String>,
}

/// One combine "message" of the async all-to-all: the computed rows of
/// one expert chunk that belong to a single replica, together with
/// their destination rows and gate weights (copied from the plan's
/// immutable prefix when the chunk drained, so the message borrows
/// nothing from the step).
struct CombineSegment {
    expert: usize,
    /// first expert-batch row held in `data` (the chunk's base offset)
    chunk_lo: usize,
    /// first expert-batch row covered by this segment (≥ `chunk_lo`)
    lo: usize,
    /// 0 for planned chunks; [`RetryTask::retry_order`] for recovery
    /// re-dispatches, so the combine sort reproduces the oracle's
    /// originals-then-redirects accumulation order per expert
    retry_order: u64,
    /// destination token rows within the replica, one per segment row
    rows: Vec<usize>,
    /// gate weights aligned with `rows`
    gates: Vec<f32>,
    /// the chunk's computed (rows, d) output, shared with the other
    /// replicas the chunk straddles
    data: Arc<Vec<f32>>,
}

/// Gate-weighted combine of one replica, dispatched to a worker the
/// moment the replica's last owed expert chunk drained.
struct CombineJob {
    replica: usize,
    /// replica row count (output is (rows, d), zeroed by the worker)
    rows: usize,
    d: usize,
    /// sorted expert-major so per-token accumulation order matches the
    /// serial reference exactly
    segments: Vec<CombineSegment>,
    /// gate mass lost to unrecovered faults, per replica row (`None` =
    /// healthy replica).  Rows with lost mass > 0 are renormalized over
    /// the gate mass actually delivered (degraded eq-1 combine).
    lost: Option<Vec<f32>>,
    /// pooled output buffer
    out: Vec<f32>,
    reply: Sender<CombineReply>,
}

struct CombineReply {
    replica: usize,
    ok: bool,
    combine_ns: u64,
    /// worker-side completion stamp, comparable with the coordinator's
    /// record of when the last expert wave drained
    finished_at: Instant,
    out: Vec<f32>,
    /// returned so chunk buffers can be recycled once unshared
    segments: Vec<CombineSegment>,
}

/// Completion record for one replica: the executor's dependency unit.
struct ReplicaTracker {
    /// dispatched expert chunks that still owe this replica rows
    outstanding: usize,
    /// routing finished *and* every routed row dispatched, so
    /// `outstanding` can only decrease from here
    sealed: bool,
    /// replica row count (combine output shape)
    rows: usize,
    /// combine messages received so far (the all-to-all recv queue)
    inbox: Vec<CombineSegment>,
    /// gate mass lost to unrecovered faults per replica row (lazily
    /// sized; empty while the replica is healthy)
    lost: Vec<f32>,
    /// combine job emitted (terminal state)
    emitted: bool,
}

impl ReplicaTracker {
    fn new(rows: usize, sealed: bool) -> Self {
        ReplicaTracker {
            outstanding: 0,
            sealed,
            rows,
            inbox: Vec::new(),
            lost: Vec::new(),
            emitted: false,
        }
    }

    /// Charge `gate` of lost mass to replica-local `row`.
    fn lose(&mut self, row: usize, gate: f32) {
        if self.lost.is_empty() {
            self.lost.resize(self.rows, 0.0);
        }
        self.lost[row] += gate;
    }

    fn ready(&self) -> bool {
        self.sealed && self.outstanding == 0 && !self.emitted
    }
}

/// Record the replicas chunk `[lo, hi)` of `expert` owes rows to, so
/// their combine jobs wait for it.  Must run before the chunk's reply
/// can be processed (i.e. before or at dispatch).
fn register_chunk(
    plan: &DispatchPlan,
    trackers: &mut [ReplicaTracker],
    expert: usize,
    lo: usize,
    hi: usize,
) {
    for (replica, _) in Dispatcher::replica_runs(plan, expert, lo..hi) {
        trackers[replica].outstanding += 1;
    }
}

enum Job {
    Compute(ComputeJob),
    Gather(GatherJob),
    Route(RouteJob),
    Combine(CombineJob),
}

/// Recycled f32 allocations shared by gather inputs, expert outputs and
/// combine outputs.
#[derive(Default)]
struct BufferPool {
    bufs: Vec<Vec<f32>>,
}

impl BufferPool {
    fn take(&mut self) -> Vec<f32> {
        self.bufs.pop().unwrap_or_default()
    }

    fn put(&mut self, mut buf: Vec<f32>) {
        buf.clear();
        if self.bufs.len() < 256 {
            self.bufs.push(buf);
        }
    }
}

/// Ensures every job sent in a step is replied to before the step call
/// can return, so borrowed pointers cannot outlive their referents.
struct DrainGuard<'a, T> {
    rx: &'a Receiver<T>,
    outstanding: usize,
}

impl<'a, T> DrainGuard<'a, T> {
    fn new(rx: &'a Receiver<T>) -> Self {
        DrainGuard { rx, outstanding: 0 }
    }

    fn sent(&mut self) {
        self.outstanding += 1;
    }

    /// Record `n` jobs sent (fault recovery fans one failed chunk out
    /// into several one-row re-dispatches).
    fn sent_n(&mut self, n: usize) {
        self.outstanding += n;
    }

    fn recv(&mut self) -> Result<T> {
        let v = self
            .rx
            .recv()
            .map_err(|_| anyhow!("execution engine worker channel closed"))?;
        self.outstanding -= 1;
        Ok(v)
    }

    /// Non-blocking receive, so the coordinator can recycle finished
    /// waves opportunistically while another pipeline stage runs.
    fn try_recv(&mut self) -> Option<T> {
        match self.rx.try_recv() {
            Ok(v) => {
                self.outstanding -= 1;
                Some(v)
            }
            Err(_) => None,
        }
    }
}

impl<T> Drop for DrainGuard<'_, T> {
    fn drop(&mut self) {
        while self.outstanding > 0 {
            if self.rx.recv().is_err() {
                break;
            }
            self.outstanding -= 1;
        }
    }
}

/// A fully streamed MoE step: per-replica outputs plus the routing
/// decisions the pipeline produced along the way (their importance/load
/// feed the balance losses), the finished dispatch plan (the trainer's
/// backward pass re-walks it), and the step telemetry.
pub struct StreamedStep {
    pub outs: Vec<TensorF>,
    pub decisions: Vec<RoutingDecision>,
    pub plan: DispatchPlan,
    pub stats: StepStats,
}

/// Long-lived worker pool executing MoE steps without per-step thread
/// spawns or per-step allocation.
pub struct ExecutionEngine {
    pub layout: ShardLayout,
    /// Native wave-capacity policy (the Artifact path always waves at
    /// the artifact capacity); adaptive policies are updated from every
    /// finished step's stats
    policy: WavePolicy,
    /// GShard-style per-expert capacity buffer applied by the streaming
    /// dispatch (`None` = exact: every route kept); see
    /// [`PlanBuilder::with_capacity`]
    dispatch_capacity: Option<usize>,
    /// how over-capacity residual routes pick among a token's other
    /// selected experts (see [`PlanBuilder::with_residual_policy`])
    residual: ResidualPolicy,
    /// active fault-injection session (`None` = no faults); advances
    /// one plan step per streamed step so same-seed runs are identical
    fault: Option<FaultSession>,
    /// fault/recovery counters of the most recent streamed step
    tally: FaultTally,
    txs: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    pool: BufferPool,
    /// tracing state shared with the workers (`None` = tracing off:
    /// one branch per job, nothing recorded — see [`crate::obs`])
    obs: Option<Arc<TraceShared>>,
    /// spans drained from completed steps, awaiting [`take_spans`]
    /// (bounded: the oldest spans are discarded past `SPAN_KEEP`)
    spans: Vec<Span>,
}

/// Retained-span bound: a serve loop tracing thousands of steps without
/// a `take_spans` drain must not grow without limit.
const SPAN_KEEP: usize = 1 << 18;

impl ExecutionEngine {
    /// Spawn one persistent worker per simulated device shard.
    pub fn start(layout: ShardLayout) -> Self {
        Self::with_policy(layout, WavePolicy::Fixed(None))
    }

    /// Like [`start`](Self::start), but Native expert batches are also
    /// processed in waves of at most `capacity` tokens (exercises the
    /// wave pipeline without an artifact; chunking is bit-exact because
    /// expert rows are independent).
    pub fn with_wave_capacity(layout: ShardLayout, capacity: Option<usize>) -> Self {
        Self::with_policy(layout, WavePolicy::Fixed(capacity))
    }

    /// Like [`start`](Self::start) with an explicit wave-capacity
    /// policy (fixed or adaptive).  Tracing follows the ambient
    /// environment (`MOE_TRACE` — [`ObsConfig::from_env`]).
    pub fn with_policy(layout: ShardLayout, policy: WavePolicy) -> Self {
        Self::with_policy_obs(layout, policy, ObsConfig::from_env())
    }

    /// Full constructor: wave policy plus explicit observability
    /// switches.  When tracing is on, every worker is spawned holding
    /// the shared trace state and records spans into its own ring; when
    /// off, workers hold `None` and tracing costs one branch per job.
    pub fn with_policy_obs(
        layout: ShardLayout,
        policy: WavePolicy,
        obs_cfg: ObsConfig,
    ) -> Self {
        let obs = obs_cfg
            .tracing
            .then(|| TraceShared::new(layout.n_devices, obs_cfg.ring_capacity));
        let mut txs = Vec::with_capacity(layout.n_devices);
        let mut handles = Vec::with_capacity(layout.n_devices);
        for dev in 0..layout.n_devices {
            let (tx, rx) = channel::<Job>();
            let tr = obs.clone();
            let handle = std::thread::Builder::new()
                .name(format!("moe-shard-{dev}"))
                .spawn(move || worker_loop(rx, dev, tr))
                .expect("spawning shard worker");
            txs.push(tx);
            handles.push(handle);
        }
        ExecutionEngine {
            layout,
            policy,
            dispatch_capacity: None,
            residual: ResidualPolicy::default(),
            fault: None,
            tally: FaultTally::default(),
            txs,
            handles,
            pool: BufferPool::default(),
            obs,
            spans: Vec::new(),
        }
    }

    /// Bound every expert's streamed batch at `capacity` rows: the
    /// streaming dispatch builds its plan with
    /// [`PlanBuilder::with_capacity`], so overflow routes fall through
    /// to the token's other selected experts and are dropped only when
    /// all are full.  Routing decisions (and thus balance losses) are
    /// unaffected — capacity shapes the dispatch, not the gating.
    pub fn with_dispatch_capacity(mut self, capacity: Option<usize>) -> Self {
        self.dispatch_capacity = capacity;
        self
    }

    /// Choose how streamed over-capacity residual routes pick among a
    /// token's other selected experts (gate order by default; seeded
    /// random spreads the spill — see
    /// [`PlanBuilder::with_residual_policy`]).
    pub fn with_residual_policy(mut self, residual: ResidualPolicy) -> Self {
        self.residual = residual;
        self
    }

    /// Attach a deterministic fault-injection plan.  Each streamed step
    /// advances the session's step counter; faults are drawn by pure
    /// keyed hashing of `(seed, step, shard, expert, chunk)`, so
    /// same-seed chaos runs are bit-identical regardless of thread
    /// timing (same pre-draw discipline as the eq-4 noise).
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.fault = plan.map(FaultSession::new);
        self
    }

    /// Fraction of shards still live at the session's current step
    /// (1.0 without a fault plan) — the serve loop's health signal.
    pub fn live_fraction(&self) -> f64 {
        self.fault
            .as_ref()
            .map(|s| s.plan.live_fraction(&self.layout, s.step))
            .unwrap_or(1.0)
    }

    /// Fault/recovery counters of the most recent streamed step.
    pub fn fault_tally(&self) -> &FaultTally {
        &self.tally
    }

    /// Whether this engine records trace spans.
    pub fn tracing_enabled(&self) -> bool {
        self.obs.is_some()
    }

    /// Spans drained from completed steps, in drain order (empty when
    /// tracing is off).  Ownership transfers to the caller; the engine
    /// starts accumulating afresh.
    pub fn take_spans(&mut self) -> Vec<Span> {
        std::mem::take(&mut self.spans)
    }

    /// Spans lost to full rings since engine start (0 when tracing is
    /// off) — nonzero means `ObsConfig::ring_capacity` is too small for
    /// the step size.
    pub fn trace_dropped(&self) -> u64 {
        self.obs.as_ref().map(|t| t.dropped()).unwrap_or(0)
    }

    /// Stamp the start of a traced step: bump the shared step counter
    /// and remember `(step, start_ns)` for the closing Step span.
    /// `None` when tracing is off.
    fn begin_step_trace(&self) -> Option<(u64, u64)> {
        self.obs.as_ref().map(|tr| (tr.begin_step(), tr.now_ns()))
    }

    /// Close a traced step: record the coordinator's whole-step span,
    /// then drain every worker ring into the engine-held span buffer.
    /// Called at step end, after the reply drain — worker quiescence is
    /// what makes consuming the SPSC rings from this thread sound.
    fn finish_step_trace(&mut self, begun: Option<(u64, u64)>) {
        let Some((step, start_ns)) = begun else { return };
        let Some(tr) = self.obs.clone() else { return };
        tr.coord_ring().push(Span {
            kind: SpanKind::Step,
            step,
            shard: NO_ID,
            expert: NO_ID,
            chunk: NO_ID,
            replica: NO_ID,
            rows: 0,
            start_ns,
            dur_ns: tr.now_ns().saturating_sub(start_ns),
        });
        tr.drain_into(&mut self.spans);
        if self.spans.len() > SPAN_KEEP {
            let cut = self.spans.len() - SPAN_KEEP;
            self.spans.drain(..cut);
        }
    }

    /// Record a coordinator-side instant event (Dispatch / Retry) on
    /// the coordinator lane.  No-op when tracing is off.
    fn trace_coord_event(
        &self,
        kind: SpanKind,
        expert: u32,
        chunk: u32,
        replica: u32,
        rows: u32,
    ) {
        if let Some(tr) = &self.obs {
            let now = tr.now_ns();
            tr.coord_ring().push(Span {
                kind,
                step: tr.step_id(),
                shard: NO_ID,
                expert,
                chunk,
                replica,
                rows,
                start_ns: now,
                dur_ns: 0,
            });
        }
    }

    /// The wave capacity the next Native step will use.
    pub fn wave_capacity(&self) -> Option<usize> {
        self.policy.capacity()
    }

    /// Execute a step with the pure-rust expert forward on the
    /// persistent shard workers.  Combine is dependency-driven (module
    /// docs): every replica's gate-weighted combine is emitted as a
    /// worker-pool job the moment its last expert wave drains, so
    /// multi-wave steps combine early replicas while later waves still
    /// compute.
    pub fn execute_native(
        &mut self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.execute_native_w(plan, xs, StepWeights::F32(weights))
    }

    /// [`execute_native`](Self::execute_native) generalized over the
    /// weight width ([`StepWeights`]); the f32 and int8 paths share
    /// every line of executor machinery.
    pub fn execute_native_w(
        &mut self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: StepWeights<'_>,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let d = xs
            .first()
            .map(|t| t.shape[1])
            .ok_or_else(|| anyhow!("no replica inputs"))?;
        if plan.n_experts != self.layout.n_experts {
            bail!(
                "plan has {} experts but engine layout has {}",
                plan.n_experts,
                self.layout.n_experts
            );
        }
        let loads = plan.expert_loads();
        let cap_opt = self.policy.capacity();
        let cap = cap_opt.unwrap_or(usize::MAX).max(1);
        let n_waves = waves_for_loads(&loads, cap_opt);
        let trace = self.begin_step_trace();
        let mut phases = PhaseNanos::default();
        let mut shard_compute = vec![0u64; self.layout.n_devices];

        // completion records: the plan is complete up front here, so
        // every replica starts sealed with its full owed-chunk count
        let mut trackers: Vec<ReplicaTracker> = plan
            .replica_rows
            .iter()
            .map(|&rows| ReplicaTracker::new(rows, true))
            .collect();
        for (e, &load) in loads.iter().enumerate() {
            let mut lo = 0;
            while lo < load {
                let hi = lo.saturating_add(cap).min(load);
                register_chunk(plan, &mut trackers, e, lo, hi);
                lo = hi;
            }
        }

        let (reply_tx, reply_rx) = channel::<ComputeReply>();
        let (k_tx, k_rx) = channel::<CombineReply>();
        let mut guard = DrainGuard::new(&reply_rx);
        let mut k_guard = DrainGuard::new(&k_rx);
        let mut panicked = false;
        let mut combine_panic = false;
        let mut outs_raw: Vec<Option<Vec<f32>>> =
            (0..trackers.len()).map(|_| None).collect();
        let mut combine_work_ns = 0u64;
        let mut combine_stamps: Vec<Instant> = Vec::new();

        // replicas owed no chunks (no routed tokens) combine immediately
        let ready_now: Vec<usize> = trackers
            .iter()
            .enumerate()
            .filter(|(_, t)| t.ready())
            .map(|(r, _)| r)
            .collect();
        for r in ready_now {
            self.emit_combine(&mut trackers, r, d, &k_tx)?;
            k_guard.sent();
        }

        // stage wave 0, then overlap: stage wave w+1 while wave w computes
        let (mut next_tasks, g_ns) = self.stage_wave(plan, xs, 0, cap, d);
        phases.gather += g_ns;
        let t_compute = Instant::now();
        let mut last_compute_done = t_compute;
        for w in 0..n_waves {
            let wave_tasks = std::mem::take(&mut next_tasks);
            let mut sent = 0usize;
            for (dev, tasks) in wave_tasks.into_iter().enumerate() {
                if tasks.is_empty() {
                    continue;
                }
                let job = ComputeJob {
                    device: dev,
                    weights: weights.raw(),
                    tasks,
                    delay_ns: 0,
                    reply: reply_tx.clone(),
                };
                // workers only exit when the engine is dropped, so this
                // cannot fail while `self` is alive
                self.txs[dev]
                    .send(Job::Compute(job))
                    .map_err(|_| anyhow!("shard worker {dev} unavailable"))?;
                guard.sent();
                sent += 1;
            }
            if w + 1 < n_waves {
                // overlapped with wave w's compute — its time is part of
                // the compute wall, not the gather phase (see PhaseNanos)
                let (tasks, _overlapped_ns) =
                    self.stage_wave(plan, xs, w + 1, cap, d);
                next_tasks = tasks;
            }
            for _ in 0..sent {
                let r = guard.recv()?;
                last_compute_done = Instant::now();
                self.absorb_compute_reply(
                    r,
                    plan,
                    &mut trackers,
                    &mut shard_compute,
                    d,
                    &k_tx,
                    &mut k_guard,
                    &mut panicked,
                    None,
                )?;
                // recycle finished combines while later waves compute
                while let Some(kr) = k_guard.try_recv() {
                    self.absorb_combine_reply(
                        kr,
                        &mut outs_raw,
                        &mut combine_work_ns,
                        &mut combine_stamps,
                        &mut combine_panic,
                    );
                }
            }
        }
        let compute_wall = t_compute.elapsed().as_nanos() as u64;
        phases.compute = compute_wall;
        if panicked {
            bail!("expert shard panicked during step");
        }

        // the only combine left on the critical path is the tail that
        // outlived the last expert wave
        let t_tail = Instant::now();
        while k_guard.outstanding > 0 {
            let kr = k_guard.recv()?;
            self.absorb_combine_reply(
                kr,
                &mut outs_raw,
                &mut combine_work_ns,
                &mut combine_stamps,
                &mut combine_panic,
            );
        }
        phases.combine = t_tail.elapsed().as_nanos() as u64;
        phases.overlap_ns = combine_work_ns.saturating_sub(phases.combine);
        if combine_panic {
            bail!("combine worker panicked during step");
        }
        let outs = collect_outs(outs_raw, &plan.replica_rows, d)?;
        let mut stats = build_stats(
            &self.layout,
            plan,
            d,
            n_waves,
            phases,
            shard_compute,
            compute_wall,
        );
        stats.combines_overlapped = combine_stamps
            .iter()
            .filter(|t| **t <= last_compute_done)
            .count();
        self.policy.observe(&stats);
        self.finish_step_trace(trace);
        Ok((outs, stats))
    }

    /// Execute a step through the AOT expert artifact.  The PJRT
    /// executable is not `Send`, so chunks run on this thread; a
    /// persistent worker gathers chunk `i+1` while chunk `i`'s PJRT call
    /// is in flight (the §3.1 wave pipeline).  Chunks are visited in
    /// expert order — `ShardLayout::owner` is monotone, so this is also
    /// device order and combine accumulation matches the serial path.
    pub fn execute_artifact(
        &mut self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
        exe: &Executable,
        capacity: usize,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let d = xs
            .first()
            .map(|t| t.shape[1])
            .ok_or_else(|| anyhow!("no replica inputs"))?;
        if plan.n_experts != self.layout.n_experts {
            bail!(
                "plan has {} experts but engine layout has {}",
                plan.n_experts,
                self.layout.n_experts
            );
        }
        let cap = capacity.max(1);
        let loads = plan.expert_loads();
        let n_waves = waves_for_loads(&loads, Some(cap));
        let trace = self.begin_step_trace();
        let mut phases = PhaseNanos::default();
        let mut shard_compute = vec![0u64; self.layout.n_devices];

        let mut chunks = Vec::new();
        for (e, &load) in loads.iter().enumerate() {
            let mut lo = 0;
            while lo < load {
                let hi = (lo + cap).min(load);
                chunks.push((e, lo, hi));
                lo = hi;
            }
        }

        let mut expert_out: Vec<Vec<f32>> = Vec::with_capacity(loads.len());
        for &l in &loads {
            let mut buf = self.pool.take();
            buf.resize(l * d, 0.0);
            expert_out.push(buf);
        }

        let (reply_tx, reply_rx) = channel::<GatherReply>();
        let mut guard = DrainGuard::new(&reply_rx);
        let gather_tx = &self.txs[0];

        let mut err: Option<anyhow::Error> = None;
        if let Some(first) = chunks.first() {
            let buf = self.pool.take();
            match send_gather(gather_tx, &reply_tx, plan, xs, *first, buf) {
                Ok(()) => guard.sent(),
                Err(e) => err = Some(e),
            }
        }
        let mut cur_expert = usize::MAX;
        // reusable 3-slot input array: [w_in, w_out, chunk]; the weight
        // hosts are built once per expert (not per chunk) and the chunk
        // slot is swapped in and out so its arena returns to the pool
        let empty_host = || Host::F32(TensorF::zeros(vec![0]));
        let mut inputs: Vec<Host> = Vec::with_capacity(3);
        let mut i = 0usize;
        while err.is_none() && i < chunks.len() {
            let (e, lo, hi) = chunks[i];
            // time blocked on the prefetch worker = the staging cost the
            // pipeline failed to hide; fully-overlapped gathers cost ~0
            let t_wait = Instant::now();
            let g = match guard.recv() {
                Ok(g) => g,
                Err(e) => {
                    err = Some(e);
                    break;
                }
            };
            phases.gather += t_wait.elapsed().as_nanos() as u64;
            if !g.ok {
                self.pool.put(g.buf);
                err = Some(anyhow!("gather worker panicked"));
                break;
            }
            // prefetch the next chunk while this one computes
            if let Some(next) = chunks.get(i + 1) {
                let buf = self.pool.take();
                match send_gather(gather_tx, &reply_tx, plan, xs, *next, buf) {
                    Ok(()) => guard.sent(),
                    Err(e) => {
                        err = Some(e);
                        break;
                    }
                }
            }
            let w = &weights[e];
            if e != cur_expert {
                cur_expert = e;
                inputs.clear();
                inputs.push(Host::F32(TensorF::new(
                    vec![w.d_model, w.hidden],
                    w.w_in.clone(),
                )));
                inputs.push(Host::F32(TensorF::new(
                    vec![w.hidden, w.d_model],
                    w.w_out.clone(),
                )));
                inputs.push(empty_host());
            }
            let rows = hi - lo;
            let t1 = Instant::now();
            let mut chunk = self.pool.take();
            chunk.resize(cap * d, 0.0);
            chunk[..rows * d].copy_from_slice(&g.buf[..rows * d]);
            self.pool.put(g.buf);
            inputs[2] = Host::F32(TensorF::new(vec![cap, d], chunk));
            match exe.run(&inputs).and_then(|ys| {
                ys.into_iter()
                    .next()
                    .ok_or_else(|| anyhow!("expert artifact returned no output"))?
                    .into_f32()
            }) {
                Ok(y) => {
                    expert_out[e][lo * d..hi * d]
                        .copy_from_slice(&y.data[..rows * d]);
                    self.pool.put(y.into_buffer());
                    shard_compute[self.layout.owner(e)] +=
                        t1.elapsed().as_nanos() as u64;
                }
                Err(e) => err = Some(e),
            }
            // recover the chunk arena for the next wave
            if let Host::F32(t) = std::mem::replace(&mut inputs[2], empty_host()) {
                self.pool.put(t.into_buffer());
            }
            i += 1;
        }
        drop(guard); // drain any in-flight gather before touching errors
        if let Some(e) = err {
            return Err(e);
        }
        // chunks execute serialized on this thread, so the expert-compute
        // critical path is the sum of per-shard busy time, and a shard's
        // idle is the time it spends waiting on the other shards' chunks
        // (the §3.1 synchronous wait) — gather/combine excluded
        let compute_serialized: u64 = shard_compute.iter().sum();
        phases.compute = compute_serialized;

        let (outs, combine_ns) = self.combine(plan, expert_out, &loads, d);
        phases.combine = combine_ns;
        let stats = build_stats(
            &self.layout,
            plan,
            d,
            n_waves,
            phases,
            shard_compute,
            compute_serialized,
        );
        self.finish_step_trace(trace);
        Ok((outs, stats))
    }

    /// Execute one *full* MoE step — gating, dispatch and expert
    /// execution — as a streaming pipeline over the persistent worker
    /// pool (module docs, "Streaming pipeline").  Requires a
    /// Native-math router (flat Native backend or hierarchical); the
    /// expert forward is always the Native one.
    ///
    /// Differential contract (proven in `rust/tests/engine_parity.rs`):
    /// identical to routing every replica serially with the same rng,
    /// building `Dispatcher::plan`, and running `execute_serial` — gate
    /// vectors bit-identical, outputs within f32 tolerance.
    pub fn execute_streaming(
        &mut self,
        router: &Router,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
        rng: Option<&mut Rng>,
    ) -> Result<StreamedStep> {
        self.execute_streaming_impl(router, xs, StepWeights::F32(weights), rng, true)
    }

    /// Forward-only (inference) variant of
    /// [`execute_streaming`](Self::execute_streaming): deterministic
    /// routing (no eq-4 noise) and none of the trainer-only bookkeeping
    /// — per-token [`GateVec`] copies, importance/load merges and the
    /// retained [`DispatchPlan`] all exist solely so a backward pass or
    /// a balance loss can re-walk the step, and a serving runtime does
    /// neither.  Same math, same workers, same pooled arenas; returns
    /// only the combined outputs and the step telemetry.
    pub fn execute_streaming_forward(
        &mut self,
        router: &Router,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let s = self.execute_streaming_impl(
            router,
            xs,
            StepWeights::F32(weights),
            None,
            false,
        )?;
        Ok((s.outs, s.stats))
    }

    /// [`execute_streaming_forward`](Self::execute_streaming_forward)
    /// with int8-quantized expert weights: the
    /// [`crate::kernels::quant::Precision::Int8`] serving path.  Same
    /// streaming pipeline, same workers, same pooled arenas and fault
    /// recovery — only the shard workers' `forward_into` differs, so
    /// outputs track the f32 path within the quantization error budget
    /// ([`crate::kernels::quant::SERVE_REL_ERR_BUDGET`]) instead of
    /// bit-exactly.
    pub fn execute_streaming_forward_quant(
        &mut self,
        router: &Router,
        xs: &[&TensorF],
        weights: &[QuantizedExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let s = self.execute_streaming_impl(
            router,
            xs,
            StepWeights::Int8(weights),
            None,
            false,
        )?;
        Ok((s.outs, s.stats))
    }

    /// Shared body of the streaming paths.  `collect_decisions` gates
    /// the per-token gate-vector copies and importance/load accumulation
    /// (returned `decisions` are empty when false — forward-only callers
    /// never read them).
    fn execute_streaming_impl(
        &mut self,
        router: &Router,
        xs: &[&TensorF],
        weights: StepWeights<'_>,
        mut rng: Option<&mut Rng>,
        collect_decisions: bool,
    ) -> Result<StreamedStep> {
        let d = match xs.first() {
            Some(t) if t.shape.len() == 2 => t.shape[1],
            Some(t) => bail!("replica input shape {:?} (want (rows, d))", t.shape),
            None => bail!("no replica inputs"),
        };
        if router.n_experts != self.layout.n_experts {
            bail!(
                "router has {} experts but engine layout has {}",
                router.n_experts,
                self.layout.n_experts
            );
        }
        if router.groups == 0
            && !matches!(router.backend, RouterBackend::Native) {
            bail!(
                "execute_streaming needs a Native-math router \
                 (artifact-backed flat gating routes on the coordinator)"
            );
        }
        for x in xs {
            if x.shape.len() != 2 || x.shape[1] != d {
                bail!("replica input shape {:?} (want (rows, {d}))", x.shape);
            }
        }
        let n = self.layout.n_experts;
        let n_dev = self.layout.n_devices;
        let cap = self
            .policy
            .capacity()
            .unwrap_or(STREAM_DEFAULT_CAP)
            .max(1);
        let trace = self.begin_step_trace();
        let mut phases = PhaseNanos::default();
        let mut shard_compute = vec![0u64; n_dev];

        // fault-injection context for this step: snapshot the plan and
        // the session's step index, then advance the counter (even if
        // the step later errors, so retries see fresh draws)
        self.tally = FaultTally::default();
        let fault_ctx: Option<(FaultPlan, u64)> =
            self.fault.as_mut().map(|s| {
                let st = s.step;
                s.step += 1;
                (s.plan.clone(), st)
            });
        // recovery needs each token's other selected experts, even on
        // the forward-only path that otherwise skips gate-vector copies
        let need_sel = collect_decisions || fault_ctx.is_some();

        // Declared before the guards below: drop order (reverse of
        // declaration) then drains every in-flight job before any
        // borrowed noise buffer or dead-expert mask is freed — see
        // module safety notes.
        let mask: Option<Vec<bool>> = fault_ctx
            .as_ref()
            .and_then(|(fp, st)| fp.router_mask(*st, &self.layout));
        let mut noises: Vec<Option<RouteNoise>> = Vec::with_capacity(xs.len());
        let mut builder = PlanBuilder::with_capacity(n, self.dispatch_capacity)
            .with_residual_policy(self.residual);
        let mut decisions: Vec<RoutingDecision> = Vec::with_capacity(xs.len());
        // rows already gathered + dispatched per expert (≤ its final load)
        let mut emitted = vec![0usize; n];
        // experts touched since the last wave-emission check, so the
        // dispatch scan is O(routes) per step instead of
        // O(blocks × n_experts)
        let mut dirty = vec![false; n];
        let mut touched: Vec<usize> = Vec::new();
        // per-replica completion records; a replica seals once routed
        // *and* fully dispatched, and combines once its last owed chunk
        // drains — usually while later replicas still route/compute
        let mut trackers: Vec<ReplicaTracker> =
            xs.iter().map(|x| ReplicaTracker::new(x.shape[0], false)).collect();
        let mut outs_raw: Vec<Option<Vec<f32>>> =
            (0..xs.len()).map(|_| None).collect();
        let mut combine_work_ns = 0u64;
        let mut combine_stamps: Vec<Instant> = Vec::new();

        let (c_tx, c_rx) = channel::<ComputeReply>();
        let (r_tx, r_rx) = channel::<RouteReply>();
        let (k_tx, k_rx) = channel::<CombineReply>();
        let mut c_guard = DrainGuard::new(&c_rx);
        let mut r_guard = DrainGuard::new(&r_rx);
        let mut k_guard = DrainGuard::new(&k_rx);

        let mut compute_panic = false;
        let mut combine_panic = false;
        let mut route_err: Option<String> = None;
        let mut first_dispatch: Option<Instant> = None;
        let mut last_compute_done = Instant::now();
        // coordinator route-waits and gather-staging that land *after*
        // the first compute dispatch — subtracted from the compute
        // window so the phases stay (approximately) disjoint and the
        // adaptive controller sees load imbalance, not routing stalls
        let mut coord_in_window = 0u64;

        for (ri, x) in xs.iter().enumerate() {
            let b = x.shape[0];
            // the noise draw is serial and cheap; drawing replica by
            // replica in order keeps the rng stream identical to the
            // serial route path
            let t0 = Instant::now();
            noises.push(router.draw_noise(b, rng.as_deref_mut()));
            phases.route += t0.elapsed().as_nanos() as u64;
            // SAFETY: valid until every route job of this replica has
            // replied — `noises` is not pushed to again before that
            let noise_ptr = noises
                .last()
                .and_then(|ns| ns.as_ref().map(|ns| ns as *const RouteNoise));

            // gate stage: fan the replica's rows out over the pool
            let block_rows = (b / (4 * n_dev.max(1))).clamp(32, 256);
            let n_blocks = if b == 0 { 0 } else { 1 + (b - 1) / block_rows };
            for blk in 0..n_blocks {
                let job = RouteJob {
                    router,
                    x: *x as *const TensorF,
                    noise: noise_ptr,
                    mask: mask.as_ref().map(|m| m as *const Vec<bool>),
                    block: blk,
                    lo: blk * block_rows,
                    hi: ((blk + 1) * block_rows).min(b),
                    reply: r_tx.clone(),
                };
                self.txs[blk % n_dev]
                    .send(Job::Route(job))
                    .map_err(|_| anyhow!("route worker unavailable"))?;
                r_guard.sent();
            }

            // dispatch stage: reassemble blocks in row order and ship
            // every expert wave whose rows are final
            let mut pending: Vec<Option<RouteBlock>> =
                (0..n_blocks).map(|_| None).collect();
            let mut next_append = 0usize;
            let mut per_token: Vec<GateVec> =
                Vec::with_capacity(if need_sel { b } else { 0 });
            let mut imp = vec![0f32; if collect_decisions { n } else { 0 }];
            let mut load = vec![0f32; if collect_decisions { n } else { 0 }];
            for _ in 0..n_blocks {
                // recycle finished waves while the gate stage runs;
                // every drained chunk may complete a replica and send
                // its combine out onto the pool
                while let Some(r) = c_guard.try_recv() {
                    last_compute_done = Instant::now();
                    self.absorb_compute_reply(
                        r,
                        builder.plan(),
                        &mut trackers,
                        &mut shard_compute,
                        d,
                        &k_tx,
                        &mut k_guard,
                        &mut compute_panic,
                        fault_ctx.as_ref(),
                    )?;
                }
                while let Some(kr) = k_guard.try_recv() {
                    self.absorb_combine_reply(
                        kr,
                        &mut outs_raw,
                        &mut combine_work_ns,
                        &mut combine_stamps,
                        &mut combine_panic,
                    );
                }
                // time blocked on the gate stage = the routing cost the
                // pipeline failed to hide under expert compute
                let t_wait = Instant::now();
                let reply = r_guard.recv()?;
                let waited = t_wait.elapsed().as_nanos() as u64;
                phases.route += waited;
                if first_dispatch.is_some() {
                    coord_in_window += waited;
                }
                match reply.result {
                    Ok(blk) => pending[reply.block] = Some(blk),
                    Err(e) => {
                        route_err.get_or_insert(e);
                    }
                }
                if route_err.is_some() {
                    continue; // keep draining this replica's blocks
                }
                while next_append < n_blocks {
                    let Some(blk) = pending[next_append].take() else {
                        break;
                    };
                    if collect_decisions {
                        for (a, v) in imp.iter_mut().zip(blk.importance.iter()) {
                            *a += v;
                        }
                        for (a, v) in load.iter_mut().zip(blk.load.iter()) {
                            *a += v;
                        }
                    }
                    for tok in &blk.per_token {
                        for &e in &tok.experts {
                            if !dirty[e] {
                                dirty[e] = true;
                                touched.push(e);
                            }
                        }
                    }
                    builder.push_rows(&blk.per_token);
                    if need_sel {
                        per_token.extend(blk.per_token);
                    }
                    next_append += 1;
                }
                let t_g = Instant::now();
                for &e in &touched {
                    dirty[e] = false;
                    while builder.expert_len(e) - emitted[e] >= cap {
                        let lo = emitted[e];
                        if first_dispatch.is_none() {
                            first_dispatch = Some(Instant::now());
                        }
                        let sent = self.send_streamed_chunk(
                            builder.plan(),
                            &mut trackers,
                            xs,
                            weights,
                            e,
                            lo,
                            lo + cap,
                            d,
                            &c_tx,
                            fault_ctx.as_ref(),
                            &per_token,
                        )?;
                        c_guard.sent_n(sent);
                        emitted[e] = lo + cap;
                    }
                }
                touched.clear();
                let staged = t_g.elapsed().as_nanos() as u64;
                phases.gather += staged;
                if first_dispatch.is_some() {
                    coord_in_window += staged;
                }
            }
            if route_err.is_some() {
                break;
            }
            // flush the sub-capacity tails of everything routed so far:
            // replica `ri` is now fully dispatched, so its completion
            // record only waits on chunks already in flight
            let t_g = Instant::now();
            for e in 0..n {
                let len = builder.expert_len(e);
                let mut lo = emitted[e];
                while lo < len {
                    let hi = (lo + cap).min(len);
                    if first_dispatch.is_none() {
                        first_dispatch = Some(Instant::now());
                    }
                    let sent = self.send_streamed_chunk(
                        builder.plan(),
                        &mut trackers,
                        xs,
                        weights,
                        e,
                        lo,
                        hi,
                        d,
                        &c_tx,
                        fault_ctx.as_ref(),
                        &per_token,
                    )?;
                    c_guard.sent_n(sent);
                    lo = hi;
                }
                emitted[e] = len;
            }
            let staged = t_g.elapsed().as_nanos() as u64;
            phases.gather += staged;
            if first_dispatch.is_some() {
                coord_in_window += staged;
            }
            builder.finish_replica();
            if collect_decisions {
                decisions.push(RoutingDecision {
                    per_token,
                    importance: imp,
                    load,
                    // safe to move out: every route job of this replica
                    // has replied (the block loop above drained them
                    // all), so no worker still borrows this noise
                    noise: noises[ri].take(),
                });
            }
            trackers[ri].sealed = true;
            if trackers[ri].ready() {
                self.emit_combine(&mut trackers, ri, d, &k_tx)?;
                k_guard.sent();
            }
        }

        while c_guard.outstanding > 0 {
            let r = c_guard.recv()?;
            last_compute_done = Instant::now();
            self.absorb_compute_reply(
                r,
                builder.plan(),
                &mut trackers,
                &mut shard_compute,
                d,
                &k_tx,
                &mut k_guard,
                &mut compute_panic,
                fault_ctx.as_ref(),
            )?;
        }
        if let Some(e) = route_err {
            bail!("streamed step gate stage failed: {e}");
        }
        if compute_panic {
            bail!("expert shard panicked during step");
        }
        // the dispatch→drain window minus the coordinator route/gather
        // time that landed inside it, keeping the reported phases
        // (approximately) disjoint; busy/idle are judged against the
        // same window, so a route-bound step does not read as shard
        // imbalance — which would make the adaptive controller shrink
        // waves (adding chunk overhead) on exactly the steps that
        // cannot benefit
        phases.compute = first_dispatch
            .map(|t| t.elapsed().as_nanos() as u64)
            .unwrap_or(0)
            .saturating_sub(coord_in_window);
        let compute_wall = phases.compute;

        let plan = builder.finish();
        let loads = plan.expert_loads();
        // every replica combine is already in flight (or done); the tail
        // left here is the only combine on the critical path
        let t_tail = Instant::now();
        while k_guard.outstanding > 0 {
            let kr = k_guard.recv()?;
            self.absorb_combine_reply(
                kr,
                &mut outs_raw,
                &mut combine_work_ns,
                &mut combine_stamps,
                &mut combine_panic,
            );
        }
        phases.combine = t_tail.elapsed().as_nanos() as u64;
        phases.overlap_ns = combine_work_ns.saturating_sub(phases.combine);
        if combine_panic {
            bail!("combine worker panicked during step");
        }
        let outs = collect_outs(outs_raw, &plan.replica_rows, d)?;
        let n_waves = waves_for_loads(&loads, Some(cap));
        let mut stats = build_stats(
            &self.layout,
            &plan,
            d,
            n_waves,
            phases,
            shard_compute,
            compute_wall,
        );
        stats.combines_overlapped = combine_stamps
            .iter()
            .filter(|t| **t <= last_compute_done)
            .count();
        stats.failed_chunks = self.tally.failed_chunks;
        stats.redispatched_routes = self.tally.redispatched_routes;
        stats.degraded_tokens = self.tally.degraded_tokens;
        stats.renorm_mass_lost = self.tally.renorm_mass_lost;
        self.policy.observe(&stats);
        self.finish_step_trace(trace);
        Ok(StreamedStep { outs, decisions, plan, stats })
    }

    /// Gather rows `[lo, hi)` of expert `e` from the builder plan's
    /// immutable prefix into pooled buffers, record the chunk on the
    /// completion records of the replicas it serves, and dispatch it to
    /// the owning shard worker.  Returns the number of compute jobs
    /// sent: 1 on the healthy path, and one single-row re-dispatch per
    /// recovered route when the fault plan fails the chunk (0 when
    /// every route degrades instead).
    ///
    /// `cur_sel` holds the current replica's routed gate vectors —
    /// streamed chunks never span replicas (everything routed is tail-
    /// flushed at each replica boundary), so every token address in
    /// `[lo, hi)` indexes into it.
    #[allow(clippy::too_many_arguments)]
    fn send_streamed_chunk(
        &mut self,
        plan: &DispatchPlan,
        trackers: &mut [ReplicaTracker],
        xs: &[&TensorF],
        weights: StepWeights<'_>,
        e: usize,
        lo: usize,
        hi: usize,
        d: usize,
        reply: &Sender<ComputeReply>,
        fault: Option<&(FaultPlan, u64)>,
        cur_sel: &[GateVec],
    ) -> Result<usize> {
        let dev = self.layout.owner(e);
        let outcome = fault
            .map(|(fp, st)| fp.chunk_outcome(*st, dev, e, lo))
            .unwrap_or(ChunkOutcome::Healthy);
        if let ChunkOutcome::Failed = outcome {
            // detected failure (shard death, chunk fault, or straggler
            // past its deadline): bounded recovery — re-dispatch each
            // route to the token's next surviving selected expert, or
            // charge its gate to the replica's lost mass.  The chunk is
            // never registered; only successful re-dispatches add owed
            // messages (retries are not themselves re-faulted).
            let (fp, st) = fault.expect("failed outcome implies a plan");
            self.tally.failed_chunks += 1;
            let batch = &plan.per_expert[e];
            let mut sent = 0usize;
            for pos in lo..hi {
                let addr = batch.tokens[pos];
                let gate = batch.gates[pos];
                let target = fp.redirect_target(
                    *st,
                    &self.layout,
                    &cur_sel[addr.row].experts,
                    e,
                );
                let Some(target) = target else {
                    trackers[addr.replica].lose(addr.row, gate);
                    continue;
                };
                let mut input = self.pool.take();
                input.extend_from_slice(
                    &xs[addr.replica].data[addr.row * d..(addr.row + 1) * d],
                );
                let mut output = self.pool.take();
                output.resize(d, 0.0);
                let tdev = self.layout.owner(target);
                let job = ComputeJob {
                    device: tdev,
                    weights: weights.raw(),
                    tasks: vec![ExpertTask {
                        expert: target,
                        rows: 1,
                        out_offset: 0,
                        input,
                        output,
                        retry: Some(RetryTask {
                            replica: addr.replica,
                            row: addr.row,
                            gate,
                            retry_order: ((e as u64 + 1) << 32)
                                | pos as u64,
                        }),
                    }],
                    delay_ns: 0,
                    reply: reply.clone(),
                };
                self.txs[tdev]
                    .send(Job::Compute(job))
                    .map_err(|_| anyhow!("shard worker {tdev} unavailable"))?;
                self.trace_coord_event(
                    SpanKind::Retry,
                    target as u32,
                    pos as u32,
                    addr.replica as u32,
                    1,
                );
                trackers[addr.replica].outstanding += 1;
                self.tally.redispatched_routes += 1;
                sent += 1;
            }
            return Ok(sent);
        }
        let delay_ns = match outcome {
            ChunkOutcome::Delayed(ns) => ns,
            _ => 0,
        };
        register_chunk(plan, trackers, e, lo, hi);
        let mut input = self.pool.take();
        Dispatcher::gather_range_into(plan, e, lo..hi, xs, &mut input);
        let mut output = self.pool.take();
        output.resize((hi - lo) * d, 0.0);
        let job = ComputeJob {
            device: dev,
            weights: weights.raw(),
            tasks: vec![ExpertTask {
                expert: e,
                rows: hi - lo,
                out_offset: lo,
                input,
                output,
                retry: None,
            }],
            delay_ns,
            reply: reply.clone(),
        };
        self.txs[dev]
            .send(Job::Compute(job))
            .map_err(|_| anyhow!("shard worker {dev} unavailable"))?;
        self.trace_coord_event(
            SpanKind::Dispatch,
            e as u32,
            lo as u32,
            NO_ID,
            (hi - lo) as u32,
        );
        Ok(1)
    }

    /// Fold one finished compute reply into the executor state: credit
    /// the shard, recycle input buffers, and deliver each task's output
    /// chunk to the combine queues of the replicas it serves.  Under an
    /// active fault plan a worker panic degrades the affected routes
    /// (their gate mass is charged to the replicas' lost mass and the
    /// owed message resolved) instead of failing the step, so the
    /// engine stays live.
    #[allow(clippy::too_many_arguments)]
    fn absorb_compute_reply(
        &mut self,
        reply: ComputeReply,
        plan: &DispatchPlan,
        trackers: &mut [ReplicaTracker],
        shard_compute: &mut [u64],
        d: usize,
        k_tx: &Sender<CombineReply>,
        k_guard: &mut DrainGuard<'_, CombineReply>,
        panicked: &mut bool,
        fault: Option<&(FaultPlan, u64)>,
    ) -> Result<()> {
        shard_compute[reply.device] += reply.compute_ns;
        for t in reply.tasks {
            self.pool.put(t.input);
            if let Some(rt) = t.retry {
                // one re-dispatched route: deliver as a single-row
                // segment, or charge its gate to the lost mass —
                // either way the owed message resolves
                if reply.ok {
                    trackers[rt.replica].inbox.push(CombineSegment {
                        expert: t.expert,
                        chunk_lo: 0,
                        lo: 0,
                        retry_order: rt.retry_order,
                        rows: vec![rt.row],
                        gates: vec![rt.gate],
                        data: Arc::new(t.output),
                    });
                } else {
                    trackers[rt.replica].lose(rt.row, rt.gate);
                    self.pool.put(t.output);
                }
                trackers[rt.replica].outstanding -= 1;
                if trackers[rt.replica].ready() {
                    self.emit_combine(trackers, rt.replica, d, k_tx)?;
                    k_guard.sent();
                }
            } else if reply.ok {
                self.deliver_chunk(
                    plan,
                    trackers,
                    t.expert,
                    t.out_offset,
                    t.rows,
                    t.output,
                    d,
                    k_tx,
                    k_guard,
                    fault,
                )?;
            } else if fault.is_some() {
                // worker panic with recovery armed: degrade every route
                // of the chunk and resolve the owed messages so the
                // step completes with renormalized outputs
                self.tally.failed_chunks += 1;
                let batch = &plan.per_expert[t.expert];
                for (replica, run) in Dispatcher::replica_runs(
                    plan,
                    t.expert,
                    t.out_offset..t.out_offset + t.rows,
                ) {
                    for pos in run {
                        trackers[replica]
                            .lose(batch.tokens[pos].row, batch.gates[pos]);
                    }
                    trackers[replica].outstanding -= 1;
                    if trackers[replica].ready() {
                        self.emit_combine(trackers, replica, d, k_tx)?;
                        k_guard.sent();
                    }
                }
                self.pool.put(t.output);
            } else {
                // garbage output of a panicked worker: recycle, leave
                // the owed counts standing (the step bails after drain)
                *panicked = true;
                self.pool.put(t.output);
            }
        }
        Ok(())
    }

    /// Deliver one drained expert chunk to the combine recv queues:
    /// split it along [`Dispatcher::replica_runs`] into per-replica
    /// segments (copying destination rows and gates out of the plan's
    /// immutable prefix), and emit the combine job of every replica
    /// whose last owed chunk this was.  An active fault plan may drop
    /// the combine *message* (the all-to-all return leg) even though
    /// the chunk computed: the affected routes degrade exactly like a
    /// failed chunk, but after compute — no retry, only renorm.
    #[allow(clippy::too_many_arguments)]
    fn deliver_chunk(
        &mut self,
        plan: &DispatchPlan,
        trackers: &mut [ReplicaTracker],
        expert: usize,
        chunk_lo: usize,
        rows: usize,
        output: Vec<f32>,
        d: usize,
        k_tx: &Sender<CombineReply>,
        k_guard: &mut DrainGuard<'_, CombineReply>,
        fault: Option<&(FaultPlan, u64)>,
    ) -> Result<()> {
        let data = Arc::new(output);
        let batch = &plan.per_expert[expert];
        for (replica, run) in
            Dispatcher::replica_runs(plan, expert, chunk_lo..chunk_lo + rows)
        {
            let dropped = fault
                .map(|(fp, st)| {
                    fp.combine_dropped(*st, expert, chunk_lo, replica)
                })
                .unwrap_or(false);
            if dropped {
                self.tally.failed_chunks += 1;
                for pos in run {
                    trackers[replica]
                        .lose(batch.tokens[pos].row, batch.gates[pos]);
                }
            } else {
                trackers[replica].inbox.push(CombineSegment {
                    expert,
                    chunk_lo,
                    lo: run.start,
                    retry_order: 0,
                    rows: batch.tokens[run.clone()]
                        .iter()
                        .map(|a| a.row)
                        .collect(),
                    gates: batch.gates[run].to_vec(),
                    data: data.clone(),
                });
            }
            trackers[replica].outstanding -= 1;
            if trackers[replica].ready() {
                self.emit_combine(trackers, replica, d, k_tx)?;
                k_guard.sent();
            }
        }
        Ok(())
    }

    /// Emit replica `r`'s gate-weighted combine as a worker-pool job.
    /// The inbox is sorted expert-major (then retries after originals,
    /// then by batch row) first, so each token accumulates its
    /// contributions in exactly the serial reference order — and, under
    /// faults, the degraded oracle's order — regardless of chunk
    /// completion timing.  Any lost gate mass rides along so the worker
    /// renormalizes the affected rows over what was actually delivered.
    fn emit_combine(
        &mut self,
        trackers: &mut [ReplicaTracker],
        r: usize,
        d: usize,
        k_tx: &Sender<CombineReply>,
    ) -> Result<()> {
        let tracker = &mut trackers[r];
        debug_assert!(!tracker.emitted, "replica {r} combined twice");
        tracker.emitted = true;
        let rows = tracker.rows;
        let mut segments = std::mem::take(&mut tracker.inbox);
        segments.sort_by_key(|s| (s.expert, s.retry_order, s.lo));
        let lost = if tracker.lost.iter().any(|&m| m > 0.0) {
            let mut lost = std::mem::take(&mut tracker.lost);
            lost.resize(rows, 0.0);
            self.tally.degraded_tokens +=
                lost.iter().filter(|&&m| m > 0.0).count();
            self.tally.renorm_mass_lost +=
                lost.iter().map(|&m| m as f64).sum::<f64>();
            Some(lost)
        } else {
            None
        };
        let out = self.pool.take();
        let dev = r % self.layout.n_devices;
        self.txs[dev]
            .send(Job::Combine(CombineJob {
                replica: r,
                rows,
                d,
                segments,
                lost,
                out,
                reply: k_tx.clone(),
            }))
            .map_err(|_| anyhow!("combine worker {dev} unavailable"))
    }

    /// Drain one combine reply: recycle chunk buffers that are no
    /// longer shared and park the finished replica output.
    fn absorb_combine_reply(
        &mut self,
        reply: CombineReply,
        outs_raw: &mut [Option<Vec<f32>>],
        combine_work_ns: &mut u64,
        combine_stamps: &mut Vec<Instant>,
        panicked: &mut bool,
    ) {
        let CombineReply {
            replica,
            ok,
            combine_ns,
            finished_at,
            out,
            segments,
        } = reply;
        *combine_work_ns += combine_ns;
        // no-op combines (replicas owed no chunks) finish before any
        // compute by construction; counting them would overstate the
        // combines_overlapped structural witness
        if !segments.is_empty() {
            combine_stamps.push(finished_at);
        }
        for seg in segments {
            if let Ok(buf) = Arc::try_unwrap(seg.data) {
                self.pool.put(buf);
            }
        }
        *panicked |= !ok;
        outs_raw[replica] = Some(out);
    }

    /// Stage one wave: gather each expert's `[w*cap, (w+1)*cap)` row
    /// chunk into pooled buffers, grouped by owning device.
    fn stage_wave(
        &mut self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        wave: usize,
        cap: usize,
        d: usize,
    ) -> (Vec<Vec<ExpertTask>>, u64) {
        let t0 = Instant::now();
        let mut tasks: Vec<Vec<ExpertTask>> =
            (0..self.layout.n_devices).map(|_| Vec::new()).collect();
        for e in 0..plan.n_experts {
            let load = plan.per_expert[e].tokens.len();
            let lo = wave.saturating_mul(cap);
            if lo >= load {
                continue;
            }
            let hi = lo.saturating_add(cap).min(load);
            let mut input = self.pool.take();
            Dispatcher::gather_range_into(plan, e, lo..hi, xs, &mut input);
            let mut output = self.pool.take();
            output.resize((hi - lo) * d, 0.0);
            tasks[self.layout.owner(e)].push(ExpertTask {
                expert: e,
                rows: hi - lo,
                out_offset: lo,
                input,
                output,
                retry: None,
            });
        }
        (tasks, t0.elapsed().as_nanos() as u64)
    }

    /// Terminal gate-weighted combine (eq 1) into pooled output
    /// storage; returns (per-replica outputs, combine wall ns).  Only
    /// the artifact path still combines this way — its chunks execute
    /// serialized on the coordinator (the PJRT handle is not `Send`),
    /// so there is no compute to hide the combine under.  The Native
    /// paths use the dependency-driven per-replica combine jobs
    /// instead (module docs).
    fn combine(
        &mut self,
        plan: &DispatchPlan,
        expert_out: Vec<Vec<f32>>,
        loads: &[usize],
        d: usize,
    ) -> (Vec<TensorF>, u64) {
        let t0 = Instant::now();
        let expert_tensors: Vec<TensorF> = expert_out
            .into_iter()
            .enumerate()
            .map(|(e, buf)| TensorF::new(vec![loads[e], d], buf))
            .collect();
        let mut outs = Vec::with_capacity(plan.replica_rows.len());
        for &rows in &plan.replica_rows {
            outs.push(TensorF::from_buffer(vec![rows, d], self.pool.take()));
        }
        Dispatcher::combine_into(plan, &expert_tensors, d, &mut outs);
        for t in expert_tensors {
            self.pool.put(t.into_buffer());
        }
        (outs, t0.elapsed().as_nanos() as u64)
    }
}

impl Drop for ExecutionEngine {
    fn drop(&mut self) {
        // closing the channels ends the worker loops
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Assemble the per-replica outputs once every combine job has replied.
fn collect_outs(
    outs_raw: Vec<Option<Vec<f32>>>,
    replica_rows: &[usize],
    d: usize,
) -> Result<Vec<TensorF>> {
    outs_raw
        .into_iter()
        .zip(replica_rows.iter())
        .enumerate()
        .map(|(r, (buf, &rows))| {
            let buf = buf.ok_or_else(|| {
                anyhow!("replica {r} combine never completed")
            })?;
            Ok(TensorF::from_buffer(vec![rows, d], buf))
        })
        .collect()
}

fn send_gather(
    tx: &Sender<Job>,
    reply: &Sender<GatherReply>,
    plan: &DispatchPlan,
    xs: &[&TensorF],
    (expert, lo, hi): (usize, usize, usize),
    buf: Vec<f32>,
) -> Result<()> {
    let job = GatherJob {
        plan,
        xs: xs.iter().map(|t| *t as *const TensorF).collect(),
        expert,
        lo,
        hi,
        buf,
        reply: reply.clone(),
    };
    tx.send(Job::Gather(job))
        .map_err(|_| anyhow!("gather worker unavailable"))
}

/// Record one expert-task span on the worker's ring: kind Retry for a
/// fault re-dispatch (carrying the replica it serves), Compute
/// otherwise.  Tracing reads the clock and writes the ring — it never
/// touches job data, so traced steps stay bit-identical to untraced.
fn record_task_span(
    tr: &TraceShared,
    dev: usize,
    t: &ExpertTask,
    start_ns: u64,
) {
    tr.ring(dev).push(Span {
        kind: if t.retry.is_some() { SpanKind::Retry } else { SpanKind::Compute },
        step: tr.step_id(),
        shard: dev as u32,
        expert: t.expert as u32,
        chunk: t.out_offset as u32,
        replica: t.retry.as_ref().map(|r| r.replica as u32).unwrap_or(NO_ID),
        rows: t.rows as u32,
        start_ns,
        dur_ns: tr.now_ns().saturating_sub(start_ns),
    });
}

/// Persistent shard worker: waits for jobs, computes into its arena,
/// always replies (even on panic — see module safety notes).  With
/// tracing on (`obs` is `Some`), each job additionally records spans
/// into this worker's own SPSC ring; with tracing off the cost is one
/// branch per job.
fn worker_loop(rx: Receiver<Job>, dev: usize, obs: Option<Arc<TraceShared>>) {
    // persistent hidden-layer scratch arena, reused across steps
    let mut scratch: Vec<f32> = Vec::new();
    while let Ok(job) = rx.recv() {
        match job {
            Job::Compute(mut j) => {
                let t0 = Instant::now();
                if j.delay_ns > 0 {
                    // injected straggler: burn wall time inside the
                    // timed window so telemetry sees the slow shard
                    std::thread::sleep(Duration::from_nanos(j.delay_ns));
                }
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    // SAFETY (both arms): the coordinator blocks until
                    // our reply.  The arms are line-for-line twins; the
                    // only difference is which width's forward_into the
                    // selected kernel runs.
                    match j.weights {
                        WeightsPtr::F32(p) => {
                            let weights: &[ExpertWeights] = unsafe { &*p };
                            for t in j.tasks.iter_mut() {
                                let s0 = obs.as_ref().map(|tr| tr.now_ns());
                                let w = &weights[t.expert];
                                w.forward_into(
                                    &t.input[..t.rows * w.d_model],
                                    t.rows,
                                    &mut scratch,
                                    &mut t.output,
                                );
                                if let (Some(tr), Some(s0)) = (&obs, s0) {
                                    record_task_span(tr, dev, t, s0);
                                }
                            }
                        }
                        WeightsPtr::Int8(p) => {
                            let weights: &[QuantizedExpertWeights] =
                                unsafe { &*p };
                            for t in j.tasks.iter_mut() {
                                let s0 = obs.as_ref().map(|tr| tr.now_ns());
                                let w = &weights[t.expert];
                                w.forward_into(
                                    &t.input[..t.rows * w.d_model],
                                    t.rows,
                                    &mut scratch,
                                    &mut t.output,
                                );
                                if let (Some(tr), Some(s0)) = (&obs, s0) {
                                    record_task_span(tr, dev, t, s0);
                                }
                            }
                        }
                    }
                }))
                .is_ok();
                let _ = j.reply.send(ComputeReply {
                    device: j.device,
                    ok,
                    tasks: j.tasks,
                    compute_ns: t0.elapsed().as_nanos() as u64,
                });
            }
            Job::Route(j) => {
                let s0 = obs.as_ref().map(|tr| tr.now_ns());
                let result = match catch_unwind(AssertUnwindSafe(|| {
                    // SAFETY: the coordinator blocks until our reply;
                    // route_rows is pure Native math (never touches a
                    // non-Send artifact handle — see module safety notes)
                    let router: &Router = unsafe { &*j.router };
                    let x: &TensorF = unsafe { &*j.x };
                    let noise: Option<&RouteNoise> =
                        j.noise.map(|p| unsafe { &*p });
                    let dead: Option<&[bool]> =
                        j.mask.map(|p| unsafe { (*p).as_slice() });
                    router.route_rows_masked(x, j.lo, j.hi, noise, dead)
                })) {
                    Ok(Ok(blk)) => Ok(blk),
                    Ok(Err(e)) => Err(e.to_string()),
                    Err(_) => Err("route worker panicked".to_string()),
                };
                if let (Some(tr), Some(s0)) = (&obs, s0) {
                    tr.ring(dev).push(Span {
                        kind: SpanKind::Route,
                        step: tr.step_id(),
                        shard: dev as u32,
                        expert: NO_ID,
                        chunk: j.lo as u32,
                        replica: NO_ID,
                        rows: (j.hi - j.lo) as u32,
                        start_ns: s0,
                        dur_ns: tr.now_ns().saturating_sub(s0),
                    });
                }
                let _ = j.reply.send(RouteReply { block: j.block, result });
            }
            Job::Gather(mut j) => {
                let s0 = obs.as_ref().map(|tr| tr.now_ns());
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    // SAFETY: the coordinator blocks until our reply
                    let plan: &DispatchPlan = unsafe { &*j.plan };
                    let xs: Vec<&TensorF> =
                        j.xs.iter().map(|&p| unsafe { &*p }).collect();
                    Dispatcher::gather_range_into(
                        plan,
                        j.expert,
                        j.lo..j.hi,
                        &xs,
                        &mut j.buf,
                    );
                }))
                .is_ok();
                if let (Some(tr), Some(s0)) = (&obs, s0) {
                    tr.ring(dev).push(Span {
                        kind: SpanKind::Gather,
                        step: tr.step_id(),
                        shard: dev as u32,
                        expert: j.expert as u32,
                        chunk: j.lo as u32,
                        replica: NO_ID,
                        rows: (j.hi - j.lo) as u32,
                        start_ns: s0,
                        dur_ns: tr.now_ns().saturating_sub(s0),
                    });
                }
                let _ = j.reply.send(GatherReply { ok, buf: j.buf });
            }
            Job::Combine(mut j) => {
                // gate-weighted combine (eq 1) of one replica; segments
                // arrive pre-sorted expert-major, all data owned/Arc'd,
                // so this touches nothing borrowed from the step.  With
                // lost gate mass attached, delivered mass is tallied in
                // the same accumulation order and the affected rows are
                // renormalized over it (degraded combine).
                let s0 = obs.as_ref().map(|tr| tr.now_ns());
                let t0 = Instant::now();
                let ok = catch_unwind(AssertUnwindSafe(|| {
                    let d = j.d;
                    j.out.clear();
                    j.out.resize(j.rows * d, 0.0);
                    let mut mass: Vec<f32> = if j.lost.is_some() {
                        vec![0.0; j.rows]
                    } else {
                        Vec::new()
                    };
                    for seg in &j.segments {
                        let base = seg.lo - seg.chunk_lo;
                        for (i, (&row, &gate)) in
                            seg.rows.iter().zip(seg.gates.iter()).enumerate()
                        {
                            let src = &seg.data
                                [(base + i) * d..(base + i + 1) * d];
                            let dst =
                                &mut j.out[row * d..(row + 1) * d];
                            for (o, s) in dst.iter_mut().zip(src.iter()) {
                                *o += gate * s;
                            }
                            if !mass.is_empty() {
                                mass[row] += gate;
                            }
                        }
                    }
                    if let Some(lost) = &j.lost {
                        for (row, &m) in lost.iter().enumerate() {
                            if m > 0.0 {
                                renormalize_row(
                                    &mut j.out[row * d..(row + 1) * d],
                                    mass[row],
                                );
                            }
                        }
                    }
                }))
                .is_ok();
                if let (Some(tr), Some(s0)) = (&obs, s0) {
                    tr.ring(dev).push(Span {
                        kind: SpanKind::Combine,
                        step: tr.step_id(),
                        shard: dev as u32,
                        expert: NO_ID,
                        chunk: NO_ID,
                        replica: j.replica as u32,
                        rows: j.rows as u32,
                        start_ns: s0,
                        dur_ns: tr.now_ns().saturating_sub(s0),
                    });
                }
                let _ = j.reply.send(CombineReply {
                    replica: j.replica,
                    ok,
                    combine_ns: t0.elapsed().as_nanos() as u64,
                    finished_at: Instant::now(),
                    out: j.out,
                    segments: j.segments,
                });
            }
        }
    }
}
