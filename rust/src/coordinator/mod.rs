//! L3 coordinator: the paper's distributed MoE training scheme (§3).
//!
//! "Mixing Data Parallelism and Model Parallelism": d devices each hold a
//! data-parallel replica of the dense layers and gating network, and a
//! model-parallel shard of the experts.  Each step:
//!
//! 1. every replica computes gating for its local batch
//!    ([`router::Router`], backed by the AOT gating artifact or the pure
//!    rust mirror);
//! 2. the [`dispatcher::Dispatcher`] builds the all-to-all plan: tokens
//!    from all replicas are grouped per expert (the combined kbd/n batch
//!    of §3.1) and shipped to the shard owning that expert;
//! 3. expert shards execute in waves of `capacity` tokens on the
//!    persistent [`engine::ExecutionEngine`] — long-lived worker threads
//!    with reusable arenas, staged through [`scheduler::Scheduler`]; no
//!    token is ever dropped, matching the paper's dynamically-sized
//!    expert batches, and wave w+1 is gathered while wave w computes;
//! 4. outputs are combined back per token with gate weights (eq 1), and
//!    [`balance::BalanceMeter`] tracks Importance / Load / CV² telemetry.
//!
//! Stages 1–3 need not run back-to-back: the *streaming* step
//! ([`scheduler::Scheduler::execute_streamed`] /
//! [`engine::ExecutionEngine::execute_streaming`]) pipelines them on
//! the engine's worker pool — row blocks are gated in parallel
//! ([`router::Router::route_rows`]), routed blocks feed an incremental
//! [`dispatcher::PlanBuilder`], and each expert wave is dispatched as
//! soon as its rows are final, so replica r+1 routes while replica r's
//! experts compute.  The Native wave size comes from a
//! [`scheduler::WavePolicy`]: fixed, or
//! [`scheduler::AdaptiveWave`]-controlled from the previous step's
//! measured busiest-shard idle.

pub mod balance;
pub mod dispatcher;
pub mod engine;
pub mod router;
pub mod scheduler;

pub use balance::BalanceMeter;
pub use dispatcher::{DispatchPlan, Dispatcher, ExpertBatch, PlanBuilder};
pub use engine::{ExecutionEngine, StreamedStep};
pub use router::{RouteBlock, RouteNoise, Router, RouterBackend};
pub use scheduler::{
    AdaptiveWave, PhaseNanos, Scheduler, ShardLayout, StepStats, WavePolicy,
};
