//! L3 coordinator: the paper's distributed MoE training scheme (§3).
//!
//! "Mixing Data Parallelism and Model Parallelism": d devices each hold a
//! data-parallel replica of the dense layers and gating network, and a
//! model-parallel shard of the experts.  Each step:
//!
//! 1. every replica computes gating for its local batch
//!    ([`router::Router`], backed by the AOT gating artifact or the pure
//!    rust mirror);
//! 2. the [`dispatcher::Dispatcher`] builds the all-to-all plan: tokens
//!    from all replicas are grouped per expert (the combined kbd/n batch
//!    of §3.1) and shipped to the shard owning that expert;
//! 3. expert shards execute in waves of `capacity` tokens on the
//!    persistent [`engine::ExecutionEngine`] — long-lived worker threads
//!    with reusable arenas, staged through [`scheduler::Scheduler`]; by
//!    default no token is ever dropped, matching the paper's
//!    dynamically-sized expert batches (GShard-style bounded buffers
//!    with deterministic drop/reroute are opt-in via
//!    [`scheduler::Scheduler::with_dispatch_capacity`]), and wave w+1 is
//!    gathered while wave w computes;
//! 4. outputs are combined back per token with gate weights (eq 1), and
//!    [`balance::BalanceMeter`] tracks Importance / Load / CV² telemetry.
//!
//! # Dependency-driven step executor
//!
//! These stages are *not* synchronized by global barriers.  The engine
//! is a dependency-driven executor built from three pieces:
//!
//! - **Completion records.**  Every replica carries an explicit record
//!   of how many dispatched expert chunks still owe it rows, derived
//!   from the [`dispatcher::PlanBuilder`] prefixes (streaming) or the
//!   finished plan (pre-routed steps).
//! - **Combine as a task.**  The moment a replica's last owed chunk
//!   drains, its gate-weighted combine (eq 1) is emitted as a job onto
//!   the same worker pool — replica 0 combines while later replicas are
//!   still routing and computing.  Only the post-compute combine tail
//!   is critical-path ([`scheduler::PhaseNanos::combine`]); the hidden
//!   part is reported as [`scheduler::PhaseNanos::overlap_ns`] and as
//!   [`scheduler::StepStats::combine_overlap_ratio`].
//! - **Async all-to-all.**  The cross-replica exchange is modelled as
//!   per-shard send/recv queues: chunk dispatches are the sends, and
//!   each drained chunk is split along
//!   [`dispatcher::Dispatcher::replica_runs`] into per-replica combine
//!   messages (destination rows + gates copied from the plan's
//!   immutable prefix), queued on the owing replica's inbox.  There is
//!   no coordinator-side terminal combine walk on the Native paths.
//!
//! The *streaming* step ([`scheduler::Scheduler::execute_streamed`] /
//! [`engine::ExecutionEngine::execute_streaming`]) runs gating on the
//! pool too: row blocks are gated in parallel
//! ([`router::Router::route_rows`]), routed blocks feed an incremental
//! [`dispatcher::PlanBuilder`], and each expert wave is dispatched as
//! soon as its rows are final — so replica r+1 routes while replica r's
//! experts compute *and* replica r−1's combine drains.  The Native wave
//! size comes from a [`scheduler::WavePolicy`]: fixed, or
//! [`scheduler::AdaptiveWave`]-controlled from the previous step's
//! measured busiest-shard idle.  [`engine::StreamedStep`] carries the
//! outputs, gate decisions, finished plan and telemetry;
//! [`train::Trainer::step_streamed`](crate::train::Trainer::step_streamed)
//! drives training on it without any artifacts.
//!
//! # Fault tolerance and the degraded combine
//!
//! The streaming step optionally runs under a seeded, deterministic
//! [`faults::FaultPlan`]: shard deaths, per-chunk failures, straggler
//! delays past a deadline, and dropped all-to-all combine messages are
//! all pure keyed-hash draws, so same-seed chaos runs are bit-identical
//! (the eq-4 noise pre-draw discipline, applied to faults).  Recovery
//! is two-tier: failed routes are first re-dispatched to the token's
//! other selected experts on live shards, and whatever remains becomes
//! lost gate mass — the replica's combine then *renormalizes* eq-1 over
//! the surviving contributions.  The completion records above are what
//! keep the step live: a failed chunk resolves its owed messages
//! (charging lost mass) instead of hanging the replica, and permanently
//! dead shards are masked out of the router on subsequent steps.
//!
//! **Degraded-combine / oracle-mask equivalence** (proven in
//! `rust/tests/faults.rs`): every degraded streamed output is *bit
//! equal* to evaluating the same step serially under the same failure
//! mask — [`faults::degrade_plan`] replays the engine's chunking over
//! the finished plan, applies the identical fault draws, and
//! [`faults::combine_degraded`] renormalizes in the identical
//! accumulation order.  Surviving chunks deliver all their rows and
//! failed chunks none, so the kept routes form a filtered subsequence
//! of the original dispatch order; combine segments sort
//! `(expert, retry_order, lo)` with re-dispatches keyed by their source
//! route, which reproduces the oracle's per-destination-row f32
//! sequence exactly.
//!
//! # Observability
//!
//! The engine optionally records structured spans — route / gather /
//! compute / combine / retry / dispatch, tagged with
//! `(step, shard, expert, chunk, replica)` — into per-worker lock-free
//! rings ([`crate::obs::TraceShared`]), drained by the coordinator at
//! each step's quiescence point and exportable as a Chrome trace
//! (`repro trace`).  Tracing is off by default
//! ([`crate::obs::ObsConfig`], `MOE_TRACE=1`), costs one branch per job
//! when off, and is *bit-neutral* when on: it only reads clocks, never
//! touching rng draws, accumulation order or scheduling
//! (`rust/tests/obs.rs` proves outputs identical either way).
//! [`scheduler::StepStats::publish`] feeds the same telemetry into the
//! unified metrics registry ([`crate::obs::Registry`]).

pub mod balance;
pub mod dispatcher;
pub mod engine;
pub mod faults;
pub mod router;
pub mod scheduler;

pub use balance::BalanceMeter;
pub use dispatcher::{
    DispatchPlan, Dispatcher, ExpertBatch, PlanBuilder, ResidualPolicy,
};
pub use engine::{ExecutionEngine, StepWeights, StreamedStep};
pub use faults::{
    combine_degraded, degrade_plan, renormalize_row, ChunkOutcome,
    DegradedPlan, FaultPlan, FaultSession, FaultTally, RecoveryPolicy,
};
pub use router::{RouteBlock, RouteNoise, Router, RouterBackend};
pub use scheduler::{
    AdaptiveWave, PhaseNanos, Scheduler, ShardLayout, StepStats, WavePolicy,
};
