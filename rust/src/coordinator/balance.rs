//! Balance telemetry: tracks the paper's §4 / Appendix A statistics over
//! training — CV(Importance), CV(Load), max/mean load — the exact columns
//! of Table 6.

use crate::gating::noisy_topk::cv_squared;
use crate::metrics::{max_over_mean, Running};

#[derive(Clone, Debug)]
pub struct BalanceMeter {
    pub n_experts: usize,
    pub cv_importance: Running,
    pub cv_load: Running,
    pub max_over_mean_load: Running,
    /// cumulative hard-assignment counts (for Table 9 style reporting)
    pub cumulative_counts: Vec<u64>,
}

impl BalanceMeter {
    pub fn new(n_experts: usize) -> Self {
        BalanceMeter {
            n_experts,
            cv_importance: Running::new(),
            cv_load: Running::new(),
            max_over_mean_load: Running::new(),
            cumulative_counts: vec![0; n_experts],
        }
    }

    /// Record one step's importance/load vectors (eq 6 / eq 10) and hard
    /// per-expert token counts.
    pub fn record(&mut self, importance: &[f32], load: &[f32], counts: &[usize]) {
        debug_assert_eq!(importance.len(), self.n_experts);
        // Table 6 reports CV (not CV^2): take sqrt of cv_squared
        self.cv_importance.push((cv_squared(importance) as f64).sqrt());
        self.cv_load.push((cv_squared(load) as f64).sqrt());
        self.max_over_mean_load.push(max_over_mean(load) as f64);
        for (c, &k) in self.cumulative_counts.iter_mut().zip(counts.iter()) {
            *c += k as u64;
        }
    }

    /// Table 6 row: (CV(Importance), CV(Load), max/mean) averaged over the
    /// recorded steps.
    pub fn summary(&self) -> (f64, f64, f64) {
        (
            self.cv_importance.mean(),
            self.cv_load.mean(),
            self.max_over_mean_load.mean(),
        )
    }

    /// Fraction of all routed tokens that went to the busiest expert —
    /// the "self-reinforcing imbalance" indicator of §4.
    pub fn busiest_share(&self) -> f64 {
        let total: u64 = self.cumulative_counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        *self.cumulative_counts.iter().max().unwrap() as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_steps_report_low_cv() {
        let mut m = BalanceMeter::new(4);
        for _ in 0..10 {
            m.record(&[1.0; 4], &[2.0; 4], &[3; 4]);
        }
        let (cvi, cvl, mm) = m.summary();
        assert!(cvi < 1e-4 && cvl < 1e-4);
        assert!((mm - 1.0).abs() < 1e-4);
        assert!((m.busiest_share() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn collapse_reports_high_cv() {
        let mut m = BalanceMeter::new(4);
        m.record(&[10.0, 0.0, 0.0, 0.0], &[20.0, 0.1, 0.1, 0.1], &[50, 0, 0, 0]);
        let (cvi, cvl, mm) = m.summary();
        assert!(cvi > 1.0, "cvi {cvi}");
        assert!(cvl > 1.0, "cvl {cvl}");
        assert!(mm > 3.0, "mm {mm}");
        assert_eq!(m.busiest_share(), 1.0);
    }
}
