//! Scheduler: execution of a dispatched MoE step across simulated
//! devices.
//!
//! Each simulated device owns a contiguous slice of experts (the §3.1
//! model-parallel shard).  Expert batches longer than the wave capacity
//! are processed in waves — tokens are never dropped, mirroring the
//! paper's dynamically-sized expert batches.  Expert compute still
//! bounds the step (the busiest shard's wall, which the load-balancing
//! losses exist to minimise), but the step no longer ends in a global
//! combine barrier: the engine tracks per-replica completion and
//! combines each replica the moment its last expert wave drains, so
//! only the combine *tail* lands on the critical path
//! ([`PhaseNanos::combine`] vs the hidden [`PhaseNanos::overlap_ns`]).
//!
//! Three execution paths share the same math:
//! - [`Scheduler::execute_streamed`] — the hot path for full steps:
//!   gating, dispatch and expert execution pipelined on the persistent
//!   [`ExecutionEngine`](crate::coordinator::engine::ExecutionEngine),
//!   with [`WavePolicy`]-controlled (optionally adaptive) wave sizes;
//! - [`Scheduler::execute`] — executes a pre-built [`DispatchPlan`] on
//!   the same engine (long-lived worker threads, reusable arenas,
//!   pipelined waves);
//! - [`Scheduler::execute_serial`] — the retained single-threaded
//!   reference, kept as the oracle for `rust/tests/engine_parity.rs`.
//!
//! All three paths run their GEMMs on the one process-wide kernel
//! selected by [`crate::kernels::Kernel::select`] (scalar oracle, AVX2
//! or NEON; `MOE_KERNEL` overrides).  The old per-matmul contract —
//! "bit-identical to the naive triple loop" — now holds for the scalar
//! kernel only; engine-vs-serial bit-equality is preserved regardless
//! of kernel because both sides share the selection, while
//! kernel-vs-oracle (and int8-vs-f32, see
//! [`Scheduler::execute_forward_quant`]) comparisons are
//! error-budgeted in `rust/tests/kernels.rs`.  [`StepStats::kernel`]
//! records the selected name per step.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::coordinator::dispatcher::{
    DispatchPlan, Dispatcher, ResidualPolicy,
};
use crate::coordinator::engine::{ExecutionEngine, StreamedStep};
use crate::coordinator::faults::FaultPlan;
use crate::coordinator::router::{Router, RouterBackend};
use crate::kernels::quant::QuantizedExpertWeights;
use crate::obs::{key, ObsConfig, Registry, Span};
use crate::runtime::{Executable, Host, TensorF};

/// Which device owns which experts.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    pub n_devices: usize,
    pub n_experts: usize,
}

impl ShardLayout {
    pub fn new(n_devices: usize, n_experts: usize) -> Self {
        assert!(n_devices >= 1);
        assert!(n_experts >= 1);
        ShardLayout { n_devices, n_experts }
    }

    pub fn owner(&self, expert: usize) -> usize {
        expert * self.n_devices / self.n_experts
    }

    pub fn experts_of(&self, device: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.owner(e) == device)
            .collect()
    }
}

/// Per-expert weights sliced from the flat parameter vector:
/// (w_in (d,h) row-major, w_out (h,d) row-major).
#[derive(Clone)]
pub struct ExpertWeights {
    pub w_in: Vec<f32>,
    pub w_out: Vec<f32>,
    pub d_model: usize,
    pub hidden: usize,
}

impl ExpertWeights {
    /// Reference CPU forward (used by the Native backend and tests).
    pub fn forward(&self, x: &TensorF) -> TensorF {
        let b = x.shape[0];
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        self.forward_into(&x.data, b, &mut scratch, &mut out);
        TensorF::new(vec![b, self.d_model], out)
    }

    /// Arena variant of [`forward`](Self::forward): the fused
    /// `relu(x·w_in)·w_out` ([`crate::kernels::ffn_forward`]) on the
    /// selected kernel, written into caller-owned buffers, so the
    /// persistent workers allocate nothing on the step hot path and the
    /// hidden layer only ever exists as a cache-resident row block.
    /// Rows are independent, so computing a batch in row-chunks is
    /// bit-identical to one pass (a kernel-layer invariant).
    pub fn forward_into(
        &self,
        x: &[f32],
        rows: usize,
        scratch: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        let (d, h) = (self.d_model, self.hidden);
        debug_assert_eq!(x.len(), rows * d);
        out.clear();
        out.resize(rows * d, 0.0);
        crate::kernels::ffn_forward(
            crate::kernels::Kernel::select(),
            x,
            rows,
            d,
            h,
            &self.w_in,
            &self.w_out,
            scratch,
            out,
        );
    }
}

pub enum ExpertBackend {
    /// AOT expert artifact with static (capacity, d) input — padded waves.
    Artifact { exe: Arc<Executable>, capacity: usize },
    /// Pure-rust forward (tests / configs without an expert artifact).
    Native,
}

/// Wall-clock nanoseconds per step phase.  Phases are disjoint slices
/// of the step wall: `gather` counts only staging on the critical path
/// — staging the engine overlaps with expert execution (waves ≥ 1 of
/// the pipelined paths) is deliberately *hidden inside* `compute`,
/// which is exactly the §3.2 overhead being engineered away.  The same
/// convention governs `route` on the streaming path: it counts only
/// coordinator time spent drawing noise or *blocked* waiting on the
/// gate stage, so fully-overlapped routing costs ~0 here.  `combine`
/// follows suit under the dependency-driven executor: it is the
/// post-compute combine *tail* only, while `overlap_ns` records the
/// combine work that ran hidden under expert compute.
#[derive(Clone, Debug, Default)]
pub struct PhaseNanos {
    /// critical-path gating cost (streaming path: noise draws + time
    /// blocked on route workers; 0 when routing happened outside the
    /// engine, e.g. the serial route→dispatch→execute composition)
    pub route: u64,
    /// critical-path staging of token rows into per-expert batches
    /// (all-to-all "send")
    pub gather: u64,
    /// expert execution: first dispatch to last shard done (includes
    /// any staging pipelined underneath it)
    pub compute: u64,
    /// gate-weighted scatter back to replicas (all-to-all "receive",
    /// eq 1): only the tail left after the last expert wave drained
    pub combine: u64,
    /// combine worker-nanoseconds hidden under expert compute by the
    /// per-replica completion-tracked combine jobs — *not* part of
    /// [`total`](Self::total), which sums critical-path time only
    pub overlap_ns: u64,
}

impl PhaseNanos {
    /// Critical-path step time; excludes `overlap_ns` by construction
    /// (overlapped combine work costs no wall time).
    pub fn total(&self) -> u64 {
        self.route + self.gather + self.compute + self.combine
    }

    /// Fraction of total combine work hidden under expert compute:
    /// `overlap_ns / (overlap_ns + combine)`.  0 when no combine work
    /// was measured at all.  The single definition of the overlap
    /// metric — [`StepStats::combine_overlap_ratio`] and the phase
    /// reports both delegate here.
    pub fn combine_overlap_ratio(&self) -> f64 {
        let total = self.overlap_ns + self.combine;
        if total == 0 {
            0.0
        } else {
            self.overlap_ns as f64 / total as f64
        }
    }

    /// Publish the per-phase walls as `step_phase_ns{phase=...}`
    /// counters (accumulating — publishing N steps sums them).
    pub fn publish(&self, reg: &mut Registry) {
        for (phase, ns) in [
            ("route", self.route),
            ("gather", self.gather),
            ("compute", self.compute),
            ("combine", self.combine),
            ("overlap_hidden", self.overlap_ns),
        ] {
            reg.counter_add(&key("step_phase_ns", &[("phase", phase)]), ns);
        }
    }
}

/// How the Native paths pick their per-wave token capacity.
#[derive(Clone, Debug)]
pub enum WavePolicy {
    /// a fixed cap (`None` = unchunked: one wave per expert batch)
    Fixed(Option<usize>),
    /// pick each step's cap from the previous step's measured
    /// busiest-shard idle
    Adaptive(AdaptiveWave),
}

impl WavePolicy {
    /// Capacity to use for the next step.
    pub fn capacity(&self) -> Option<usize> {
        match self {
            WavePolicy::Fixed(c) => *c,
            WavePolicy::Adaptive(a) => Some(a.capacity()),
        }
    }

    /// Feed a finished step's stats back into an adaptive controller.
    pub fn observe(&mut self, stats: &StepStats) {
        if let WavePolicy::Adaptive(a) = self {
            a.observe(stats);
        }
    }
}

/// Adaptive wave capacity: instead of a fixed artifact-style constant,
/// the Native wave size for step *s+1* is derived from step *s*'s
/// measured busiest-shard idle ([`StepStats::shard_idle_ns`]).  A large
/// idle fraction means the step is serialized behind one overloaded
/// shard — smaller waves interleave its queue with the others' and give
/// the pipeline earlier dispatch opportunities; a negligible idle
/// fraction means the waves only add per-chunk overhead, so the cap
/// grows back.  Multiplicative moves with clamping keep the controller
/// stable under noisy timings.
#[derive(Clone, Debug)]
pub struct AdaptiveWave {
    cap: usize,
    min: usize,
    max: usize,
    /// grow the cap below this idle fraction of the compute wall
    lo_frac: f64,
    /// shrink the cap above this idle fraction
    hi_frac: f64,
}

impl Default for AdaptiveWave {
    fn default() -> Self {
        AdaptiveWave {
            cap: 256,
            min: 16,
            max: 8192,
            lo_frac: 0.05,
            hi_frac: 0.25,
        }
    }
}

impl AdaptiveWave {
    pub fn new() -> Self {
        Self::default()
    }

    /// Like [`new`](Self::new) but starting from (and clamped to)
    /// explicit bounds.
    pub fn with_bounds(start: usize, min: usize, max: usize) -> Self {
        let min = min.max(1);
        let max = max.max(min);
        AdaptiveWave {
            cap: start.clamp(min, max),
            min,
            max,
            ..Self::default()
        }
    }

    /// The wave capacity the next step should use.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Observe a finished step: shrink the cap when the busiest-shard
    /// idle dominates the compute wall, grow it back when idle is
    /// negligible.
    ///
    /// Idle is judged only over shards that actually computed this
    /// step: a shard that owns no experts (devices > n_experts) or
    /// received no tokens is idle for the whole wall *no matter the
    /// wave size* — counting it would pin the capacity at `min`
    /// forever in exactly those configurations.
    pub fn observe(&mut self, stats: &StepStats) {
        // reconstruct the window the idles were measured against
        // (busy + idle = that window for every shard, by construction),
        // which is exact regardless of how a path derived its compute
        // phase from the raw walls
        let wall = stats
            .shard_compute_ns
            .iter()
            .zip(stats.shard_idle_ns.iter())
            .map(|(busy, idle)| busy + idle)
            .max()
            .unwrap_or(stats.phases.compute)
            .max(1);
        let idle = stats
            .shard_compute_ns
            .iter()
            .zip(stats.shard_idle_ns.iter())
            .filter(|(busy, _)| **busy > 0)
            .map(|(_, idle)| *idle)
            .max()
            .unwrap_or(0);
        let frac = idle as f64 / wall as f64;
        if frac > self.hi_frac {
            self.cap = (self.cap / 2).max(self.min);
        } else if frac < self.lo_frac {
            self.cap = (self.cap * 2).min(self.max);
        }
    }
}

/// Telemetry for one executed step.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub expert_loads: Vec<usize>,
    /// synchronous waves needed: max over experts of ceil(load/capacity)
    /// (1 for the un-chunked Native path whenever any token routed)
    pub waves: usize,
    /// interconnect bytes of this step's all-to-all — inter-device
    /// routes only ([`DispatchPlan::network_bytes`]); a token whose
    /// expert lives on its own shard moves nothing
    pub network_bytes: u64,
    /// routes redirected to a second-choice expert by the capacity
    /// buffers (0 on the exact paths)
    pub rerouted_routes: usize,
    /// routes dropped at the capacity buffers (0 on the exact paths)
    pub dropped_routes: usize,
    pub busiest_shard_tokens: usize,
    /// per-phase wall-clock breakdown of this step
    pub phases: PhaseNanos,
    /// busy nanoseconds per shard inside the compute phase
    pub shard_compute_ns: Vec<u64>,
    /// idle nanoseconds per shard: compute-phase wall minus busy — the
    /// §3.1 synchronous wait on the busiest shard
    pub shard_idle_ns: Vec<u64>,
    /// replica combine jobs that finished before the step's last expert
    /// wave drained — the structural witness that the dependency-driven
    /// executor overlapped the all-to-all "receive" with compute
    pub combines_overlapped: usize,
    /// expert chunks that failed this step (injected chunk faults, dead
    /// shards, blown deadlines, dropped combine messages, or worker
    /// panics absorbed by recovery); 0 without a fault plan
    pub failed_chunks: usize,
    /// failed routes recovered by re-dispatching to another of the
    /// token's selected experts
    pub redispatched_routes: usize,
    /// tokens whose combine renormalized over surviving routes because
    /// some of their gate mass was lost
    pub degraded_tokens: usize,
    /// total eq-1 gate mass lost to unrecovered faults this step
    pub renorm_mass_lost: f64,
    /// name of the matmul kernel every GEMM of this step dispatched to
    /// ([`crate::kernels::Kernel::selected_name`]): `"scalar"`,
    /// `"avx2"` or `"neon"` — `repro efficiency` prints it so perf rows
    /// say which path ran ("" on `Default`-built stats)
    pub kernel: &'static str,
}

impl StepStats {
    /// Fraction of total combine work the executor hid under expert
    /// compute (see [`PhaseNanos::combine_overlap_ratio`]).
    pub fn combine_overlap_ratio(&self) -> f64 {
        self.phases.combine_overlap_ratio()
    }

    /// Publish this step's telemetry into the unified registry
    /// ([`crate::obs::Registry`]): phase walls, dispatch counters,
    /// per-shard busy/idle, and the fault tally (under the same
    /// `fault_*` keys [`crate::coordinator::faults::FaultTally`] uses,
    /// so engine- and serve-side fault accounting aggregate into one
    /// series).  Counters accumulate — publishing every step of a run
    /// yields run totals.
    pub fn publish(&self, reg: &mut Registry) {
        self.phases.publish(reg);
        reg.counter_add("step_waves", self.waves as u64);
        reg.counter_add("step_network_bytes", self.network_bytes);
        reg.counter_add("step_rerouted_routes", self.rerouted_routes as u64);
        reg.counter_add("step_dropped_routes", self.dropped_routes as u64);
        reg.counter_add(
            "step_busiest_shard_tokens",
            self.busiest_shard_tokens as u64,
        );
        reg.counter_add(
            "step_combines_overlapped",
            self.combines_overlapped as u64,
        );
        for (i, (&busy, &idle)) in self
            .shard_compute_ns
            .iter()
            .zip(self.shard_idle_ns.iter())
            .enumerate()
        {
            let shard = i.to_string();
            reg.counter_add(
                &key("step_shard_compute_ns", &[("shard", &shard)]),
                busy,
            );
            reg.counter_add(
                &key("step_shard_idle_ns", &[("shard", &shard)]),
                idle,
            );
        }
        reg.counter_add("fault_failed_chunks", self.failed_chunks as u64);
        reg.counter_add(
            "fault_redispatched_routes",
            self.redispatched_routes as u64,
        );
        reg.counter_add("fault_degraded_tokens", self.degraded_tokens as u64);
        reg.gauge_add("fault_renorm_mass_lost", self.renorm_mass_lost);
    }
}

/// Waves needed for the given loads at `capacity` tokens per wave:
/// max over experts of ceil(load / capacity).
pub(crate) fn waves_for_loads(loads: &[usize], capacity: Option<usize>) -> usize {
    let cap = capacity.unwrap_or(usize::MAX).max(1);
    loads
        .iter()
        .map(|&l| if l == 0 { 0 } else { 1 + (l - 1) / cap })
        .max()
        .unwrap_or(0)
}

/// Assemble [`StepStats`] from a finished step's raw measurements.
pub(crate) fn build_stats(
    layout: &ShardLayout,
    plan: &DispatchPlan,
    d_model: usize,
    waves: usize,
    phases: PhaseNanos,
    shard_compute_ns: Vec<u64>,
    compute_wall_ns: u64,
) -> StepStats {
    let loads = plan.expert_loads();
    let mut shard_tokens = vec![0usize; layout.n_devices];
    for (e, &l) in loads.iter().enumerate() {
        shard_tokens[layout.owner(e)] += l;
    }
    let shard_idle_ns = shard_compute_ns
        .iter()
        .map(|&busy| compute_wall_ns.saturating_sub(busy))
        .collect();
    StepStats {
        busiest_shard_tokens: shard_tokens.iter().copied().max().unwrap_or(0),
        expert_loads: loads,
        waves,
        network_bytes: plan.network_bytes(d_model, layout),
        rerouted_routes: plan.rerouted_routes,
        dropped_routes: plan.dropped_routes,
        phases,
        shard_compute_ns,
        shard_idle_ns,
        // set by the engine paths that track per-replica completion
        combines_overlapped: 0,
        // set by the streaming path when a fault plan is active
        failed_chunks: 0,
        redispatched_routes: 0,
        degraded_tokens: 0,
        renorm_mass_lost: 0.0,
        kernel: crate::kernels::Kernel::selected_name(),
    }
}

pub struct Scheduler {
    // private: the engine below is keyed to this layout/backend pair,
    // so they must not change after the first step
    layout: ShardLayout,
    backend: ExpertBackend,
    /// wave-capacity policy handed to the engine when it starts
    policy: WavePolicy,
    /// GShard-style per-expert capacity buffer applied by the streaming
    /// dispatch (`None` = exact: every route kept)
    dispatch_capacity: Option<usize>,
    /// residual-target selection rule for over-capacity routes
    residual: ResidualPolicy,
    /// deterministic fault-injection schedule handed to the engine when
    /// it starts (`None` = no faults)
    fault_plan: Option<FaultPlan>,
    /// observability switches handed to the engine when it starts
    /// (defaults to the environment — `MOE_TRACE`)
    obs: ObsConfig,
    /// Persistent execution engine, started on first use and reused for
    /// every subsequent step (no per-step thread spawn).
    engine: Mutex<Option<ExecutionEngine>>,
}

impl Scheduler {
    pub fn new(layout: ShardLayout, backend: ExpertBackend) -> Self {
        Self::with_policy(layout, backend, WavePolicy::Fixed(None))
    }

    /// Like [`new`](Self::new) with an explicit Native wave-capacity
    /// policy (fixed cap or [`AdaptiveWave`]).
    pub fn with_policy(
        layout: ShardLayout,
        backend: ExpertBackend,
        policy: WavePolicy,
    ) -> Self {
        Scheduler {
            layout,
            backend,
            policy,
            dispatch_capacity: None,
            residual: ResidualPolicy::default(),
            fault_plan: None,
            obs: ObsConfig::from_env(),
            engine: Mutex::new(None),
        }
    }

    /// Bound every expert's per-step batch at `capacity` rows
    /// (GShard-style capacity-factor dispatch, see
    /// [`Dispatcher::capacity_for`]); overflow falls through to the
    /// token's other selected experts and is dropped only when all are
    /// full.  Must be set before the first step (the engine is keyed to
    /// it on start).
    pub fn with_dispatch_capacity(mut self, capacity: Option<usize>) -> Self {
        self.dispatch_capacity = capacity;
        self
    }

    /// Choose how over-capacity residual routes pick among a token's
    /// other selected experts (see [`ResidualPolicy`]).  Must be set
    /// before the first step.
    pub fn with_residual_policy(mut self, residual: ResidualPolicy) -> Self {
        self.residual = residual;
        self
    }

    /// Attach a deterministic fault-injection schedule (see
    /// [`FaultPlan`]); each streamed step advances the fault step
    /// counter.  Must be set before the first step (the engine is keyed
    /// to it on start).
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.fault_plan = plan;
        self
    }

    /// Set the observability switches explicitly (overriding the
    /// `MOE_TRACE` environment default).  Must be set before the first
    /// step — the engine spawns its workers with or without trace
    /// rings.
    pub fn with_obs(mut self, obs: ObsConfig) -> Self {
        self.obs = obs;
        self
    }

    /// Whether steps through this scheduler record trace spans.
    pub fn tracing_enabled(&self) -> bool {
        self.obs.tracing
    }

    /// Drain the spans recorded by completed steps, in drain order
    /// (empty when tracing is off or no traced step ran yet) — the feed
    /// for [`crate::obs::chrome_trace_json`].
    pub fn take_spans(&self) -> Vec<Span> {
        self.with_engine(|engine| Ok(engine.take_spans()))
            .unwrap_or_default()
    }

    /// Spans lost to full rings since the engine started (0 when
    /// tracing is off) — nonzero means [`ObsConfig::ring_capacity`] is
    /// too small for the step size.
    pub fn trace_dropped(&self) -> u64 {
        self.with_engine(|engine| Ok(engine.trace_dropped()))
            .unwrap_or(0)
    }

    /// Fraction of shards still live at the engine's current fault step
    /// (1.0 without a fault plan) — the serve loop's health signal.
    pub fn live_fraction(&self) -> f64 {
        self.with_engine(|engine| Ok(engine.live_fraction()))
            .unwrap_or(1.0)
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    pub fn backend(&self) -> &ExpertBackend {
        &self.backend
    }

    /// Resolve (starting on first use) the persistent engine under the
    /// lock and run `f` against it — the single engine-bootstrap path
    /// every entry point shares.  A poisoned lock means a previous step
    /// panicked mid-execute; the engine itself is safe to reuse (its
    /// drain guards restore the worker protocol on unwind), so recover
    /// instead of re-panicking.
    fn with_engine<T>(
        &self,
        f: impl FnOnce(&mut ExecutionEngine) -> Result<T>,
    ) -> Result<T> {
        let mut guard = self
            .engine
            .lock()
            .unwrap_or_else(|poison| poison.into_inner());
        let engine = guard.get_or_insert_with(|| {
            ExecutionEngine::with_policy_obs(
                self.layout.clone(),
                self.policy.clone(),
                self.obs.clone(),
            )
            .with_dispatch_capacity(self.dispatch_capacity)
            .with_residual_policy(self.residual)
            .with_fault_plan(self.fault_plan.clone())
        });
        f(engine)
    }

    /// Can the full step run as the engine's streaming pipeline?
    /// (Native-math router and Native expert backend.)  `pub(crate)` so
    /// [`crate::serve::ServeLoop`] can reject int8 configurations that
    /// would have no quantized path at construction time.
    pub(crate) fn streams_natively(&self, router: &Router) -> bool {
        (router.groups > 0 || matches!(router.backend, RouterBackend::Native))
            && matches!(self.backend, ExpertBackend::Native)
    }

    /// The serially-composed full step shared by the streamed/forward
    /// fallbacks: route on the coordinator, execute the finished plan on
    /// the engine, stamp the route wall into `stats.phases.route`.
    fn composed_step(
        &self,
        engine: &mut ExecutionEngine,
        router: &Router,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
        rng: Option<&mut crate::util::rng::Rng>,
    ) -> Result<StreamedStep> {
        let t0 = Instant::now();
        let (decisions, plan) = Dispatcher::route_and_plan(router, xs, rng)?;
        let route_ns = t0.elapsed().as_nanos() as u64;
        let (outs, mut stats) = match &self.backend {
            ExpertBackend::Native => engine.execute_native(&plan, xs, weights)?,
            ExpertBackend::Artifact { exe, capacity } => {
                engine.execute_artifact(&plan, xs, weights, exe, *capacity)?
            }
        };
        stats.phases.route = route_ns;
        Ok(StreamedStep { outs, decisions, plan, stats })
    }

    /// Execute the expert computation for a dispatch plan on the
    /// persistent engine.
    ///
    /// `xs[replica]`: (rows, d) activations per replica.
    /// `weights[e]`: weights of expert e.
    /// Returns (per-replica combined outputs, stats).
    pub fn execute(
        &self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.with_engine(|engine| match &self.backend {
            ExpertBackend::Native => engine.execute_native(plan, xs, weights),
            // The PJRT executable is not Send (the xla crate wraps the
            // client in an Rc), so artifact waves run from this thread;
            // the engine's persistent workers overlap next-wave gathers
            // with the in-flight PJRT call.
            ExpertBackend::Artifact { exe, capacity } => {
                engine.execute_artifact(plan, xs, weights, exe, *capacity)
            }
        })
    }

    /// Execute one *full* MoE step — gating, dispatch and expert
    /// execution — as a streaming pipeline on the persistent engine
    /// (see [`ExecutionEngine::execute_streaming`]): replica r+1 routes
    /// while replica r's experts compute, and the first expert wave is
    /// dispatched before the last token is gated.
    ///
    /// Requires Native expert and router backends; artifact-backed
    /// configurations fall back to the serially-composed
    /// route → plan → execute step (with the route wall recorded in
    /// `stats.phases.route`), so callers can use this entry point
    /// unconditionally.
    pub fn execute_streamed(
        &self,
        router: &Router,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
        rng: Option<&mut crate::util::rng::Rng>,
    ) -> Result<StreamedStep> {
        self.with_engine(|engine| {
            if self.streams_natively(router) {
                engine.execute_streaming(router, xs, weights, rng)
            } else {
                self.composed_step(engine, router, xs, weights, rng)
            }
        })
    }

    /// Forward-only (inference) full step: deterministic routing (no
    /// eq-4 noise) with none of the trainer-only bookkeeping — no
    /// per-token gate-vector copies, no importance/load merges, no
    /// retained [`DispatchPlan`]
    /// ([`ExecutionEngine::execute_streaming_forward`]).  This is the
    /// serving hot path: [`crate::serve::ServeLoop`] drives it batch
    /// after batch, reusing the engine's pooled arenas across steps.
    ///
    /// Artifact-backed configurations fall back to the serially-composed
    /// route → plan → execute step, exactly like
    /// [`execute_streamed`](Self::execute_streamed).
    pub fn execute_forward(
        &self,
        router: &Router,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.with_engine(|engine| {
            if self.streams_natively(router) {
                engine.execute_streaming_forward(router, xs, weights)
            } else {
                let s = self.composed_step(engine, router, xs, weights, None)?;
                Ok((s.outs, s.stats))
            }
        })
    }

    /// [`execute_forward`](Self::execute_forward) with int8-quantized
    /// expert weights ([`QuantizedExpertWeights`], quantized at load
    /// from the f32 checkpoint): the serving hot path under
    /// [`crate::kernels::quant::Precision::Int8`].  Outputs are
    /// error-budgeted against the f32 path over the same weights
    /// ([`crate::kernels::quant::SERVE_REL_ERR_BUDGET`]), not
    /// bit-identical.
    ///
    /// Int8 serving is streaming-only: there is no quantized composed
    /// or artifact fallback (those paths are f32 by design — training
    /// and checkpoints stay f32), so non-streamable configurations are
    /// an error rather than a silent f32 fallback.
    pub fn execute_forward_quant(
        &self,
        router: &Router,
        xs: &[&TensorF],
        qweights: &[QuantizedExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.with_engine(|engine| {
            if self.streams_natively(router) {
                engine.execute_streaming_forward_quant(router, xs, qweights)
            } else {
                Err(anyhow!(
                    "int8 serving requires Native router + expert backends \
                     (streaming path); this configuration would fall back \
                     to the f32 composed step"
                ))
            }
        })
    }

    /// Retained single-threaded reference path: gather, run each expert
    /// in index order, combine.  This is the oracle the differential
    /// tests compare the persistent engine against; it allocates per
    /// step and overlaps nothing on purpose.
    pub fn execute_serial(
        &self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let d_model = xs
            .first()
            .map(|t| t.shape[1])
            .ok_or_else(|| anyhow!("no replica inputs"))?;
        let n = plan.n_experts;
        let mut phases = PhaseNanos::default();
        let mut shard_compute = vec![0u64; self.layout.n_devices];
        let mut waves_max = 0usize;

        let mut expert_outputs = Vec::with_capacity(n);
        for e in 0..n {
            let t0 = Instant::now();
            let x = Dispatcher::gather(plan, e, xs);
            phases.gather += t0.elapsed().as_nanos() as u64;
            let t1 = Instant::now();
            let (y, waves) = run_expert(&self.backend, &weights[e], &x)?;
            shard_compute[self.layout.owner(e)] += t1.elapsed().as_nanos() as u64;
            waves_max = waves_max.max(waves);
            expert_outputs.push(y);
        }
        // experts run serialized here, so the compute critical path is
        // the sum of per-shard busy time and a shard's idle is its wait
        // on the other shards — gather/combine excluded, matching the
        // engine's artifact-path accounting
        let compute_serialized: u64 = shard_compute.iter().sum();
        phases.compute = compute_serialized;

        let t2 = Instant::now();
        let combined = Dispatcher::combine(plan, &expert_outputs, d_model);
        phases.combine = t2.elapsed().as_nanos() as u64;

        let stats = build_stats(
            &self.layout,
            plan,
            d_model,
            waves_max,
            phases,
            shard_compute,
            compute_serialized,
        );
        Ok((combined, stats))
    }
}

/// Run one expert over its (len, d) batch; returns (output, waves used).
pub(crate) fn run_expert(
    backend: &ExpertBackend,
    w: &ExpertWeights,
    x: &TensorF,
) -> Result<(TensorF, usize)> {
    let (len, d) = (x.shape[0], x.shape[1]);
    if len == 0 {
        return Ok((TensorF::zeros(vec![0, d]), 0));
    }
    match backend {
        ExpertBackend::Native => Ok((w.forward(x), 1)),
        ExpertBackend::Artifact { exe, capacity } => {
            let cap = (*capacity).max(1);
            let h = w.hidden;
            let w_in = Host::F32(TensorF::new(vec![d, h], w.w_in.clone()));
            let w_out = Host::F32(TensorF::new(vec![h, d], w.w_out.clone()));
            let mut out = Vec::with_capacity(len * d);
            let mut waves = 0usize;
            let mut start = 0usize;
            while start < len {
                let take = cap.min(len - start);
                let mut chunk = vec![0f32; cap * d];
                chunk[..take * d]
                    .copy_from_slice(&x.data[start * d..(start + take) * d]);
                let ys = exe.run(&[
                    w_in.clone(),
                    w_out.clone(),
                    Host::F32(TensorF::new(vec![cap, d], chunk)),
                ])?;
                let y = ys.into_iter().next().unwrap().into_f32()?;
                out.extend_from_slice(&y.data[..take * d]);
                start += take;
                waves += 1;
            }
            Ok((TensorF::new(vec![len, d], out), waves))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn shard_layout_partitions_all_experts() {
        prop::forall("layout partition", |rng| {
            let devices = prop::dim(rng, 1, 8);
            // deliberately allows the degenerate devices > experts case
            let experts = prop::dim(rng, 1, 64);
            let layout = ShardLayout::new(devices, experts);
            let mut covered = vec![false; experts];
            for d in 0..devices {
                for e in layout.experts_of(d) {
                    assert!(!covered[e], "expert {e} owned twice");
                    covered[e] = true;
                    assert_eq!(layout.owner(e), d);
                }
            }
            assert!(covered.iter().all(|&c| c));
        });
    }

    #[test]
    fn layout_is_balanced() {
        let layout = ShardLayout::new(4, 16);
        for d in 0..4 {
            assert_eq!(layout.experts_of(d).len(), 4);
        }
    }

    fn mk_weights(n: usize, d: usize, h: usize, rng: &mut Rng) -> Vec<ExpertWeights> {
        (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(rng, d * h, 0.3),
                w_out: prop::vec_f32(rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect()
    }

    #[test]
    fn native_moe_step_matches_single_threaded_reference() {
        let (d, h, n, k, rows) = (6, 10, 8, 2, 12);
        let mut rng = Rng::new(4);
        let weights = mk_weights(n, d, h, &mut rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..3)
            .map(|_| TensorF::new(vec![rows, d], prop::vec_f32(&mut rng, rows * d, 1.0)))
            .collect();
        let mut nrng = rng.fold_in(7);
        let decisions: Vec<_> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut nrng)).unwrap())
            .collect();
        let plan = Dispatcher::plan(&decisions, n);
        let refs: Vec<&TensorF> = xs.iter().collect();

        for devices in [1, 2, 4] {
            let sched = Scheduler::new(
                ShardLayout::new(devices, n),
                ExpertBackend::Native,
            );
            let (outs, stats) = sched.execute(&plan, &refs, &weights).unwrap();
            // reference: per token, sum gate * expert(x)
            for (ri, x) in xs.iter().enumerate() {
                for (row, tok) in decisions[ri].per_token.iter().enumerate() {
                    let mut want = vec![0f32; d];
                    for (e, g) in tok.experts.iter().zip(tok.weights.iter()) {
                        let xt = TensorF::new(vec![1, d], x.row(row).to_vec());
                        let y = weights[*e].forward(&xt);
                        for (w, v) in want.iter_mut().zip(y.data.iter()) {
                            *w += g * v;
                        }
                    }
                    for (a, b) in outs[ri].row(row).iter().zip(want.iter()) {
                        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
                    }
                }
            }
            assert_eq!(stats.expert_loads.iter().sum::<usize>(), 3 * rows * k);
            assert_eq!(stats.shard_compute_ns.len(), devices);
            assert_eq!(stats.shard_idle_ns.len(), devices);
        }
    }

    #[test]
    fn repeated_steps_reuse_the_engine() {
        // the persistent engine must give identical answers across many
        // steps through one Scheduler (arenas fully reset between steps)
        let (d, h, n, k, rows) = (5, 7, 6, 2, 9);
        let mut rng = Rng::new(12);
        let weights = mk_weights(n, d, h, &mut rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let sched = Scheduler::new(ShardLayout::new(3, n), ExpertBackend::Native);
        for step in 0..5 {
            let x = TensorF::new(
                vec![rows, d],
                prop::vec_f32(&mut rng, rows * d, 1.0),
            );
            let mut nrng = rng.fold_in(100 + step);
            let dec = router.route(&x, Some(&mut nrng)).unwrap();
            let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
            let (fast, _) = sched.execute(&plan, &[&x], &weights).unwrap();
            let (slow, _) = sched.execute_serial(&plan, &[&x], &weights).unwrap();
            for (a, b) in fast[0].data.iter().zip(slow[0].data.iter()) {
                assert!((a - b).abs() <= 1e-5, "step {step}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn execute_forward_matches_streamed_eval_routing() {
        // the serving entry point skips decision bookkeeping but must
        // produce bit-identical outputs to the trainer's streamed step
        // under the same (deterministic, noise-free) routing
        let (d, h, n, k, rows) = (6, 9, 5, 2, 14);
        let mut rng = Rng::new(21);
        let weights = mk_weights(n, d, h, &mut rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..2)
            .map(|_| {
                TensorF::new(vec![rows, d], prop::vec_f32(&mut rng, rows * d, 1.0))
            })
            .collect();
        let refs: Vec<&TensorF> = xs.iter().collect();
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let s = sched.execute_streamed(&router, &refs, &weights, None).unwrap();
        let (outs, stats) = sched.execute_forward(&router, &refs, &weights).unwrap();
        assert_eq!(outs.len(), s.outs.len());
        for (a, b) in outs.iter().zip(s.outs.iter()) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.data, b.data, "forward path must be bit-identical");
        }
        assert_eq!(stats.expert_loads, s.stats.expert_loads);
        assert!(!s.decisions.is_empty(), "trainer path keeps decisions");
    }

    #[test]
    fn empty_expert_batches_are_fine() {
        let (d, h, n) = (4, 6, 4);
        let mut rng = Rng::new(5);
        let weights = mk_weights(n, d, h, &mut rng);
        // route everything to expert 0
        let dec = crate::coordinator::router::RoutingDecision {
            per_token: vec![
                crate::gating::noisy_topk::GateVec {
                    experts: vec![0],
                    weights: vec![1.0],
                };
                5
            ],
            importance: vec![5.0, 0.0, 0.0, 0.0],
            load: vec![5.0, 0.0, 0.0, 0.0],
            noise: None,
        };
        let x = TensorF::new(vec![5, d], prop::vec_f32(&mut rng, 5 * d, 1.0));
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
        let sched = Scheduler::new(ShardLayout::new(2, n), ExpertBackend::Native);
        let (outs, stats) = sched.execute(&plan, &[&x], &weights).unwrap();
        assert_eq!(outs[0].shape, vec![5, d]);
        assert_eq!(stats.expert_loads, vec![5, 0, 0, 0]);
        assert_eq!(stats.busiest_shard_tokens, 5);
        assert_eq!(stats.waves, 1);
    }
}
