//! Scheduler: synchronous execution of a dispatched MoE step across
//! simulated devices.
//!
//! Each simulated device owns a contiguous slice of experts (the §3.1
//! model-parallel shard) and runs on its own OS thread.  Expert batches
//! longer than the artifact's static `capacity` are processed in waves —
//! tokens are never dropped, mirroring the paper's dynamically-sized
//! expert batches.  The step barrier is the thread join: like the paper's
//! synchronous training, the step takes as long as the busiest shard,
//! which is what the load-balancing losses exist to minimise.

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::dispatcher::{DispatchPlan, Dispatcher};
use crate::runtime::{Executable, Host, TensorF};

/// Which device owns which experts.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    pub n_devices: usize,
    pub n_experts: usize,
}

impl ShardLayout {
    pub fn new(n_devices: usize, n_experts: usize) -> Self {
        assert!(n_devices >= 1);
        ShardLayout { n_devices, n_experts }
    }

    pub fn owner(&self, expert: usize) -> usize {
        expert * self.n_devices / self.n_experts
    }

    pub fn experts_of(&self, device: usize) -> Vec<usize> {
        (0..self.n_experts)
            .filter(|&e| self.owner(e) == device)
            .collect()
    }
}

/// Per-expert weights sliced from the flat parameter vector:
/// (w_in (d,h) row-major, w_out (h,d) row-major).
#[derive(Clone)]
pub struct ExpertWeights {
    pub w_in: Vec<f32>,
    pub w_out: Vec<f32>,
    pub d_model: usize,
    pub hidden: usize,
}

impl ExpertWeights {
    /// Reference CPU forward (used by the Native backend and tests).
    pub fn forward(&self, x: &TensorF) -> TensorF {
        let (b, d, h) = (x.shape[0], self.d_model, self.hidden);
        let mut hid = vec![0f32; b * h];
        crate::gating::noisy_topk::matmul(&x.data, &self.w_in, &mut hid, b, d, h);
        for v in hid.iter_mut() {
            *v = v.max(0.0);
        }
        let mut out = vec![0f32; b * d];
        crate::gating::noisy_topk::matmul(&hid, &self.w_out, &mut out, b, h, d);
        TensorF::new(vec![b, d], out)
    }
}

pub enum ExpertBackend {
    /// AOT expert artifact with static (capacity, d) input — padded waves.
    Artifact { exe: Arc<Executable>, capacity: usize },
    /// Pure-rust forward (tests / configs without an expert artifact).
    Native,
}

pub struct Scheduler {
    pub layout: ShardLayout,
    pub backend: ExpertBackend,
}

/// Telemetry for one executed step.
#[derive(Clone, Debug, Default)]
pub struct StepStats {
    pub expert_loads: Vec<usize>,
    pub waves: usize,
    pub network_bytes: u64,
    pub busiest_shard_tokens: usize,
}

impl Scheduler {
    /// Execute the expert computation for a dispatch plan.
    ///
    /// `xs[replica]`: (rows, d) activations per replica.
    /// `weights[e]`: weights of expert e.
    /// Returns (per-replica combined outputs, stats).
    pub fn execute(
        &self,
        plan: &DispatchPlan,
        xs: &[&TensorF],
        weights: &[ExpertWeights],
    ) -> Result<(Vec<TensorF>, StepStats)> {
        let d_model = xs
            .first()
            .map(|t| t.shape[1])
            .ok_or_else(|| anyhow!("no replica inputs"))?;
        let n = plan.n_experts;
        let mut expert_inputs: Vec<TensorF> = (0..n)
            .map(|e| Dispatcher::gather(plan, e, xs))
            .collect();

        // group expert inputs by owning device
        let mut per_device: Vec<Vec<(usize, TensorF)>> =
            (0..self.layout.n_devices).map(|_| Vec::new()).collect();
        for (e, t) in expert_inputs.drain(..).enumerate() {
            per_device[self.layout.owner(e)].push((e, t));
        }
        let mut outputs: Vec<Option<TensorF>> = vec![None; n];
        let mut waves_total = 0usize;
        match &self.backend {
            // The PJRT executable is not Send (the xla crate wraps the
            // client in an Rc), so artifact-backed shards execute
            // sequentially from the coordinator thread — the PJRT CPU
            // client is itself a thread pool, so expert GEMMs still use
            // all cores.  The per-device decomposition is preserved for
            // the timing model.
            ExpertBackend::Artifact { .. } => {
                for batch in per_device {
                    for (e, x) in batch {
                        let (y, w) =
                            run_expert(&self.backend, &weights[e], &x)?;
                        waves_total += w;
                        outputs[e] = Some(y);
                    }
                }
            }
            // Native shards genuinely run one OS thread per device.
            ExpertBackend::Native => {
                std::thread::scope(|scope| -> Result<()> {
                    let mut handles = Vec::new();
                    for batch in per_device {
                        let weights = &weights;
                        handles.push(scope.spawn(move || {
                            let mut outs = Vec::new();
                            for (e, x) in batch {
                                outs.push((e, weights[e].forward(&x)));
                            }
                            outs
                        }));
                    }
                    for h in handles {
                        let outs = h
                            .join()
                            .map_err(|_| anyhow!("expert shard panicked"))?;
                        for (e, y) in outs {
                            waves_total += 1;
                            outputs[e] = Some(y);
                        }
                    }
                    Ok(())
                })?;
            }
        }

        let expert_outputs: Vec<TensorF> = outputs
            .into_iter()
            .enumerate()
            .map(|(e, o)| o.ok_or_else(|| anyhow!("expert {e} missing output")))
            .collect::<Result<_>>()?;
        let combined = Dispatcher::combine(plan, &expert_outputs, d_model);

        let loads = plan.expert_loads();
        let mut shard_tokens = vec![0usize; self.layout.n_devices];
        for (e, &l) in loads.iter().enumerate() {
            shard_tokens[self.layout.owner(e)] += l;
        }
        let stats = StepStats {
            busiest_shard_tokens: shard_tokens.iter().copied().max().unwrap_or(0),
            expert_loads: loads,
            waves: waves_total,
            network_bytes: plan.network_bytes(d_model),
        };
        Ok((combined, stats))
    }
}

/// Run one expert over its (len, d) batch; returns (output, waves used).
fn run_expert(
    backend: &ExpertBackend,
    w: &ExpertWeights,
    x: &TensorF,
) -> Result<(TensorF, usize)> {
    let (len, d) = (x.shape[0], x.shape[1]);
    match backend {
        ExpertBackend::Native => Ok((w.forward(x), 1)),
        ExpertBackend::Artifact { exe, capacity } => {
            let cap = *capacity;
            let h = w.hidden;
            let w_in = Host::F32(TensorF::new(vec![d, h], w.w_in.clone()));
            let w_out = Host::F32(TensorF::new(vec![h, d], w.w_out.clone()));
            let mut out = Vec::with_capacity(len * d);
            let mut waves = 0usize;
            let mut start = 0usize;
            while start < len || (len == 0 && waves == 0) {
                let take = cap.min(len - start);
                let mut chunk = vec![0f32; cap * d];
                chunk[..take * d]
                    .copy_from_slice(&x.data[start * d..(start + take) * d]);
                let ys = exe.run(&[
                    w_in.clone(),
                    w_out.clone(),
                    Host::F32(TensorF::new(vec![cap, d], chunk)),
                ])?;
                let y = ys.into_iter().next().unwrap().into_f32()?;
                out.extend_from_slice(&y.data[..take * d]);
                start += take;
                waves += 1;
                if len == 0 {
                    break;
                }
            }
            if len == 0 {
                return Ok((TensorF::zeros(vec![0, d]), 0));
            }
            Ok((TensorF::new(vec![len, d], out), waves))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::Router;
    use crate::util::{prop, rng::Rng};

    #[test]
    fn shard_layout_partitions_all_experts() {
        prop::forall("layout partition", |rng| {
            let devices = prop::dim(rng, 1, 8);
            let experts = prop::dim(rng, devices, 64);
            let layout = ShardLayout::new(devices, experts);
            let mut covered = vec![false; experts];
            for d in 0..devices {
                for e in layout.experts_of(d) {
                    assert!(!covered[e], "expert {e} owned twice");
                    covered[e] = true;
                    assert_eq!(layout.owner(e), d);
                }
            }
            assert!(covered.iter().all(|&c| c));
        });
    }

    #[test]
    fn layout_is_balanced() {
        let layout = ShardLayout::new(4, 16);
        for d in 0..4 {
            assert_eq!(layout.experts_of(d).len(), 4);
        }
    }

    fn mk_weights(n: usize, d: usize, h: usize, rng: &mut Rng) -> Vec<ExpertWeights> {
        (0..n)
            .map(|_| ExpertWeights {
                w_in: prop::vec_f32(rng, d * h, 0.3),
                w_out: prop::vec_f32(rng, h * d, 0.3),
                d_model: d,
                hidden: h,
            })
            .collect()
    }

    #[test]
    fn native_moe_step_matches_single_threaded_reference() {
        let (d, h, n, k, rows) = (6, 10, 8, 2, 12);
        let mut rng = Rng::new(4);
        let weights = mk_weights(n, d, h, &mut rng);
        let router = Router::flat_native(
            d, n, k,
            prop::vec_f32(&mut rng, d * n, 0.5),
            Some(prop::vec_f32(&mut rng, d * n, 0.3)),
        );
        let xs: Vec<TensorF> = (0..3)
            .map(|_| TensorF::new(vec![rows, d], prop::vec_f32(&mut rng, rows * d, 1.0)))
            .collect();
        let mut nrng = rng.fold_in(7);
        let decisions: Vec<_> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut nrng)).unwrap())
            .collect();
        let plan = Dispatcher::plan(&decisions, n);
        let refs: Vec<&TensorF> = xs.iter().collect();

        for devices in [1, 2, 4] {
            let sched = Scheduler {
                layout: ShardLayout::new(devices, n),
                backend: ExpertBackend::Native,
            };
            let (outs, stats) = sched.execute(&plan, &refs, &weights).unwrap();
            // reference: per token, sum gate * expert(x)
            for (ri, x) in xs.iter().enumerate() {
                for (row, tok) in decisions[ri].per_token.iter().enumerate() {
                    let mut want = vec![0f32; d];
                    for (e, g) in tok.experts.iter().zip(tok.weights.iter()) {
                        let xt = TensorF::new(vec![1, d], x.row(row).to_vec());
                        let y = weights[*e].forward(&xt);
                        for (w, v) in want.iter_mut().zip(y.data.iter()) {
                            *w += g * v;
                        }
                    }
                    for (a, b) in outs[ri].row(row).iter().zip(want.iter()) {
                        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
                    }
                }
            }
            assert_eq!(stats.expert_loads.iter().sum::<usize>(), 3 * rows * k);
        }
    }

    #[test]
    fn empty_expert_batches_are_fine() {
        let (d, h, n) = (4, 6, 4);
        let mut rng = Rng::new(5);
        let weights = mk_weights(n, d, h, &mut rng);
        // route everything to expert 0
        let dec = crate::coordinator::router::RoutingDecision {
            per_token: vec![
                crate::gating::noisy_topk::GateVec {
                    experts: vec![0],
                    weights: vec![1.0],
                };
                5
            ],
            importance: vec![5.0, 0.0, 0.0, 0.0],
            load: vec![5.0, 0.0, 0.0, 0.0],
        };
        let x = TensorF::new(vec![5, d], prop::vec_f32(&mut rng, 5 * d, 1.0));
        let plan = Dispatcher::plan(std::slice::from_ref(&dec), n);
        let sched = Scheduler {
            layout: ShardLayout::new(2, n),
            backend: ExpertBackend::Native,
        };
        let (outs, stats) = sched.execute(&plan, &[&x], &weights).unwrap();
        assert_eq!(outs[0].shape, vec![5, d]);
        assert_eq!(stats.expert_loads, vec![5, 0, 0, 0]);
        assert_eq!(stats.busiest_shard_tokens, 5);
    }
}
