//! GShard-style cluster scaling simulation: drive the REAL engine —
//! hierarchical O(group) local-group routing, streaming dispatch with
//! capacity-factor buffers — and price the *measured* dispatch plan
//! against the multi-host [`Topology`] model.
//!
//! This is the 64 → 4096-expert scaling study the ROADMAP's
//! cluster-scale item asks for, feeding `benches/cluster.rs`
//! (`BENCH_cluster.json`), `repro cluster` and the quickstart.  One
//! simulated device hosts 16 experts (the paper's ratio at its largest
//! configurations), 8 devices share a host's PCIe complex, and hosts
//! talk over a far slower fabric — so the curves show exactly the §3.2
//! story: the all-to-all is nearly free while the model fits one host,
//! then inter-host bytes take over the step.
//!
//! Network bytes here use the *corrected* accounting
//! ([`DispatchPlan::network_bytes`]): only routes whose expert lives on
//! a different device than the token's replica count; same-shard
//! dispatches are tallied as `local_bytes` and priced at zero.

use anyhow::Result;

use crate::cluster::perf::DeviceSpec;
use crate::cluster::topology::{model_cluster_step, ClusterStepTiming, Topology};
use crate::coordinator::engine::StreamedStep;
use crate::coordinator::router::{Router, RouterBackend};
use crate::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout, WavePolicy,
};
use crate::coordinator::{DispatchPlan, Dispatcher};
use crate::runtime::TensorF;
use crate::util::rng::Rng;

/// Experts per simulated device and devices per host — fixed across the
/// ladder so the device count grows with the expert count.
pub const EXPERTS_PER_DEVICE: usize = 16;
pub const DEVICES_PER_HOST: usize = 8;

/// The expert-count ladder the scaling study sweeps.
pub fn scaling_ladder() -> [usize; 4] {
    [64, 256, 1024, 4096]
}

/// One simulated cluster configuration, holding a real engine sized to
/// the coordinator host plus the (much larger) simulated layout and
/// topology the measured plan is priced against.
pub struct ClusterSim {
    pub n_experts: usize,
    pub groups: usize,
    pub group_size: usize,
    pub d_model: usize,
    pub hidden: usize,
    /// primary/secondary top-k; each token routes k² experts
    pub k: usize,
    pub sim_devices: usize,
    pub rows_per_replica: usize,
    /// `None` = exact dispatch; `Some(cf)` = GShard capacity factor
    pub capacity_factor: Option<f64>,
    /// the per-expert buffer derived from `capacity_factor`
    pub capacity: Option<usize>,
    pub seed: u64,
    pub sim_layout: ShardLayout,
    pub topo: Topology,
    device: DeviceSpec,
    router: Router,
    weights: Vec<ExpertWeights>,
    xs: Vec<TensorF>,
    sched: Scheduler,
}

/// One priced point of the scaling curve.
#[derive(Clone, Debug)]
pub struct ClusterPoint {
    pub n_experts: usize,
    pub groups: usize,
    pub sim_devices: usize,
    pub n_hosts: usize,
    pub tokens: usize,
    /// 0.0 encodes exact (uncapped) dispatch
    pub capacity_factor: f64,
    pub capacity: usize,
    pub offered_routes: usize,
    pub kept_routes: usize,
    pub dropped_routes: usize,
    pub rerouted_routes: usize,
    pub drop_fraction: f64,
    /// corrected §3.2 interconnect bytes (inter-device routes only)
    pub interconnect_bytes: u64,
    pub intra_host_bytes: u64,
    pub inter_host_bytes: u64,
    /// bytes that never left their device (previously over-counted)
    pub local_bytes: u64,
    pub messages: u64,
    pub timing: ClusterStepTiming,
    /// wall time of the real engine step on the coordinator host
    pub measured_step_ns: u64,
}

impl ClusterPoint {
    /// Modelled cluster throughput at this point.
    pub fn tokens_per_sec(&self) -> f64 {
        self.tokens as f64 / self.timing.total().max(1e-12)
    }

    /// Publish the point's traffic and routing tallies into the unified
    /// registry: per-link bytes under one labelled family
    /// (`cluster_link_bytes{link=...}`) so a Prometheus scrape of the
    /// scaling study sums/splits the §3.2 story the same way the table
    /// renders it, plus the capacity-drop counters the step executor
    /// also publishes (`step_dropped_routes` / `step_rerouted_routes`).
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        use crate::obs::key;
        for (link, bytes) in [
            ("intra_host", self.intra_host_bytes),
            ("inter_host", self.inter_host_bytes),
            ("local", self.local_bytes),
        ] {
            reg.counter_add(
                &key("cluster_link_bytes", &[("link", link)]),
                bytes,
            );
        }
        reg.counter_add("cluster_messages", self.messages);
        reg.counter_add("step_network_bytes", self.interconnect_bytes);
        reg.counter_add("step_dropped_routes", self.dropped_routes as u64);
        reg.counter_add("step_rerouted_routes", self.rerouted_routes as u64);
        reg.gauge_set("cluster_tokens_per_sec", self.tokens_per_sec());
    }
}

impl ClusterSim {
    /// Build a point of the ladder: `n_experts` must be a square (the
    /// hierarchical gate uses `√n` groups of `√n` experts, Appendix B's
    /// O(√n)-per-level routing) with at least [`EXPERTS_PER_DEVICE`]
    /// experts.  One replica per simulated device, `rows_per_replica`
    /// tokens each.
    pub fn build(
        n_experts: usize,
        rows_per_replica: usize,
        capacity_factor: Option<f64>,
        seed: u64,
    ) -> Result<Self> {
        let groups = (n_experts as f64).sqrt().round() as usize;
        anyhow::ensure!(
            groups * groups == n_experts,
            "cluster sim wants a square expert count, got {n_experts}"
        );
        let group_size = n_experts / groups;
        let (d, h, k) = (16usize, 32usize, 2usize);
        let sim_devices = (n_experts / EXPERTS_PER_DEVICE).max(1);
        let replicas = sim_devices;
        let tokens = replicas * rows_per_replica;
        let k_eff = k * k;
        let capacity = capacity_factor.map(|cf| {
            Dispatcher::capacity_for(cf, tokens, k_eff, n_experts)
        });

        let mut rng = Rng::new(seed);
        let weights: Vec<ExpertWeights> = (0..n_experts)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router {
            backend: RouterBackend::Native,
            n_experts,
            k,
            groups,
            d_model: d,
            w_g: (0..d * groups).map(|_| rng.normal_f32() * 0.4).collect(),
            w_noise: Some(
                (0..d * groups).map(|_| rng.normal_f32() * 0.3).collect(),
            ),
            w_g_sec: Some(
                (0..d * n_experts).map(|_| rng.normal_f32() * 0.4).collect(),
            ),
            w_n_sec: Some(
                (0..d * n_experts).map(|_| rng.normal_f32() * 0.3).collect(),
            ),
        };
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                TensorF::new(
                    vec![rows_per_replica, d],
                    (0..rows_per_replica * d)
                        .map(|_| rng.normal_f32())
                        .collect(),
                )
            })
            .collect();

        // the real engine runs on the coordinator host: a worker per
        // core-ish shard, while traffic is priced on the simulated
        // cluster layout below
        let exec_devices = sim_devices.min(8);
        let sched = Scheduler::with_policy(
            ShardLayout::new(exec_devices, n_experts),
            ExpertBackend::Native,
            WavePolicy::Fixed(Some(256)),
        )
        .with_dispatch_capacity(capacity);

        Ok(ClusterSim {
            n_experts,
            groups,
            group_size,
            d_model: d,
            hidden: h,
            k,
            sim_devices,
            rows_per_replica,
            capacity_factor,
            capacity,
            seed,
            sim_layout: ShardLayout::new(sim_devices, n_experts),
            topo: Topology::k40_hosts(sim_devices, DEVICES_PER_HOST),
            device: DeviceSpec::k40(),
            router,
            weights,
            xs,
            sched,
        })
    }

    pub fn tokens(&self) -> usize {
        self.xs.iter().map(|x| x.shape[0]).sum()
    }

    /// One streamed step on the real engine (seeded eq-4 noise; `fold`
    /// varies the draw across bench iterations deterministically).
    pub fn step(&self, fold: u64) -> Result<StreamedStep> {
        let refs: Vec<&TensorF> = self.xs.iter().collect();
        let mut nrng = Rng::new(self.seed).fold_in(fold);
        self.sched.execute_streamed(
            &self.router,
            &refs,
            &self.weights,
            Some(&mut nrng),
        )
    }

    /// Price a finished step's plan on the simulated cluster.
    pub fn price(&self, plan: &DispatchPlan, measured_step_ns: u64)
        -> ClusterPoint {
        let traffic =
            plan.network_bytes_by_link(self.d_model, &self.sim_layout);
        // two-level gate: primary over `groups` columns, then k
        // secondary slices of `group_size` columns — O(√n) each, vs the
        // flat gate's O(n)
        let gate_cols = self.groups + self.k * self.group_size;
        let timing = model_cluster_step(
            &self.device,
            &self.topo,
            &self.sim_layout,
            self.d_model,
            self.hidden,
            gate_cols,
            self.rows_per_replica,
            &plan.expert_loads(),
            &traffic,
        );
        ClusterPoint {
            n_experts: self.n_experts,
            groups: self.groups,
            sim_devices: self.sim_devices,
            n_hosts: self.topo.n_hosts(),
            tokens: self.tokens(),
            capacity_factor: self.capacity_factor.unwrap_or(0.0),
            capacity: self.capacity.unwrap_or(0),
            offered_routes: plan.offered_routes(),
            kept_routes: plan.total_routes(),
            dropped_routes: plan.dropped_routes,
            rerouted_routes: plan.rerouted_routes,
            drop_fraction: plan.drop_fraction(),
            interconnect_bytes: traffic.interconnect_bytes(),
            intra_host_bytes: timing.a2a.intra_bytes,
            inter_host_bytes: timing.a2a.inter_bytes,
            local_bytes: traffic.local_bytes,
            messages: traffic.total_messages(),
            timing,
            measured_step_ns,
        }
    }

    /// Run one step and price it.
    pub fn point(&self) -> Result<ClusterPoint> {
        let t0 = std::time::Instant::now();
        let s = self.step(1)?;
        let ns = t0.elapsed().as_nanos() as u64;
        Ok(self.price(&s.plan, ns))
    }
}

/// One formatted row of the scaling table (shared by `repro cluster`
/// and the quickstart).
pub fn point_line(p: &ClusterPoint) -> String {
    let cf = if p.capacity_factor == 0.0 {
        "exact".to_string()
    } else {
        format!("cf={:.2}", p.capacity_factor)
    };
    format!(
        "n={:<5} dev={:<4} hosts={:<3} {:<8} drop={:>5.1}%  \
         net={:>10}B (intra {:>10}B | inter {:>10}B | local {:>10}B)  \
         step={:>8.3}ms  {:>9.0} tok/s",
        p.n_experts,
        p.sim_devices,
        p.n_hosts,
        cf,
        p.drop_fraction * 100.0,
        p.interconnect_bytes,
        p.intra_host_bytes,
        p.inter_host_bytes,
        p.local_bytes,
        p.timing.total() * 1e3,
        p.tokens_per_sec(),
    )
}

/// The 64 → 4096 scaling study: every ladder rung at every requested
/// capacity factor (`None` = exact), printed as a table and returned
/// for further rendering.
pub fn run_scaling_study(
    rows_per_replica: usize,
    factors: &[Option<f64>],
    seed: u64,
) -> Result<Vec<ClusterPoint>> {
    let mut points = Vec::new();
    println!(
        "cluster scaling study ({EXPERTS_PER_DEVICE} experts/device, \
         {DEVICES_PER_HOST} devices/host, corrected §3.2 traffic):"
    );
    for &cf in factors {
        for n in scaling_ladder() {
            let sim = ClusterSim::build(n, rows_per_replica, cf, seed)?;
            let p = sim.point()?;
            println!("  {}", point_line(&p));
            points.push(p);
        }
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smallest_rung_prices_sanely() {
        let sim = ClusterSim::build(64, 4, None, 7).unwrap();
        assert_eq!(sim.sim_devices, 4);
        assert_eq!(sim.groups, 8);
        let p = sim.point().unwrap();
        assert_eq!(p.tokens, 16);
        assert_eq!(p.offered_routes, 16 * 4, "k²=4 routes per token");
        assert_eq!(p.dropped_routes, 0, "exact dispatch drops nothing");
        assert_eq!(p.drop_fraction, 0.0);
        assert!(p.timing.total().is_finite() && p.timing.total() > 0.0);
        // conservation: every route's in+out bytes are either on a link
        // or local
        assert_eq!(
            p.interconnect_bytes + p.local_bytes,
            (p.kept_routes * sim.d_model * 4 * 2) as u64
        );
        // 4 devices on one host: nothing crosses the fabric
        assert_eq!(p.n_hosts, 1);
        assert_eq!(p.inter_host_bytes, 0);
        // the registry view splits the same bytes by link label
        let mut reg = crate::obs::Registry::new();
        p.publish(&mut reg);
        let s = reg.snapshot();
        let link = |l: &str| {
            s.counter(&crate::obs::key("cluster_link_bytes", &[("link", l)]))
        };
        assert_eq!(link("intra_host"), p.intra_host_bytes);
        assert_eq!(link("inter_host"), 0);
        assert_eq!(link("local"), p.local_bytes);
        assert_eq!(
            link("intra_host") + link("inter_host"),
            s.counter("step_network_bytes"),
            "per-link split must sum to the corrected interconnect total"
        );
        assert!(s.gauge("cluster_tokens_per_sec") > 0.0);
    }

    #[test]
    fn capacity_factor_bounds_every_buffer() {
        let sim = ClusterSim::build(64, 6, Some(1.0), 11).unwrap();
        let cap = sim.capacity.unwrap();
        let s = sim.step(1).unwrap();
        for load in s.plan.expert_loads() {
            assert!(load <= cap, "load {load} over capacity {cap}");
        }
        let p = sim.price(&s.plan, 0);
        assert!(p.drop_fraction >= 0.0 && p.drop_fraction <= 1.0);
        assert_eq!(p.kept_routes + p.dropped_routes, p.offered_routes);
    }
}
