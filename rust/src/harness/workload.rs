//! Synthetic Native-backend MoE workloads, shared by the measured
//! efficiency report and the bench targets so the expert/router/plan
//! construction lives in exactly one place — plus the **open-loop
//! traffic generator** for the serving runtime: seeded Poisson
//! arrivals with ragged request lengths and an optional bursty mode
//! ([`poisson_trace`]), materialised into serve-ready requests by
//! [`trace_requests`], and the shared latency-vs-offered-load report
//! ([`serve_load_curve`]) behind `examples/serve_demo.rs` and
//! `repro serve`.

use anyhow::Result;

use crate::coordinator::engine::StreamedStep;
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout, StepStats,
};
use crate::coordinator::{DispatchPlan, Dispatcher};
use crate::kernels::quant::Precision;
use crate::runtime::TensorF;
use crate::serve::{
    AdmissionPolicy, DrainPolicy, EngineBackend, ServeBackend, ServeConfig,
    ServeLoop, ServeStats, TenantRequest, TenantServeConfig, TenantServeLoop,
    TenantServeReport, TenantSpec, TimedRequest,
};
use crate::util::rng::Rng;

/// A fully routed synthetic MoE step: expert weights, gating router,
/// per-replica activations and the resulting dispatch plan.
pub struct SyntheticMoe {
    pub d_model: usize,
    pub hidden: usize,
    pub n_experts: usize,
    pub k: usize,
    pub weights: Vec<ExpertWeights>,
    pub router: Router,
    pub xs: Vec<TensorF>,
    pub plan: DispatchPlan,
}

impl SyntheticMoe {
    /// Build `replicas` activations of `rows` tokens each, noisy-top-k
    /// routed over `n` experts, from a deterministic seed.
    pub fn build(
        seed: u64,
        d: usize,
        h: usize,
        n: usize,
        k: usize,
        replicas: usize,
        rows: usize,
    ) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d,
            n,
            k,
            (0..d * n).map(|_| rng.normal_f32() * 0.4).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.4).collect()),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                TensorF::new(
                    vec![rows, d],
                    (0..rows * d).map(|_| rng.normal_f32()).collect(),
                )
            })
            .collect();
        let mut nrng = rng.fold_in(1);
        let decisions: Vec<_> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut nrng)))
            .collect::<Result<_>>()?;
        let plan = Dispatcher::plan(&decisions, n);
        Ok(SyntheticMoe {
            d_model: d,
            hidden: h,
            n_experts: n,
            k,
            weights,
            router,
            xs,
            plan,
        })
    }

    /// Borrowed replica activations in `Scheduler::execute` form.
    pub fn refs(&self) -> Vec<&TensorF> {
        self.xs.iter().collect()
    }

    pub fn tokens(&self) -> usize {
        self.xs.iter().map(|x| x.shape[0]).sum()
    }

    /// One PR-1-shaped step: route every replica serially on the
    /// caller's thread, build the plan, then execute on the persistent
    /// engine — route, dispatch and execute composed back-to-back.  The
    /// route+plan wall lands in `stats.phases.route` so the result is
    /// directly comparable with [`run_streamed`](Self::run_streamed).
    pub fn run_unpipelined(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.run_composed(rng, |plan, refs, weights| {
            sched.execute(plan, refs, weights)
        })
    }

    /// The serially-composed step on the single-threaded reference path
    /// (route → plan → [`Scheduler::execute_serial`]), with the route
    /// wall stamped into `stats.phases.route` — the full-step oracle
    /// row for reports and benches.
    pub fn run_serial_reference(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.run_composed(rng, |plan, refs, weights| {
            sched.execute_serial(plan, refs, weights)
        })
    }

    /// Shared route→plan→execute composition: time the serial routing,
    /// run `exec`, stamp the route wall into `stats.phases.route`.
    fn run_composed<F>(
        &self,
        rng: Option<&mut Rng>,
        exec: F,
    ) -> Result<(Vec<TensorF>, StepStats)>
    where
        F: FnOnce(
            &DispatchPlan,
            &[&TensorF],
            &[ExpertWeights],
        ) -> Result<(Vec<TensorF>, StepStats)>,
    {
        let refs = self.refs();
        let t0 = std::time::Instant::now();
        let (_decisions, plan) =
            Dispatcher::route_and_plan(&self.router, &refs, rng)?;
        let route_ns = t0.elapsed().as_nanos() as u64;
        let (outs, mut stats) = exec(&plan, &refs, &self.weights)?;
        stats.phases.route = route_ns;
        Ok((outs, stats))
    }

    /// The same full step as a streaming routing→dispatch pipeline on
    /// the engine ([`Scheduler::execute_streamed`]).
    pub fn run_streamed(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<StreamedStep> {
        let refs = self.refs();
        sched.execute_streamed(&self.router, &refs, &self.weights, rng)
    }
}

/// The route/gather/compute/combine fragment shared by every phase
/// report ([`phase_line`], [`serve_phase_line`]) so the rendering lives
/// in exactly one place.  Reads the `step_phase_ns{phase=...}` counters
/// `PhaseNanos::publish` writes, so any registry snapshot — one step's
/// or a whole run's — renders the same way.  `combine` is the
/// critical-path tail; the parenthesised hidden time is combine work
/// the executor ran under expert compute (`overlap` = fraction of
/// combine hidden).
fn phase_fragment(s: &crate::obs::Snapshot) -> String {
    let phase =
        |p: &str| s.counter(&crate::obs::key("step_phase_ns", &[("phase", p)]));
    let (combine, hidden) = (phase("combine"), phase("overlap_hidden"));
    let overlap_pct = if hidden + combine == 0 {
        0.0
    } else {
        hidden as f64 / (hidden + combine) as f64 * 100.0
    };
    format!(
        "route {:.3}ms  gather {:.3}ms  compute {:.3}ms  combine {:.3}ms \
         (+{:.3}ms hidden, overlap {overlap_pct:.0}%)",
        phase("route") as f64 / 1e6,
        phase("gather") as f64 / 1e6,
        phase("compute") as f64 / 1e6,
        combine as f64 / 1e6,
        hidden as f64 / 1e6,
    )
}

/// Max per-shard idle out of the `step_shard_idle_ns{shard=...}`
/// counters of a snapshot (0 when no shard published).
fn max_shard_idle_ns(s: &crate::obs::Snapshot) -> u64 {
    s.counters
        .iter()
        .filter(|(k, _)| k.starts_with("step_shard_idle_ns{"))
        .map(|&(_, v)| v)
        .max()
        .unwrap_or(0)
}

/// One-line rendering of a step's per-phase breakdown (benches,
/// efficiency report, quickstart — all through here).  A renderer over
/// the unified registry: publishes `stats` into a fresh registry and
/// formats via [`render_phase_line`].
pub fn phase_line(stats: &StepStats) -> String {
    let mut reg = crate::obs::Registry::new();
    stats.publish(&mut reg);
    render_phase_line(&reg.snapshot())
}

/// Format the step-phase report from a registry snapshot (the `step_*`
/// keys `StepStats::publish` writes).
pub fn render_phase_line(s: &crate::obs::Snapshot) -> String {
    format!(
        "{}  waves={}  busiest_shard={} tok  max shard idle {:.3}ms",
        phase_fragment(s),
        s.counter("step_waves"),
        s.counter("step_busiest_shard_tokens"),
        max_shard_idle_ns(s) as f64 / 1e6,
    )
}

/// The serving variant of [`phase_line`]: the same phase fragment
/// (summed over every dispatched batch) prefixed with the queue-wait
/// column the serve path adds in front of the engine, plus batching
/// telemetry.  Publishes into a fresh registry and formats via
/// [`render_serve_phase_line`].
pub fn serve_phase_line(stats: &crate::serve::ServeStats) -> String {
    let mut reg = crate::obs::Registry::new();
    stats.publish(&mut reg);
    render_serve_phase_line(&reg.snapshot())
}

/// Format the serve-phase report from a registry snapshot (the keys
/// `ServeStats::publish` writes).
pub fn render_serve_phase_line(s: &crate::obs::Snapshot) -> String {
    let queue_p50 = s
        .hist("serve_queue_wait_ns")
        .map(|h| h.p50_ns)
        .unwrap_or(0);
    let cap = s.counter("serve_batch_capacity");
    let occupancy = if cap == 0 {
        0.0
    } else {
        s.counter("serve_batch_tokens") as f64 / cap as f64
    };
    format!(
        "queue p50 {:.3}ms  {}  batches={}  occupancy {:.0}%",
        queue_p50 as f64 / 1e6,
        phase_fragment(s),
        s.counter("serve_batches"),
        occupancy * 100.0,
    )
}

/// Open-loop traffic spec for the serving harness.  Requests arrive by
/// a Poisson process at `rate_per_sec` with lengths uniform in
/// `[min_rows, max_rows]`; `bursty` modulates the rate ×4 / ÷4 in
/// alternating 16-request epochs (mean rate roughly preserved, arrival
/// clumping very much not).  Fully determined by `seed` — no
/// wall-clock anywhere, so identical seeds give identical traces.
#[derive(Clone, Debug)]
pub struct TraceSpec {
    pub seed: u64,
    pub rate_per_sec: f64,
    pub n_requests: usize,
    pub min_rows: usize,
    pub max_rows: usize,
    pub bursty: bool,
}

/// One generated arrival: when (ns on the serve clock) and how long.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RequestSpec {
    pub arrival_ns: u64,
    pub rows: usize,
}

/// Generate an arrival trace from the spec (module docs): exponential
/// inter-arrival gaps via inverse-transform sampling on the shared
/// deterministic [`Rng`].
pub fn poisson_trace(spec: &TraceSpec) -> Vec<RequestSpec> {
    let mut rng = Rng::new(spec.seed);
    let lo = spec.min_rows.max(1);
    let hi = spec.max_rows.max(lo);
    let base_rate = spec.rate_per_sec.max(1e-9);
    let mut t_secs = 0f64;
    (0..spec.n_requests)
        .map(|i| {
            let rate = if spec.bursty {
                // alternating hot/cold epochs; ×4 then ÷4
                base_rate * if (i / 16) % 2 == 0 { 4.0 } else { 0.25 }
            } else {
                base_rate
            };
            // u in [0,1) so 1-u in (0,1]: ln is finite, gap >= 0
            let u = rng.uniform();
            t_secs += -(1.0 - u).ln() / rate;
            RequestSpec {
                arrival_ns: (t_secs * 1e9) as u64,
                rows: lo + rng.below(hi - lo + 1),
            }
        })
        .collect()
}

/// Materialise serve-ready requests for a trace: (rows, d) activations
/// drawn from `seed` (independent of the arrival seed so load shape
/// and payload can vary separately).
pub fn trace_requests(
    trace: &[RequestSpec],
    d: usize,
    seed: u64,
) -> Vec<TimedRequest> {
    let mut rng = Rng::new(seed);
    trace
        .iter()
        .map(|r| TimedRequest {
            arrival_ns: r.arrival_ns,
            x: TensorF::new(
                vec![r.rows, d],
                (0..r.rows * d).map(|_| rng.normal_f32()).collect(),
            ),
        })
        .collect()
}

/// A ready-to-drive serving stack over a synthetic frozen MoE — the
/// model dims, serve config and burst-calibration ritual
/// `examples/serve_demo.rs`, `repro serve` and `benches/serve.rs`
/// share, defined once.
pub struct ServeHarness {
    pub serve: ServeLoop,
    pub d_model: usize,
    pub n_experts: usize,
    pub k: usize,
    pub devices: usize,
    pub min_rows: usize,
    pub max_rows: usize,
}

impl ServeHarness {
    /// Freeze the standard synthetic serving model (16 experts, k=2,
    /// d=32) behind a 64-deep queue batching up to 256 tokens under a
    /// 0.5ms latency budget.
    pub fn build(seed: u64, devices: usize) -> Result<Self> {
        Self::build_with_obs(seed, devices, crate::obs::ObsConfig::from_env())
    }

    /// [`build`](Self::build) with an explicit observability config —
    /// `repro trace` and `rust/tests/obs.rs` turn span recording on
    /// here regardless of `MOE_TRACE`.
    pub fn build_with_obs(
        seed: u64,
        devices: usize,
        obs: crate::obs::ObsConfig,
    ) -> Result<Self> {
        let (d, h, n, k) = (32, 128, 16, 2);
        let devices = devices.max(1);
        let work = SyntheticMoe::build(seed, d, h, n, k, 1, 8)?;
        let cfg = ServeConfig {
            queue_depth: 64,
            max_batch_tokens: 256,
            latency_budget_ns: 500_000, // 0.5ms
            ..Default::default()
        };
        let sched = Scheduler::new(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
        )
        .with_obs(obs);
        Ok(ServeHarness {
            serve: ServeLoop::new(sched, work.router, work.weights, cfg)?,
            d_model: d,
            n_experts: n,
            k,
            devices,
            min_rows: 4,
            max_rows: 24,
        })
    }

    /// Seeded Poisson trace at an absolute request rate, materialised.
    pub fn trace(
        &self,
        arrival_seed: u64,
        rate_per_sec: f64,
        n_requests: usize,
        bursty: bool,
        payload_seed: u64,
    ) -> Vec<TimedRequest> {
        trace_requests(
            &poisson_trace(&TraceSpec {
                seed: arrival_seed,
                rate_per_sec,
                n_requests,
                min_rows: self.min_rows,
                max_rows: self.max_rows,
                bursty,
            }),
            self.d_model,
            payload_seed,
        )
    }

    /// Warm the engine, then measure serving capacity (tokens/sec)
    /// from a simultaneous 64-request burst — every batch saturated,
    /// so the achieved rate approximates the engine's ceiling.
    pub fn calibrate(&self, seed: u64) -> Result<f64> {
        let calib = self.trace(seed ^ 0xca11b8, 1e12, 64, false, seed ^ 1);
        self.serve.run_trace(&calib)?; // warm the engine + arenas
        Ok(self.serve.run_trace(&calib)?.stats.tokens_per_sec().max(1.0))
    }

    /// Request rate offering `mult` × a calibrated token capacity.
    pub fn rate_for(&self, capacity_tok_per_sec: f64, mult: f64) -> f64 {
        let mean_rows = (self.min_rows + self.max_rows) as f64 / 2.0;
        (capacity_tok_per_sec * mult / mean_rows).max(1.0)
    }
}

/// The latency-vs-offered-load report shared by `examples/serve_demo.rs`
/// and `repro serve`: calibrate a [`ServeHarness`], then replay
/// open-loop Poisson traces at `load_multipliers` × capacity, printing
/// p50/p99 latency, achieved tokens/sec, occupancy and sheds per point.
pub fn serve_load_curve(
    seed: u64,
    devices: usize,
    load_multipliers: &[f64],
    n_requests: usize,
) -> Result<()> {
    let harness = ServeHarness::build(seed, devices)?;
    let capacity = harness.calibrate(seed)?;
    println!(
        "# serve load curve: {} experts (k={}, d={}) on {} device(s), \
         calibrated capacity {capacity:.0} tok/s",
        harness.n_experts, harness.k, harness.d_model, harness.devices,
    );
    for &mult in load_multipliers {
        let rate = harness.rate_for(capacity, mult);
        let trace = harness.trace(
            seed ^ 0x70ad ^ (mult * 1e3) as u64,
            rate,
            n_requests,
            false,
            seed ^ 2,
        );
        let report = harness.serve.run_trace(&trace)?;
        println!(
            "offered {mult:>4.1}x ({rate:>7.0} req/s)  {}",
            report.stats.summary_line()
        );
        println!("  {}", serve_phase_line(&report.stats));
    }
    Ok(())
}

/// Tenant index of the flooding tenant in [`heavy_hitter_specs`]
/// traces (and the fairness sweep built on them).
pub const HITTER: usize = 0;
/// Tenant index of the well-behaved victim in [`heavy_hitter_specs`]
/// traces.
pub const VICTIM: usize = 1;

/// Merge per-tenant [`TraceSpec`]s into one arrival-sorted multi-tenant
/// trace.  Tenant `t` gets `specs[t]`'s arrival process and a payload
/// stream folded from `payload_seed` and `t`, so tenants' activations
/// differ but the whole trace is a pure function of its seeds.
pub fn tenant_trace(
    specs: &[TraceSpec],
    d: usize,
    payload_seed: u64,
) -> Vec<TenantRequest> {
    let mut all: Vec<TenantRequest> = Vec::new();
    for (t, spec) in specs.iter().enumerate() {
        let mut rng =
            Rng::new(payload_seed.wrapping_add(0x9e37_79b9 * (t as u64 + 1)));
        for r in poisson_trace(spec) {
            all.push(TenantRequest {
                tenant: t,
                arrival_ns: r.arrival_ns,
                x: TensorF::new(
                    vec![r.rows, d],
                    (0..r.rows * d).map(|_| rng.normal_f32()).collect(),
                ),
            });
        }
    }
    // stable: simultaneous arrivals keep per-tenant generation order
    all.sort_by_key(|r| r.arrival_ns);
    all
}

/// The adversarial two-tenant mix: tenant [`HITTER`] floods bursty at
/// `hitter_rate`, tenant [`VICTIM`] trickles smoothly at `victim_rate`,
/// and both streams span the *same* time horizon (the hitter's request
/// count is scaled up so it keeps flooding for the victim's whole
/// trace — isolation claims are vacuous if the flood ends early).
pub fn heavy_hitter_specs(
    seed: u64,
    hitter_rate: f64,
    victim_rate: f64,
    n_victim: usize,
    min_rows: usize,
    max_rows: usize,
) -> Vec<TraceSpec> {
    let horizon_secs = n_victim as f64 / victim_rate.max(1e-9);
    let n_hitter = (hitter_rate * horizon_secs).ceil().max(1.0) as usize;
    vec![
        TraceSpec {
            seed: seed ^ 0x4177,
            rate_per_sec: hitter_rate,
            n_requests: n_hitter,
            min_rows,
            max_rows,
            bursty: true,
        },
        TraceSpec {
            seed: seed ^ 0x1c71,
            rate_per_sec: victim_rate,
            n_requests: n_victim,
            min_rows,
            max_rows,
            bursty: false,
        },
    ]
}

/// A head tenant plus `n_tail` trickle tenants (the long-tail shape:
/// one hot customer, many sporadic ones) — the conservation tests run
/// this under every admission × drain policy combination.
pub fn long_tail_specs(
    seed: u64,
    head_rate: f64,
    n_head: usize,
    n_tail: usize,
    min_rows: usize,
    max_rows: usize,
) -> Vec<TraceSpec> {
    let mut specs = vec![TraceSpec {
        seed: seed ^ 0x4ead,
        rate_per_sec: head_rate,
        n_requests: n_head,
        min_rows,
        max_rows,
        bursty: true,
    }];
    for t in 0..n_tail {
        specs.push(TraceSpec {
            seed: seed ^ 0x7a11 ^ ((t as u64 + 1) << 8),
            rate_per_sec: (head_rate / 16.0).max(1.0),
            n_requests: (n_head / 8).max(2),
            min_rows,
            max_rows,
            bursty: false,
        });
    }
    specs
}

/// The multi-tenant counterpart of [`ServeHarness`]: the same frozen
/// synthetic serving model (16 experts, k=2, d=32, 256-token batches
/// under a 0.5ms budget) behind a [`TenantServeLoop`], with one- and
/// two-backend fleet builders.  `rust/tests/tenants.rs`,
/// `benches/tenants.rs` and `repro tenants` all drive this, so the
/// model and calibration ritual live in exactly one place.
pub struct TenantHarness {
    pub seed: u64,
    pub devices: usize,
    pub d_model: usize,
    pub hidden: usize,
    pub n_experts: usize,
    pub k: usize,
    pub max_batch_tokens: usize,
    pub latency_budget_ns: u64,
    pub min_rows: usize,
    pub max_rows: usize,
}

impl TenantHarness {
    pub fn new(seed: u64, devices: usize) -> Self {
        TenantHarness {
            seed,
            devices: devices.max(1),
            d_model: 32,
            hidden: 128,
            n_experts: 16,
            k: 2,
            max_batch_tokens: 256,
            latency_budget_ns: 500_000, // 0.5ms
            min_rows: 4,
            max_rows: 24,
        }
    }

    /// Freeze one engine backend over a seeded synthetic checkpoint.
    /// Different `ckpt_seed`s give genuinely different model weights —
    /// that's what makes the A/B routing bit-identity test meaningful.
    pub fn backend(
        &self,
        name: &str,
        variant: &str,
        precision: Precision,
        ckpt_seed: u64,
    ) -> Result<EngineBackend> {
        let work = SyntheticMoe::build(
            ckpt_seed,
            self.d_model,
            self.hidden,
            self.n_experts,
            self.k,
            1,
            8,
        )?;
        let sched = Scheduler::new(
            ShardLayout::new(self.devices, self.n_experts),
            ExpertBackend::Native,
        );
        EngineBackend::new(
            name,
            variant,
            sched,
            work.router,
            work.weights,
            precision,
            self.max_batch_tokens,
        )
    }

    /// Front-end config with the harness's latency budget and the
    /// requested drain policy (Reject admission — the fairness sweep's
    /// contrast is about *which* tenant gets refused, not how).
    pub fn config(&self, drain: DrainPolicy) -> TenantServeConfig {
        TenantServeConfig {
            admission: AdmissionPolicy::Reject,
            drain,
            latency_budget_ns: self.latency_budget_ns,
            capture_outputs: false,
        }
    }

    /// A single-engine fleet: one f32 `"base"` backend.  The fairness
    /// sweep uses this so drain policy is the only variable.
    pub fn single_loop(
        &self,
        specs: Vec<TenantSpec>,
        cfg: TenantServeConfig,
    ) -> Result<TenantServeLoop> {
        let backends: Vec<Box<dyn ServeBackend>> =
            vec![Box::new(self.backend(
                "engine",
                "base",
                Precision::F32,
                self.seed,
            )?)];
        TenantServeLoop::new(backends, specs, cfg)
    }

    /// A two-engine A/B fleet: an exact f32 `"base"` backend plus an
    /// int8 `"canary"` over a *different* checkpoint seed — tenants pin
    /// precision/variant to force routing, or leave both unset and let
    /// least-wait scoring pick.
    pub fn ab_loop(
        &self,
        specs: Vec<TenantSpec>,
        cfg: TenantServeConfig,
    ) -> Result<TenantServeLoop> {
        let backends: Vec<Box<dyn ServeBackend>> = vec![
            Box::new(self.backend(
                "exact",
                "base",
                Precision::F32,
                self.seed,
            )?),
            Box::new(self.backend(
                "turbo",
                "canary",
                Precision::Int8,
                self.seed ^ 0xab,
            )?),
        ];
        TenantServeLoop::new(backends, specs, cfg)
    }

    /// Materialise a multi-tenant trace for these model dims.
    pub fn trace(&self, specs: &[TraceSpec]) -> Vec<TenantRequest> {
        tenant_trace(specs, self.d_model, self.seed ^ 0x9a71)
    }

    /// Single-engine serving capacity (tokens/sec) from a simultaneous
    /// burst, measured on the second of two runs (the first warms the
    /// engine) — the same ritual as [`ServeHarness::calibrate`].
    pub fn calibrate(&self) -> Result<f64> {
        let lp = self.single_loop(
            vec![TenantSpec::new("calib", 64)],
            self.config(DrainPolicy::WeightedFair),
        )?;
        let trace = self.trace(&[TraceSpec {
            seed: self.seed ^ 0xca11b8,
            rate_per_sec: 1e12,
            n_requests: 64,
            min_rows: self.min_rows,
            max_rows: self.max_rows,
            bursty: false,
        }]);
        lp.run_trace(&trace)?;
        Ok(lp.run_trace(&trace)?.global.tokens_per_sec().max(1.0))
    }

    /// Request rate offering `mult` × a calibrated token capacity.
    pub fn rate_for(&self, capacity_tok_per_sec: f64, mult: f64) -> f64 {
        let mean_rows = (self.min_rows + self.max_rows) as f64 / 2.0;
        (capacity_tok_per_sec * mult / mean_rows).max(1.0)
    }
}

/// Completed fraction of a ledger (1.0 when nothing was offered, so a
/// zero-traffic tenant doesn't read as fully shed).
pub fn completed_fraction(s: &ServeStats) -> f64 {
    if s.offered == 0 {
        1.0
    } else {
        s.completed as f64 / s.offered as f64
    }
}

/// One structured fairness-sweep row — `repro tenants`,
/// `benches/tenants.rs` and the CI validator all read these instead of
/// re-deriving numbers from three reports.
pub struct TenantRow {
    /// which replay: `"solo"`, `"wfq"` or `"fifo"`
    pub run: &'static str,
    pub tenant: String,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub failed: u64,
    pub completed_fraction: f64,
    pub shed_fraction: f64,
    pub p99_total_ns: u64,
    /// per-tenant ledger conservation: `offered == completed + shed + failed`
    pub conserved: bool,
}

/// The isolation experiment: the same heavy-hitter trace replayed under
/// weighted-fair and global-FIFO drains, plus the victim's solo
/// baseline (identical victim traffic, hitter silenced).  The claim the
/// tier-1 test pins down: WFQ keeps the victim's completed fraction and
/// p99 near solo while global FIFO demonstrably sheds it.
pub struct FairnessOutcome {
    pub capacity_tok_per_sec: f64,
    pub victim_deadline_ns: u64,
    pub solo: TenantServeReport,
    pub wfq: TenantServeReport,
    pub fifo: TenantServeReport,
}

impl FairnessOutcome {
    pub fn victim_fraction(run: &TenantServeReport) -> f64 {
        completed_fraction(&run.per_tenant[VICTIM])
    }

    pub fn victim_p99_ns(run: &TenantServeReport) -> u64 {
        run.per_tenant[VICTIM].total.percentile(0.99)
    }

    pub fn rows(&self) -> Vec<TenantRow> {
        let mut rows = Vec::new();
        for (run, rep) in
            [("solo", &self.solo), ("wfq", &self.wfq), ("fifo", &self.fifo)]
        {
            for (name, s) in rep.tenants.iter().zip(&rep.per_tenant) {
                rows.push(TenantRow {
                    run,
                    tenant: name.clone(),
                    offered: s.offered,
                    completed: s.completed,
                    shed: s.shed,
                    failed: s.failed,
                    completed_fraction: completed_fraction(s),
                    shed_fraction: if s.offered == 0 {
                        0.0
                    } else {
                        s.shed as f64 / s.offered as f64
                    },
                    p99_total_ns: s.total.percentile(0.99),
                    conserved: s.offered == s.completed + s.shed + s.failed,
                });
            }
        }
        rows
    }

    /// The one-line verdict `repro tenants` prints.
    pub fn isolation_line(&self) -> String {
        format!(
            "isolation: victim completed {:.0}% solo / {:.0}% weighted-fair \
             / {:.0}% global-fifo; victim p99 {:.3}ms solo vs {:.3}ms \
             weighted-fair — per-lane DRR holds the victim near its solo \
             baseline while the shared FIFO lets the heavy hitter shed it",
            Self::victim_fraction(&self.solo) * 100.0,
            Self::victim_fraction(&self.wfq) * 100.0,
            Self::victim_fraction(&self.fifo) * 100.0,
            Self::victim_p99_ns(&self.solo) as f64 / 1e6,
            Self::victim_p99_ns(&self.wfq) as f64 / 1e6,
        )
    }
}

/// The fairness experiment's tenant contracts: a 64-deep flood lane
/// and a 16-deep victim lane whose latency SLO gates admission.
pub fn fairness_tenants(victim_deadline_ns: u64) -> Vec<TenantSpec> {
    vec![
        TenantSpec::new("hitter", 64),
        TenantSpec {
            deadline_ns: Some(victim_deadline_ns),
            ..TenantSpec::new("victim", 16)
        },
    ]
}

/// The fairness experiment's traffic: hitter at 10× calibrated
/// capacity, victim trickling at 0.25×, over one shared horizon.
pub fn fairness_traffic(
    h: &TenantHarness,
    capacity_tok_per_sec: f64,
    n_victim: usize,
) -> Vec<TraceSpec> {
    heavy_hitter_specs(
        h.seed,
        h.rate_for(capacity_tok_per_sec, 10.0),
        h.rate_for(capacity_tok_per_sec, 0.25),
        n_victim,
        h.min_rows,
        h.max_rows,
    )
}

/// [`fairness_traffic`] with the hitter silenced — identical victim
/// arrivals, so the solo replay is a true baseline.
pub fn fairness_solo_traffic(hh: &[TraceSpec]) -> Vec<TraceSpec> {
    let mut s = hh.to_vec();
    s[HITTER].n_requests = 0;
    s
}

/// Victim latency SLO derived from measured capacity: ~350 effective
/// tokens of backlog — a few requests' worth under weighted-fair, a
/// small fraction of the shared backlog a 64-deep flooded FIFO
/// carries, so the same deadline admits under one drain policy and
/// sheds under the other.
pub fn fairness_deadline_ns(capacity_tok_per_sec: f64) -> u64 {
    (350.0 * 1e9 / capacity_tok_per_sec) as u64
}

/// Run the fairness experiment: calibrate, derive the victim's SLO
/// ([`fairness_deadline_ns`]), then replay the same heavy-hitter mix
/// under both drain policies plus the victim-solo baseline.  Every
/// replay runs twice and reports the warm run, so the EWMA throughput
/// estimates feeding deadline admission are stable.
pub fn tenant_fairness_run(
    seed: u64,
    devices: usize,
    n_victim: usize,
) -> Result<FairnessOutcome> {
    let h = TenantHarness::new(seed, devices);
    let capacity = h.calibrate()?;
    let victim_deadline_ns = fairness_deadline_ns(capacity);
    let hh = fairness_traffic(&h, capacity, n_victim);
    let solo_specs = fairness_solo_traffic(&hh);
    let run = |drain: DrainPolicy,
               traffic: &[TraceSpec]|
     -> Result<TenantServeReport> {
        let lp = h.single_loop(
            fairness_tenants(victim_deadline_ns),
            h.config(drain),
        )?;
        let trace = h.trace(traffic);
        lp.run_trace(&trace)?; // warm the engine + EWMA walls
        lp.run_trace(&trace)
    };
    Ok(FairnessOutcome {
        capacity_tok_per_sec: capacity,
        victim_deadline_ns,
        solo: run(DrainPolicy::WeightedFair, &solo_specs)?,
        wfq: run(DrainPolicy::WeightedFair, &hh)?,
        fifo: run(DrainPolicy::GlobalFifo, &hh)?,
    })
}

/// `repro tenants`: the fairness sweep as a console report — calibrated
/// capacity, per-tenant summary lines for all three replays, and the
/// isolation verdict.
pub fn tenant_report(seed: u64, devices: usize, n_victim: usize) -> Result<()> {
    let out = tenant_fairness_run(seed, devices, n_victim)?;
    println!(
        "# tenant fairness: capacity {:.0} tok/s on {} device(s), victim \
         SLO {:.3}ms, hitter 10.0x / victim 0.25x offered",
        out.capacity_tok_per_sec,
        devices.max(1),
        out.victim_deadline_ns as f64 / 1e6,
    );
    for (label, rep) in [
        ("victim solo (weighted-fair)", &out.solo),
        ("heavy hitter, weighted-fair", &out.wfq),
        ("heavy hitter, global fifo", &out.fifo),
    ] {
        println!("-- {label}");
        for line in rep.summary_lines() {
            println!("  {line}");
        }
    }
    println!("{}", out.isolation_line());
    Ok(())
}

/// `repro trace`: run one traced streamed step plus one traced serve
/// burst, merge both span streams into a single Chrome trace-event file
/// (`out`, loadable in `chrome://tracing` or Perfetto), and print the
/// unified registry snapshot both ways (JSON + Prometheus text).
pub fn trace_report(
    devices: usize,
    tokens: usize,
    requests: usize,
    seed: u64,
    out: &str,
) -> Result<()> {
    use crate::obs::{push_chrome_events, ObsConfig, Registry};

    let devices = devices.max(1);
    let mut reg = Registry::new();
    let mut events = Vec::new();

    // one streamed step, span recording on (engine workers + coordinator)
    let (d, h, n, k) = (64usize, 128usize, 64.max(devices), 4usize);
    let rows = (tokens / devices).max(1);
    let work = SyntheticMoe::build(seed, d, h, n, k, devices, rows)?;
    let sched =
        Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native)
            .with_obs(ObsConfig::enabled());
    let s = work.run_streamed(&sched, None)?;
    s.stats.publish(&mut reg);
    let step_spans = sched.take_spans();
    anyhow::ensure!(!step_spans.is_empty(), "traced step recorded no spans");
    push_chrome_events(&mut events, &step_spans, 0, "streamed step", devices);
    println!(
        "streamed step: {:>5} spans  {}",
        step_spans.len(),
        phase_line(&s.stats)
    );

    // a serve burst on the shared serving stack, span recording on
    let harness =
        ServeHarness::build_with_obs(seed, devices, ObsConfig::enabled())?;
    let trace =
        harness.trace(seed ^ 0x77ace, 2_000.0, requests, false, seed ^ 1);
    let report = harness.serve.run_trace(&trace)?;
    report.stats.publish(&mut reg);
    let serve_spans = harness.serve.take_spans();
    anyhow::ensure!(
        !serve_spans.is_empty(),
        "traced serve run recorded no spans"
    );
    push_chrome_events(&mut events, &serve_spans, 1, "serve", devices);
    println!(
        "serve burst:   {:>5} spans  {}",
        serve_spans.len(),
        report.stats.summary_line()
    );

    let json = format!("{{\"traceEvents\": [{}]}}\n", events.join(", "));
    std::fs::write(out, &json)?;
    println!(
        "wrote {out} ({} events) — open in chrome://tracing or \
         https://ui.perfetto.dev",
        events.len()
    );
    let snap = reg.snapshot();
    println!("--- registry snapshot (json) ---");
    println!("{}", snap.to_json().trim_end());
    println!("--- registry snapshot (prometheus) ---");
    print!("{}", snap.to_prometheus());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_consistent_workload() {
        let w = SyntheticMoe::build(3, 8, 16, 6, 2, 2, 10).unwrap();
        assert_eq!(w.weights.len(), 6);
        assert_eq!(w.xs.len(), 2);
        assert_eq!(w.tokens(), 20);
        assert_eq!(w.plan.total_routes(), 20 * 2);
        assert_eq!(w.refs().len(), 2);
    }

    #[test]
    fn streamed_helper_matches_unpipelined() {
        use crate::coordinator::scheduler::ExpertBackend;
        use crate::coordinator::ShardLayout;

        let w = SyntheticMoe::build(5, 8, 16, 6, 2, 2, 12).unwrap();
        let sched =
            Scheduler::new(ShardLayout::new(2, 6), ExpertBackend::Native);
        let mut r1 = Rng::new(99);
        let (outs, stats) = w.run_unpipelined(&sched, Some(&mut r1)).unwrap();
        let mut r2 = Rng::new(99);
        let s = w.run_streamed(&sched, Some(&mut r2)).unwrap();
        assert_eq!(outs.len(), s.outs.len());
        for (a, b) in outs.iter().zip(s.outs.iter()) {
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
            }
        }
        assert_eq!(s.decisions.len(), 2);
        assert_eq!(s.stats.expert_loads, stats.expert_loads);
        assert_eq!(s.plan.expert_loads(), stats.expert_loads);
        assert!(stats.phases.route > 0, "unpipelined route wall recorded");
    }

    #[test]
    fn poisson_trace_is_seed_deterministic() {
        // the satellite property: identical seeds give identical traces,
        // with no wall-clock input anywhere in the generator
        crate::util::prop::forall("poisson trace seed", |rng| {
            let spec = TraceSpec {
                seed: rng.next_u64(),
                rate_per_sec: 0.5 + rng.uniform() * 5000.0,
                n_requests: 1 + rng.below(60),
                min_rows: 1 + rng.below(4),
                max_rows: 4 + rng.below(16),
                bursty: rng.below(2) == 1,
            };
            let a = poisson_trace(&spec);
            let b = poisson_trace(&spec);
            assert_eq!(a, b, "same seed must give the same trace");
            assert_eq!(a.len(), spec.n_requests);
            let lo = spec.min_rows.max(1);
            let hi = spec.max_rows.max(lo);
            for w in a.windows(2) {
                assert!(w[0].arrival_ns <= w[1].arrival_ns, "unsorted trace");
            }
            for r in &a {
                assert!((lo..=hi).contains(&r.rows), "rows {} out of range", r.rows);
            }
            let other = TraceSpec {
                seed: spec.seed.wrapping_add(1),
                ..spec.clone()
            };
            assert_ne!(
                a,
                poisson_trace(&other),
                "different seeds should differ"
            );
        });
    }

    #[test]
    fn bursty_mode_clumps_arrivals() {
        let base = TraceSpec {
            seed: 11,
            rate_per_sec: 1000.0,
            n_requests: 64,
            min_rows: 1,
            max_rows: 8,
            bursty: false,
        };
        let smooth = poisson_trace(&base);
        let bursty =
            poisson_trace(&TraceSpec { bursty: true, ..base.clone() });
        // same seed, same length; burstiness only reshapes the gaps
        assert_eq!(smooth.len(), bursty.len());
        assert_ne!(smooth, bursty);
        // gap j precedes arrival j+1, whose epoch chose its rate: hot
        // epochs run ×4, cold ÷4, so mean cold gaps must dominate mean
        // hot gaps by far more than exponential sampling noise (the
        // nominal ratio is 16×; 4× is the regression-proof floor)
        let mut hot: Vec<u64> = Vec::new();
        let mut cold: Vec<u64> = Vec::new();
        for (j, w) in bursty.windows(2).enumerate() {
            let gap = w[1].arrival_ns - w[0].arrival_ns;
            if ((j + 1) / 16) % 2 == 0 {
                hot.push(gap);
            } else {
                cold.push(gap);
            }
        }
        let mean =
            |v: &[u64]| v.iter().sum::<u64>() as f64 / v.len().max(1) as f64;
        assert!(!hot.is_empty() && !cold.is_empty());
        assert!(
            mean(&cold) > 4.0 * mean(&hot),
            "bursty trace shows no clumping: cold mean {} vs hot mean {}",
            mean(&cold),
            mean(&hot)
        );
    }

    #[test]
    fn trace_requests_match_spec_shapes() {
        let trace = poisson_trace(&TraceSpec {
            seed: 3,
            rate_per_sec: 100.0,
            n_requests: 10,
            min_rows: 2,
            max_rows: 5,
            bursty: false,
        });
        let reqs = trace_requests(&trace, 6, 9);
        assert_eq!(reqs.len(), 10);
        for (r, spec) in reqs.iter().zip(trace.iter()) {
            assert_eq!(r.arrival_ns, spec.arrival_ns);
            assert_eq!(r.x.shape, vec![spec.rows, 6]);
        }
        // payload seed is independent of the arrival seed
        let reqs2 = trace_requests(&trace, 6, 10);
        assert_eq!(reqs2[0].arrival_ns, reqs[0].arrival_ns);
        assert_ne!(reqs2[0].x.data, reqs[0].x.data);
    }

    #[test]
    fn tenant_trace_merges_sorted_and_tags_tenants() {
        let specs = vec![
            TraceSpec {
                seed: 5,
                rate_per_sec: 800.0,
                n_requests: 12,
                min_rows: 2,
                max_rows: 6,
                bursty: false,
            },
            TraceSpec {
                seed: 6,
                rate_per_sec: 400.0,
                n_requests: 7,
                min_rows: 2,
                max_rows: 6,
                bursty: true,
            },
        ];
        let trace = tenant_trace(&specs, 4, 17);
        assert_eq!(trace.len(), 19);
        for w in trace.windows(2) {
            assert!(w[0].arrival_ns <= w[1].arrival_ns, "unsorted merge");
        }
        let per_tenant =
            |t: usize| trace.iter().filter(|r| r.tenant == t).count();
        assert_eq!(per_tenant(0), 12);
        assert_eq!(per_tenant(1), 7);
        for r in &trace {
            assert_eq!(r.x.shape.len(), 2);
            assert_eq!(r.x.shape[1], 4);
            assert!((2..=6).contains(&r.x.shape[0]));
        }
        // deterministic, and payload seed varies payloads only
        let again = tenant_trace(&specs, 4, 17);
        assert_eq!(trace.len(), again.len());
        assert!(trace
            .iter()
            .zip(&again)
            .all(|(a, b)| a.x.data == b.x.data && a.tenant == b.tenant));
        let other = tenant_trace(&specs, 4, 18);
        assert_eq!(trace[0].arrival_ns, other[0].arrival_ns);
        assert_ne!(trace[0].x.data, other[0].x.data);
    }

    #[test]
    fn heavy_hitter_specs_share_one_horizon() {
        let specs = heavy_hitter_specs(9, 4_000.0, 100.0, 20, 4, 24);
        assert_eq!(specs.len(), 2);
        assert!(specs[HITTER].bursty, "the hitter clumps");
        assert!(!specs[VICTIM].bursty);
        assert_eq!(specs[VICTIM].n_requests, 20);
        // hitter keeps flooding for the victim's whole horizon:
        // (4000/100) × 20 victim requests
        assert_eq!(specs[HITTER].n_requests, 800);
        let tails = long_tail_specs(9, 4_000.0, 64, 5, 4, 24);
        assert_eq!(tails.len(), 6);
        assert!(tails[0].bursty && tails[0].n_requests == 64);
        for t in &tails[1..] {
            assert!(t.rate_per_sec < tails[0].rate_per_sec / 10.0);
            assert!(t.n_requests >= 2);
        }
        // per-tail seeds differ so arrivals don't duplicate
        assert_ne!(tails[1].seed, tails[2].seed);
    }

    #[test]
    fn phase_reports_share_one_fragment() {
        let plain = phase_line(&StepStats::default());
        assert!(!plain.contains("queue"));
        assert!(plain.contains("route 0.000ms"));

        let mut serve = crate::serve::ServeStats::new();
        serve.queue_wait.push(2_000_000);
        serve.phases.compute = 3_000_000;
        let line = serve_phase_line(&serve);
        assert!(line.starts_with("queue p50 2.000ms"), "{line}");
        assert!(line.contains("compute 3.000ms"), "{line}");
        assert!(line.contains("batches=0"), "{line}");
    }
}
