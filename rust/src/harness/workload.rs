//! Synthetic Native-backend MoE workloads, shared by the measured
//! efficiency report and the bench targets so the expert/router/plan
//! construction lives in exactly one place.

use anyhow::Result;

use crate::coordinator::engine::StreamedStep;
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{ExpertWeights, Scheduler, StepStats};
use crate::coordinator::{DispatchPlan, Dispatcher};
use crate::runtime::TensorF;
use crate::util::rng::Rng;

/// A fully routed synthetic MoE step: expert weights, gating router,
/// per-replica activations and the resulting dispatch plan.
pub struct SyntheticMoe {
    pub d_model: usize,
    pub hidden: usize,
    pub n_experts: usize,
    pub k: usize,
    pub weights: Vec<ExpertWeights>,
    pub router: Router,
    pub xs: Vec<TensorF>,
    pub plan: DispatchPlan,
}

impl SyntheticMoe {
    /// Build `replicas` activations of `rows` tokens each, noisy-top-k
    /// routed over `n` experts, from a deterministic seed.
    pub fn build(
        seed: u64,
        d: usize,
        h: usize,
        n: usize,
        k: usize,
        replicas: usize,
        rows: usize,
    ) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d,
            n,
            k,
            (0..d * n).map(|_| rng.normal_f32() * 0.4).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.4).collect()),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                TensorF::new(
                    vec![rows, d],
                    (0..rows * d).map(|_| rng.normal_f32()).collect(),
                )
            })
            .collect();
        let mut nrng = rng.fold_in(1);
        let decisions: Vec<_> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut nrng)))
            .collect::<Result<_>>()?;
        let plan = Dispatcher::plan(&decisions, n);
        Ok(SyntheticMoe {
            d_model: d,
            hidden: h,
            n_experts: n,
            k,
            weights,
            router,
            xs,
            plan,
        })
    }

    /// Borrowed replica activations in `Scheduler::execute` form.
    pub fn refs(&self) -> Vec<&TensorF> {
        self.xs.iter().collect()
    }

    pub fn tokens(&self) -> usize {
        self.xs.iter().map(|x| x.shape[0]).sum()
    }

    /// One PR-1-shaped step: route every replica serially on the
    /// caller's thread, build the plan, then execute on the persistent
    /// engine — route, dispatch and execute composed back-to-back.  The
    /// route+plan wall lands in `stats.phases.route` so the result is
    /// directly comparable with [`run_streamed`](Self::run_streamed).
    pub fn run_unpipelined(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.run_composed(rng, |plan, refs, weights| {
            sched.execute(plan, refs, weights)
        })
    }

    /// The serially-composed step on the single-threaded reference path
    /// (route → plan → [`Scheduler::execute_serial`]), with the route
    /// wall stamped into `stats.phases.route` — the full-step oracle
    /// row for reports and benches.
    pub fn run_serial_reference(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<(Vec<TensorF>, StepStats)> {
        self.run_composed(rng, |plan, refs, weights| {
            sched.execute_serial(plan, refs, weights)
        })
    }

    /// Shared route→plan→execute composition: time the serial routing,
    /// run `exec`, stamp the route wall into `stats.phases.route`.
    fn run_composed<F>(
        &self,
        rng: Option<&mut Rng>,
        exec: F,
    ) -> Result<(Vec<TensorF>, StepStats)>
    where
        F: FnOnce(
            &DispatchPlan,
            &[&TensorF],
            &[ExpertWeights],
        ) -> Result<(Vec<TensorF>, StepStats)>,
    {
        let refs = self.refs();
        let t0 = std::time::Instant::now();
        let (_decisions, plan) =
            Dispatcher::route_and_plan(&self.router, &refs, rng)?;
        let route_ns = t0.elapsed().as_nanos() as u64;
        let (outs, mut stats) = exec(&plan, &refs, &self.weights)?;
        stats.phases.route = route_ns;
        Ok((outs, stats))
    }

    /// The same full step as a streaming routing→dispatch pipeline on
    /// the engine ([`Scheduler::execute_streamed`]).
    pub fn run_streamed(
        &self,
        sched: &Scheduler,
        rng: Option<&mut Rng>,
    ) -> Result<StreamedStep> {
        let refs = self.refs();
        sched.execute_streamed(&self.router, &refs, &self.weights, rng)
    }
}

/// One-line rendering of a step's per-phase breakdown (shared by the
/// benches and the efficiency report).  `combine` is the critical-path
/// tail; the parenthesised hidden time is combine work the executor
/// ran under expert compute (`overlap` = fraction of combine hidden).
pub fn phase_line(stats: &StepStats) -> String {
    format!(
        "route {:.3}ms  gather {:.3}ms  compute {:.3}ms  combine {:.3}ms \
         (+{:.3}ms hidden, overlap {:.0}%)  waves={}  busiest_shard={} tok  \
         max shard idle {:.3}ms",
        stats.phases.route as f64 / 1e6,
        stats.phases.gather as f64 / 1e6,
        stats.phases.compute as f64 / 1e6,
        stats.phases.combine as f64 / 1e6,
        stats.phases.overlap_ns as f64 / 1e6,
        stats.combine_overlap_ratio() * 100.0,
        stats.waves,
        stats.busiest_shard_tokens,
        stats.shard_idle_ns.iter().copied().max().unwrap_or(0) as f64 / 1e6,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_consistent_workload() {
        let w = SyntheticMoe::build(3, 8, 16, 6, 2, 2, 10).unwrap();
        assert_eq!(w.weights.len(), 6);
        assert_eq!(w.xs.len(), 2);
        assert_eq!(w.tokens(), 20);
        assert_eq!(w.plan.total_routes(), 20 * 2);
        assert_eq!(w.refs().len(), 2);
    }

    #[test]
    fn streamed_helper_matches_unpipelined() {
        use crate::coordinator::scheduler::ExpertBackend;
        use crate::coordinator::ShardLayout;

        let w = SyntheticMoe::build(5, 8, 16, 6, 2, 2, 12).unwrap();
        let sched =
            Scheduler::new(ShardLayout::new(2, 6), ExpertBackend::Native);
        let mut r1 = Rng::new(99);
        let (outs, stats) = w.run_unpipelined(&sched, Some(&mut r1)).unwrap();
        let mut r2 = Rng::new(99);
        let s = w.run_streamed(&sched, Some(&mut r2)).unwrap();
        assert_eq!(outs.len(), s.outs.len());
        for (a, b) in outs.iter().zip(s.outs.iter()) {
            assert_eq!(a.shape, b.shape);
            for (x, y) in a.data.iter().zip(b.data.iter()) {
                assert!((x - y).abs() <= 1e-5, "{x} vs {y}");
            }
        }
        assert_eq!(s.decisions.len(), 2);
        assert_eq!(s.stats.expert_loads, stats.expert_loads);
        assert_eq!(s.plan.expert_loads(), stats.expert_loads);
        assert!(stats.phases.route > 0, "unpipelined route wall recorded");
    }
}
