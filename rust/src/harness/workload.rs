//! Synthetic Native-backend MoE workloads, shared by the measured
//! efficiency report and the bench targets so the expert/router/plan
//! construction lives in exactly one place.

use anyhow::Result;

use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{ExpertWeights, StepStats};
use crate::coordinator::{DispatchPlan, Dispatcher};
use crate::runtime::TensorF;
use crate::util::rng::Rng;

/// A fully routed synthetic MoE step: expert weights, gating router,
/// per-replica activations and the resulting dispatch plan.
pub struct SyntheticMoe {
    pub d_model: usize,
    pub hidden: usize,
    pub n_experts: usize,
    pub k: usize,
    pub weights: Vec<ExpertWeights>,
    pub router: Router,
    pub xs: Vec<TensorF>,
    pub plan: DispatchPlan,
}

impl SyntheticMoe {
    /// Build `replicas` activations of `rows` tokens each, noisy-top-k
    /// routed over `n` experts, from a deterministic seed.
    pub fn build(
        seed: u64,
        d: usize,
        h: usize,
        n: usize,
        k: usize,
        replicas: usize,
        rows: usize,
    ) -> Result<Self> {
        let mut rng = Rng::new(seed);
        let weights = (0..n)
            .map(|_| ExpertWeights {
                w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
                w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
                d_model: d,
                hidden: h,
            })
            .collect();
        let router = Router::flat_native(
            d,
            n,
            k,
            (0..d * n).map(|_| rng.normal_f32() * 0.4).collect(),
            Some((0..d * n).map(|_| rng.normal_f32() * 0.4).collect()),
        );
        let xs: Vec<TensorF> = (0..replicas)
            .map(|_| {
                TensorF::new(
                    vec![rows, d],
                    (0..rows * d).map(|_| rng.normal_f32()).collect(),
                )
            })
            .collect();
        let mut nrng = rng.fold_in(1);
        let decisions: Vec<_> = xs
            .iter()
            .map(|x| router.route(x, Some(&mut nrng)))
            .collect::<Result<_>>()?;
        let plan = Dispatcher::plan(&decisions, n);
        Ok(SyntheticMoe {
            d_model: d,
            hidden: h,
            n_experts: n,
            k,
            weights,
            router,
            xs,
            plan,
        })
    }

    /// Borrowed replica activations in `Scheduler::execute` form.
    pub fn refs(&self) -> Vec<&TensorF> {
        self.xs.iter().collect()
    }

    pub fn tokens(&self) -> usize {
        self.xs.iter().map(|x| x.shape[0]).sum()
    }
}

/// One-line rendering of a step's per-phase breakdown (shared by the
/// benches and the efficiency report).
pub fn phase_line(stats: &StepStats) -> String {
    format!(
        "gather {:.3}ms  compute {:.3}ms  combine {:.3}ms  waves={}  \
         busiest_shard={} tok  max shard idle {:.3}ms",
        stats.phases.gather as f64 / 1e6,
        stats.phases.compute as f64 / 1e6,
        stats.phases.combine as f64 / 1e6,
        stats.waves,
        stats.busiest_shard_tokens,
        stats.shard_idle_ns.iter().copied().max().unwrap_or(0) as f64 / 1e6,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_consistent_workload() {
        let w = SyntheticMoe::build(3, 8, 16, 6, 2, 2, 10).unwrap();
        assert_eq!(w.weights.len(), 6);
        assert_eq!(w.xs.len(), 2);
        assert_eq!(w.tokens(), 20);
        assert_eq!(w.plan.total_routes(), 20 * 2);
        assert_eq!(w.refs().len(), 2);
    }
}
