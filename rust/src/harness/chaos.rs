//! Chaos harness: sweep deterministic fault rates × recovery policies
//! on the REAL engine and serve loop, proving the two properties the
//! fault layer owes (`rust/tests/faults.rs` proves the bit-level
//! ones):
//!
//! - **liveness** — every step under every injected schedule (chunk
//!   failures, stragglers past deadline, dropped combines, shard
//!   deaths) completes with finite latency and finite outputs: no
//!   replica ever hangs waiting on a chunk that will never deliver;
//! - **conservation** — at the serving boundary every offered request
//!   lands in exactly one bucket: `offered == completed + shed +
//!   failed`.
//!
//! Faults are drawn from a seeded [`FaultPlan`], so every point of the
//! sweep is exactly reproducible: same seed, same faults, same
//! degraded outputs.  Feeds `benches/chaos.rs` (`BENCH_chaos.json`)
//! and `repro chaos`.

use std::time::Instant;

use anyhow::Result;

use crate::coordinator::engine::StreamedStep;
use crate::coordinator::scheduler::{
    ExpertBackend, ExpertWeights, Scheduler, ShardLayout, WavePolicy,
};
use crate::coordinator::{Dispatcher, FaultPlan, RecoveryPolicy, Router};
use crate::runtime::TensorF;
use crate::serve::{AdmissionPolicy, ServeConfig, ServeLoop, TimedRequest};
use crate::util::rng::Rng;

/// One chaos configuration: a small sharded MoE plus the injected
/// [`FaultPlan`] it runs under.
pub struct ChaosSim {
    pub devices: usize,
    pub n_experts: usize,
    pub d_model: usize,
    pub hidden: usize,
    pub k: usize,
    pub rows_per_replica: usize,
    /// per-expert dispatch/wave capacity (capacity factor 1.25)
    pub capacity: usize,
    pub seed: u64,
    pub plan: FaultPlan,
    router: Router,
    weights: Vec<ExpertWeights>,
    xs: Vec<TensorF>,
    sched: Scheduler,
}

/// Model-size constants shared by every point of the sweep (small: the
/// harness measures recovery behaviour, not throughput).
const D_MODEL: usize = 8;
const HIDDEN: usize = 16;
const TOP_K: usize = 2;

fn build_model(
    seed: u64,
    devices: usize,
    n_experts: usize,
    rows_per_replica: usize,
) -> (Router, Vec<ExpertWeights>, Vec<TensorF>) {
    let (d, h) = (D_MODEL, HIDDEN);
    let mut rng = Rng::new(seed);
    let weights: Vec<ExpertWeights> = (0..n_experts)
        .map(|_| ExpertWeights {
            w_in: (0..d * h).map(|_| rng.normal_f32() * 0.2).collect(),
            w_out: (0..h * d).map(|_| rng.normal_f32() * 0.2).collect(),
            d_model: d,
            hidden: h,
        })
        .collect();
    let router = Router::flat_native(
        d,
        n_experts,
        TOP_K,
        (0..d * n_experts).map(|_| rng.normal_f32() * 0.4).collect(),
        Some((0..d * n_experts).map(|_| rng.normal_f32() * 0.3).collect()),
    );
    let xs: Vec<TensorF> = (0..devices)
        .map(|_| {
            TensorF::new(
                vec![rows_per_replica, d],
                (0..rows_per_replica * d).map(|_| rng.normal_f32()).collect(),
            )
        })
        .collect();
    (router, weights, xs)
}

impl ChaosSim {
    /// One replica per device, `rows_per_replica` tokens each, GShard
    /// capacity buffers on (so failed routes have reroute machinery to
    /// land on), the whole model drawn from one seeded stream.
    pub fn build(
        devices: usize,
        n_experts: usize,
        rows_per_replica: usize,
        plan: FaultPlan,
        seed: u64,
    ) -> Result<Self> {
        anyhow::ensure!(devices >= 1 && n_experts >= devices);
        let tokens = devices * rows_per_replica;
        let capacity =
            Dispatcher::capacity_for(1.25, tokens, TOP_K, n_experts);
        let (router, weights, xs) =
            build_model(seed, devices, n_experts, rows_per_replica);
        let sched = Scheduler::with_policy(
            ShardLayout::new(devices, n_experts),
            ExpertBackend::Native,
            WavePolicy::Fixed(Some(capacity)),
        )
        .with_dispatch_capacity(Some(capacity))
        .with_fault_plan(Some(plan.clone()));
        Ok(ChaosSim {
            devices,
            n_experts,
            d_model: D_MODEL,
            hidden: HIDDEN,
            k: TOP_K,
            rows_per_replica,
            capacity,
            seed,
            plan,
            router,
            weights,
            xs,
            sched,
        })
    }

    /// One streamed step under the fault plan (seeded eq-4 noise;
    /// `fold` varies the gating draw across steps deterministically
    /// while the fault draws follow the engine's own step counter).
    pub fn step(&self, fold: u64) -> Result<(StreamedStep, u64)> {
        let refs: Vec<&TensorF> = self.xs.iter().collect();
        let mut nrng = Rng::new(self.seed).fold_in(fold);
        let t0 = Instant::now();
        let s = self.sched.execute_streamed(
            &self.router,
            &refs,
            &self.weights,
            Some(&mut nrng),
        )?;
        Ok((s, t0.elapsed().as_nanos() as u64))
    }

    /// Replay a paced request burst on a [`ServeLoop`] running the same
    /// model under the same fault plan, with retry-with-backoff and
    /// health-aware shedding on.
    pub fn serve_burst(
        &self,
        requests: usize,
    ) -> Result<crate::serve::ServeReport> {
        let (router, weights, _) = build_model(
            self.seed,
            self.devices,
            self.n_experts,
            self.rows_per_replica,
        );
        let sched = Scheduler::with_policy(
            ShardLayout::new(self.devices, self.n_experts),
            ExpertBackend::Native,
            WavePolicy::Fixed(Some(self.capacity)),
        )
        .with_dispatch_capacity(Some(self.capacity))
        .with_fault_plan(Some(self.plan.clone()));
        let cfg = ServeConfig {
            queue_depth: 64,
            policy: AdmissionPolicy::Reject,
            max_batch_tokens: 16,
            latency_budget_ns: 50_000,
            capture_outputs: false,
            retry_max: 1,
            retry_backoff_ns: 10_000,
            // generous SLO: health-aware shedding engages only when
            // shard deaths genuinely collapse live capacity
            deadline_ns: Some(2_000_000_000),
            ..Default::default()
        };
        let serve = ServeLoop::new(sched, router, weights, cfg)?;
        let mut rng = Rng::new(self.seed ^ 0x5eed);
        let d = self.d_model;
        let trace: Vec<TimedRequest> = (0..requests)
            .map(|i| TimedRequest {
                arrival_ns: i as u64 * 5_000,
                x: TensorF::new(
                    vec![2, d],
                    (0..2 * d).map(|_| rng.normal_f32()).collect(),
                ),
            })
            .collect();
        serve.run_trace(&trace)
    }
}

/// One measured point of the chaos sweep.
#[derive(Clone, Debug)]
pub struct ChaosPoint {
    pub fault_rate: f64,
    pub policy: RecoveryPolicy,
    pub shard_deaths: usize,
    pub steps: usize,
    /// worst measured step wall — liveness means this is finite and the
    /// loop got here at all
    pub max_step_ns: u64,
    pub failed_chunks: usize,
    pub redispatched_routes: usize,
    pub degraded_tokens: usize,
    pub renorm_mass_lost: f64,
    /// shards still live after the last step
    pub live_fraction: f64,
    /// every output value of every step was finite
    pub all_finite: bool,
    // serving-boundary conservation buckets
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub failed: u64,
    pub retried: u64,
}

impl ChaosPoint {
    /// The conservation invariant at the serving boundary.
    pub fn conserved(&self) -> bool {
        self.offered == self.completed + self.shed + self.failed
    }

    /// Publish the point into the unified registry, reusing the same
    /// `serve_*` / `fault_*` keys the serve loop and step executor
    /// publish under so one snapshot format covers the chaos sweep
    /// too; `chaos_live_fraction` is the sweep-only gauge (surviving
    /// shard capacity after the last step).
    pub fn publish(&self, reg: &mut crate::obs::Registry) {
        reg.counter_add("serve_offered", self.offered);
        reg.counter_add("serve_completed", self.completed);
        reg.counter_add("serve_shed", self.shed);
        reg.counter_add("serve_failed", self.failed);
        reg.counter_add("serve_retried", self.retried);
        reg.counter_add("fault_failed_chunks", self.failed_chunks as u64);
        reg.counter_add(
            "fault_redispatched_routes",
            self.redispatched_routes as u64,
        );
        reg.counter_add("fault_degraded_tokens", self.degraded_tokens as u64);
        reg.gauge_add("fault_renorm_mass_lost", self.renorm_mass_lost);
        reg.gauge_set("chaos_live_fraction", self.live_fraction);
    }
}

/// Run `steps` engine steps plus one serve burst for a configuration.
pub fn run_point(
    sim: &ChaosSim,
    steps: usize,
    requests: usize,
) -> Result<ChaosPoint> {
    let mut p = ChaosPoint {
        fault_rate: sim.plan.chunk_fail_rate,
        policy: sim.plan.policy,
        shard_deaths: sim.plan.shard_deaths.len(),
        steps,
        max_step_ns: 0,
        failed_chunks: 0,
        redispatched_routes: 0,
        degraded_tokens: 0,
        renorm_mass_lost: 0.0,
        live_fraction: 1.0,
        all_finite: true,
        offered: requests as u64,
        completed: 0,
        shed: 0,
        failed: 0,
        retried: 0,
    };
    for i in 0..steps {
        let (s, ns) = sim.step(i as u64 + 1)?;
        p.max_step_ns = p.max_step_ns.max(ns);
        p.failed_chunks += s.stats.failed_chunks;
        p.redispatched_routes += s.stats.redispatched_routes;
        p.degraded_tokens += s.stats.degraded_tokens;
        p.renorm_mass_lost += s.stats.renorm_mass_lost;
        p.all_finite &= s
            .outs
            .iter()
            .all(|o| o.data.iter().all(|v| v.is_finite()));
    }
    p.live_fraction = sim.sched.live_fraction();
    let report = sim.serve_burst(requests)?;
    p.offered = report.stats.offered;
    p.completed = report.stats.completed;
    p.shed = report.stats.shed;
    p.failed = report.stats.failed;
    p.retried = report.stats.retried;
    Ok(p)
}

/// One formatted row of the chaos table (shared by `repro chaos` and
/// the quickstart).
pub fn point_line(p: &ChaosPoint) -> String {
    let policy = match p.policy {
        RecoveryPolicy::Redispatch => "redispatch",
        RecoveryPolicy::DegradeOnly => "degrade",
    };
    format!(
        "rate={:<5.2} {:<10} deaths={:<2} live={:>4.0}%  \
         chunks_failed={:<4} redisp={:<4} degraded_tok={:<5} \
         mass_lost={:>8.4}  step_max={:>8.3}ms  \
         serve {}+{}+{}/{} (ok+shed+failed/offered){}",
        p.fault_rate,
        policy,
        p.shard_deaths,
        p.live_fraction * 100.0,
        p.failed_chunks,
        p.redispatched_routes,
        p.degraded_tokens,
        p.renorm_mass_lost,
        p.max_step_ns as f64 / 1e6,
        p.completed,
        p.shed,
        p.failed,
        p.offered,
        if p.conserved() { "" } else { "  CONSERVATION BROKEN" },
    )
}

/// The chaos study: every fault rate × both recovery policies, plus a
/// shard-death schedule (including one seed where every shard dies) at
/// the maximum rate.  Returns every point after asserting liveness and
/// conservation on each.
pub fn run_chaos_study(
    rows_per_replica: usize,
    fault_rates: &[f64],
    seed: u64,
) -> Result<Vec<ChaosPoint>> {
    let (devices, n_experts) = (4usize, 8usize);
    let (steps, requests) = (3usize, 32usize);
    let mut points = Vec::new();
    println!(
        "chaos study ({devices} devices, {n_experts} experts, \
         deterministic seeded faults):"
    );
    for &rate in fault_rates {
        for policy in [RecoveryPolicy::Redispatch, RecoveryPolicy::DegradeOnly]
        {
            let plan = FaultPlan {
                seed: seed ^ 0xc4a0_5000,
                chunk_fail_rate: rate,
                straggler_rate: rate * 0.5,
                straggler_delay_ns: 30_000,
                deadline_ns: 60_000,
                combine_drop_rate: rate * 0.25,
                shard_deaths: Vec::new(),
                policy,
            };
            let sim = ChaosSim::build(
                devices,
                n_experts,
                rows_per_replica,
                plan,
                seed,
            )?;
            let p = run_point(&sim, steps, requests)?;
            println!("  {}", point_line(&p));
            points.push(p);
        }
    }
    // shard deaths at the max rate: one shard dies mid-run, and the
    // all-dead extreme (every shard dead from step 0) must still
    // terminate with finite (all-zero) outputs
    let max_rate = fault_rates.iter().copied().fold(0.0, f64::max);
    for deaths in [
        vec![(1u64, 1usize)],
        (0..devices).map(|sh| (0u64, sh)).collect::<Vec<_>>(),
    ] {
        let plan = FaultPlan {
            seed: seed ^ 0xdead,
            chunk_fail_rate: max_rate,
            straggler_rate: 0.0,
            straggler_delay_ns: 0,
            deadline_ns: u64::MAX,
            combine_drop_rate: max_rate * 0.25,
            shard_deaths: deaths,
            policy: RecoveryPolicy::Redispatch,
        };
        let sim =
            ChaosSim::build(devices, n_experts, rows_per_replica, plan, seed)?;
        let p = run_point(&sim, steps, requests)?;
        println!("  {}", point_line(&p));
        points.push(p);
    }
    for p in &points {
        anyhow::ensure!(
            p.all_finite,
            "non-finite output at rate {} policy {:?}",
            p.fault_rate,
            p.policy
        );
        anyhow::ensure!(
            p.max_step_ns > 0 && p.max_step_ns < 60_000_000_000,
            "step latency unbounded at rate {}",
            p.fault_rate
        );
        anyhow::ensure!(
            p.conserved(),
            "conservation broken at rate {}: {} != {} + {} + {}",
            p.fault_rate,
            p.offered,
            p.completed,
            p.shed,
            p.failed
        );
    }
    Ok(points)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_rate_point_is_fault_free_and_conserved() {
        let sim = ChaosSim::build(
            2,
            4,
            6,
            FaultPlan::none(3),
            11,
        )
        .unwrap();
        let p = run_point(&sim, 2, 12).unwrap();
        assert_eq!(p.failed_chunks, 0);
        assert_eq!(p.degraded_tokens, 0);
        assert_eq!(p.failed, 0);
        assert_eq!(p.live_fraction, 1.0);
        assert!(p.all_finite);
        assert!(p.conserved());
        assert_eq!(p.completed + p.shed, p.offered);
        // the registry view carries the same ledger
        let mut reg = crate::obs::Registry::new();
        p.publish(&mut reg);
        let s = reg.snapshot();
        assert_eq!(s.counter("serve_offered"), p.offered);
        assert_eq!(
            s.counter("serve_offered"),
            s.counter("serve_completed")
                + s.counter("serve_shed")
                + s.counter("serve_failed")
        );
        assert_eq!(s.gauge("chaos_live_fraction"), 1.0);
    }

    #[test]
    fn faulty_point_recovers_and_conserves() {
        let plan = FaultPlan {
            seed: 5,
            chunk_fail_rate: 0.3,
            combine_drop_rate: 0.1,
            ..Default::default()
        };
        let sim = ChaosSim::build(2, 4, 8, plan, 13).unwrap();
        let p = run_point(&sim, 3, 16).unwrap();
        assert!(p.failed_chunks > 0, "rate 0.3 must hit some chunk");
        assert!(p.all_finite, "degraded outputs must stay finite");
        assert!(p.conserved());
    }
}
