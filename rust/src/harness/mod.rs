//! Experiment harnesses: one entry point per paper table/figure.
//! See DESIGN.md's experiment index for the mapping.

pub mod distributed;
pub mod experiments;
pub mod tables;
pub mod workload;

pub use experiments::{run_lm_experiment, LmRun};
pub use workload::SyntheticMoe;
