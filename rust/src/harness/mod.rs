//! Experiment harnesses: one entry point per paper table/figure.
//! See DESIGN.md's experiment index for the mapping.

pub mod chaos;
pub mod cluster_sim;
pub mod distributed;
pub mod experiments;
pub mod tables;
pub mod workload;

pub use chaos::{run_chaos_study, ChaosPoint, ChaosSim};
pub use cluster_sim::{run_scaling_study, ClusterPoint, ClusterSim};
pub use experiments::{run_lm_experiment, LmRun};
pub use workload::SyntheticMoe;
