//! One harness per paper table/figure.  Each prints the same rows/series
//! the paper reports, computed on the synthetic substrate (DESIGN.md
//! §Substitutions).  Absolute numbers differ from the paper (different
//! corpus, simulated cluster); the SHAPES — who wins, by what factor,
//! where curves bend — are the reproduction targets, recorded in
//! EXPERIMENTS.md.

use anyhow::Result;

use crate::data::synthetic::{CorpusSpec, TopicCorpus};
use crate::data::translation::TranslationTask;
use crate::data::Vocab;
use crate::ngram::KneserNey;
use crate::runtime::{Engine, Manifest};
use crate::translate::bleu;
use crate::util::rng::Rng;

use super::experiments::{run_lm_experiment, ExperimentOpts, LmRun};

fn engine_manifest(artifacts: &str) -> Result<(Engine, Manifest)> {
    Ok((Engine::new()?, Manifest::load(artifacts)?))
}

fn cv(x: f64) -> f64 {
    x.max(0.0).sqrt() // metrics carry CV^2; tables report CV
}

fn print_lm_header() {
    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>8} {:>8} {:>9} {:>8}",
        "model", "test ppl", "ops/ts", "MoE params", "CV(imp)", "CV(load)",
        "max/mean", "TFLOPS"
    );
}

fn print_lm_row(r: &LmRun) {
    println!(
        "{:<16} {:>10.2} {:>12} {:>12} {:>8.3} {:>8.3} {:>9.2} {:>8.2}",
        r.config,
        r.test_perplexity,
        r.ops_per_timestep,
        r.moe_params,
        cv(r.cv_importance),
        cv(r.cv_load),
        r.max_over_mean_load,
        r.tflops_per_device
    );
}

/// Figure 2-left: test perplexity vs MoE capacity at matched ~ops/timestep.
/// Figure 2-right: perplexity vs computational budget.
pub fn fig2(artifacts: &str, steps: u64, side: &str) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    let configs: &[&str] = match side {
        "right" => &["lstm-4x", "lstm-big", "moe-lowbudget", "moe-midbudget",
                     "moe-highbudget"],
        _ => &["moe-4", "moe-32", "moe-256", "moe-256-h", "moe-1024-h"],
    };
    println!("# Figure 2-{side}: perplexity vs {}", if side == "right" {
        "computational budget"
    } else {
        "capacity (matched ops/timestep)"
    });
    print_lm_header();
    let opts = ExperimentOpts { steps, ..Default::default() };
    for cfg in configs {
        let r = run_lm_experiment(&engine, &manifest, cfg, &opts)?;
        print_lm_row(&r);
    }
    Ok(())
}

/// Table 1 analogue: high-capacity MoE at three budgets vs dense baseline.
pub fn table1(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Table 1: high-capacity MoE models vs best dense baseline");
    print_lm_header();
    let opts = ExperimentOpts { steps, ..Default::default() };
    for cfg in ["lstm-big", "moe-lowbudget", "moe-midbudget", "moe-highbudget"] {
        let r = run_lm_experiment(&engine, &manifest, cfg, &opts)?;
        print_lm_row(&r);
    }
    Ok(())
}

/// Table 6: w_importance/w_load ablation on the MoE-32 analogue.
pub fn table6(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Table 6: balancing-loss ablation (paper Appendix A)");
    println!(
        "{:<22} {:>10} {:>10} {:>10} {:>10}",
        "w_imp/w_load", "test ppl", "CV(imp)", "CV(load)", "max/mean"
    );
    let opts = ExperimentOpts { steps, ..Default::default() };
    for (wi, wl) in [("0.0", "0.0"), ("0.2", "0.0"), ("0.0", "0.2"),
                     ("0.1", "0.1"), ("0.01", "0.01"), ("1.0", "1.0")] {
        let cfg = format!("balance-wi{wi}-wl{wl}");
        let r = run_lm_experiment(&engine, &manifest, &cfg, &opts)?;
        println!(
            "{:<22} {:>10.2} {:>10.3} {:>10.3} {:>10.2}",
            format!("{wi} / {wl}"),
            r.test_perplexity,
            cv(r.cv_importance),
            cv(r.cv_load),
            r.max_over_mean_load
        );
    }
    Ok(())
}

/// Table 7: the full model ladder including computationally-matched
/// baselines and the KN 5-gram.
pub fn table7(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Table 7: model ladder on the topic corpus (1B-word analogue)");
    print_lm_header();
    // n-gram baseline row first (no neural artifacts involved)
    let ppl = kneser_ney_row(2048, 400_000, 40_000);
    println!("{:<16} {:>10.2} {:>12} {:>12}", "kn5", ppl, "~0", 0);
    let opts = ExperimentOpts { steps, ..Default::default() };
    for cfg in ["lstm-big", "lstm-4x", "moe-1-wide", "moe-1-deep", "moe-4",
                "moe-32", "moe-256", "moe-256-h", "moe-1024-h"] {
        let r = run_lm_experiment(&engine, &manifest, cfg, &opts)?;
        print_lm_row(&r);
    }
    Ok(())
}

/// Figure 3 / Table 8: the larger-corpus capacity sweep (0.1 vs 1 epoch
/// analogue: fewer vs more training steps on a wider topic corpus).
pub fn table8(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Table 8 / Figure 3: capacity sweep on the 100B-word analogue");
    println!("(corpus: 4x more topics than the Table 7 corpus)");
    let corpus = CorpusSpec { n_topics: 128, ..CorpusSpec::default() };
    println!(
        "{:<16} {:>10} {:>10} {:>12} {:>8}",
        "model", "steps", "test ppl", "MoE params", "TFLOPS"
    );
    let ppl = kneser_ney_row(2048, 400_000, 40_000);
    println!("{:<16} {:>10} {:>10.2} {:>12} {:>8}", "kn5", "-", ppl, 0, "-");
    for cfg in ["lstm-4x", "moe-32", "moe-256", "moe-256-h", "moe-1024-h"] {
        for mult in [1u64, 4] {
            let opts = ExperimentOpts {
                steps: steps * mult,
                corpus: corpus.clone(),
                devices: 32,
                ..Default::default()
            };
            let r = run_lm_experiment(&engine, &manifest, cfg, &opts)?;
            println!(
                "{:<16} {:>10} {:>10.2} {:>12} {:>8.2}",
                r.config,
                r.steps,
                r.test_perplexity,
                r.moe_params,
                r.tflops_per_device
            );
        }
    }
    Ok(())
}

/// Tables 2/3/4 analogue: single-pair MT, MoE vs dense at matched ops.
pub fn mt_single(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Tables 2-4: synthetic single-pair translation");
    println!(
        "{:<12} {:>10} {:>8} {:>12}",
        "model", "test ppl", "BLEU", "ops/ts"
    );
    for cfg in ["mt-dense", "mt-moe"] {
        let (ppl, b) = mt_run(&engine, &manifest, cfg, 7, steps)?;
        let ops = manifest.config(cfg)?.config.ops_per_timestep;
        println!("{:<12} {:>10.2} {:>8.2} {:>12}", cfg, ppl, b, ops);
    }
    Ok(())
}

/// Table 5 analogue: multilingual — one model on 4 language pairs vs
/// per-pair dense models.
pub fn mt_multi(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Table 5: multilingual translation (4 synthetic pairs)");
    let pairs: Vec<u64> = vec![11, 22, 33, 44];
    // multilingual MoE: one model over all pairs
    let (_, multi_bleus) =
        mt_run_multi(&engine, &manifest, "mt-moe", &pairs, steps)?;
    let (_, dense_bleus) =
        mt_run_multi(&engine, &manifest, "mt-dense", &pairs, steps)?;
    println!(
        "{:<10} {:>14} {:>14} {:>8}",
        "pair", "MoE-Multi", "Dense-Multi", "delta"
    );
    for (i, p) in pairs.iter().enumerate() {
        println!(
            "{:<10} {:>14.2} {:>14.2} {:>8.2}",
            format!("pair-{p}"),
            multi_bleus[i],
            dense_bleus[i],
            multi_bleus[i] - dense_bleus[i]
        );
    }
    Ok(())
}

/// Table 9 analogue: expert specialisation — which topics each expert
/// serves (the synthetic analogue of syntax/semantics contexts).
pub fn table9(artifacts: &str, steps: u64) -> Result<()> {
    use crate::coordinator::router::Router;
    let (engine, manifest) = engine_manifest(artifacts)?;
    let cfg = "moe-32";
    let entry = manifest.config(cfg)?.clone();
    let c = entry.config.clone();
    println!("# Table 9: expert specialisation on the topic corpus");
    let opts = ExperimentOpts {
        steps,
        checkpoint: Some(std::env::temp_dir().join("moe_table9.ckpt")),
        ..Default::default()
    };
    run_lm_experiment(&engine, &manifest, cfg, &opts)?;
    let state = crate::train::checkpoint::load(
        &std::env::temp_dir().join("moe_table9.ckpt"),
        cfg,
    )?;
    // Route embedded tokens through the trained gating net and report the
    // top words per expert.
    let wg = entry.slice(&state.params.data, "moe.wg")?.to_vec();
    let router = Router::flat_native(c.d_model, c.n_experts, c.k, wg, None);
    let emb = entry.slice(&state.params.data, "embed")?;
    let vocab = Vocab::synthetic(c.vocab);
    let x = crate::runtime::TensorF::new(
        vec![c.vocab, c.d_model],
        emb.to_vec(),
    );
    let dec = router.route(&x, None)?;
    let mut per_expert: Vec<Vec<(f32, i32)>> = vec![vec![]; c.n_experts];
    for (word, tok) in dec.per_token.iter().enumerate() {
        for (e, w) in tok.experts.iter().zip(tok.weights.iter()) {
            per_expert[*e].push((*w, word as i32));
        }
    }
    for (e, mut words) in per_expert.into_iter().enumerate() {
        words.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        let top: Vec<&str> =
            words.iter().take(8).map(|(_, w)| vocab.word(*w)).collect();
        println!("expert {e:>3}: {}", top.join(" "));
    }
    Ok(())
}

/// Figure 4 analogue: perplexity vs tokens processed per capacity.
pub fn fig4(artifacts: &str, steps: u64) -> Result<()> {
    let (engine, manifest) = engine_manifest(artifacts)?;
    println!("# Figure 4: test perplexity vs training tokens");
    println!("{:<14} {:>12} {:>10}", "model", "tokens", "test ppl");
    for cfg in ["lstm-4x", "moe-32", "moe-256"] {
        for frac in [1u64, 4] {
            let opts = ExperimentOpts {
                steps: steps * frac / 4,
                ..Default::default()
            };
            let r = run_lm_experiment(&engine, &manifest, cfg, &opts)?;
            let c = &manifest.config(cfg)?.config;
            println!(
                "{:<14} {:>12} {:>10.2}",
                cfg,
                r.steps * (c.batch * c.seq_len) as u64,
                r.test_perplexity
            );
        }
    }
    Ok(())
}

// ------------------------------------------------------------------ MT --

fn mt_run(engine: &Engine, manifest: &Manifest, cfg: &str, pair: u64,
          steps: u64) -> Result<(f64, f64)> {
    let (ppl, bleus) = mt_run_multi(engine, manifest, cfg, &[pair], steps)?;
    Ok((ppl, bleus[0]))
}

/// Train a prefix-LM seq2seq on one or more synthetic pairs; returns
/// (dev perplexity, per-pair BLEU via the decode artifact, greedy beam 4).
fn mt_run_multi(engine: &Engine, manifest: &Manifest, cfg: &str,
                pairs: &[u64], steps: u64) -> Result<(f64, Vec<f64>)> {
    use crate::data::synthetic::EOS;
    use crate::translate::BeamDecoder;
    use crate::train::Trainer;

    let trainer = Trainer::new(engine, manifest, cfg)?;
    let c = trainer.entry.config.clone();
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab: c.vocab,
        n_topics: 8,
        branch: 3,
        mean_len: 7,
        seed: 100,
    });
    let tasks: Vec<TranslationTask> =
        pairs.iter().map(|&p| TranslationTask::new(p, c.vocab)).collect();
    let mut state = trainer.init(0)?;
    let mut rng = Rng::new(42);
    for step in 0..steps {
        let task = &tasks[(step as usize) % tasks.len()];
        let batch = task.batch(&corpus, &mut rng, c.batch, c.seq_len);
        trainer.step(&mut state, &batch)?;
    }
    // dev perplexity over fresh batches from all pairs
    let mut eval_rng = Rng::new(4242);
    let dev: Vec<_> = tasks
        .iter()
        .map(|t| t.batch(&corpus, &mut eval_rng, c.batch, c.seq_len))
        .collect();
    let ppl = trainer.evaluate_tokens(&state, &dev)?.perplexity();

    // BLEU: decode continuations after `<s> src <sep>` and compare
    let decoder = BeamDecoder::new(
        engine.load(manifest, cfg, "decode")?,
        &trainer.entry,
    );
    let mut bleus = Vec::new();
    let seg = (c.seq_len + 1 - 3) / 2;
    for task in &tasks {
        let mut pairs_scored = Vec::new();
        let mut drng = Rng::new(777 ^ task.pair_id);
        for _ in 0..12 {
            let (src, tgt) = task.example(&corpus, &mut drng);
            let src = &src[..src.len().min(seg)];
            let tgt = &tgt[..tgt.len().min(seg)];
            let mut prefix = vec![crate::data::synthetic::BOS];
            prefix.extend_from_slice(src);
            prefix.push(crate::data::translation::SEP);
            let hyps = decoder.decode(&state.params, &prefix, 4,
                                      seg + 2, EOS)?;
            let mut hyp = hyps
                .first()
                .map(|h| h.tokens.clone())
                .unwrap_or_default();
            hyp.retain(|&t| t != EOS);
            let mut reference = tgt.to_vec();
            reference.retain(|&t| t != EOS);
            pairs_scored.push((hyp, reference));
        }
        bleus.push(bleu(&pairs_scored));
    }
    Ok((ppl, bleus))
}

// --------------------------------------------------------------- ngram --

fn kneser_ney_row(vocab: usize, train_tokens: usize, test_tokens: usize) -> f64 {
    let corpus = TopicCorpus::new(CorpusSpec {
        vocab,
        ..CorpusSpec::default()
    });
    let mut train = vec![0i32; train_tokens];
    corpus.stream(0).fill(&mut train);
    let mut test = vec![0i32; test_tokens];
    corpus.stream(1 << 32).fill(&mut test);
    let mut kn = KneserNey::new(5, vocab);
    kn.train(&train);
    kn.perplexity(&test)
}
