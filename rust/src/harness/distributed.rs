//! Distributed MoE demo + efficiency report: exercises the full L3 stack
//! on simulated devices through the streamed dependency-driven step
//! executor (`Scheduler::execute_streamed`: routing, dispatch, expert
//! compute and per-replica combine pipelined on the engine), and feeds
//! the REAL dispatch traffic into the K40 cluster model to regenerate
//! the paper's TFLOPS/GPU efficiency columns.  Per-step telemetry
//! includes the per-phase ns breakdown and the combine-overlap ratio.

use anyhow::{bail, Result};

use crate::cluster::perf::{model_step, ClusterSpec};
use crate::coordinator::router::Router;
use crate::coordinator::scheduler::{ExpertBackend, ExpertWeights, Scheduler, ShardLayout};
use crate::coordinator::BalanceMeter;
use crate::metrics::OpsModel;
use crate::runtime::{Engine, Manifest, TensorF};
use crate::util::rng::Rng;

/// Slice per-expert FFN weights out of the flat parameter vector.
pub fn expert_weights(entry: &crate::runtime::ConfigEntry, flat: &[f32])
    -> Result<Vec<ExpertWeights>> {
    let c = &entry.config;
    let (n, d, h) = (c.n_experts, c.d_model, c.expert_hidden);
    let w_in_all = entry.slice(flat, "moe.w_in")?;
    let w_out_all = entry.slice(flat, "moe.w_out")?;
    Ok((0..n)
        .map(|e| ExpertWeights {
            w_in: w_in_all[e * d * h..(e + 1) * d * h].to_vec(),
            w_out: w_out_all[e * h * d..(e + 1) * h * d].to_vec(),
            d_model: d,
            hidden: h,
        })
        .collect())
}

/// Build a router for a config from flat params (flat or hierarchical).
pub fn router_for(entry: &crate::runtime::ConfigEntry, flat: &[f32],
                  engine: &Engine, manifest: &Manifest, use_artifact: bool)
    -> Result<Router> {
    let c = &entry.config;
    if c.middle != "moe" {
        bail!("config '{}' has no MoE layer", c.name);
    }
    if c.groups > 0 {
        Ok(Router {
            backend: crate::coordinator::router::RouterBackend::Native,
            n_experts: c.n_experts,
            k: c.k,
            groups: c.groups,
            d_model: c.d_model,
            w_g: entry.slice(flat, "moe.wg_pri")?.to_vec(),
            w_noise: Some(entry.slice(flat, "moe.wn_pri")?.to_vec()),
            w_g_sec: Some(entry.slice(flat, "moe.wg_sec")?.to_vec()),
            w_n_sec: Some(entry.slice(flat, "moe.wn_sec")?.to_vec()),
        })
    } else {
        let backend = if use_artifact {
            crate::coordinator::router::RouterBackend::Artifact(
                engine.load(manifest, &c.name, "gating")?,
            )
        } else {
            crate::coordinator::router::RouterBackend::Native
        };
        Ok(Router {
            backend,
            n_experts: c.n_experts,
            k: c.k,
            groups: 0,
            d_model: c.d_model,
            w_g: entry.slice(flat, "moe.wg")?.to_vec(),
            w_noise: Some(entry.slice(flat, "moe.wn")?.to_vec()),
            w_g_sec: None,
            w_n_sec: None,
        })
    }
}

/// Run `steps` synchronous distributed MoE steps over `devices` simulated
/// devices and print per-step telemetry plus modelled timing.
pub fn run_distributed_demo(artifacts: &str, cfg: &str, devices: usize,
                            steps: usize) -> Result<()> {
    let engine = Engine::new()?;
    let manifest = Manifest::load(artifacts)?;
    let entry = manifest.config(cfg)?.clone();
    let c = entry.config.clone();
    if c.middle != "moe" {
        bail!("distributed demo needs a MoE config, got '{}'", c.name);
    }
    // fresh params from the init artifact; gating nets start at zero so
    // we perturb W_g slightly to make routing non-degenerate, as a few
    // training steps would.
    let trainer = crate::train::Trainer::new(&engine, &manifest, cfg)?;
    let mut state = trainer.init(0)?;
    let mut prng = Rng::new(17);
    {
        let p = entry.param(if c.groups > 0 { "moe.wg_pri" } else { "moe.wg" })?;
        for v in state.params.data[p.offset..p.offset + p.size()].iter_mut() {
            *v += prng.normal_f32() * 0.3;
        }
        if c.groups > 0 {
            let p = entry.param("moe.wg_sec")?;
            for v in state.params.data[p.offset..p.offset + p.size()].iter_mut() {
                *v += prng.normal_f32() * 0.3;
            }
        }
    }
    let weights = expert_weights(&entry, &state.params.data)?;
    let use_artifact = entry.artifacts.contains_key("gating");
    let router = router_for(&entry, &state.params.data, &engine, &manifest,
                            use_artifact)?;
    let backend = if entry.artifacts.contains_key("expert") {
        ExpertBackend::Artifact {
            exe: engine.load(&manifest, cfg, "expert")?,
            capacity: c.capacity,
        }
    } else {
        ExpertBackend::Native
    };
    let sched = Scheduler::new(ShardLayout::new(devices, c.n_experts), backend);
    let mut meter = BalanceMeter::new(c.n_experts);
    let cluster = ClusterSpec::k40s(devices);
    let ops = OpsModel::from_config(&c);
    let tokens_per_replica = c.batch * c.seq_len / devices.max(1);

    println!(
        "# distributed MoE: {} experts on {} devices, {} replica tokens/step \
         (streamed step executor)",
        c.n_experts, devices, tokens_per_replica * devices
    );
    let mut rng = Rng::new(3);
    let mut total_wall = 0.0;
    for step in 0..steps {
        // per-replica activations (stand-in for the LSTM output)
        let xs: Vec<TensorF> = (0..devices)
            .map(|_| {
                TensorF::new(
                    vec![tokens_per_replica, c.d_model],
                    (0..tokens_per_replica * c.d_model)
                        .map(|_| rng.normal_f32())
                        .collect(),
                )
            })
            .collect();
        let mut nrng = rng.fold_in(step as u64);
        let refs: Vec<&TensorF> = xs.iter().collect();
        // the streamed step executor: routing, dispatch, expert compute
        // and per-replica combine all pipelined on the engine (artifact
        // routers/backends fall back to the serially-composed step)
        let t0 = std::time::Instant::now();
        let s = sched.execute_streamed(&router, &refs, &weights, Some(&mut nrng))?;
        let wall = t0.elapsed().as_secs_f64();
        total_wall += wall;
        let stats = &s.stats;
        let counts = stats.expert_loads.clone();
        meter.record(&merge_vec(&s.decisions, |d| &d.importance),
                     &merge_vec(&s.decisions, |d| &d.load), &counts);
        let timing = model_step(&c, &cluster, tokens_per_replica, &counts);
        if step < 3 || step + 1 == steps {
            // xdev_net is the corrected §3.2 interconnect volume: only
            // routes landing on another device's shard count; a token
            // dispatched to an expert on its own device moves nothing
            let idle_max =
                stats.shard_idle_ns.iter().copied().max().unwrap_or(0);
            println!(
                "step {:>3}: routes={:<6} busiest_shard={:<5} waves={:<3} \
                 xdev_net={:>8}B  wall={:.3}s  measured: route {:.2}ms + gather \
                 {:.2}ms + compute {:.2}ms + combine {:.2}ms (+{:.2}ms \
                 hidden, overlap {:.0}%, max shard idle {:.2}ms)  \
                 modelled: dense {:.1}ms + moe {:.1}ms + a2a {:.1}ms",
                step,
                s.plan.total_routes(),
                stats.busiest_shard_tokens,
                stats.waves,
                stats.network_bytes,
                wall,
                stats.phases.route as f64 / 1e6,
                stats.phases.gather as f64 / 1e6,
                stats.phases.compute as f64 / 1e6,
                stats.phases.combine as f64 / 1e6,
                stats.phases.overlap_ns as f64 / 1e6,
                stats.combine_overlap_ratio() * 100.0,
                idle_max as f64 / 1e6,
                timing.dense_time * 1e3,
                timing.moe_compute_time * 1e3,
                timing.all_to_all_time * 1e3,
            );
        }
    }
    let (cvi, cvl, mm) = meter.summary();
    println!(
        "balance over {steps} steps: CV(imp)={cvi:.3} CV(load)={cvl:.3} \
         max/mean={mm:.2} busiest_share={:.3}",
        meter.busiest_share()
    );
    println!("wall total {total_wall:.2}s ({:.3}s/step)",
             total_wall / steps.max(1) as f64);
    let counts = vec![
        (c.batch * c.seq_len * c.k_effective) / c.n_experts.max(1);
        c.n_experts
    ];
    let timing = model_step(&c, &cluster, tokens_per_replica, &counts);
    println!(
        "modelled TFLOPS/device at balanced load: {:.2}",
        ops.tflops_per_device((c.batch * c.seq_len) as u64, timing.total(),
                              devices)
    );
    Ok(())
}

fn merge_vec<'a, F: Fn(&'a crate::coordinator::router::RoutingDecision) -> &'a [f32]>(
    decisions: &'a [crate::coordinator::router::RoutingDecision],
    f: F,
) -> Vec<f32> {
    let n = f(&decisions[0]).len();
    let mut out = vec![0f32; n];
    for d in decisions {
        for (o, v) in out.iter_mut().zip(f(d).iter()) {
            *o += v;
        }
    }
    out
}

/// Measured §3.1 economics on the persistent execution engine: runs a
/// synthetic Native-backend MoE step (no artifacts needed) and reports
/// the per-phase breakdown plus the busiest-shard wait, for the
/// streamed routing→dispatch pipeline next to the serially-composed
/// engine step and the retained serial reference path.  All three rows
/// include the full step (routing included), so the streamed row's win
/// is the route/dispatch overlap, not a smaller workload.
pub fn measured_engine_report(devices: usize, tokens: usize) -> Result<()> {
    let devices = devices.max(1);
    let (d, h, n, k) = (64, 256, 64.max(devices), 4);
    let rows = (tokens / devices).max(1);
    let work = crate::harness::workload::SyntheticMoe::build(
        41, d, h, n, k, devices, rows,
    )?;
    let sched = Scheduler::new(ShardLayout::new(devices, n), ExpertBackend::Native);
    println!(
        "# measured MoE step: {} experts (k={k}) on {} simulated \
         devices, {} tokens",
        n,
        devices,
        work.tokens()
    );
    println!(
        "# matmul kernel: {} (MOE_KERNEL overrides; scalar = bit-exact \
         oracle)",
        crate::kernels::Kernel::selected_name()
    );
    work.run_streamed(&sched, None)?; // warm the engine + arenas
    let phase_line = crate::harness::workload::phase_line;
    let streamed_stats;
    {
        let t0 = std::time::Instant::now();
        let s = work.run_streamed(&sched, None)?;
        println!(
            "{:<22} wall {:>8.3}ms  {}",
            "streamed pipeline",
            t0.elapsed().as_secs_f64() * 1e3,
            phase_line(&s.stats),
        );
        streamed_stats = s.stats.clone();
    }
    {
        let t0 = std::time::Instant::now();
        let (_outs, stats) = work.run_unpipelined(&sched, None)?;
        println!(
            "{:<22} wall {:>8.3}ms  {}",
            "engine, serial route",
            t0.elapsed().as_secs_f64() * 1e3,
            phase_line(&stats),
        );
    }
    {
        let t0 = std::time::Instant::now();
        let (_outs, stats) = work.run_serial_reference(&sched, None)?;
        println!(
            "{:<22} wall {:>8.3}ms  {}",
            "serial reference",
            t0.elapsed().as_secs_f64() * 1e3,
            phase_line(&stats),
        );
    }
    // the same streamed-row numbers as a unified-registry snapshot —
    // the machine-readable form every console line above renders from
    // (and what `repro trace` / the Prometheus export serialise)
    let mut reg = crate::obs::Registry::new();
    streamed_stats.publish(&mut reg);
    println!("registry snapshot: {}", reg.snapshot().to_json().trim_end());
    Ok(())
}

/// §5.1 computational-efficiency table: modelled TFLOPS/GPU per config on
/// the simulated K40 cluster, at balanced and at collapsed routing,
/// preceded by the measured engine breakdown (which needs no artifacts).
pub fn efficiency_report(artifacts: &str, devices: usize, tokens: usize)
    -> Result<()> {
    measured_engine_report(devices, tokens)?;
    let manifest = match Manifest::load(artifacts) {
        Ok(m) => m,
        Err(e) => {
            println!(
                "(skipping modelled table: {e}; the measured section above \
                 is artifact-free)"
            );
            return Ok(());
        }
    };
    let cluster = ClusterSpec::k40s(devices);
    println!(
        "# modelled computational efficiency, {} simulated K40s",
        devices
    );
    println!(
        "{:<18} {:>9} {:>12} {:>14} {:>14}",
        "config", "ops/ts", "params", "TFLOPS(bal)", "TFLOPS(collapsed)"
    );
    for (name, entry) in &manifest.configs {
        let c = &entry.config;
        if name.starts_with("test-") || name.starts_with("balance-") {
            continue;
        }
        let tokens = c.batch * c.seq_len;
        let ops = OpsModel::from_config(c);
        let (bal, coll) = if c.middle == "moe" {
            let routed = tokens * c.k_effective;
            let balanced = vec![routed / c.n_experts.max(1); c.n_experts];
            let mut collapsed = vec![0usize; c.n_experts];
            collapsed[0] = routed;
            (
                model_step(c, &cluster, tokens / devices, &balanced),
                model_step(c, &cluster, tokens / devices, &collapsed),
            )
        } else {
            let t = model_step(c, &cluster, tokens / devices, &[]);
            (t.clone(), t)
        };
        println!(
            "{:<18} {:>9} {:>12} {:>14.2} {:>14.2}",
            name,
            c.ops_per_timestep,
            entry.param_size,
            ops.tflops_per_device(tokens as u64, bal.total(), devices),
            ops.tflops_per_device(tokens as u64, coll.total(), devices),
        );
    }
    Ok(())
}

/// Artifact-free native training demo (`repro train-native`): trains
/// the MoE sublayer end to end on the streamed executor with the
/// gating network *learning* — task gradients through the noisy top-k
/// plus the eq-6/eq-8 balance losses, Adam updates — and prints the
/// per-step balance-CV trajectory next to a frozen-gating baseline run
/// from the identical init, data and noise streams.  The CV columns
/// falling while the frozen ones hold is the paper's §4 story made
/// visible on a bare checkout.
pub fn native_training_demo(devices: usize, steps: usize) -> Result<()> {
    use crate::runtime::ModelConfig;
    use crate::train::{StreamedStepOptions, Trainer};

    let devices = devices.max(1);
    let steps = steps.max(2);
    let (d, h, n, k) = (16, 32, 4 * devices.max(2), 2);
    let rows = 64;
    let trainer = Trainer::native(ModelConfig::native_moe(
        "train-native", d, n, k, h, devices, rows,
    ));
    println!(
        "# native MoE training: {n} experts (k={k}) on {devices} simulated \
         devices, {} tokens/step, Adam lr 0.01, w_importance/w_load 0.1 \
         (no artifacts)",
        devices * rows
    );
    println!(
        "{:>4}  {:>10} {:>8} {:>8}   {:>10} {:>8} {:>8}",
        "step", "loss", "cv_imp", "cv_load", "frozen", "cv_imp", "cv_load"
    );
    let run = |train_gating: bool| -> Result<Vec<crate::train::StreamedStepMetrics>> {
        let mut state = trainer.init_streamed(17);
        let sched = Scheduler::new(
            ShardLayout::new(devices, n),
            ExpertBackend::Native,
        );
        let mut data_rng = Rng::new(5);
        let mk = |rng: &mut Rng, s: f32| -> Vec<TensorF> {
            (0..devices)
                .map(|_| {
                    TensorF::new(
                        vec![rows, d],
                        (0..rows * d).map(|_| rng.normal_f32() * s).collect(),
                    )
                })
                .collect()
        };
        let xs = mk(&mut data_rng, 1.0);
        let targets = mk(&mut data_rng, 0.5);
        let mut noise_rng = Rng::new(23);
        let opts = StreamedStepOptions {
            lr: 0.01,
            train_gating,
            w_importance: 0.1,
            w_load: 0.1,
        };
        (0..steps)
            .map(|_| {
                trainer.step_streamed_with(
                    &sched,
                    &mut state,
                    &xs,
                    &targets,
                    Some(&mut noise_rng),
                    &opts,
                )
            })
            .collect()
    };
    let learned = run(true)?;
    let frozen = run(false)?;
    let every = (steps / 10).max(1);
    for (i, (l, f)) in learned.iter().zip(frozen.iter()).enumerate() {
        if i % every == 0 || i + 1 == steps {
            println!(
                "{:>4}  {:>10.5} {:>8.3} {:>8.3}   {:>10.5} {:>8.3} {:>8.3}",
                i, l.loss, l.cv_importance, l.cv_load, f.loss,
                f.cv_importance, f.cv_load
            );
        }
    }
    let tail = |ms: &[crate::train::StreamedStepMetrics]| {
        let w = ms.len().min(10);
        let s: f64 = ms[ms.len() - w..].iter().map(|m| m.cv_importance).sum();
        s / w as f64
    };
    println!(
        "late-window CV(importance): learned {:.3} vs frozen {:.3} — the \
         eq-6/eq-8 losses keep {} experts balanced while the task trains",
        tail(&learned),
        tail(&frozen),
        n
    );
    Ok(())
}
