//! Shared experiment driver: train a config on the synthetic corpus,
//! evaluate held-out perplexity, and collect the efficiency estimates.
//! Every table/figure harness in [`super::tables`] builds on this.

use anyhow::Result;

use crate::cluster::perf::{model_step, ClusterSpec};
use crate::data::synthetic::{CorpusSpec, TopicCorpus};
use crate::data::Batcher;
use crate::metrics::OpsModel;
use crate::runtime::{Engine, Manifest};
use crate::train::{checkpoint, Trainer};

/// Result of one LM training run.
#[derive(Clone, Debug)]
pub struct LmRun {
    pub config: String,
    pub test_perplexity: f64,
    pub train_nll_last: f64,
    pub ops_per_timestep: u64,
    pub moe_params: u64,
    pub cv_importance: f64,
    pub cv_load: f64,
    pub max_over_mean_load: f64,
    pub dropped_frac: f64,
    pub steps: u64,
    pub wall_secs: f64,
    /// modelled TFLOPS/device on the simulated K40 cluster
    pub tflops_per_device: f64,
    /// metric curve: (step, train nll)
    pub curve: Vec<(u64, f64)>,
}

pub struct ExperimentOpts {
    pub steps: u64,
    pub eval_batches: usize,
    pub corpus: CorpusSpec,
    pub devices: usize,
    pub log_every: u64,
    pub checkpoint: Option<std::path::PathBuf>,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            steps: 200,
            eval_batches: 20,
            corpus: CorpusSpec::default(),
            devices: 16,
            log_every: 50,
            checkpoint: None,
        }
    }
}

/// Train `cfg` for `opts.steps` and measure everything the tables need.
pub fn run_lm_experiment(
    engine: &Engine,
    manifest: &Manifest,
    cfg: &str,
    opts: &ExperimentOpts,
) -> Result<LmRun> {
    let trainer = Trainer::new(engine, manifest, cfg)?;
    let c = &trainer.entry.config;
    let mut corpus_spec = opts.corpus.clone();
    corpus_spec.vocab = c.vocab;
    let corpus = TopicCorpus::new(corpus_spec);
    let mut train_batcher = Batcher::new(&corpus, c.batch, c.seq_len, 0);
    // held-out streams: ids far above any training row
    let mut test_batcher = Batcher::new(&corpus, c.batch, c.seq_len, 1 << 32);

    let t0 = std::time::Instant::now();
    let mut state = trainer.init(0)?;
    let metrics = trainer.run(&mut state, &mut train_batcher, opts.steps,
                              opts.log_every)?;
    let wall = t0.elapsed().as_secs_f64();
    let eval = trainer.evaluate(&state, &mut test_batcher, opts.eval_batches)?;

    if let Some(path) = &opts.checkpoint {
        checkpoint::save(path, cfg, &state)?;
    }

    // tail-window averages for balance stats (skip warmup noise)
    let tail = &metrics[metrics.len().saturating_sub(20)..];
    let avg = |f: fn(&crate::train::StepMetrics) -> f64| {
        tail.iter().map(f).sum::<f64>() / tail.len().max(1) as f64
    };

    // modelled efficiency on the simulated K40 cluster: balanced loads at
    // the measured dropped fraction
    let cluster = ClusterSpec::k40s(opts.devices);
    let tokens = c.batch * c.seq_len;
    let routed = (tokens * c.k_effective) as f64 * (1.0 - avg(|m| m.dropped_frac));
    let loads = if c.n_experts > 0 && c.middle == "moe" {
        let imbalance = avg(|m| m.max_over_mean_load).max(1.0);
        let mean = routed / c.n_experts as f64;
        let mut l = vec![mean as usize; c.n_experts];
        l[0] = (mean * imbalance) as usize; // busiest expert sets the pace
        l
    } else {
        vec![]
    };
    let timing = model_step(c, &cluster, tokens / opts.devices.max(1), &loads);
    let ops = OpsModel::from_config(c);
    let tflops =
        ops.tflops_per_device(tokens as u64, timing.total(), opts.devices);

    Ok(LmRun {
        config: cfg.to_string(),
        test_perplexity: eval.perplexity(),
        train_nll_last: avg(|m| m.nll),
        ops_per_timestep: c.ops_per_timestep,
        moe_params: c.moe_params,
        cv_importance: avg(|m| m.cv_importance),
        cv_load: avg(|m| m.cv_load),
        max_over_mean_load: avg(|m| m.max_over_mean_load),
        dropped_frac: avg(|m| m.dropped_frac),
        steps: opts.steps,
        wall_secs: wall,
        tflops_per_device: tflops,
        curve: metrics.iter().map(|m| (m.step, m.nll)).collect(),
    })
}
