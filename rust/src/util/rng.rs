//! Deterministic PRNG: splitmix64/xoshiro256** core with Box–Muller
//! normal sampling.  Used by the data generators, the rust-side gating
//! mirror (Gaussian gate noise, eq 4) and the property tests.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller sample
    spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (`jax.random.fold_in` analogue).
    pub fn fold_in(&self, data: u64) -> Rng {
        let mut sm = self.s[0] ^ data.wrapping_mul(0x2545f4914f6cdd1d);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        // xoshiro256**
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            v.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fold_in_changes_stream() {
        let base = Rng::new(7);
        let mut a = base.fold_in(1);
        let mut b = base.fold_in(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_prefers_heavy_weight() {
        let mut r = Rng::new(11);
        let w = [0.05, 0.9, 0.05];
        let mut counts = [0usize; 3];
        for _ in 0..2000 {
            counts[r.categorical(&w)] += 1;
        }
        assert!(counts[1] > 1500, "{counts:?}");
    }
}
