//! Small self-contained utilities.
//!
//! This repo builds fully offline against a vendored crate set that only
//! contains `xla` and `anyhow`, so the usual ecosystem crates are
//! re-implemented here at the scale we need: a JSON parser for the AOT
//! manifest ([`json`]), a deterministic PRNG with normal sampling
//! ([`rng`]), a micro benchmark harness ([`bench`]) and a tiny
//! property-testing helper ([`prop`]).

pub mod bench;
pub mod json;
pub mod prop;
pub mod rng;
