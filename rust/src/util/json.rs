//! Minimal JSON parser for the AOT manifest.
//!
//! The only JSON this repo ever reads is `artifacts/manifest.json`, which
//! our own `aot.py` emits, so the parser targets well-formed RFC 8259
//! documents and reports errors with byte offsets rather than recovering.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field access that errors with the key name.
    pub fn field(&self, key: &str) -> anyhow::Result<&Value> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Some(m),
            _ => None,
        }
    }
}

#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: input.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // no surrogate-pair handling: manifest is ASCII
                            out.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("true").unwrap().as_bool(), Some(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{,}").is_err());
        assert!(parse("[1 2]").is_err());
        assert!(parse("\"open").is_err());
        assert!(parse("{\"a\":1}x").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse("\"\\u0041\"").unwrap().as_str(), Some("A"));
    }
}
