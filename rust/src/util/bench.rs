//! Micro benchmark harness (criterion is not in the offline crate set).
//!
//! Warms up, then runs timed iterations until a wall-clock budget or an
//! iteration cap is reached, and reports mean / p50 / p95 plus derived
//! throughput.  Used by the `benches/*.rs` targets (harness = false).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }

    pub fn report_throughput(&self, unit: &str, per_iter: f64) {
        let rate = per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} mean {:>12?}  {:>12.1} {unit}/s",
            self.name, self.mean, rate
        );
    }
}

pub struct Bencher {
    budget: Duration,
    max_iters: usize,
    warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: Duration::from_secs(3), max_iters: 1000, warmup: 2 }
    }
}

impl Bencher {
    pub fn new(budget: Duration, max_iters: usize, warmup: usize) -> Self {
        Bencher { budget, max_iters, warmup }
    }

    pub fn quick() -> Self {
        Bencher { budget: Duration::from_secs(1), max_iters: 50, warmup: 1 }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.budget && samples.len() < self.max_iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let p = |q: f64| samples[((n - 1) as f64 * q) as usize];
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean,
            p50: p(0.5),
            p95: p(0.95),
        }
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(Duration::from_millis(50), 20, 1);
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 1);
        assert!(r.mean > Duration::ZERO);
        assert!(r.p95 >= r.p50);
    }
}
