//! Micro benchmark harness (criterion is not in the offline crate set).
//!
//! Warms up, then runs timed iterations until a wall-clock budget or an
//! iteration cap is reached, and reports mean / p50 / p95 plus derived
//! throughput.  Used by the `benches/*.rs` targets (harness = false).

use std::time::{Duration, Instant};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>8} iters  mean {:>12?}  p50 {:>12?}  p95 {:>12?}",
            self.name, self.iters, self.mean, self.p50, self.p95
        );
    }

    pub fn report_throughput(&self, unit: &str, per_iter: f64) {
        let rate = per_iter / self.mean.as_secs_f64();
        println!(
            "{:<44} mean {:>12?}  {:>12.1} {unit}/s",
            self.name, self.mean, rate
        );
    }
}

pub struct Bencher {
    budget: Duration,
    max_iters: usize,
    warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { budget: Duration::from_secs(3), max_iters: 1000, warmup: 2 }
    }
}

impl Bencher {
    pub fn new(budget: Duration, max_iters: usize, warmup: usize) -> Self {
        Bencher { budget, max_iters, warmup }
    }

    pub fn quick() -> Self {
        Bencher { budget: Duration::from_secs(1), max_iters: 50, warmup: 1 }
    }

    /// One un-warmed iteration per case — the CI smoke job uses this to
    /// assert the benches run and emit well-formed JSON without
    /// spending minutes measuring.
    fn smoke() -> Self {
        Bencher { budget: Duration::ZERO, max_iters: 1, warmup: 0 }
    }

    fn smoke_requested() -> bool {
        std::env::var_os("BENCH_SMOKE").is_some()
    }

    /// [`smoke`](Self::smoke) when the `BENCH_SMOKE` env var is set,
    /// default timing otherwise.
    pub fn from_env() -> Self {
        if Self::smoke_requested() { Self::smoke() } else { Bencher::default() }
    }

    /// Like [`from_env`](Self::from_env) but with [`quick`](Self::quick)
    /// timing when `BENCH_SMOKE` is unset.
    pub fn from_env_quick() -> Self {
        if Self::smoke_requested() { Self::smoke() } else { Bencher::quick() }
    }

    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        // always at least one sample, so a zero budget means "run once"
        while samples.is_empty()
            || (start.elapsed() < self.budget && samples.len() < self.max_iters)
        {
            let t = Instant::now();
            f();
            samples.push(t.elapsed());
        }
        samples.sort();
        let n = samples.len().max(1);
        let mean = samples.iter().sum::<Duration>() / n as u32;
        let p = |q: f64| samples[((n - 1) as f64 * q) as usize];
        BenchResult {
            name: name.to_string(),
            iters: n,
            mean,
            p50: p(0.5),
            p95: p(0.95),
        }
    }
}

/// Prevent the optimizer from discarding a value (std::hint::black_box
/// wrapper kept for call-site clarity).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Exact sample histogram for latency telemetry: raw u64 nanosecond
/// samples, percentiles by nearest-rank over the sorted set (the same
/// convention as [`Bencher::run`]'s p50/p95).  Serving traces are
/// thousands of requests, so storing the samples outright is cheaper
/// and more precise than bucketing; used by
/// [`crate::serve::ServeStats`].
#[derive(Clone, Debug, Default)]
pub struct Histogram {
    samples: Vec<u64>,
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, ns: u64) {
        self.samples.push(ns);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Nearest-rank percentile, `q` in [0, 1]; 0 on an empty histogram
    /// (serving reports render before any request may have completed).
    pub fn percentile(&self, q: f64) -> u64 {
        self.percentiles(&[q])[0]
    }

    /// Batch variant of [`percentile`](Self::percentile): one sort for
    /// any number of quantiles (reports query several per histogram).
    pub fn percentiles(&self, qs: &[f64]) -> Vec<u64> {
        if self.samples.is_empty() {
            return vec![0; qs.len()];
        }
        let mut v = self.samples.clone();
        v.sort_unstable();
        qs.iter()
            .map(|&q| {
                v[((v.len() - 1) as f64 * q.clamp(0.0, 1.0)).round() as usize]
            })
            .collect()
    }

    /// Raw recorded samples, in push order (the registry merges whole
    /// serve-side histograms sample-exactly).
    pub fn samples(&self) -> &[u64] {
        &self.samples
    }

    /// Append every sample of `other` (exact merge — percentiles of the
    /// merged set are computed over the union, not approximated).
    pub fn merge(&mut self, other: &Histogram) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn mean_ns(&self) -> u64 {
        if self.samples.is_empty() {
            return 0;
        }
        (self.samples.iter().map(|&v| v as u128).sum::<u128>()
            / self.samples.len() as u128) as u64
    }

    pub fn max_ns(&self) -> u64 {
        self.samples.iter().copied().max().unwrap_or(0)
    }
}

/// Machine-readable bench output: accumulates [`BenchResult`]s and
/// writes a `BENCH_<name>.json` document (ns/op, throughput, arbitrary
/// per-phase extras) so the perf trajectory is tracked across PRs.  The
/// rendering is exactly the dialect [`crate::util::json`] parses —
/// round-trip asserted in tests.
pub struct BenchReport {
    bench: String,
    /// pre-rendered JSON objects, one per recorded result
    results: Vec<String>,
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        BenchReport { bench: bench.to_string(), results: Vec::new() }
    }

    /// Record one result, with optional throughput (`unit`, items per
    /// iteration) and extra numeric fields (e.g. per-phase ns).
    pub fn push(
        &mut self,
        r: &BenchResult,
        throughput: Option<(&str, f64)>,
        extra: &[(&str, f64)],
    ) {
        let mut obj = format!(
            "{{\"name\": {}, \"iters\": {}, \"mean_ns\": {}, \
             \"p50_ns\": {}, \"p95_ns\": {}",
            json_str(&r.name),
            r.iters,
            r.mean.as_nanos(),
            r.p50.as_nanos(),
            r.p95.as_nanos()
        );
        if let Some((unit, per_iter)) = throughput {
            let rate = per_iter / r.mean_secs();
            obj.push_str(&format!(
                ", \"unit\": {}, \"per_sec\": {}",
                json_str(unit),
                json_num(rate)
            ));
        }
        for (key, v) in extra {
            obj.push_str(&format!(", {}: {}", json_str(key), json_num(*v)));
        }
        obj.push('}');
        self.results.push(obj);
    }

    /// Render the full JSON document.
    pub fn render(&self) -> String {
        format!(
            "{{\"bench\": {}, \"results\": [{}]}}\n",
            json_str(&self.bench),
            self.results.join(", ")
        )
    }

    /// Write to `path` (conventionally `BENCH_<name>.json` in the repo
    /// root, committed so the trajectory is diffable across PRs).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

/// RFC 8259 string escaping (bench names are ASCII, but stay correct).
/// `pub(crate)`: the metrics-registry snapshot (`crate::obs::registry`)
/// renders the same JSON dialect.
pub(crate) fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Finite JSON number (NaN/inf have no JSON encoding; emit 0 instead).
pub(crate) fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "0".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bencher::new(Duration::from_millis(50), 20, 1);
        let mut acc = 0u64;
        let r = b.run("spin", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.iters >= 1);
        assert!(r.mean > Duration::ZERO);
        assert!(r.p95 >= r.p50);
    }

    #[test]
    fn zero_budget_runs_exactly_once() {
        let b = Bencher::new(Duration::ZERO, 1, 0);
        let mut n = 0;
        let r = b.run("once", || n += 1);
        assert_eq!(n, 1);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn histogram_percentiles_are_order_invariant_and_monotone() {
        let mut h = Histogram::new();
        for v in [50u64, 10, 40, 20, 30] {
            h.push(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.percentile(0.0), 10);
        assert_eq!(h.percentile(0.5), 30);
        assert_eq!(h.percentile(1.0), 50);
        assert!(h.percentile(0.5) <= h.percentile(0.95));
        assert!(h.percentile(0.95) <= h.percentile(0.99));
        assert_eq!(h.mean_ns(), 30);
        assert_eq!(h.max_ns(), 50);
        assert_eq!(
            h.percentiles(&[0.0, 0.5, 1.0]),
            vec![h.percentile(0.0), h.percentile(0.5), h.percentile(1.0)]
        );
        let empty = Histogram::new();
        assert_eq!(empty.percentile(0.5), 0);
        assert_eq!(empty.percentiles(&[0.5, 0.99]), vec![0, 0]);
        assert_eq!(empty.mean_ns(), 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn report_renders_parseable_json() {
        let b = Bencher::new(Duration::from_millis(10), 3, 0);
        let r = b.run("spin \"quoted\"", || {
            black_box(1 + 1);
        });
        let mut rep = BenchReport::new("step");
        rep.push(&r, Some(("tok", 4096.0)), &[("compute_ns", 123.0)]);
        rep.push(&r, None, &[]);
        let doc = crate::util::json::parse(&rep.render()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("step"));
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(
            results[0].get("name").unwrap().as_str(),
            Some("spin \"quoted\"")
        );
        assert!(results[0].get("per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            results[0].get("compute_ns").unwrap().as_f64(),
            Some(123.0)
        );
        assert!(results[1].get("iters").unwrap().as_usize().unwrap() >= 1);
        assert!(results[1].get("per_sec").is_none());
    }
}
