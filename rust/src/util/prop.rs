//! Tiny property-testing helper (proptest is not in the offline crate
//! set).  Runs a property over `CASES` randomized inputs derived from a
//! fixed seed; on failure it reports the case seed so the exact input can
//! be replayed with `case_rng(seed)`.

use super::rng::Rng;

pub const CASES: u64 = 64;

/// Run `prop` over `CASES` seeded RNGs; panics with the failing seed.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, mut prop: F) {
    for case in 0..CASES {
        let mut rng = case_rng(case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case seed {case}");
            std::panic::resume_unwind(e);
        }
    }
}

pub fn case_rng(case: u64) -> Rng {
    Rng::new(0xda7a_5eed ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15)))
}

/// Random dimension helpers for property tests.
pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

/// Central finite difference of `f` at `x0` with nominal step `h`.
/// The perturbed points are rounded to f32 (parameters are f32), so the
/// quotient divides by the *achieved* step `(x0+h) − (x0−h)`, not the
/// nominal `2h` — removing the quantization error that would otherwise
/// dominate near large `x0`.
pub fn central_diff(f: &mut dyn FnMut(f32) -> f64, x0: f32, h: f32) -> f64 {
    let (wp, wm) = (x0 + h, x0 - h);
    let step = wp as f64 - wm as f64;
    assert!(step > 0.0, "step underflow at x0={x0} h={h}");
    (f(wp) - f(wm)) / step
}

/// Check an analytic gradient against central finite differences, one
/// parameter at a time: `loss` is evaluated on a perturbed copy of
/// `base` (±`h` per coordinate, via [`central_diff`]) and each quotient
/// must match `analytic[i]` within
/// `|fd − an| ≤ tol · max(1, |fd|, |an|)` — relative for large
/// gradients, absolute at `tol` for small ones.  Panics with the
/// offending index and both values.  `loss` should be the *frozen-branch*
/// loss (fixed top-k selection / thresholds / relu masks) so piecewise
/// boundaries — exact duplicate logits included — stay differentiable;
/// see `rust/tests/grad_check.rs` for the harness built on this.
pub fn grad_check(
    name: &str,
    base: &[f32],
    analytic: &[f32],
    mut loss: impl FnMut(&[f32]) -> f64,
    h: f32,
    tol: f64,
) {
    assert_eq!(
        base.len(),
        analytic.len(),
        "{name}: {} params but {} analytic grads",
        base.len(),
        analytic.len()
    );
    let mut w = base.to_vec();
    for i in 0..w.len() {
        let x0 = base[i];
        let fd = central_diff(
            &mut |x| {
                w[i] = x;
                loss(&w)
            },
            x0,
            h,
        );
        w[i] = x0;
        let an = analytic[i] as f64;
        let scale = 1f64.max(fd.abs()).max(an.abs());
        assert!(
            (fd - an).abs() <= tol * scale,
            "{name}[{i}]: analytic {an:.6e} vs central difference {fd:.6e} \
             (|Δ| {:.3e} > {tol:.1e}·{scale:.3e})",
            (fd - an).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counter", |_| count += 1);
        assert_eq!(count, CASES);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", |rng| assert!(rng.uniform() < 0.5));
    }

    #[test]
    fn dim_bounds() {
        let mut rng = case_rng(0);
        for _ in 0..100 {
            let d = dim(&mut rng, 3, 9);
            assert!((3..=9).contains(&d));
        }
    }

    #[test]
    fn central_diff_recovers_polynomial_slope() {
        // f(x) = x³ − 2x: f'(x) = 3x² − 2
        let mut f = |x: f32| {
            let x = x as f64;
            x * x * x - 2.0 * x
        };
        for x0 in [-1.5f32, -0.2, 0.0, 0.8, 2.0] {
            let fd = central_diff(&mut f, x0, 1e-3);
            let want = 3.0 * (x0 as f64) * (x0 as f64) - 2.0;
            assert!((fd - want).abs() < 1e-4, "x0={x0}: {fd} vs {want}");
        }
    }

    #[test]
    fn grad_check_accepts_exact_and_rejects_wrong_gradients() {
        // L(w) = Σ w_i² + w_0·w_1 over f64
        let base = [0.5f32, -1.25, 2.0];
        let loss = |w: &[f32]| -> f64 {
            let s: f64 = w.iter().map(|x| (*x as f64) * (*x as f64)).sum();
            s + w[0] as f64 * w[1] as f64
        };
        let good = [
            2.0 * base[0] + base[1],
            2.0 * base[1] + base[0],
            2.0 * base[2],
        ];
        grad_check("quadratic", &base, &good, loss, 1e-3, 1e-4);
        let mut bad = good;
        bad[1] += 0.1;
        let r = std::panic::catch_unwind(|| {
            grad_check("bad quadratic", &base, &bad, loss, 1e-3, 1e-4)
        });
        assert!(r.is_err(), "a wrong gradient must fail the check");
    }
}
