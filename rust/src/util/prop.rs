//! Tiny property-testing helper (proptest is not in the offline crate
//! set).  Runs a property over `CASES` randomized inputs derived from a
//! fixed seed; on failure it reports the case seed so the exact input can
//! be replayed with `case_rng(seed)`.

use super::rng::Rng;

pub const CASES: u64 = 64;

/// Run `prop` over `CASES` seeded RNGs; panics with the failing seed.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, mut prop: F) {
    for case in 0..CASES {
        let mut rng = case_rng(case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || prop(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case seed {case}");
            std::panic::resume_unwind(e);
        }
    }
}

pub fn case_rng(case: u64) -> Rng {
    Rng::new(0xda7a_5eed ^ (case.wrapping_mul(0x9e37_79b9_7f4a_7c15)))
}

/// Random dimension helpers for property tests.
pub fn dim(rng: &mut Rng, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

pub fn vec_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall("counter", |_| count += 1);
        assert_eq!(count, CASES);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fails", |rng| assert!(rng.uniform() < 0.5));
    }

    #[test]
    fn dim_bounds() {
        let mut rng = case_rng(0);
        for _ in 0..100 {
            let d = dim(&mut rng, 3, 9);
            assert!((3..=9).contains(&d));
        }
    }
}
