//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the ONLY channel through which rust learns about model
//! shapes: parameter layout inside the flat vector, artifact input/output
//! signatures, ops/timestep accounting and the training hyperparameters
//! each config was lowered with.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::tensor::DType;
use crate::util::json::{self, Value};

#[derive(Clone, Debug)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

#[derive(Clone, Debug)]
pub struct ArtifactSig {
    pub file: String,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

#[derive(Clone, Debug)]
pub struct ParamEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
}

impl ParamEntry {
    pub fn size(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Hyperparameters the config was lowered with (subset rust needs).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub lstm_hidden: usize,
    pub lstm_proj: usize,
    pub middle: String,
    pub n_experts: usize,
    pub k: usize,
    pub groups: usize,
    pub expert_hidden: usize,
    pub capacity: usize,
    pub k_effective: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub w_importance: f64,
    pub w_load: f64,
    pub ops_per_timestep: u64,
    pub moe_params: u64,
    pub optimizer: String,
}

impl ModelConfig {
    /// A minimal MoE-sublayer config for the artifact-free native
    /// training path ([`crate::train::Trainer::native`]): only the
    /// fields the streamed MoE step consumes are meaningful, everything
    /// artifact-specific is zeroed.
    pub fn native_moe(
        name: &str,
        d_model: usize,
        n_experts: usize,
        k: usize,
        expert_hidden: usize,
        batch: usize,
        seq_len: usize,
    ) -> Self {
        ModelConfig {
            name: name.to_string(),
            vocab: 0,
            d_model,
            lstm_hidden: 0,
            lstm_proj: 0,
            middle: "moe".to_string(),
            n_experts,
            k,
            groups: 0,
            expert_hidden,
            capacity: 0,
            k_effective: k,
            batch,
            seq_len,
            w_importance: 0.1,
            w_load: 0.1,
            ops_per_timestep: 0,
            moe_params: (n_experts * 2 * d_model * expert_hidden) as u64,
            optimizer: "adam".to_string(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub config: ModelConfig,
    pub metric_names: Vec<String>,
    pub params: Vec<ParamEntry>,
    pub param_size: usize,
    pub opt_sizes: (usize, usize),
    pub decode_batch: usize,
    pub n_lstm: usize,
    pub artifacts: BTreeMap<String, ArtifactSig>,
}

impl ConfigEntry {
    pub fn param(&self, name: &str) -> Result<&ParamEntry> {
        self.params
            .iter()
            .find(|p| p.name == name)
            .ok_or_else(|| anyhow!("no param '{name}' in config"))
    }

    pub fn artifact(&self, kind: &str) -> Result<&ArtifactSig> {
        self.artifacts.get(kind).ok_or_else(|| {
            anyhow!("config '{}' has no '{kind}' artifact", self.config.name)
        })
    }

    /// Slice a named parameter tensor out of the flat vector.
    pub fn slice<'a>(&self, flat: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let p = self.param(name)?;
        if p.offset + p.size() > flat.len() {
            bail!("param '{name}' out of range of flat vector");
        }
        Ok(&flat[p.offset..p.offset + p.size()])
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
}

fn sig_list(v: &Value) -> Result<Vec<TensorSig>> {
    v.as_arr()
        .context("expected array of signatures")?
        .iter()
        .map(|s| {
            let shape = s
                .field("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|d| d.as_usize().context("dim"))
                .collect::<Result<_>>()?;
            let dtype = DType::parse(s.field("dtype")?.as_str().context("dtype")?)?;
            Ok(TensorSig { shape, dtype })
        })
        .collect()
}

fn get_usize(v: &Value, key: &str) -> Result<usize> {
    v.field(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("field '{key}' not a number"))
}

fn get_f64(v: &Value, key: &str) -> Result<f64> {
    v.field(key)?
        .as_f64()
        .ok_or_else(|| anyhow!("field '{key}' not a number"))
}

fn get_str(v: &Value, key: &str) -> Result<String> {
    Ok(v.field(key)?
        .as_str()
        .ok_or_else(|| anyhow!("field '{key}' not a string"))?
        .to_string())
}

fn parse_config(name: &str, v: &Value) -> Result<ConfigEntry> {
    let c = v.field("config")?;
    let config = ModelConfig {
        name: name.to_string(),
        vocab: get_usize(c, "vocab")?,
        d_model: get_usize(c, "d_model")?,
        lstm_hidden: get_usize(c, "lstm_hidden")?,
        lstm_proj: get_usize(c, "lstm_proj")?,
        middle: get_str(c, "middle")?,
        n_experts: get_usize(c, "n_experts")?,
        k: get_usize(c, "k")?,
        groups: get_usize(c, "groups")?,
        expert_hidden: get_usize(c, "expert_hidden")?,
        capacity: get_usize(c, "capacity")?,
        k_effective: get_usize(c, "k_effective")?,
        batch: get_usize(c, "batch")?,
        seq_len: get_usize(c, "seq_len")?,
        w_importance: get_f64(c, "w_importance")?,
        w_load: get_f64(c, "w_load")?,
        ops_per_timestep: get_f64(c, "ops_per_timestep")? as u64,
        moe_params: get_f64(c, "moe_params")? as u64,
        optimizer: get_str(c, "optimizer")?,
    };
    let metric_names = v
        .field("metrics")?
        .as_arr()
        .context("metrics")?
        .iter()
        .map(|m| Ok(m.as_str().context("metric name")?.to_string()))
        .collect::<Result<_>>()?;
    let params = v
        .field("param_layout")?
        .as_arr()
        .context("param_layout")?
        .iter()
        .map(|p| {
            Ok(ParamEntry {
                name: get_str(p, "name")?,
                shape: p
                    .field("shape")?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|d| d.as_usize().context("dim"))
                    .collect::<Result<_>>()?,
                offset: get_usize(p, "offset")?,
            })
        })
        .collect::<Result<Vec<_>>>()?;
    let opt = v.field("opt_sizes")?.as_arr().context("opt_sizes")?;
    let artifacts = v
        .field("artifacts")?
        .as_obj()
        .context("artifacts")?
        .iter()
        .map(|(k, a)| {
            Ok((
                k.clone(),
                ArtifactSig {
                    file: get_str(a, "file")?,
                    inputs: sig_list(a.field("inputs")?)?,
                    outputs: sig_list(a.field("outputs")?)?,
                },
            ))
        })
        .collect::<Result<BTreeMap<_, _>>>()?;
    Ok(ConfigEntry {
        config,
        metric_names,
        params,
        param_size: get_usize(v, "param_size")?,
        opt_sizes: (
            opt[0].as_usize().context("opt m size")?,
            opt[1].as_usize().context("opt v size")?,
        ),
        decode_batch: get_usize(v, "decode_batch")?,
        n_lstm: get_usize(v, "n_lstm")?,
        artifacts,
    })
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts`"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let configs = root
            .field("configs")?
            .as_obj()
            .context("configs")?
            .iter()
            .map(|(name, v)| Ok((name.clone(), parse_config(name, v)?)))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Manifest { dir, configs })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!(
                "config '{name}' not in manifest (have: {:?}); re-run `make artifacts`",
                self.configs.keys().collect::<Vec<_>>()
            )
        })
    }

    pub fn artifact_path(&self, sig: &ArtifactSig) -> PathBuf {
        self.dir.join(&sig.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "configs": {
        "t": {
          "config": {"name":"t","vocab":64,"d_model":16,"lstm_hidden":16,
            "lstm_proj":0,"middle":"moe","n_experts":4,"k":2,"groups":0,
            "expert_hidden":32,"capacity":24,"k_effective":2,"batch":4,
            "seq_len":6,"w_importance":0.1,"w_load":0.1,
            "ops_per_timestep":10000,"moe_params":4096,"optimizer":"adam"},
          "metrics": ["loss","nll"],
          "param_layout": [
            {"name":"embed","shape":[64,16],"offset":0,"init":"normal"},
            {"name":"moe.wg","shape":[16,4],"offset":1024,"init":"zeros"}],
          "param_size": 1088,
          "opt_sizes": [1088, 1088],
          "decode_batch": 8,
          "n_lstm": 2,
          "artifacts": {
            "step": {"file":"step_t.hlo.txt",
              "inputs":[{"shape":[1088],"dtype":"float32"},
                        {"shape":[4,7],"dtype":"int32"}],
              "outputs":[{"shape":[9],"dtype":"float32"}]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let root = json::parse(SAMPLE).unwrap();
        let entry =
            parse_config("t", root.field("configs").unwrap().field("t").unwrap())
                .unwrap();
        assert_eq!(entry.config.vocab, 64);
        assert_eq!(entry.params.len(), 2);
        assert_eq!(entry.param("moe.wg").unwrap().offset, 1024);
        let art = entry.artifact("step").unwrap();
        assert_eq!(art.inputs[1].dtype, DType::I32);
        assert_eq!(art.outputs[0].shape, vec![9]);
        assert!(entry.artifact("nope").is_err());
    }

    #[test]
    fn slice_param() {
        let root = json::parse(SAMPLE).unwrap();
        let entry =
            parse_config("t", root.field("configs").unwrap().field("t").unwrap())
                .unwrap();
        let flat = vec![0.5f32; 1088];
        assert_eq!(entry.slice(&flat, "moe.wg").unwrap().len(), 64);
        let short = vec![0.0f32; 10];
        assert!(entry.slice(&short, "moe.wg").is_err());
    }
}
