//! Host-side tensors and conversion to/from `xla::Literal`.
//!
//! The runtime moves three dtypes across the PJRT boundary: f32 (params,
//! activations, metrics), i32 (tokens, indices) and nothing else — the AOT
//! pipeline guarantees it (see manifest "dtype" fields, checked at load).

use anyhow::{bail, Context, Result};
use xla::{ArrayElement, Literal, NativeType};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor<T> {
    pub shape: Vec<usize>,
    pub data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI = Tensor<i32>;

impl<T: Copy + Default> Tensor<T> {
    pub fn new(shape: Vec<usize>, data: Vec<T>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![T::default(); n] }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Row-major index for a 2-D tensor.
    pub fn at2(&self, i: usize, j: usize) -> T {
        debug_assert_eq!(self.rank(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn row(&self, i: usize) -> &[T] {
        let cols = self.shape[self.rank() - 1];
        &self.data[i * cols..(i + 1) * cols]
    }

    // -- borrowed-buffer API -------------------------------------------
    //
    // The execution engine reuses gather/compute/combine storage across
    // steps; these constructors let a tensor adopt (and later release) a
    // caller-owned allocation instead of allocating per step.

    /// Build a zero-filled tensor on top of a recycled buffer, reusing
    /// its allocation (the buffer is cleared first).
    pub fn from_buffer(shape: Vec<usize>, mut buf: Vec<T>) -> Self {
        let n = shape.iter().product();
        buf.clear();
        buf.resize(n, T::default());
        Tensor { shape, data: buf }
    }

    /// Consume the tensor, releasing its backing buffer for reuse.
    pub fn into_buffer(self) -> Vec<T> {
        self.data
    }
}

impl<T: NativeType + ArrayElement + Copy + Default> Tensor<T> {
    pub fn to_literal(&self) -> Result<Literal> {
        let lit = Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit
            .array_shape()
            .context("literal is not an array (tuple leaked through?)")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<T>()?;
        Ok(Tensor::new(dims, data))
    }
}

/// Dtype tag used by the manifest signature checks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported artifact dtype '{other}'"),
        }
    }
}

/// A value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum Host {
    F32(TensorF),
    I32(TensorI),
}

impl Host {
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            Host::F32(t) => t.to_literal(),
            Host::I32(t) => t.to_literal(),
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            Host::F32(_) => DType::F32,
            Host::I32(_) => DType::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Host::F32(t) => &t.shape,
            Host::I32(t) => &t.shape,
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            Host::F32(t) => Ok(t),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Host::I32(t) => Ok(t),
            _ => bail!("expected i32 tensor"),
        }
    }

    pub fn into_f32(self) -> Result<TensorF> {
        match self {
            Host::F32(t) => Ok(t),
            _ => bail!("expected f32 tensor"),
        }
    }

    pub fn from_literal(lit: &Literal, dtype: DType) -> Result<Self> {
        Ok(match dtype {
            DType::F32 => Host::F32(Tensor::from_literal(lit)?),
            DType::I32 => Host::I32(Tensor::from_literal(lit)?),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = TensorF::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.rank(), 2);
        assert_eq!(t.at2(1, 2), 0.0);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        TensorF::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn buffer_reuse_roundtrip() {
        let t = TensorF::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let buf = t.into_buffer();
        let cap = buf.capacity();
        let t2 = TensorF::from_buffer(vec![1, 4], buf);
        assert_eq!(t2.shape, vec![1, 4]);
        assert_eq!(t2.data, vec![0.0; 4]);
        assert!(t2.data.capacity() >= cap.min(4));
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }
}
