//! Runtime: the PJRT bridge between the rust coordinator and the AOT'd
//! JAX/Pallas artifacts.
//!
//! Flow: `Manifest::load` (shapes + layout) -> `Engine::load` (HLO text ->
//! compile, cached) -> `Executable::run` (host tensors in, host tensors
//! out).  See /opt/xla-example/load_hlo for the reference wiring this
//! module generalises.

pub mod client;
pub mod manifest;
pub mod tensor;

pub use client::{Engine, ExecPhases, Executable};
pub use manifest::{ConfigEntry, Manifest, ModelConfig};
pub use tensor::{DType, Host, Tensor, TensorF, TensorI};
