//! PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Python never runs at this point — the artifacts under `artifacts/` are
//! the entire model.  HLO *text* is the interchange format (jax >= 0.5
//! emits protos with 64-bit ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids).  Compiled executables are cached per artifact
//! file, so e.g. every expert shard in the coordinator shares one
//! `expert_<cfg>` executable.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::runtime::manifest::{ArtifactSig, Manifest, TensorSig};
use crate::runtime::tensor::Host;

pub struct Engine {
    client: PjRtClient,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

pub struct Executable {
    exe: PjRtLoadedExecutable,
    pub sig: ArtifactSig,
    pub name: String,
    pub compile_time: std::time::Duration,
}

fn shape_matches(sig: &TensorSig, host: &Host) -> bool {
    sig.shape == host.shape() && sig.dtype == host.dtype()
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached by file name).
    pub fn load(&self, manifest: &Manifest, cfg_name: &str, kind: &str)
        -> Result<Arc<Executable>> {
        let entry = manifest.config(cfg_name)?;
        let sig = entry.artifact(kind)?;
        if let Some(exe) = self.cache.lock().unwrap().get(&sig.file) {
            return Ok(exe.clone());
        }
        let path = manifest.artifact_path(sig);
        let exe = Arc::new(self.compile_file(&path, sig.clone())?);
        self.cache
            .lock()
            .unwrap()
            .insert(sig.file.clone(), exe.clone());
        Ok(exe)
    }

    pub fn compile_file(&self, path: &Path, sig: ArtifactSig)
        -> Result<Executable> {
        let t0 = Instant::now();
        let proto = HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            sig,
            compile_time: t0.elapsed(),
        })
    }
}

/// Wall-clock breakdown of one artifact execution: host-to-literal
/// staging, device execution, output decode.  The PJRT analogue of the
/// coordinator's gather/compute/combine phase split.
#[derive(Clone, Debug, Default)]
pub struct ExecPhases {
    pub h2d_ns: u64,
    pub exec_ns: u64,
    pub d2h_ns: u64,
}

impl Executable {
    /// Execute with host tensors; returns the output leaves in manifest
    /// order.  Input shapes/dtypes are validated against the signature so
    /// a stale artifact fails loudly rather than numerically.
    pub fn run(&self, inputs: &[Host]) -> Result<Vec<Host>> {
        self.run_phased(inputs).map(|(outs, _)| outs)
    }

    /// [`run`](Self::run) with a per-phase timing breakdown.
    pub fn run_phased(&self, inputs: &[Host]) -> Result<(Vec<Host>, ExecPhases)> {
        if inputs.len() != self.sig.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.sig.inputs.len(),
                inputs.len()
            );
        }
        for (i, (sig, host)) in
            self.sig.inputs.iter().zip(inputs.iter()).enumerate() {
            if !shape_matches(sig, host) {
                bail!(
                    "{}: input {i} shape/dtype mismatch: artifact wants \
                     {:?} {:?}, caller passed {:?} {:?}",
                    self.name, sig.shape, sig.dtype, host.shape(),
                    host.dtype()
                );
            }
        }
        let mut phases = ExecPhases::default();
        let t0 = Instant::now();
        let literals: Vec<Literal> = inputs
            .iter()
            .map(|h| h.to_literal())
            .collect::<Result<_>>()?;
        phases.h2d_ns = t0.elapsed().as_nanos() as u64;
        let outs = self.run_literals_phased(&literals, &mut phases)?;
        Ok((outs, phases))
    }

    /// Execute pre-built literals (skips signature validation; used on the
    /// trainer hot loop where literals are reused across steps).
    pub fn run_literals(&self, literals: &[Literal]) -> Result<Vec<Host>> {
        self.run_literals_phased(literals, &mut ExecPhases::default())
    }

    fn run_literals_phased(
        &self,
        literals: &[Literal],
        phases: &mut ExecPhases,
    ) -> Result<Vec<Host>> {
        let t0 = Instant::now();
        let result = self.exe.execute::<Literal>(literals)?;
        phases.exec_ns = t0.elapsed().as_nanos() as u64;
        let t1 = Instant::now();
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching output tuple")?;
        // AOT lowers with return_tuple=True: root is always a tuple.
        let leaves = tuple.to_tuple().context("decomposing output tuple")?;
        if leaves.len() != self.sig.outputs.len() {
            bail!(
                "{}: artifact returned {} outputs, manifest says {}",
                self.name,
                leaves.len(),
                self.sig.outputs.len()
            );
        }
        let outs: Result<Vec<Host>> = leaves
            .iter()
            .zip(self.sig.outputs.iter())
            .map(|(lit, sig)| Host::from_literal(lit, sig.dtype))
            .collect();
        phases.d2h_ns = t1.elapsed().as_nanos() as u64;
        outs
    }
}
