//! NEON kernel (aarch64).
//!
//! 4-lane `f32` with `vfmaq_f32`; the main GEMM tiles 16 output columns
//! across 4 q-registers and reuses them over a k-block — the same
//! register-tile shape as the AVX2 kernel at half the lane width.  Like
//! that kernel it keeps the row-independence and fixed
//! per-element-reduction-order invariants while contracting each
//! multiply-add, so it is error-budgeted against the scalar oracle,
//! not bit-equal to it.

use super::MatmulKernel;
use std::arch::aarch64::*;

/// Runtime gate (NEON is baseline on aarch64, but keep the check
/// symmetric with the x86 path).
pub fn supported() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// See the module docs.
pub struct NeonKernel;

impl MatmulKernel for NeonKernel {
    fn name(&self) -> &'static str {
        "neon"
    }

    fn matmul(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(out.len(), m * n);
        unsafe { matmul_neon(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, k, n) }
    }

    fn matmul_tn(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), m * n);
        assert_eq!(out.len(), k * n);
        unsafe { matmul_tn_neon(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, k, n) }
    }

    fn matmul_nt(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), n * k);
        assert_eq!(out.len(), m * n);
        unsafe { matmul_nt_neon(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, n, k) }
    }

    #[allow(clippy::too_many_arguments)]
    fn matmul_q8(
        &self,
        a: &[f32],
        q: &[i8],
        scales: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(q.len(), k * n);
        assert_eq!(scales.len(), n);
        assert_eq!(out.len(), m * n);
        unsafe {
            matmul_q8_neon(
                a.as_ptr(),
                q.as_ptr(),
                scales.as_ptr(),
                out.as_mut_ptr(),
                m,
                k,
                n,
            )
        }
    }
}

/// `out (m,n) = a (m,k) · b (k,n)` — 16-wide register tiles over a
/// k-block.
#[target_feature(enable = "neon")]
unsafe fn matmul_neon(a: *const f32, b: *const f32, out: *mut f32, m: usize, k: usize, n: usize) {
    std::ptr::write_bytes(out, 0, m * n);
    const KB: usize = 128;
    let mut kb = 0;
    while kb < k {
        let k_end = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.add(i * k);
            let orow = out.add(i * n);
            let mut j = 0;
            while j + 16 <= n {
                let mut acc0 = vld1q_f32(orow.add(j));
                let mut acc1 = vld1q_f32(orow.add(j + 4));
                let mut acc2 = vld1q_f32(orow.add(j + 8));
                let mut acc3 = vld1q_f32(orow.add(j + 12));
                for l in kb..k_end {
                    let av = vdupq_n_f32(*arow.add(l));
                    let brow = b.add(l * n + j);
                    acc0 = vfmaq_f32(acc0, av, vld1q_f32(brow));
                    acc1 = vfmaq_f32(acc1, av, vld1q_f32(brow.add(4)));
                    acc2 = vfmaq_f32(acc2, av, vld1q_f32(brow.add(8)));
                    acc3 = vfmaq_f32(acc3, av, vld1q_f32(brow.add(12)));
                }
                vst1q_f32(orow.add(j), acc0);
                vst1q_f32(orow.add(j + 4), acc1);
                vst1q_f32(orow.add(j + 8), acc2);
                vst1q_f32(orow.add(j + 12), acc3);
                j += 16;
            }
            while j + 4 <= n {
                let mut acc = vld1q_f32(orow.add(j));
                for l in kb..k_end {
                    let av = vdupq_n_f32(*arow.add(l));
                    acc = vfmaq_f32(acc, av, vld1q_f32(b.add(l * n + j)));
                }
                vst1q_f32(orow.add(j), acc);
                j += 4;
            }
            while j < n {
                let mut acc = *orow.add(j);
                for l in kb..k_end {
                    acc = (*arow.add(l)).mul_add(*b.add(l * n + j), acc);
                }
                *orow.add(j) = acc;
                j += 1;
            }
        }
        kb += KB;
    }
}

/// `out (k,n) += aᵀ · b` — broadcast-axpy per `(i, l)` pair, 4-wide
/// over `n`.
#[target_feature(enable = "neon")]
unsafe fn matmul_tn_neon(
    a: *const f32,
    b: *const f32,
    out: *mut f32,
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let arow = a.add(i * k);
        let brow = b.add(i * n);
        for l in 0..k {
            let av = *arow.add(l);
            let avv = vdupq_n_f32(av);
            let orow = out.add(l * n);
            let mut j = 0;
            while j + 4 <= n {
                let o = vld1q_f32(orow.add(j));
                let bb = vld1q_f32(brow.add(j));
                vst1q_f32(orow.add(j), vfmaq_f32(o, avv, bb));
                j += 4;
            }
            while j < n {
                *orow.add(j) = av.mul_add(*brow.add(j), *orow.add(j));
                j += 1;
            }
        }
    }
}

/// `out (m,n) = a (m,k) · bᵀ (n,k)` — 4-lane dot products reduced with
/// `vaddvq_f32`, scalar tail folded in last.
#[target_feature(enable = "neon")]
unsafe fn matmul_nt_neon(
    a: *const f32,
    b: *const f32,
    out: *mut f32,
    m: usize,
    n: usize,
    k: usize,
) {
    for i in 0..m {
        let arow = a.add(i * k);
        for j in 0..n {
            let brow = b.add(j * k);
            let mut acc = vdupq_n_f32(0.0);
            let mut l = 0;
            while l + 4 <= k {
                acc = vfmaq_f32(acc, vld1q_f32(arow.add(l)), vld1q_f32(brow.add(l)));
                l += 4;
            }
            let mut s = vaddvq_f32(acc);
            while l < k {
                s = (*arow.add(l)).mul_add(*brow.add(l), s);
                l += 1;
            }
            *out.add(i * n + j) = s;
        }
    }
}

/// Int8 GEMM: 8 weights at a time via
/// `vld1_s8 → vmovl_s8 → vmovl_s16 → vcvtq_f32_s32` feeding two 4-lane
/// accumulators, per-column scales applied once after the full
/// k-reduction (same contract as [`crate::kernels::scalar::matmul_q8`]).
#[target_feature(enable = "neon")]
unsafe fn matmul_q8_neon(
    a: *const f32,
    q: *const i8,
    scales: *const f32,
    out: *mut f32,
    m: usize,
    k: usize,
    n: usize,
) {
    std::ptr::write_bytes(out, 0, m * n);
    const KB: usize = 128;
    let mut kb = 0;
    while kb < k {
        let k_end = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.add(i * k);
            let orow = out.add(i * n);
            let mut j = 0;
            while j + 8 <= n {
                let mut acc0 = vld1q_f32(orow.add(j));
                let mut acc1 = vld1q_f32(orow.add(j + 4));
                for l in kb..k_end {
                    let av = vdupq_n_f32(*arow.add(l));
                    let q16 = vmovl_s8(vld1_s8(q.add(l * n + j)));
                    let lo = vcvtq_f32_s32(vmovl_s16(vget_low_s16(q16)));
                    let hi = vcvtq_f32_s32(vmovl_s16(vget_high_s16(q16)));
                    acc0 = vfmaq_f32(acc0, av, lo);
                    acc1 = vfmaq_f32(acc1, av, hi);
                }
                vst1q_f32(orow.add(j), acc0);
                vst1q_f32(orow.add(j + 4), acc1);
                j += 8;
            }
            while j < n {
                let mut acc = *orow.add(j);
                for l in kb..k_end {
                    acc = (*arow.add(l)).mul_add(*q.add(l * n + j) as f32, acc);
                }
                *orow.add(j) = acc;
                j += 1;
            }
        }
        kb += KB;
    }
    for i in 0..m {
        let orow = out.add(i * n);
        let mut j = 0;
        while j + 4 <= n {
            let o = vld1q_f32(orow.add(j));
            let s = vld1q_f32(scales.add(j));
            vst1q_f32(orow.add(j), vmulq_f32(o, s));
            j += 4;
        }
        while j < n {
            *orow.add(j) *= *scales.add(j);
            j += 1;
        }
    }
}
