//! The scalar kernel: the pre-kernel-layer cache-blocked matmuls,
//! retained as the **bit-exact oracle** every other kernel is budgeted
//! against.
//!
//! [`ScalarKernel::matmul_sparse`] is the original
//! `gating::noisy_topk::matmul` loop verbatim, `av == 0.0` skip branch
//! included.  [`ScalarKernel::matmul`] is its branch-free twin: for
//! finite inputs the two are bit-identical (skipping `out += 0.0 * b`
//! skips an exact no-op — `0.0 * b` is `±0.0` and `x + ±0.0 == x` for
//! every finite non-negative-zero `x`; when `x` is `-0.0` both paths
//! still round to the same bits because `-0.0 + 0.0 == 0.0` only
//! differs for exactly-zero accumulators that started at `+0.0` here),
//! so `MOE_KERNEL=scalar` reproduces pre-kernel-layer step outputs
//! bit-for-bit.  The dense twin exists because on dense activations the
//! skip is a per-element branch in the innermost loop that the
//! predictor loses on; the sparse entry stays the right call for
//! post-ReLU hidden blocks where most of `a` really is zero.

use super::MatmulKernel;

/// See the module docs: the retained scalar oracle.
pub struct ScalarKernel;

impl MatmulKernel for ScalarKernel {
    fn name(&self) -> &'static str {
        "scalar"
    }

    /// Cache-blocked row-major `(m,k) × (k,n) → (m,n)`, dense
    /// (branch-free) inner loop.  Blocks over `k` and `n` so each
    /// `KB × JB` panel of `b` stays in L1/L2 while `m` rows stream
    /// through it, with a 4-wide unrolled inner loop.  For any fixed
    /// output element the reduction runs over `l` in increasing order
    /// (k-blocks are visited in order and the j-unroll never reorders a
    /// single element's sum), so results are bit-identical to the naive
    /// triple loop — and to [`matmul_sparse`](Self::matmul_sparse); the
    /// engine differential tests rely on this.
    fn matmul(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        const KB: usize = 64;
        const JB: usize = 256;
        out.fill(0.0);
        for kb in (0..k).step_by(KB) {
            let k_end = (kb + KB).min(k);
            for jb in (0..n).step_by(JB) {
                let j_end = (jb + JB).min(n);
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let orow = &mut out[i * n + jb..i * n + j_end];
                    for (l, &av) in arow[kb..k_end].iter().enumerate() {
                        let brow = &b[(kb + l) * n + jb..(kb + l) * n + j_end];
                        let chunks = orow.len() & !3;
                        let mut j = 0;
                        while j < chunks {
                            orow[j] += av * brow[j];
                            orow[j + 1] += av * brow[j + 1];
                            orow[j + 2] += av * brow[j + 2];
                            orow[j + 3] += av * brow[j + 3];
                            j += 4;
                        }
                        while j < orow.len() {
                            orow[j] += av * brow[j];
                            j += 1;
                        }
                    }
                }
            }
        }
    }

    /// The original `gating::noisy_topk::matmul` retained verbatim:
    /// identical blocking, plus the `av == 0.0` skip that pays on
    /// post-ReLU activations.  Bit-identical to
    /// [`matmul`](Self::matmul) for finite inputs (see module docs).
    fn matmul_sparse(
        &self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        const KB: usize = 64;
        const JB: usize = 256;
        out.fill(0.0);
        for kb in (0..k).step_by(KB) {
            let k_end = (kb + KB).min(k);
            for jb in (0..n).step_by(JB) {
                let j_end = (jb + JB).min(n);
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let orow = &mut out[i * n + jb..i * n + j_end];
                    for (l, &av) in arow[kb..k_end].iter().enumerate() {
                        if av == 0.0 {
                            continue;
                        }
                        let brow = &b[(kb + l) * n + jb..(kb + l) * n + j_end];
                        let chunks = orow.len() & !3;
                        let mut j = 0;
                        while j < chunks {
                            orow[j] += av * brow[j];
                            orow[j + 1] += av * brow[j + 1];
                            orow[j + 2] += av * brow[j + 2];
                            orow[j + 3] += av * brow[j + 3];
                            j += 4;
                        }
                        while j < orow.len() {
                            orow[j] += av * brow[j];
                            j += 1;
                        }
                    }
                }
            }
        }
    }

    /// `out (k,n) += aᵀ · b` for row-major `a (m,k)`, `b (m,n)`,
    /// retained verbatim: walks `a`/`b` row by row so the inner loops
    /// stream contiguous memory.  The backward-pass workhorse
    /// (`dW = xᵀ · dY`), shared by the trainer and the gating backward.
    fn matmul_tn(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), m * n);
        debug_assert_eq!(out.len(), k * n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (av, orow) in arow.iter().zip(out.chunks_mut(n)) {
                for (o, bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
    }

    /// `out (m,n) = a · bᵀ` for row-major `a (m,k)`, `b (n,k)`, now
    /// k-blocked: long `d_model` rows used to stream the whole of `a`'s
    /// row per dot product, thrashing L1 on the backward path.  Each
    /// `KB` slice of a row of `a` is now reused across all `n` rows of
    /// `b` while L1-resident.
    ///
    /// Note on bit-identity: blocking sums each `KB` span into its own
    /// partial accumulator and adds the partials in block order, which
    /// *changes the reduction order* relative to the old single-pass
    /// dot product — `matmul_nt` results are covered by the
    /// error-budgeted oracle tests in `rust/tests/kernels.rs`, not a
    /// bit-equality claim.  (The reduction order is still fixed per
    /// element and row-independent, which is the invariant the engine
    /// needs.)
    fn matmul_nt(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(out.len(), m * n);
        const KB: usize = 256;
        if k == 0 {
            out.fill(0.0);
            return;
        }
        for (arow, orow) in a.chunks(k).zip(out.chunks_mut(n)) {
            orow.fill(0.0);
            for kb in (0..k).step_by(KB) {
                let k_end = (kb + KB).min(k);
                let ab = &arow[kb..k_end];
                for (bv, o) in b.chunks(k).zip(orow.iter_mut()) {
                    let bslice = &bv[kb..k_end];
                    let mut acc = 0.0f32;
                    for (x, y) in ab.iter().zip(bslice.iter()) {
                        acc += x * y;
                    }
                    *o += acc;
                }
            }
        }
    }
}

/// Scalar int8 GEMM: `out (m,n) = (a (m,k) · q (k,n)) · diag(scales)`.
/// Accumulates `a[i,l] * q[l,j] as f32` in f32 and applies the
/// per-output-channel scale once after the full k-reduction — the
/// default [`MatmulKernel::matmul_q8`] body, and the reference the
/// SIMD int8 paths are budgeted against.
#[allow(clippy::too_many_arguments)]
pub fn matmul_q8(
    a: &[f32],
    q: &[i8],
    scales: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(q.len(), k * n);
    debug_assert_eq!(scales.len(), n);
    debug_assert_eq!(out.len(), m * n);
    const KB: usize = 64;
    const JB: usize = 256;
    out.fill(0.0);
    for kb in (0..k).step_by(KB) {
        let k_end = (kb + KB).min(k);
        for jb in (0..n).step_by(JB) {
            let j_end = (jb + JB).min(n);
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let orow = &mut out[i * n + jb..i * n + j_end];
                for (l, &av) in arow[kb..k_end].iter().enumerate() {
                    let qrow = &q[(kb + l) * n + jb..(kb + l) * n + j_end];
                    for (o, &qv) in orow.iter_mut().zip(qrow.iter()) {
                        *o += av * qv as f32;
                    }
                }
            }
        }
    }
    for orow in out.chunks_mut(n) {
        for (o, &s) in orow.iter_mut().zip(scales.iter()) {
            *o *= s;
        }
    }
}
