//! Int8 row-quantized expert weights for the forward/serve path.
//!
//! Symmetric per-output-channel quantization (the MoE-in-LLMs survey's
//! weight-only recipe): for each output column `j` of a weight matrix,
//! `scale[j] = max_i |w[i,j]| / 127` (`1.0` when the column is all
//! zeros) and `q[i,j] = round(w[i,j] / scale[j])` — so the int8 code
//! range is fully used per channel, the quantizer is **deterministic**
//! (same weights → same bytes, no calibration data), and dequantization
//! error per element is at most `scale[j]/2 ≈ 0.4 %` of the column's
//! amax.
//!
//! Training and checkpoints stay f32: quantization happens **at load**
//! ([`QuantizedExpertWeights::from_f32`] /
//! [`QuantizedExpertWeights::quantize_all`], called by
//! `ServeLoop::new` when [`Precision::Int8`] is configured), and the
//! f32 [`ExpertWeights`] are kept alongside untouched.  The int8 GEMM
//! ([`MatmulKernel::matmul_q8`](super::MatmulKernel::matmul_q8))
//! accumulates in f32 and applies the per-column scale once after the
//! full k-reduction, so the serve-output error is the quantization
//! error itself plus the usual accumulation term — budgeted normwise at
//! [`SERVE_REL_ERR_BUDGET`] against the f32 path over the same weights
//! (asserted in `rust/tests/kernels.rs` and `benches/kernels.rs`).

use super::{Kernel, MatmulKernel};
use crate::coordinator::scheduler::ExpertWeights;

/// Serving numeric width for the expert FFNs
/// (`crate::serve::ServeConfig::precision`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// Full f32 weights — bit-identical to the training forward.
    #[default]
    F32,
    /// Int8 weight-only quantization, error-budgeted against f32.
    Int8,
}

/// Normwise relative error budget for int8 serve outputs vs the f32
/// path over the same weights: `‖y_int8 − y_f32‖₂ ≤ 0.05 · ‖y_f32‖₂`
/// per output batch.
pub const SERVE_REL_ERR_BUDGET: f64 = 0.05;

/// Int8 twin of [`ExpertWeights`]: both layers quantized per output
/// channel, forward-only (no backward — training stays f32).
#[derive(Clone)]
pub struct QuantizedExpertWeights {
    pub d_model: usize,
    pub hidden: usize,
    /// `w_in (d, h)` codes, row-major like the f32 original.
    pub q_in: Vec<i8>,
    /// Per-output-channel scales for `q_in` (`len == hidden`).
    pub s_in: Vec<f32>,
    /// `w_out (h, d)` codes, row-major.
    pub q_out: Vec<i8>,
    /// Per-output-channel scales for `q_out` (`len == d_model`).
    pub s_out: Vec<f32>,
}

/// Quantize one row-major `(rows, cols)` matrix per output column.
/// Deterministic: pure arithmetic on the input bytes, no RNG, no
/// data-dependent tie-breaking (`round` half-away-from-zero).
fn quantize_cols(w: &[f32], rows: usize, cols: usize) -> (Vec<i8>, Vec<f32>) {
    debug_assert_eq!(w.len(), rows * cols);
    let mut scales = vec![1.0f32; cols];
    for j in 0..cols {
        let mut amax = 0.0f32;
        for i in 0..rows {
            amax = amax.max(w[i * cols + j].abs());
        }
        if amax > 0.0 {
            scales[j] = amax / 127.0;
        }
    }
    let mut q = vec![0i8; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            let v = (w[i * cols + j] / scales[j]).round();
            q[i * cols + j] = v.clamp(-127.0, 127.0) as i8;
        }
    }
    (q, scales)
}

impl QuantizedExpertWeights {
    /// Quantize one expert's f32 weights (the f32 source is left
    /// untouched — the caller keeps it for checkpointing/training).
    pub fn from_f32(w: &ExpertWeights) -> Self {
        let (q_in, s_in) = quantize_cols(&w.w_in, w.d_model, w.hidden);
        let (q_out, s_out) = quantize_cols(&w.w_out, w.hidden, w.d_model);
        QuantizedExpertWeights {
            d_model: w.d_model,
            hidden: w.hidden,
            q_in,
            s_in,
            q_out,
            s_out,
        }
    }

    /// Quantize a whole expert set (the `ServeLoop::new` load path).
    pub fn quantize_all(ws: &[ExpertWeights]) -> Vec<Self> {
        ws.iter().map(Self::from_f32).collect()
    }

    /// Reconstruct f32 weights from the codes (`q[i,j] * scale[j]`) —
    /// the round-trip the per-channel error budget is asserted on.
    pub fn dequantize(&self) -> ExpertWeights {
        let deq = |q: &[i8], s: &[f32], cols: usize| -> Vec<f32> {
            q.chunks(cols)
                .flat_map(|row| {
                    row.iter().zip(s.iter()).map(|(&qv, &sv)| qv as f32 * sv)
                })
                .collect()
        };
        ExpertWeights {
            w_in: deq(&self.q_in, &self.s_in, self.hidden),
            w_out: deq(&self.q_out, &self.s_out, self.d_model),
            d_model: self.d_model,
            hidden: self.hidden,
        }
    }

    /// Int8 twin of [`ExpertWeights::forward_into`]: fused
    /// `relu(x·q_in·s_in)·q_out·s_out` in cache-resident row blocks on
    /// the selected kernel's [`matmul_q8`](MatmulKernel::matmul_q8).
    /// Same signature as the f32 version so the engine's worker arm
    /// treats both symmetrically.
    pub fn forward_into(
        &self,
        x: &[f32],
        rows: usize,
        scratch: &mut Vec<f32>,
        out: &mut Vec<f32>,
    ) {
        let (d, h) = (self.d_model, self.hidden);
        debug_assert_eq!(x.len(), rows * d);
        out.clear();
        out.resize(rows * d, 0.0);
        if rows == 0 {
            return;
        }
        let kern = Kernel::select();
        let rb = (32 * 1024 / h.max(1)).clamp(1, rows);
        scratch.clear();
        scratch.resize(rb * h, 0.0);
        let mut r0 = 0;
        while r0 < rows {
            let rblk = rb.min(rows - r0);
            let hid = &mut scratch[..rblk * h];
            kern.matmul_q8(
                &x[r0 * d..(r0 + rblk) * d],
                &self.q_in,
                &self.s_in,
                hid,
                rblk,
                d,
                h,
            );
            for v in hid.iter_mut() {
                *v = v.max(0.0);
            }
            kern.matmul_q8(
                hid,
                &self.q_out,
                &self.s_out,
                &mut out[r0 * d..(r0 + rblk) * d],
                rblk,
                h,
                d,
            );
            r0 += rblk;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn rand_expert(rng: &mut crate::util::rng::Rng, d: usize, h: usize) -> ExpertWeights {
        ExpertWeights {
            w_in: prop::vec_f32(rng, d * h, 0.5),
            w_out: prop::vec_f32(rng, h * d, 0.5),
            d_model: d,
            hidden: h,
        }
    }

    #[test]
    fn round_trip_error_within_per_channel_budget() {
        prop::forall("q8 round trip", |rng| {
            let d = prop::dim(rng, 1, 12);
            let h = prop::dim(rng, 1, 17);
            let w = rand_expert(rng, d, h);
            let q = QuantizedExpertWeights::from_f32(&w);
            let dq = q.dequantize();
            for j in 0..h {
                let bound = q.s_in[j] * 0.5 + 1e-12;
                for i in 0..d {
                    let e = (w.w_in[i * h + j] - dq.w_in[i * h + j]).abs();
                    assert!(e <= bound, "w_in[{i},{j}]: err {e} > scale/2 {bound}");
                }
            }
            for j in 0..d {
                let bound = q.s_out[j] * 0.5 + 1e-12;
                for i in 0..h {
                    let e = (w.w_out[i * d + j] - dq.w_out[i * d + j]).abs();
                    assert!(e <= bound, "w_out[{i},{j}]: err {e} > scale/2 {bound}");
                }
            }
        });
    }

    #[test]
    fn quantization_is_bit_deterministic() {
        let mut rng = prop::case_rng(7);
        let w = rand_expert(&mut rng, 9, 13);
        let q1 = QuantizedExpertWeights::from_f32(&w);
        let q2 = QuantizedExpertWeights::from_f32(&w.clone());
        assert_eq!(q1.q_in, q2.q_in);
        assert_eq!(q1.q_out, q2.q_out);
        assert_eq!(
            q1.s_in.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            q2.s_in.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            q1.s_out.iter().map(|s| s.to_bits()).collect::<Vec<_>>(),
            q2.s_out.iter().map(|s| s.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn zero_columns_quantize_to_zero_with_unit_scale() {
        let w = ExpertWeights {
            w_in: vec![0.0; 6],
            w_out: vec![0.0; 6],
            d_model: 2,
            hidden: 3,
        };
        let q = QuantizedExpertWeights::from_f32(&w);
        assert!(q.q_in.iter().all(|&v| v == 0));
        assert!(q.s_in.iter().all(|&s| s == 1.0));
        let dq = q.dequantize();
        assert!(dq.w_in.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn int8_forward_tracks_f32_forward_within_budget() {
        prop::forall("q8 forward budget", |rng| {
            let d = prop::dim(rng, 2, 10);
            let h = prop::dim(rng, 2, 16);
            let rows = prop::dim(rng, 1, 6);
            let w = rand_expert(rng, d, h);
            let q = QuantizedExpertWeights::from_f32(&w);
            let x = prop::vec_f32(rng, rows * d, 1.0);
            let (mut s1, mut s2) = (Vec::new(), Vec::new());
            let (mut y32, mut y8) = (Vec::new(), Vec::new());
            w.forward_into(&x, rows, &mut s1, &mut y32);
            q.forward_into(&x, rows, &mut s2, &mut y8);
            let norm: f64 = y32.iter().map(|v| (*v as f64).powi(2)).sum::<f64>().sqrt();
            let err: f64 = y32
                .iter()
                .zip(y8.iter())
                .map(|(a, b)| (*a as f64 - *b as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            assert!(
                err <= SERVE_REL_ERR_BUDGET * norm + 1e-6,
                "int8 forward error {err:.3e} exceeds budget over norm {norm:.3e}"
            );
        });
    }
}
