//! Explicit-SIMD kernel layer for the MoE hot path.
//!
//! Every f32 GEMM on the step hot path — gating logits
//! ([`crate::gating::noisy_topk`]), the expert FFN forward
//! ([`crate::coordinator::scheduler::ExpertWeights::forward_into`]) and
//! the training backward ([`crate::train`], [`crate::gating::backward`])
//! — routes through one process-wide selected [`MatmulKernel`].  Three
//! implementations exist:
//!
//! - [`scalar::ScalarKernel`] — the pre-kernel-layer cache-blocked
//!   scalar code, retained verbatim as the **bit-exact oracle** (its
//!   `matmul` is bit-identical to the naive triple loop);
//! - `Avx2Kernel` (x86_64) — `std::arch` AVX2 + FMA, 8-lane with
//!   32-wide register tiles, behind `is_x86_feature_detected!`;
//! - `NeonKernel` (aarch64) — `std::arch` NEON, 4-lane with 16-wide
//!   register tiles, behind `is_aarch64_feature_detected!`.
//!
//! # Selection
//!
//! [`Kernel::select`] picks the fastest kernel the host supports, once,
//! at first use; the `MOE_KERNEL` env var (`scalar` / `avx2` / `neon`)
//! overrides the policy for A/B runs.  An override naming a kernel the
//! host cannot run falls back to auto-selection with a warning rather
//! than crashing.  [`crate::coordinator::StepStats::kernel`] records
//! the selected name per step so `repro efficiency` shows which path
//! ran.
//!
//! # Numerical contract
//!
//! The engine-vs-serial differential proofs
//! (`rust/tests/engine_parity.rs`, `serve.rs`, `faults.rs`) stay
//! **bit-identical**: every execution path calls the *same* selected
//! kernel, so those comparisons never cross kernels.  What is
//! kernel-dependent is the relation to the scalar oracle: SIMD kernels
//! reassociate the k-reduction (FMA contraction, lane-tiled
//! accumulation), so kernel-vs-oracle and int8-vs-f32 comparisons are
//! **error-budgeted** differential tests with tolerances derived from
//! accumulation-order analysis (`rust/tests/kernels.rs`).
//!
//! Two structural invariants every implementation must keep, because
//! the engine's streaming paths depend on them:
//!
//! - **row independence** — computing any contiguous row block of `a`
//!   yields bit-identical rows to a full-batch call (expert chunks and
//!   row-blocked gating rely on it);
//! - **fixed reduction order per element** — the reduction order over
//!   `k` for a given output element must not depend on `m` or `n`.
//!
//! # Quantized serving
//!
//! [`quant`] adds int8 row-quantized expert weights (per-output-channel
//! symmetric scales, quantize-at-load from f32 checkpoints) for the
//! forward/serve path only, behind
//! [`quant::Precision`] in [`crate::serve::ServeConfig`]; the int8 GEMM
//! ([`MatmulKernel::matmul_q8`]) dispatches through the same kernel
//! selection.

pub mod quant;
pub mod scalar;
#[cfg(target_arch = "aarch64")]
pub mod simd_neon;
#[cfg(target_arch = "x86_64")]
pub mod simd_x86;

use std::sync::OnceLock;

/// The three hot GEMM shapes of the MoE step plus the int8 serve GEMM.
///
/// Shape conventions (all row-major):
/// - [`matmul`](Self::matmul):    `out (m,n) = a (m,k) · b (k,n)` — overwrites `out`;
/// - [`matmul_tn`](Self::matmul_tn): `out (k,n) += aᵀ · b` for `a (m,k)`, `b (m,n)` —
///   *accumulates* (the backward-pass `dW = xᵀ·dY` contract);
/// - [`matmul_nt`](Self::matmul_nt): `out (m,n) = a (m,k) · bᵀ` for `b (n,k)` — overwrites.
///
/// See the module docs for the row-independence and reduction-order
/// invariants implementations must keep.
pub trait MatmulKernel: Sync {
    /// Stable identifier (`"scalar"`, `"avx2"`, `"neon"`) used by the
    /// `MOE_KERNEL` override, bench rows and [`crate::coordinator::StepStats`].
    fn name(&self) -> &'static str;

    /// `out (m,n) = a (m,k) · b (k,n)`.
    fn matmul(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize);

    /// Like [`matmul`](Self::matmul), but the caller asserts `a` is
    /// mostly zeros (e.g. a post-ReLU hidden block).  Implementations
    /// may skip zero elements of `a` — bit-neutral for finite inputs,
    /// since accumulating `0.0 * b` is an exact no-op — or ignore the
    /// hint (the SIMD kernels do: a per-element branch costs more than
    /// the multiply it saves on 8 lanes).
    fn matmul_sparse(
        &self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        self.matmul(a, b, out, m, k, n);
    }

    /// `out (k,n) += aᵀ · b` for `a (m,k)`, `b (m,n)` (accumulating).
    fn matmul_tn(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize);

    /// `out (m,n) = a (m,k) · bᵀ` for `b (n,k)`.
    fn matmul_nt(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize);

    /// Int8 GEMM for the quantized serve path:
    /// `out (m,n) = (a (m,k) · q (k,n)) · diag(scales)`, with `q`
    /// symmetric per-output-channel int8 (`scales[j]` dequantizes
    /// column `j`).  Accumulation is f32; scales are applied once after
    /// the full k-reduction, so the error budget is the quantization
    /// error itself plus the usual accumulation term.
    #[allow(clippy::too_many_arguments)]
    fn matmul_q8(
        &self,
        a: &[f32],
        q: &[i8],
        scales: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        scalar::matmul_q8(a, q, scales, out, m, k, n);
    }
}

static SCALAR: scalar::ScalarKernel = scalar::ScalarKernel;
#[cfg(target_arch = "x86_64")]
static AVX2: simd_x86::Avx2Kernel = simd_x86::Avx2Kernel;
#[cfg(target_arch = "aarch64")]
static NEON: simd_neon::NeonKernel = simd_neon::NeonKernel;

static SELECTED: OnceLock<&'static dyn MatmulKernel> = OnceLock::new();

/// Kernel selection policy (see module docs).
pub struct Kernel;

impl Kernel {
    /// The process-wide selected kernel: the `MOE_KERNEL` env override
    /// when set and runnable, else the fastest kernel the host
    /// supports.  Resolved once and cached — every GEMM on the hot
    /// path shares the result, which is what keeps the engine-vs-serial
    /// differentials bit-identical.
    pub fn select() -> &'static dyn MatmulKernel {
        *SELECTED.get_or_init(|| {
            if let Ok(name) = std::env::var("MOE_KERNEL") {
                if let Some(k) = Self::by_name(&name) {
                    return k;
                }
                eprintln!(
                    "MOE_KERNEL={name:?} is unknown or unsupported on this \
                     host; auto-selecting"
                );
            }
            Self::fastest()
        })
    }

    /// Name of the selected kernel (stamped into
    /// [`crate::coordinator::StepStats::kernel`]).
    pub fn selected_name() -> &'static str {
        Self::select().name()
    }

    /// The scalar bit-exact oracle, independent of selection — the
    /// reference side of every error-budgeted kernel test.
    pub fn scalar() -> &'static dyn MatmulKernel {
        &SCALAR
    }

    /// Look a kernel up by its [`MatmulKernel::name`]; `None` when the
    /// name is unknown *or* the host cannot run it.  Tests and benches
    /// use this to A/B kernels directly without racing on the
    /// process-wide `MOE_KERNEL` selection.
    pub fn by_name(name: &str) -> Option<&'static dyn MatmulKernel> {
        match name {
            "scalar" => Some(&SCALAR),
            #[cfg(target_arch = "x86_64")]
            "avx2" if simd_x86::supported() => Some(&AVX2),
            #[cfg(target_arch = "aarch64")]
            "neon" if simd_neon::supported() => Some(&NEON),
            _ => None,
        }
    }

    /// Every kernel runnable on this host (scalar first).  The bench
    /// sweep iterates this.
    pub fn available() -> Vec<&'static dyn MatmulKernel> {
        let mut v: Vec<&'static dyn MatmulKernel> = vec![&SCALAR];
        #[cfg(target_arch = "x86_64")]
        if simd_x86::supported() {
            v.push(&AVX2);
        }
        #[cfg(target_arch = "aarch64")]
        if simd_neon::supported() {
            v.push(&NEON);
        }
        v
    }

    /// Auto-selection: the widest SIMD the host supports, else scalar.
    fn fastest() -> &'static dyn MatmulKernel {
        #[cfg(target_arch = "x86_64")]
        if simd_x86::supported() {
            return &AVX2;
        }
        #[cfg(target_arch = "aarch64")]
        if simd_neon::supported() {
            return &NEON;
        }
        &SCALAR
    }
}

/// `out (m,n) = a (m,k) · b (k,n)` on the selected kernel.
pub fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    Kernel::select().matmul(a, b, out, m, k, n);
}

/// `out (k,n) += aᵀ · b` on the selected kernel (see
/// [`MatmulKernel::matmul_tn`]).
pub fn matmul_tn(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    Kernel::select().matmul_tn(a, b, out, m, k, n);
}

/// `out (m,n) = a (m,k) · bᵀ` on the selected kernel.
pub fn matmul_nt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    Kernel::select().matmul_nt(a, b, out, m, n, k);
}

/// Fused expert-FFN forward: `out = relu(x · w_in) · w_out` in
/// cache-resident row blocks, so the `(rows, h)` hidden layer is never
/// materialized whole — each block's hidden activations are produced,
/// rectified and consumed while still hot (~128 KiB per block).
///
/// Rows are independent in both GEMMs, so the row blocking is
/// bit-identical to a whole-batch two-matmul pass *on the same kernel*;
/// with the scalar kernel the result is bit-identical to the
/// pre-kernel-layer `forward_into` (dense first GEMM, sparse-aware
/// second GEMM — the ReLU output is exactly where the retained
/// `av == 0.0` skip pays).
///
/// `x` is `(rows, d)`, `w_in` is `(d, h)`, `w_out` is `(h, d)`,
/// `out` must hold `rows * d`; `scratch` is the caller's reusable
/// hidden-block arena.
#[allow(clippy::too_many_arguments)]
pub fn ffn_forward(
    kern: &dyn MatmulKernel,
    x: &[f32],
    rows: usize,
    d: usize,
    h: usize,
    w_in: &[f32],
    w_out: &[f32],
    scratch: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(x.len(), rows * d);
    assert_eq!(w_in.len(), d * h);
    assert_eq!(w_out.len(), h * d);
    assert_eq!(out.len(), rows * d);
    if rows == 0 {
        return;
    }
    // hidden block sized to stay L2-resident: ~128 KiB of f32
    let rb = (32 * 1024 / h.max(1)).clamp(1, rows);
    scratch.clear();
    scratch.resize(rb * h, 0.0);
    let mut r0 = 0;
    while r0 < rows {
        let rblk = rb.min(rows - r0);
        let hid = &mut scratch[..rblk * h];
        kern.matmul(&x[r0 * d..(r0 + rblk) * d], w_in, hid, rblk, d, h);
        for v in hid.iter_mut() {
            *v = v.max(0.0);
        }
        kern.matmul_sparse(hid, w_out, &mut out[r0 * d..(r0 + rblk) * d], rblk, h, d);
        r0 += rblk;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn selection_is_stable_and_listed() {
        let a = Kernel::select().name();
        let b = Kernel::selected_name();
        assert_eq!(a, b, "selection must be cached, not re-resolved");
        assert!(
            Kernel::available().iter().any(|k| k.name() == a),
            "selected kernel {a} missing from available()"
        );
        assert_eq!(Kernel::scalar().name(), "scalar");
        assert!(Kernel::by_name("scalar").is_some());
        assert!(Kernel::by_name("no-such-kernel").is_none());
    }

    #[test]
    fn available_kernels_agree_on_small_shapes_within_budget() {
        // cross-kernel agreement on the dispatch surface itself; the
        // exhaustive per-shape oracle tests live in rust/tests/kernels.rs
        prop::forall("kernels agree", |rng| {
            let m = prop::dim(rng, 1, 7);
            let k = prop::dim(rng, 1, 40);
            let n = prop::dim(rng, 1, 40);
            let a = prop::vec_f32(rng, m * k, 1.0);
            let b = prop::vec_f32(rng, k * n, 1.0);
            let mut want = vec![0f32; m * n];
            Kernel::scalar().matmul(&a, &b, &mut want, m, k, n);
            for kern in Kernel::available() {
                let mut got = vec![0f32; m * n];
                kern.matmul(&a, &b, &mut got, m, k, n);
                for (g, w) in got.iter().zip(want.iter()) {
                    assert!(
                        (g - w).abs() <= 1e-4 * w.abs().max(1.0),
                        "{}: {g} vs {w}",
                        kern.name()
                    );
                }
            }
        });
    }

    #[test]
    fn ffn_forward_matches_unfused_reference() {
        prop::forall("fused ffn", |rng| {
            let rows = prop::dim(rng, 1, 9);
            let d = prop::dim(rng, 1, 12);
            let h = prop::dim(rng, 1, 20);
            let x = prop::vec_f32(rng, rows * d, 1.0);
            let w_in = prop::vec_f32(rng, d * h, 0.5);
            let w_out = prop::vec_f32(rng, h * d, 0.5);
            for kern in Kernel::available() {
                // unfused: whole-batch matmul → relu → matmul, same kernel
                let mut hid = vec![0f32; rows * h];
                kern.matmul(&x, &w_in, &mut hid, rows, d, h);
                for v in hid.iter_mut() {
                    *v = v.max(0.0);
                }
                let mut want = vec![0f32; rows * d];
                kern.matmul_sparse(&hid, &w_out, &mut want, rows, h, d);

                let mut scratch = Vec::new();
                let mut got = vec![0f32; rows * d];
                ffn_forward(
                    kern, &x, rows, d, h, &w_in, &w_out, &mut scratch, &mut got,
                );
                // row blocking is bit-identical (rows independent)
                for (g, w) in got.iter().zip(want.iter()) {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "{}: fused ffn drifted from unfused",
                        kern.name()
                    );
                }
            }
        });
    }

    #[test]
    fn ffn_forward_handles_empty_batches() {
        let mut scratch = Vec::new();
        let mut out: Vec<f32> = Vec::new();
        ffn_forward(
            Kernel::select(), &[], 0, 4, 8, &[0.0; 32], &[0.0; 32],
            &mut scratch, &mut out,
        );
        assert!(out.is_empty());
    }
}
