//! AVX2 + FMA kernel (x86_64).
//!
//! 8-lane `f32` with `_mm256_fmadd_ps`; the main GEMM tiles 32 output
//! columns across 4 ymm accumulators and reuses them over a k-block, so
//! each `b` panel row is loaded once per 32 outputs and the accumulators
//! never round-trip through memory inside the block.  Every entry point
//! keeps the kernel-layer invariants (row independence; per-element
//! reduction order fixed by `l` ascending) but *contracts* each
//! multiply-add (FMA keeps the product unrounded), so results are
//! error-budgeted against the scalar oracle, not bit-equal to it.
//!
//! All `unsafe` is confined to private `#[target_feature]` functions;
//! the safe trait wrappers assert slice lengths and the runtime check
//! lives in [`supported`] (callers go through `Kernel::by_name` /
//! `Kernel::select`, which only hand out this kernel when
//! [`supported`] is true).

use super::MatmulKernel;
use std::arch::x86_64::*;

/// Runtime gate: both `avx2` (integer/shuffle ops) and `fma` are
/// required.
pub fn supported() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// See the module docs.
pub struct Avx2Kernel;

impl MatmulKernel for Avx2Kernel {
    fn name(&self) -> &'static str {
        "avx2"
    }

    fn matmul(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), k * n);
        assert_eq!(out.len(), m * n);
        unsafe { matmul_avx2(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, k, n) }
    }

    fn matmul_tn(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), m * n);
        assert_eq!(out.len(), k * n);
        unsafe { matmul_tn_avx2(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, k, n) }
    }

    fn matmul_nt(&self, a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
        assert_eq!(a.len(), m * k);
        assert_eq!(b.len(), n * k);
        assert_eq!(out.len(), m * n);
        unsafe { matmul_nt_avx2(a.as_ptr(), b.as_ptr(), out.as_mut_ptr(), m, n, k) }
    }

    #[allow(clippy::too_many_arguments)]
    fn matmul_q8(
        &self,
        a: &[f32],
        q: &[i8],
        scales: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        assert_eq!(a.len(), m * k);
        assert_eq!(q.len(), k * n);
        assert_eq!(scales.len(), n);
        assert_eq!(out.len(), m * n);
        unsafe {
            matmul_q8_avx2(
                a.as_ptr(),
                q.as_ptr(),
                scales.as_ptr(),
                out.as_mut_ptr(),
                m,
                k,
                n,
            )
        }
    }
}

/// Horizontal sum of 8 lanes.  Lane-pairwise (lo+hi halves, then a
/// movehl/shuffle tree) — part of the fixed per-element reduction order
/// of [`matmul_nt_avx2`].
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps(v, 1);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
    _mm_cvtss_f32(s)
}

/// `out (m,n) = a (m,k) · b (k,n)` — 32-wide register tiles over a
/// k-block (see module docs).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_avx2(a: *const f32, b: *const f32, out: *mut f32, m: usize, k: usize, n: usize) {
    std::ptr::write_bytes(out, 0, m * n);
    const KB: usize = 128;
    let mut kb = 0;
    while kb < k {
        let k_end = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.add(i * k);
            let orow = out.add(i * n);
            let mut j = 0;
            while j + 32 <= n {
                let mut acc0 = _mm256_loadu_ps(orow.add(j));
                let mut acc1 = _mm256_loadu_ps(orow.add(j + 8));
                let mut acc2 = _mm256_loadu_ps(orow.add(j + 16));
                let mut acc3 = _mm256_loadu_ps(orow.add(j + 24));
                for l in kb..k_end {
                    let av = _mm256_set1_ps(*arow.add(l));
                    let brow = b.add(l * n + j);
                    acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(brow), acc0);
                    acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(brow.add(8)), acc1);
                    acc2 = _mm256_fmadd_ps(av, _mm256_loadu_ps(brow.add(16)), acc2);
                    acc3 = _mm256_fmadd_ps(av, _mm256_loadu_ps(brow.add(24)), acc3);
                }
                _mm256_storeu_ps(orow.add(j), acc0);
                _mm256_storeu_ps(orow.add(j + 8), acc1);
                _mm256_storeu_ps(orow.add(j + 16), acc2);
                _mm256_storeu_ps(orow.add(j + 24), acc3);
                j += 32;
            }
            while j + 8 <= n {
                let mut acc = _mm256_loadu_ps(orow.add(j));
                for l in kb..k_end {
                    let av = _mm256_set1_ps(*arow.add(l));
                    acc = _mm256_fmadd_ps(av, _mm256_loadu_ps(b.add(l * n + j)), acc);
                }
                _mm256_storeu_ps(orow.add(j), acc);
                j += 8;
            }
            while j < n {
                let mut acc = *orow.add(j);
                for l in kb..k_end {
                    acc = (*arow.add(l)).mul_add(*b.add(l * n + j), acc);
                }
                *orow.add(j) = acc;
                j += 1;
            }
        }
        kb += KB;
    }
}

/// `out (k,n) += aᵀ · b` — broadcast-axpy per `(i, l)` pair, 8-wide
/// over `n`.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_tn_avx2(
    a: *const f32,
    b: *const f32,
    out: *mut f32,
    m: usize,
    k: usize,
    n: usize,
) {
    for i in 0..m {
        let arow = a.add(i * k);
        let brow = b.add(i * n);
        for l in 0..k {
            let av = *arow.add(l);
            let avv = _mm256_set1_ps(av);
            let orow = out.add(l * n);
            let mut j = 0;
            while j + 8 <= n {
                let o = _mm256_loadu_ps(orow.add(j));
                let bb = _mm256_loadu_ps(brow.add(j));
                _mm256_storeu_ps(orow.add(j), _mm256_fmadd_ps(avv, bb, o));
                j += 8;
            }
            while j < n {
                *orow.add(j) = av.mul_add(*brow.add(j), *orow.add(j));
                j += 1;
            }
        }
    }
}

/// `out (m,n) = a (m,k) · bᵀ (n,k)` — 8-lane dot products with a fixed
/// lane-pairwise horizontal reduction, scalar tail folded in last.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_nt_avx2(
    a: *const f32,
    b: *const f32,
    out: *mut f32,
    m: usize,
    n: usize,
    k: usize,
) {
    for i in 0..m {
        let arow = a.add(i * k);
        for j in 0..n {
            let brow = b.add(j * k);
            let mut acc = _mm256_setzero_ps();
            let mut l = 0;
            while l + 8 <= k {
                acc = _mm256_fmadd_ps(
                    _mm256_loadu_ps(arow.add(l)),
                    _mm256_loadu_ps(brow.add(l)),
                    acc,
                );
                l += 8;
            }
            let mut s = hsum256(acc);
            while l < k {
                s = (*arow.add(l)).mul_add(*brow.add(l), s);
                l += 1;
            }
            *out.add(i * n + j) = s;
        }
    }
}

/// Int8 GEMM: 8 weights at a time via
/// `_mm_loadl_epi64 → _mm256_cvtepi8_epi32 → _mm256_cvtepi32_ps`, FMA
/// against the broadcast activation, per-column scales applied once
/// after the full k-reduction (same contract as
/// [`crate::kernels::scalar::matmul_q8`]).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn matmul_q8_avx2(
    a: *const f32,
    q: *const i8,
    scales: *const f32,
    out: *mut f32,
    m: usize,
    k: usize,
    n: usize,
) {
    std::ptr::write_bytes(out, 0, m * n);
    const KB: usize = 128;
    let mut kb = 0;
    while kb < k {
        let k_end = (kb + KB).min(k);
        for i in 0..m {
            let arow = a.add(i * k);
            let orow = out.add(i * n);
            let mut j = 0;
            while j + 8 <= n {
                let mut acc = _mm256_loadu_ps(orow.add(j));
                for l in kb..k_end {
                    let av = _mm256_set1_ps(*arow.add(l));
                    let qv = _mm_loadl_epi64(q.add(l * n + j) as *const __m128i);
                    let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv));
                    acc = _mm256_fmadd_ps(av, qf, acc);
                }
                _mm256_storeu_ps(orow.add(j), acc);
                j += 8;
            }
            while j < n {
                let mut acc = *orow.add(j);
                for l in kb..k_end {
                    acc = (*arow.add(l)).mul_add(*q.add(l * n + j) as f32, acc);
                }
                *orow.add(j) = acc;
                j += 1;
            }
        }
        kb += KB;
    }
    for i in 0..m {
        let orow = out.add(i * n);
        let mut j = 0;
        while j + 8 <= n {
            let o = _mm256_loadu_ps(orow.add(j));
            let s = _mm256_loadu_ps(scales.add(j));
            _mm256_storeu_ps(orow.add(j), _mm256_mul_ps(o, s));
            j += 8;
        }
        while j < n {
            *orow.add(j) *= *scales.add(j);
            j += 1;
        }
    }
}
