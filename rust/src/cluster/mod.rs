//! Cluster simulator: the distributed-hardware model behind the paper's
//! efficiency numbers (Tables 1, 7, 8 TFLOPS/GPU columns and the §3
//! shrinking-batch / network-bandwidth analysis).
//!
//! The paper trained on 16–128 Tesla K40s.  We cannot, so the simulator
//! computes what the paper's §5.1 "Computational Efficiency" section
//! computes: FLOPs from the model's op counts divided by a *modelled* step
//! time, where the step time comes from (a) per-device dense compute,
//! (b) per-expert-shard MoE compute given the REAL dispatch sizes produced
//! by the rust router, and (c) all-to-all bytes over a finite-bandwidth
//! interconnect.  The shapes the paper reports (dense baselines ~1.2
//! TFLOPS/GPU, MoE ~0.7–1.1, degradation at extreme expert counts) emerge
//! from those three terms.

pub mod perf;
pub mod topology;

pub use perf::{ClusterSpec, DeviceSpec, StepTiming};
pub use topology::{
    model_cluster_step, AllToAllCost, ClusterStepTiming, LinkSpec, Topology,
};
