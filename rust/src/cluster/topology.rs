//! Multi-host cluster topology: two link tiers (intra-host, inter-host)
//! with per-link bandwidth and per-message latency, priced against the
//! *measured* per-link all-to-all traffic the dispatcher tracks
//! ([`LinkTraffic`], from [`DispatchPlan::network_bytes_by_link`]).
//!
//! This is the GShard-style view of the paper's §3.2 network concern:
//! the all-to-all is cheap while the experts fit one host's PCIe
//! complex, then the inter-host fabric (an order of magnitude less
//! bandwidth, an order of magnitude more per-message latency) takes
//! over as the expert count — and with it the device count — grows.
//! Because the traffic matrix comes from a real [`DispatchPlan`], the
//! model prices exactly the routes the corrected accounting says cross
//! the interconnect: a token dispatched to an expert on its own shard
//! costs nothing anywhere in this module.
//!
//! [`DispatchPlan`]: crate::coordinator::dispatcher::DispatchPlan
//! [`DispatchPlan::network_bytes_by_link`]:
//!     crate::coordinator::dispatcher::DispatchPlan::network_bytes_by_link

use crate::cluster::perf::DeviceSpec;
use crate::coordinator::dispatcher::LinkTraffic;
use crate::coordinator::scheduler::ShardLayout;

/// One link tier: sustainable point-to-point bandwidth plus the fixed
/// per-message cost (latency, framing, kernel hand-off).
#[derive(Clone, Copy, Debug)]
pub struct LinkSpec {
    /// bytes/s
    pub bandwidth: f64,
    /// seconds per message
    pub latency: f64,
}

/// Devices packed onto hosts: device `d` lives on host
/// `d / devices_per_host`; links within a host use the `intra` tier,
/// links between hosts the `inter` tier.
#[derive(Clone, Debug)]
pub struct Topology {
    pub n_devices: usize,
    pub devices_per_host: usize,
    pub intra: LinkSpec,
    pub inter: LinkSpec,
}

impl Topology {
    /// The paper-era testbed shape: K40s on PCIe within a host
    /// (~8 GB/s effective, microsecond messages), a 10GbE-class fabric
    /// between hosts (~1.1 GB/s effective, tens of microseconds per
    /// message).
    pub fn k40_hosts(n_devices: usize, devices_per_host: usize) -> Self {
        Topology {
            n_devices: n_devices.max(1),
            devices_per_host: devices_per_host.max(1),
            intra: LinkSpec { bandwidth: 8e9, latency: 5e-6 },
            inter: LinkSpec { bandwidth: 1.1e9, latency: 50e-6 },
        }
    }

    pub fn n_hosts(&self) -> usize {
        (self.n_devices + self.devices_per_host - 1) / self.devices_per_host
    }

    pub fn host_of(&self, device: usize) -> usize {
        device / self.devices_per_host
    }

    /// The link tier connecting two *distinct* devices.
    pub fn link(&self, src: usize, dst: usize) -> &LinkSpec {
        if self.host_of(src) == self.host_of(dst) {
            &self.intra
        } else {
            &self.inter
        }
    }

    /// Serialization time of one message batch over one link.
    fn leg_time(&self, src: usize, dst: usize, bytes: u64, msgs: u64) -> f64 {
        let l = self.link(src, dst);
        bytes as f64 / l.bandwidth + msgs as f64 * l.latency
    }

    /// Price the all-to-all described by `traffic`: all links run
    /// concurrently, but each device's egress serializes through its
    /// send port and its ingress through its receive port, so the phase
    /// lasts as long as the busiest port.  Local bytes cost nothing.
    pub fn all_to_all_time(&self, traffic: &LinkTraffic) -> AllToAllCost {
        let n = traffic.n_devices;
        assert!(
            n <= self.n_devices,
            "traffic over {n} devices on a {}-device topology",
            self.n_devices
        );
        let mut egress = vec![0f64; n];
        let mut ingress = vec![0f64; n];
        let mut cost = AllToAllCost::default();
        for (src, dst, bytes, msgs) in traffic.links() {
            let t = self.leg_time(src, dst, bytes, msgs);
            egress[src] += t;
            ingress[dst] += t;
            if self.host_of(src) == self.host_of(dst) {
                cost.intra_bytes += bytes;
            } else {
                cost.inter_bytes += bytes;
            }
            cost.messages += msgs;
        }
        cost.time = egress
            .iter()
            .chain(ingress.iter())
            .fold(0.0f64, |a, &b| a.max(b));
        cost
    }
}

/// One all-to-all phase, priced.
#[derive(Clone, Debug, Default)]
pub struct AllToAllCost {
    /// wall time of the phase: the busiest port's serialization time
    pub time: f64,
    /// interconnect bytes that stayed within a host (PCIe tier)
    pub intra_bytes: u64,
    /// interconnect bytes that crossed hosts (fabric tier)
    pub inter_bytes: u64,
    /// messages sent (replica-runs per direction)
    pub messages: u64,
}

/// Modelled wall time of one synchronous training step of the §3.1
/// scheme on the simulated cluster, built from measured dispatch state.
#[derive(Clone, Debug, Default)]
pub struct ClusterStepTiming {
    /// gating cost per device — O(gate_cols) per token, which is why
    /// hierarchical local-group routing matters at large expert counts
    pub gating_time: f64,
    /// busiest expert shard's compute (empty batches cost nothing)
    pub moe_compute_time: f64,
    /// forward + backward all-to-all over the topology
    pub all_to_all_time: f64,
    /// the forward all-to-all's per-tier breakdown
    pub a2a: AllToAllCost,
}

impl ClusterStepTiming {
    pub fn total(&self) -> f64 {
        self.gating_time + self.moe_compute_time + self.all_to_all_time
    }
}

/// Model one MoE-layer training step on the cluster.
///
/// * `gate_cols` — output columns the gating network computes per token:
///   `n_experts` for flat softmax gating, `groups + k · group_size` for
///   the two-level hierarchical gate (the O(group) routing cost).
/// * `expert_loads` — real per-expert batch sizes from the dispatch
///   plan (post-capacity if capacity dispatch was on).
/// * `traffic` — the plan's measured per-link traffic on `layout`.
pub fn model_cluster_step(
    dev: &DeviceSpec,
    topo: &Topology,
    layout: &ShardLayout,
    d_model: usize,
    expert_hidden: usize,
    gate_cols: usize,
    tokens_per_device: usize,
    expert_loads: &[usize],
    traffic: &LinkTraffic,
) -> ClusterStepTiming {
    // fwd + bwd ≈ 3× forward MACs, 2 FLOPs per MAC (paper's accounting)
    let train_mult = 3.0 * 2.0;

    let gating_flops =
        (tokens_per_device * d_model * gate_cols) as f64 * train_mult;
    let gating_time = dev.compute_time(gating_flops, tokens_per_device as f64);

    // every shard computes its experts back to back; the synchronous
    // step waits on the busiest shard
    let expert_flops_per_row = (2 * d_model * expert_hidden) as f64 * train_mult;
    let mut shard_time = vec![0f64; layout.n_devices];
    for (e, &load) in expert_loads.iter().enumerate() {
        shard_time[layout.owner(e)] +=
            dev.compute_time(expert_flops_per_row * load as f64, load as f64);
    }
    let moe_compute_time = shard_time.iter().fold(0.0f64, |a, &b| a.max(b));

    let a2a = topo.all_to_all_time(traffic);
    // the backward pass moves the same activations' gradients back
    // through the same links
    let all_to_all_time = a2a.time * 2.0;

    ClusterStepTiming { gating_time, moe_compute_time, all_to_all_time, a2a }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dispatcher::Dispatcher;
    use crate::coordinator::router::RoutingDecision;
    use crate::gating::noisy_topk::GateVec;

    fn topo(devices: usize, per_host: usize) -> Topology {
        Topology::k40_hosts(devices, per_host)
    }

    /// One replica per device, every token of replica r routed to
    /// `expert_of(r)` — a controllable traffic generator.
    fn traffic_for(
        devices: usize,
        n_experts: usize,
        rows: usize,
        d_model: usize,
        expert_of: impl Fn(usize) -> usize,
    ) -> (LinkTraffic, crate::coordinator::dispatcher::DispatchPlan) {
        let decisions: Vec<RoutingDecision> = (0..devices)
            .map(|r| RoutingDecision {
                per_token: vec![
                    GateVec {
                        experts: vec![expert_of(r)],
                        weights: vec![1.0],
                    };
                    rows
                ],
                importance: vec![0.0; n_experts],
                load: vec![0.0; n_experts],
                noise: None,
            })
            .collect();
        let plan = Dispatcher::plan(&decisions, n_experts);
        let layout = ShardLayout::new(devices, n_experts);
        (plan.network_bytes_by_link(d_model, &layout), plan)
    }

    #[test]
    fn hosts_partition_devices() {
        let t = topo(16, 8);
        assert_eq!(t.n_hosts(), 2);
        assert_eq!(t.host_of(0), 0);
        assert_eq!(t.host_of(7), 0);
        assert_eq!(t.host_of(8), 1);
        assert!((t.link(0, 7).bandwidth - t.intra.bandwidth).abs() < 1.0);
        assert!((t.link(0, 8).bandwidth - t.inter.bandwidth).abs() < 1.0);
    }

    #[test]
    fn local_traffic_is_free() {
        // every replica keeps its tokens on its own shard: nothing to
        // price, regardless of volume
        let devices = 8;
        let (traffic, plan) =
            traffic_for(devices, devices, 64, 32, |r| r);
        assert_eq!(plan.total_routes(), 8 * 64);
        let cost = topo(devices, 4).all_to_all_time(&traffic);
        assert_eq!(cost.time, 0.0);
        assert_eq!(cost.intra_bytes + cost.inter_bytes, 0);
        assert!(traffic.local_bytes > 0);
    }

    #[test]
    fn inter_host_hops_cost_more_than_intra() {
        // same byte volume, one hop within the host vs one across hosts
        let devices = 4;
        let t = topo(devices, 2);
        let (intra, _) = traffic_for(devices, devices, 32, 16, |r| {
            if r == 0 { 1 } else { r } // device 0 -> device 1 (same host)
        });
        let (inter, _) = traffic_for(devices, devices, 32, 16, |r| {
            if r == 0 { 2 } else { r } // device 0 -> device 2 (other host)
        });
        let c_intra = t.all_to_all_time(&intra);
        let c_inter = t.all_to_all_time(&inter);
        assert!(c_intra.time > 0.0);
        assert!(
            c_inter.time > c_intra.time * 2.0,
            "inter {} vs intra {}",
            c_inter.time,
            c_intra.time
        );
        assert_eq!(c_intra.inter_bytes, 0);
        assert_eq!(c_inter.intra_bytes, 0);
        assert_eq!(c_intra.intra_bytes, c_inter.inter_bytes);
    }

    #[test]
    fn a2a_time_scales_with_bytes() {
        let devices = 4;
        let t = topo(devices, 2);
        let (small, _) = traffic_for(devices, devices, 16, 16, |r| {
            (r + 1) % devices
        });
        let (large, _) = traffic_for(devices, devices, 160, 16, |r| {
            (r + 1) % devices
        });
        let c_small = t.all_to_all_time(&small);
        let c_large = t.all_to_all_time(&large);
        assert!(c_large.time > c_small.time);
        assert_eq!(c_large.inter_bytes, 10 * c_small.inter_bytes);
    }

    #[test]
    fn cluster_step_prices_imbalance_and_drops() {
        let devices = 4;
        let n = 8;
        let t = topo(devices, 2);
        let layout = ShardLayout::new(devices, n);
        let (traffic, _) =
            traffic_for(devices, n, 32, 16, |r| (2 * r + 3) % n);
        let dev = DeviceSpec::k40();
        let balanced = model_cluster_step(
            &dev, &t, &layout, 16, 32, n, 32, &[16; 8], &traffic,
        );
        let mut skewed_loads = [0usize; 8];
        skewed_loads[0] = 128;
        let skewed = model_cluster_step(
            &dev, &t, &layout, 16, 32, n, 32, &skewed_loads, &traffic,
        );
        assert!(balanced.total().is_finite() && balanced.total() > 0.0);
        assert!(
            skewed.moe_compute_time > balanced.moe_compute_time,
            "one hot shard must bound the step"
        );
        // empty expert batches cost nothing (the capacity-drop path
        // produces them routinely): all-empty loads price to zero, and
        // a shard full of empty batches charges no kernel overhead
        let empty = model_cluster_step(
            &dev, &t, &layout, 16, 32, n, 32, &[0; 8], &traffic,
        );
        assert_eq!(empty.moe_compute_time, 0.0);
        let mut one_shard = [0usize; 8];
        one_shard[0] = 16;
        one_shard[1] = 16;
        let sparse = model_cluster_step(
            &dev, &t, &layout, 16, 32, n, 32, &one_shard, &traffic,
        );
        assert_eq!(sparse.moe_compute_time, balanced.moe_compute_time);
        // hierarchical gating (fewer gate columns) beats flat at scale
        let flat = model_cluster_step(
            &dev, &t, &layout, 16, 32, 4096, 32, &[16; 8], &traffic,
        );
        let hier = model_cluster_step(
            &dev, &t, &layout, 16, 32, 64 + 2 * 64, 32, &[16; 8], &traffic,
        );
        assert!(hier.gating_time < flat.gating_time);
    }
}
