//! Device and interconnect performance model.
//!
//! Calibrated to the paper's testbed: Tesla K40 (4.29 TFLOPS peak,
//! NVIDIA's number quoted in §5.1), achievable dense-GEMM efficiency ~30%
//! (the paper's dense baselines observe 1.07–1.29 TFLOPS/GPU), PCIe-era
//! interconnect ~ 8 GB/s effective per device.  The model is deliberately
//! simple — three additive terms per step — because that is exactly the
//! granularity of the paper's own analysis (§3.1–3.2).

use crate::runtime::ModelConfig;

#[derive(Clone, Copy, Debug)]
pub struct DeviceSpec {
    /// peak multiply-add throughput, FLOPs/s (MAC = 2 FLOPs)
    pub peak_flops: f64,
    /// fraction of peak achievable on large dense GEMMs
    pub gemm_efficiency: f64,
    /// fixed per-kernel launch / sync overhead (s)
    pub kernel_overhead: f64,
    /// effective all-to-all bandwidth per device, bytes/s
    pub net_bandwidth: f64,
}

impl DeviceSpec {
    /// Tesla K40 as §5.1 describes it.
    pub fn k40() -> Self {
        DeviceSpec {
            peak_flops: 4.29e12,
            gemm_efficiency: 0.30,
            kernel_overhead: 50e-6,
            net_bandwidth: 8e9,
        }
    }

    /// Dense-compute time for `flops` at a given achieved-batch fraction:
    /// small batches cannot fill the device, which is the §3.1 shrinking
    /// batch effect.  `batch_rows` is the GEMM's row count; utilisation
    /// rises ~sqrt(rows) (K40-era GEMM behaviour: latency-bound at small
    /// row counts, saturating around 64 rows).  A linear fill model would
    /// make step time independent of how tokens distribute over experts —
    /// the sqrt keeps the §3.1 imbalance cost real.
    pub fn compute_time(&self, flops: f64, batch_rows: f64) -> f64 {
        if batch_rows <= 0.0 {
            // an empty batch launches no kernel at all — the
            // capacity-drop dispatch path produces these routinely
            return 0.0;
        }
        let fill = (batch_rows / 64.0).sqrt().min(1.0).max(1.0 / 32.0);
        flops / (self.peak_flops * self.gemm_efficiency * fill)
            + self.kernel_overhead
    }

    pub fn transfer_time(&self, bytes: f64) -> f64 {
        bytes / self.net_bandwidth
    }
}

#[derive(Clone, Debug)]
pub struct ClusterSpec {
    pub device: DeviceSpec,
    pub n_devices: usize,
}

impl ClusterSpec {
    pub fn k40s(n_devices: usize) -> Self {
        ClusterSpec { device: DeviceSpec::k40(), n_devices }
    }
}

/// Timing breakdown for one synchronous training step (§3.1 scheme: the
/// same devices act as data-parallel replicas and expert shards).
#[derive(Clone, Debug, Default)]
pub struct StepTiming {
    pub dense_time: f64,
    pub moe_compute_time: f64,
    pub all_to_all_time: f64,
}

impl StepTiming {
    pub fn total(&self) -> f64 {
        self.dense_time + self.moe_compute_time + self.all_to_all_time
    }
}

/// Model a synchronous step.
///
/// * `cfg` — model config (op counts, expert sizes).
/// * `cluster` — devices.
/// * `tokens_per_device` — dense-layer batch per replica (b in §3.1).
/// * `expert_loads` — tokens routed to each expert this step (REAL sizes
///   from the router; the max shard determines MoE time because the step
///   is synchronous).
pub fn model_step(
    cfg: &ModelConfig,
    cluster: &ClusterSpec,
    tokens_per_device: usize,
    expert_loads: &[usize],
) -> StepTiming {
    let dev = &cluster.device;
    let d = cluster.n_devices.max(1);
    let macs_to_flops = 2.0;
    // fwd + bwd ~= 3x forward MACs (paper's TFLOPS accounting)
    let train_mult = 3.0 * macs_to_flops;

    // --- dense layers: data-parallel, per device ---
    let expert_macs_per_token =
        (cfg.k_effective * 2 * cfg.d_model * cfg.expert_hidden) as f64;
    let dense_macs_per_token =
        cfg.ops_per_timestep as f64 - expert_macs_per_token
            + (cfg.d_model * cfg.vocab) as f64; // include softmax like §5.1
    let dense_flops =
        dense_macs_per_token * tokens_per_device as f64 * train_mult;
    let dense_time = dev.compute_time(dense_flops, tokens_per_device as f64);

    // --- MoE: model-parallel shards; sync step waits for the max shard ---
    let experts_per_device = (cfg.n_experts + d - 1) / d.max(1);
    let mut shard_tokens = vec![0usize; d];
    for (e, &load) in expert_loads.iter().enumerate() {
        shard_tokens[(e / experts_per_device.max(1)).min(d - 1)] += load;
    }
    let expert_flops_per_token =
        (2 * cfg.d_model * cfg.expert_hidden) as f64 * train_mult;
    let moe_compute_time = shard_tokens
        .iter()
        .map(|&t| {
            if t == 0 {
                0.0
            } else {
                // per-expert batches on the shard: t tokens split across
                // that shard's active experts; row count per GEMM is the
                // per-expert batch (the §3.1 kb/n term)
                let per_expert =
                    t as f64 / experts_per_device.max(1) as f64;
                dev.compute_time(expert_flops_per_token * t as f64, per_expert)
            }
        })
        .fold(0.0f64, f64::max);

    // --- all-to-all: every routed token moves d_model activations in and
    //     out, twice (fwd + bwd), 4 bytes each (§3.2) ---
    let routed: usize = expert_loads.iter().sum();
    let bytes = routed as f64 * cfg.d_model as f64 * 4.0 * 2.0 * 2.0;
    let all_to_all_time = dev.transfer_time(bytes / d as f64);

    StepTiming { dense_time, moe_compute_time, all_to_all_time }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(n_experts: usize, k: usize) -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 2048,
            d_model: 64,
            lstm_hidden: 64,
            lstm_proj: 0,
            middle: "moe".into(),
            n_experts,
            k,
            groups: 0,
            expert_hidden: 256,
            capacity: 64,
            k_effective: k,
            batch: 32,
            seq_len: 16,
            w_importance: 0.1,
            w_load: 0.1,
            ops_per_timestep: (2 * 2 * 4 * (64 * 64 + 64 * 64)
                + k * 2 * 64 * 256) as u64,
            moe_params: (n_experts * 2 * 64 * 256) as u64,
            optimizer: "adam".into(),
        }
    }

    #[test]
    fn empty_batch_costs_nothing() {
        // a zero-row expert batch must not be charged kernel overhead or
        // floor-fill FLOPs (no kernel is launched for it)
        let dev = DeviceSpec::k40();
        assert_eq!(dev.compute_time(0.0, 0.0), 0.0);
        assert_eq!(dev.compute_time(1e9, 0.0), 0.0);
        // and the smallest non-empty batch still pays overhead
        assert!(dev.compute_time(1.0, 1.0) >= dev.kernel_overhead);
    }

    #[test]
    fn balanced_beats_imbalanced() {
        let c = cfg(16, 4);
        let cluster = ClusterSpec::k40s(4);
        let balanced = model_step(&c, &cluster, 512, &[128; 16]);
        let mut skewed = vec![16usize; 16];
        skewed[0] = 2048 - 15 * 16;
        let imbalanced = model_step(&c, &cluster, 512, &skewed);
        assert!(imbalanced.moe_compute_time > balanced.moe_compute_time);
        assert!(imbalanced.total() > balanced.total());
    }

    #[test]
    fn all_to_all_scales_with_routed_tokens() {
        let c = cfg(8, 2);
        let cluster = ClusterSpec::k40s(2);
        let a = model_step(&c, &cluster, 256, &[64; 8]);
        let b = model_step(&c, &cluster, 256, &[128; 8]);
        assert!(b.all_to_all_time > a.all_to_all_time * 1.5);
    }

    #[test]
    fn shrinking_batch_hurts_efficiency() {
        // same total routed tokens across many more experts => smaller
        // per-expert batches => worse MoE time (the §3.1 effect)
        let cluster = ClusterSpec::k40s(4);
        let few = cfg(8, 4);
        let many = cfg(512, 4);
        let t_few = model_step(&few, &cluster, 512, &[256; 8]);
        let t_many = model_step(&many, &cluster, 512, &[4; 512]);
        assert!(
            t_many.moe_compute_time > t_few.moe_compute_time,
            "many {:?} vs few {:?}",
            t_many,
            t_few
        );
    }

    #[test]
    fn dense_time_dominated_models_hit_decent_tflops() {
        // sanity: a dense-ish config should land near the K40 dense
        // efficiency band when converted to TFLOPS
        let c = cfg(4, 4);
        let cluster = ClusterSpec::k40s(1);
        let tokens = 4096usize;
        let t = model_step(&c, &cluster, tokens, &[tokens; 4]);
        let flops = (c.ops_per_timestep as f64
            + (c.d_model * c.vocab) as f64)
            * tokens as f64
            * 6.0;
        let tflops = flops / t.total() / 1e12;
        assert!(tflops > 0.2 && tflops < 4.29, "tflops {tflops}");
    }
}
